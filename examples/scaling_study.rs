//! Scaling study — emits the Fig. 9 dataset: live strong-scaling
//! measurements on the in-process testbed (reduced size) and the modeled
//! projection at paper scale (256^3 cube, batch 256, sphere d=128, up to
//! 1024 GPUs), as CSV on stdout.
//!
//! Run: `cargo run --release --example scaling_study > fig9.csv`

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{NonBatchedLoop, PencilPlan, PlaneWavePlan, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{fig9_row, grid_2d, Machine, Variant, Workload};
use fftb::util::stats::bench;

fn main() {
    // ------------------------------------------------ live, reduced size
    let n = 32usize;
    let nb = 8usize;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());

    println!("# section,live");
    println!("p,slab1d_batched_s,slab1d_nonbatched_s,pencil2d_batched_s,planewave_s");
    for p in [1usize, 2, 4, 8] {
        let off2 = Arc::clone(&off);
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let backend = RustFftBackend::new();
            let slab = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let pw = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();

            let input = phased(slab.input_len(), 3);
            let t_slab = bench(2, 5, || {
                let _ = slab.forward(&backend, input.clone());
            })
            .mean()
            .as_secs_f64();
            let t_loop = bench(1, 3, || {
                let _ = looped.forward(&backend, input.clone());
            })
            .mean()
            .as_secs_f64();
            let pw_in = phased(pw.input_len(), 5);
            let t_pw = bench(2, 5, || {
                let _ = pw.forward(&backend, pw_in.clone());
            })
            .mean()
            .as_secs_f64();

            // 2D grid where the rank count factors.
            let (p0, p1) = grid_2d(p);
            let t_pencil = if p0 > 1 || p1 > 1 {
                let g2 = ProcGrid::new(&[p0, p1], comm).unwrap();
                let pencil = PencilPlan::new([n, n, n], nb, Arc::clone(&g2)).unwrap();
                let pin = phased(pencil.input_len(), 6);
                bench(2, 5, || {
                    let _ = pencil.forward(&backend, pin.clone());
                })
                .mean()
                .as_secs_f64()
            } else {
                t_slab
            };
            (t_slab, t_loop, t_pencil, t_pw)
        });
        let worst = |f: fn(&(f64, f64, f64, f64)) -> f64| {
            rows.iter().map(f).fold(0.0, f64::max)
        };
        println!(
            "{p},{:.6},{:.6},{:.6},{:.6}",
            worst(|r| r.0),
            worst(|r| r.1),
            worst(|r| r.2),
            worst(|r| r.3)
        );
    }

    // ------------------------------------------- modeled, paper scale
    let nn = 256usize;
    let spec = SphereSpec::new([nn, nn, nn], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [nn, nn, nn], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();

    println!("# section,modeled (perlmutter-a100 estimate)");
    println!(
        "p,{}",
        Variant::all().map(|v| format!("{}_s", v.label())).join(",")
    );
    let mut p = 4;
    while p <= 1024 {
        let row = fig9_row(&w, p, &m);
        println!(
            "{p},{}",
            row.iter().map(|t| format!("{t:.5}")).collect::<Vec<_>>().join(",")
        );
        p *= 2;
    }
}
