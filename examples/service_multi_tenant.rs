//! END-TO-END: the multi-tenant transform service — two distributed SCF
//! solvers plus one raw batched-sphere stream sharing ONE coalesced
//! transform world.
//!
//! The scenario (CI runs this on p=2 as a smoke test):
//!
//! 1. an [`ScfServiceDriver`] hosts tenants "scf-a" (2 bands) and "scf-b"
//!    (3 bands) on the same plane-wave sphere — each lockstep iteration
//!    runs FIVE coalesced flushes total, no matter how many tenants
//!    (three band flushes plus the Hartree inverse/forward round trip);
//! 2. a third tenant, "aux-bands", submits raw sphere transforms through
//!    [`TransformService`] *before* each `step`, so its jobs ride the
//!    iteration's first forward flush — three tenants, one fused exchange;
//! 3. a deliberately under-provisioned tenant, "greedy", shows typed
//!    admission: the checkout past its one-slot quota returns
//!    [`ServiceError::QuotaExhausted`] (never a panic, never an unbounded
//!    queue), and dropping the outstanding slot frees the charge.
//!
//! Validation gates: charge conservation for both SCF tenants, every
//! coalesced flush serving >= 2 tenants (the first forward flush of each
//! iteration serving all 3), steady-state iterations with `plan_cache_hit`
//! and zero `alloc_bytes`, and per-tenant p50/p95/p99 latency percentiles
//! present in the service's [`MetricsSink`].
//!
//! Run: `cargo run --release --example service_multi_tenant [--p N]
//!       [--iters K]`

use fftb::comm::communicator::run_world;
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfServiceDriver};
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::plan::testutil::phased;
use fftb::service::{ServiceConfig, ServiceError};

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let p = arg_usize("--p", 2);
    let iters = arg_usize("--iters", 4);

    let n = 12usize; // FFT grid
    let a = 8.0; // cell (bohr)
    let ecut = 2.0; // hartree
    let aux_bands = 2usize; // the raw tenant's bands per iteration

    println!("multi-tenant transform service");
    println!("{n}^3 grid, a={a} bohr, ecut={ecut} Ha, {p} ranks, {iters} iterations");
    println!("tenants: scf-a (2 bands) + scf-b (3 bands) + aux-bands ({aux_bands} raw)");
    println!();

    let out = run_world(p, move |comm| {
        let lat = Lattice::new(a, n, ecut);
        let backend = RustFftBackend::new();
        let mut driver = ScfServiceDriver::new(&lat, &comm, ServiceConfig::default())
            .expect("the service must assemble on this world");

        let base = ScfOptions { max_iters: iters, tol: 0.0, ..Default::default() };
        driver
            .add_tenant(
                "scf-a",
                lat.clone(),
                2,
                &GaussianWells::single(1.0, 1.5),
                &comm,
                base.clone(),
            )
            .expect("tenant scf-a must register");
        driver
            .add_tenant(
                "scf-b",
                lat.clone(),
                3,
                &GaussianWells::single(3.0, 1.2),
                &comm,
                ScfOptions { seed: 7, ..base },
            )
            .expect("tenant scf-b must register");

        let lane = driver.lane();
        let aux = driver.service_mut().register_tenant("aux-bands");

        // --- typed admission: a one-slot tenant refused past its quota.
        let slot_bytes = driver.service().slot_bytes(lane).expect("the sphere lane exists");
        let greedy = driver.service_mut().register_tenant_with_quota("greedy", slot_bytes);
        let held = driver
            .service_mut()
            .checkout(greedy, lane, Direction::Forward)
            .expect("the first checkout fits the one-slot quota");
        let refused = driver.service_mut().checkout(greedy, lane, Direction::Forward);
        let quota_err = match refused {
            Err(e @ ServiceError::QuotaExhausted { .. }) => format!("{e}"),
            Err(e) => panic!("expected QuotaExhausted, got {e:?}"),
            Ok(_) => panic!("the over-quota checkout must be refused"),
        };
        drop(held); // recycling the slot frees the whole charge...
        assert_eq!(driver.service().tenant_charged(greedy), 0, "drop must release the quota");
        // ...so the same checkout now succeeds (and is dropped unused).
        driver
            .service_mut()
            .checkout(greedy, lane, Direction::Forward)
            .expect("the freed quota must admit the retry");

        // --- the lockstep loop: aux submits BEFORE each step, so its raw
        // bands coalesce into the iteration's first forward flush.
        let mut aux_done = 0usize;
        for it in 0..iters {
            for b in 0..aux_bands as u64 {
                let mut slot = driver
                    .service_mut()
                    .checkout(aux, lane, Direction::Forward)
                    .expect("aux checkout fits the default quota");
                let src = phased(slot.len(), it as u64 * aux_bands as u64 + b);
                slot.data_mut().copy_from_slice(&src);
                driver
                    .service_mut()
                    .submit(aux, lane, Direction::Forward, slot)
                    .expect("aux submit fits the in-flight window");
            }
            driver.step(&backend).expect("the lockstep iteration must run");
            let got = driver.service_mut().collect(aux);
            assert_eq!(got.len(), aux_bands, "aux bands lost in the coalesced flush");
            aux_done += got.len();
        }
        let results = driver.results();

        // --- audit trail: every flush coalesced, the first of each
        // iteration across all three tenants.
        let recs: Vec<_> = driver.service().flush_records().to_vec();
        assert_eq!(recs.len(), 5 * iters, "five coalesced flushes per iteration");
        for (i, r) in recs.iter().enumerate() {
            assert!(r.tenants >= 2, "flush {i} served a single tenant");
        }
        for it in 0..iters {
            let chunk = &recs[5 * it..5 * (it + 1)];
            assert_eq!(chunk[0].tenants, 3, "iteration {it}: aux missed the forward flush");
            assert_eq!(chunk[0].jobs, 2 + 3 + aux_bands, "iteration {it}: wrong batch size");
            // The Hartree round trip coalesces one density job per active
            // SCF tenant: an inverse (r->G) then a forward (G->r) flush.
            assert_eq!(chunk[3].dir, Direction::Inverse, "iteration {it}: Hartree order");
            assert_eq!(chunk[4].dir, Direction::Forward, "iteration {it}: Hartree order");
            for r in &chunk[3..] {
                assert_eq!(r.jobs, 2, "iteration {it}: one Hartree job per SCF tenant");
            }
        }

        let metrics_rows: Vec<String> = driver
            .service()
            .metrics()
            .tenant_metrics()
            .iter()
            .filter(|t| t.requests > 0)
            .map(|t| {
                assert!(t.p50().is_some() && t.p95().is_some() && t.p99().is_some());
                t.one_line()
            })
            .collect();
        let messages = driver.service().metrics().total_messages();
        (results, recs, metrics_rows, quota_err, aux_done, messages)
    });

    let (results, recs, metrics_rows, quota_err, aux_done, messages) = &out[0];

    println!("== admission ==");
    println!("greedy tenant refused past its quota: {quota_err}");
    println!("(dropping the outstanding slot freed the charge; the retry was admitted)");
    println!();

    println!("== coalesced flushes (rank 0 audit trail) ==");
    println!(
        "{:>5} {:>8} {:>5} {:>8} {:>9} {:>7} {:>6}",
        "flush", "dir", "jobs", "tenants", "messages", "cache", "alloc"
    );
    for (i, r) in recs.iter().enumerate() {
        println!(
            "{:>5} {:>8?} {:>5} {:>8} {:>9} {:>7} {:>6}",
            i, r.dir, r.jobs, r.tenants, r.messages, r.plan_cache_hit, r.alloc_bytes
        );
    }
    println!();

    // --- validation gates (the CI smoke step relies on these).
    for (r, (results_r, _, _, _, _, _)) in out.iter().enumerate() {
        for res in results_r {
            let nb = res.eigenvalues.len();
            for s in &res.history {
                assert!(
                    (s.charge - nb as f64).abs() < 1e-6,
                    "rank {r}: charge drift at iter {}",
                    s.iter
                );
            }
            let last = res.history.last().expect("the run must record history");
            assert!(last.plan_cache_hit, "rank {r}: steady state re-planned");
            assert_eq!(last.alloc_bytes, 0, "rank {r}: steady state allocated");
        }
    }
    assert_eq!(*aux_done, iters * aux_bands, "aux must get every band back");

    println!("== SCF tenants ==");
    for res in results {
        println!(
            "{} bands: charge {:.8}, residual {:.3e} after {} iterations",
            res.eigenvalues.len(),
            res.history.last().map(|s| s.charge).unwrap_or(0.0),
            res.history.last().map(|s| s.max_residual).unwrap_or(0.0),
            res.iterations
        );
    }
    println!();

    println!("== per-tenant metrics ({messages} fused-exchange messages total) ==");
    for row in metrics_rows {
        println!("{row}");
    }
    println!();
    println!("service_multi_tenant OK");
}
