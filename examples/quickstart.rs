//! Quickstart — the paper's Fig. 5/6 flow in rust:
//! create a processing grid, describe the input/output tensors with
//! distribution strings, let the planner pick the stages, execute, and
//! verify against the single-node substrate.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fft::complex::max_abs_diff;
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::domain::{Domain, DomainList};
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::{gather_cube_z, phased, scatter_cube_x};
use fftb::fftb::plan::Fftb;
use fftb::fftb::tensor::DistTensor;

fn main() {
    let n = 64usize;
    let p = 4usize;
    println!("distributed 3D FFT of size {n}^3 on a 1D grid of {p} ranks");

    // A reference answer from the single-node substrate.
    let global = phased(n * n * n, 1);
    let mut want = global.clone();
    fftb::fft::nd::fft_3d(&mut want, [n, n, n], Direction::Forward);

    let global2 = global.clone();
    let outs = run_world(p, move |comm| {
        // --- paper Fig. 6, line by line ---
        // create processing grid
        let g = ProcGrid::new(&[p], comm).unwrap();

        // create input tensor, distributed in the x-dimension
        let dom = || Domain::new(vec![0, 0, 0], vec![n as i64 - 1; 3]).unwrap();
        let mut ti = DistTensor::zeros(
            DomainList::new(vec![dom()]).unwrap(),
            "x{0} y z",
            Arc::clone(&g),
        )
        .unwrap();

        // create output tensor, distributed in the z-dimension
        let to = DistTensor::zeros(
            DomainList::new(vec![dom()]).unwrap(),
            "X Y Z{0}",
            Arc::clone(&g),
        )
        .unwrap();

        // create fft operation
        let fx = Fftb::plan([n, n, n], &to, "X Y Z", &ti, "x y z", Arc::clone(&g)).unwrap();
        if g.rank() == 0 {
            println!("planner selected: {}", fx.kind.name());
        }

        // load this rank's slice and execute
        ti.local = scatter_cube_x(&global2, 1, [n, n, n], p, g.rank());
        let backend = RustFftBackend::new();
        let (out, trace) = fx.execute(&backend, ti.local.clone(), Direction::Forward);
        if g.rank() == 0 {
            print!("{}", trace.summary());
        }
        out
    });

    let got = gather_cube_z(&outs, 1, [n, n, n], p);
    let err = max_abs_diff(&got, &want);
    println!("max abs error vs single-node FFT: {err:.3e}");
    assert!(err < 1e-8 * (n * n * n) as f64);
    println!("quickstart OK");
}
