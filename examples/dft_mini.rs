//! END-TO-END driver: a mini plane-wave DFT calculation run entirely through
//! the FFTB stack — the full-system validation workload (DESIGN.md §5 E2E).
//!
//! A toy two-atom system in a cubic supercell: Gaussian-well pseudopotential,
//! plane-wave basis from an energy cutoff (Eq. 8-9), all-band preconditioned
//! eigensolve (Eq. 10) where every Hamiltonian application runs one batched
//! forward + inverse plane-wave transform (the Fig. 9 red-line workload),
//! followed by a density build and charge check.
//!
//! Logs the convergence curve; EXPERIMENTS.md records a reference run.
//!
//! Run: `cargo run --release --example dft_mini [--pjrt]`

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::dft::{build_density, solve_bands, EigenOptions, GaussianWells, Hamiltonian, Lattice};
use fftb::fftb::backend::{LocalFftBackend, RustFftBackend};
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::StageKind;
use fftb::runtime::{PjrtFftBackend, PjrtRuntime};
use fftb::util::prng::Prng;

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let n = 24usize; // FFT grid
    let a = 12.0; // cell (bohr)
    let ecut = 3.0; // hartree
    let nb = 8usize; // bands
    let p = 4usize; // ranks

    let backend: Arc<dyn LocalFftBackend> = if use_pjrt {
        match PjrtRuntime::open("artifacts") {
            Ok(rt) => Arc::new(PjrtFftBackend::new(Arc::new(rt))),
            Err(e) => {
                eprintln!("warning: PJRT unavailable ({e}); falling back to the rust backend");
                Arc::new(RustFftBackend::new())
            }
        }
    } else {
        Arc::new(RustFftBackend::new())
    };
    println!("mini DFT: {n}^3 grid, a={a} bohr, ecut={ecut} Ha, {nb} bands, {p} ranks");
    println!("backend: {}", backend.name());

    let t0 = std::time::Instant::now();
    let backend2 = Arc::clone(&backend);
    let results = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
        let lat = Lattice::new(a, n, ecut);
        let n_pw = lat.n_pw();
        let pot = GaussianWells::dimer(3.0, 1.3, 0.35);
        let h = Hamiltonian::new(lat, nb, &pot, grid);

        let mut psi = Prng::new(42 + comm.rank() as u64).complex_vec(nb * h.n_local());
        let res = solve_bands(
            &h,
            backend2.as_ref(),
            &comm,
            &mut psi,
            &EigenOptions { max_iters: 250, tol: 1e-6, ..Default::default() },
        );
        let density = build_density(&h, backend2.as_ref(), &comm, &psi);

        // Count the FFT work one H application performs.
        let (_, traces) = h.apply(backend2.as_ref(), &psi);
        let fft_stages: usize = traces
            .iter()
            .map(|t| t.stages.iter().filter(|s| s.kind == StageKind::Compute).count())
            .sum();
        (res, n_pw, density.charge, fft_stages)
    });
    let elapsed = t0.elapsed();

    let (res, n_pw, charge, fft_stages) = &results[0];
    println!();
    println!("plane waves per band : {n_pw}");
    println!("eigensolver iterations: {} ({elapsed:?} wall)", res.iterations);
    println!("FFT compute stages per H-apply: {fft_stages}");
    println!();
    println!("convergence (max band residual):");
    for (it, r) in res.history.iter().enumerate() {
        if it % 10 == 0 || it + 1 == res.history.len() {
            println!("  iter {it:>4}: {r:.3e}");
        }
    }
    println!();
    println!("band energies (hartree):");
    for (b, (ev, rn)) in res.eigenvalues.iter().zip(&res.residuals).enumerate() {
        println!("  band {b}: eps = {ev:+.6}   |r| = {rn:.2e}");
    }
    println!();
    println!("density charge: {charge:.8} (expect {nb})");

    // Validation gates for CI use.
    assert!((charge - nb as f64).abs() < 1e-6, "charge conservation");
    assert!(
        res.eigenvalues.windows(2).all(|w| w[0] <= w[1] + 1e-9),
        "eigenvalues sorted"
    );
    assert!(res.eigenvalues[0] < 0.0, "dimer must bind the lowest band");
    let final_res = res.history.last().unwrap();
    let initial_res = res.history.first().unwrap();
    assert!(final_res < &(initial_res * 1e-2), "residual must drop >100x");
    println!("dft_mini OK");
}
