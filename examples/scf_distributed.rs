//! END-TO-END: the distributed SCF loop driven through the autotuner —
//! every transform requested via `Fftb::plan_auto_scf`, decisions shared
//! across iterations and ranks through a wisdom file, steady-state
//! iterations pure plan-cache hits executing warmed workspaces.
//!
//! The example runs the loop TWICE in the same process tree:
//!
//! 1. a cold run — the tuner searches (or measures, with
//!    `--empirical`), records the decision to wisdom, and writes the file;
//! 2. a warm "restart" — a fresh tuner loads the wisdom file and the very
//!    first plan request is decided without any search.
//!
//! Validation gates (CI runs this on p=2 as a smoke test): charge
//! conservation every iteration, all-rank agreement on the tuner's
//! decision, steady-state iterations with `plan_cache_hit` and zero
//! `alloc_bytes`, and the warm run's decision coming from wisdom.
//!
//! With `--worker` the example appends the depth-2 smoke: the same SCF on
//! a pinned plane-wave plan with the exchange's helper worker thread
//! enabled (bit-identical to worker-off), then the coordinator's two-deep
//! software pipeline pushed through batched flushes (depth 2 bit-identical
//! to depth 1, overlap reported). CI runs this section on p=2.
//!
//! With `--converge` the example appends the convergence gate: a long SCF
//! run on the small smoke lattice that must drive `max_residual` below
//! 1e-8 and whose total energy must decrease monotonically once the
//! density mixing has settled (`delta_rho/nb < 1e-3`). CI runs this
//! section on p=2.
//!
//! Run: `cargo run --release --example scf_distributed [--p N] [--iters K]
//!       [--empirical] [--wisdom PATH] [--worker] [--converge]`

use std::path::PathBuf;
use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::comm::CommTuning;
use fftb::coordinator::{BatchingDriver, MetricsSink, TransformJob};
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfRunner};
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{Fftb, PlanKind, PlaneWavePlan};

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let p = arg_usize("--p", 2);
    let iters = arg_usize("--iters", 6);
    let empirical = std::env::args().any(|a| a == "--empirical");
    let worker_smoke = std::env::args().any(|a| a == "--worker");
    let converge = std::env::args().any(|a| a == "--converge");
    let wisdom_path: PathBuf = std::env::args()
        .collect::<Vec<_>>()
        .iter()
        .position(|a| a == "--wisdom")
        .and_then(|i| std::env::args().nth(i + 1))
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("fftb_scf_wisdom_p{p}.json"))
        });
    std::fs::remove_file(&wisdom_path).ok(); // start genuinely cold

    let n = 16usize; // FFT grid
    let a = 10.0; // cell (bohr)
    let ecut = 2.5; // hartree
    let nb = 4usize; // bands

    println!("distributed SCF through the autotuner");
    println!("{n}^3 grid, a={a} bohr, ecut={ecut} Ha, {nb} bands, {p} ranks");
    println!("wisdom: {}", wisdom_path.display());
    println!();

    let opts = ScfOptions {
        max_iters: iters,
        tol: 0.0, // run the full budget so the steady state is visible
        coupling: 0.3,
        empirical_top_k: if empirical { 3 } else { 0 },
        wisdom_path: Some(wisdom_path.clone()),
        ..Default::default()
    };

    // ---- cold run: search (or measure), execute, persist wisdom.
    let t0 = std::time::Instant::now();
    let opts2 = opts.clone();
    let cold = run_world(p, move |comm| {
        let lat = Lattice::new(a, n, ecut);
        let backend = RustFftBackend::new();
        let pot = GaussianWells::dimer(3.0, 1.3, 0.35);
        let mut runner = ScfRunner::new(lat, nb, &pot, &comm, &backend, opts2.clone())
            .expect("the tuner must find a feasible plan");
        let res = runner.run(&backend);
        let mut sink = MetricsSink::new(format!("rank {}", comm.rank()));
        for t in runner.drain_traces() {
            sink.record(t);
        }
        (res, sink.cache_hit_rate(), sink.total_alloc_bytes())
    });
    let cold_wall = t0.elapsed();

    let (res, hit_rate, alloc) = &cold[0];
    println!("== cold run ({cold_wall:?}) ==");
    println!(
        "tuner picked: {} (window {}, from_wisdom={}, measured={})",
        res.plan_kind, res.window, res.from_wisdom, res.measured
    );
    println!(
        "{:>5} {:>14} {:>12} {:>12} {:>14} {:>10} {:>8}",
        "iter", "charge", "delta_rho", "residual", "energy", "cache", "alloc"
    );
    for s in &res.history {
        println!(
            "{:>5} {:>14.8} {:>12.3e} {:>12.3e} {:>14.8} {:>10} {:>8}",
            s.iter,
            s.charge,
            s.delta_rho,
            s.max_residual,
            s.energy.total,
            s.plan_cache_hit,
            s.alloc_bytes
        );
    }
    let e = &res.energy;
    println!(
        "energy breakdown: kinetic {:.8}  external {:.8}  hartree {:.8}  mean-field {:.8}  \
         total {:.8}",
        e.kinetic, e.external, e.hartree, e.mean_field, e.total
    );
    println!("plan-cache hit rate over all transforms: {hit_rate:.2}, alloc {alloc} B");
    println!();

    // ---- validation gates (the CI smoke step relies on these).
    for (r, (res_r, _, _)) in cold.iter().enumerate() {
        assert_eq!(
            (&res_r.plan_kind, res_r.window),
            (&res.plan_kind, res.window),
            "rank {r} disagrees with rank 0 on the tuner decision"
        );
        for s in &res_r.history {
            assert!((s.charge - nb as f64).abs() < 1e-6, "charge drift at iter {}", s.iter);
        }
        for s in res_r.history.iter().skip(1) {
            assert!(s.plan_cache_hit, "iter {} re-planned", s.iter);
            assert_eq!(s.alloc_bytes, 0, "iter {} allocated", s.iter);
        }
    }
    assert!(!res.from_wisdom, "the cold run must have searched");
    assert!(res.measured == empirical, "measurement must follow --empirical");
    assert!(wisdom_path.exists(), "rank 0 must persist the wisdom file");

    // ---- warm restart: a fresh process life, seeded by the wisdom file.
    let opts3 = opts.clone();
    let warm = run_world(p, move |comm| {
        let lat = Lattice::new(a, n, ecut);
        let backend = RustFftBackend::new();
        let pot = GaussianWells::dimer(3.0, 1.3, 0.35);
        let mut runner = ScfRunner::new(lat, nb, &pot, &comm, &backend, opts3.clone())
            .expect("the tuner must find a feasible plan");
        runner.run(&backend)
    });
    println!("== warm restart ==");
    println!(
        "decision: {} (window {}), from_wisdom={}",
        warm[0].plan_kind, warm[0].window, warm[0].from_wisdom
    );
    for w in &warm {
        assert!(w.from_wisdom, "the warm run must decide from the wisdom file");
        assert_eq!((&w.plan_kind, w.window), (&res.plan_kind, res.window));
        assert!((w.density.charge - nb as f64).abs() < 1e-6);
    }
    std::fs::remove_file(&wisdom_path).ok();

    // ---- depth-2 worker smoke (opt-in: --worker; CI runs it on p=2).
    if worker_smoke {
        // The tuner owns the worker axis in the runs above; pinning the
        // plan is what lets this section force it both ways and assert the
        // threaded exchange changes nothing but the clock.
        let scf_mode = move |worker: bool| {
            move |comm: fftb::comm::Comm| {
                let lat = Lattice::new(a, n, ecut);
                let backend = RustFftBackend::new();
                let pot = GaussianWells::dimer(3.0, 1.3, 0.35);
                let grid = ProcGrid::new(&[comm.size()], comm.clone()).unwrap();
                let plan = PlaneWavePlan::new(Arc::clone(&lat.offsets), nb, grid).unwrap();
                let mut fx = Fftb { kind: PlanKind::PlaneWave(plan), sizes: [n, n, n], nb };
                fx.set_comm_tuning(CommTuning::with_window(2).with_worker(worker));
                let opts = ScfOptions {
                    max_iters: iters,
                    tol: 0.0,
                    coupling: 0.3,
                    ..Default::default()
                };
                let mut runner =
                    ScfRunner::with_plan(lat, nb, &pot, &comm, Arc::new(fx), opts)
                        .expect("the pinned plane-wave plan must assemble");
                let res = runner.run(&backend);
                (res.eigenvalues, res.density.rho, res.density.charge)
            }
        };
        let off = run_world(p, scf_mode(false));
        let on = run_world(p, scf_mode(true));
        for (r, ((ev_off, rho_off, _), (ev_on, rho_on, charge))) in
            off.iter().zip(&on).enumerate()
        {
            assert!((charge - nb as f64).abs() < 1e-6, "worker SCF: charge drift on rank {r}");
            for (x, y) in ev_off.iter().zip(ev_on) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r}: eigenvalue differs under worker");
            }
            for (x, y) in rho_off.iter().zip(rho_on) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r}: density differs under worker");
            }
        }

        // Two-deep coordinator pipeline over batched flushes: depth 2 must
        // return exactly what depth 1 returns, in the same order.
        assert!(
            n % p == 0,
            "--worker pipeline smoke assumes an even slab split (p must divide {n})"
        );
        let pipe = |depth: usize| {
            run_world(p, move |comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let mut driver = BatchingDriver::new([n, n, n], Arc::clone(&grid))
                    .with_pipeline_depth(depth);
                let per_band = n * n * n / p;
                let mut got = Vec::new();
                for round in 0..3u64 {
                    for i in 0..nb as u64 {
                        let id = round * nb as u64 + i;
                        driver.submit(TransformJob {
                            id,
                            data: phased(per_band, id),
                            dir: Direction::Forward,
                        });
                    }
                    driver.flush(&backend, Direction::Forward);
                    got.extend(driver.drain_completed());
                }
                let overlap: u64 =
                    driver.drain_traces().iter().map(|t| t.pipeline_overlap_ns).sum();
                (got, overlap)
            })
        };
        let d1 = pipe(1);
        let d2 = pipe(2);
        let mut overlap_total = 0u64;
        for (r, ((g1, ov1), (g2, ov2))) in d1.iter().zip(&d2).enumerate() {
            assert_eq!(*ov1, 0, "rank {r}: depth 1 must report no pipeline overlap");
            assert_eq!(g1.len(), g2.len(), "rank {r}: result count differs across depths");
            for ((id1, v1), (id2, v2)) in g1.iter().zip(g2) {
                assert_eq!(id1, id2, "rank {r}: the pipeline reordered results");
                for (x, y) in v1.iter().zip(v2) {
                    assert_eq!(x.re.to_bits(), y.re.to_bits(), "rank {r} job {id1}: re differs");
                    assert_eq!(x.im.to_bits(), y.im.to_bits(), "rank {r} job {id1}: im differs");
                }
            }
            overlap_total += ov2;
        }
        println!("== depth-2 worker smoke ==");
        println!(
            "worker-on SCF bit-identical to worker-off; depth-2 pipeline bit-identical \
             to depth 1 (overlap {overlap_total} ns across ranks)"
        );
    }

    // ---- convergence gate (opt-in: --converge; CI runs it on p=2).
    if converge {
        // The small smoke lattice (the one tests/scf_distributed.rs pins),
        // run long enough for the residual to bottom out: the loop may
        // early-exit on the density tolerance, and the gates below demand
        // a genuinely converged fixed point, not just a settled mixer.
        let cn = 12usize;
        let ca = 8.0;
        let cecut = 2.0;
        let cnb = 2usize;
        let citers = arg_usize("--converge-iters", 1200);
        let outs = run_world(p, move |comm| {
            let lat = Lattice::new(ca, cn, cecut);
            let backend = RustFftBackend::new();
            let pot = GaussianWells::single(2.0, 1.4);
            let opts = ScfOptions {
                max_iters: citers,
                tol: 1e-12,
                coupling: 0.3,
                ..Default::default()
            };
            let mut runner = ScfRunner::new(lat, cnb, &pot, &comm, &backend, opts)
                .expect("the tuner must find a feasible plan");
            runner.run(&backend)
        });
        let r0 = &outs[0];
        let last = r0.history.last().expect("the convergence run must iterate");
        println!("== convergence gate ==");
        println!(
            "{cn}^3 grid, {cnb} bands: {} iterations, final residual {:.3e}, \
             final energy {:.10}",
            r0.iterations, last.max_residual, r0.energy.total
        );
        for (r, res_r) in outs.iter().enumerate() {
            let fin = res_r.history.last().unwrap();
            assert!(
                fin.max_residual < 1e-8,
                "rank {r}: residual stalled at {:.3e} after {} iterations",
                fin.max_residual,
                res_r.iterations
            );
            // Once the density mixing has settled, the total energy must
            // walk downhill to the fixed point (tiny fp slack).
            let settle = res_r
                .history
                .iter()
                .position(|s| s.delta_rho / cnb as f64 < 1e-3)
                .expect("the mixer must settle below 1e-3");
            for w in res_r.history[settle..].windows(2) {
                assert!(
                    w[1].energy.total <= w[0].energy.total + 1e-7,
                    "rank {r}: energy rose {:.3e} -> {:.3e} at iter {}",
                    w[0].energy.total,
                    w[1].energy.total,
                    w[1].iter
                );
            }
            // All ranks agree on the converged energy to the last bit.
            assert_eq!(
                res_r.energy.total.to_bits(),
                r0.energy.total.to_bits(),
                "rank {r}: converged energy differs from rank 0"
            );
        }
        println!("residual < 1e-8 and energy monotone after settling on all {p} ranks");
    }

    println!();
    println!("scf_distributed OK");
}
