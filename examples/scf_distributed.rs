//! END-TO-END: the distributed SCF loop driven through the autotuner —
//! every transform requested via `Fftb::plan_auto_scf`, decisions shared
//! across iterations and ranks through a wisdom file, steady-state
//! iterations pure plan-cache hits executing warmed workspaces.
//!
//! The example runs the loop TWICE in the same process tree:
//!
//! 1. a cold run — the tuner searches (or measures, with
//!    `--empirical`), records the decision to wisdom, and writes the file;
//! 2. a warm "restart" — a fresh tuner loads the wisdom file and the very
//!    first plan request is decided without any search.
//!
//! Validation gates (CI runs this on p=2 as a smoke test): charge
//! conservation every iteration, all-rank agreement on the tuner's
//! decision, steady-state iterations with `plan_cache_hit` and zero
//! `alloc_bytes`, and the warm run's decision coming from wisdom.
//!
//! Run: `cargo run --release --example scf_distributed [--p N] [--iters K]
//!       [--empirical] [--wisdom PATH]`

use std::path::PathBuf;

use fftb::comm::communicator::run_world;
use fftb::coordinator::MetricsSink;
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfRunner};
use fftb::fftb::backend::RustFftBackend;

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let p = arg_usize("--p", 2);
    let iters = arg_usize("--iters", 6);
    let empirical = std::env::args().any(|a| a == "--empirical");
    let wisdom_path: PathBuf = std::env::args()
        .collect::<Vec<_>>()
        .iter()
        .position(|a| a == "--wisdom")
        .and_then(|i| std::env::args().nth(i + 1))
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("fftb_scf_wisdom_p{p}.json"))
        });
    std::fs::remove_file(&wisdom_path).ok(); // start genuinely cold

    let n = 16usize; // FFT grid
    let a = 10.0; // cell (bohr)
    let ecut = 2.5; // hartree
    let nb = 4usize; // bands

    println!("distributed SCF through the autotuner");
    println!("{n}^3 grid, a={a} bohr, ecut={ecut} Ha, {nb} bands, {p} ranks");
    println!("wisdom: {}", wisdom_path.display());
    println!();

    let opts = ScfOptions {
        max_iters: iters,
        tol: 0.0, // run the full budget so the steady state is visible
        coupling: 0.3,
        empirical_top_k: if empirical { 3 } else { 0 },
        wisdom_path: Some(wisdom_path.clone()),
        ..Default::default()
    };

    // ---- cold run: search (or measure), execute, persist wisdom.
    let t0 = std::time::Instant::now();
    let opts2 = opts.clone();
    let cold = run_world(p, move |comm| {
        let lat = Lattice::new(a, n, ecut);
        let backend = RustFftBackend::new();
        let pot = GaussianWells::dimer(3.0, 1.3, 0.35);
        let mut runner = ScfRunner::new(lat, nb, &pot, &comm, &backend, opts2.clone())
            .expect("the tuner must find a feasible plan");
        let res = runner.run(&backend);
        let mut sink = MetricsSink::new(format!("rank {}", comm.rank()));
        for t in runner.drain_traces() {
            sink.record(t);
        }
        (res, sink.cache_hit_rate(), sink.total_alloc_bytes())
    });
    let cold_wall = t0.elapsed();

    let (res, hit_rate, alloc) = &cold[0];
    println!("== cold run ({cold_wall:?}) ==");
    println!(
        "tuner picked: {} (window {}, from_wisdom={}, measured={})",
        res.plan_kind, res.window, res.from_wisdom, res.measured
    );
    println!(
        "{:>5} {:>14} {:>12} {:>12} {:>10} {:>8}",
        "iter", "charge", "delta_rho", "residual", "cache", "alloc"
    );
    for s in &res.history {
        println!(
            "{:>5} {:>14.8} {:>12.3e} {:>12.3e} {:>10} {:>8}",
            s.iter, s.charge, s.delta_rho, s.max_residual, s.plan_cache_hit, s.alloc_bytes
        );
    }
    println!("plan-cache hit rate over all transforms: {hit_rate:.2}, alloc {alloc} B");
    println!();

    // ---- validation gates (the CI smoke step relies on these).
    for (r, (res_r, _, _)) in cold.iter().enumerate() {
        assert_eq!(
            (&res_r.plan_kind, res_r.window),
            (&res.plan_kind, res.window),
            "rank {r} disagrees with rank 0 on the tuner decision"
        );
        for s in &res_r.history {
            assert!((s.charge - nb as f64).abs() < 1e-6, "charge drift at iter {}", s.iter);
        }
        for s in res_r.history.iter().skip(1) {
            assert!(s.plan_cache_hit, "iter {} re-planned", s.iter);
            assert_eq!(s.alloc_bytes, 0, "iter {} allocated", s.iter);
        }
    }
    assert!(!res.from_wisdom, "the cold run must have searched");
    assert!(res.measured == empirical, "measurement must follow --empirical");
    assert!(wisdom_path.exists(), "rank 0 must persist the wisdom file");

    // ---- warm restart: a fresh process life, seeded by the wisdom file.
    let opts3 = opts.clone();
    let warm = run_world(p, move |comm| {
        let lat = Lattice::new(a, n, ecut);
        let backend = RustFftBackend::new();
        let pot = GaussianWells::dimer(3.0, 1.3, 0.35);
        let mut runner = ScfRunner::new(lat, nb, &pot, &comm, &backend, opts3.clone())
            .expect("the tuner must find a feasible plan");
        runner.run(&backend)
    });
    println!("== warm restart ==");
    println!(
        "decision: {} (window {}), from_wisdom={}",
        warm[0].plan_kind, warm[0].window, warm[0].from_wisdom
    );
    for w in &warm {
        assert!(w.from_wisdom, "the warm run must decide from the wisdom file");
        assert_eq!((&w.plan_kind, w.window), (&res.plan_kind, res.window));
        assert!((w.density.charge - nb as f64).abs() < 1e-6);
    }
    std::fs::remove_file(&wisdom_path).ok();
    println!();
    println!("scf_distributed OK");
}
