//! Plane-wave batched sphere transform — the paper's Fig. 7/8 flow:
//! build a cut-off sphere from an energy cutoff, attach its CSR offset
//! array to the input domain, and compare the staged-padding plan against
//! the pad-to-cube baseline (Fig. 2) on identical data.
//!
//! Run: `cargo run --release --example planewave_batched`

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fft::complex::max_abs_diff;
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::domain::{Domain, DomainList};
use fftb::fftb::grid::{cyclic, ProcGrid};
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{Fftb, FftbOptions};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::fftb::tensor::DistTensor;

fn main() {
    let n = 64usize; // FFT grid (cube width = 2x sphere diameter/2)
    let nb = 16usize; // wavefunction batch
    let p = 4usize;

    // Cut-off sphere of diameter n/2 (the paper's d=128-in-256 geometry).
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    println!(
        "sphere: {} of {} points ({:.1}%), cube is {:.1}x the sphere data",
        off.total(),
        n * n * n,
        100.0 * off.total() as f64 / (n * n * n) as f64,
        (n * n * n) as f64 / off.total() as f64
    );

    let outs = run_world(p, move |comm| {
        let g = ProcGrid::new(&[p], comm).unwrap();

        // --- paper Fig. 8: batch domain x sphere domain with offsets ---
        let b = Domain::new(vec![0], vec![nb as i64 - 1]).unwrap();
        let c = Domain::with_offsets(vec![0, 0, 0], vec![n as i64 - 1; 3], Arc::clone(&off))
            .unwrap();
        let ti = DistTensor::zeros(
            DomainList::new(vec![b.clone(), c]).unwrap(),
            "b x{0} y z",
            Arc::clone(&g),
        )
        .unwrap();
        let co = Domain::new(vec![0, 0, 0], vec![n as i64 - 1; 3]).unwrap();
        let to = DistTensor::zeros(
            DomainList::new(vec![b, co]).unwrap(),
            "B X Y Z{0}",
            Arc::clone(&g),
        )
        .unwrap();

        // Staged-padding plane-wave plan (the paper's contribution) ...
        let staged =
            Fftb::plan([n, n, n], &to, "X Y Z", &ti, "x y z", Arc::clone(&g)).unwrap();
        // ... and the pad-to-cube baseline (Fig. 2) on the same tensors.
        let padded = Fftb::plan_opt(
            [n, n, n],
            &to,
            "X Y Z",
            &ti,
            "x y z",
            Arc::clone(&g),
            FftbOptions { pad_sphere_to_cube: true, ..Default::default() },
        )
        .unwrap();

        let input = phased(staged.input_len(), 100 + g.rank() as u64);
        let backend = RustFftBackend::new();
        let (out_a, tr_a) = staged.execute(&backend, input.clone(), Direction::Forward);
        let (out_b, tr_b) = padded.execute(&backend, input.clone(), Direction::Forward);
        let err = max_abs_diff(&out_a, &out_b);

        // Round trip through the staged inverse.
        let (back, _) = staged.execute(&backend, out_a, Direction::Inverse);
        let rt_err = max_abs_diff(&back, &input);

        if g.rank() == 0 {
            println!("staged plan : {}", staged.kind.name());
            println!("padded plan : {}", padded.kind.name());
        }
        (
            err,
            rt_err,
            tr_a.comm_bytes(),
            tr_b.comm_bytes(),
            tr_a.total_time(),
            tr_b.total_time(),
        )
    });

    let lzc = cyclic::local_count(n, p, 0);
    let _ = lzc;
    let (err, rt_err, staged_bytes, padded_bytes, staged_t, padded_t) = outs[0].clone();
    println!("staged == padded numerics: max abs diff {err:.3e}");
    println!("round-trip error: {rt_err:.3e}");
    println!(
        "bytes on the wire per rank: staged {staged_bytes} vs padded {padded_bytes} ({:.1}x less)",
        padded_bytes as f64 / staged_bytes as f64
    );
    println!("wall time (rank 0): staged {staged_t:?} vs padded {padded_t:?}");
    assert!(err < 1e-6);
    assert!(rt_err < 1e-9);
    assert!(staged_bytes * 3 < padded_bytes);
    println!("planewave_batched OK");
}
