//! END-TO-END: real-input (r2c/c2r) sphere transforms and k-point offset
//! bases through the fused exchange.
//!
//! The scenario (CI runs this on p=2 as a smoke test):
//!
//! 1. the same Γ-point sphere goes through both the r2c plan
//!    ([`RealPlaneWavePlan`]) and the c2c plan ([`PlaneWavePlan`]) with
//!    identical coefficients — the gathered half-spectrum must match the
//!    c2c cube on the Hermitian-unique bins `kz < nz/2 + 1` to a relative
//!    1e-12;
//! 2. the `ExecTrace` wire accounting must show the half-traffic exchange:
//!    summed across ranks, the r2c forward puts strictly less than 0.6x
//!    the c2c bytes on the wire (the exact ratio is `(nz/2 + 1)/nz`);
//! 3. the c2r inverse must be the exact adjoint: the round trip lands back
//!    on the real input to 1e-12;
//! 4. the tuner, asked for a real transform (`Tuner::plan_auto_real`),
//!    must pick the `plane-wave-r2c` candidate on its own;
//! 5. a Bloch-shifted basis (`SphereSpec::offset(k)`) gets its own
//!    fingerprint — its own plan/wisdom/lane identity — while `k = 0` is
//!    bit-identical to the Γ basis; the offset sphere round-trips through
//!    the c2c plan to the same tolerance.
//!
//! Run: `cargo run --release --example real_kpoint [--p N]`

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fft::complex::{max_abs_diff, Complex};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::{gather_cube_z, phased};
use fftb::fftb::plan::{PlaneWavePlan, RealPlaneWavePlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::tuner::Tuner;

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let p = arg_usize("--p", 2);
    let n = 16usize; // FFT grid per dimension
    let nb = 2usize; // bands per transform
    let nh = n / 2 + 1; // Hermitian-unique z bins
    let kappa = [0.25, 0.0, 0.0]; // the off-Γ k-point (fractional)

    assert!(p <= nh, "real plan needs p <= nz/2 + 1 (p={p}, nh={nh})");

    let spec = SphereSpec::new([n, n, n], 6.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());

    println!("real-input (r2c/c2r) + k-point sphere transforms");
    println!("{n}^3 grid, sphere of {} points, nb={nb}, {p} ranks, k = {kappa:?}", off.total());
    println!();

    let off_main = Arc::clone(&off);
    let out = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm.clone()).expect("1D grid must assemble");
        let backend = RustFftBackend::new();

        let r2c = RealPlaneWavePlan::new(Arc::clone(&off_main), nb, Arc::clone(&grid))
            .expect("r2c plan must build on this world");
        let c2c = PlaneWavePlan::new(Arc::clone(&off_main), nb, Arc::clone(&grid))
            .expect("c2c plan must build on this world");

        // Identical coefficients through both plans: the two input packings
        // share the sphere's y-outer / local-x / z-run order, so the real
        // vector and its zero-imaginary embedding describe the same field.
        let x: Vec<f64> =
            phased(r2c.input_len(), comm.rank() as u64).iter().map(|c| c.re).collect();
        let zin: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();

        let (hx, rt) = r2c.forward(&backend, x.clone());
        let (zout, ct) = c2c.forward(&backend, zin);

        // Gate 3: the c2r inverse is the exact adjoint of the r2c forward.
        let (back, _) = r2c.inverse(&backend, hx.clone());
        let rt_err = back
            .iter()
            .zip(&x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);

        // Gate 4: the tuner picks the half-spectrum family for a real
        // request (its `|r2c`-signed entry never collides with c2c).
        let mut tuner = Tuner::local();
        let tuned = tuner
            .plan_auto_real([n, n, n], nb, Arc::clone(&off_main), &comm, None)
            .expect("the real request must resolve");
        let label = tuned.choice.kind.label();

        // Gate 5: the Bloch-shifted basis has its own identity; Γ shares.
        let k_off = Arc::new(spec.offset(kappa));
        assert_eq!(
            spec.offset([0.0; 3]).fingerprint(),
            off_main.fingerprint(),
            "k = 0 must be bit-identical to the Γ basis"
        );
        assert_ne!(
            k_off.fingerprint(),
            off_main.fingerprint(),
            "a shifted k-point must salt the fingerprint"
        );
        let k_pts = k_off.total();
        let kplan = PlaneWavePlan::new(k_off, nb, grid)
            .expect("the k-point plan must build on this world");
        let kin = phased(kplan.input_len(), 7 + comm.rank() as u64);
        let (kspec, _) = kplan.forward(&backend, kin.clone());
        let (kback, _) = kplan.inverse(&backend, kspec);
        let k_err = max_abs_diff(&kback, &kin);

        (hx, zout, rt.comm_bytes(), ct.comm_bytes(), rt_err, label, k_err, k_pts)
    });

    // Gate 1: gathered half-spectrum == c2c cube on the unique bins. The
    // gathered layout is kz-outermost, so the half cube is literally the
    // full cube's prefix.
    let halves: Vec<Vec<Complex>> = out.iter().map(|o| o.0.clone()).collect();
    let fulls: Vec<Vec<Complex>> = out.iter().map(|o| o.1.clone()).collect();
    let half = gather_cube_z(&halves, nb, [n, n, nh], p);
    let full = gather_cube_z(&fulls, nb, [n, n, n], p);
    // 1e-12 relative to the spectrum's own magnitude (the unnormalized
    // forward reaches O(n_pw), so an absolute gate would mismeasure).
    let scale = full.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
    let spec_err = max_abs_diff(&half, &full[..half.len()]);
    assert!(
        spec_err <= 1e-12 * scale,
        "r2c diverged from c2c on the unique bins: {spec_err:.3e} (scale {scale:.1})"
    );

    // Gate 2: summed wire bytes strictly below 0.6x of c2c.
    let r2c_bytes: u64 = out.iter().map(|o| o.2).sum();
    let c2c_bytes: u64 = out.iter().map(|o| o.3).sum();
    if p > 1 {
        assert!(
            (r2c_bytes as f64) < 0.6 * c2c_bytes as f64,
            "r2c exchange not halved: {r2c_bytes} vs {c2c_bytes} bytes"
        );
    }

    for (rank, o) in out.iter().enumerate() {
        assert!(o.4 <= 1e-12, "rank {rank}: c2r round trip drifted: {:.3e}", o.4);
        assert_eq!(o.5, "plane-wave-r2c", "rank {rank}: tuner skipped the r2c candidate");
        assert!(o.6 <= 1e-12, "rank {rank}: k-point round trip drifted: {:.3e}", o.6);
    }

    println!("== gates ==");
    println!("r2c vs c2c on kz < {nh}:   max |diff| = {spec_err:.3e}  (<= 1e-12 rel)");
    println!("c2r round trip:           max |diff| = {:.3e}  (<= 1e-12)", out[0].4);
    println!(
        "k-point round trip:       max |diff| = {:.3e}  ({} pts at k={kappa:?})",
        out[0].6, out[0].7
    );
    println!("tuner pick for the real request: {}", out[0].5);
    if p > 1 {
        println!(
            "fused-exchange wire bytes: r2c {r2c_bytes} vs c2c {c2c_bytes}  (ratio {:.4}, gate < 0.6; exact {nh}/{n} = {:.4})",
            r2c_bytes as f64 / c2c_bytes as f64, nh as f64 / n as f64
        );
    }
    println!();
    println!("real_kpoint OK");
}
