#!/usr/bin/env sh
# Tier-1 verification + the full lane structure, invoked verbatim by
# .github/workflows/ci.yml on every push/PR. Run from anywhere.
#
# Lanes, in order (fail fast on the cheap static ones):
#   fmt            cargo fmt --check (style drift fails CI, not review)
#   clippy         warnings as errors over every target; the structural
#                  lints at odds with this tree's numeric idiom are
#                  allowed centrally in Cargo.toml [lints.clippy]
#   build          release build (tier-1)
#   test           unit + integration lanes, incl. tests/tuner.rs and
#                  tests/scf_distributed.rs (tier-1)
#   doctest        every README / docs/TUNING.md / rustdoc example runs
#                  exactly once
#   bench-compile  cargo bench --no-run: benches only build on demand and
#                  can rot otherwise
#   examples       cargo build --examples: same rot-protection for the
#                  runnable walkthroughs at examples/
#   doc            RUSTDOCFLAGS=-D warnings doc build — enforces the
#                  #![warn(missing_docs)] coverage of the comm, fftb::plan,
#                  tuner, coordinator and model trees
#   smoke          actually RUN the SCF example on p=2: the end-to-end
#                  DFT-through-the-autotuner scenario (charge conservation,
#                  steady-state plan-cache hits, zero steady-state allocs,
#                  wisdom round trip) gates every change
set -eu
cd "$(dirname "$0")/rust"
cargo fmt --check
cargo clippy --all-targets -- -D warnings
cargo build --release
cargo test -q --lib --bins --tests
cargo test --doc -q
cargo bench --no-run --quiet
cargo build --examples --release --quiet
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo run --release --quiet --example scf_distributed -- --p 2 --iters 4
echo "ci.sh: OK (fmt + clippy + build + test + doctest + bench-compile + examples + doc + scf smoke)"
