#!/usr/bin/env sh
# Tier-1 verification + the full lane structure, invoked verbatim by
# .github/workflows/ci.yml on every push/PR. Run from anywhere.
#
# Lanes, in order (fail fast on the cheap static ones):
#   fmt            cargo fmt --check (style drift fails CI, not review)
#   clippy         warnings as errors over every target; the structural
#                  lints at odds with this tree's numeric idiom are
#                  allowed centrally in Cargo.toml [lints.clippy]
#   lint           pallas-lint, the repo-specific static pass: SAFETY
#                  comments on every unsafe block, atomic-ordering
#                  rationale, no allocation inside steady-state regions,
#                  no panicking calls in library code (see rust/src/lint)
#   build          release build (tier-1)
#   test           unit + integration lanes, incl. tests/tuner.rs,
#                  tests/scf_distributed.rs and the schedule-perturbation
#                  lanes of tests/comm_schedules.rs (tier-1)
#   doctest        every README / docs/TUNING.md / rustdoc example runs
#                  exactly once
#   bench-compile  cargo bench --no-run: benches only build on demand and
#                  can rot otherwise
#   examples       cargo build --examples: same rot-protection for the
#                  runnable walkthroughs at examples/
#   doc            RUSTDOCFLAGS=-D warnings doc build — enforces the
#                  #![warn(missing_docs)] coverage of the comm, fftb::plan,
#                  tuner, coordinator and model trees
#   smoke          actually RUN the SCF example on p=2: the end-to-end
#                  DFT-through-the-autotuner scenario (charge conservation,
#                  steady-state plan-cache hits, zero steady-state allocs —
#                  now including the per-iteration Hartree round trip —
#                  wisdom round trip), plus --worker: the depth-2 pipeline
#                  smoke — the pinned-plan SCF with the exchange helper
#                  worker enabled must be bit-identical to worker-off, and
#                  the coordinator's two-deep pipeline to depth 1; plus
#                  --converge: the convergence gate — a long SCF on the
#                  smoke lattice must drive max_residual below 1e-8 with
#                  the total energy decreasing monotonically once the
#                  density mixing settles, bit-identical across p=2; then the
#                  multi-tenant service smoke on p=2: two SCF tenants plus
#                  a raw batched-sphere tenant coalescing through one
#                  service (typed quota rejection, three-tenant flushes,
#                  steady-state zero-alloc, per-tenant percentiles); then
#                  the real/k-point smoke on p=2: the r2c half-spectrum
#                  must match the c2c plan on the unique bins to 1e-12,
#                  the summed fused-exchange bytes must come in below
#                  0.6x of c2c, the tuner must pick plane-wave-r2c for
#                  the real request, and the Bloch-shifted sphere must
#                  round-trip under its own fingerprint
#
# Nightly sanitizer lanes (opt-in, PALLAS_NIGHTLY=1; PALLAS_NIGHTLY=only
# skips the stable lanes and runs just the sanitizers):
#   miri           cargo +nightly miri over the unsafe surface — the
#                  fft::complex byte/f64 reinterpret casts, the comm::arena
#                  checkout/recycle unit tests, and the comm::worker buffer
#                  handoff (ownership moves through the job channel)
#   tsan           ThreadSanitizer (-Z sanitizer=thread, -Zbuild-std) over
#                  the comm-layer unit tests: mailbox delivery, arena
#                  stress, collectives, and (via the same comm:: filter)
#                  the worker thread's channel handoff and shutdown-on-drop
#                  — the threads-as-ranks surface
# Both lanes skip with a visible notice when no nightly toolchain (or the
# miri / rust-src component) is installed, so the stable lanes never block
# on nightly availability.
set -eu
cd "$(dirname "$0")/rust"

PALLAS_NIGHTLY="${PALLAS_NIGHTLY:-}"

if [ "$PALLAS_NIGHTLY" != "only" ]; then
    cargo fmt --check
    cargo clippy --all-targets -- -D warnings
    cargo run --release --quiet --bin pallas-lint
    cargo build --release
    cargo test -q --lib --bins --tests
    cargo test --doc -q
    cargo bench --no-run --quiet
    cargo build --examples --release --quiet
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
    cargo run --release --quiet --example scf_distributed -- --p 2 --iters 4 --worker --converge
    cargo run --release --quiet --example service_multi_tenant -- --p 2 --iters 3
    cargo run --release --quiet --example real_kpoint -- --p 2
    echo "ci.sh: OK (fmt + clippy + pallas-lint + build + test + doctest + bench-compile + examples + doc + scf smoke incl. depth-2 worker + convergence gate + service smoke + real/k-point smoke)"
fi

if [ -n "$PALLAS_NIGHTLY" ]; then
    if ! rustup toolchain list 2>/dev/null | grep -q nightly; then
        echo "ci.sh: NOTICE: PALLAS_NIGHTLY set but no nightly toolchain installed — skipping miri + tsan lanes"
        exit 0
    fi
    if rustup component list --toolchain nightly 2>/dev/null | grep -q "^miri.*(installed)"; then
        # Miri over the unsafe surface: byte/f64 reinterpret casts, the
        # arena's checkout/recycle ownership dance, and the worker thread's
        # buffer handoff (an arena buffer moves through the job channel and
        # back — the driver pipeline's ownership pattern).
        MIRIFLAGS="-Zmiri-strict-provenance" \
            cargo +nightly miri test -q --lib fft::complex comm::arena comm::worker
        echo "ci.sh: miri lane OK"
    else
        echo "ci.sh: NOTICE: nightly miri component not installed — skipping miri lane"
    fi
    if rustup component list --toolchain nightly 2>/dev/null | grep -q "^rust-src.*(installed)"; then
        # TSan needs a sanitized std (-Zbuild-std) and a nightly-only
        # RUSTFLAGS; run the comm-layer unit tests where every rank is a
        # thread sharing mailboxes, the arena and the stats counters. The
        # comm:: filter also picks up comm::worker:: — the helper thread's
        # channel handoff and shutdown-on-drop run under TSan here.
        host="$(rustc -vV | sed -n 's/^host: //p')"
        RUSTFLAGS="-Z sanitizer=thread" \
            cargo +nightly test -q --lib comm:: \
            -Zbuild-std --target "$host"
        echo "ci.sh: tsan lane OK"
    else
        echo "ci.sh: NOTICE: nightly rust-src component not installed — skipping tsan lane"
    fi
fi
