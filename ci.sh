#!/usr/bin/env sh
# Tier-1 verification: build and test the rust tree with the default
# (dependency-free) feature set (the unit/integration lane includes the
# tuner integration tests in tests/tuner.rs; doc examples are split into
# their own explicit lane so each doctest runs exactly once: cargo test
# --doc covers the README quickstarts, the docs/TUNING.md walkthroughs
# included into the tuner rustdoc, and all rustdoc examples), compile
# every bench harness (cargo bench --no-run: benches otherwise only build
# on demand and can rot), then build the docs with warnings as errors
# (enforces the #![warn(missing_docs)] coverage of the comm, fftb::plan,
# tuner, coordinator and model trees). Run from anywhere.
set -eu
cd "$(dirname "$0")/rust"
cargo build --release
cargo test -q --lib --bins --tests
cargo test --doc -q
cargo bench --no-run --quiet
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "ci.sh: tier-1 OK (build + test + doctest + bench-compile + doc)"
