#!/usr/bin/env sh
# Tier-1 verification: build and test the rust tree with the default
# (dependency-free) feature set. Run from anywhere.
set -eu
cd "$(dirname "$0")/rust"
cargo build --release
cargo test -q
echo "ci.sh: tier-1 OK"
