#!/usr/bin/env sh
# Tier-1 verification: build and test the rust tree with the default
# (dependency-free) feature set, then build the docs with warnings as
# errors (enforces the #![warn(missing_docs)] coverage of the comm and
# fftb::plan trees). Run from anywhere.
set -eu
cd "$(dirname "$0")/rust"
cargo build --release
cargo test -q
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "ci.sh: tier-1 OK (build + test + doc)"
