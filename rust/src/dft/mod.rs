//! Mini plane-wave DFT application — the downstream consumer the paper's
//! plane-wave transform exists for (its §5 lists DFT-code integration as
//! future work; this module is that integration, at toy scale).
//!
//! * [`lattice`] — supercell, plane-wave basis from E_cut (Eq. 8-9).
//! * [`linalg`] — small dense complex algebra (Cholesky, Jacobi eigh).
//! * [`hamiltonian`] — kinetic + local potential via an injectable
//!   (tuner-picked) transform plan.
//! * [`eigensolver`] — all-band preconditioned steepest descent + Ritz.
//! * [`scf`] — density build, charge checks, mixing, the G-space Hartree
//!   (Poisson) solve with per-iteration energy tracking, and
//!   [`ScfRunner`]: the distributed self-consistency loop driven
//!   end-to-end through the autotuner (`Fftb::plan_auto_scf`, shared
//!   wisdom, steady-state plan-cache hits).

pub mod eigensolver;
pub mod hamiltonian;
pub mod lattice;
pub mod linalg;
pub mod scf;

pub use eigensolver::{solve_bands, EigenOptions, EigenResult};
pub use hamiltonian::{GaussianWells, Hamiltonian};
pub use lattice::Lattice;
pub use scf::{
    build_density, mix_density, poisson_scale, Density, EnergyBreakdown, ScfIterStats,
    ScfOptions, ScfResult, ScfRunner, ScfServiceDriver,
};
