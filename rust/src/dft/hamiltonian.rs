//! The Kohn-Sham-style Hamiltonian of the mini DFT app (paper Eq. 1):
//! `H psi = 1/2 |G|^2 psi + FFT^-1[ V(r) * FFT[psi] ]`.
//!
//! The kinetic term is diagonal in the plane-wave basis; the local
//! potential is diagonal in real space — so every application is exactly
//! the batched sphere->cube->sphere transform pair the paper's plane-wave
//! FFT serves (this module is the "integration with DFT codes" the paper
//! lists as future work, §5).

use std::sync::Arc;

use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::grid::{cyclic, ProcGrid};
use crate::fftb::plan::{ExecTrace, Fftb, PlanKind, PlaneWavePlan};

use super::lattice::Lattice;

/// Per-rank Hamiltonian: plan + local kinetic array + local potential slab.
///
/// The transform plan is any [`Fftb`] whose forward maps the packed
/// plane-wave sphere to this rank's dense z-slab — by default the staged
/// plane-wave plan built by [`Hamiltonian::new`], or a tuner-picked plan
/// injected through [`Hamiltonian::with_plan`] (the `ScfRunner` path,
/// where `Fftb::plan_auto_scf` chooses the decomposition and window and
/// the plan object is shared with the tuner's cache).
pub struct Hamiltonian {
    pub lattice: Lattice,
    pub nb: usize,
    pub plan: Arc<Fftb>,
    /// Kinetic 1/2 |G|^2 per local packed plane wave.
    kin: Vec<f64>,
    /// Local potential V(r) on this rank's z-slab `[nx, ny, lzc]`.
    vloc: Vec<f64>,
    grid: Arc<ProcGrid>,
}

/// A local external potential: sum of Gaussian wells.
#[derive(Clone, Debug)]
pub struct GaussianWells {
    /// (center in fractional coords, depth hartree, width bohr).
    pub wells: Vec<([f64; 3], f64, f64)>,
}

impl GaussianWells {
    /// One well in the middle of the cell — a hydrogen-ish toy atom.
    pub fn single(depth: f64, width: f64) -> Self {
        GaussianWells { wells: vec![([0.5, 0.5, 0.5], depth, width)] }
    }

    /// Two wells along the diagonal — a toy dimer.
    pub fn dimer(depth: f64, width: f64, sep_frac: f64) -> Self {
        let lo = 0.5 - sep_frac / 2.0;
        let hi = 0.5 + sep_frac / 2.0;
        GaussianWells {
            wells: vec![
                ([lo, 0.5, 0.5], depth, width),
                ([hi, 0.5, 0.5], depth, width),
            ],
        }
    }

    /// Evaluate at fractional position (periodic images of the nearest
    /// cell only — widths are small relative to the cell).
    pub fn eval(&self, a: f64, frac: [f64; 3]) -> f64 {
        let mut v = 0.0;
        for (c, depth, width) in &self.wells {
            let mut d2 = 0.0;
            for k in 0..3 {
                let mut d = (frac[k] - c[k]).abs();
                if d > 0.5 {
                    d = 1.0 - d; // minimum image
                }
                let d = d * a;
                d2 += d * d;
            }
            v -= depth * (-d2 / (2.0 * width * width)).exp();
        }
        v
    }
}

impl Hamiltonian {
    /// Build on rank `grid.rank()` of a 1D processing grid, planning the
    /// default staged plane-wave transform by hand.
    pub fn new(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        grid: Arc<ProcGrid>,
    ) -> Self {
        let n = lattice.n;
        let plan = PlaneWavePlan::new(Arc::clone(&lattice.offsets), nb, Arc::clone(&grid))
            // pallas-lint: allow(no-panic) — `Lattice` always builds a full
            // cubic grid with a centered sphere, which satisfies every
            // `PlaneWavePlan` constraint; failure is a construction bug.
            .expect("lattice grid must satisfy the plane-wave plan constraints");
        let plan = Arc::new(Fftb { kind: PlanKind::PlaneWave(plan), sizes: [n, n, n], nb });
        Self::with_plan(lattice, nb, potential, grid, plan)
    }

    /// Build around an already-constructed (e.g. tuner-picked, cached)
    /// transform plan. The plan must map `nb` bands of the lattice's
    /// plane-wave sphere to the rank's dense z-slab — exactly what
    /// [`Fftb::plan_auto_scf`] returns for
    /// `(sizes = [n, n, n], nb, sphere = lattice.offsets)`.
    pub fn with_plan(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        grid: Arc<ProcGrid>,
        plan: Arc<Fftb>,
    ) -> Self {
        assert_eq!(grid.ndim(), 1, "the mini DFT app runs on 1D grids");
        let p = grid.size();
        let r = grid.rank();
        let n = lattice.n;
        assert_eq!(plan.sizes, [n, n, n], "plan sizes must match the lattice grid");
        assert_eq!(plan.nb, nb, "plan batch count must match the band count");
        let kin = lattice.local_kinetic(p, r);
        assert_eq!(
            plan.input_len(),
            nb * kin.len(),
            "plan input layout must match the local plane-wave basis"
        );
        let vloc = Self::external_potential(&lattice, potential, p, r);
        Hamiltonian { lattice, nb, plan, kin, vloc, grid }
    }

    /// Build at Bloch vector `k` (fractional reciprocal coordinates),
    /// planning the staged plane-wave transform over the k-point sphere
    /// `lattice.kpoint_offsets(k)` by hand. At `k = [0, 0, 0]` this is
    /// [`Hamiltonian::new`] exactly.
    pub fn new_k(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        grid: Arc<ProcGrid>,
        k: [f64; 3],
    ) -> Self {
        let n = lattice.n;
        let off = lattice.kpoint_offsets(k);
        let plan = PlaneWavePlan::new(off, nb, Arc::clone(&grid))
            // pallas-lint: allow(no-panic) — the k-point sphere lives on the
            // same full cubic grid as the Γ basis, so the plane-wave plan
            // constraints hold whenever `Lattice::new` accepted the grid.
            .expect("k-point sphere must satisfy the plane-wave plan constraints");
        let plan = Arc::new(Fftb { kind: PlanKind::PlaneWave(plan), sizes: [n, n, n], nb });
        Self::with_plan_k(lattice, nb, potential, grid, plan, k)
    }

    /// [`Hamiltonian::with_plan`] at Bloch vector `k`: the kinetic diagonal
    /// becomes `1/2 |G + k|^2` walked over the k-point sphere in plan
    /// packed order ([`Lattice::local_kinetic_k`]), and the injected plan
    /// must map `nb` bands of `lattice.kpoint_offsets(k)`.
    pub fn with_plan_k(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        grid: Arc<ProcGrid>,
        plan: Arc<Fftb>,
        k: [f64; 3],
    ) -> Self {
        assert_eq!(grid.ndim(), 1, "the mini DFT app runs on 1D grids");
        let p = grid.size();
        let r = grid.rank();
        let n = lattice.n;
        assert_eq!(plan.sizes, [n, n, n], "plan sizes must match the lattice grid");
        assert_eq!(plan.nb, nb, "plan batch count must match the band count");
        let offsets = lattice.kpoint_offsets(k);
        let kin = lattice.local_kinetic_k(p, r, k, &offsets);
        assert_eq!(
            plan.input_len(),
            nb * kin.len(),
            "plan input layout must match the local k-point plane-wave basis"
        );
        let vloc = Self::external_potential(&lattice, potential, p, r);
        Hamiltonian { lattice, nb, plan, kin, vloc, grid }
    }

    /// The external potential sampled on rank `r`'s z-slab `[nx, ny, lzc]`
    /// (z cyclic over `p` ranks) — the fixed part of the SCF potential.
    pub fn external_potential(
        lattice: &Lattice,
        potential: &GaussianWells,
        p: usize,
        r: usize,
    ) -> Vec<f64> {
        let n = lattice.n;
        let lzc = cyclic::local_count(n, p, r);
        let mut vloc = vec![0.0; n * n * lzc];
        for lz in 0..lzc {
            let gz = cyclic::local_to_global(lz, p, r);
            for y in 0..n {
                for x in 0..n {
                    let frac =
                        [x as f64 / n as f64, y as f64 / n as f64, gz as f64 / n as f64];
                    vloc[x + n * (y + n * lz)] = potential.eval(lattice.a, frac);
                }
            }
        }
        vloc
    }

    /// Mutable access to the local potential slab — the SCF loop rewrites
    /// it in place every iteration (`v = v_ext + coupling * rho`) without
    /// minting a new vector. The length (the rank's z-slab) must not
    /// change.
    pub fn vloc_mut(&mut self) -> &mut [f64] {
        &mut self.vloc
    }

    /// The current local potential slab `[nx, ny, lzc]`.
    pub fn vloc(&self) -> &[f64] {
        &self.vloc
    }

    /// Local plane-wave count (per band).
    pub fn n_local(&self) -> usize {
        self.kin.len()
    }

    pub fn grid(&self) -> &Arc<ProcGrid> {
        &self.grid
    }

    pub fn kinetic(&self) -> &[f64] {
        &self.kin
    }

    /// Apply H to a band block `psi` (`[nb, n_local]`, batch fastest).
    /// Returns `H psi` and the FFT traces (for the metrics report).
    ///
    /// Zero-copy: the borrowed band block feeds the forward transform
    /// directly through [`Fftb::execute_into`] — no owned copy of `psi` is
    /// ever made — and both intermediate buffers come from the plan's
    /// recycled slot pool. Callers that are done with the returned `H psi`
    /// should hand it back via `plan.recycle` to keep steady-state loops
    /// allocation-free.
    pub fn apply(
        &self,
        backend: &dyn LocalFftBackend,
        psi: &[Complex],
    ) -> (Vec<Complex>, Vec<ExecTrace>) {
        let nb = self.nb;
        assert_eq!(psi.len(), nb * self.kin.len());

        // steady-state: hamiltonian apply
        // Potential term through the plane-wave transform pair.
        let (mut cube, grew_c) = self.plan.take_buffer(self.plan.output_len());
        let mut tr_f = self.plan.execute_into(backend, psi, &mut cube, Direction::Forward);
        tr_f.alloc_bytes += grew_c;
        for (i, chunk) in cube.chunks_exact_mut(nb).enumerate() {
            let v = self.vloc[i];
            for c in chunk {
                *c = c.scale(v);
            }
        }
        let (mut hpsi, grew_s) = self.plan.take_buffer(self.plan.input_len());
        let mut tr_i = self.plan.execute_into(backend, &cube, &mut hpsi, Direction::Inverse);
        tr_i.alloc_bytes += grew_s;
        self.plan.recycle(cube);

        // Kinetic term, diagonal in G.
        for (e, &t) in self.kin.iter().enumerate() {
            for b in 0..nb {
                let idx = b + nb * e;
                hpsi[idx] += psi[idx].scale(t);
            }
        }
        // steady-state: end
        (hpsi, vec![tr_f, tr_i])
    }

    /// Density accumulation: `n(r) += sum_b |psi_b(r)|^2` on the local slab,
    /// normalized so that the cell integral equals `nb` for orthonormal
    /// bands (`sum_G |c|^2 = 1` maps to `1/vol sum_r |psi(r)|^2 dv = 1`).
    pub fn density(&self, backend: &dyn LocalFftBackend, psi: &[Complex]) -> Vec<f64> {
        let mut rho = Vec::new();
        self.density_into(backend, psi, &mut rho);
        rho
    }

    /// [`Hamiltonian::density`] into caller-owned storage: `rho` is resized
    /// to the local slab and overwritten, the transform's dense output is
    /// recycled back into the plan's slot pool, and the execution trace is
    /// returned — this is the SCF loop's path, which must neither mint a
    /// density vector per iteration nor leak pool buffers.
    pub fn density_into(
        &self,
        backend: &dyn LocalFftBackend,
        psi: &[Complex],
        rho: &mut Vec<f64>,
    ) -> ExecTrace {
        let nb = self.nb;
        // steady-state: hamiltonian density
        let (mut cube, grew) = self.plan.take_buffer(self.plan.output_len());
        let mut trace = self.plan.execute_into(backend, psi, &mut cube, Direction::Forward);
        trace.alloc_bytes += grew;
        let npts = cube.len() / nb;
        let cell_vol = self.lattice.a.powi(3);
        // |psi(r)|^2 with psi(r) = sum_G c e^{igr}: the forward transform is
        // the unnormalized DFT, so sum_r |psi(r)|^2 = n^3 sum_G |c|^2 and
        // the per-point integral weight dv = vol/n^3 makes the cell
        // integral of n(r) equal the band count for orthonormal bands.
        let scale = 1.0 / cell_vol;
        rho.clear();
        rho.resize(npts, 0.0);
        for (i, chunk) in cube.chunks_exact(nb).enumerate() {
            let s: f64 = chunk.iter().map(|c| c.norm_sqr()).sum();
            rho[i] = s * scale;
        }
        self.plan.recycle(cube);
        // steady-state: end
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::ZERO;
    use crate::fftb::backend::RustFftBackend;

    fn setup(p: usize, f: impl Fn(&Hamiltonian, &RustFftBackend) + Send + Sync) {
        run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let lat = Lattice::new(8.0, 16, 3.0);
            let h = Hamiltonian::new(lat, 2, &GaussianWells::single(1.0, 1.5), grid);
            let backend = RustFftBackend::new();
            f(&h, &backend);
        });
    }

    #[test]
    fn free_particle_is_diagonal() {
        // V = 0: H psi = kin * psi exactly.
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let lat = Lattice::new(8.0, 16, 3.0);
            let none = GaussianWells { wells: vec![] };
            let h = Hamiltonian::new(lat, 2, &none, grid);
            let backend = RustFftBackend::new();
            let npts = h.n_local();
            let mut psi = vec![ZERO; 2 * npts];
            for (i, v) in psi.iter_mut().enumerate() {
                *v = Complex::new((i as f64 * 0.37).sin(), (i as f64 * 0.11).cos());
            }
            let (hpsi, _) = h.apply(&backend, &psi);
            for e in 0..npts {
                for b in 0..2 {
                    let idx = b + 2 * e;
                    let want = psi[idx].scale(h.kinetic()[e]);
                    assert!(
                        (hpsi[idx] - want).abs() < 1e-8 * (1.0 + want.abs()),
                        "e={e} b={b}"
                    );
                }
            }
        });
    }

    #[test]
    fn free_particle_at_k_is_diagonal() {
        // V = 0 off Γ: H psi = 1/2 |G+k|^2 psi exactly, on the k-sphere.
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let lat = Lattice::new(8.0, 16, 3.0);
            let none = GaussianWells { wells: vec![] };
            let k = [0.25, 0.0, 0.0];
            let h = Hamiltonian::new_k(lat, 2, &none, grid, k);
            let backend = RustFftBackend::new();
            let npts = h.n_local();
            let mut psi = vec![ZERO; 2 * npts];
            for (i, v) in psi.iter_mut().enumerate() {
                *v = Complex::new((i as f64 * 0.23).sin(), (i as f64 * 0.19).cos());
            }
            let (hpsi, _) = h.apply(&backend, &psi);
            for e in 0..npts {
                for b in 0..2 {
                    let idx = b + 2 * e;
                    let want = psi[idx].scale(h.kinetic()[e]);
                    assert!(
                        (hpsi[idx] - want).abs() < 1e-8 * (1.0 + want.abs()),
                        "e={e} b={b}"
                    );
                }
            }
            // The k-point kinetic differs from Γ's on this basis.
            let gamma = h.lattice.local_kinetic(2, h.grid().rank());
            assert!(h.kinetic().iter().zip(&gamma).any(|(a, b)| a != b));
        });
    }

    #[test]
    fn hamiltonian_is_hermitian_in_expectation() {
        // <phi|H psi> == conj(<psi|H phi>) after global reduction.
        use crate::comm::collectives::allreduce_sum_complex;
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 16, 3.0);
            let h = Hamiltonian::new(lat, 1, &GaussianWells::single(2.0, 1.0), grid);
            let backend = RustFftBackend::new();
            let npts = h.n_local();
            let mk = |s: f64| -> Vec<Complex> {
                (0..npts)
                    .map(|i| Complex::new((i as f64 * s).sin(), (i as f64 * s * 0.5).cos()))
                    .collect()
            };
            let psi = mk(0.17);
            let phi = mk(0.29);
            let (hpsi, _) = h.apply(&backend, &psi);
            let (hphi, _) = h.apply(&backend, &phi);
            let dot = |a: &[Complex], b: &[Complex]| -> Complex {
                let mut s = [a.iter().zip(b).map(|(x, y)| x.conj() * *y).fold(ZERO, |u, v| u + v)];
                allreduce_sum_complex(&comm, &mut s);
                s[0]
            };
            let lhs = dot(&phi, &hpsi);
            let rhs = dot(&psi, &hphi).conj();
            assert!((lhs - rhs).abs() < 1e-7 * (1.0 + lhs.abs()), "{lhs:?} vs {rhs:?}");
        });
    }

    #[test]
    fn gaussian_well_is_negative_at_center() {
        let w = GaussianWells::single(2.0, 1.0);
        assert!(w.eval(8.0, [0.5, 0.5, 0.5]) < -1.9);
        assert!(w.eval(8.0, [0.0, 0.0, 0.0]).abs() < 0.1);
    }

    #[test]
    fn apply_shapes_and_traces() {
        setup(2, |h, backend| {
            let psi = vec![ZERO; 2 * h.n_local()];
            let (hpsi, traces) = h.apply(backend, &psi);
            assert_eq!(hpsi.len(), psi.len());
            assert_eq!(traces.len(), 2); // forward + inverse
        });
    }
}
