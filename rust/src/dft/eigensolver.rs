//! All-band eigensolver (paper §2.2: "Equation 1 can be solved for all
//! psi_i wavefunctions using a Conjugate Gradient algorithm ... the
//! wavefunctions can be batched together" — Eq. 10). Blocked preconditioned
//! steepest descent with Rayleigh-Ritz rotation each iteration: the
//! all-band structure turns every inner product into an `nb x nb` matrix
//! built from one batched reduction, and every H application into one
//! batched plane-wave transform pair — exactly the workload Fig. 9's red
//! line serves.

use crate::comm::collectives::{allreduce_max_f64, allreduce_sum_complex};
use crate::comm::communicator::Comm;
use crate::fft::complex::{Complex, ZERO};
use crate::fftb::backend::LocalFftBackend;

use super::hamiltonian::Hamiltonian;
use super::linalg::{cholesky, eigh_jacobi, CMat};

#[derive(Clone, Debug)]
pub struct EigenOptions {
    pub max_iters: usize,
    /// Convergence: max band residual 2-norm.
    pub tol: f64,
    /// Jacobi sweeps for the nb x nb Ritz problem.
    pub jacobi_sweeps: usize,
}

impl Default for EigenOptions {
    fn default() -> Self {
        EigenOptions { max_iters: 200, tol: 1e-6, jacobi_sweeps: 30 }
    }
}

#[derive(Clone, Debug)]
pub struct EigenResult {
    pub eigenvalues: Vec<f64>,
    pub residuals: Vec<f64>,
    pub iterations: usize,
    /// Max-residual history (one entry per iteration) — the convergence
    /// curve logged by examples/dft_mini.
    pub history: Vec<f64>,
}

/// `nb x nb` subspace matrix `A^H B` over distributed band blocks
/// (batch-fastest storage `[nb, n_local]`), allreduced over `comm`.
pub fn subspace_matrix(comm: &Comm, a: &[Complex], b: &[Complex], nb: usize) -> CMat {
    assert_eq!(a.len(), b.len());
    let mut m = CMat::zeros(nb, nb);
    for e in 0..a.len() / nb {
        let av = &a[nb * e..nb * (e + 1)];
        let bv = &b[nb * e..nb * (e + 1)];
        for j in 0..nb {
            let bj = bv[j];
            for i in 0..nb {
                m[(i, j)] += av[i].conj() * bj;
            }
        }
    }
    allreduce_sum_complex(comm, &mut m.data);
    m
}

/// In-place band rotation `psi <- psi * U` on batch-fastest storage.
pub fn rotate_bands(psi: &mut [Complex], nb: usize, u: &CMat) {
    assert_eq!(u.n_rows, nb);
    assert_eq!(u.n_cols, nb);
    let mut tmp = vec![ZERO; nb];
    for chunk in psi.chunks_exact_mut(nb) {
        for (i, t) in tmp.iter_mut().enumerate() {
            let mut s = ZERO;
            for j in 0..nb {
                s += chunk[j] * u[(j, i)];
            }
            *t = s;
        }
        chunk.copy_from_slice(&tmp);
    }
}

/// Orthonormalize a band block by Cholesky: `S = psi^H psi = L L^H`,
/// `psi <- psi (L^H)^{-1}`.
pub fn orthonormalize(comm: &Comm, psi: &mut [Complex], nb: usize) {
    let s = subspace_matrix(comm, psi, psi, nb);
    // pallas-lint: allow(no-panic) — a Gram matrix of linearly independent
    // bands is positive definite by construction; failure means the caller
    // fed degenerate bands, a programming error worth an immediate abort.
    let l = cholesky(&s).expect("Gram matrix must be positive definite");
    // psi_j <- (psi_j - sum_{k<j} psi_k L^H[k,j]) / L[j,j], elementwise over
    // the batch-fastest chunks.
    for chunk in psi.chunks_exact_mut(nb) {
        for j in 0..nb {
            for k in 0..j {
                let lkj = l[(j, k)].conj();
                let sub = chunk[k] * lkj;
                chunk[j] -= sub;
            }
            let d = 1.0 / l[(j, j)].re;
            chunk[j] = chunk[j].scale(d);
        }
    }
}

/// Solve for the lowest `nb` bands of `h`.
///
/// `psi` is the starting guess (`[nb, n_local]` batch fastest, any
/// non-degenerate data); on return it holds the Ritz-rotated eigenvectors.
pub fn solve_bands(
    h: &Hamiltonian,
    backend: &dyn LocalFftBackend,
    comm: &Comm,
    psi: &mut Vec<Complex>,
    opts: &EigenOptions,
) -> EigenResult {
    let nb = h.nb;
    let npts = h.n_local();
    assert_eq!(psi.len(), nb * npts);
    orthonormalize(comm, psi, nb);

    let mut history = Vec::new();
    let mut eigenvalues = vec![0.0; nb];
    let mut residuals = vec![f64::INFINITY; nb];
    let mut iters = 0;

    for it in 0..opts.max_iters {
        iters = it + 1;
        let (mut hpsi, _) = h.apply(backend, psi);

        // Rayleigh-Ritz in the current subspace.
        let m = subspace_matrix(comm, psi, &hpsi, nb);
        let (theta, u) = eigh_jacobi(&m, opts.jacobi_sweeps);
        rotate_bands(psi, nb, &u);
        rotate_bands(&mut hpsi, nb, &u);
        eigenvalues.copy_from_slice(&theta);

        // Residuals R = H psi - theta psi.
        let mut res2 = vec![0.0f64; nb];
        let mut resid = hpsi;
        for (e, chunk) in resid.chunks_exact_mut(nb).enumerate() {
            for b in 0..nb {
                chunk[b] -= psi[b + nb * e].scale(theta[b]);
                res2[b] += chunk[b].norm_sqr();
            }
        }
        crate::comm::collectives::allreduce_sum_f64(comm, &mut res2);
        for (r, &s) in residuals.iter_mut().zip(&res2) {
            *r = s.sqrt();
        }
        let worst = residuals.iter().cloned().fold(0.0, f64::max);
        let worst = allreduce_max_f64(comm, worst);
        history.push(worst);
        if worst < opts.tol {
            break;
        }

        // Preconditioned steepest-descent update:
        // psi <- orthonormalize(psi - K R), K = 1 / (1 + kin/|theta_scale|).
        let kin = h.kinetic();
        for (e, chunk) in resid.chunks_exact(nb).enumerate() {
            let t = kin[e];
            for b in 0..nb {
                let scale_ref = theta[b].abs().max(0.5);
                let k = 1.0 / (1.0 + t / scale_ref);
                let idx = b + nb * e;
                psi[idx] -= chunk[b].scale(k);
            }
        }
        orthonormalize(comm, psi, nb);
    }

    EigenResult { eigenvalues, residuals, iterations: iters, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::grid::ProcGrid;
    use crate::dft::hamiltonian::GaussianWells;
    use crate::dft::lattice::Lattice;
    use crate::util::prng::Prng;

    fn random_bands(nb: usize, npts: usize, seed: u64) -> Vec<Complex> {
        let mut p = Prng::new(seed);
        p.complex_vec(nb * npts)
    }

    #[test]
    fn free_electron_eigenvalues_are_kinetic() {
        // V = 0: the exact spectrum is the sorted kinetic energies.
        let p = 2;
        let results = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 12, 2.0);
            let want: Vec<f64> = lat.kinetic_spectrum();
            let nb = 4;
            let h = Hamiltonian::new(lat, nb, &GaussianWells { wells: vec![] }, grid);
            let backend = RustFftBackend::new();
            let mut psi = random_bands(nb, h.n_local(), 17 + comm.rank() as u64);
            let res = solve_bands(
                &h,
                &backend,
                &comm,
                &mut psi,
                &EigenOptions { max_iters: 300, tol: 1e-8, ..Default::default() },
            );
            (res, want)
        });
        for (res, want) in results {
            for (b, ev) in res.eigenvalues.iter().enumerate() {
                assert!(
                    (ev - want[b]).abs() < 1e-5 + 1e-3 * want[b].abs(),
                    "band {b}: got {ev}, want {}",
                    want[b]
                );
            }
            assert!(res.history.last().unwrap() < &1e-6);
        }
    }

    #[test]
    fn well_lowers_the_ground_state() {
        let p = 2;
        let results = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 12, 2.0);
            let nb = 2;
            let h = Hamiltonian::new(lat, nb, &GaussianWells::single(2.0, 1.5), grid);
            let backend = RustFftBackend::new();
            let mut psi = random_bands(nb, h.n_local(), 3);
            solve_bands(
                &h,
                &backend,
                &comm,
                &mut psi,
                &EigenOptions { max_iters: 200, tol: 1e-5, ..Default::default() },
            )
        });
        for res in results {
            // Bound state: strictly below the V=0 ground state (0).
            assert!(res.eigenvalues[0] < -0.1, "ground state {}", res.eigenvalues[0]);
            assert!(res.eigenvalues[0] < res.eigenvalues[1]);
        }
    }

    #[test]
    fn orthonormalize_produces_identity_gram() {
        run_world(2, |comm| {
            let nb = 3;
            let npts = 50;
            let mut psi = random_bands(nb, npts, comm.rank() as u64);
            orthonormalize(&comm, &mut psi, nb);
            let s = subspace_matrix(&comm, &psi, &psi, nb);
            let id = CMat::identity(nb);
            assert!(s.max_abs_diff(&id) < 1e-10, "gram err {}", s.max_abs_diff(&id));
        });
    }

    #[test]
    fn ritz_rotation_preserves_orthonormality() {
        // The Rayleigh-Ritz step rotates an orthonormal band block by the
        // unitary eigenvector matrix of the subspace Hamiltonian; the
        // rotated (Ritz) vectors must still have an identity Gram matrix,
        // and their Rayleigh quotients must be the Ritz values.
        run_world(2, |comm| {
            let nb = 4;
            let npts = 40;
            let mut psi = random_bands(nb, npts, 11 + comm.rank() as u64);
            orthonormalize(&comm, &mut psi, nb);
            // A surrogate "H psi": any linear image of psi gives a
            // Hermitian subspace matrix psi^H (H psi) when H is Hermitian;
            // emulate one by mixing bands with a fixed Hermitian stencil.
            let mut hpsi = psi.clone();
            for chunk in hpsi.chunks_exact_mut(nb) {
                let orig: Vec<Complex> = chunk.to_vec();
                for (b, c) in chunk.iter_mut().enumerate() {
                    *c = orig[b].scale(1.0 + b as f64);
                    if b + 1 < nb {
                        *c += orig[b + 1].scale(0.25);
                    }
                    if b > 0 {
                        *c += orig[b - 1].scale(0.25);
                    }
                }
            }
            let m = subspace_matrix(&comm, &psi, &hpsi, nb);
            assert!(m.hermiticity_err() < 1e-12, "subspace matrix must be Hermitian");
            let (theta, u) = eigh_jacobi(&m, 30);
            rotate_bands(&mut psi, nb, &u);
            rotate_bands(&mut hpsi, nb, &u);
            // Orthonormality survives the unitary rotation.
            let s = subspace_matrix(&comm, &psi, &psi, nb);
            let id = CMat::identity(nb);
            assert!(s.max_abs_diff(&id) < 1e-10, "gram err {}", s.max_abs_diff(&id));
            // The rotated subspace Hamiltonian is diag(theta).
            let d = subspace_matrix(&comm, &psi, &hpsi, nb);
            for j in 0..nb {
                for i in 0..nb {
                    let want = if i == j { theta[i] } else { 0.0 };
                    assert!(
                        (d[(i, j)] - Complex::new(want, 0.0)).abs() < 1e-10,
                        "rotated H[{i},{j}] = {:?}, want {want}",
                        d[(i, j)]
                    );
                }
            }
        });
    }

    #[test]
    fn rotate_bands_is_linear() {
        let nb = 2;
        let mut a = vec![
            Complex::new(1.0, 0.0),
            Complex::new(0.0, 1.0),
            Complex::new(2.0, 0.0),
            Complex::new(0.0, -1.0),
        ];
        // U = [[0, 1], [1, 0]] swaps bands.
        let mut u = CMat::zeros(2, 2);
        u[(0, 1)] = crate::fft::complex::ONE;
        u[(1, 0)] = crate::fft::complex::ONE;
        rotate_bands(&mut a, nb, &u);
        assert_eq!(a[0], Complex::new(0.0, 1.0));
        assert_eq!(a[1], Complex::new(1.0, 0.0));
    }
}
