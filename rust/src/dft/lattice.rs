//! Supercell lattice + plane-wave basis enumeration (paper §2.2, Eq. 8-9).
//!
//! A cubic supercell of side `a` has reciprocal vectors `g = (2 pi / a) m`
//! for integer triples `m`. The basis keeps `|g|^2 / 2 <= E_cut` (Eq. 9) —
//! the `Wrapped` sphere over the FFT grid, with negative frequencies at the
//! top of each axis.

use std::sync::Arc;

use crate::fftb::grid::cyclic;
use crate::fftb::sphere::{OffsetArray, SphereKind, SphereSpec};

/// A cubic supercell with its plane-wave cutoff and FFT grid.
#[derive(Clone, Debug)]
pub struct Lattice {
    /// Cell side length (bohr).
    pub a: f64,
    /// FFT grid points per dimension.
    pub n: usize,
    /// Kinetic cutoff (hartree).
    pub ecut: f64,
    pub spec: SphereSpec,
    pub offsets: Arc<OffsetArray>,
}

impl Lattice {
    pub fn new(a: f64, n: usize, ecut: f64) -> Self {
        // |g| = (2 pi / a) |m| <= sqrt(2 ecut)  =>  |m| <= sqrt(2 ecut) a/(2 pi)
        let m_max = (2.0 * ecut).sqrt() * a / (2.0 * std::f64::consts::PI);
        assert!(
            2.0 * m_max < n as f64,
            "FFT grid n={n} too small for ecut={ecut} (need > {})",
            2.0 * m_max
        );
        let spec = SphereSpec::new([n, n, n], m_max, SphereKind::Wrapped);
        let offsets = Arc::new(spec.offsets());
        Lattice { a, n, ecut, spec, offsets }
    }

    /// Number of plane waves in the basis.
    pub fn n_pw(&self) -> usize {
        self.offsets.total()
    }

    /// Signed integer frequency of grid index `i`.
    #[inline]
    pub fn freq(&self, i: usize) -> i64 {
        if i <= self.n / 2 {
            i as i64
        } else {
            i as i64 - self.n as i64
        }
    }

    /// Kinetic energy `|g|^2 / 2` of the plane wave at grid point (x, y, z).
    pub fn kinetic(&self, x: usize, y: usize, z: usize) -> f64 {
        let s = 2.0 * std::f64::consts::PI / self.a;
        let (fx, fy, fz) = (self.freq(x) as f64, self.freq(y) as f64, self.freq(z) as f64);
        0.5 * s * s * (fx * fx + fy * fy + fz * fz)
    }

    /// Kinetic energies of rank `r`'s local plane waves, in the packed
    /// coefficient order of the plane-wave plan (y outer, local-x, z runs).
    pub fn local_kinetic(&self, p: usize, r: usize) -> Vec<f64> {
        let mut out = Vec::new();
        let lnx = cyclic::local_count(self.n, p, r);
        for y in 0..self.n {
            for lx in 0..lnx {
                let gx = cyclic::local_to_global(lx, p, r);
                for &(z0, len) in self.offsets.col_runs(gx, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        out.push(self.kinetic(gx, y, z));
                    }
                }
            }
        }
        out
    }

    /// Kinetic energy `|g + k|^2 / 2` of the plane wave at grid point
    /// (x, y, z) for Bloch vector `k` (fractional coordinates of the
    /// reciprocal lattice). At `k = [0, 0, 0]` this is exactly
    /// [`kinetic`](Self::kinetic).
    pub fn kinetic_at(&self, k: [f64; 3], x: usize, y: usize, z: usize) -> f64 {
        let s = 2.0 * std::f64::consts::PI / self.a;
        let dx = self.freq(x) as f64 + k[0];
        let dy = self.freq(y) as f64 + k[1];
        let dz = self.freq(z) as f64 + k[2];
        0.5 * s * s * (dx * dx + dy * dy + dz * dz)
    }

    /// Kinetic energies `|g + k|^2 / 2` of rank `r`'s local plane waves of
    /// the k-point sphere `offsets` (from
    /// [`kpoint_offsets`](Self::kpoint_offsets)), walking the same packed
    /// order as [`local_kinetic`](Self::local_kinetic) — the k-point
    /// diagonal the Hamiltonian applies on sphere coefficients.
    pub fn local_kinetic_k(
        &self,
        p: usize,
        r: usize,
        k: [f64; 3],
        offsets: &OffsetArray,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        let lnx = cyclic::local_count(self.n, p, r);
        for y in 0..self.n {
            for lx in 0..lnx {
                let gx = cyclic::local_to_global(lx, p, r);
                for &(z0, len) in offsets.col_runs(gx, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        out.push(self.kinetic_at(k, gx, y, z));
                    }
                }
            }
        }
        out
    }

    /// The plane-wave basis at Bloch vector `k` (fractional coordinates of
    /// the reciprocal lattice): every integer triple with
    /// `|g + k|^2 / 2 <= E_cut`, i.e. `|m + k| <= m_max` — the k-point
    /// sphere of paper Eq. 9 shifted off Γ. At `k = [0, 0, 0]` this is
    /// bit-identical to [`Lattice::offsets`] (same fingerprint, so plans
    /// and wisdom entries are shared); any other `k` reshapes the sphere's
    /// z-runs and salts the fingerprint, so each k-point gets its own
    /// plan-cache and wisdom identity.
    pub fn kpoint_offsets(&self, k: [f64; 3]) -> Arc<OffsetArray> {
        if k == [0.0; 3] {
            return Arc::clone(&self.offsets);
        }
        Arc::new(self.spec.offset(k))
    }

    /// The bases for a batch of k-points, in order — the per-k sphere set
    /// a k-point SCF loop feeds to `Fftb::plan_real` (one plan per
    /// distinct fingerprint; duplicated k's share via the plan cache).
    pub fn kpoint_batch(&self, ks: &[[f64; 3]]) -> Vec<Arc<OffsetArray>> {
        ks.iter().map(|&k| self.kpoint_offsets(k)).collect()
    }

    /// All kinetic energies, ascending — the analytic spectrum of the
    /// free-electron (V = 0) Hamiltonian, used to validate the eigensolver.
    pub fn kinetic_spectrum(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n_pw());
        for y in 0..self.n {
            for x in 0..self.n {
                for &(z0, len) in self.offsets.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        out.push(self.kinetic(x, y, z));
                    }
                }
            }
        }
        out.sort_by(f64::total_cmp);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basis_counts_and_cutoff() {
        let lat = Lattice::new(8.0, 16, 4.0);
        assert!(lat.n_pw() > 0);
        // Every retained G respects the cutoff.
        for y in 0..16 {
            for x in 0..16 {
                for &(z0, len) in lat.offsets.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        assert!(lat.kinetic(x, y, z) <= lat.ecut * 1.0001);
                    }
                }
            }
        }
    }

    #[test]
    fn local_kinetic_partitions_spectrum() {
        let lat = Lattice::new(8.0, 16, 4.0);
        for p in [1usize, 2, 4] {
            let mut all: Vec<f64> = (0..p).flat_map(|r| lat.local_kinetic(p, r)).collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want = lat.kinetic_spectrum();
            assert_eq!(all.len(), want.len());
            for (a, b) in all.iter().zip(&want) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn lowest_kinetic_is_zero() {
        let lat = Lattice::new(10.0, 16, 3.0);
        assert_eq!(lat.kinetic_spectrum()[0], 0.0); // G = 0
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn grid_must_hold_sphere() {
        Lattice::new(20.0, 8, 10.0);
    }

    #[test]
    fn gamma_kpoint_is_the_plain_basis() {
        let lat = Lattice::new(8.0, 16, 4.0);
        let gamma = lat.kpoint_offsets([0.0; 3]);
        // Same object, not just an equal one: Γ shares the lattice's basis,
        // so its plans and wisdom entries are shared too.
        assert!(Arc::ptr_eq(&gamma, &lat.offsets));
        assert_eq!(gamma.fingerprint(), lat.offsets.fingerprint());
    }

    #[test]
    fn distinct_kpoints_get_distinct_fingerprints() {
        let lat = Lattice::new(8.0, 16, 4.0);
        let k1 = lat.kpoint_offsets([0.25, 0.0, 0.0]);
        let k2 = lat.kpoint_offsets([0.0, 0.25, 0.0]);
        let gamma = lat.kpoint_offsets([0.0; 3]);
        assert_ne!(k1.fingerprint(), gamma.fingerprint());
        assert_ne!(k1.fingerprint(), k2.fingerprint());
        // The shifted sphere still respects the cutoff: every retained
        // (m + k) sits inside m_max (the offset build's own membership).
        let m_max = (2.0 * lat.ecut).sqrt() * lat.a / (2.0 * std::f64::consts::PI);
        for y in 0..lat.n {
            for x in 0..lat.n {
                for &(z0, len) in k1.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        let (fx, fy, fz) =
                            (lat.freq(x) as f64, lat.freq(y) as f64, lat.freq(z) as f64);
                        let (dx, dy, dz) = (fx + 0.25, fy, fz);
                        let r2 = dx * dx + dy * dy + dz * dz;
                        assert!(r2.sqrt() <= m_max * 1.0001, "({x},{y},{z}): |m+k|={}", r2.sqrt());
                    }
                }
            }
        }
    }

    #[test]
    fn kpoint_kinetic_reduces_to_gamma() {
        let lat = Lattice::new(8.0, 16, 4.0);
        for p in [1usize, 2] {
            for r in 0..p {
                let g = lat.local_kinetic(p, r);
                let k = lat.local_kinetic_k(p, r, [0.0; 3], &lat.offsets);
                assert_eq!(g, k, "p={p} r={r}: Γ k-kinetic must be bit-identical");
            }
        }
        // Off Γ the diagonal follows the shifted sphere and stays within
        // the cutoff (the sphere membership is |m + k| <= m_max).
        let k = [0.25, 0.0, 0.0];
        let off = lat.kpoint_offsets(k);
        let kin = lat.local_kinetic_k(1, 0, k, &off);
        assert_eq!(kin.len(), off.total());
        for e in &kin {
            assert!(*e >= 0.0 && *e <= lat.ecut * 1.0001);
        }
    }

    #[test]
    fn kpoint_batch_maps_in_order() {
        let lat = Lattice::new(8.0, 16, 4.0);
        let ks = [[0.0; 3], [0.25, 0.0, 0.0], [0.0; 3]];
        let batch = lat.kpoint_batch(&ks);
        assert_eq!(batch.len(), 3);
        assert!(Arc::ptr_eq(&batch[0], &lat.offsets));
        assert!(Arc::ptr_eq(&batch[2], &lat.offsets));
        assert_ne!(batch[1].fingerprint(), batch[0].fingerprint());
    }
}
