//! Small dense complex linear algebra for the all-band eigensolver:
//! Hermitian Jacobi eigensolver, Cholesky factorization, triangular solves.
//!
//! Band counts are O(10-100), so classic O(n^3) kernels are ample; no LAPACK
//! exists in the offline dependency set. Matrices are column-major
//! `a[i + n*j]`.

use crate::fft::complex::{Complex, ONE, ZERO};

/// Column-major dense complex matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CMat {
    pub n_rows: usize,
    pub n_cols: usize,
    pub data: Vec<Complex>,
}

impl CMat {
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        CMat { n_rows, n_cols, data: vec![ZERO; n_rows * n_cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = CMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = ONE;
        }
        m
    }

    pub fn from_fn(n_rows: usize, n_cols: usize, f: impl Fn(usize, usize) -> Complex) -> Self {
        let mut m = CMat::zeros(n_rows, n_cols);
        for j in 0..n_cols {
            for i in 0..n_rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// `self * other`.
    pub fn matmul(&self, other: &CMat) -> CMat {
        assert_eq!(self.n_cols, other.n_rows);
        let mut out = CMat::zeros(self.n_rows, other.n_cols);
        for j in 0..other.n_cols {
            for k in 0..self.n_cols {
                let b = other[(k, j)];
                if b == ZERO {
                    continue;
                }
                for i in 0..self.n_rows {
                    out[(i, j)] += self[(i, k)] * b;
                }
            }
        }
        out
    }

    /// Conjugate transpose.
    pub fn dagger(&self) -> CMat {
        CMat::from_fn(self.n_cols, self.n_rows, |i, j| self[(j, i)].conj())
    }

    /// Hermitian deviation `max |A - A^H|` (diagnostics).
    pub fn hermiticity_err(&self) -> f64 {
        assert_eq!(self.n_rows, self.n_cols);
        let mut e: f64 = 0.0;
        for j in 0..self.n_cols {
            for i in 0..self.n_rows {
                e = e.max((self[(i, j)] - self[(j, i)].conj()).abs());
            }
        }
        e
    }

    pub fn max_abs_diff(&self, other: &CMat) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for CMat {
    type Output = Complex;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &Complex {
        &self.data[i + self.n_rows * j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for CMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut Complex {
        &mut self.data[i + self.n_rows * j]
    }
}

/// Cholesky factorization `A = L L^H` of a Hermitian positive-definite
/// matrix. Returns lower-triangular `L`; fails on non-PD input.
pub fn cholesky(a: &CMat) -> Result<CMat, String> {
    assert_eq!(a.n_rows, a.n_cols);
    let n = a.n_rows;
    let mut l = CMat::zeros(n, n);
    for j in 0..n {
        let mut d = a[(j, j)].re;
        for k in 0..j {
            d -= l[(j, k)].norm_sqr();
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(format!("matrix not positive definite at pivot {j} (d={d})"));
        }
        let dj = d.sqrt();
        l[(j, j)] = Complex::new(dj, 0.0);
        for i in j + 1..n {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)].conj();
            }
            l[(i, j)] = s.scale(1.0 / dj);
        }
    }
    Ok(l)
}

/// Solve `L^H X = B` in place of B columns (L lower-triangular) — used to
/// orthonormalize band blocks: `psi <- psi * (L^H)^{-1}` via X = B (L^H)^-1
/// i.e. solving row systems. Here we provide the right-multiplication form:
/// returns `B * (L^H)^{-1}`.
pub fn right_solve_lh(b: &CMat, l: &CMat) -> CMat {
    // X L^H = B, solve column by column of L^H (forward substitution on
    // columns since L^H is upper triangular).
    let n = l.n_rows;
    assert_eq!(b.n_cols, n);
    let mut x = b.clone();
    for j in 0..n {
        // X[:, j] = (B[:, j] - sum_{k<j} X[:, k] * L^H[k, j]) / L^H[j, j]
        for k in 0..j {
            let lkj = l[(j, k)].conj(); // L^H[k, j]
            for i in 0..x.n_rows {
                let sub = x[(i, k)] * lkj;
                x[(i, j)] -= sub;
            }
        }
        let d = 1.0 / l[(j, j)].re;
        for i in 0..x.n_rows {
            x[(i, j)] = x[(i, j)].scale(d);
        }
    }
    x
}

/// Cyclic Jacobi eigensolver for a Hermitian matrix: returns (eigenvalues
/// ascending, eigenvector matrix V with A V = V diag(w)).
pub fn eigh_jacobi(a: &CMat, sweeps: usize) -> (Vec<f64>, CMat) {
    assert_eq!(a.n_rows, a.n_cols);
    let n = a.n_rows;
    let mut m = a.clone();
    let mut v = CMat::identity(n);

    for _ in 0..sweeps {
        let mut off: f64 = 0.0;
        for j in 0..n {
            for i in 0..j {
                off = off.max(m[(i, j)].abs());
            }
        }
        if off < 1e-14 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                let g = apq.abs();
                if g < 1e-300 {
                    continue;
                }
                // Unitary 2x2 rotation J = P * R on the (p, q) block:
                //   P = diag(1, e^{-i phi}) makes a_pq real (a_pq = g e^{i phi}),
                //   R = [[c, -s], [s, c]] with tan(2 theta) = 2g / (a_pp - a_qq)
                // zeroes the off-diagonal of the phased block.
                // J = [[c, -s], [s e^{-i phi}, c e^{-i phi}]].
                let phase = apq.scale(1.0 / g); // e^{i phi}
                let alpha = m[(p, p)].re;
                let beta = m[(q, q)].re;
                let theta = 0.5 * (2.0 * g).atan2(alpha - beta);
                let (s, c) = theta.sin_cos();
                let jqp = phase.conj().scale(s); //  s e^{-i phi}
                let jqq = phase.conj().scale(c); //  c e^{-i phi}

                // Column update (A <- A J, V <- V J).
                let col = |mat: &mut CMat, rows: usize| {
                    for i in 0..rows {
                        let xp = mat[(i, p)];
                        let xq = mat[(i, q)];
                        mat[(i, p)] = xp.scale(c) + xq * jqp;
                        mat[(i, q)] = xq * jqq - xp.scale(s);
                    }
                };
                col(&mut m, n);
                col(&mut v, n);
                // Row update (A <- J^H A):
                //   row_p <- c row_p + s e^{i phi} row_q
                //   row_q <- c e^{i phi} row_q - s row_p   (old values)
                let jhpq = phase.scale(s);
                let jhqq = phase.scale(c);
                for j in 0..n {
                    let xp = m[(p, j)];
                    let xq = m[(q, j)];
                    m[(p, j)] = xp.scale(c) + xq * jhpq;
                    m[(q, j)] = xq * jhqq - xp.scale(s);
                }
            }
        }
    }
    // Extract and sort.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)].re, i)).collect();
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let w: Vec<f64> = pairs.iter().map(|&(x, _)| x).collect();
    let mut vs = CMat::zeros(n, n);
    for (new_j, &(_, old_j)) in pairs.iter().enumerate() {
        for i in 0..n {
            vs[(i, new_j)] = v[(i, old_j)];
        }
    }
    (w, vs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hermitian_test_matrix(n: usize, seed: u64) -> CMat {
        let mut p = crate::util::prng::Prng::new(seed);
        let vals: Vec<Complex> = (0..n * n)
            .map(|_| Complex::new(p.next_signed(), p.next_signed()))
            .collect();
        let b = CMat { n_rows: n, n_cols: n, data: vals };
        // A = B^H B + n*I: Hermitian positive definite.
        let mut a = b.dagger().matmul(&b);
        for i in 0..n {
            a[(i, i)] += Complex::new(n as f64, 0.0);
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let a = hermitian_test_matrix(4, 1);
        let i = CMat::identity(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = hermitian_test_matrix(6, 2);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.dagger());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = CMat::identity(3);
        a[(2, 2)] = Complex::new(-1.0, 0.0);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn right_solve_inverts_lh() {
        let a = hermitian_test_matrix(5, 3);
        let l = cholesky(&a).unwrap();
        let b = CMat::from_fn(3, 5, |i, j| Complex::new((i + 2 * j) as f64, j as f64));
        let x = right_solve_lh(&b, &l);
        // x * L^H == b
        let rec = x.matmul(&l.dagger());
        assert!(rec.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn jacobi_diagonalizes() {
        let a = hermitian_test_matrix(8, 4);
        let (w, v) = eigh_jacobi(&a, 30);
        // A V = V diag(w)
        let av = a.matmul(&v);
        let mut vd = v.clone();
        for j in 0..8 {
            for i in 0..8 {
                vd[(i, j)] = vd[(i, j)].scale(w[j]);
            }
        }
        assert!(av.max_abs_diff(&vd) < 1e-8, "err {}", av.max_abs_diff(&vd));
        // V unitary.
        let vhv = v.dagger().matmul(&v);
        assert!(vhv.max_abs_diff(&CMat::identity(8)) < 1e-9);
        // Ascending.
        for k in 1..8 {
            assert!(w[k] >= w[k - 1]);
        }
    }

    #[test]
    fn jacobi_matches_naive_reference() {
        // Reference the spectrum against quantities computable without any
        // eigensolver: trace = sum(w), Frobenius norm^2 = sum(w^2) (both
        // exact for Hermitian A), and the extreme eigenvalues from naive
        // power iteration on A and on (shift*I - A).
        let n = 6;
        let a = hermitian_test_matrix(n, 9);
        let (w, _) = eigh_jacobi(&a, 30);

        let trace: f64 = (0..n).map(|i| a[(i, i)].re).sum();
        let frob2: f64 = a.data.iter().map(|c| c.norm_sqr()).sum();
        let wsum: f64 = w.iter().sum();
        let w2sum: f64 = w.iter().map(|x| x * x).sum();
        assert!((trace - wsum).abs() < 1e-10 * trace.abs(), "trace {trace} vs {wsum}");
        assert!((frob2 - w2sum).abs() < 1e-10 * frob2, "frob {frob2} vs {w2sum}");

        // Power iteration for the dominant eigenvalue (A is PD, so the
        // dominant one is the largest).
        let power = |m: &CMat| -> f64 {
            let mut x = CMat::from_fn(n, 1, |i, _| Complex::new(1.0 + i as f64, 0.3 * i as f64));
            let mut lambda = 0.0;
            for _ in 0..2000 {
                let y = m.matmul(&x);
                let norm = y.data.iter().map(|c| c.norm_sqr()).sum::<f64>().sqrt();
                lambda = norm
                    / x.data.iter().map(|c| c.norm_sqr()).sum::<f64>().sqrt().max(1e-300);
                for (xi, yi) in x.data.iter_mut().zip(&y.data) {
                    *xi = yi.scale(1.0 / norm);
                }
            }
            lambda
        };
        let w_max = power(&a);
        assert!((w_max - w[n - 1]).abs() < 1e-4 * w_max, "max {w_max} vs {}", w[n - 1]);
        // Smallest eigenvalue via the shifted complement: shift*I - A has
        // dominant eigenvalue shift - w_min.
        let shift = 2.0 * w_max;
        let mut comp = CMat::from_fn(n, n, |i, j| (a[(i, j)]).scale(-1.0));
        for i in 0..n {
            comp[(i, i)] += Complex::new(shift, 0.0);
        }
        let w_min = shift - power(&comp);
        assert!((w_min - w[0]).abs() < 1e-4 * w_max, "min {w_min} vs {}", w[0]);
    }

    #[test]
    fn jacobi_known_eigenvalues() {
        // [[2, i], [-i, 2]] has eigenvalues 1 and 3.
        let mut a = CMat::zeros(2, 2);
        a[(0, 0)] = Complex::new(2.0, 0.0);
        a[(1, 1)] = Complex::new(2.0, 0.0);
        a[(0, 1)] = Complex::new(0.0, 1.0);
        a[(1, 0)] = Complex::new(0.0, -1.0);
        let (w, _) = eigh_jacobi(&a, 20);
        assert!((w[0] - 1.0).abs() < 1e-10);
        assert!((w[1] - 3.0).abs() < 1e-10);
    }
}
