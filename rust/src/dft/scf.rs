//! The SCF layer: density construction, charge checks, linear mixing —
//! and [`ScfRunner`], the distributed self-consistency driver that
//! requests every transform through the autotuner.
//!
//! The paper's motivating workload is not one transform but the
//! plane-wave DFT self-consistency loop: every iteration applies the
//! Hamiltonian to the whole band block (one batched sphere-forward
//! transform, a pointwise multiply, one batched inverse), rebuilds the
//! density (one more forward), and solves the G-space Poisson equation
//! for the Hartree potential (`v_H(G) = 4π ρ(G) / |G|²`, one more
//! inverse/forward round trip on an nb = 1 plan) — hundreds of times
//! (Fig. 9's red-line workload; the batched formulation follows Popovici
//! et al.). The runner closes the gap between that loop and the tuning
//! stack one layer below:
//!
//! * the transform plan comes from [`Fftb::plan_auto_scf`] — the tuner
//!   picks the decomposition (plane-wave staged padding vs its per-band
//!   loop vs pad-to-cube) and the exchange window, measures the SCF-shaped
//!   alternating forward/inverse cadence when the empirical mode is on,
//!   and remembers the decision in a wisdom file shared across iterations,
//!   ranks and process restarts;
//! * every iteration *re-requests* the plan, so steady-state iterations
//!   are pure [`PlanCache`](crate::tuner::PlanCache) hits
//!   (`ExecTrace::plan_cache_hit`) executing warmed workspaces
//!   (`alloc_bytes == 0`) — the plan-once / execute-many contract held at
//!   the application layer, asserted by `tests/scf_distributed.rs`;
//! * the band block lives in a [`DistTensor`] over the lattice's
//!   plane-wave sphere, so the declared distribution and the plan's local
//!   layout are checked against each other at construction.

use std::path::PathBuf;
use std::sync::Arc;

use crate::comm::collectives::allreduce_sum_f64;
use crate::comm::communicator::Comm;
use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::domain::{Domain, DomainList};
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::{cyclic, ProcGrid};
use crate::fftb::plan::{ExecTrace, Fftb, PlanKind, PlaneWavePlan};
use crate::fftb::tensor::DistTensor;
use crate::model::machine::Machine;
use crate::service::{ServiceConfig, ServiceError, TenantId, TransformService};
use crate::tuner::{Tuner, Wisdom};
use crate::util::prng::Prng;

use super::eigensolver::{orthonormalize, rotate_bands, subspace_matrix};
use super::hamiltonian::{GaussianWells, Hamiltonian};
use super::lattice::Lattice;
use super::linalg::eigh_jacobi;

/// Electron density on this rank's z-slab, plus bookkeeping.
#[derive(Clone, Debug)]
pub struct Density {
    /// n(r) on the local slab `[nx, ny, lzc]`.
    pub rho: Vec<f64>,
    /// Cell integral of n(r) (should equal the band count for orthonormal
    /// filled bands).
    pub charge: f64,
}

/// Build the density from orthonormal bands.
pub fn build_density(
    h: &Hamiltonian,
    backend: &dyn LocalFftBackend,
    comm: &Comm,
    psi: &[Complex],
) -> Density {
    let rho = h.density(backend, psi);
    let n = h.lattice.n;
    let dv = h.lattice.a.powi(3) / (n * n * n) as f64;
    let mut charge = [rho.iter().sum::<f64>() * dv];
    allreduce_sum_f64(comm, &mut charge);
    Density { rho, charge: charge[0] }
}

/// Linear density mixing `rho <- (1-alpha) rho_old + alpha rho_new` —
/// the stabilizer every SCF loop needs.
pub fn mix_density(old: &mut [f64], new: &[f64], alpha: f64) {
    assert_eq!(old.len(), new.len());
    for (o, &n) in old.iter_mut().zip(new) {
        *o = (1.0 - alpha) * *o + alpha * n;
    }
}

/// Scale packed `ρ(G)` sphere coefficients into the Hartree potential
/// `v_H(G) = 4π ρ(G) / |G|²`, in place, walking the plan's packed order.
/// `kin` is the matching kinetic array (`|G|²/2` per packed entry, from
/// [`Lattice::local_kinetic`]), so `|G|² = 2·kin`. The `G = 0` bin — the
/// entry whose kinetic energy is exactly `0.0` — is zeroed outright: the
/// charge-neutrality convention of a periodic cell, where the divergent
/// monopole term cancels against the uniform compensating background.
pub fn poisson_scale(kin: &[f64], rg: &mut [Complex]) {
    assert_eq!(kin.len(), rg.len(), "kinetic array must match the packed coefficients");
    for (c, &t) in rg.iter_mut().zip(kin) {
        if t == 0.0 {
            *c = ZERO;
        } else {
            *c = c.scale(4.0 * std::f64::consts::PI / (2.0 * t));
        }
    }
}

/// Per-iteration decomposition of the total energy functional
/// `E = E_kin + E_ext + E_H + E_mf` (hartree units), plus the band sum of
/// the iteration's Ritz values. Every term is cell-global (allreduced);
/// `total` is what the convergence gates in `ci.sh` and the module tests
/// watch settle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Kinetic energy `Σ_b Σ_G |c_bG|² |G|²/2` of the orthonormal bands.
    pub kinetic: f64,
    /// External-potential energy `∫ v_ext ρ dv`.
    pub external: f64,
    /// Hartree energy `½ ∫ v_H ρ dv` from the G-space Poisson solve.
    pub hartree: f64,
    /// Mean-field energy `(u/2) ∫ ρ² dv` of the model coupling.
    pub mean_field: f64,
    /// Band-structure sum `Σ_b θ_b` of the Ritz values (diagnostic; not a
    /// term of `total`, which counts each interaction once).
    pub band: f64,
    /// `kinetic + external + hartree + mean_field`.
    pub total: f64,
}

/// Knobs of the [`ScfRunner`] density loop.
#[derive(Clone, Debug)]
pub struct ScfOptions {
    /// Maximum SCF iterations.
    pub max_iters: usize,
    /// Convergence threshold on the per-electron density change
    /// (`delta_rho / nb < tol`, checked from iteration 2 on).
    pub tol: f64,
    /// Linear mixing weight of the fresh density.
    pub mix: f64,
    /// Mean-field coupling `u` of the density back into the potential
    /// (`v = v_ext + u * rho`) — what makes the loop genuinely
    /// self-consistent; `0.0` freezes the potential.
    pub coupling: f64,
    /// Tuner shortlist size for the live SCF-shaped measurement; `0` or
    /// `1` trusts the cost model outright.
    pub empirical_top_k: usize,
    /// Wisdom file shared across iterations, ranks and process restarts:
    /// loaded (if present and readable) before the first plan request,
    /// written back by rank 0 after the run. Stale-version or corrupt
    /// files are skipped — the runner falls back to a fresh search.
    pub wisdom_path: Option<PathBuf>,
    /// Seed of the starting-guess wavefunctions. The guess is derived
    /// from each coefficient's global index (plus this seed), so a given
    /// seed produces the same global starting state on every world size.
    pub seed: u64,
}

impl Default for ScfOptions {
    fn default() -> Self {
        ScfOptions {
            max_iters: 12,
            tol: 1e-5,
            mix: 0.5,
            coupling: 0.25,
            empirical_top_k: 0,
            wisdom_path: None,
            seed: 42,
        }
    }
}

/// What one SCF iteration did — the per-iteration row of
/// [`ScfResult::history`].
#[derive(Clone, Debug)]
pub struct ScfIterStats {
    /// Iteration number, 1-based.
    pub iter: usize,
    /// Cell integral of the fresh density (should equal the band count).
    pub charge: f64,
    /// Allreduced L1 change of the density against the previous iterate
    /// (cell-integral weighted).
    pub delta_rho: f64,
    /// Max band residual 2-norm after the iteration's Ritz step.
    pub max_residual: f64,
    /// Whether *every* transform this iteration executed a plan served
    /// from the tuner's plan cache (steady-state iterations must).
    pub plan_cache_hit: bool,
    /// Workspace growth summed over the iteration's transforms — 0 in
    /// steady state (the plan-once / execute-many contract).
    pub alloc_bytes: u64,
    /// Distributed transform executions this iteration (forward + inverse
    /// of the Hamiltonian application, the density forward, and the
    /// Hartree round trip's inverse + forward).
    pub transforms: usize,
    /// Total-energy decomposition at the end of the iteration, from the
    /// mixed density and the fresh Hartree potential.
    pub energy: EnergyBreakdown,
}

/// Outcome of an [`ScfRunner`] run.
#[derive(Clone, Debug)]
pub struct ScfResult {
    /// Final (mixed) density with its charge integral.
    pub density: Density,
    /// Ritz eigenvalues of the final iteration, ascending.
    pub eigenvalues: Vec<f64>,
    /// Total-energy breakdown of the final iteration.
    pub energy: EnergyBreakdown,
    /// Per-iteration statistics, in order.
    pub history: Vec<ScfIterStats>,
    /// Iterations actually run.
    pub iterations: usize,
    /// Whether the density change dropped below `tol` before `max_iters`.
    pub converged: bool,
    /// Label of the tuner-picked decomposition (e.g. `"plane-wave"`).
    pub plan_kind: String,
    /// Exchange window the tuner picked.
    pub window: usize,
    /// Whether the initial decision came from persisted wisdom.
    pub from_wisdom: bool,
    /// Whether the initial decision was confirmed by live measurement
    /// (the SCF-shaped probe) in this process.
    pub measured: bool,
}

/// The plan supply of a runner: tuner-driven (re-requested every
/// iteration, cache-served in steady state) or a caller-pinned plan (the
/// hand-picked baselines of `benches/scf_ablation.rs`).
enum PlanSource {
    Tuned(Box<Tuner>),
    Fixed,
}

/// Distributed SCF driver: all-band density loop over a tuner-planned
/// batched sphere transform. See the module docs for the cadence and the
/// steady-state contract; `examples/scf_distributed.rs` is the runnable
/// walkthrough.
pub struct ScfRunner {
    h: Hamiltonian,
    comm: Comm,
    source: PlanSource,
    /// Band block `[nb, n_pw_local]` (batch fastest) over the sphere — the
    /// declared distribution the plan was checked against.
    pub psi: DistTensor,
    vext: Vec<f64>,
    rho: Vec<f64>,
    rho_new: Vec<f64>,
    /// nb = 1 plan of the per-iteration Hartree (Poisson) round trip —
    /// same sphere as the band plan, one "band": the density field.
    hplan: Arc<Fftb>,
    /// `v_H(r)` on the local slab, refreshed every iteration.
    vh: Vec<f64>,
    opts: ScfOptions,
    traces: Vec<ExecTrace>,
    plan_kind: String,
    window: usize,
    from_wisdom: bool,
    measured: bool,
}

impl ScfRunner {
    /// Build a runner whose transform plan (decomposition + window) comes
    /// from the autotuner via [`Fftb::plan_auto_scf`]: wisdom is loaded
    /// from `opts.wisdom_path` when present, the SCF-shaped empirical
    /// probe runs when `opts.empirical_top_k > 1`, and the decision is
    /// cached so the run's iterations re-plan nothing. Collective over
    /// `comm` — every rank must construct with identical arguments.
    pub fn new(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        comm: &Comm,
        backend: &dyn LocalFftBackend,
        opts: ScfOptions,
    ) -> Result<ScfRunner> {
        let mut tuner = match &opts.wisdom_path {
            Some(path) => match Wisdom::load(path) {
                // Same file on every rank => same decisions on every rank.
                Ok(w) => Tuner::with_wisdom(Machine::local_cpu(), w),
                // Missing, corrupt or stale-version wisdom: fresh search.
                Err(_) => Tuner::local(),
            },
            None => Tuner::local(),
        };
        tuner.empirical_top_k = opts.empirical_top_k;
        let n = lattice.n;
        let backend_opt = if opts.empirical_top_k > 1 { Some(backend) } else { None };
        let tuned = Fftb::plan_auto_scf(
            [n, n, n],
            nb,
            Some(Arc::clone(&lattice.offsets)),
            comm,
            &mut tuner,
            backend_opt,
        )?;
        let (plan_kind, window) = (tuned.choice.kind.label(), tuned.choice.window);
        let (from_wisdom, measured) = (tuned.from_wisdom, tuned.measured);
        // The Hartree round trip gets its own nb = 1 request through the
        // same tuner (its own plan-cache/wisdom identity, also re-issued
        // every iteration so steady-state stays pure cache hits).
        let htuned = Fftb::plan_auto_scf(
            [n, n, n],
            1,
            Some(Arc::clone(&lattice.offsets)),
            comm,
            &mut tuner,
            backend_opt,
        )?;
        Self::assemble(
            lattice,
            nb,
            potential,
            comm,
            tuned.plan,
            Some(htuned.plan),
            PlanSource::Tuned(Box::new(tuner)),
            plan_kind,
            window,
            from_wisdom,
            measured,
            opts,
        )
    }

    /// Build a runner around a caller-pinned plan, bypassing the tuner —
    /// the hand-picked baselines the ablation bench compares the
    /// auto-tuned loop against. Iteration stats report no cache hits
    /// (there is no cache).
    pub fn with_plan(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        comm: &Comm,
        plan: Arc<Fftb>,
        opts: ScfOptions,
    ) -> Result<ScfRunner> {
        let kind = plan.kind.name().to_string();
        Self::assemble(
            lattice,
            nb,
            potential,
            comm,
            plan,
            None,
            PlanSource::Fixed,
            kind,
            0,
            false,
            false,
            opts,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        comm: &Comm,
        plan: Arc<Fftb>,
        hplan: Option<Arc<Fftb>>,
        source: PlanSource,
        plan_kind: String,
        window: usize,
        from_wisdom: bool,
        measured: bool,
        opts: ScfOptions,
    ) -> Result<ScfRunner> {
        let p = comm.size();
        let r = comm.rank();
        let n = lattice.n;
        let grid = ProcGrid::new(&[p], comm.clone())?;

        // The band block as a declared distributed tensor: batch dim `b`,
        // sphere domain distributed in x on grid axis 0 — the plane-wave
        // pattern. Its local length is derived from the declaration and
        // must agree with the plan's input layout.
        let b = Domain::new(vec![0], vec![nb as i64 - 1])?;
        let c = Domain::with_offsets(
            vec![0, 0, 0],
            vec![n as i64 - 1, n as i64 - 1, n as i64 - 1],
            Arc::clone(&lattice.offsets),
        )?;
        let mut psi = DistTensor::zeros(
            DomainList::new(vec![b, c])?,
            "b x{0} y z",
            Arc::clone(&grid),
        )?;
        assert_eq!(
            psi.local.len(),
            plan.input_len(),
            "declared tensor distribution disagrees with the plan layout"
        );
        // Deterministic starting guess derived from each coefficient's
        // *global* (x, y, z, band) index — not from the rank — so every
        // world size starts from the same global state and the loop's
        // results are reproducible across p (pinned by
        // `tests/scf_distributed.rs`). The enumeration mirrors the plan's
        // packed coefficient order: y outer, local x, z runs.
        let phase = Prng::new(opts.seed).complex_vec(1)[0];
        let lnx = cyclic::local_count(n, p, r);
        let mut e = 0usize;
        for y in 0..n {
            for lx in 0..lnx {
                let gx = cyclic::local_to_global(lx, p, r);
                for &(z0, len) in lattice.offsets.col_runs(gx, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        let g = ((gx * n + y) * n + z) as f64;
                        for b in 0..nb {
                            let a = 0.37 * g + 1.7 * b as f64 + phase.re;
                            psi.local[b + nb * e] =
                                Complex::new(a.sin(), (0.11 * g + phase.im).cos());
                        }
                        e += 1;
                    }
                }
            }
        }
        assert_eq!(nb * e, psi.local.len(), "packed-order enumeration mismatch");

        // Pinned runners get a pinned plane-wave Hartree companion, so the
        // service/standalone bit-identity contract extends to the Hartree
        // term; tuned runners hand theirs in from the tuner.
        let hplan = match hplan {
            Some(hp) => hp,
            None => {
                let pw = PlaneWavePlan::new(Arc::clone(&lattice.offsets), 1, Arc::clone(&grid))?;
                Arc::new(Fftb { kind: PlanKind::PlaneWave(pw), sizes: [n, n, n], nb: 1 })
            }
        };
        let vext = Hamiltonian::external_potential(&lattice, potential, p, r);
        let h = Hamiltonian::with_plan(lattice, nb, potential, grid, plan);
        let slab = vext.len();
        Ok(ScfRunner {
            h,
            comm: comm.clone(),
            source,
            psi,
            vext,
            rho: vec![0.0; slab],
            rho_new: Vec::with_capacity(slab),
            hplan,
            vh: vec![0.0; slab],
            opts,
            traces: Vec::new(),
            plan_kind,
            window,
            from_wisdom,
            measured,
        })
    }

    /// The Hamiltonian the loop applies (plan, kinetic array, potential).
    pub fn hamiltonian(&self) -> &Hamiltonian {
        &self.h
    }

    /// The tuner driving this runner's plans (`None` for pinned plans) —
    /// its cache stats and wisdom are the run's planning audit trail.
    pub fn tuner(&self) -> Option<&Tuner> {
        match &self.source {
            PlanSource::Tuned(t) => Some(t),
            PlanSource::Fixed => None,
        }
    }

    /// Run the density loop until convergence or `max_iters`.
    ///
    /// Per iteration: re-request both plans through the tuner (pure cache
    /// hits in steady state), orthonormalize, apply `H` to the whole band
    /// block (batched sphere-forward, pointwise `V(r)`, batched inverse),
    /// Ritz-rotate, take one preconditioned descent step, rebuild the
    /// density (one more batched forward), mix it, solve Poisson for the
    /// Hartree potential (inverse + forward on the nb = 1 plan), fold
    /// `v = v_ext + u·ρ + v_H`, and record the energy breakdown.
    /// Collective over the construction communicator.
    pub fn run(&mut self, backend: &dyn LocalFftBackend) -> ScfResult {
        assert!(self.opts.max_iters >= 1, "an SCF run needs at least one iteration");
        let nb = self.h.nb;
        let comm = self.comm.clone();
        let n = self.h.lattice.n;
        let dv = self.h.lattice.a.powi(3) / (n * n * n) as f64;
        let mut history: Vec<ScfIterStats> = Vec::new();
        let mut eigenvalues = vec![0.0; nb];
        let mut converged = false;

        for it in 1..=self.opts.max_iters {
            // Steady-state iterations must be pure plan-cache hits: the
            // request is identical every iteration, so the tuner serves
            // the same warmed plan object it already built.
            let cache_hit = match &mut self.source {
                PlanSource::Tuned(tuner) => {
                    let tuned = tuner
                        .plan_auto_scf(
                            [n, n, n],
                            nb,
                            Some(Arc::clone(&self.h.lattice.offsets)),
                            &comm,
                            None,
                        )
                        // pallas-lint: allow(no-panic) — this request
                        // already planned successfully at construction;
                        // iterations re-issue the identical request, which
                        // by the plan cache's invariant can only hit.
                        .expect("the cached SCF plan request cannot fail");
                    assert!(
                        Arc::ptr_eq(&tuned.plan, &self.h.plan),
                        "the tuner must serve the iteration the same plan object"
                    );
                    let htuned = tuner
                        .plan_auto_scf(
                            [n, n, n],
                            1,
                            Some(Arc::clone(&self.h.lattice.offsets)),
                            &comm,
                            None,
                        )
                        // pallas-lint: allow(no-panic) — same cache
                        // invariant as the band plan above.
                        .expect("the cached Hartree plan request cannot fail");
                    assert!(
                        Arc::ptr_eq(&htuned.plan, &self.hplan),
                        "the tuner must serve the iteration the same Hartree plan object"
                    );
                    tuned.cache_hit && htuned.cache_hit
                }
                PlanSource::Fixed => false,
            };

            orthonormalize(&comm, &mut self.psi.local, nb);

            // H psi: batched sphere-forward + pointwise V(r) + inverse.
            let (hpsi, traces) = self.h.apply(backend, &self.psi.local);

            // Rayleigh-Ritz + one preconditioned descent step (the body
            // shared verbatim with the service-driven loop, so the two
            // paths stay bit-identical).
            let (max_residual, resid) = Self::ritz_and_descend(
                &comm,
                &mut self.psi.local,
                hpsi,
                self.h.kinetic(),
                nb,
                &mut eigenvalues,
            );
            // The band-block buffer came from the plan's slot pool (it was
            // the inverse-transform output); hand it back so the pool
            // stays balanced and later iterations allocate nothing.
            self.h.plan.recycle(resid);
            orthonormalize(&comm, &mut self.psi.local, nb);

            // Fresh density (one more batched forward), charge and change,
            // then mixing.
            let mut rho_new = std::mem::take(&mut self.rho_new);
            let tr_d = self.h.density_into(backend, &self.psi.local, &mut rho_new);
            let (charge, delta_rho) = self.absorb_density(it, rho_new, dv);

            // Hartree: one G-space Poisson solve of the mixed density —
            // the iteration's fourth and fifth transforms — then the
            // potential fold `v = v_ext + u·ρ + v_H` and the energy
            // bookkeeping (both shared verbatim with the service loop).
            let (tr_hi, tr_hf) = self.hartree_update(backend);
            self.fold_potential();
            let energy = self.energy_breakdown(&eigenvalues, dv);

            // Stamp the cache provenance onto the iteration's traces (the
            // per-execution view the steady-state tests consume) and log
            // them for `drain_traces`.
            let mut traces = traces;
            traces.push(tr_d);
            traces.push(tr_hi);
            traces.push(tr_hf);
            let mut alloc_bytes = 0;
            let transforms = traces.len();
            for t in &mut traces {
                t.plan_cache_hit = cache_hit;
                alloc_bytes += t.alloc_bytes;
            }
            self.traces.extend(traces);
            history.push(ScfIterStats {
                iter: it,
                charge,
                delta_rho,
                max_residual,
                plan_cache_hit: cache_hit,
                alloc_bytes,
                transforms,
                energy,
            });

            if it > 1 && delta_rho / nb as f64 < self.opts.tol {
                converged = true;
                break;
            }
        }

        // Persist the planning decisions for the next process life. All
        // ranks hold identical wisdom; rank 0 writes. Failures are
        // non-fatal (wisdom is an optimization, not state).
        if let (PlanSource::Tuned(tuner), Some(path), 0) =
            (&self.source, &self.opts.wisdom_path, self.comm.rank())
        {
            tuner.wisdom.save(path).ok();
        }

        let iterations = history.len();
        ScfResult {
            density: Density {
                rho: self.rho.clone(),
                charge: history.last().map(|h| h.charge).unwrap_or(0.0),
            },
            eigenvalues,
            energy: history.last().map(|h| h.energy).unwrap_or_default(),
            history,
            iterations,
            converged,
            plan_kind: self.plan_kind.clone(),
            window: self.window,
            from_wisdom: self.from_wisdom,
            measured: self.measured,
        }
    }

    /// Take every `ExecTrace` recorded since the last drain (five per
    /// iteration, in order: H-apply forward + inverse, density forward,
    /// Hartree inverse + forward), each stamped with its iteration's
    /// plan-cache provenance — the per-execution view (`plan_cache_hit`,
    /// `alloc_bytes`) the steady-state tests and the metrics sink consume.
    pub fn drain_traces(&mut self) -> Vec<ExecTrace> {
        std::mem::take(&mut self.traces)
    }

    /// The Hartree potential `v_H(r)` of the current mixed density on the
    /// local slab (all zeros until the first iteration completes).
    pub fn hartree_potential(&self) -> &[f64] {
        &self.vh
    }

    /// One G-space Poisson solve of the mixed density: lift `ρ(r)` onto
    /// the dense grid, inverse-transform to packed `ρ(G)`, apply
    /// [`poisson_scale`], forward-transform back and keep the real part
    /// as `v_H(r)`. Two more executions on the iteration's trace tape,
    /// both through the nb = 1 Hartree plan — pure cache hits at
    /// `alloc_bytes == 0` in steady state like every other transform of
    /// the loop (the buffers come from and return to the plan's pool).
    fn hartree_update(&mut self, backend: &dyn LocalFftBackend) -> (ExecTrace, ExecTrace) {
        // steady-state: scf hartree
        let (mut cube, grew_c) = self.hplan.take_buffer(self.hplan.output_len());
        for (c, &r) in cube.iter_mut().zip(&self.rho) {
            *c = Complex::new(r, 0.0);
        }
        let (mut rg, grew_g) = self.hplan.take_buffer(self.hplan.input_len());
        let mut tr_i = self.hplan.execute_into(backend, &cube, &mut rg, Direction::Inverse);
        tr_i.alloc_bytes += grew_c + grew_g;
        poisson_scale(self.h.kinetic(), &mut rg);
        let tr_f = self.hplan.execute_into(backend, &rg, &mut cube, Direction::Forward);
        for (v, c) in self.vh.iter_mut().zip(&cube) {
            *v = c.re;
        }
        self.hplan.recycle(cube);
        self.hplan.recycle(rg);
        // steady-state: end
        (tr_i, tr_f)
    }

    /// Fold the mixed density and fresh Hartree potential into the local
    /// potential: `v = v_ext + u·ρ + v_H`. Shared verbatim by
    /// [`ScfRunner::run`] and the service-driven loop, so the two paths
    /// stay bit-identical.
    fn fold_potential(&mut self) {
        let u = self.opts.coupling;
        let vext = &self.vext;
        let rho = &self.rho;
        let vh = &self.vh;
        let vloc = self.h.vloc_mut();
        for (i, v) in vloc.iter_mut().enumerate() {
            *v = vext[i] + u * rho[i] + vh[i];
        }
    }

    /// Assemble the iteration's [`EnergyBreakdown`] from the *mixed*
    /// density, the fresh Hartree potential and the orthonormal band
    /// block: four local sums in one fixed order, one 4-slot allreduce.
    /// Shared verbatim by [`ScfRunner::run`] and the service-driven loop,
    /// so every term is bit-identical across the two paths.
    fn energy_breakdown(&self, theta: &[f64], dv: f64) -> EnergyBreakdown {
        let nb = self.h.nb;
        let mut e_kin = 0.0f64;
        for (e, &t) in self.h.kinetic().iter().enumerate() {
            let mut s = 0.0f64;
            for b in 0..nb {
                s += self.psi.local[b + nb * e].norm_sqr();
            }
            e_kin += t * s;
        }
        let (mut e_ext, mut e_h, mut e_mf) = (0.0f64, 0.0f64, 0.0f64);
        let u = self.opts.coupling;
        for (i, &r) in self.rho.iter().enumerate() {
            e_ext += self.vext[i] * r;
            e_h += 0.5 * self.vh[i] * r;
            e_mf += 0.5 * u * r * r;
        }
        let mut sums = [e_kin, e_ext * dv, e_h * dv, e_mf * dv];
        allreduce_sum_f64(&self.comm, &mut sums);
        let band: f64 = theta.iter().sum();
        EnergyBreakdown {
            kinetic: sums[0],
            external: sums[1],
            hartree: sums[2],
            mean_field: sums[3],
            band,
            total: sums[0] + sums[1] + sums[2] + sums[3],
        }
    }

    /// Rayleigh-Ritz rotation plus one preconditioned descent step — the
    /// per-iteration eigen-update shared *verbatim* by [`ScfRunner::run`]
    /// and the service-driven loop ([`ScfServiceDriver`]): one body, so
    /// the two paths are arithmetically identical and their scalars
    /// bit-equal. `resid` enters holding `H psi` (batch-fastest band
    /// block) and leaves as the spent residual block; the caller owns
    /// recycling its storage. Returns the allreduced max band-residual
    /// 2-norm together with that spent block.
    fn ritz_and_descend(
        comm: &Comm,
        psi: &mut [Complex],
        mut resid: Vec<Complex>,
        kin: &[f64],
        nb: usize,
        eigenvalues: &mut [f64],
    ) -> (f64, Vec<Complex>) {
        let m = subspace_matrix(comm, psi, &resid, nb);
        let (theta, u) = eigh_jacobi(&m, 30);
        rotate_bands(psi, nb, &u);
        rotate_bands(&mut resid, nb, &u);
        eigenvalues.copy_from_slice(&theta);

        // Residuals R = H psi - theta psi, then one preconditioned
        // descent step psi <- psi - K R (K = 1 / (1 + kin/|theta|)).
        let mut res2 = vec![0.0f64; nb];
        for (e, chunk) in resid.chunks_exact_mut(nb).enumerate() {
            for b in 0..nb {
                chunk[b] -= psi[b + nb * e].scale(theta[b]);
                res2[b] += chunk[b].norm_sqr();
            }
            let t = kin[e];
            for b in 0..nb {
                let k = 1.0 / (1.0 + t / theta[b].abs().max(0.5));
                psi[b + nb * e] -= chunk[b].scale(k);
            }
        }
        allreduce_sum_f64(comm, &mut res2);
        // res2 was just sum-allreduced (gather-at-0 + broadcast), so
        // every rank holds bit-identical values — the max needs no
        // further collective.
        let max_residual = res2.iter().cloned().fold(0.0, f64::max).sqrt();
        (max_residual, resid)
    }

    /// Absorb a freshly built density: allreduce its charge and L1
    /// change, mix it into the running density (the first iteration
    /// copies outright) and park the storage for the next iteration. The
    /// potential fold happens separately in
    /// [`fold_potential`](Self::fold_potential), after the Hartree solve
    /// of the mixed density. Shared verbatim by [`ScfRunner::run`] and
    /// the service-driven loop. Returns `(charge, delta_rho)`.
    fn absorb_density(&mut self, it: usize, rho_new: Vec<f64>, dv: f64) -> (f64, f64) {
        let mut sums = [
            rho_new.iter().sum::<f64>() * dv,
            rho_new.iter().zip(&self.rho).map(|(a, b)| (a - b).abs()).sum::<f64>() * dv,
        ];
        allreduce_sum_f64(&self.comm, &mut sums);
        let (charge, delta_rho) = (sums[0], sums[1]);

        if it == 1 {
            self.rho.copy_from_slice(&rho_new);
        } else {
            mix_density(&mut self.rho, &rho_new, self.opts.mix);
        }
        self.rho_new = rho_new;
        (charge, delta_rho)
    }
}

/// Several SCF solvers as tenants of one [`TransformService`].
///
/// Each lockstep iteration batches *every* active tenant's bands into the
/// service's shared sphere lane and flushes them as five coalesced
/// executions — the H-apply forward, its inverse, the density forward,
/// and the Hartree round trip's inverse + forward
/// — so two solvers pay roughly one solver's worth of exchange latency
/// instead of two (fewer, larger messages; the paper's batching argument
/// applied across clients). Per-band transforms are arithmetically
/// independent inside a batch, and the per-tenant updates between flushes
/// are the *same code* the standalone runner executes
/// (`ritz_and_descend`, `absorb_density`, [`Hamiltonian`]'s pointwise
/// forms), so every tenant's global scalars — charge, `delta_rho`, max
/// residual — are bit-identical to running that tenant alone on a pinned
/// plane-wave plan. `tests/service.rs` pins this across world sizes.
///
/// SPMD contract: construct, register tenants, and step in identical
/// order on every rank.
pub struct ScfServiceDriver {
    service: TransformService,
    lane: u64,
    it: usize,
    tenants: Vec<ScfTenant>,
}

/// One SCF solver riding the service.
struct ScfTenant {
    id: TenantId,
    runner: ScfRunner,
    /// Reusable interleaved `H psi` block (`[nb, n_local]`, batch
    /// fastest); the spent residual of one iteration becomes the scratch
    /// of the next, so the steady-state loop allocates nothing here.
    hpsi: Vec<Complex>,
    eigenvalues: Vec<f64>,
    max_residual: f64,
    /// Charge and density change of the iteration in flight, parked
    /// between the absorb and the history push (the Hartree flushes sit
    /// between the two).
    charge: f64,
    delta_rho: f64,
    history: Vec<ScfIterStats>,
    converged: bool,
}

impl ScfTenant {
    fn active(&self, it: usize) -> bool {
        !self.converged && it <= self.runner.opts.max_iters
    }
}

fn svc_err(e: ServiceError) -> FftbError {
    FftbError::Runtime(format!("transform service: {e}"))
}

impl ScfServiceDriver {
    /// A driver whose tenants all share `lattice`'s plane-wave sphere on
    /// the world of `comm`. Collective — identical arguments on every
    /// rank.
    pub fn new(lattice: &Lattice, comm: &Comm, config: ServiceConfig) -> Result<ScfServiceDriver> {
        let n = lattice.n;
        let grid = ProcGrid::new(&[comm.size()], comm.clone())?;
        let mut service = TransformService::new([n, n, n], grid, config)?;
        let lane = service.sphere_lane(Arc::clone(&lattice.offsets))?;
        Ok(ScfServiceDriver { service, lane, it: 0, tenants: Vec::new() })
    }

    /// Register one SCF solver as a tenant on a pinned plane-wave plan.
    /// Its quota is sized to exactly its band-parallel working set —
    /// `nb` slots — so a correctly behaving driver never trips admission
    /// while a runaway submitter would. `lattice` must carry the sphere
    /// the driver was built with; registration order must be identical
    /// on every rank.
    pub fn add_tenant(
        &mut self,
        label: &str,
        lattice: Lattice,
        nb: usize,
        potential: &GaussianWells,
        comm: &Comm,
        opts: ScfOptions,
    ) -> Result<TenantId> {
        if lattice.offsets.fingerprint() != self.lane {
            return Err(FftbError::Shape(
                "service SCF tenants must share the driver's plane-wave sphere".into(),
            ));
        }
        let n = lattice.n;
        let grid = ProcGrid::new(&[comm.size()], comm.clone())?;
        let plan = PlaneWavePlan::new(Arc::clone(&lattice.offsets), nb, grid)?;
        let plan = Arc::new(Fftb { kind: PlanKind::PlaneWave(plan), sizes: [n, n, n], nb });
        let runner = ScfRunner::with_plan(lattice, nb, potential, comm, plan, opts)?;
        let slot = match self.service.slot_bytes(self.lane) {
            Some(b) => b,
            None => return Err(FftbError::Runtime("service lane vanished".into())),
        };
        let id = self.service.register_tenant_with_quota(label, nb * slot);
        self.tenants.push(ScfTenant {
            id,
            runner,
            hpsi: Vec::new(),
            eigenvalues: vec![0.0; nb],
            max_residual: 0.0,
            charge: 0.0,
            delta_rho: 0.0,
            history: Vec::new(),
            converged: false,
        });
        Ok(id)
    }

    /// The service under the driver — flush records and per-tenant
    /// metrics for audits.
    pub fn service(&self) -> &TransformService {
        &self.service
    }

    /// Mutable service access, the submission surface for extra non-SCF
    /// tenants sharing the lane: submit their requests *before*
    /// [`ScfServiceDriver::step`] and they coalesce into the iteration's
    /// first forward flush.
    pub fn service_mut(&mut self) -> &mut TransformService {
        &mut self.service
    }

    /// Key of the shared sphere lane.
    pub fn lane(&self) -> u64 {
        self.lane
    }

    /// Run one lockstep SCF iteration across every active tenant — five
    /// coalesced flushes total, regardless of tenant count. Returns
    /// whether any tenant was still active (converged tenants stop
    /// submitting; `delta_rho` is allreduced, so the decision is
    /// SPMD-consistent without extra communication).
    pub fn step(&mut self, backend: &dyn LocalFftBackend) -> Result<bool> {
        self.it += 1;
        let it = self.it;
        if !self.tenants.iter().any(|t| t.active(it)) {
            return Ok(false);
        }
        let rec_mark = self.service.flush_records().len();

        // Phase A: orthonormalize, then submit every active tenant's
        // bands; ONE coalesced sphere-forward flush serves them all.
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let nb = t.runner.h.nb;
            orthonormalize(&t.runner.comm, &mut t.runner.psi.local, nb);
            let per = t.runner.h.n_local();
            for b in 0..nb {
                let mut slot = self
                    .service
                    .checkout(t.id, self.lane, Direction::Forward)
                    .map_err(svc_err)?;
                let dst = slot.data_mut();
                for e in 0..per {
                    dst[e] = t.runner.psi.local[b + nb * e];
                }
                self.service
                    .submit(t.id, self.lane, Direction::Forward, slot)
                    .map_err(svc_err)?;
            }
        }
        self.service.flush(backend, Direction::Forward);

        // Pointwise V(r) on each dense band (the same per-element form as
        // `Hamiltonian::apply`), resubmitted as the inverse half of the
        // Hamiltonian application — again one coalesced flush.
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let collected = self.service.collect(t.id);
            let vloc = t.runner.h.vloc();
            for (_, mut slot) in collected {
                for (i, c) in slot.data_mut().iter_mut().enumerate() {
                    *c = c.scale(vloc[i]);
                }
                self.service
                    .submit(t.id, self.lane, Direction::Inverse, slot)
                    .map_err(svc_err)?;
            }
        }
        self.service.flush(backend, Direction::Inverse);

        // Phase B: assemble `H psi` (kinetic term added in G-space, same
        // form as `Hamiltonian::apply`), then the shared Ritz + descent
        // step; the collected slots drop straight back into the tenant's
        // pool.
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let nb = t.runner.h.nb;
            let per = t.runner.h.n_local();
            t.hpsi.clear();
            t.hpsi.resize(nb * per, ZERO);
            let collected = self.service.collect(t.id);
            debug_assert_eq!(collected.len(), nb, "one inverse result per band");
            for (b, (_, slot)) in collected.iter().enumerate() {
                let src = slot.data();
                for e in 0..per {
                    t.hpsi[b + nb * e] = src[e];
                }
            }
            drop(collected);
            let kin = t.runner.h.kinetic();
            for (e, &tk) in kin.iter().enumerate() {
                for b in 0..nb {
                    let idx = b + nb * e;
                    t.hpsi[idx] += t.runner.psi.local[idx].scale(tk);
                }
            }
            let hpsi = std::mem::take(&mut t.hpsi);
            let (max_res, resid) = ScfRunner::ritz_and_descend(
                &t.runner.comm,
                &mut t.runner.psi.local,
                hpsi,
                t.runner.h.kinetic(),
                nb,
                &mut t.eigenvalues,
            );
            t.max_residual = max_res;
            t.hpsi = resid;
            orthonormalize(&t.runner.comm, &mut t.runner.psi.local, nb);
        }

        // Phase C: density forwards for every active tenant, one more
        // coalesced flush.
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let nb = t.runner.h.nb;
            let per = t.runner.h.n_local();
            for b in 0..nb {
                let mut slot = self
                    .service
                    .checkout(t.id, self.lane, Direction::Forward)
                    .map_err(svc_err)?;
                let dst = slot.data_mut();
                for e in 0..per {
                    dst[e] = t.runner.psi.local[b + nb * e];
                }
                self.service
                    .submit(t.id, self.lane, Direction::Forward, slot)
                    .map_err(svc_err)?;
            }
        }
        self.service.flush(backend, Direction::Forward);

        // Accumulate |psi|^2 per grid point across bands in ascending
        // band order — the exact fold `Hamiltonian::density_into` runs —
        // then the shared absorb (allreduce, mix) per tenant.
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let nb = t.runner.h.nb;
            let collected = self.service.collect(t.id);
            debug_assert_eq!(collected.len(), nb, "one density result per band");
            let npts = t.runner.h.vloc().len();
            let n = t.runner.h.lattice.n;
            let cell_vol = t.runner.h.lattice.a.powi(3);
            let dv = cell_vol / (n * n * n) as f64;
            let scale = 1.0 / cell_vol;
            let mut rho_new = std::mem::take(&mut t.runner.rho_new);
            rho_new.clear();
            rho_new.resize(npts, 0.0);
            for (i, r) in rho_new.iter_mut().enumerate() {
                let mut s = 0.0f64;
                for (_, slot) in &collected {
                    s += slot.data()[i].norm_sqr();
                }
                *r = s * scale;
            }
            drop(collected);
            let (charge, delta_rho) = t.runner.absorb_density(it, rho_new, dv);
            t.charge = charge;
            t.delta_rho = delta_rho;
        }

        // Phase D: the Hartree round trip. Each tenant lifts its mixed
        // density onto the dense grid and submits it down the same lane —
        // one coalesced inverse to packed ρ(G), the G-space Poisson scale
        // (the exact form `ScfRunner::hartree_update` applies), and one
        // coalesced forward back to v_H(r).
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let mut slot = self
                .service
                .checkout(t.id, self.lane, Direction::Inverse)
                .map_err(svc_err)?;
            let dst = slot.data_mut();
            for (c, &r) in dst.iter_mut().zip(&t.runner.rho) {
                *c = Complex::new(r, 0.0);
            }
            self.service
                .submit(t.id, self.lane, Direction::Inverse, slot)
                .map_err(svc_err)?;
        }
        self.service.flush(backend, Direction::Inverse);

        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let collected = self.service.collect(t.id);
            debug_assert_eq!(collected.len(), 1, "one packed density per tenant");
            for (_, mut slot) in collected {
                poisson_scale(t.runner.h.kinetic(), slot.data_mut());
                self.service
                    .submit(t.id, self.lane, Direction::Forward, slot)
                    .map_err(svc_err)?;
            }
        }
        self.service.flush(backend, Direction::Forward);
        let (hit, alloc) = {
            let recs = &self.service.flush_records()[rec_mark..];
            (
                recs.iter().all(|r| r.plan_cache_hit),
                recs.iter().map(|r| r.alloc_bytes).sum::<u64>(),
            )
        };

        // v_H lands; the shared potential fold and energy bookkeeping
        // close the iteration, exactly as in `ScfRunner::run`.
        for t in self.tenants.iter_mut().filter(|t| t.active(it)) {
            let nb = t.runner.h.nb;
            let collected = self.service.collect(t.id);
            debug_assert_eq!(collected.len(), 1, "one Hartree potential per tenant");
            for (_, slot) in &collected {
                for (v, c) in t.runner.vh.iter_mut().zip(slot.data()) {
                    *v = c.re;
                }
            }
            drop(collected);
            t.runner.fold_potential();
            let n = t.runner.h.lattice.n;
            let dv = t.runner.h.lattice.a.powi(3) / (n * n * n) as f64;
            let energy = t.runner.energy_breakdown(&t.eigenvalues, dv);
            t.history.push(ScfIterStats {
                iter: it,
                charge: t.charge,
                delta_rho: t.delta_rho,
                max_residual: t.max_residual,
                plan_cache_hit: hit,
                alloc_bytes: alloc,
                transforms: 5,
                energy,
            });
            if it > 1 && t.delta_rho / nb as f64 < t.runner.opts.tol {
                t.converged = true;
            }
        }
        Ok(true)
    }

    /// Run until every tenant converges or exhausts its iteration budget;
    /// returns one [`ScfResult`] per tenant, in registration order.
    pub fn run(&mut self, backend: &dyn LocalFftBackend) -> Result<Vec<ScfResult>> {
        while self.step(backend)? {}
        Ok(self.results())
    }

    /// Per-tenant results so far, in registration order.
    pub fn results(&self) -> Vec<ScfResult> {
        self.tenants
            .iter()
            .map(|t| ScfResult {
                density: Density {
                    rho: t.runner.rho.clone(),
                    charge: t.history.last().map(|h| h.charge).unwrap_or(0.0),
                },
                eigenvalues: t.eigenvalues.clone(),
                energy: t.history.last().map(|h| h.energy).unwrap_or_default(),
                history: t.history.clone(),
                iterations: t.history.len(),
                converged: t.converged,
                plan_kind: t.runner.plan_kind.clone(),
                window: t.runner.window,
                from_wisdom: t.runner.from_wisdom,
                measured: t.runner.measured,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::dft::eigensolver::{orthonormalize, solve_bands, EigenOptions};
    use crate::dft::hamiltonian::GaussianWells;
    use crate::dft::lattice::Lattice;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::grid::ProcGrid;
    use crate::util::prng::Prng;

    #[test]
    fn orthonormal_bands_integrate_to_band_count() {
        let p = 2;
        let charges = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 12, 2.0);
            let nb = 3;
            let h = Hamiltonian::new(lat, nb, &GaussianWells::single(1.0, 1.5), grid);
            let backend = RustFftBackend::new();
            let mut psi = Prng::new(5 + comm.rank() as u64).complex_vec(nb * h.n_local());
            orthonormalize(&comm, &mut psi, nb);
            build_density(&h, &backend, &comm, &psi).charge
        });
        for c in charges {
            assert!((c - 3.0).abs() < 1e-8, "charge {c}");
        }
    }

    #[test]
    fn density_nonnegative_and_peaked_at_well() {
        let p = 2;
        run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 12, 2.0);
            let nb = 1;
            let h = Hamiltonian::new(
                Lattice::new(8.0, 12, 2.0),
                nb,
                &GaussianWells::single(3.0, 1.2),
                grid.clone(),
            );
            let _ = lat;
            let backend = RustFftBackend::new();
            let mut psi = Prng::new(9).complex_vec(nb * h.n_local());
            solve_bands(
                &h,
                &backend,
                &comm,
                &mut psi,
                &EigenOptions { max_iters: 150, tol: 1e-5, ..Default::default() },
            );
            let d = build_density(&h, &backend, &comm, &psi);
            assert!(d.rho.iter().all(|&v| v >= -1e-12));
            // The max density on the rank owning the cell center should be
            // near the center column (x=y=n/2).
            let n = h.lattice.n;
            let (mut best, mut best_i) = (0.0, 0);
            for (i, &v) in d.rho.iter().enumerate() {
                if v > best {
                    best = v;
                    best_i = i;
                }
            }
            if best > 0.01 {
                let x = best_i % n;
                let y = (best_i / n) % n;
                assert!((x as i64 - (n / 2) as i64).abs() <= 2);
                assert!((y as i64 - (n / 2) as i64).abs() <= 2);
            }
        });
    }

    #[test]
    fn mixing_interpolates() {
        let mut old = vec![1.0, 2.0];
        mix_density(&mut old, &[3.0, 4.0], 0.5);
        assert_eq!(old, vec![2.0, 3.0]);
    }

    #[test]
    fn scf_runner_loop_is_cache_hot_and_conserves_charge() {
        let p = 2;
        let nb = 2;
        let outs = run_world(p, move |comm| {
            let lat = Lattice::new(8.0, 12, 2.0);
            let backend = RustFftBackend::new();
            let opts = ScfOptions { max_iters: 4, tol: 0.0, ..Default::default() };
            let mut runner = ScfRunner::new(
                lat,
                nb,
                &GaussianWells::single(1.0, 1.5),
                &comm,
                &backend,
                opts,
            )
            .unwrap();
            let res = runner.run(&backend);
            let traces = runner.drain_traces();
            (res, traces)
        });
        for (res, traces) in outs {
            assert_eq!(res.iterations, 4, "tol 0 must run out the iteration budget");
            assert_eq!(res.plan_kind, "plane-wave");
            // Orthonormalized bands integrate to the band count every
            // iteration — density conservation through the tuned plan.
            for s in &res.history {
                assert!((s.charge - nb as f64).abs() < 1e-8, "iter {}: {}", s.iter, s.charge);
                assert_eq!(
                    s.transforms, 5,
                    "fwd + inv + density fwd + hartree inv/fwd per iteration"
                );
                assert!(s.plan_cache_hit, "iter {} re-planned", s.iter);
                // The Hartree energy is a positive-semidefinite quadratic
                // form of the density; the breakdown must sum coherently.
                assert!(s.energy.hartree >= -1e-12, "iter {}: {}", s.iter, s.energy.hartree);
                let sum = s.energy.kinetic
                    + s.energy.external
                    + s.energy.hartree
                    + s.energy.mean_field;
                assert!((s.energy.total - sum).abs() < 1e-12);
                assert!(s.energy.total.is_finite());
            }
            // Steady state: no workspace growth anywhere past iteration 1.
            for s in res.history.iter().skip(1) {
                assert_eq!(s.alloc_bytes, 0, "iter {} allocated", s.iter);
            }
            assert_eq!(traces.len(), 5 * res.iterations);
            for t in traces.iter().skip(5) {
                assert!(t.plan_cache_hit && t.alloc_bytes == 0);
            }
        }
    }

    #[test]
    fn scf_runner_couples_density_into_potential() {
        // With a positive mean-field coupling, the potential the loop ends
        // on must be the external wells shifted by exactly u * rho + v_H —
        // i.e. the density genuinely feeds back, and the charge survives.
        let p = 2;
        let outs = run_world(p, |comm| {
            let lat = Lattice::new(8.0, 12, 2.0);
            let backend = RustFftBackend::new();
            let pot = GaussianWells::single(3.0, 1.3);
            let u = 0.5;
            let opts = ScfOptions { max_iters: 5, coupling: u, tol: 1e-9, ..Default::default() };
            let mut r = ScfRunner::new(lat, 1, &pot, &comm, &backend, opts).unwrap();
            let res = r.run(&backend);
            let vext = Hamiltonian::external_potential(
                &r.hamiltonian().lattice,
                &pot,
                comm.size(),
                comm.rank(),
            );
            let vh = r.hartree_potential();
            let worst = r
                .hamiltonian()
                .vloc()
                .iter()
                .enumerate()
                .map(|(i, v)| (v - (vext[i] + u * res.density.rho[i] + vh[i])).abs())
                .fold(0.0, f64::max);
            (res, worst)
        });
        for (res, worst) in outs {
            assert!((res.density.charge - 1.0).abs() < 1e-8);
            assert!(worst < 1e-12, "vloc must equal vext + u*rho + v_H (err {worst})");
            assert!(res.density.rho.iter().any(|&r| r > 1e-6), "density must be nonzero");
        }
    }

    #[test]
    fn poisson_scale_zeroes_the_charge_neutrality_bin() {
        // The G = 0 entry is the one whose kinetic energy is exactly 0.0;
        // the Poisson scale must zero it bitwise (charge neutrality) and
        // scale every other bin by exactly 4 pi / |G|^2 = 4 pi / (2 kin).
        let kin = [0.0f64, 0.5, 2.0];
        let mut rg = [
            Complex::new(3.0, -1.0),
            Complex::new(2.0, 0.5),
            Complex::new(-1.0, 4.0),
        ];
        poisson_scale(&kin, &mut rg);
        assert_eq!(rg[0].re.to_bits(), 0.0f64.to_bits(), "G=0 bin must be exactly zero");
        assert_eq!(rg[0].im.to_bits(), 0.0f64.to_bits(), "G=0 bin must be exactly zero");
        let f1 = 4.0 * std::f64::consts::PI / 1.0;
        let f2 = 4.0 * std::f64::consts::PI / 4.0;
        assert_eq!(rg[1].re.to_bits(), (2.0 * f1).to_bits());
        assert_eq!(rg[1].im.to_bits(), (0.5 * f1).to_bits());
        assert_eq!(rg[2].re.to_bits(), (-1.0 * f2).to_bits());
        assert_eq!(rg[2].im.to_bits(), (4.0 * f2).to_bits());
    }

    #[test]
    fn uniform_density_has_zero_hartree_potential_and_energy() {
        // A uniform density is pure G = 0 — exactly the charge-neutrality
        // bin the Poisson solve zeroes — so v_H must vanish and the
        // Hartree energy with it (to FFT roundoff of the non-DC bins,
        // which hold only cancellation noise).
        let p = 2;
        run_world(p, |comm| {
            let lat = Lattice::new(8.0, 12, 2.0);
            let backend = RustFftBackend::new();
            let opts = ScfOptions { max_iters: 1, tol: 0.0, ..Default::default() };
            let mut r = pinned_runner(lat, 1, &GaussianWells::single(1.0, 1.5), &comm, opts);
            for v in r.rho.iter_mut() {
                *v = 0.75;
            }
            r.hartree_update(&backend);
            let worst = r.hartree_potential().iter().fold(0.0f64, |m, v| m.max(v.abs()));
            assert!(worst < 1e-12, "uniform density must give zero v_H (got {worst})");
            let n = r.h.lattice.n;
            let dv = r.h.lattice.a.powi(3) / (n * n * n) as f64;
            let e = r.energy_breakdown(&[0.0], dv);
            assert!(e.hartree.abs() < 1e-12, "uniform density must give E_H = 0 ({})", e.hartree);
        });
    }

    #[test]
    fn scf_total_energy_settles_and_decreases() {
        // Once mixing settles (the density change per electron drops under
        // 1e-3), the preconditioned descent must push the total energy
        // monotonically down, up to roundoff — the convergence gate the
        // smoke lane in ci.sh holds the example run to.
        let p = 2;
        run_world(p, |comm| {
            let lat = Lattice::new(8.0, 12, 2.0);
            let backend = RustFftBackend::new();
            let opts = ScfOptions { max_iters: 40, tol: 0.0, ..Default::default() };
            let mut r =
                ScfRunner::new(lat, 2, &GaussianWells::single(1.0, 1.5), &comm, &backend, opts)
                    .unwrap();
            let res = r.run(&backend);
            let settle = res
                .history
                .iter()
                .position(|s| s.delta_rho / 2.0 < 1e-3)
                .expect("the smoke lattice must settle within the budget");
            let tail = &res.history[settle..];
            assert!(tail.len() >= 2, "need settled iterations to check");
            for w in tail.windows(2) {
                assert!(
                    w[1].energy.total <= w[0].energy.total + 1e-7,
                    "iter {}: total energy rose {} -> {}",
                    w[1].iter,
                    w[0].energy.total,
                    w[1].energy.total
                );
            }
            // And the residual heads toward the eigenstates.
            let first = res.history.first().unwrap().max_residual;
            let last = res.history.last().unwrap().max_residual;
            assert!(last < first, "residual must shrink ({first} -> {last})");
        });
    }

    /// A standalone runner pinned to the same plane-wave plan the service
    /// driver builds for its tenants.
    fn pinned_runner(
        lat: Lattice,
        nb: usize,
        pot: &GaussianWells,
        comm: &Comm,
        opts: ScfOptions,
    ) -> ScfRunner {
        let n = lat.n;
        let grid = ProcGrid::new(&[comm.size()], comm.clone()).unwrap();
        let plan = PlaneWavePlan::new(Arc::clone(&lat.offsets), nb, grid).unwrap();
        let plan = Arc::new(Fftb { kind: PlanKind::PlaneWave(plan), sizes: [n, n, n], nb });
        ScfRunner::with_plan(lat, nb, pot, comm, plan, opts).unwrap()
    }

    #[test]
    fn service_driver_tenants_match_standalone_runs_bit_for_bit() {
        // Two SCF solvers (different band counts, potentials and seeds)
        // share one TransformService; every iteration's five flushes
        // coalesce both tenants' bands into single batched executions,
        // yet each tenant's scalars, eigenvalues and final density are
        // bit-identical to running it alone on a pinned plan.
        let p = 2;
        let iters = 4;
        run_world(p, move |comm| {
            let lat = Lattice::new(8.0, 12, 2.0);
            let backend = RustFftBackend::new();
            let pot_a = GaussianWells::single(1.0, 1.5);
            let pot_b = GaussianWells::single(3.0, 1.2);
            let opts_a = ScfOptions { max_iters: iters, tol: 0.0, ..Default::default() };
            let opts_b =
                ScfOptions { max_iters: iters, tol: 0.0, seed: 7, ..Default::default() };

            let mut driver =
                ScfServiceDriver::new(&lat, &comm, ServiceConfig::default()).unwrap();
            let a = driver
                .add_tenant("scf-a", lat.clone(), 2, &pot_a, &comm, opts_a.clone())
                .unwrap();
            let b = driver
                .add_tenant("scf-b", lat.clone(), 3, &pot_b, &comm, opts_b.clone())
                .unwrap();
            let results = driver.run(&backend).unwrap();

            // Every iteration flushed both tenants together: five
            // coalesced flushes per iteration — three band flushes of
            // 2 + 3 = 5 jobs each, then the Hartree inverse/forward pair
            // with one job per tenant — not the ten separate ones two
            // isolated loops would pay.
            let recs = driver.service().flush_records();
            assert_eq!(recs.len(), 5 * iters);
            for chunk in recs.chunks_exact(5) {
                for r in chunk {
                    assert_eq!(r.tenants, 2, "flush must serve both tenants");
                }
                for r in &chunk[..3] {
                    assert_eq!(r.jobs, 5, "2 + 3 bands per coalesced band flush");
                }
                for r in &chunk[3..] {
                    assert_eq!(r.jobs, 2, "one Hartree job per tenant");
                }
            }
            // Steady state through the service path: the last iteration
            // ran entirely on cached plans with zero workspace growth.
            let last = results[0].history.last().unwrap();
            assert!(last.plan_cache_hit, "steady-state iterations must be cache hits");
            assert_eq!(last.alloc_bytes, 0, "steady-state iterations must not allocate");
            // Per-tenant telemetry grew: (3 band transforms x nb bands +
            // 2 Hartree legs) x iters requests each, with live latency
            // percentiles.
            let mt = &driver.service().metrics().tenant_metrics()[a.index()];
            assert_eq!(mt.requests, ((3 * 2 + 2) * iters) as u64);
            assert!(mt.p50().is_some() && mt.p95().is_some() && mt.p99().is_some());
            assert_eq!(
                driver.service().metrics().tenant_metrics()[b.index()].requests,
                ((3 * 3 + 2) * iters) as u64
            );
            // All quota charges returned once the run's slots dropped.
            assert_eq!(driver.service().tenant_charged(a), 0);
            assert_eq!(driver.service().tenant_charged(b), 0);

            // The same two problems, each alone on a pinned plan.
            let res_a = pinned_runner(lat.clone(), 2, &pot_a, &comm, opts_a).run(&backend);
            let res_b = pinned_runner(lat.clone(), 3, &pot_b, &comm, opts_b).run(&backend);

            for (svc, alone) in [(&results[0], &res_a), (&results[1], &res_b)] {
                assert_eq!(svc.history.len(), alone.history.len());
                for (s, t) in svc.history.iter().zip(&alone.history) {
                    assert_eq!(s.charge.to_bits(), t.charge.to_bits(), "iter {}", s.iter);
                    assert_eq!(s.delta_rho.to_bits(), t.delta_rho.to_bits(), "iter {}", s.iter);
                    assert_eq!(
                        s.max_residual.to_bits(),
                        t.max_residual.to_bits(),
                        "iter {}",
                        s.iter
                    );
                    assert_eq!(
                        s.energy.total.to_bits(),
                        t.energy.total.to_bits(),
                        "iter {} total energy",
                        s.iter
                    );
                    assert_eq!(
                        s.energy.hartree.to_bits(),
                        t.energy.hartree.to_bits(),
                        "iter {} Hartree energy",
                        s.iter
                    );
                }
                for (x, y) in svc.eigenvalues.iter().zip(&alone.eigenvalues) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
                assert_eq!(svc.density.rho.len(), alone.density.rho.len());
                for (x, y) in svc.density.rho.iter().zip(&alone.density.rho) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        });
    }
}
