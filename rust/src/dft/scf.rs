//! SCF-lite: density construction, charge checks, linear mixing.
//!
//! The mini app is non-self-consistent by default (fixed external
//! potential), but this module demonstrates the density pipeline a real
//! plane-wave code runs after every eigensolve: one more batched
//! plane-wave transform (the same red-line workload of Fig. 9) plus a
//! reduction.

use crate::comm::collectives::allreduce_sum_f64;
use crate::comm::communicator::Comm;
use crate::fft::complex::Complex;
use crate::fftb::backend::LocalFftBackend;

use super::hamiltonian::Hamiltonian;

/// Electron density on this rank's z-slab, plus bookkeeping.
#[derive(Clone, Debug)]
pub struct Density {
    /// n(r) on the local slab `[nx, ny, lzc]`.
    pub rho: Vec<f64>,
    /// Cell integral of n(r) (should equal the band count for orthonormal
    /// filled bands).
    pub charge: f64,
}

/// Build the density from orthonormal bands.
pub fn build_density(
    h: &Hamiltonian,
    backend: &dyn LocalFftBackend,
    comm: &Comm,
    psi: &[Complex],
) -> Density {
    let rho = h.density(backend, psi);
    let n = h.lattice.n;
    let dv = h.lattice.a.powi(3) / (n * n * n) as f64;
    let mut charge = [rho.iter().sum::<f64>() * dv];
    allreduce_sum_f64(comm, &mut charge);
    Density { rho, charge: charge[0] }
}

/// Linear density mixing `rho <- (1-alpha) rho_old + alpha rho_new` —
/// the stabilizer every SCF loop needs.
pub fn mix_density(old: &mut [f64], new: &[f64], alpha: f64) {
    assert_eq!(old.len(), new.len());
    for (o, &n) in old.iter_mut().zip(new) {
        *o = (1.0 - alpha) * *o + alpha * n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::dft::eigensolver::{orthonormalize, solve_bands, EigenOptions};
    use crate::dft::hamiltonian::GaussianWells;
    use crate::dft::lattice::Lattice;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::grid::ProcGrid;
    use crate::util::prng::Prng;

    #[test]
    fn orthonormal_bands_integrate_to_band_count() {
        let p = 2;
        let charges = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 12, 2.0);
            let nb = 3;
            let h = Hamiltonian::new(lat, nb, &GaussianWells::single(1.0, 1.5), grid);
            let backend = RustFftBackend::new();
            let mut psi = Prng::new(5 + comm.rank() as u64).complex_vec(nb * h.n_local());
            orthonormalize(&comm, &mut psi, nb);
            build_density(&h, &backend, &comm, &psi).charge
        });
        for c in charges {
            assert!((c - 3.0).abs() < 1e-8, "charge {c}");
        }
    }

    #[test]
    fn density_nonnegative_and_peaked_at_well() {
        let p = 2;
        run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(8.0, 12, 2.0);
            let nb = 1;
            let h = Hamiltonian::new(
                Lattice::new(8.0, 12, 2.0),
                nb,
                &GaussianWells::single(3.0, 1.2),
                grid.clone(),
            );
            let _ = lat;
            let backend = RustFftBackend::new();
            let mut psi = Prng::new(9).complex_vec(nb * h.n_local());
            solve_bands(
                &h,
                &backend,
                &comm,
                &mut psi,
                &EigenOptions { max_iters: 150, tol: 1e-5, ..Default::default() },
            );
            let d = build_density(&h, &backend, &comm, &psi);
            assert!(d.rho.iter().all(|&v| v >= -1e-12));
            // The max density on the rank owning the cell center should be
            // near the center column (x=y=n/2).
            let n = h.lattice.n;
            let (mut best, mut best_i) = (0.0, 0);
            for (i, &v) in d.rho.iter().enumerate() {
                if v > best {
                    best = v;
                    best_i = i;
                }
            }
            if best > 0.01 {
                let x = best_i % n;
                let y = (best_i / n) % n;
                assert!((x as i64 - (n / 2) as i64).abs() <= 2);
                assert!((y as i64 - (n / 2) as i64).abs() <= 2);
            }
        });
    }

    #[test]
    fn mixing_interpolates() {
        let mut old = vec![1.0, 2.0];
        mix_density(&mut old, &[3.0, 4.0], 0.5);
        assert_eq!(old, vec![2.0, 3.0]);
    }
}
