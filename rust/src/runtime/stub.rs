//! Stub PJRT client used when the `pjrt` cargo feature is off (the default
//! in the offline environment, which has no `xla` crate).
//!
//! Keeps the full `PjrtRuntime` surface so the CLI, benches and integration
//! tests compile unchanged; [`PjrtRuntime::open`] always fails with a clear
//! message, so every caller takes its documented fallback path (the
//! pure-rust backend, or skipping the PJRT tests).

use std::path::Path;

use crate::fftb::error::{FftbError, Result};

use super::manifest::Manifest;

const MSG: &str = "built without the `pjrt` cargo feature; \
     rebuild with `--features pjrt` (requires the vendored `xla` crate)";

/// Placeholder runtime: can never be constructed, so the methods beyond
/// [`PjrtRuntime::open`] exist only to satisfy the shared call sites.
pub struct PjrtRuntime {
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Always fails: there is no PJRT client in this build.
    pub fn open(_dir: impl AsRef<Path>) -> Result<Self> {
        Err(FftbError::Runtime(MSG.into()))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has_entry(&self, _name: &str) -> bool {
        false
    }

    pub fn execute_f32(&self, _name: &str, _input: &[f32]) -> Result<Vec<f32>> {
        Err(FftbError::Runtime(MSG.into()))
    }

    pub fn compiled_count(&self) -> usize {
        0
    }
}
