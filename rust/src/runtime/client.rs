//! The real PJRT client (`pjrt` feature). Requires the vendored `xla`
//! crate in the build environment — see `rust/README.md`.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax >= 0.5's 64-bit-id protos), and
//! entries are lowered with `return_tuple=True`, so results unwrap with
//! `to_tuple1`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::fftb::error::{FftbError, Result};

use super::manifest::Manifest;

fn err(msg: String) -> FftbError {
    FftbError::Runtime(msg)
}

struct Inner {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// A loaded artifact directory: PJRT CPU client + lazily compiled entries.
///
/// The `xla` wrapper types hold raw pointers and are not `Send`/`Sync`;
/// the PJRT CPU client itself is thread-safe for compile/execute, and we
/// additionally serialize every call through the `Mutex`, so sharing the
/// runtime across rank threads is sound.
pub struct PjrtRuntime {
    dir: PathBuf,
    manifest: Manifest,
    inner: Mutex<Inner>,
}

// SAFETY: the non-Send xla handles live in `inner` and every access goes
// through its mutex, so moving the runtime between threads cannot observe
// a handle mid-use; raw handles are never handed out.
unsafe impl Send for PjrtRuntime {}
// SAFETY: as for `Send` — the `inner` mutex serializes all use of the xla
// handles, so `&PjrtRuntime` may be shared across rank threads.
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Open `artifacts/` (reads `manifest.json`, creates the CPU client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .map_err(|e| err(format!("loading manifest from {}: {e}", dir.display())))?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| err(format!("PJRT cpu client: {e:?}")))?;
        Ok(PjrtRuntime {
            dir,
            manifest,
            inner: Mutex::new(Inner { client, execs: HashMap::new() }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.manifest.entry(name).is_some()
    }

    /// Execute entry `name` with one f32 input of the manifest's shape
    /// (flattened, row-major); returns the flattened f32 output.
    pub fn execute_f32(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .entry(name)
            .ok_or_else(|| err(format!("no artifact entry named `{name}`")))?;
        let shape = &entry.inputs[0];
        let want: usize = shape.iter().product();
        if input.len() != want {
            return Err(err(format!(
                "entry `{name}` expects {want} f32s (shape {shape:?}), got {}",
                input.len()
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let file = self.dir.join(&entry.file);

        let mut inner = self.inner.lock().unwrap();
        if !inner.execs.contains_key(name) {
            let proto = xla::HloModuleProto::from_text_file(&file)
                .map_err(|e| err(format!("parsing {}: {e:?}", file.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| err(format!("compiling {name}: {e:?}")))?;
            inner.execs.insert(name.to_string(), exe);
        }
        let exe = match inner.execs.get(name) {
            Some(exe) => exe,
            None => return Err(err(format!("entry `{name}` vanished from the executable cache"))),
        };

        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| err(format!("reshape input: {e:?}")))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| err(format!("executing {name}: {e:?}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| err(format!("fetching result: {e:?}")))?;
        // Entries are lowered with return_tuple=True -> 1-tuple.
        let out = out.to_tuple1().map_err(|e| err(format!("untuple: {e:?}")))?;
        out.to_vec::<f32>().map_err(|e| err(format!("to_vec: {e:?}")))
    }

    /// Number of compiled (cached) entries.
    pub fn compiled_count(&self) -> usize {
        self.inner.lock().unwrap().execs.len()
    }
}
