//! `PjrtFftBackend` — the [`LocalFftBackend`] that runs batched line FFTs
//! through the AOT-compiled Pallas/XLA artifacts instead of the rust
//! substrate. This is the production wiring of the three-layer stack:
//! L3 plans → contiguous line batches → PJRT executables (L2/L1).
//!
//! The artifacts are compiled for a fixed batch tile (`manifest.batch`) and
//! a fixed set of line lengths; the backend tiles arbitrary batches (zero
//! padding the tail tile) and falls back to the rust substrate for sizes
//! without an artifact, counting both paths for the metrics report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::{LocalFftBackend, RustFftBackend};

use super::PjrtRuntime;

/// [`LocalFftBackend`] that runs batched line FFTs through AOT-compiled
/// PJRT artifacts, falling back to the rust substrate for uncovered sizes.
pub struct PjrtFftBackend {
    rt: Arc<PjrtRuntime>,
    fallback: RustFftBackend,
    /// Lines executed through PJRT artifacts.
    pub pjrt_lines: AtomicU64,
    /// Lines that fell back to the rust substrate (no artifact for n).
    pub fallback_lines: AtomicU64,
}

impl PjrtFftBackend {
    /// Wrap an opened PJRT runtime.
    pub fn new(rt: Arc<PjrtRuntime>) -> Self {
        PjrtFftBackend {
            rt,
            fallback: RustFftBackend::new(),
            pjrt_lines: AtomicU64::new(0),
            fallback_lines: AtomicU64::new(0),
        }
    }

    /// The underlying PJRT runtime handle.
    pub fn runtime(&self) -> &Arc<PjrtRuntime> {
        &self.rt
    }

    fn entry_name(n: usize, dir: Direction) -> String {
        match dir {
            Direction::Forward => format!("fft{n}_f"),
            Direction::Inverse => format!("fft{n}_i"),
        }
    }

    /// Transform `lines` full tiles worth of data through the artifact.
    fn run_tile(&self, name: &str, tile: &mut [Complex], n: usize) {
        let batch = self.rt.manifest().batch;
        debug_assert_eq!(tile.len(), batch * n);
        // f64 complex -> f32 interleaved (B, n, 2).
        let mut buf = Vec::with_capacity(batch * n * 2);
        for c in tile.iter() {
            buf.push(c.re as f32);
            buf.push(c.im as f32);
        }
        let out = self
            .rt
            .execute_f32(name, &buf)
            // pallas-lint: allow(no-panic) — `LocalFftBackend::fft_batch`
            // has no error channel; an execute failure on an artifact that
            // loaded and compiled at open() means the artifact itself is
            // broken, and aborting loudly beats silently corrupting data.
            .unwrap_or_else(|e| panic!("PJRT execute {name}: {e:#}"));
        debug_assert_eq!(out.len(), batch * n * 2);
        for (c, pair) in tile.iter_mut().zip(out.chunks_exact(2)) {
            c.re = pair[0] as f64;
            c.im = pair[1] as f64;
        }
    }
}

impl LocalFftBackend for PjrtFftBackend {
    fn fft_batch(&self, data: &mut [Complex], n: usize, dir: Direction) {
        assert_eq!(data.len() % n, 0);
        let nlines = data.len() / n;
        let name = Self::entry_name(n, dir);
        if !self.rt.has_entry(&name) {
            self.fallback_lines.fetch_add(nlines as u64, Ordering::Relaxed);
            return self.fallback.fft_batch(data, n, dir);
        }
        self.pjrt_lines.fetch_add(nlines as u64, Ordering::Relaxed);
        let batch = self.rt.manifest().batch;
        let tile_len = batch * n;

        let full_tiles = (nlines / batch) * tile_len;
        for tile in data[..full_tiles].chunks_exact_mut(tile_len) {
            self.run_tile(&name, tile, n);
        }
        let rem = &mut data[full_tiles..];
        if !rem.is_empty() {
            // Zero-pad the tail tile.
            let mut tile = vec![crate::fft::complex::ZERO; tile_len];
            tile[..rem.len()].copy_from_slice(rem);
            self.run_tile(&name, &mut tile, n);
            rem.copy_from_slice(&tile[..rem.len()]);
        }
    }

    fn name(&self) -> &str {
        "pjrt-pallas"
    }
}
