//! PJRT artifact runtime: load the AOT-compiled HLO text produced by
//! `python/compile/aot.py`, compile it once on the PJRT CPU client, and
//! execute it from the rust hot path. Python is never loaded at runtime.
//!
//! The real client needs the vendored `xla` crate, which the offline
//! default build does not carry — it is gated behind the non-default
//! `pjrt` cargo feature. With the feature off, [`PjrtRuntime::open`] is a
//! stub that returns a clear [`FftbError::Runtime`](crate::fftb::FftbError)
//! so every caller (CLI, benches, integration tests) degrades to the
//! pure-rust backend instead of failing to compile. [`Manifest`] parsing
//! and [`PjrtFftBackend`] are dependency-free and always available.

pub mod backend;
pub mod manifest;

#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
mod client;

pub use backend::PjrtFftBackend;
pub use client::PjrtRuntime;
pub use manifest::{Manifest, ManifestEntry};
