//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: entry names, files, and input shapes.
//!
//! Errors surface as [`FftbError::Runtime`]; this module has no external
//! dependencies, so it is available with or without the `pjrt` feature.

use std::path::Path;

use crate::fftb::error::{FftbError, Result};
use crate::util::json::Json;

fn err(msg: String) -> FftbError {
    FftbError::Runtime(msg)
}

/// One AOT-compiled executable in the artifact manifest.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Entry name (e.g. `fft64_f`).
    pub name: String,
    /// HLO text file relative to the manifest.
    pub file: String,
    /// Input shapes (row-major dims), one per positional argument.
    pub inputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Batch tile every fft entry was compiled for.
    pub batch: usize,
    /// All compiled entries.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load and parse a manifest file.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| err(format!("reading {}: {e}", path.as_ref().display())))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| err(format!("manifest JSON: {e}")))?;
        let batch = j
            .get("batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| err("manifest missing `batch`".into()))?;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("manifest missing `entries`".into()))?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| err("entry missing `name`".into()))?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| err(format!("entry `{name}` missing `file`")))?
                .to_string();
            let mut inputs = Vec::new();
            for shape in e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| err(format!("entry `{name}` missing `inputs`")))?
            {
                let dims: Option<Vec<usize>> = shape
                    .as_arr()
                    .map(|a| a.iter().filter_map(Json::as_usize).collect());
                inputs.push(dims.ok_or_else(|| err(format!("bad shape in `{name}`")))?);
            }
            entries.push(ManifestEntry { name, file, inputs });
        }
        Ok(Manifest { batch, entries })
    }

    /// Look up an entry by name.
    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Line lengths with both forward and inverse fft entries present.
    pub fn fft_sizes(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for e in &self.entries {
            if let Some(rest) = e.name.strip_prefix("fft") {
                if let Some(n) = rest.strip_suffix("_f").and_then(|s| s.parse::<usize>().ok()) {
                    if self.entry(&format!("fft{n}_i")).is_some() {
                        out.push(n);
                    }
                }
            }
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "batch": 64,
 "entries": [
  {"name": "fft8_f", "file": "fft8_f.hlo.txt", "inputs": [[64, 8, 2]]},
  {"name": "fft8_i", "file": "fft8_i.hlo.txt", "inputs": [[64, 8, 2]]},
  {"name": "padfft_4_8_2_f", "file": "padfft_4_8_2_f.hlo.txt", "inputs": [[64, 4, 2]]}
 ]
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.entry("fft8_f").unwrap().inputs[0], vec![64, 8, 2]);
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn fft_sizes_requires_both_directions() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.fft_sizes(), vec![8]);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"entries": []}"#).is_err());
        assert!(Manifest::parse(r#"{"batch": 64}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
