//! Candidate enumeration and model-based ranking — the part of the tuner
//! that answers "which decomposition, on which grid factorization, with
//! which exchange window?".
//!
//! For a [`TuneRequest`] the search enumerates every plan the framework
//! could run (slab-pencil and its non-batched loop on a 1D grid, every
//! pencil factorization `p0 x p1 = p` of the rank count, plane-wave staged
//! padding and the pad-to-cube baseline for sphere inputs), crossed with
//! the exchange-window ladder `{1, 2, 4, ...}` and the exchange's
//! helper-worker axis (worker on/off). Each candidate is priced by
//! the exact stage counts of [`model::cost`](crate::model::cost) on a
//! [`Machine`] — the fused windowed alltoall model
//! ([`Machine::alltoall_time_fused`](crate::model::machine::Machine::alltoall_time_fused))
//! prices both the overlap knob *and* the pack/unpack traffic each
//! exchange hides behind its waits, so fused schedules shift the window
//! optimum — and the result is a deterministically ordered ranking: pure
//! arithmetic on rank-independent inputs, so every rank of an SPMD program
//! computes the *same* list and picks the same winner without
//! communicating.

use std::sync::Arc;

use crate::comm::alltoall::CommTuning;
use crate::comm::communicator::Comm;
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::ProcGrid;
use crate::fftb::plan::{
    Fftb, NonBatchedLoop, PaddedSpherePlan, PencilPlan, PlaneWaveLoop, PlaneWavePlan, PlanKind,
    RealPlaneWavePlan, SlabPencilPlan,
};
use crate::fftb::sphere::OffsetArray;
use crate::model::cost::{self, PlanCost};
use crate::model::machine::Machine;

/// One decomposition the planner could select (before window crossing).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CandidateKind {
    /// Batched slab-pencil on a 1D grid of all `p` ranks.
    SlabPencil,
    /// Non-batched loop of single slab-pencil transforms (1D grid).
    SlabPencilLoop,
    /// Pencil decomposition on a `p0 x p1` grid.
    Pencil {
        /// Grid extent along axis 0 (splits x/y).
        p0: usize,
        /// Grid extent along axis 1 (splits y/z).
        p1: usize,
    },
    /// Plane-wave staged padding for sphere inputs (1D grid).
    PlaneWave,
    /// Non-batched loop of single plane-wave sphere transforms (1D grid):
    /// per-band exchange cadence instead of one fused batched exchange.
    PlaneWaveLoop,
    /// Real-input (r2c/c2r) plane-wave sphere transform (1D grid): the
    /// fused exchange carries only the `nz/2 + 1` Hermitian-unique z bins.
    /// Enumerated only for requests flagged [`TuneRequest::real`].
    PlaneWaveR2c,
    /// Pad-to-cube baseline for sphere inputs (1D grid).
    PaddedSphere,
}

impl CandidateKind {
    /// Stable label, also used as the plan-cache / wisdom kind key.
    pub fn label(&self) -> String {
        match self {
            CandidateKind::SlabPencil => "slab-pencil".into(),
            CandidateKind::SlabPencilLoop => "slab-pencil-loop".into(),
            CandidateKind::Pencil { p0, p1 } => format!("pencil:{p0}x{p1}"),
            CandidateKind::PlaneWave => "plane-wave".into(),
            CandidateKind::PlaneWaveLoop => "plane-wave-loop".into(),
            CandidateKind::PlaneWaveR2c => "plane-wave-r2c".into(),
            CandidateKind::PaddedSphere => "padded-sphere".into(),
        }
    }

    /// Parse a [`CandidateKind::label`] back (wisdom deserialization).
    pub fn from_label(s: &str) -> Option<CandidateKind> {
        match s {
            "slab-pencil" => Some(CandidateKind::SlabPencil),
            "slab-pencil-loop" => Some(CandidateKind::SlabPencilLoop),
            "plane-wave" => Some(CandidateKind::PlaneWave),
            "plane-wave-loop" => Some(CandidateKind::PlaneWaveLoop),
            "plane-wave-r2c" => Some(CandidateKind::PlaneWaveR2c),
            "padded-sphere" => Some(CandidateKind::PaddedSphere),
            _ => {
                let rest = s.strip_prefix("pencil:")?;
                let (a, b) = rest.split_once('x')?;
                Some(CandidateKind::Pencil { p0: a.parse().ok()?, p1: b.parse().ok()? })
            }
        }
    }
}

/// How the requested plan will be driven — what one "use" of the plan
/// looks like to the caller. The tuner's empirical mode measures exactly
/// this shape, and the wisdom/cache signatures keep the profiles apart so
/// a winner measured under one cadence never steers the other.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WorkloadProfile {
    /// One forward transform per use (the historical probe shape).
    #[default]
    Forward,
    /// One forward *and* one inverse transform per use — the SCF loop's
    /// cadence (G→r, multiply by V(r), r→G every Hamiltonian application),
    /// where inverse-heavy costs would be mispriced by a forward-only
    /// measurement.
    RoundTrip,
}

/// A tuning question: what to transform, over how many ranks.
#[derive(Clone)]
pub struct TuneRequest {
    /// Global transform sizes `[nx, ny, nz]`.
    pub shape: [usize; 3],
    /// Batch count.
    pub nb: usize,
    /// Total rank count the plan must run on.
    pub p: usize,
    /// Offset array of the cut-off sphere for sphere workloads; `None`
    /// selects the dense cuboid candidate set.
    pub sphere: Option<Arc<OffsetArray>>,
    /// The cadence the plan will be driven at (empirical probes measure
    /// this shape; signatures keep the profiles' wisdom apart).
    pub profile: WorkloadProfile,
    /// The sphere coefficients are real (Γ-point wavefunctions): enumerate
    /// the r2c/c2r half-spectrum candidate alongside the c2c family, and
    /// keep this request's wisdom/cache entries apart from complex ones
    /// (the signature carries an `|r2c` suffix).
    pub real: bool,
}

impl TuneRequest {
    /// Canonical string form — the wisdom key and the cache signature.
    /// Sphere requests carry the offset array's structural fingerprint, so
    /// two different spheres with the same point count never share a plan
    /// or a wisdom entry; round-trip (SCF-shaped) requests carry an `|rt`
    /// suffix so their measured winners never steer forward-only requests.
    pub fn signature(&self) -> String {
        let [nx, ny, nz] = self.shape;
        let sphere = match &self.sphere {
            Some(off) => format!("sphere:{}:{:016x}", off.total(), off.fingerprint()),
            None => "dense".into(),
        };
        let rt = match self.profile {
            WorkloadProfile::Forward => "",
            WorkloadProfile::RoundTrip => "|rt",
        };
        let r2c = if self.real { "|r2c" } else { "" };
        format!("{nx}x{ny}x{nz}|nb={}|p={}|{sphere}{rt}{r2c}", self.nb, self.p)
    }
}

/// One priced candidate: decomposition + window + worker mode + predicted
/// seconds.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// The decomposition.
    pub kind: CandidateKind,
    /// Exchange window (`CommTuning::window`) the prediction assumed.
    pub window: usize,
    /// Whether the exchange's helper worker thread
    /// (`CommTuning::worker`) was priced in — pack/unpack hidden behind
    /// the waits, a per-message handoff charge in its place.
    pub worker: bool,
    /// Model-predicted execution time, seconds.
    pub predicted: f64,
}

/// The exchange-window ladder for `p` ranks: powers of two up to the round
/// count `p - 1`, with the full window appended (e.g. `p = 8` gives
/// `[1, 2, 4, 7]`).
pub fn windows(p: usize) -> Vec<usize> {
    let msgs = p.saturating_sub(1).max(1);
    let mut out = Vec::new();
    let mut w = 1usize;
    while w < msgs {
        out.push(w);
        w *= 2;
    }
    out.push(msgs);
    out
}

/// All ordered factorizations `p0 * p1 == p` (includes the degenerate
/// `1 x p` and `p x 1` grids).
pub fn factorizations(p: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for p0 in 1..=p {
        if p % p0 == 0 {
            out.push((p0, p / p0));
        }
    }
    out
}

/// Enumerate every *feasible* decomposition for `req`, mirroring the
/// feasibility checks of the concrete plan constructors (so nothing the
/// search returns can fail to build).
pub fn enumerate(req: &TuneRequest) -> Vec<CandidateKind> {
    let [nx, ny, nz] = req.shape;
    let p = req.p;
    let mut out = Vec::new();
    if let Some(off) = &req.sphere {
        // Sphere workloads: 1D-grid plans only (the paper's pattern). The
        // offsets must describe the requested cube — the plans are built
        // (and priced) from the offsets' own extents, so a mismatched
        // request has no feasible candidate rather than a surprise
        // constructor failure downstream.
        if req.shape == [off.nx, off.ny, off.nz] && p <= nx && p <= nz {
            out.push(CandidateKind::PlaneWave);
            if req.nb > 1 {
                out.push(CandidateKind::PlaneWaveLoop);
            }
            // Real coefficients open the half-spectrum candidate: needs an
            // even nz (the two-for-one z packing) and a rank per unique
            // bin. The c2c family stays enumerated — embedding real data
            // is always legal — so the ranking decides on price.
            if req.real && nz % 2 == 0 && p <= nz / 2 + 1 {
                out.push(CandidateKind::PlaneWaveR2c);
            }
            out.push(CandidateKind::PaddedSphere);
        }
        return out;
    }
    if p <= nx && p <= nz {
        out.push(CandidateKind::SlabPencil);
        if req.nb > 1 {
            out.push(CandidateKind::SlabPencilLoop);
        }
    }
    for (p0, p1) in factorizations(p) {
        if p0 <= nx.min(ny) && p1 <= ny.min(nz) {
            out.push(CandidateKind::Pencil { p0, p1 });
        }
    }
    out
}

/// The sphere offsets of a plane-wave-family request. [`enumerate`] emits
/// sphere candidate kinds only for requests that carry offsets, so every
/// sphere kind reaching [`stage_cost`] or [`build`] has `Some` here —
/// absence is a caller bug worth an immediate abort.
fn sphere_of(req: &TuneRequest) -> &Arc<OffsetArray> {
    match req.sphere.as_ref() {
        Some(off) => off,
        // pallas-lint: allow(no-panic) — unreachable for candidates
        // produced by `enumerate` (see above).
        None => panic!("sphere candidate priced against a sphere-free request"),
    }
}

/// Exact stage counts of one candidate (the `model::cost` table it is
/// priced from).
pub fn stage_cost(kind: CandidateKind, req: &TuneRequest) -> PlanCost {
    match kind {
        CandidateKind::SlabPencil => cost::slab_pencil(req.shape, req.nb, req.p, true),
        CandidateKind::SlabPencilLoop => cost::slab_pencil(req.shape, req.nb, req.p, false),
        CandidateKind::Pencil { p0, p1 } => cost::pencil(req.shape, req.nb, p0, p1, true),
        CandidateKind::PlaneWave => cost::planewave(sphere_of(req), req.nb, req.p, true),
        CandidateKind::PlaneWaveLoop => cost::planewave(sphere_of(req), req.nb, req.p, false),
        CandidateKind::PlaneWaveR2c => cost::planewave_r2c(sphere_of(req), req.nb, req.p),
        CandidateKind::PaddedSphere => cost::padded_sphere(sphere_of(req), req.nb, req.p),
    }
}

/// Price one `(kind, window)` pair on `m` through the same stage walk the
/// Fig. 9 projections use ([`price_stages`](crate::model::scaling::price_stages)).
pub fn predict(kind: CandidateKind, window: usize, req: &TuneRequest, m: &Machine) -> f64 {
    crate::model::scaling::price_stages(&stage_cost(kind, req), m, window)
}

/// Enumerate, cross with the window ladder *and* the worker on/off axis,
/// price, and sort: cheapest first, ties broken by the (total) ordering
/// on kind, then window, then worker-off-first, so the ranking is
/// deterministic across ranks. The (window-independent) stage table is
/// derived once per decomposition, not once per rung.
pub fn rank_candidates(req: &TuneRequest, m: &Machine) -> Vec<Candidate> {
    let mut out: Vec<Candidate> = Vec::new();
    let ladder = windows(req.p);
    for kind in enumerate(req) {
        let cost = stage_cost(kind, req);
        for &window in &ladder {
            for worker in [false, true] {
                out.push(Candidate {
                    kind,
                    window,
                    worker,
                    predicted: crate::model::scaling::price_stages_with(&cost, m, window, worker),
                });
            }
        }
    }
    out.sort_by(|a, b| {
        a.predicted
            .total_cmp(&b.predicted)
            .then_with(|| a.kind.cmp(&b.kind))
            .then_with(|| a.window.cmp(&b.window))
            .then_with(|| a.worker.cmp(&b.worker))
    });
    out
}

/// The measurement shortlist: the first (cheapest) candidate per distinct
/// *decomposition*, in rank order, capped at `cap`. Window rungs of one
/// kind execute near-identically (the windowed exchange is bit-identical
/// and close in time), so measuring them would compare a plan against
/// itself — the empirical mode and `benches/tuner_ablation.rs` both
/// measure over this list instead.
pub fn shortlist(req: &TuneRequest, m: &Machine, cap: usize) -> Vec<Candidate> {
    shortlist_of(&rank_candidates(req, m), cap)
}

/// [`shortlist`] over an already-computed [`rank_candidates`] list (the
/// tuner has one in hand; no point re-enumerating and re-pricing).
pub fn shortlist_of(ranked: &[Candidate], cap: usize) -> Vec<Candidate> {
    let mut out: Vec<Candidate> = Vec::new();
    if cap == 0 {
        return out;
    }
    for c in ranked {
        if !out.iter().any(|s| s.kind == c.kind) {
            out.push(c.clone());
        }
        if out.len() == cap {
            break;
        }
    }
    out
}

/// The model's pick: the cheapest candidate, or an `Unsupported` error when
/// no decomposition is feasible for the request.
pub fn best(req: &TuneRequest, m: &Machine) -> Result<Candidate> {
    rank_candidates(req, m).into_iter().next().ok_or_else(|| {
        FftbError::Unsupported(format!(
            "no feasible decomposition for shape {:?} on p={}",
            req.shape, req.p
        ))
    })
}

/// Build the concrete [`Fftb`] for a chosen candidate: construct the grid
/// it wants over `comm`, run the matching plan constructor, and set the
/// window. Used by `Tuner::plan_auto` and the empirical measurement pass.
pub fn build(cand: &Candidate, req: &TuneRequest, comm: &Comm) -> Result<Fftb> {
    let kind = match cand.kind {
        CandidateKind::SlabPencil => {
            let grid = ProcGrid::new(&[req.p], comm.clone())?;
            PlanKind::SlabPencil(SlabPencilPlan::new(req.shape, req.nb, grid)?)
        }
        CandidateKind::SlabPencilLoop => {
            let grid = ProcGrid::new(&[req.p], comm.clone())?;
            PlanKind::SlabPencilLoop(NonBatchedLoop::new(req.shape, req.nb, grid)?)
        }
        CandidateKind::Pencil { p0, p1 } => {
            let grid = ProcGrid::new(&[p0, p1], comm.clone())?;
            PlanKind::Pencil(PencilPlan::new(req.shape, req.nb, grid)?)
        }
        CandidateKind::PlaneWave => {
            let grid = ProcGrid::new(&[req.p], comm.clone())?;
            let off = Arc::clone(sphere_of(req));
            PlanKind::PlaneWave(PlaneWavePlan::new(off, req.nb, grid)?)
        }
        CandidateKind::PlaneWaveLoop => {
            let grid = ProcGrid::new(&[req.p], comm.clone())?;
            let off = Arc::clone(sphere_of(req));
            PlanKind::PlaneWaveLoop(PlaneWaveLoop::new(off, req.nb, grid)?)
        }
        CandidateKind::PlaneWaveR2c => {
            let grid = ProcGrid::new(&[req.p], comm.clone())?;
            let off = Arc::clone(sphere_of(req));
            PlanKind::PlaneWaveR2c(RealPlaneWavePlan::new(off, req.nb, grid)?)
        }
        CandidateKind::PaddedSphere => {
            let grid = ProcGrid::new(&[req.p], comm.clone())?;
            let off = Arc::clone(sphere_of(req));
            PlanKind::PaddedSphere(PaddedSpherePlan::new(off, req.nb, grid)?)
        }
    };
    let mut fx = Fftb { kind, sizes: req.shape, nb: req.nb };
    fx.set_comm_tuning(CommTuning::with_window(cand.window).with_worker(cand.worker));
    Ok(fx)
}

/// Pick the cheapest exchange window for one decomposition of a request —
/// the window-only search shared by [`auto_window_for`] (the
/// `FftbOptions::auto()` path) and
/// [`BatchingDriver::with_auto_window`](crate::coordinator::BatchingDriver::with_auto_window)
/// (which resolves a window per flushed batch size). Deterministic across
/// ranks: pricing uses the rank-0 worst-rank stage counts of `model::cost`,
/// and ties keep the narrower window.
pub fn auto_window(kind: CandidateKind, req: &TuneRequest, m: &Machine) -> usize {
    let cost = stage_cost(kind, req);
    let mut best = (f64::INFINITY, 1usize);
    for w in windows(req.p) {
        let t = crate::model::scaling::price_stages(&cost, m, w);
        // Strict `<`: ties keep the narrower window (deterministic).
        if t < best.0 {
            best = (t, w);
        }
    }
    best.1
}

/// Pick the cheapest exchange window for an already-constructed plan (the
/// `FftbOptions::auto()` path, where the tensors have pinned the
/// decomposition and only the window is free).
pub fn auto_window_for(fx: &Fftb, m: &Machine) -> usize {
    let (kind, p, sphere) = match &fx.kind {
        PlanKind::SlabPencil(pl) => (CandidateKind::SlabPencil, pl.grid_size(), None),
        PlanKind::SlabPencilLoop(pl) => (CandidateKind::SlabPencilLoop, pl.grid_size(), None),
        PlanKind::Pencil(pl) => (
            CandidateKind::Pencil { p0: pl.grid_dims().0, p1: pl.grid_dims().1 },
            pl.grid_dims().0 * pl.grid_dims().1,
            None,
        ),
        PlanKind::PlaneWave(pl) => {
            (CandidateKind::PlaneWave, pl.grid_size(), Some(Arc::clone(&pl.offsets)))
        }
        PlanKind::PlaneWaveLoop(pl) => {
            (CandidateKind::PlaneWaveLoop, pl.grid_size(), Some(Arc::clone(pl.offsets())))
        }
        PlanKind::PaddedSphere(pl) => {
            (CandidateKind::PaddedSphere, pl.grid_size(), Some(Arc::clone(&pl.offsets)))
        }
        PlanKind::PlaneWaveR2c(pl) => {
            (CandidateKind::PlaneWaveR2c, pl.grid_size(), Some(Arc::clone(&pl.offsets)))
        }
    };
    let req = TuneRequest {
        shape: fx.sizes,
        nb: fx.nb,
        p,
        sphere,
        profile: WorkloadProfile::Forward,
        real: matches!(kind, CandidateKind::PlaneWaveR2c),
    };
    auto_window(kind, &req, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    fn dense(shape: [usize; 3], nb: usize, p: usize) -> TuneRequest {
        TuneRequest { shape, nb, p, sphere: None, profile: WorkloadProfile::Forward, real: false }
    }

    fn sphere(n: usize, nb: usize, p: usize, off: Arc<OffsetArray>) -> TuneRequest {
        TuneRequest {
            shape: [n, n, n],
            nb,
            p,
            sphere: Some(off),
            profile: WorkloadProfile::Forward,
            real: false,
        }
    }

    #[test]
    fn window_ladder_shapes() {
        assert_eq!(windows(2), vec![1]);
        assert_eq!(windows(4), vec![1, 2, 3]);
        assert_eq!(windows(8), vec![1, 2, 4, 7]);
        assert_eq!(windows(1), vec![1]);
    }

    #[test]
    fn enumerate_respects_feasibility() {
        // Prime p on a shape that rules out the 1D-grid plans entirely.
        let req = dense([4, 8, 8], 1, 7);
        let cands = enumerate(&req);
        assert!(!cands.contains(&CandidateKind::SlabPencil), "7 > nx=4");
        assert!(cands.contains(&CandidateKind::Pencil { p0: 1, p1: 7 }));
        assert!(!cands.contains(&CandidateKind::Pencil { p0: 7, p1: 1 }), "p0=7 > nx=4");
        // Every enumerated pencil factorization must satisfy the plan's
        // own constructor bounds.
        for c in &cands {
            if let CandidateKind::Pencil { p0, p1 } = c {
                assert!(*p0 <= 4 && *p1 <= 8);
            }
        }
    }

    #[test]
    fn sphere_requests_get_sphere_candidates_only() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
        let req = sphere(8, 2, 2, Arc::new(spec.offsets()));
        let cands = enumerate(&req);
        assert_eq!(
            cands,
            vec![
                CandidateKind::PlaneWave,
                CandidateKind::PlaneWaveLoop,
                CandidateKind::PaddedSphere
            ]
        );
        // Single-band requests have no loop to run.
        let single = sphere(8, 1, 2, Arc::clone(req.sphere.as_ref().unwrap()));
        assert!(!enumerate(&single).contains(&CandidateKind::PlaneWaveLoop));
    }

    #[test]
    fn mismatched_sphere_shape_has_no_candidates() {
        // The plans are built from the offsets' own extents; a request
        // whose shape disagrees must have an empty feasible set instead of
        // a surprise constructor failure.
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
        let req = TuneRequest {
            shape: [16, 16, 16],
            nb: 1,
            p: 2,
            sphere: Some(Arc::new(spec.offsets())),
            profile: WorkloadProfile::Forward,
            real: false,
        };
        assert!(enumerate(&req).is_empty());
        assert!(best(&req, &Machine::local_cpu()).is_err());
    }

    #[test]
    fn planewave_ranks_first_for_spheres() {
        let n = 32;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let req = sphere(n, 4, 4, Arc::new(spec.offsets()));
        let ranked = rank_candidates(&req, &Machine::local_cpu());
        assert!(!ranked.is_empty());
        assert_eq!(ranked[0].kind, CandidateKind::PlaneWave, "staged padding must win");
    }

    #[test]
    fn batched_outranks_non_batched_loop() {
        let req = dense([16, 16, 16], 8, 4);
        let m = Machine::perlmutter_a100();
        let batched = predict(CandidateKind::SlabPencil, 2, &req, &m);
        let looped = predict(CandidateKind::SlabPencilLoop, 2, &req, &m);
        assert!(batched < looped, "batched {batched} must beat looped {looped}");
    }

    #[test]
    fn planewave_loop_priced_distinctly_from_batched() {
        // The acceptance pin: the batched plane-wave variant and its
        // non-batched loop must never collapse to the same cost (they did
        // before the loop carried its own round count) — and there must
        // exist a machine where the *winner* flips.
        let n = 32;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let req = sphere(n, 8, 4, Arc::clone(&off));

        // On the live-testbed machine the costs differ and batching wins
        // (per-band exchanges pay nb x the latency convoy).
        let m = Machine::local_cpu();
        for w in windows(req.p) {
            let batched = predict(CandidateKind::PlaneWave, w, &req, &m);
            let looped = predict(CandidateKind::PlaneWaveLoop, w, &req, &m);
            assert_ne!(batched, looped, "window {w}: the two cadences priced identically");
            assert!(batched < looped, "window {w}: batching must win on local_cpu");
        }
        let ranked = rank_candidates(&req, &m);
        assert_eq!(ranked[0].kind, CandidateKind::PlaneWave);
        assert!(ranked.iter().any(|c| c.kind == CandidateKind::PlaneWaveLoop));

        // A machine whose eager (small-message) protocol is much cheaper
        // than rendezvous: the batched exchange's large blocks pay the full
        // rendezvous latency while the loop's per-band blocks stay eager —
        // the winner flips to the loop cadence.
        let batched_msg = {
            let c = stage_cost(CandidateKind::PlaneWave, &req);
            c.stages[1].a2a_bytes / (req.p - 1) as f64
        };
        let eager = Machine {
            name: "eager-interconnect",
            small_msg_threshold: batched_msg as usize, // loop msgs fall below
            small_msg_alpha_factor: 0.02,              // eager skips rendezvous
            alpha: 5.0e-5,
            ..Machine::local_cpu()
        };
        let ranked = rank_candidates(&req, &eager);
        assert_eq!(
            ranked[0].kind,
            CandidateKind::PlaneWaveLoop,
            "eager machine must flip the winner to the per-band cadence"
        );
    }

    #[test]
    fn round_trip_signature_is_distinct() {
        let fwd = dense([8, 8, 8], 2, 2);
        let rt = TuneRequest { profile: WorkloadProfile::RoundTrip, ..fwd.clone() };
        assert_ne!(fwd.signature(), rt.signature());
        assert!(rt.signature().ends_with("|rt"));
    }

    #[test]
    fn ranking_is_deterministic() {
        let req = dense([16, 16, 16], 4, 8);
        let m = Machine::local_cpu();
        let a = rank_candidates(&req, &m);
        let b = rank_candidates(&req, &m);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.window, y.window);
            assert_eq!(x.predicted.to_bits(), y.predicted.to_bits());
        }
    }

    #[test]
    fn labels_round_trip() {
        for kind in [
            CandidateKind::SlabPencil,
            CandidateKind::SlabPencilLoop,
            CandidateKind::Pencil { p0: 3, p1: 5 },
            CandidateKind::PlaneWave,
            CandidateKind::PlaneWaveLoop,
            CandidateKind::PlaneWaveR2c,
            CandidateKind::PaddedSphere,
        ] {
            assert_eq!(CandidateKind::from_label(&kind.label()), Some(kind));
        }
        assert_eq!(CandidateKind::from_label("nonsense"), None);
    }

    #[test]
    fn r2c_candidate_beats_c2c_for_real_spheres() {
        // Acceptance pin: a real-flagged sphere request must surface the
        // half-spectrum candidate and its modeled cost must beat every c2c
        // variant on local_cpu (it moves ~(nz/2+1)/nz of the bytes and
        // runs a half-length z FFT).
        let n = 16;
        let spec = SphereSpec::new([n, n, n], 4.0, SphereKind::Centered);
        let mut req = sphere(n, 4, 4, Arc::new(spec.offsets()));
        req.real = true;
        assert!(req.signature().ends_with("|r2c"), "{}", req.signature());
        assert!(enumerate(&req).contains(&CandidateKind::PlaneWaveR2c));
        let m = Machine::local_cpu();
        let ranked = rank_candidates(&req, &m);
        assert_eq!(ranked[0].kind, CandidateKind::PlaneWaveR2c, "r2c must win for real inputs");
        let best_of = |k: CandidateKind| {
            ranked.iter().find(|c| c.kind == k).map(|c| c.predicted).unwrap()
        };
        assert!(best_of(CandidateKind::PlaneWaveR2c) < best_of(CandidateKind::PlaneWave));

        // Complex requests on the same sphere never see the r2c candidate.
        let complex = sphere(n, 4, 4, Arc::clone(req.sphere.as_ref().unwrap()));
        assert!(!enumerate(&complex).contains(&CandidateKind::PlaneWaveR2c));
        assert_ne!(complex.signature(), req.signature());

        // Odd nz: the two-for-one packing is infeasible, so only the c2c
        // family is enumerated even for real requests.
        let odd_spec = SphereSpec::new([16, 16, 15], 4.0, SphereKind::Centered);
        let odd = TuneRequest {
            shape: [16, 16, 15],
            nb: 1,
            p: 2,
            sphere: Some(Arc::new(odd_spec.offsets())),
            profile: WorkloadProfile::Forward,
            real: true,
        };
        let cands = enumerate(&odd);
        assert!(!cands.contains(&CandidateKind::PlaneWaveR2c));
        assert!(cands.contains(&CandidateKind::PlaneWave));
    }

    #[test]
    fn worker_choice_flips_between_machine_profiles() {
        // The acceptance pin of the worker axis: two machine profiles on
        // the same request must disagree about engaging the helper, so the
        // tuner demonstrably treats worker-on/off as a real priced axis.
        let req = dense([16, 16, 16], 8, 4);
        // Pack-bound profile: modest memory bandwidth makes the exposed
        // pack fraction expensive while handoffs stay cheap — the helper
        // must be engaged.
        let pack_bound = Machine {
            name: "pack-bound",
            mem_bw: 2.0e9,
            alpha: 1.0e-7,
            ..Machine::local_cpu()
        };
        let ranked = rank_candidates(&req, &pack_bound);
        assert!(
            ranked[0].worker,
            "pack-bound machine must hide pack/unpack on the helper thread"
        );
        // Latency-bound profile: pack is effectively free and every
        // channel handoff costs a quarter of a (large) message latency —
        // the helper is pure overhead.
        let latency_bound = Machine {
            name: "latency-bound",
            mem_bw: 1.0e15,
            alpha: 1.0e-3,
            ..Machine::local_cpu()
        };
        let ranked = rank_candidates(&req, &latency_bound);
        assert!(
            !ranked[0].worker,
            "latency-bound machine must keep the exchange single-threaded"
        );
        // Both settings are enumerated for every (kind, window) pair.
        let ranked = rank_candidates(&req, &Machine::local_cpu());
        assert!(ranked.iter().any(|c| c.worker) && ranked.iter().any(|c| !c.worker));
        assert_eq!(ranked.len() % 2, 0, "the worker axis doubles the candidate set");
    }

    #[test]
    fn infeasible_request_is_unsupported() {
        // p larger than every dimension: nothing fits.
        let req = dense([2, 2, 2], 1, 64);
        assert!(matches!(best(&req, &Machine::local_cpu()), Err(FftbError::Unsupported(_))));
    }
}
