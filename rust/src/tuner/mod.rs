//! The autotuning planner — the planning brain between the coordinator
//! layer and `fftb::plan`.
//!
//! The paper's core pitch is *flexibility*: one framework that picks the
//! right decomposition (slab-pencil vs pencil vs batched plane-wave
//! spheres) for each workload instead of hand-coding per application. The
//! `model` layer has always been able to *price* every plan kind on a
//! described machine; this subsystem is what finally consumes those prices:
//!
//! * [`cache`] — [`PlanCache`]: memoized `Fftb` objects keyed by
//!   `(shape, signature, kind, nb, direction, sphere, window, worker,
//!   transform)`, extending
//!   plan-once / execute-many to the layer that requests plans.
//! * [`search`] — feasible-candidate enumeration (all decompositions ×
//!   grid factorizations × exchange windows) and deterministic model-based
//!   ranking.
//! * [`calibrate`] — timed micro-runs that refine the cost model's
//!   constants to the actual host, plus the *empirical* mode that executes
//!   the top-k model candidates once and keeps the measured winner.
//! * [`wisdom`] — FFTW-style persisted tuning records (calibration +
//!   per-request winners) through `util::json`.
//!
//! [`Tuner`] composes the four; [`Fftb::plan_auto`] is the one-call entry
//! point (`FftbOptions::auto()` is the lighter variant that only frees the
//! exchange window when the tensors have already pinned the decomposition).
//!
//! ## SPMD determinism
//!
//! Every rank runs the same tuning logic on rank-independent inputs: the
//! model prices the *worst-rank* stage counts (rank 0 owns the ceiling of
//! every cyclic split), so ranking is pure arithmetic that agrees across
//! ranks without communication. The empirical mode does communicate — its
//! per-candidate timings are allreduced to the cross-rank critical path —
//! and therefore also agrees. `tests/tuner.rs` pins both properties.
//!
//! ---
//!
//! The user guide below is `docs/TUNING.md`, included verbatim — its code
//! blocks run as doctests, so every walkthrough in the guide is checked
//! by `cargo test --doc`.
//!
#![doc = include_str!("../../../docs/TUNING.md")]
#![warn(missing_docs)]

pub mod cache;
pub mod calibrate;
pub mod search;
pub mod wisdom;

use std::sync::Arc;

use crate::comm::communicator::Comm;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::error::{FftbError, Result};
use crate::fftb::plan::Fftb;
use crate::fftb::sphere::OffsetArray;
use crate::model::machine::Machine;

pub use cache::{PlanCache, PlanKey};
pub use calibrate::{calibrate_local, calibrated_local_machine, Calibration};
pub use search::{Candidate, CandidateKind, TuneRequest, WorkloadProfile};
pub use wisdom::{Probe, Wisdom, WisdomEntry};

/// The result of one auto-planning call: the (shared, possibly cached)
/// plan plus how the tuner arrived at it.
pub struct TunedPlan {
    /// The constructed (or cache-served) plan.
    pub plan: Arc<Fftb>,
    /// The winning candidate (decomposition + window + seconds).
    pub choice: Candidate,
    /// Whether the plan object came out of the [`PlanCache`].
    pub cache_hit: bool,
    /// Whether the decision came from persisted [`Wisdom`] rather than a
    /// fresh search.
    pub from_wisdom: bool,
    /// Whether the decision was confirmed by live measurement (empirical
    /// mode) in this call.
    pub measured: bool,
}

/// The autotuning planner: a machine description to price candidates on,
/// a plan cache, persisted wisdom, and the empirical-mode knob.
pub struct Tuner {
    /// Machine the cost model prices candidates on.
    pub machine: Machine,
    /// Memoized plans (see [`PlanCache`]).
    pub cache: PlanCache,
    /// Persisted winners and calibration (see [`Wisdom`]).
    pub wisdom: Wisdom,
    /// When `> 1` and a backend is supplied to [`Tuner::plan_auto`], the
    /// top-k model candidates are executed once and the measured winner is
    /// kept (the paper-style "try the shortlist" mode). `0` or `1` trusts
    /// the model outright.
    pub empirical_top_k: usize,
    /// Wisdom lifecycle knob for long-lived services: when `> 0`, a wisdom
    /// entry that has steered `remeasure_after` requests is retired and the
    /// next request runs a fresh search (or empirical probe) instead of
    /// trusting the remembered winner forever. `0` (the default) keeps
    /// entries live indefinitely. Retirement is pure arithmetic on the
    /// entry's `loads` counter, so all SPMD ranks retire and re-search in
    /// lockstep; the re-search lands on the same [`PlanKey`], so cached
    /// plan objects keep their identity across a re-measure.
    pub remeasure_after: u64,
}

impl Tuner {
    /// A tuner pricing on the given machine, empty cache and wisdom.
    pub fn new(machine: Machine) -> Self {
        Tuner {
            machine,
            cache: PlanCache::new(),
            wisdom: Wisdom::new(),
            empirical_top_k: 0,
            remeasure_after: 0,
        }
    }

    /// A tuner for the live in-process testbed ([`Machine::local_cpu`]).
    pub fn local() -> Self {
        Self::new(Machine::local_cpu())
    }

    /// A tuner whose machine constants come from stored wisdom when the
    /// file carries a calibration record (falling back to `base`'s
    /// constants otherwise).
    pub fn with_wisdom(base: Machine, wisdom: Wisdom) -> Self {
        let machine = match &wisdom.calibration {
            Some(c) => c.apply(base),
            None => base,
        };
        Tuner { machine, cache: PlanCache::new(), wisdom, empirical_top_k: 0, remeasure_after: 0 }
    }

    /// Run the calibration micro-probes ([`calibrate_local`]) and fold the
    /// measured constants into this tuner's machine and wisdom. Spawns a
    /// private two-rank world — call *before* SPMD execution. Previously
    /// remembered winners are dropped: they were ranked with the old
    /// constants and would otherwise pin stale decisions (wisdom files are
    /// machine-specific for the same reason — load them only on the host
    /// that wrote them).
    pub fn calibrate(&mut self, backend: &dyn LocalFftBackend) -> Calibration {
        let c = calibrate_local(backend);
        self.machine = c.apply(self.machine.clone());
        self.wisdom.calibration = Some(c);
        self.wisdom.clear_entries();
        c
    }

    /// Pick, build and cache the best plan for a workload with zero
    /// user-supplied `PlanKind` or window.
    ///
    /// `sphere` selects the sphere candidate set (plane-wave staged padding
    /// vs pad-to-cube); `None` the dense cuboid set. `backend` enables the
    /// empirical mode when [`Tuner::empirical_top_k`] asks for it.
    /// Collective over `comm` (grid construction splits communicators; the
    /// empirical mode allreduces timings): every rank must call with
    /// identical arguments, and every rank returns the same choice.
    ///
    /// Convenience alias for
    /// `Fftb::request(shape).nb(nb).sphere_opt(sphere).plan(..)`.
    pub fn plan_auto(
        &mut self,
        shape: [usize; 3],
        nb: usize,
        sphere: Option<Arc<OffsetArray>>,
        comm: &Comm,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<TunedPlan> {
        Fftb::request(shape).nb(nb).sphere_opt(sphere).plan(self, comm, backend)
    }

    /// [`Tuner::plan_auto`] for real-input (r2c/c2r) workloads: the request
    /// carries the `real` flag, so the search enumerates the Hermitian
    /// half-spectrum plane-wave family alongside the c2c candidates and the
    /// signature, wisdom and plan-cache entries (`PlanKey::r2c`) never
    /// collide with complex requests on the same sphere. Requires a sphere:
    /// the half-traffic exchange is a sphere-plan property.
    ///
    /// Convenience alias for
    /// `Fftb::request(shape).nb(nb).sphere(sphere).real().plan(..)`.
    pub fn plan_auto_real(
        &mut self,
        shape: [usize; 3],
        nb: usize,
        sphere: Arc<OffsetArray>,
        comm: &Comm,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<TunedPlan> {
        Fftb::request(shape).nb(nb).sphere(sphere).real().plan(self, comm, backend)
    }

    /// [`Tuner::plan_auto`] for SCF-shaped (round-trip) workloads: the
    /// request is tagged [`WorkloadProfile::RoundTrip`], so its wisdom and
    /// cache entries never collide with forward-only requests, and the
    /// empirical mode (when enabled) measures the alternating
    /// forward/inverse cadence through
    /// [`calibrate::measure_candidates_scf`] instead of the forward-only
    /// probe — the critical-path seconds of one G→r / r→G pair, allreduced
    /// across ranks and persisted to wisdom with probe kind `"scf"`.
    ///
    /// Convenience alias for `Fftb::request(shape).nb(nb).sphere_opt(sphere)
    /// .workload(WorkloadProfile::RoundTrip).plan(..)`.
    pub fn plan_auto_scf(
        &mut self,
        shape: [usize; 3],
        nb: usize,
        sphere: Option<Arc<OffsetArray>>,
        comm: &Comm,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<TunedPlan> {
        Fftb::request(shape)
            .nb(nb)
            .sphere_opt(sphere)
            .workload(WorkloadProfile::RoundTrip)
            .plan(self, comm, backend)
    }

    /// Resolve an assembled [`TuneRequest`]: wisdom lookup → model ranking
    /// → optional empirical probe (shaped by the request's profile) →
    /// wisdom record → plan-cache fetch. The request comes from the one
    /// builder that assembles them,
    /// [`Fftb::request`](crate::fftb::plan::Fftb::request) — the named
    /// `plan_auto*` entry points are aliases over that builder. Collective
    /// over `comm`; `req.p` must equal `comm.size()`.
    pub fn plan_request(
        &mut self,
        req: TuneRequest,
        comm: &Comm,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<TunedPlan> {
        let shape = req.shape;
        let nb = req.nb;
        let profile = req.profile;
        if req.p != comm.size() {
            return Err(FftbError::Unsupported(format!(
                "request was assembled for p={} but the communicator has {} ranks",
                req.p,
                comm.size()
            )));
        }
        if let Some(off) = &req.sphere {
            if shape != [off.nx, off.ny, off.nz] {
                return Err(FftbError::Unsupported(format!(
                    "sphere offsets describe a {}x{}x{} grid but the requested shape \
                     is {shape:?}",
                    off.nx, off.ny, off.nz
                )));
            }
        }
        let sphere_fp = req.sphere.as_ref().map_or(0, |o| o.fingerprint());
        let sig = req.signature();

        // Wisdom lifecycle: retire entries that have steered too many
        // requests so a long-lived service re-validates its plans (see
        // [`Tuner::remeasure_after`]). Deterministic across ranks — the
        // counter advances identically everywhere.
        if self.remeasure_after > 0 {
            let stale =
                matches!(self.wisdom.lookup(&sig), Some(e) if e.loads >= self.remeasure_after);
            if stale {
                self.wisdom.remove(&sig);
            }
        }

        let mut prebuilt: Option<Arc<Fftb>> = None;
        let mut probe = Probe::Model;
        // Live critical-path seconds when the empirical mode ran; the
        // wisdom record falls back to the model prediction otherwise.
        let mut measured_seconds: Option<f64> = None;
        let (choice, from_wisdom) =
            match self.wisdom.note_load(&sig).and_then(WisdomEntry::candidate) {
                Some(c) => (c, true),
                None => {
                    let ranked = search::rank_candidates(&req, &self.machine);
                    if ranked.is_empty() {
                        return Err(FftbError::Unsupported(format!(
                            "no feasible decomposition for shape {shape:?} on p={}",
                            req.p
                        )));
                    }
                    // Empirical mode measures one candidate per distinct
                    // decomposition, at its model-best window (see
                    // search::shortlist) — but only when there genuinely
                    // is more than one decomposition to compare.
                    let mut short = Vec::new();
                    if backend.is_some() && self.empirical_top_k > 1 {
                        short = search::shortlist_of(&ranked, self.empirical_top_k);
                    }
                    let choice = match backend {
                        Some(be) if short.len() > 1 => {
                            let plans = short
                                .iter()
                                .map(|c| search::build(c, &req, comm).map(Arc::new))
                                .collect::<Result<Vec<_>>>()?;
                            // Probe the cadence the caller will run: the
                            // SCF-shaped probe times one fwd + inv pair,
                            // replacing the forward-only measurement for
                            // inverse-heavy (round-trip) requests.
                            let (win, secs) = match profile {
                                WorkloadProfile::Forward => {
                                    probe = Probe::Forward;
                                    calibrate::measure_candidates(&plans, be, comm)
                                }
                                WorkloadProfile::RoundTrip => {
                                    probe = Probe::Scf;
                                    calibrate::measure_candidates_scf(&plans, be, comm)
                                }
                            };
                            measured_seconds = Some(secs);
                            prebuilt = Some(Arc::clone(&plans[win]));
                            short.swap_remove(win)
                        }
                        // pallas-lint: allow(no-panic) — `ranked` was
                        // checked non-empty right after rank_candidates,
                        // so the model-mode head always exists.
                        _ => ranked.into_iter().next().unwrap(),
                    };
                    (choice, false)
                }
            };

        if !from_wisdom {
            self.wisdom.record(
                sig.clone(),
                WisdomEntry {
                    kind: choice.kind.label(),
                    window: choice.window,
                    worker: choice.worker,
                    seconds: measured_seconds.unwrap_or(choice.predicted),
                    measured: probe.is_measured(),
                    probe,
                    loads: 0,
                    measured_at: wisdom::now_secs(),
                    r2c: matches!(choice.kind, CandidateKind::PlaneWaveR2c),
                },
            );
        }
        let measured = probe.is_measured();

        let key = PlanKey {
            comm_id: comm.identity(),
            sizes: shape,
            signature: sig.into(),
            kind: choice.kind.label().into(),
            nb,
            dir: None,
            sphere: sphere_fp,
            window: choice.window,
            worker: choice.worker,
            r2c: matches!(choice.kind, CandidateKind::PlaneWaveR2c),
        };
        let (plan, cache_hit) = match prebuilt {
            Some(plan) => {
                // Built fresh this call during measurement: install it
                // without touching the hit/miss counters.
                self.cache.insert(key, Arc::clone(&plan));
                (plan, false)
            }
            None => self.cache.get_or_insert(key, || search::build(&choice, &req, comm))?,
        };
        Ok(TunedPlan { plan, choice, cache_hit, from_wisdom, measured })
    }
}
