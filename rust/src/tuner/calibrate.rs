//! Machine calibration: refine the cost model's constants from timed
//! micro-runs on the actual host.
//!
//! [`Machine::local_cpu`] ships plausible defaults for "a modern server
//! core", but the candidate ranking is only as good as the constants it
//! prices with. [`calibrate_local`] measures the four model constants with
//! small probes:
//!
//! * `fft_flops_per_sec` — batched power-of-two line FFTs through the live
//!   [`LocalFftBackend`] (the same kernels the plans run),
//! * `mem_bw` — a pack-shaped buffer copy (read + write streams),
//! * `alpha` / `beta` — a two-rank flat exchange through the existing
//!   nonblocking engine at a small and a large message size; the latency
//!   is the small-message time, the per-byte rate comes from the delta.
//!
//! Calibration spawns its own micro-world, so call it **before** entering
//! SPMD execution and share the resulting [`Machine`] with every rank —
//! identical constants are what make the ranking deterministic across
//! ranks. Inside an SPMD region, use [`measure_candidates`] (the tuner's
//! *empirical* mode): it executes already-built candidate plans once per
//! rank, reduces each timing to the cross-rank critical path, and every
//! rank deterministically keeps the measured winner.

use std::sync::Arc;
use std::time::Instant;

use crate::comm::alltoall::{alltoallv_complex_flat_tuned, CommTuning};
use crate::comm::collectives::allreduce_max_f64;
use crate::comm::communicator::{run_world, Comm};
use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::plan::Fftb;
use crate::model::machine::Machine;

/// Measured model constants, applied to a base [`Machine`] with
/// [`Calibration::apply`] and persisted through the wisdom file.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Calibration {
    /// Effective local FFT throughput, complex-FLOP/s.
    pub fft_flops_per_sec: f64,
    /// Effective pack/unpack memory bandwidth, B/s.
    pub mem_bw: f64,
    /// Per-message exchange latency, seconds.
    pub alpha: f64,
    /// Per-byte exchange time, s/B.
    pub beta: f64,
}

impl Calibration {
    /// Overwrite `base`'s rate constants with the measured ones (guarding
    /// against non-finite or non-positive probes, which keep the default).
    pub fn apply(&self, mut base: Machine) -> Machine {
        base = base.calibrated(self.fft_flops_per_sec, self.mem_bw);
        if self.alpha.is_finite() && self.alpha > 0.0 {
            base.alpha = self.alpha;
        }
        if self.beta.is_finite() && self.beta > 0.0 {
            base.beta = self.beta;
        }
        base
    }
}

/// Median-of-runs wall time of `f`, in seconds.
fn timed(runs: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

/// Measure local FFT throughput: `lines` batched length-`n` line FFTs
/// through `backend`, converted via the model's own flop formula.
fn measure_fft(backend: &dyn LocalFftBackend) -> f64 {
    let (n, lines) = (64usize, 256usize);
    let mut buf = vec![Complex::new(1.0, 0.5); n * lines];
    let secs = timed(5, || {
        backend.fft_batch(&mut buf, n, Direction::Forward);
    });
    let flops = backend.flops(n * lines, n);
    flops / secs.max(1e-9)
}

/// Measure pack-shaped memory bandwidth: copy a buffer (one read + one
/// write stream per element).
fn measure_mem_bw() -> f64 {
    let elems = 1usize << 18; // 4 MiB of complex
    let src = vec![Complex::new(0.25, -0.75); elems];
    let mut dst = vec![ZERO; elems];
    let secs = timed(5, || {
        dst.copy_from_slice(&src);
        std::hint::black_box(&mut dst);
    });
    let bytes = 2.0 * (elems * std::mem::size_of::<Complex>()) as f64;
    bytes / secs.max(1e-9)
}

/// Measure `alpha`/`beta` with a two-rank flat exchange through the
/// nonblocking engine at two message sizes.
fn measure_exchange() -> (f64, f64) {
    let small = 64usize; // elements per block
    let large = 1usize << 15;
    let times = run_world(2, move |comm| {
        let mut t = [0.0f64; 2];
        for (i, &n) in [small, large].iter().enumerate() {
            let send = vec![Complex::new(1.0, -1.0); 2 * n];
            let mut recv = vec![ZERO; 2 * n];
            let offs = vec![0usize, n, 2 * n];
            t[i] = timed(5, || {
                alltoallv_complex_flat_tuned(
                    &comm,
                    &send,
                    &offs,
                    &mut recv,
                    &offs,
                    CommTuning::serial(),
                );
            });
        }
        t
    });
    // Critical path over the two ranks.
    let t_small = times.iter().map(|t| t[0]).fold(0.0, f64::max);
    let t_large = times.iter().map(|t| t[1]).fold(0.0, f64::max);
    let alpha = t_small.max(1e-9);
    let dbytes = ((large - small) * std::mem::size_of::<Complex>()) as f64;
    let beta = ((t_large - t_small) / dbytes).max(1e-15);
    (alpha, beta)
}

/// Run every probe and return the measured constants. Spawns a private
/// two-rank world for the exchange probe — call before SPMD execution.
pub fn calibrate_local(backend: &dyn LocalFftBackend) -> Calibration {
    let (alpha, beta) = measure_exchange();
    Calibration { fft_flops_per_sec: measure_fft(backend), mem_bw: measure_mem_bw(), alpha, beta }
}

/// [`calibrate_local`] applied to [`Machine::local_cpu`] in one call.
pub fn calibrated_local_machine(backend: &dyn LocalFftBackend) -> Machine {
    calibrate_local(backend).apply(Machine::local_cpu())
}

/// Empirical mode: execute each candidate plan twice (forward, zero
/// input) — the first run warms its workspaces, only the second is timed,
/// so the measurement reflects the steady-state execute-many regime the
/// tuner optimizes for, not one-time setup. Each timing is reduced to the
/// cross-rank max (the critical path); returns `(index, seconds)` of the
/// measured winner. Collective — every rank must call with plans built
/// from the same ranked list; the allreduce makes the winner (and its
/// time) identical everywhere.
pub fn measure_candidates(
    plans: &[Arc<Fftb>],
    backend: &dyn LocalFftBackend,
    comm: &Comm,
) -> (usize, f64) {
    measure_with(plans, backend, comm, false)
}

/// The SCF-shaped empirical probe: like [`measure_candidates`] but each
/// timed use is one **forward plus one inverse** transform — the
/// alternating G→r / r→G cadence every Hamiltonian application of a
/// plane-wave SCF loop runs. A forward-only measurement misprices
/// inverse-heavy workloads whose two directions cost differently (e.g.
/// the staged-padding sphere plans, whose pack kernels are asymmetric);
/// this probe is what [`Tuner::plan_auto_scf`](crate::tuner::Tuner::plan_auto_scf)
/// runs for round-trip requests, and its critical-path seconds are what
/// lands in the wisdom record (probe kind `"scf"`). Collective, same
/// contract as [`measure_candidates`].
pub fn measure_candidates_scf(
    plans: &[Arc<Fftb>],
    backend: &dyn LocalFftBackend,
    comm: &Comm,
) -> (usize, f64) {
    measure_with(plans, backend, comm, true)
}

/// Shared body of the two empirical probes: warm up (fwd + inv when
/// `round_trip`, so both directions' workspaces reach their high-water
/// mark untimed), then time one use and allreduce it to the cross-rank
/// critical path.
fn measure_with(
    plans: &[Arc<Fftb>],
    backend: &dyn LocalFftBackend,
    comm: &Comm,
    round_trip: bool,
) -> (usize, f64) {
    assert!(!plans.is_empty(), "measure_candidates needs at least one plan");
    let mut best = (f64::INFINITY, 0usize);
    for (i, plan) in plans.iter().enumerate() {
        // Warm-up: grows workspaces and slot pools, untimed.
        let (warm, _) = plan.execute(backend, vec![ZERO; plan.input_len()], Direction::Forward);
        if round_trip {
            let (back, _) = plan.execute(backend, warm, Direction::Inverse);
            plan.recycle(back);
        } else {
            plan.recycle(warm);
        }
        let input = vec![ZERO; plan.input_len()];
        let t0 = Instant::now();
        let (out, _) = plan.execute(backend, input, Direction::Forward);
        let mine = if round_trip {
            let (back, _) = plan.execute(backend, out, Direction::Inverse);
            let secs = t0.elapsed().as_secs_f64();
            plan.recycle(back);
            secs
        } else {
            let secs = t0.elapsed().as_secs_f64();
            plan.recycle(out);
            secs
        };
        let worst = allreduce_max_f64(comm, mine);
        if worst < best.0 {
            best = (worst, i);
        }
    }
    (best.1, best.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::backend::RustFftBackend;

    #[test]
    fn calibration_produces_sane_constants() {
        let backend = RustFftBackend::new();
        let c = calibrate_local(&backend);
        // Very loose bounds: a working host is somewhere within 1e6x of a
        // modern core on every axis.
        assert!(c.fft_flops_per_sec > 1e6 && c.fft_flops_per_sec < 1e14);
        assert!(c.mem_bw > 1e6 && c.mem_bw < 1e14);
        assert!(c.alpha > 0.0 && c.alpha < 1.0);
        assert!(c.beta > 0.0 && c.beta < 1e-3);
    }

    #[test]
    fn apply_overrides_base_machine() {
        let c = Calibration { fft_flops_per_sec: 1e9, mem_bw: 2e9, alpha: 1e-6, beta: 1e-10 };
        let m = c.apply(Machine::local_cpu());
        assert_eq!(m.fft_flops_per_sec, 1e9);
        assert_eq!(m.mem_bw, 2e9);
        assert_eq!(m.alpha, 1e-6);
        assert_eq!(m.beta, 1e-10);
        // Bad probes keep the defaults.
        let bad =
            Calibration { fft_flops_per_sec: f64::NAN, mem_bw: -1.0, alpha: 0.0, beta: 1e-10 };
        let m2 = bad.apply(Machine::local_cpu());
        let base = Machine::local_cpu();
        assert_eq!(m2.fft_flops_per_sec, base.fft_flops_per_sec);
        assert_eq!(m2.mem_bw, base.mem_bw);
        assert_eq!(m2.alpha, base.alpha);
        assert_eq!(m2.beta, 1e-10);
    }
}
