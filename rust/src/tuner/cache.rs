//! The plan cache: plan-once / execute-many, extended to the layer that
//! *requests* plans.
//!
//! Individual plans already amortize their own setup (schedules, workspaces)
//! across executions, but a coordinator that re-plans per request — the
//! `BatchingDriver::flush` pattern — pays the planning cost and a cold
//! workspace every time. A [`PlanCache`] memoizes constructed [`Fftb`]
//! objects behind a [`PlanKey`], so repeated requests with the same shape,
//! distribution signature, plan kind, batch count, direction, exchange
//! window and worker setting return the *same* plan object — schedules, warmed workspaces,
//! slot pools and all. `ExecTrace::plan_cache_hit` reports whether an
//! execution's plan came from here.
//!
//! The cache is per-rank state (each rank thread owns its driver); SPMD
//! correctness follows from all ranks issuing the same request sequence,
//! the usual driver contract.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::fftb::error::Result;
use crate::fftb::plan::Fftb;

/// Everything that distinguishes one cached plan from another.
///
/// Mirrors the planner inputs: the communicator the plan's grid was built
/// over ([`Comm::identity`](crate::comm::communicator::Comm::identity) —
/// a plan is bound to its mailboxes, so two same-sized communicators must
/// never share one), global shape, a canonical distribution signature
/// string (e.g. `"x{0} y z -> X Y Z{0}"` or a driver-chosen tag), the
/// plan-kind label, batch count, direction (`None` when one plan serves
/// both directions), and the exchange window and worker flag it was tuned
/// with. The
/// string fields are `Cow` so fixed-key callers (the batching driver's
/// per-flush lookup) build keys without heap allocation.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    /// Identity of the communication domain the plan executes over.
    pub comm_id: u64,
    /// Global transform sizes `[nx, ny, nz]`.
    pub sizes: [usize; 3],
    /// Canonical distribution signature of the request.
    pub signature: Cow<'static, str>,
    /// Plan-kind label (e.g. `"slab-pencil"`, `"pencil:2x4"`).
    pub kind: Cow<'static, str>,
    /// Batch count.
    pub nb: usize,
    /// Direction discriminant: `None` = direction-agnostic, `Some(0)` =
    /// forward, `Some(1)` = inverse.
    pub dir: Option<u8>,
    /// Structural fingerprint of the sphere offset array (0 for dense
    /// cuboid requests) — two different spheres with the same shape and
    /// batch must never share one plan.
    pub sphere: u64,
    /// Exchange window the plan's `CommTuning` carries.
    pub window: usize,
    /// Whether the plan's `CommTuning` enables the helper worker thread.
    pub worker: bool,
    /// Transform tag: `true` for the real-input (r2c/c2r) plan family,
    /// `false` for c2c. A real request and a complex request on the same
    /// sphere must never share a plan — the r2c output carries only the
    /// `nz/2 + 1` Hermitian-unique z bins.
    pub r2c: bool,
}

/// Memoized `Fftb` plans keyed by [`PlanKey`], with hit/miss accounting.
#[derive(Default)]
pub struct PlanCache {
    plans: BTreeMap<PlanKey, Arc<Fftb>>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    /// Create an empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the plan for `key`, building it with `build` on a miss.
    /// Returns the shared plan handle and whether it was a cache hit.
    /// A failing `build` is not cached; the error propagates.
    pub fn get_or_insert(
        &mut self,
        key: PlanKey,
        build: impl FnOnce() -> Result<Fftb>,
    ) -> Result<(Arc<Fftb>, bool)> {
        if let Some(plan) = self.plans.get(&key) {
            self.hits += 1;
            return Ok((Arc::clone(plan), true));
        }
        let plan = Arc::new(build()?);
        self.misses += 1;
        self.plans.insert(key, Arc::clone(&plan));
        Ok((plan, false))
    }

    /// Install an already-built plan under `key` (the empirical tuning path
    /// measures candidates before caching the winner). Replaces any
    /// previous resident.
    pub fn insert(&mut self, key: PlanKey, plan: Arc<Fftb>) {
        self.plans.insert(key, plan);
    }

    /// Look up a plan without building on miss.
    pub fn get(&mut self, key: &PlanKey) -> Option<Arc<Fftb>> {
        match self.plans.get(key) {
            Some(p) => {
                self.hits += 1;
                Some(Arc::clone(p))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Number of distinct plans currently cached.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    /// Whether the cache holds no plans.
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// `(hits, misses)` counters since construction (or the last clear).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Drop every cached plan and reset the counters.
    pub fn clear(&mut self) {
        self.plans.clear();
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::grid::ProcGrid;
    use crate::fftb::plan::{PlanKind, SlabPencilPlan};

    fn key(nb: usize, dir: Option<u8>, window: usize) -> PlanKey {
        PlanKey {
            comm_id: 7,
            sizes: [8, 8, 8],
            signature: "slab".into(),
            kind: "slab-pencil".into(),
            nb,
            dir,
            sphere: 0,
            window,
            worker: false,
            r2c: false,
        }
    }

    fn build_slab(nb: usize, grid: &std::sync::Arc<ProcGrid>) -> Result<Fftb> {
        Ok(Fftb {
            kind: PlanKind::SlabPencil(SlabPencilPlan::new([8, 8, 8], nb, Arc::clone(grid))?),
            sizes: [8, 8, 8],
            nb,
        })
    }

    #[test]
    fn hit_returns_same_plan_object() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let mut cache = PlanCache::new();
            let (a, hit_a) = cache.get_or_insert(key(2, None, 2), || build_slab(2, &grid)).unwrap();
            let (b, hit_b) = cache.get_or_insert(key(2, None, 2), || build_slab(2, &grid)).unwrap();
            assert!(!hit_a, "first request must miss");
            assert!(hit_b, "second request must hit");
            assert!(Arc::ptr_eq(&a, &b), "hit must return the same plan");
            assert_eq!(cache.stats(), (1, 1));
            assert_eq!(cache.len(), 1);
        });
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let mut cache = PlanCache::new();
            cache.get_or_insert(key(2, None, 2), || build_slab(2, &grid)).unwrap();
            let (_, hit) =
                cache.get_or_insert(key(3, None, 2), || build_slab(3, &grid)).unwrap();
            assert!(!hit, "different nb is a different plan");
            let (_, hit) =
                cache.get_or_insert(key(2, Some(0), 2), || build_slab(2, &grid)).unwrap();
            assert!(!hit, "different direction is a different plan");
            let (_, hit) =
                cache.get_or_insert(key(2, None, 4), || build_slab(2, &grid)).unwrap();
            assert!(!hit, "different window is a different plan");
            let other_comm = PlanKey { comm_id: 8, ..key(2, None, 2) };
            let (_, hit) = cache.get_or_insert(other_comm, || build_slab(2, &grid)).unwrap();
            assert!(!hit, "a different communicator is a different plan");
            let threaded = PlanKey { worker: true, ..key(2, None, 2) };
            let (_, hit) = cache.get_or_insert(threaded, || build_slab(2, &grid)).unwrap();
            assert!(!hit, "the worker axis is a different plan");
            let other_sphere = PlanKey { sphere: 42, ..key(2, None, 2) };
            let (_, hit) = cache.get_or_insert(other_sphere, || build_slab(2, &grid)).unwrap();
            assert!(!hit, "a different sphere fingerprint is a different plan");
            let real = PlanKey { r2c: true, ..key(2, None, 2) };
            let (_, hit) = cache.get_or_insert(real, || build_slab(2, &grid)).unwrap();
            assert!(!hit, "the r2c transform tag is a different plan");
            assert_eq!(cache.len(), 8);
        });
    }

    #[test]
    fn failed_build_is_not_cached() {
        let mut cache = PlanCache::new();
        let e = cache.get_or_insert(key(1, None, 2), || {
            Err(crate::fftb::error::FftbError::Unsupported("nope".into()))
        });
        assert!(e.is_err());
        assert!(cache.is_empty(), "errors must not be memoized");
    }
}
