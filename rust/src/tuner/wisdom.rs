//! FFTW-style persisted tuning wisdom: calibration constants and per-request
//! winners, serialized through `util::json` so they survive process
//! restarts.
//!
//! A [`Wisdom`] holds an optional machine [`Calibration`] record and a map
//! from request signatures
//! ([`TuneRequest::signature`](crate::tuner::search::TuneRequest::signature))
//! to the candidate that won for that request — decomposition label, window, and the
//! predicted (or, in empirical mode, measured) seconds. `Tuner::plan_auto`
//! consults it before searching and records every fresh decision into it;
//! [`Wisdom::save`] / [`Wisdom::load`] move it through a JSON file.
//!
//! The format is versioned (`"version": 4`); unknown or malformed entries
//! — and files written by an *unknown* format version — are rejected with
//! an `Err` at load (never a panic), so a stale file never silently steers
//! the planner and callers can fall back to a fresh search. Version 2
//! added the per-entry `probe` record: *how* the stored seconds were
//! obtained — `"model"` (cost-model prediction), `"forward"` (the
//! forward-only empirical probe) or `"scf"` (the SCF-shaped alternating
//! forward/inverse probe of
//! [`measure_candidates_scf`](crate::tuner::calibrate::measure_candidates_scf)).
//! Version 3 added the lifecycle fields: a per-entry `loads` counter (how
//! many requests the entry has steered — [`Wisdom::note_load`] advances
//! it, `Tuner::remeasure_after` retires entries past a threshold) and a
//! `measured_at` provenance stamp (seconds since the UNIX epoch when the
//! decision was recorded). Version 4 added the `transform` tag: whether
//! the remembered winner is a real-input (`"r2c"`) or complex (`"c2c"`)
//! plan — the Hermitian half-spectrum family prices, caches and executes
//! differently, so a winner measured under one transform must never steer
//! the other. Version-2 and version-3 files are **upgraded in place** at
//! load — missing lifecycle fields parse as `loads = 0` / `measured_at =
//! 0.0`, and the missing transform tag derives from the kind label — so
//! existing wisdom keeps steering; only v1 and unknown versions are
//! rejected.

use std::collections::BTreeMap;

use crate::tuner::calibrate::Calibration;
use crate::tuner::search::{Candidate, CandidateKind};
use crate::util::json::Json;

/// Current on-disk format version.
const VERSION: f64 = 4.0;

/// Previous versions still accepted at load (upgraded in place).
const UPGRADABLE_VERSIONS: [f64; 2] = [2.0, 3.0];

/// Seconds since the UNIX epoch, or `0.0` when the system clock predates
/// it (never a panic) — the provenance stamp for fresh wisdom entries.
pub fn now_secs() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// How a wisdom entry's `seconds` were obtained.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Probe {
    /// Cost-model prediction (no live execution).
    #[default]
    Model,
    /// Forward-only empirical measurement
    /// ([`measure_candidates`](crate::tuner::calibrate::measure_candidates)).
    Forward,
    /// SCF-shaped alternating forward/inverse measurement
    /// ([`measure_candidates_scf`](crate::tuner::calibrate::measure_candidates_scf)).
    Scf,
}

impl Probe {
    /// Stable on-disk label.
    pub fn label(&self) -> &'static str {
        match self {
            Probe::Model => "model",
            Probe::Forward => "forward",
            Probe::Scf => "scf",
        }
    }

    /// Parse an on-disk label back.
    pub fn from_label(s: &str) -> Option<Probe> {
        match s {
            "model" => Some(Probe::Model),
            "forward" => Some(Probe::Forward),
            "scf" => Some(Probe::Scf),
            _ => None,
        }
    }

    /// Whether the seconds came from a live execution (any non-model probe).
    pub fn is_measured(&self) -> bool {
        !matches!(self, Probe::Model)
    }
}

/// One remembered winner for one request signature.
#[derive(Clone, Debug, PartialEq)]
pub struct WisdomEntry {
    /// Winning decomposition, as its [`CandidateKind::label`].
    pub kind: String,
    /// Winning exchange window.
    pub window: usize,
    /// Whether the winner engages the exchange's helper worker thread
    /// (`CommTuning::worker`). Absent in files written before the worker
    /// axis existed — those parse as `false` (the single-threaded engine),
    /// which is exactly what they were measured or predicted with.
    pub worker: bool,
    /// Predicted (model mode) or measured (empirical mode) seconds.
    pub seconds: f64,
    /// Whether `seconds` came from a live measurement. Derived from
    /// `probe` at load ([`Probe::is_measured`]), so the two fields cannot
    /// disagree after a round trip; kept alongside `probe` for callers
    /// that only care about provenance, not shape.
    pub measured: bool,
    /// Which probe produced `seconds` (see [`Probe`]).
    pub probe: Probe,
    /// How many requests this entry has steered since it was recorded
    /// ([`Wisdom::note_load`] advances it on every hit). The lifecycle
    /// knob `Tuner::remeasure_after` retires entries whose count passes
    /// its threshold, forcing a fresh search.
    pub loads: u64,
    /// Seconds since the UNIX epoch when the decision was recorded
    /// ([`now_secs`]); `0.0` for entries upgraded from v2 files, which
    /// carried no provenance.
    pub measured_at: f64,
    /// Whether the winner is a real-input (r2c/c2r) plan — serialized as
    /// `"transform": "r2c"` / `"c2c"`. Files written before v4 carry no
    /// tag; the upgrade path derives it from the kind label, which for
    /// every pre-v4 kind is unambiguous (`"plane-wave-r2c"` did not exist).
    pub r2c: bool,
}

impl WisdomEntry {
    /// The entry as a buildable candidate, or `None` if the stored label
    /// no longer parses (e.g. written by a newer version).
    pub fn candidate(&self) -> Option<Candidate> {
        Some(Candidate {
            kind: CandidateKind::from_label(&self.kind)?,
            window: self.window,
            worker: self.worker,
            predicted: self.seconds,
        })
    }
}

/// Persisted tuning state: calibration + per-request winners.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Wisdom {
    /// Measured machine constants, if a calibration has been recorded.
    pub calibration: Option<Calibration>,
    entries: BTreeMap<String, WisdomEntry>,
}

impl Wisdom {
    /// Empty wisdom (no calibration, no winners).
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up the remembered winner for a request signature.
    pub fn lookup(&self, signature: &str) -> Option<&WisdomEntry> {
        self.entries.get(signature)
    }

    /// Record (or overwrite) the winner for a request signature.
    pub fn record(&mut self, signature: String, entry: WisdomEntry) {
        self.entries.insert(signature, entry);
    }

    /// Look up the remembered winner for a request signature, advancing
    /// its `loads` counter — the lifecycle bookkeeping behind
    /// `Tuner::remeasure_after`. Use [`Wisdom::lookup`] for a counter-free
    /// peek.
    pub fn note_load(&mut self, signature: &str) -> Option<&WisdomEntry> {
        let e = self.entries.get_mut(signature)?;
        e.loads = e.loads.saturating_add(1);
        Some(e)
    }

    /// Forget the winner for one request signature (lifecycle retirement);
    /// returns the retired entry, if any.
    pub fn remove(&mut self, signature: &str) -> Option<WisdomEntry> {
        self.entries.remove(signature)
    }

    /// Drop every remembered winner, keeping the calibration record. Call
    /// when the machine constants change (re-calibration): the entries
    /// were ranked with the old constants and would otherwise pin stale
    /// choices forever.
    pub fn clear_entries(&mut self) {
        self.entries.clear();
    }

    /// Number of remembered winners.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether any winners are remembered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("version".into(), Json::Num(VERSION));
        if let Some(c) = &self.calibration {
            let mut m = BTreeMap::new();
            m.insert("fft_flops_per_sec".into(), Json::Num(c.fft_flops_per_sec));
            m.insert("mem_bw".into(), Json::Num(c.mem_bw));
            m.insert("alpha".into(), Json::Num(c.alpha));
            m.insert("beta".into(), Json::Num(c.beta));
            root.insert("calibration".into(), Json::Obj(m));
        }
        let mut entries = BTreeMap::new();
        for (sig, e) in &self.entries {
            let mut m = BTreeMap::new();
            m.insert("kind".into(), Json::Str(e.kind.clone()));
            m.insert("window".into(), Json::Num(e.window as f64));
            m.insert("worker".into(), Json::Bool(e.worker));
            m.insert("seconds".into(), Json::Num(e.seconds));
            m.insert("measured".into(), Json::Bool(e.measured));
            m.insert("probe".into(), Json::Str(e.probe.label().into()));
            m.insert("loads".into(), Json::Num(e.loads as f64));
            m.insert("measured_at".into(), Json::Num(e.measured_at));
            m.insert(
                "transform".into(),
                Json::Str(if e.r2c { "r2c" } else { "c2c" }.into()),
            );
            entries.insert(sig.clone(), Json::Obj(m));
        }
        root.insert("entries".into(), Json::Obj(entries));
        Json::Obj(root)
    }

    /// Parse the versioned JSON document back.
    pub fn from_json(j: &Json) -> Result<Wisdom, String> {
        let version = j
            .get("version")
            .and_then(Json::as_f64)
            .ok_or_else(|| "wisdom: missing version".to_string())?;
        if version != VERSION && !UPGRADABLE_VERSIONS.contains(&version) {
            return Err(format!("wisdom: unsupported version {version}"));
        }
        let calibration = match j.get("calibration") {
            None => None,
            Some(c) => {
                let f = |k: &str| {
                    c.get(k)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| format!("wisdom: calibration missing `{k}`"))
                };
                Some(Calibration {
                    fft_flops_per_sec: f("fft_flops_per_sec")?,
                    mem_bw: f("mem_bw")?,
                    alpha: f("alpha")?,
                    beta: f("beta")?,
                })
            }
        };
        let mut entries = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("entries") {
            for (sig, e) in map {
                let kind = e
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("wisdom: entry `{sig}` missing kind"))?
                    .to_string();
                if CandidateKind::from_label(&kind).is_none() {
                    return Err(format!("wisdom: entry `{sig}` has unknown kind `{kind}`"));
                }
                let window = e
                    .get("window")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("wisdom: entry `{sig}` missing window"))?;
                // Optional for compatibility with files written before the
                // worker axis: absent means the single-threaded engine.
                let worker = match e.get("worker") {
                    None => false,
                    Some(Json::Bool(b)) => *b,
                    Some(_) => {
                        return Err(format!("wisdom: entry `{sig}` worker must be a bool"))
                    }
                };
                let seconds = e
                    .get("seconds")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("wisdom: entry `{sig}` missing seconds"))?;
                let probe = match e.get("probe") {
                    None => Probe::Model,
                    Some(v) => {
                        let label = v.as_str().ok_or_else(|| {
                            format!("wisdom: entry `{sig}` probe must be a string")
                        })?;
                        Probe::from_label(label).ok_or_else(|| {
                            format!("wisdom: entry `{sig}` has unknown probe `{label}`")
                        })?
                    }
                };
                // `measured` is derived, not read back: a hand-edited file
                // whose `measured` flag contradicts its probe kind cannot
                // smuggle the disagreement into memory.
                let measured = probe.is_measured();
                // Lifecycle fields (v3). Absent — the in-place v2 upgrade
                // path — means a fresh counter and no provenance; present
                // but non-integer (or negative) `loads` is corruption.
                let loads = match e.get("loads") {
                    None => 0,
                    Some(v) => {
                        let f = v.as_f64().ok_or_else(|| {
                            format!("wisdom: entry `{sig}` loads must be a number")
                        })?;
                        if f.fract() != 0.0 || f < 0.0 {
                            return Err(format!(
                                "wisdom: entry `{sig}` loads must be a non-negative \
                                 integer (got {f})"
                            ));
                        }
                        f as u64
                    }
                };
                let measured_at = match e.get("measured_at") {
                    None => 0.0,
                    Some(v) => v.as_f64().ok_or_else(|| {
                        format!("wisdom: entry `{sig}` measured_at must be a number")
                    })?,
                };
                // Transform tag (v4). Absent — the v2/v3 upgrade path —
                // derives from the kind label (every pre-v4 kind is c2c,
                // so the derivation is exact); an unknown string is
                // corruption, not a default.
                let r2c = match e.get("transform") {
                    None => kind.contains("r2c"),
                    Some(v) => match v.as_str() {
                        Some("r2c") => true,
                        Some("c2c") => false,
                        Some(other) => {
                            return Err(format!(
                                "wisdom: entry `{sig}` has unknown transform `{other}`"
                            ))
                        }
                        None => {
                            return Err(format!(
                                "wisdom: entry `{sig}` transform must be a string"
                            ))
                        }
                    },
                };
                entries.insert(
                    sig.clone(),
                    WisdomEntry {
                        kind,
                        window,
                        worker,
                        seconds,
                        measured,
                        probe,
                        loads,
                        measured_at,
                        r2c,
                    },
                );
            }
        } else if j.get("entries").is_some() {
            return Err("wisdom: `entries` must be an object".into());
        }
        Ok(Wisdom { calibration, entries })
    }

    /// Write the wisdom file (creates or truncates `path`).
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Read a wisdom file written by [`Wisdom::save`].
    pub fn load(path: &std::path::Path) -> Result<Wisdom, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("wisdom: {e}"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Wisdom {
        let mut w = Wisdom::new();
        w.calibration = Some(Calibration {
            fft_flops_per_sec: 2.5e9,
            mem_bw: 9.5e9,
            alpha: 3.25e-7,
            beta: 2.5e-10,
        });
        w.record(
            "16x16x16|nb=4|p=8|dense".into(),
            WisdomEntry {
                kind: "pencil:2x4".into(),
                window: 4,
                worker: true,
                seconds: 0.0125,
                measured: false,
                probe: Probe::Model,
                loads: 0,
                measured_at: 0.0,
                r2c: false,
            },
        );
        w.record(
            "32x32x32|nb=8|p=4|sphere:4169".into(),
            WisdomEntry {
                kind: "plane-wave".into(),
                window: 2,
                worker: false,
                seconds: 0.5,
                measured: true,
                probe: Probe::Forward,
                loads: 17,
                measured_at: 1.7e9,
                r2c: false,
            },
        );
        w.record(
            "32x32x32|nb=8|p=4|sphere:4169|rt".into(),
            WisdomEntry {
                kind: "plane-wave".into(),
                window: 1,
                worker: false,
                seconds: 0.75,
                measured: true,
                probe: Probe::Scf,
                loads: 3,
                measured_at: 1.7e9 + 60.0,
                r2c: false,
            },
        );
        w.record(
            "16x16x16|nb=4|p=4|sphere:2109|r2c".into(),
            WisdomEntry {
                kind: "plane-wave-r2c".into(),
                window: 2,
                worker: false,
                seconds: 0.31,
                measured: true,
                probe: Probe::Forward,
                loads: 5,
                measured_at: 1.7e9 + 120.0,
                r2c: true,
            },
        );
        w
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let w = sample();
        let text = w.to_json().to_string();
        let back = Wisdom::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, w);
        assert_eq!(back.lookup("16x16x16|nb=4|p=8|dense").unwrap().window, 4);
        assert!(back.lookup("32x32x32|nb=8|p=4|sphere:4169").unwrap().measured);
        // The lifecycle fields survive the round trip too.
        assert_eq!(back.lookup("32x32x32|nb=8|p=4|sphere:4169").unwrap().loads, 17);
        assert_eq!(back.lookup("32x32x32|nb=8|p=4|sphere:4169").unwrap().measured_at, 1.7e9);
        // The probe record survives the round trip — including the
        // SCF-shaped probe under its round-trip (`|rt`) signature.
        assert_eq!(back.lookup("32x32x32|nb=8|p=4|sphere:4169").unwrap().probe, Probe::Forward);
        let scf = back.lookup("32x32x32|nb=8|p=4|sphere:4169|rt").unwrap();
        assert_eq!(scf.probe, Probe::Scf);
        assert!(scf.probe.is_measured());
        assert_eq!(scf.window, 1);
        let cand = back.lookup("16x16x16|nb=4|p=8|dense").unwrap().candidate().unwrap();
        assert_eq!(cand.kind, crate::tuner::search::CandidateKind::Pencil { p0: 2, p1: 4 });
        assert!(cand.worker, "the worker flag survives the round trip");
        let fwd = back.lookup("32x32x32|nb=8|p=4|sphere:4169").unwrap().candidate().unwrap();
        assert!(!fwd.worker);
    }

    #[test]
    fn missing_worker_defaults_to_single_threaded() {
        // Entries written before the worker axis existed have no `worker`
        // key; they must parse as worker-off (what they were priced with),
        // and a non-bool value must be rejected, not coerced.
        let doc = r#"{"version": 2, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5}}}"#;
        let w = Wisdom::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert!(!w.lookup("k").unwrap().worker);
        assert!(!w.lookup("k").unwrap().candidate().unwrap().worker);
        let bad = r#"{"version": 2, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5, "worker": 1}}}"#;
        assert!(Wisdom::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn file_round_trip() {
        let w = sample();
        let path = std::env::temp_dir().join("fftb_wisdom_test.json");
        w.save(&path).unwrap();
        let back = Wisdom::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, w);
    }

    #[test]
    fn bad_documents_are_rejected() {
        assert!(Wisdom::from_json(&Json::parse("{}").unwrap()).is_err(), "missing version");
        assert!(
            Wisdom::from_json(&Json::parse(r#"{"version": 99}"#).unwrap()).is_err(),
            "future version"
        );
        let bad_kind = r#"{"version": 2, "entries": {"k": {"kind": "warp-drive", "window": 1, "seconds": 1}}}"#;
        assert!(Wisdom::from_json(&Json::parse(bad_kind).unwrap()).is_err(), "unknown kind");
        let bad_probe = r#"{"version": 2, "entries": {"k": {"kind": "plane-wave", "window": 1, "seconds": 1, "probe": "guesswork"}}}"#;
        assert!(Wisdom::from_json(&Json::parse(bad_probe).unwrap()).is_err(), "unknown probe");
    }

    #[test]
    fn stale_version_files_are_rejected_gracefully() {
        // A version-1 file (pre-probe format) must come back as a plain
        // `Err` — never a panic — so callers can fall back to a fresh
        // search instead of being steered by a record whose semantics
        // changed under them.
        let v1 = r#"{"version": 1, "entries": {"8x8x8|nb=2|p=2|dense":
            {"kind": "slab-pencil", "window": 2, "seconds": 0.001, "measured": false}}}"#;
        let got = Wisdom::from_json(&Json::parse(v1).unwrap());
        assert!(matches!(&got, Err(e) if e.contains("unsupported version")), "{got:?}");

        // Same through the file path: Wisdom::load returns the error.
        let path = std::env::temp_dir().join("fftb_wisdom_stale_v1.json");
        std::fs::write(&path, v1).unwrap();
        let loaded = Wisdom::load(&path);
        std::fs::remove_file(&path).ok();
        assert!(loaded.is_err());
    }

    #[test]
    fn measured_flag_is_derived_from_probe() {
        // A hand-edited file whose `measured` flag contradicts its probe
        // kind is normalized at load — probe is the source of truth.
        let doc = r#"{"version": 2, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5,
             "measured": true, "probe": "model"}}}"#;
        let w = Wisdom::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert!(!w.lookup("k").unwrap().measured, "contradiction must be normalized");
    }

    #[test]
    fn missing_probe_defaults_to_model() {
        // Entries written without an explicit probe (e.g. hand-edited
        // files) parse as model predictions.
        let doc = r#"{"version": 2, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5}}}"#;
        let w = Wisdom::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert_eq!(w.lookup("k").unwrap().probe, Probe::Model);
        assert!(!w.lookup("k").unwrap().probe.is_measured());
    }

    #[test]
    fn v2_files_are_upgraded_in_place() {
        // A version-2 file (pre-lifecycle format) must load — not be
        // rejected — with a fresh `loads` counter and no provenance stamp,
        // so existing wisdom keeps steering across the format bump.
        let v2 = r#"{"version": 2, "entries": {"8x8x8|nb=2|p=2|dense":
            {"kind": "slab-pencil", "window": 2, "seconds": 0.001,
             "worker": true, "probe": "scf"}}}"#;
        let w = Wisdom::from_json(&Json::parse(v2).unwrap()).unwrap();
        let e = w.lookup("8x8x8|nb=2|p=2|dense").unwrap();
        assert_eq!((e.loads, e.measured_at), (0, 0.0));
        assert!(e.worker && e.measured, "v2 payload fields must survive the upgrade");
        // Saving re-serializes at the current version.
        let text = w.to_json().to_string();
        assert!(text.contains("\"version\": 4") || text.contains("\"version\":4"), "{text}");
        assert_eq!(Wisdom::from_json(&Json::parse(&text).unwrap()).unwrap(), w);
    }

    #[test]
    fn v3_files_are_upgraded_in_place() {
        // A version-3 file (pre-transform-tag format) must load with the
        // transform derived from the kind label: every pre-v4 kind is c2c.
        let v3 = r#"{"version": 3, "entries": {"8x8x8|nb=2|p=2|sphere:251":
            {"kind": "plane-wave", "window": 2, "seconds": 0.002,
             "worker": false, "probe": "forward", "loads": 9,
             "measured_at": 1.6e9}}}"#;
        let w = Wisdom::from_json(&Json::parse(v3).unwrap()).unwrap();
        let e = w.lookup("8x8x8|nb=2|p=2|sphere:251").unwrap();
        assert!(!e.r2c, "pre-v4 kinds are all complex transforms");
        assert_eq!((e.loads, e.measured_at), (9, 1.6e9), "v3 lifecycle fields survive");
        // Saving re-serializes at the current version with an explicit tag.
        let text = w.to_json().to_string();
        assert!(text.contains("\"version\": 4") || text.contains("\"version\":4"), "{text}");
        assert!(text.contains("\"transform\": \"c2c\"") || text.contains("\"transform\":\"c2c\""));
        assert_eq!(Wisdom::from_json(&Json::parse(&text).unwrap()).unwrap(), w);
    }

    #[test]
    fn transform_tag_round_trips_and_derives_from_kind() {
        // The explicit tag survives a round trip on both families.
        let w = sample();
        let back = Wisdom::from_json(&Json::parse(&w.to_json().to_string()).unwrap()).unwrap();
        assert!(back.lookup("16x16x16|nb=4|p=4|sphere:2109|r2c").unwrap().r2c);
        assert!(!back.lookup("32x32x32|nb=8|p=4|sphere:4169").unwrap().r2c);
        // A tagless entry whose kind *is* the r2c family (a hand-trimmed
        // v4 file) still lands on the real side via the kind derivation.
        let doc = r#"{"version": 4, "entries": {"k|r2c":
            {"kind": "plane-wave-r2c", "window": 1, "seconds": 0.5}}}"#;
        let w = Wisdom::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert!(w.lookup("k|r2c").unwrap().r2c);
    }

    #[test]
    fn unknown_transform_values_are_rejected() {
        let bad = r#"{"version": 4, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5, "transform": "quaternion"}}}"#;
        let got = Wisdom::from_json(&Json::parse(bad).unwrap());
        assert!(matches!(&got, Err(e) if e.contains("transform")), "{got:?}");
        let non_string = r#"{"version": 4, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5, "transform": true}}}"#;
        assert!(Wisdom::from_json(&Json::parse(non_string).unwrap()).is_err());
    }

    #[test]
    fn non_integer_loads_are_rejected() {
        let bad = r#"{"version": 3, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5, "loads": 2.5}}}"#;
        let got = Wisdom::from_json(&Json::parse(bad).unwrap());
        assert!(matches!(&got, Err(e) if e.contains("loads")), "{got:?}");
        let negative = r#"{"version": 3, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5, "loads": -1}}}"#;
        assert!(Wisdom::from_json(&Json::parse(negative).unwrap()).is_err());
        let non_number = r#"{"version": 3, "entries": {"k":
            {"kind": "plane-wave", "window": 1, "seconds": 0.5, "loads": "many"}}}"#;
        assert!(Wisdom::from_json(&Json::parse(non_number).unwrap()).is_err());
    }

    #[test]
    fn note_load_advances_the_counter_and_remove_retires() {
        let mut w = sample();
        assert_eq!(w.lookup("16x16x16|nb=4|p=8|dense").unwrap().loads, 0);
        w.note_load("16x16x16|nb=4|p=8|dense");
        w.note_load("16x16x16|nb=4|p=8|dense");
        assert_eq!(w.lookup("16x16x16|nb=4|p=8|dense").unwrap().loads, 2);
        assert!(w.note_load("no-such-signature").is_none());
        let retired = w.remove("16x16x16|nb=4|p=8|dense").unwrap();
        assert_eq!(retired.loads, 2);
        assert!(w.lookup("16x16x16|nb=4|p=8|dense").is_none());
        assert!(w.remove("16x16x16|nb=4|p=8|dense").is_none());
    }

    #[test]
    fn empty_wisdom_round_trips() {
        let w = Wisdom::new();
        let back = Wisdom::from_json(&Json::parse(&w.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, w);
        assert!(back.is_empty());
        assert!(back.calibration.is_none());
    }
}
