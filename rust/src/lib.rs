//! FFTB-rs — flexible distributed multi-dimensional FFTs for plane-wave
//! Density Functional Theory codes.
//!
//! Reproduction of Popovici et al., "Flexible Multi-Dimensional FFTs for
//! Plane Wave Density Functional Theory Codes" (CS.DC 2024). See
//! `docs/ARCHITECTURE.md` for the layer map and the plan-time vs
//! execute-time contract, and EXPERIMENTS.md for the measured results.
//!
//! The crate README below doubles as the documented quickstart; its code
//! block runs verbatim as a doctest under `cargo test -q`.
//!
#![doc = include_str!("../README.md")]

pub mod comm;
pub mod coordinator;
pub mod dft;
pub mod fft;
pub mod fftb;
pub mod lint;
pub mod model;
pub mod runtime;
pub mod service;
pub mod tuner;
pub mod util;
