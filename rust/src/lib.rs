//! FFTB-rs — flexible distributed multi-dimensional FFTs for plane-wave
//! Density Functional Theory codes.
//!
//! Reproduction of Popovici et al., "Flexible Multi-Dimensional FFTs for
//! Plane Wave Density Functional Theory Codes" (CS.DC 2024). See DESIGN.md
//! for the full architecture and EXPERIMENTS.md for the measured results.

pub mod comm;
pub mod coordinator;
pub mod dft;
pub mod fft;
pub mod fftb;
pub mod model;
pub mod runtime;
pub mod util;
