//! Performance model: prices the planner's exact stage counts on a described
//! machine to project the paper's strong-scaling experiment (Fig. 9) beyond
//! the live in-process rank count. See DESIGN.md §3 for the substitution
//! argument and §4.5 for the module inventory.
#![warn(missing_docs)]

pub mod cost;
pub mod machine;
pub mod scaling;

pub use cost::{PlanCost, StageCost};
pub use machine::Machine;
pub use scaling::{
    fig9_row, fold_ranks, grid_2d, price_stages, price_stages_with, project, Variant, Workload,
};
