//! Strong-scaling projection — regenerates the Fig. 9 series at paper scale.
//!
//! For each variant and each GPU count `p`, the projector prices the exact
//! stage counts of `super::cost` on a [`Machine`]. Past the grid dimension
//! (`p > n`), ranks are folded into batch groups exactly as the paper does
//! ("we first parallelize the data in the dimensions of the Fourier
//! transforms. If the number of processors is greater than the dimensions,
//! we then parallelize in the batch dimension"): `p = px * pg` with
//! `px <= n` ranks per transform group and `pg` groups each owning `nb/pg`
//! bands.

use crate::fftb::sphere::OffsetArray;

use super::cost::{self, PlanCost};
use super::machine::Machine;

/// The five Fig. 9 variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// 1D processing grid, batched (dark blue).
    Slab1dBatched,
    /// 1D processing grid, non-batched loop (light blue).
    Slab1dNonBatched,
    /// 2D processing grid, batched (dark orange).
    Pencil2dBatched,
    /// 2D processing grid, non-batched (light orange).
    Pencil2dNonBatched,
    /// Plane-wave staged padding, batched, 1D grid (red).
    PlaneWave,
}

impl Variant {
    /// All five variants, in the paper's legend order.
    pub fn all() -> [Variant; 5] {
        [
            Variant::Slab1dBatched,
            Variant::Slab1dNonBatched,
            Variant::Pencil2dBatched,
            Variant::Pencil2dNonBatched,
            Variant::PlaneWave,
        ]
    }

    /// Stable series label used in bench tables and CSV output.
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Slab1dBatched => "cube-1Dgrid-batched",
            Variant::Slab1dNonBatched => "cube-1Dgrid-nonbatched",
            Variant::Pencil2dBatched => "cube-2Dgrid-batched",
            Variant::Pencil2dNonBatched => "cube-2Dgrid-nonbatched",
            Variant::PlaneWave => "planewave-sphere-batched",
        }
    }
}

/// Fig. 9 workload description.
pub struct Workload<'a> {
    /// Global cube extents `[nx, ny, nz]`.
    pub shape: [usize; 3],
    /// Batch count (bands per transform).
    pub nb: usize,
    /// Offset array of the wavefunction sphere (plane-wave variant).
    pub offsets: &'a OffsetArray,
}

/// Split `p` into (per-transform ranks, batch groups) per the paper's rule.
pub fn fold_ranks(p: usize, n: usize, nb: usize) -> (usize, usize) {
    if p <= n {
        return (p, 1);
    }
    let pg = (p / n).min(nb.max(1));
    (n, pg.max(1))
}

/// Split a 2D-grid rank count into (p0, p1) as square as possible.
pub fn grid_2d(p: usize) -> (usize, usize) {
    let mut p0 = 1usize;
    while p0 * p0 < p {
        p0 *= 2;
    }
    while p % p0 != 0 {
        p0 /= 2;
    }
    (p0, p / p0)
}

/// Price a stage table on `m`: compute stages through the roofline, comm
/// stages through the *fused* windowed alltoall model (each exchange
/// carries its per-destination pack/unpack traffic as
/// `StageCost::fused_bytes`, hidden behind waits in proportion to the
/// window), non-batched rounds serialized. `window == 1` is the serial
/// pricing the Fig. 9 projections use — at that window the fused pricing
/// degenerates to the old pack-stage + exchange-stage sum exactly; the
/// tuner's candidate search prices its window ladder through the same
/// walk, so the two layers can never diverge.
pub fn price_stages(cost: &PlanCost, m: &Machine, window: usize) -> f64 {
    price_stages_with(cost, m, window, false)
}

/// [`price_stages`] with the exchange's helper-worker axis: `worker ==
/// false` delegates to the single-threaded fused pricing bit-for-bit (this
/// is what [`price_stages`] calls), `worker == true` prices every comm
/// stage through [`Machine::alltoall_time_fused_threaded`] — pack/unpack
/// hidden behind the waits, a per-message channel-handoff charge in its
/// place. The tuner's candidate search crosses its window ladder with this
/// flag, so worker-on/worker-off is a real priced axis, not a heuristic.
pub fn price_stages_with(cost: &PlanCost, m: &Machine, window: usize, worker: bool) -> f64 {
    let mut t = 0.0;
    let mut comm_idx = 0;
    for s in &cost.stages {
        // Comm stages are identified by `rounds > 0` (StageCost::comm_fused
        // sets it >= 1, compute stages 0) — NOT by nonzero bytes: a
        // degenerate single-rank exchange (e.g. the first alltoall of a
        // pencil 1xN grid) carries zero bytes but must still consume its
        // a2a_ranks slot, or every later exchange is priced on the wrong
        // rank count.
        if s.rounds > 0 {
            let pc = cost.a2a_ranks[comm_idx];
            comm_idx += 1;
            let per_round = s.a2a_bytes / s.rounds as f64;
            let fused_per_round = s.fused_bytes / s.rounds as f64;
            t += s.rounds as f64
                * m.alltoall_time_fused_threaded(pc, per_round, window, fused_per_round, worker);
        } else {
            t += m.compute_time(s.flops, s.touched_bytes);
        }
    }
    t
}

/// Projected execution time (seconds) of one batched transform.
pub fn project(variant: Variant, w: &Workload, p: usize, m: &Machine) -> f64 {
    let n = w.shape[0];
    let cost: PlanCost = match variant {
        Variant::Slab1dBatched | Variant::Slab1dNonBatched | Variant::PlaneWave => {
            let (px, pg) = fold_ranks(p, n, w.nb);
            let nb_group = (w.nb + pg - 1) / pg;
            match variant {
                Variant::PlaneWave => cost::planewave(w.offsets, nb_group, px, true),
                Variant::Slab1dBatched => cost::slab_pencil(w.shape, nb_group, px, true),
                _ => cost::slab_pencil(w.shape, nb_group, px, false),
            }
        }
        Variant::Pencil2dBatched | Variant::Pencil2dNonBatched => {
            // 2D grids fold the excess into the second axis up to ny*nz use;
            // beyond n^2 ranks, batch groups (rare at paper sizes).
            let (p0, p1) = grid_2d(p.min(n * n));
            let pg = (p / (p0 * p1)).max(1).min(w.nb.max(1));
            let nb_group = (w.nb + pg - 1) / pg;
            let batched = variant == Variant::Pencil2dBatched;
            cost::pencil(w.shape, nb_group, p0, p1, batched)
        }
    };
    price_stages(&cost, m, 1)
}

/// One Fig. 9 row: times for all five variants at one GPU count.
pub fn fig9_row(w: &Workload, p: usize, m: &Machine) -> [f64; 5] {
    let mut out = [0.0; 5];
    for (i, v) in Variant::all().into_iter().enumerate() {
        out[i] = project(v, w, p, m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    fn paper_workload() -> (SphereSpec, [usize; 3], usize) {
        // Fig. 9: 256^3 cube, batch 256, sphere diameter 128.
        let n = 256usize;
        (SphereSpec::new([n, n, n], 64.0, SphereKind::Centered), [n, n, n], 256)
    }

    #[test]
    fn degenerate_pencil_axis_does_not_desync_pricing() {
        // pencil 1xN: the first exchange is a single-rank no-op (zero
        // bytes) but must still consume its a2a_ranks slot, so the second
        // (real) exchange is priced over N ranks — not over 1, which would
        // make the whole decomposition look communication-free.
        let m = Machine::perlmutter_a100();
        let p = 8usize;
        let one_by_p = cost::pencil([32, 32, 32], 4, 1, p, true);
        let t = price_stages(&one_by_p, &m, 1);
        // Lower bound: the priced time must at least cover the second
        // exchange's bytes on the wire.
        let real_a2a = &one_by_p.stages[3];
        assert!(real_a2a.a2a_bytes > 0.0, "second exchange moves real bytes");
        assert!(
            t > real_a2a.a2a_bytes * m.beta,
            "pricing must include the 1xN grid's real exchange"
        );
    }

    #[test]
    fn fold_ranks_paper_rule() {
        assert_eq!(fold_ranks(64, 256, 256), (64, 1));
        assert_eq!(fold_ranks(256, 256, 256), (256, 1));
        assert_eq!(fold_ranks(512, 256, 256), (256, 2));
        assert_eq!(fold_ranks(1024, 256, 256), (256, 4));
    }

    #[test]
    fn grid_2d_square_ish() {
        assert_eq!(grid_2d(16), (4, 4));
        assert_eq!(grid_2d(64), (8, 8));
        assert_eq!(grid_2d(128), (16, 8));
    }

    #[test]
    fn fig9_shape_holds_at_paper_scale() {
        // The qualitative claims of Fig. 9 must hold in the projection:
        let (spec, shape, nb) = paper_workload();
        let off = spec.offsets();
        let w = Workload { shape, nb, offsets: &off };
        let m = Machine::perlmutter_a100();

        for p in [4usize, 16, 64, 256, 1024] {
            let row = fig9_row(&w, p, &m);
            let [slab_b, slab_nb, _pen_b, pen_nb, pw] = row;
            // 1. batched beats non-batched on both grids.
            assert!(slab_b < slab_nb, "p={p}: batched {slab_b} < nonbatched {slab_nb}");
            assert!(row[2] < pen_nb, "p={p}: pencil batched wins");
            // 2. plane-wave beats the batched cube (the paper's headline).
            assert!(pw < slab_b, "p={p}: planewave {pw} < slab {slab_b}");
        }
    }

    #[test]
    fn batched_scales_nonbatched_flattens() {
        let (spec, shape, nb) = paper_workload();
        let off = spec.offsets();
        let w = Workload { shape, nb, offsets: &off };
        let m = Machine::perlmutter_a100();
        // Batched: near-linear 4 -> 256.
        let b4 = project(Variant::Slab1dBatched, &w, 4, &m);
        let b256 = project(Variant::Slab1dBatched, &w, 256, &m);
        assert!(b4 / b256 > 20.0, "batched speedup {}", b4 / b256);
        // Non-batched: latency floor keeps the speedup far from linear.
        let n4 = project(Variant::Slab1dNonBatched, &w, 4, &m);
        let n1024 = project(Variant::Slab1dNonBatched, &w, 1024, &m);
        assert!(n4 / n1024 < 64.0, "non-batched speedup {}", n4 / n1024);
    }

    #[test]
    fn planewave_advantage_grows_from_data_volume() {
        let (spec, shape, nb) = paper_workload();
        let off = spec.offsets();
        let w = Workload { shape, nb, offsets: &off };
        let m = Machine::perlmutter_a100();
        let p = 64;
        let pw = project(Variant::PlaneWave, &w, p, &m);
        let slab = project(Variant::Slab1dBatched, &w, p, &m);
        // Sphere d=n/2: ~6x less data through z-FFT + exchange; the overall
        // win should be >1.5x and <16x.
        let speedup = slab / pw;
        assert!(speedup > 1.5 && speedup < 16.0, "speedup {speedup}");
    }
}
