//! Exact per-stage cost counts for each plan variant.
//!
//! These formulas mirror the plan implementations stage by stage (and are
//! tested against the live traces): flops from line counts, pack/unpack
//! bytes from buffer sizes, alltoall bytes from the cyclic block split.
//! `max` over ranks is taken by evaluating rank 0, which owns the ceil of
//! every cyclic split.
//!
//! Since the exchanges run *fused* (per-destination pack kernels inside
//! the windowed engine), the pack/unpack memory traffic of each exchange
//! is carried on the comm stage itself as [`StageCost::fused_bytes`] —
//! priced by [`Machine::alltoall_time_fused`], which hides all but a
//! `1/window` fraction of it behind the waits. At window 1 that prices
//! identically to the old separate pack/unpack compute stages, so the
//! Fig. 9 projections are unchanged while the tuner's window search sees
//! the fusion benefit.
//!
//! [`Machine::alltoall_time_fused`]: super::machine::Machine::alltoall_time_fused

use crate::fft::batch::fft_flops;
use crate::fftb::grid::cyclic;
use crate::fftb::sphere::OffsetArray;

/// Bytes per complex element (f64 re + f64 im).
pub const BYTES_PER_ELEM: f64 = 16.0;

/// One stage's worth of priced work on the slowest rank.
#[derive(Clone, Debug)]
pub struct StageCost {
    /// Stage label, matching the live trace's stage names.
    pub name: &'static str,
    /// Complex-FLOP count of local compute in this stage (0 for comm).
    pub flops: f64,
    /// Local bytes touched by reshapes/scatters around this stage.
    pub touched_bytes: f64,
    /// Bytes this rank puts on the wire (one alltoall), 0 for compute.
    pub a2a_bytes: f64,
    /// Local pack/unpack bytes fused into this exchange's rounds (0 for
    /// compute stages): moved per destination inside the windowed engine,
    /// so all but a `1/window` fraction hides behind the waits.
    pub fused_bytes: f64,
    /// Number of alltoall invocations this stage performs (non-batched
    /// variants loop; each invocation carries a2a_bytes / rounds).
    pub rounds: usize,
}

impl StageCost {
    fn compute(name: &'static str, flops: f64, touched: f64) -> Self {
        StageCost {
            name,
            flops,
            touched_bytes: touched,
            a2a_bytes: 0.0,
            fused_bytes: 0.0,
            rounds: 0,
        }
    }

    fn comm_fused(name: &'static str, bytes: f64, rounds: usize, fused_bytes: f64) -> Self {
        StageCost { name, flops: 0.0, touched_bytes: 0.0, a2a_bytes: bytes, fused_bytes, rounds }
    }
}

/// Full variant cost: stage list + the communicator size each alltoall uses.
#[derive(Clone, Debug)]
pub struct PlanCost {
    /// Per-stage cost rows, in execution order.
    pub stages: Vec<StageCost>,
    /// Ranks participating in each alltoall (1D grid: p; 2D: the axis size).
    pub a2a_ranks: Vec<usize>,
}

impl PlanCost {
    /// Total complex-FLOP count over all stages.
    pub fn total_flops(&self) -> f64 {
        self.stages.iter().map(|s| s.flops).sum()
    }

    /// Total bytes this rank puts on the wire over all exchanges.
    pub fn total_a2a_bytes(&self) -> f64 {
        self.stages.iter().map(|s| s.a2a_bytes).sum()
    }

    /// Time the driver's two-deep software pipeline can hide per flush:
    /// the memory time of the heaviest compute stage (its de-interleave /
    /// staging traffic priced on `m`'s bandwidth), which is the tail the
    /// driver hands to its persistent worker while the next flush's
    /// exchange runs on the communicating thread. The pipeline can never
    /// hide more than one stage's traffic per flush — the worker is one
    /// thread — so the heaviest stage bounds the benefit.
    pub fn pipeline_tail_time(&self, m: &super::machine::Machine) -> f64 {
        self.stages
            .iter()
            .filter(|s| s.rounds == 0)
            .map(|s| s.touched_bytes / m.mem_bw)
            .fold(0.0, f64::max)
    }
}

/// Batched slab-pencil forward on a 1D grid of `p` ranks.
pub fn slab_pencil(shape: [usize; 3], nb: usize, p: usize, batched: bool) -> PlanCost {
    let [nx, ny, nz] = shape;
    let lxc = cyclic::local_count(nx, p, 0);
    let lzc = cyclic::local_count(nz, p, 0);
    let local = (nb * lxc * ny * nz) as f64;
    let out_local = (nb * nx * ny * lzc) as f64;

    let fft_yz = (nb * lxc * nz) as f64 * fft_flops(ny) + (nb * lxc * ny) as f64 * fft_flops(nz);
    let fft_x = (nb * ny * lzc) as f64 * fft_flops(nx);
    let a2a_bytes = local * BYTES_PER_ELEM * (p - 1) as f64 / p as f64;
    let rounds = if batched { 1 } else { nb };

    // Pack and unpack each touch their full tensor twice (gather+scatter);
    // fused into the exchange, that traffic rides on the comm stage.
    let fused = (2.0 * local + 2.0 * out_local) * BYTES_PER_ELEM;
    PlanCost {
        stages: vec![
            StageCost::compute("fft_yz", fft_yz, 4.0 * local * BYTES_PER_ELEM),
            StageCost::comm_fused("a2a_xz", a2a_bytes, rounds, fused),
            StageCost::compute("fft_x", fft_x, 4.0 * out_local * BYTES_PER_ELEM),
        ],
        a2a_ranks: vec![p],
    }
}

/// Pencil-pencil forward on a `p0 x p1` grid.
pub fn pencil(shape: [usize; 3], nb: usize, p0: usize, p1: usize, batched: bool) -> PlanCost {
    let [nx, ny, nz] = shape;
    let lyc0 = cyclic::local_count(ny, p0, 0);
    let lzc1 = cyclic::local_count(nz, p1, 0);
    let lxc0 = cyclic::local_count(nx, p0, 0);
    let lyc1 = cyclic::local_count(ny, p1, 0);

    let v1 = (nb * nx * lyc0 * lzc1) as f64; // after stage 1
    let v2 = (nb * lxc0 * ny * lzc1) as f64; // after first exchange
    let v3 = (nb * lxc0 * lyc1 * nz) as f64; // after second exchange

    let rounds = if batched { 1 } else { nb };
    // Each exchange's pack (2x its source tensor) and unpack (2x its
    // destination tensor) are fused into the exchange itself.
    PlanCost {
        stages: vec![
            StageCost::compute(
                "fft_x",
                (nb * lyc0 * lzc1) as f64 * fft_flops(nx),
                4.0 * v1 * BYTES_PER_ELEM,
            ),
            StageCost::comm_fused(
                "a2a_xy",
                v1 * BYTES_PER_ELEM * (p0 - 1) as f64 / p0 as f64,
                rounds,
                (2.0 * v1 + 2.0 * v2) * BYTES_PER_ELEM,
            ),
            StageCost::compute(
                "fft_y",
                (nb * lxc0 * lzc1) as f64 * fft_flops(ny),
                4.0 * v2 * BYTES_PER_ELEM,
            ),
            StageCost::comm_fused(
                "a2a_yz",
                v2 * BYTES_PER_ELEM * (p1 - 1) as f64 / p1 as f64,
                rounds,
                (2.0 * v2 + 2.0 * v3) * BYTES_PER_ELEM,
            ),
            StageCost::compute(
                "fft_z",
                (nb * lxc0 * lyc1) as f64 * fft_flops(nz),
                4.0 * v3 * BYTES_PER_ELEM,
            ),
        ],
        a2a_ranks: vec![p0, p1],
    }
}

/// Plane-wave staged-padding forward on a 1D grid, from the *real* offset
/// array (exact disc/sphere counts). `batched` selects the paper's batched
/// execution (one fused sphere exchange carrying all `nb` bands); the
/// non-batched *loop* variant issues `nb` per-band exchanges instead —
/// same total wire bytes and pack/unpack traffic, but `nb`x the message
/// count at `1/nb` the size, which is what separates the two cadences on
/// a latency-sensitive machine (they priced identically before the loop
/// variant carried its own round count).
pub fn planewave(off: &OffsetArray, nb: usize, p: usize, batched: bool) -> PlanCost {
    let (nx, ny, nz) = (off.nx, off.ny, off.nz);
    let lzc = cyclic::local_count(nz, p, 0);
    // Worst rank: rank 0 owns ceil of the x columns.
    let local_off = off.restrict_x_cyclic(p, 0);
    let my_cols = local_off.disc_columns().len() as f64;
    let my_pts = local_off.total() as f64;
    let disc_xs = off.x_runs().iter().map(|r| r.1 as usize).sum::<usize>() as f64;

    let cyl = nb as f64 * my_cols * nz as f64; // dense z-columns
    let slab = (nb * nx * ny * lzc) as f64;
    let rounds = if batched { 1 } else { nb };

    PlanCost {
        stages: vec![
            StageCost::compute(
                "pad_fft_z",
                nb as f64 * my_cols * fft_flops(nz),
                (2.0 * nb as f64 * my_pts + 4.0 * cyl) * BYTES_PER_ELEM,
            ),
            // The landing of received columns into the slab (2x the moved
            // cylinder volume — the traffic the old model carried in
            // pad_fft_y's touched bytes) is fused into the exchange, so
            // window-1 pricing stays exactly the old sum.
            StageCost::comm_fused(
                "a2a_sphere",
                cyl * BYTES_PER_ELEM * (p - 1) as f64 / p as f64,
                rounds,
                2.0 * cyl * BYTES_PER_ELEM,
            ),
            StageCost::compute(
                "pad_fft_y",
                nb as f64 * disc_xs * lzc as f64 * fft_flops(ny),
                (2.0 * slab + 4.0 * nb as f64 * disc_xs * (ny * lzc) as f64) * BYTES_PER_ELEM,
            ),
            StageCost::compute(
                "fft_x",
                (nb * ny * lzc) as f64 * fft_flops(nx),
                4.0 * slab * BYTES_PER_ELEM,
            ),
        ],
        a2a_ranks: vec![p],
    }
}

/// Real-input (r2c) plane-wave forward on a 1D grid: the z stage runs one
/// *half-length* FFT per column plus an O(nh) twiddle unpack, and the fused
/// exchange carries only the `nh = nz/2 + 1` Hermitian-unique bins — so both
/// the wire volume and the downstream y/x slab shrink by ~`nh/nz` ≈ 0.5x
/// versus [`planewave`] on the same sphere. Always batched (the r2c family
/// has no loop cadence).
pub fn planewave_r2c(off: &OffsetArray, nb: usize, p: usize) -> PlanCost {
    let (nx, ny, nz) = (off.nx, off.ny, off.nz);
    let h = nz / 2;
    let nh = h + 1;
    let lzc = cyclic::local_count(nh, p, 0);
    let local_off = off.restrict_x_cyclic(p, 0);
    let my_cols = local_off.disc_columns().len() as f64;
    let my_pts = local_off.total() as f64;
    let disc_xs = off.x_runs().iter().map(|r| r.1 as usize).sum::<usize>() as f64;

    let cyl_half = nb as f64 * my_cols * h as f64; // pair-packed z-columns
    let cyl_h = nb as f64 * my_cols * nh as f64; // Hermitian-unique bins
    let slab = (nb * nx * ny * lzc) as f64;

    PlanCost {
        stages: vec![
            // Real scatter (8 B/elem) + half-length FFT + twiddle unpack
            // (~8 complex flops per unique bin).
            StageCost::compute(
                "pad_rfft_z",
                nb as f64 * my_cols * fft_flops(h) + 8.0 * cyl_h,
                nb as f64 * my_pts * 8.0 + (4.0 * cyl_half + 2.0 * cyl_h) * BYTES_PER_ELEM,
            ),
            StageCost::comm_fused(
                "a2a_herm",
                cyl_h * BYTES_PER_ELEM * (p - 1) as f64 / p as f64,
                1,
                2.0 * cyl_h * BYTES_PER_ELEM,
            ),
            StageCost::compute(
                "pad_fft_y",
                nb as f64 * disc_xs * lzc as f64 * fft_flops(ny),
                (2.0 * slab + 4.0 * nb as f64 * disc_xs * (ny * lzc) as f64) * BYTES_PER_ELEM,
            ),
            StageCost::compute(
                "fft_x",
                (nb * ny * lzc) as f64 * fft_flops(nx),
                4.0 * slab * BYTES_PER_ELEM,
            ),
        ],
        a2a_ranks: vec![p],
    }
}

/// Pad-to-cube baseline for sphere inputs (paper Fig. 2): scatter the
/// packed sphere into the full local cube slice, then run the dense batched
/// slab-pencil transform on everything, padding included.
pub fn padded_sphere(off: &OffsetArray, nb: usize, p: usize) -> PlanCost {
    let shape = [off.nx, off.ny, off.nz];
    let mut c = slab_pencil(shape, nb, p, true);
    // The up-front pad touches the packed points (read) and the full local
    // cube (zero + write) on the worst rank.
    let local_off = off.restrict_x_cyclic(p, 0);
    let lxc = cyclic::local_count(off.nx, p, 0);
    let pad_touched = (nb as f64 * local_off.total() as f64
        + 2.0 * (nb * lxc * off.ny * off.nz) as f64)
        * BYTES_PER_ELEM;
    c.stages.insert(0, StageCost::compute("pad_full", 0.0, pad_touched));
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    #[test]
    fn slab_flops_match_dense_3d_fft() {
        // Summed over ranks, the flop count must equal nb full 3D FFTs
        // (for divisible sizes).
        let shape = [8usize, 8, 8];
        let (nb, p) = (4usize, 4usize);
        let per_rank = slab_pencil(shape, nb, p, true).total_flops();
        let want = nb as f64
            * (64.0 * fft_flops(8) + 64.0 * fft_flops(8) + 64.0 * fft_flops(8));
        assert!((per_rank * p as f64 - want).abs() < 1e-6 * want);
    }

    #[test]
    fn non_batched_same_bytes_more_rounds() {
        let a = slab_pencil([16, 16, 16], 8, 4, true);
        let b = slab_pencil([16, 16, 16], 8, 4, false);
        assert_eq!(a.total_a2a_bytes(), b.total_a2a_bytes());
        // Stage list mirrors the fused live pipeline: [fft_yz, a2a_xz, fft_x].
        assert_eq!(a.stages[1].rounds, 1);
        assert_eq!(b.stages[1].rounds, 8);
        assert!(a.stages[1].fused_bytes > 0.0, "the exchange carries its pack/unpack traffic");
    }

    #[test]
    fn pencil_has_two_exchanges() {
        let c = pencil([16, 16, 16], 2, 2, 2, true);
        let comm_stages: Vec<_> = c.stages.iter().filter(|s| s.a2a_bytes > 0.0).collect();
        assert_eq!(comm_stages.len(), 2);
        assert_eq!(c.a2a_ranks, vec![2, 2]);
    }

    #[test]
    fn planewave_moves_fewer_bytes_than_slab() {
        let n = 32;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = spec.offsets();
        let (nb, p) = (4usize, 4usize);
        let pw = planewave(&off, nb, p, true);
        let dense = slab_pencil([n, n, n], nb, p, true);
        assert!(pw.total_a2a_bytes() < 0.4 * dense.total_a2a_bytes());
        assert!(pw.total_flops() < 0.7 * dense.total_flops());
    }

    #[test]
    fn planewave_loop_same_bytes_more_rounds() {
        // The loop cadence moves the same data as the batched exchange but
        // in nb per-band invocations — the stage tables must agree on
        // everything except the round count (the knob the tuner prices).
        let n = 16;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = spec.offsets();
        let (nb, p) = (8usize, 4usize);
        let batched = planewave(&off, nb, p, true);
        let looped = planewave(&off, nb, p, false);
        assert_eq!(batched.total_a2a_bytes(), looped.total_a2a_bytes());
        assert_eq!(batched.total_flops(), looped.total_flops());
        assert_eq!(batched.stages[1].rounds, 1);
        assert_eq!(looped.stages[1].rounds, nb);
        assert_eq!(batched.stages[1].fused_bytes, looped.stages[1].fused_bytes);
    }

    #[test]
    fn r2c_halves_wire_and_flops_vs_c2c() {
        let n = 32;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = spec.offsets();
        let (nb, p) = (4usize, 4usize);
        let r2c = planewave_r2c(&off, nb, p);
        let c2c = planewave(&off, nb, p, true);
        // Wire: exactly nh/nz of the c2c cylinder — (n/2+1)/n, under 0.6.
        let ratio = r2c.total_a2a_bytes() / c2c.total_a2a_bytes();
        let want = (n / 2 + 1) as f64 / n as f64;
        assert!((ratio - want).abs() < 1e-12, "ratio {ratio} want {want}");
        assert!(ratio < 0.6);
        // Flops: half-length z FFT plus the half-depth y/x slab.
        assert!(r2c.total_flops() < 0.75 * c2c.total_flops());
        assert_eq!(r2c.stages[1].name, "a2a_herm");
    }

    #[test]
    fn padded_sphere_costs_more_than_planewave() {
        let n = 32;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = spec.offsets();
        let (nb, p) = (4usize, 4usize);
        let padded = padded_sphere(&off, nb, p);
        let pw = planewave(&off, nb, p, true);
        assert!(padded.total_a2a_bytes() > pw.total_a2a_bytes());
        assert!(padded.total_flops() > pw.total_flops());
        // Same wire volume as the dense cube plan, plus the pad stage.
        let dense = slab_pencil([n, n, n], nb, p, true);
        assert_eq!(padded.total_a2a_bytes(), dense.total_a2a_bytes());
        assert_eq!(padded.stages.len(), dense.stages.len() + 1);
    }

    #[test]
    fn pipeline_tail_is_the_heaviest_compute_stage() {
        use crate::model::machine::Machine;
        let m = Machine::local_cpu();
        let c = slab_pencil([16, 16, 16], 8, 4, true);
        let heaviest = c
            .stages
            .iter()
            .filter(|s| s.rounds == 0)
            .map(|s| s.touched_bytes)
            .fold(0.0, f64::max);
        assert!(heaviest > 0.0);
        assert_eq!(c.pipeline_tail_time(&m), heaviest / m.mem_bw);
        // Comm stages never contribute: a cost table with only exchanges
        // has no tail to hand to the worker.
        let comm_only = PlanCost {
            stages: vec![StageCost::comm_fused("a2a", 1e6, 1, 1e6)],
            a2a_ranks: vec![4],
        };
        assert_eq!(comm_only.pipeline_tail_time(&m), 0.0);
    }

    #[test]
    fn cost_matches_live_trace_bytes() {
        // The analytical a2a bytes must equal what the live plan reports.
        use crate::comm::communicator::run_world;
        use crate::fftb::backend::RustFftBackend;
        use crate::fftb::grid::ProcGrid;
        use crate::fftb::plan::testutil::phased;
        use crate::fftb::plan::SlabPencilPlan;
        use std::sync::Arc;

        let shape = [8usize, 8, 8];
        let (nb, p) = (2usize, 2usize);
        let traces = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = phased(plan.input_len(), 1);
            let backend = RustFftBackend::new();
            plan.forward(&backend, local).1
        });
        let model = slab_pencil(shape, nb, p, true);
        let model_bytes = model.total_a2a_bytes();
        for tr in traces {
            assert_eq!(tr.comm_bytes() as f64, model_bytes);
        }
    }
}
