//! Machine descriptions for the performance model.
//!
//! The live testbed is `p` threads in one process; the paper's testbed is
//! Perlmutter (4x A100 per node, Slingshot-11 dragonfly). The model prices
//! the *exact* per-stage flop/byte/message counts produced by the planner
//! (see `super::cost`) on a described machine, which is how the Fig. 9
//! series are projected beyond the live thread count (DESIGN.md §3).
//!
//! Constants for `perlmutter_a100` are drawn from public numbers: A100
//! peak/effective FFT throughput, 1.55 TB/s HBM, ~22 GB/s per-GPU effective
//! injection bandwidth (4 GPUs sharing 2x25 GB/s Slingshot NICs), and a
//! few-microsecond MPI latency with an eager->rendezvous protocol switch.
//! They are estimates — the reproduction claims *shape*, not absolute time.

/// A machine to price stage counts on.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: &'static str,
    /// Effective local FFT throughput per rank, complex-FLOP/s.
    pub fft_flops_per_sec: f64,
    /// Effective local memory bandwidth per rank for pack/unpack, B/s.
    pub mem_bw: f64,
    /// Per-message latency of the interconnect (alpha), seconds.
    pub alpha: f64,
    /// Per-byte time (1/bandwidth) per rank (beta), s/B.
    pub beta: f64,
    /// Message size (bytes) below which the MPI alltoall switches algorithm
    /// (the 64->128 GPU jump in the paper's light-blue line).
    pub small_msg_threshold: usize,
    /// Latency multiplier after the switch (protocol overhead).
    pub small_msg_alpha_factor: f64,
}

impl Machine {
    /// Perlmutter GPU-node estimate (per-GPU rank).
    pub fn perlmutter_a100() -> Machine {
        Machine {
            name: "perlmutter-a100",
            // cuFFT on A100 sustains O(1-2) TFLOP/s on batched C2C lines.
            fft_flops_per_sec: 1.2e12,
            mem_bw: 1.3e12,
            alpha: 3.0e-6,
            beta: 1.0 / 22.0e9,
            small_msg_threshold: 8 * 1024,
            small_msg_alpha_factor: 4.0,
        }
    }

    /// The live in-process testbed (rank = one CPU thread). Calibrate with
    /// [`Machine::calibrated`] from a measured trace for accurate absolute
    /// numbers; these defaults are a modern server core.
    pub fn local_cpu() -> Machine {
        Machine {
            name: "local-cpu-thread",
            fft_flops_per_sec: 2.0e9,
            mem_bw: 8.0e9,
            alpha: 2.0e-7, // shared-memory mailbox
            beta: 1.0 / 5.0e9,
            small_msg_threshold: 0, // no protocol switch in-process
            small_msg_alpha_factor: 1.0,
        }
    }

    /// Replace the compute/memory rates with measured values (from a live
    /// `ExecTrace`): flops/s over the compute stages and B/s over the
    /// reshape stages.
    pub fn calibrated(mut self, fft_flops_per_sec: f64, mem_bw: f64) -> Machine {
        if fft_flops_per_sec.is_finite() && fft_flops_per_sec > 0.0 {
            self.fft_flops_per_sec = fft_flops_per_sec;
        }
        if mem_bw.is_finite() && mem_bw > 0.0 {
            self.mem_bw = mem_bw;
        }
        self
    }

    /// Time for one alltoall: each rank sends `bytes_per_rank` split into
    /// `p - 1` messages (pairwise exchange), or the small-message algorithm
    /// past the protocol switch.
    pub fn alltoall_time(&self, p: usize, bytes_per_rank: f64) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let msgs = (p - 1) as f64;
        let msg_size = bytes_per_rank / msgs;
        let alpha = if (msg_size as usize) < self.small_msg_threshold {
            self.alpha * self.small_msg_alpha_factor
        } else {
            self.alpha
        };
        msgs * alpha + bytes_per_rank * self.beta
    }

    /// Time for local compute of `flops` plus `touched_bytes` of pack/unpack
    /// traffic (simple roofline: compute and memory do not overlap).
    pub fn compute_time(&self, flops: f64, touched_bytes: f64) -> f64 {
        flops / self.fft_flops_per_sec + touched_bytes / self.mem_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alltoall_latency_dominates_small_messages() {
        let m = Machine::perlmutter_a100();
        let p = 1024;
        // 1 KiB per peer: latency bound.
        let t_small = m.alltoall_time(p, 1024.0 * (p - 1) as f64);
        // Same total bytes in one call with 1 MiB per peer.
        let t_large = m.alltoall_time(p, 1024.0 * 1024.0 * (p - 1) as f64);
        assert!(t_small > 0.01); // >10 ms of pure latency
        assert!(t_large > t_small); // more bytes still costs more
        // But per-byte, small messages are far worse:
        let eff_small = (1024.0 * (p - 1) as f64) / t_small;
        let eff_large = (1024.0 * 1024.0 * (p - 1) as f64) / t_large;
        assert!(eff_large > 20.0 * eff_small);
    }

    #[test]
    fn protocol_switch_raises_alpha() {
        let m = Machine::perlmutter_a100();
        let p = 128;
        let just_above = (m.small_msg_threshold as f64 + 1.0) * (p - 1) as f64;
        let just_below = (m.small_msg_threshold as f64 - 1.0) * (p - 1) as f64;
        let t_above = m.alltoall_time(p, just_above);
        let t_below = m.alltoall_time(p, just_below);
        // Nearly the same bytes, but the switch makes the smaller one slower.
        assert!(t_below > t_above);
    }

    #[test]
    fn single_rank_is_free() {
        assert_eq!(Machine::local_cpu().alltoall_time(1, 1e9), 0.0);
    }
}
