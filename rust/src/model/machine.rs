//! Machine descriptions for the performance model.
//!
//! The live testbed is `p` threads in one process; the paper's testbed is
//! Perlmutter (4x A100 per node, Slingshot-11 dragonfly). The model prices
//! the *exact* per-stage flop/byte/message counts produced by the planner
//! (see `super::cost`) on a described machine, which is how the Fig. 9
//! series are projected beyond the live thread count (DESIGN.md §3).
//!
//! Constants for `perlmutter_a100` are drawn from public numbers: A100
//! peak/effective FFT throughput, 1.55 TB/s HBM, ~22 GB/s per-GPU effective
//! injection bandwidth (4 GPUs sharing 2x25 GB/s Slingshot NICs), and a
//! few-microsecond MPI latency with an eager->rendezvous protocol switch.
//! They are estimates — the reproduction claims *shape*, not absolute time.

/// A machine to price stage counts on.
#[derive(Clone, Debug)]
pub struct Machine {
    /// Human-readable machine label (printed by the benches).
    pub name: &'static str,
    /// Effective local FFT throughput per rank, complex-FLOP/s.
    pub fft_flops_per_sec: f64,
    /// Effective local memory bandwidth per rank for pack/unpack, B/s.
    pub mem_bw: f64,
    /// Per-message latency of the interconnect (alpha), seconds.
    pub alpha: f64,
    /// Per-byte time (1/bandwidth) per rank (beta), s/B.
    pub beta: f64,
    /// Message size (bytes) below which the MPI alltoall switches algorithm
    /// (the 64->128 GPU jump in the paper's light-blue line).
    pub small_msg_threshold: usize,
    /// Latency multiplier after the switch (protocol overhead).
    pub small_msg_alpha_factor: f64,
}

impl Machine {
    /// Buffer-pinning / injection-contention charge of the windowed
    /// exchange, as a fraction of the base per-message latency per round
    /// held open ahead of the current wait (see
    /// [`Machine::alltoall_time_windowed`]).
    pub const WINDOW_PIN_ALPHA_FRACTION: f64 = 0.5;

    /// Per-message channel-handoff charge of the threaded exchange (ship a
    /// buffer through the helper's mpsc channel and wake it), as a
    /// fraction of the base per-message latency (see
    /// [`Machine::alltoall_time_fused_threaded`]).
    pub const WORKER_HANDOFF_ALPHA_FRACTION: f64 = 0.25;

    /// Perlmutter GPU-node estimate (per-GPU rank).
    pub fn perlmutter_a100() -> Machine {
        Machine {
            name: "perlmutter-a100",
            // cuFFT on A100 sustains O(1-2) TFLOP/s on batched C2C lines.
            fft_flops_per_sec: 1.2e12,
            mem_bw: 1.3e12,
            alpha: 3.0e-6,
            beta: 1.0 / 22.0e9,
            small_msg_threshold: 8 * 1024,
            small_msg_alpha_factor: 4.0,
        }
    }

    /// The live in-process testbed (rank = one CPU thread). Calibrate with
    /// [`Machine::calibrated`] from a measured trace for accurate absolute
    /// numbers; these defaults are a modern server core.
    pub fn local_cpu() -> Machine {
        Machine {
            name: "local-cpu-thread",
            fft_flops_per_sec: 2.0e9,
            mem_bw: 8.0e9,
            alpha: 2.0e-7, // shared-memory mailbox
            beta: 1.0 / 5.0e9,
            small_msg_threshold: 0, // no protocol switch in-process
            small_msg_alpha_factor: 1.0,
        }
    }

    /// Replace the compute/memory rates with measured values (from a live
    /// `ExecTrace`): flops/s over the compute stages and B/s over the
    /// reshape stages.
    pub fn calibrated(mut self, fft_flops_per_sec: f64, mem_bw: f64) -> Machine {
        if fft_flops_per_sec.is_finite() && fft_flops_per_sec > 0.0 {
            self.fft_flops_per_sec = fft_flops_per_sec;
        }
        if mem_bw.is_finite() && mem_bw > 0.0 {
            self.mem_bw = mem_bw;
        }
        self
    }

    /// Time for one alltoall under the serial schedule: each rank sends
    /// `bytes_per_rank` split into `p - 1` messages (pairwise exchange),
    /// or the small-message algorithm past the protocol switch. Identical
    /// to [`Machine::alltoall_time_windowed`] with window 1.
    pub fn alltoall_time(&self, p: usize, bytes_per_rank: f64) -> f64 {
        self.alltoall_time_windowed(p, bytes_per_rank, 1)
    }

    /// Time for local compute of `flops` plus `touched_bytes` of pack/unpack
    /// traffic (simple roofline: compute and memory do not overlap).
    pub fn compute_time(&self, flops: f64, touched_bytes: f64) -> f64 {
        flops / self.fft_flops_per_sec + touched_bytes / self.mem_bw
    }

    /// Time for one alltoall under the *windowed overlapped* pipeline of
    /// `comm::alltoall` with `window` rounds of sends in flight.
    ///
    /// The per-message latency convoy is pipelined across the window
    /// (`ceil(msgs / window)` serialized latencies instead of `msgs`),
    /// while the byte term is wire-bound and unchanged. Each round held
    /// open *ahead* of the current wait pins a packed send buffer and a
    /// posted receive and contends for injection — charged as
    /// [`Machine::WINDOW_PIN_ALPHA_FRACTION`] of a base latency per extra
    /// in-flight round, so widening the window has a real cost and the
    /// optimum is an interior point that moves with `p` and message size
    /// rather than degenerating to the maximum. `window == 1` reproduces
    /// [`Machine::alltoall_time`] exactly (the serial schedule).
    pub fn alltoall_time_windowed(&self, p: usize, bytes_per_rank: f64, window: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let msgs = p - 1;
        let msg_size = bytes_per_rank / msgs as f64;
        let alpha = if (msg_size as usize) < self.small_msg_threshold {
            self.alpha * self.small_msg_alpha_factor
        } else {
            self.alpha
        };
        let w = window.clamp(1, msgs);
        let serialized = (msgs + w - 1) / w; // ceil(msgs / window)
        let pin = (w - 1) as f64 * Self::WINDOW_PIN_ALPHA_FRACTION * self.alpha;
        serialized as f64 * alpha + pin + bytes_per_rank * self.beta
    }

    /// [`Machine::alltoall_time_windowed`] with the **fused-pack
    /// discount**: `fused_bytes` of per-destination pack/unpack memory
    /// traffic ride *inside* the exchange (the live engine packs block
    /// `s + w` between the waits for rounds `s` and `s + 1`, and unpacks
    /// each block as its wait completes), so a window-`w` pipeline hides
    /// all but a `1/w` fraction of that traffic behind the waits.
    ///
    /// `window == 1` exposes the full pack/unpack time — the serial
    /// ordering interleaves but cannot hide, which keeps window-1 pricing
    /// exactly equal to the old monolithic pack-stage + exchange-stage sum
    /// (the Fig. 9 projections are unchanged). Wider windows hide more, so
    /// fused schedules push the model's window optimum wider than the
    /// pinning charge alone would allow — this is what lets
    /// `tuner::search` price fusion and move the optimum accordingly. On a
    /// single-rank communicator the "exchange" is pure local pack/unpack
    /// and nothing can hide it.
    pub fn alltoall_time_fused(
        &self,
        p: usize,
        bytes_per_rank: f64,
        window: usize,
        fused_bytes: f64,
    ) -> f64 {
        let pack_time = fused_bytes / self.mem_bw;
        if p <= 1 {
            return pack_time;
        }
        let w = window.clamp(1, p - 1);
        self.alltoall_time_windowed(p, bytes_per_rank, window) + pack_time / w as f64
    }

    /// [`Machine::alltoall_time_fused`] with the exchange's **helper worker
    /// thread** priced in. With `worker == false` this is exactly the
    /// single-threaded fused model (bit-for-bit the same float ops), so
    /// everything priced before the worker existed is unchanged.
    ///
    /// With `worker == true`, pack/unpack runs on the helper *while the
    /// communicating thread is blocked in waits*, so the exposed `1/w`
    /// pack fraction disappears entirely — but every round pays a channel
    /// handoff (send the packed buffer / received block across the mpsc
    /// channel, wake the helper), charged as
    /// [`Machine::WORKER_HANDOFF_ALPHA_FRACTION`] of a base latency per
    /// message. The worker therefore wins exactly when the exposed pack
    /// time `pack_time / w` exceeds `msgs * handoff` — large fused volumes
    /// and narrow windows — and loses on latency-dominated exchanges,
    /// which is the trade [`crate::tuner::search`] enumerates. On a
    /// single-rank communicator there are no rounds to hide behind and the
    /// helper is never engaged: pure local pack time, same as fused.
    pub fn alltoall_time_fused_threaded(
        &self,
        p: usize,
        bytes_per_rank: f64,
        window: usize,
        fused_bytes: f64,
        worker: bool,
    ) -> f64 {
        if !worker {
            return self.alltoall_time_fused(p, bytes_per_rank, window, fused_bytes);
        }
        let pack_time = fused_bytes / self.mem_bw;
        if p <= 1 {
            return pack_time;
        }
        let handoff = (p - 1) as f64 * Self::WORKER_HANDOFF_ALPHA_FRACTION * self.alpha;
        self.alltoall_time_windowed(p, bytes_per_rank, window) + handoff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alltoall_latency_dominates_small_messages() {
        let m = Machine::perlmutter_a100();
        let p = 1024;
        // 1 KiB per peer: latency bound.
        let t_small = m.alltoall_time(p, 1024.0 * (p - 1) as f64);
        // Same total bytes in one call with 1 MiB per peer.
        let t_large = m.alltoall_time(p, 1024.0 * 1024.0 * (p - 1) as f64);
        assert!(t_small > 0.01); // >10 ms of pure latency
        assert!(t_large > t_small); // more bytes still costs more
        // But per-byte, small messages are far worse:
        let eff_small = (1024.0 * (p - 1) as f64) / t_small;
        let eff_large = (1024.0 * 1024.0 * (p - 1) as f64) / t_large;
        assert!(eff_large > 20.0 * eff_small);
    }

    #[test]
    fn protocol_switch_raises_alpha() {
        let m = Machine::perlmutter_a100();
        let p = 128;
        let just_above = (m.small_msg_threshold as f64 + 1.0) * (p - 1) as f64;
        let just_below = (m.small_msg_threshold as f64 - 1.0) * (p - 1) as f64;
        let t_above = m.alltoall_time(p, just_above);
        let t_below = m.alltoall_time(p, just_below);
        // Nearly the same bytes, but the switch makes the smaller one slower.
        assert!(t_below > t_above);
    }

    #[test]
    fn single_rank_is_free() {
        assert_eq!(Machine::local_cpu().alltoall_time(1, 1e9), 0.0);
    }

    #[test]
    fn window_one_matches_serial_model() {
        // Pinned against the explicit serial formula (alltoall_time is now
        // a window-1 delegation, so spell the formula out here).
        let m = Machine::perlmutter_a100();
        for p in [2usize, 7, 64] {
            let bytes = 4096.0 * (p - 1) as f64;
            let msgs = (p - 1) as f64;
            let alpha = if ((bytes / msgs) as usize) < m.small_msg_threshold {
                m.alpha * m.small_msg_alpha_factor
            } else {
                m.alpha
            };
            let want = msgs * alpha + bytes * m.beta;
            assert_eq!(m.alltoall_time_windowed(p, bytes, 1), want);
            assert_eq!(m.alltoall_time(p, bytes), want);
        }
    }

    #[test]
    fn fused_discount_preserves_window_one_and_widens_the_optimum() {
        // local_cpu: memory-bound regime where pack time is comparable to
        // the latency terms, so hiding it visibly moves the optimum.
        let m = Machine::local_cpu();
        let p = 8usize;
        let bytes = (64 * 1024) as f64 * (p - 1) as f64;
        let fused = 4.0 * bytes; // pack + unpack touch ~4x the wire volume
        // Window 1: the serial ordering hides nothing — pricing must equal
        // the old "pack stage + exchange stage" sum exactly.
        let want = m.alltoall_time_windowed(p, bytes, 1) + fused / m.mem_bw;
        assert_eq!(m.alltoall_time_fused(p, bytes, 1, fused), want);
        // Zero fused bytes: exactly the plain windowed model.
        for w in [1usize, 2, 7] {
            assert_eq!(
                m.alltoall_time_fused(p, bytes, w, 0.0),
                m.alltoall_time_windowed(p, bytes, w)
            );
        }
        // The fused discount must move the window optimum wider: pick the
        // argmin over the ladder with and without fused bytes.
        let argmin = |fused: f64| {
            (1..p)
                .min_by(|&a, &b| {
                    m.alltoall_time_fused(p, bytes, a, fused)
                        .total_cmp(&m.alltoall_time_fused(p, bytes, b, fused))
                })
                .unwrap()
        };
        let (w_plain, w_fused) = (argmin(0.0), argmin(fused));
        assert!(
            w_fused > w_plain,
            "fused pack must widen the optimum (plain {w_plain}, fused {w_fused})"
        );
        // Single-rank communicators: pure local pack/unpack, nothing hidden.
        assert_eq!(m.alltoall_time_fused(1, 0.0, 4, fused), fused / m.mem_bw);
    }

    #[test]
    fn threaded_model_prices_the_worker_tradeoff() {
        let m = Machine::local_cpu();
        let p = 8usize;
        let bytes = (64 * 1024) as f64 * (p - 1) as f64;
        let fused = 4.0 * bytes;
        // worker=false is bit-for-bit the single-threaded fused model.
        for w in [1usize, 2, 7] {
            assert_eq!(
                m.alltoall_time_fused_threaded(p, bytes, w, fused, false),
                m.alltoall_time_fused(p, bytes, w, fused)
            );
        }
        // worker=true replaces the exposed pack fraction with the per-round
        // handoff charge.
        let handoff = (p - 1) as f64 * Machine::WORKER_HANDOFF_ALPHA_FRACTION * m.alpha;
        for w in [1usize, 2, 7] {
            assert_eq!(
                m.alltoall_time_fused_threaded(p, bytes, w, fused, true),
                m.alltoall_time_windowed(p, bytes, w) + handoff
            );
        }
        // Memory-bound regime (local_cpu, heavy fused traffic): the worker
        // must win at window 1, where all the pack time is exposed.
        assert!(
            m.alltoall_time_fused_threaded(p, bytes, 1, fused, true)
                < m.alltoall_time_fused_threaded(p, bytes, 1, fused, false),
            "hiding pack behind waits must beat exposing it"
        );
        // Latency-dominated regime: tiny fused volume, the handoff charge
        // is pure overhead and the worker must lose.
        assert!(
            m.alltoall_time_fused_threaded(p, bytes, 2, 0.0, true)
                > m.alltoall_time_fused_threaded(p, bytes, 2, 0.0, false),
            "a worker with nothing to hide must cost its handoffs"
        );
        // Single rank: pure local pack time either way, helper never engaged.
        assert_eq!(m.alltoall_time_fused_threaded(1, 0.0, 4, fused, true), fused / m.mem_bw);
    }

    #[test]
    fn windowed_cost_has_interior_optimum() {
        // Overlap must help over serial, but the pinning charge must keep
        // the maximum window from being a degenerate always-winner —
        // otherwise window autotuning is a constant function.
        let m = Machine::perlmutter_a100();
        let p = 8;
        // Large messages: above the protocol switch, latency-visible.
        let bytes = (64 * 1024) as f64 * (p - 1) as f64;
        let t = |w| m.alltoall_time_windowed(p, bytes, w);
        assert!(t(2) < t(1), "a little overlap must beat serial");
        assert!(t(4) < t(2), "more overlap still helps here");
        assert!(t(7) > t(4), "the full window must not always win");
        // The byte term is a floor overlap cannot beat.
        assert!(t(4) >= bytes * m.beta);
    }
}
