//! Timing statistics for the hand-rolled bench harness (no criterion in the
//! offline dependency set — `cargo bench` runs `harness = false` binaries
//! built on this module).

use std::time::{Duration, Instant};

/// Summary statistics of repeated timed runs.
#[derive(Clone, Debug)]
pub struct Samples {
    pub times: Vec<Duration>,
}

impl Samples {
    pub fn mean(&self) -> Duration {
        if self.times.is_empty() {
            return Duration::ZERO;
        }
        self.times.iter().sum::<Duration>() / self.times.len() as u32
    }

    pub fn min(&self) -> Duration {
        self.times.iter().min().copied().unwrap_or(Duration::ZERO)
    }

    pub fn max(&self) -> Duration {
        self.times.iter().max().copied().unwrap_or(Duration::ZERO)
    }

    /// Sample standard deviation in seconds.
    pub fn stddev(&self) -> f64 {
        if self.times.len() < 2 {
            return 0.0;
        }
        let mean = self.mean().as_secs_f64();
        let var: f64 = self
            .times
            .iter()
            .map(|t| {
                let d = t.as_secs_f64() - mean;
                d * d
            })
            .sum::<f64>()
            / (self.times.len() - 1) as f64;
        var.sqrt()
    }
}

/// Run `f` with `warmup` untimed and `iters` timed iterations — the paper's
/// methodology (§4.2: "a warmup phase of 10 iterations ... a hot phase of
/// another 10 iterations, where we measure the execution time ... we take
/// the average").
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    Samples { times }
}

/// Format a duration in engineering units.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0usize;
        let s = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.times.len(), 5);
        assert!(s.mean() >= Duration::ZERO);
        assert!(s.min() <= s.max());
    }

    #[test]
    fn formats() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(7)).ends_with(" us"));
    }
}
