//! Deterministic splitmix64/xoshiro-style PRNG.
//!
//! The offline build has no `rand`/`proptest`; this PRNG powers the
//! hand-rolled property-test harness (`rust/tests/prop_*.rs`) and the
//! workload generators in the benches. Deterministic seeding keeps every
//! test and bench reproducible.

/// splitmix64 — tiny, fast, good enough for test-data generation.
#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [-1, 1).
    pub fn next_signed(&mut self) -> f64 {
        2.0 * self.next_f64() - 1.0
    }

    /// Uniform integer in [0, bound).
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len())]
    }

    /// Random complex vector with components in [-1, 1).
    pub fn complex_vec(&mut self, n: usize) -> Vec<crate::fft::complex::Complex> {
        (0..n)
            .map(|_| crate::fft::complex::Complex::new(self.next_signed(), self.next_signed()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(42);
        for _ in 0..1000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_ints_cover_range() {
        let mut p = Prng::new(3);
        let mut seen = [false; 7];
        for _ in 0..200 {
            seen[p.next_below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
