//! FNV-1a hashing over `u64` words — the one fingerprinting scheme shared
//! by [`Comm::identity`](crate::comm::communicator::Comm::identity) and
//! [`OffsetArray::fingerprint`](crate::fftb::sphere::OffsetArray::fingerprint),
//! so communicator identities and sphere fingerprints stay provably
//! consistent with each other.

/// FNV-1a offset basis.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
/// FNV-1a prime.
pub const FNV_PRIME: u64 = 0x100000001b3;

/// Fold one little-endian `u64` word into the running hash `h`.
pub fn fnv1a_word(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash a sequence of `u64` words from the offset basis.
pub fn fnv1a_words(words: impl IntoIterator<Item = u64>) -> u64 {
    words.into_iter().fold(FNV_OFFSET, fnv1a_word)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_and_content_sensitive() {
        assert_ne!(fnv1a_words([1, 2]), fnv1a_words([2, 1]));
        assert_ne!(fnv1a_words([1, 2]), fnv1a_words([1, 3]));
        assert_eq!(fnv1a_words([1, 2]), fnv1a_words([1, 2]));
        assert_ne!(fnv1a_words([]), fnv1a_words([0]));
    }
}
