//! Small dependency-free utilities: JSON (manifest/bench output), timing
//! statistics, FNV-1a fingerprinting, and a deterministic PRNG for the
//! property-test harness.

pub mod fnv;
pub mod json;
pub mod prng;
pub mod stats;
