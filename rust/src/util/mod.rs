//! Small dependency-free utilities: JSON (manifest/bench output), timing
//! statistics, and a deterministic PRNG for the property-test harness.

pub mod json;
pub mod prng;
pub mod stats;
