//! Minimal JSON parser/writer for the artifact manifest and bench output.
//!
//! The offline build has no `serde`/`serde_json` (only the `xla` crate's
//! dependency tree is vendored), and the manifest is tiny — a hand-rolled
//! recursive-descent parser is 150 lines and keeps the repo dependency-free.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| format!("bad utf-8 in number at byte {start}: {e}"))?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(c) => return Err(format!("unsupported escape \\{}", c as char)),
                        None => return Err("eof in string".into()),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Multi-byte UTF-8 passes through unchanged.
                    let len = utf8_len(c);
                    let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + len])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                    self.pos += len;
                }
                None => return Err("eof in string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.pos)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "\"{k}\":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{"batch": 64, "entries": [{"name": "fft8_f", "file": "fft8_f.hlo.txt", "inputs": [[64, 8, 2]]}]}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(64));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("fft8_f"));
        let dims = e.get("inputs").unwrap().as_arr().unwrap()[0].as_arr().unwrap();
        assert_eq!(dims.len(), 3);
        assert_eq!(dims[1].as_usize(), Some(8));
    }

    #[test]
    fn round_trips_through_display() {
        let s = r#"{"a":[1,2.5,"x\"y"],"b":null,"c":true}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"[[[1]], {"k": [2, {"m": 3}]}]"#).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr[0].as_arr().unwrap()[0].as_arr().unwrap()[0].as_f64(), Some(1.0));
    }
}
