//! Minimal complex arithmetic used throughout the FFT substrate.
//!
//! We deliberately avoid external crates: the whole repository builds
//! offline against the vendored `xla` dependency tree only. `Complex` is
//! `repr(C)` so slices of it can be reinterpreted as byte/f64 buffers when
//! crossing the communicator or the PJRT boundary.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Double-precision complex number (the native element of the local FFT
/// substrate and of all distributed tensors).
#[derive(Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };

impl Complex {
    #[inline(always)]
    pub const fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    /// `exp(i * theta)` — unit phasor.
    #[inline]
    pub fn expi(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        Complex { re: c, im: s }
    }

    #[inline(always)]
    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }

    #[inline(always)]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline(always)]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Multiply by `i` (90 degree rotation) without a full complex multiply.
    #[inline(always)]
    pub fn mul_i(self) -> Self {
        Complex { re: -self.im, im: self.re }
    }

    /// Multiply by `-i`.
    #[inline(always)]
    pub fn mul_neg_i(self) -> Self {
        Complex { re: self.im, im: -self.re }
    }

    /// Fused multiply-add: `self * b + c`.
    #[inline(always)]
    pub fn mul_add(self, b: Complex, c: Complex) -> Self {
        Complex {
            re: self.re * b.re - self.im * b.im + c.re,
            im: self.re * b.im + self.im * b.re + c.im,
        }
    }
}

impl Add for Complex {
    type Output = Complex;
    #[inline(always)]
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for Complex {
    type Output = Complex;
    #[inline(always)]
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for Complex {
    type Output = Complex;
    #[inline(always)]
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Div for Complex {
    type Output = Complex;
    #[inline]
    fn div(self, o: Complex) -> Complex {
        let d = o.norm_sqr();
        Complex {
            re: (self.re * o.re + self.im * o.im) / d,
            im: (self.im * o.re - self.re * o.im) / d,
        }
    }
}

impl Neg for Complex {
    type Output = Complex;
    #[inline(always)]
    fn neg(self) -> Complex {
        Complex { re: -self.re, im: -self.im }
    }
}

impl AddAssign for Complex {
    #[inline(always)]
    fn add_assign(&mut self, o: Complex) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for Complex {
    #[inline(always)]
    fn sub_assign(&mut self, o: Complex) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for Complex {
    #[inline(always)]
    fn mul_assign(&mut self, o: Complex) {
        *self = *self * o;
    }
}

impl Mul<f64> for Complex {
    type Output = Complex;
    #[inline(always)]
    fn mul(self, s: f64) -> Complex {
        self.scale(s)
    }
}

impl fmt::Debug for Complex {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({:+.6e}{:+.6e}i)", self.re, self.im)
    }
}

impl fmt::Display for Complex {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}{}i", self.re, if self.im < 0.0 { "-" } else { "+" }, self.im.abs())
    }
}

impl From<f64> for Complex {
    fn from(re: f64) -> Self {
        Complex { re, im: 0.0 }
    }
}

/// Maximum absolute element-wise error between two complex slices.
pub fn max_abs_diff(a: &[Complex], b: &[Complex]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
}

/// Relative L2 error `||a-b|| / max(||b||, eps)`.
pub fn rel_l2_err(a: &[Complex], b: &[Complex]) -> f64 {
    assert_eq!(a.len(), b.len(), "rel_l2_err: length mismatch");
    let num: f64 = a.iter().zip(b).map(|(x, y)| (*x - *y).norm_sqr()).sum();
    let den: f64 = b.iter().map(|y| y.norm_sqr()).sum();
    (num / den.max(1e-300)).sqrt()
}

/// Reinterpret a complex slice as its raw `f64` storage (re,im interleaved).
pub fn as_f64_slice(a: &[Complex]) -> &[f64] {
    // SAFETY: Complex is repr(C) { f64, f64 } with no padding.
    unsafe { std::slice::from_raw_parts(a.as_ptr() as *const f64, a.len() * 2) }
}

/// Reinterpret a mutable complex slice as its raw `f64` storage.
pub fn as_f64_slice_mut(a: &mut [Complex]) -> &mut [f64] {
    // SAFETY: Complex is repr(C) { f64, f64 } with no padding.
    unsafe { std::slice::from_raw_parts_mut(a.as_mut_ptr() as *mut f64, a.len() * 2) }
}

/// Reinterpret a complex slice as raw bytes (for the communicator).
pub fn as_bytes(a: &[Complex]) -> &[u8] {
    // SAFETY: Complex is POD.
    unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, std::mem::size_of_val(a)) }
}

/// Reinterpret a mutable complex slice as raw bytes (the in-place receive
/// target of the flat alltoall engine).
pub fn as_bytes_mut(a: &mut [Complex]) -> &mut [u8] {
    // SAFETY: Complex is POD, and every byte pattern is a valid f64 pair.
    unsafe {
        std::slice::from_raw_parts_mut(a.as_mut_ptr() as *mut u8, std::mem::size_of_val(a))
    }
}

/// Reinterpret an `f64` slice as raw bytes (the wire view the reduction
/// collectives send). Centralized here so the comm layer holds no unsafe
/// byte casts of its own.
pub fn f64_as_bytes(a: &[f64]) -> &[u8] {
    // SAFETY: f64 is POD with no padding; the view borrows `a`, so the
    // bytes cannot outlive or alias a mutation of the source slice.
    unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, std::mem::size_of_val(a)) }
}

/// Copy raw bytes into an existing complex slice (the allocation-free
/// receive path of the flat alltoall). Byte length must equal the slice's
/// storage size.
pub fn copy_from_bytes(bytes: &[u8], out: &mut [Complex]) {
    assert_eq!(
        bytes.len(),
        std::mem::size_of_val(out),
        "copy_from_bytes: length mismatch"
    );
    // SAFETY: Complex is POD and `out` has exactly bytes.len() bytes.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, bytes.len());
    }
}

/// Copy raw bytes back into a complex vector. Length must be a multiple of 16.
pub fn from_bytes(bytes: &[u8]) -> Vec<Complex> {
    assert_eq!(bytes.len() % std::mem::size_of::<Complex>(), 0);
    let n = bytes.len() / std::mem::size_of::<Complex>();
    let mut out = vec![ZERO; n];
    // SAFETY: out has exactly bytes.len() bytes of POD storage.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, bytes.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_basics() {
        let a = Complex::new(1.0, 2.0);
        let b = Complex::new(3.0, -1.0);
        assert_eq!(a + b, Complex::new(4.0, 1.0));
        assert_eq!(a - b, Complex::new(-2.0, 3.0));
        assert_eq!(a * b, Complex::new(5.0, 5.0));
        let q = (a / b) * b;
        assert!((q - a).abs() < 1e-12);
    }

    #[test]
    fn expi_is_unit_phasor() {
        for k in 0..16 {
            let t = k as f64 * std::f64::consts::PI / 8.0;
            let p = Complex::expi(t);
            assert!((p.abs() - 1.0).abs() < 1e-12);
        }
        let p = Complex::expi(std::f64::consts::PI / 2.0);
        assert!((p - Complex::new(0.0, 1.0)).abs() < 1e-12);
    }

    #[test]
    fn mul_i_matches_full_multiply() {
        let a = Complex::new(0.3, -0.7);
        assert!((a.mul_i() - a * Complex::new(0.0, 1.0)).abs() < 1e-15);
        assert!((a.mul_neg_i() - a * Complex::new(0.0, -1.0)).abs() < 1e-15);
    }

    #[test]
    fn byte_round_trip() {
        let v = vec![Complex::new(1.5, -2.5), Complex::new(0.0, 3.25)];
        let b = as_bytes(&v);
        let w = from_bytes(b);
        assert_eq!(v, w);
    }

    #[test]
    fn conj_and_norm() {
        let a = Complex::new(3.0, 4.0);
        assert_eq!(a.norm_sqr(), 25.0);
        assert_eq!(a.abs(), 5.0);
        assert_eq!(a.conj(), Complex::new(3.0, -4.0));
        assert!((a * a.conj()).im.abs() < 1e-15);
    }
}
