//! Local FFT substrate (pure rust, no external math crates).
//!
//! This is the node-local compute layer the distributed FFTB planner builds
//! on — the role cuFFT/FFTW play in the paper (§3.1 "Local Computation ...
//! abstractions are replaced with actual function calls from off-the-shelf
//! libraries"). It is also the oracle used to validate the Pallas/PJRT
//! artifact path.
//!
//! * [`complex`] — `Complex` arithmetic and raw-byte reinterpretation.
//! * [`dft`] — naive O(n^2) oracle + `Direction`.
//! * [`twiddle`] — cached twiddle tables.
//! * [`stockham`] — power-of-two Stockham autosort (radix 4/2).
//! * [`bluestein`] — arbitrary-length chirp-z.
//! * [`batch`] — unified plan + batched / strided application.
//! * [`nd`] — column-major multi-dimensional transforms + transposes.

pub mod batch;
pub mod bluestein;
pub mod complex;
pub mod dft;
pub mod nd;
pub mod real;
pub mod stockham;
pub mod twiddle;

pub use batch::{fft_flops, Fft1d, Fft1dRef};
pub use complex::{Complex, ONE, ZERO};
pub use dft::Direction;
pub use real::{irfft, rfft, rfft_batch};
pub use nd::{fft_2d, fft_3d, fft_dim, fft_nd, transpose_batch};
