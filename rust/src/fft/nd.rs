//! Local (single-rank) multi-dimensional FFTs over column-major tensors.
//!
//! Convention (paper §2.1): a tensor of shape `(n0, n1, n2)` stores element
//! `(i0, i1, i2)` at `i0 + n0*(i1 + n1*i2)` — dimension 0 fastest. These
//! routines are the single-node reference the distributed plans are tested
//! against, and the local compute backend used by the executor when no PJRT
//! artifact is loaded.

use super::batch::Fft1d;
use super::complex::{Complex, ZERO};
use super::dft::Direction;

/// FFT along one dimension of a column-major tensor, in place.
///
/// `shape` is the full tensor shape (any rank), `dim` the dimension to
/// transform. All other dimensions are batched over.
pub fn fft_dim(data: &mut [Complex], shape: &[usize], dim: usize, dir: Direction) {
    assert!(dim < shape.len());
    let total: usize = shape.iter().product();
    assert_eq!(data.len(), total);
    let n = shape[dim];
    if n <= 1 || total == 0 {
        if n == 1 || total == 0 {
            return;
        }
    }
    let plan = Fft1d::new(n, dir);
    let inner: usize = shape[..dim].iter().product(); // stride of `dim`
    let outer: usize = shape[dim + 1..].iter().product();
    let mut scratch = vec![ZERO; n + plan.scratch_len()];

    if inner == 1 {
        // Contiguous lines.
        for o in 0..outer {
            let start = o * n;
            plan.run_line(&mut data[start..start + n], &mut scratch[n..]);
        }
    } else {
        // Lines with stride `inner`; batch over the inner index within each
        // outer block.
        for o in 0..outer {
            let base = o * inner * n;
            plan.run_strided(data, base, 1, inner, inner, &mut scratch);
        }
    }
}

/// Full N-dimensional FFT (all dimensions), in place.
pub fn fft_nd(data: &mut [Complex], shape: &[usize], dir: Direction) {
    for dim in 0..shape.len() {
        fft_dim(data, shape, dim, dir);
    }
}

/// 3D FFT convenience wrapper.
pub fn fft_3d(data: &mut [Complex], shape: [usize; 3], dir: Direction) {
    fft_nd(data, &shape, dir);
}

/// 2D FFT convenience wrapper.
pub fn fft_2d(data: &mut [Complex], shape: [usize; 2], dir: Direction) {
    fft_nd(data, &shape, dir);
}

/// Out-of-place transpose of a column-major `(n0, n1)` matrix batch.
///
/// Input holds `batch` matrices of shape `(n0, n1)` back to back; output
/// holds the `(n1, n0)` transposes. Used by the executor to rotate tensor
/// dimensions so FFT lines become contiguous.
pub fn transpose_batch(
    input: &[Complex],
    output: &mut [Complex],
    n0: usize,
    n1: usize,
    batch: usize,
) {
    assert_eq!(input.len(), n0 * n1 * batch);
    assert_eq!(output.len(), n0 * n1 * batch);
    let mat = n0 * n1;
    // Blocked transpose for cache behaviour on large planes.
    const B: usize = 32;
    for m in 0..batch {
        let src = &input[m * mat..(m + 1) * mat];
        let dst = &mut output[m * mat..(m + 1) * mat];
        let mut i1b = 0;
        while i1b < n1 {
            let i1e = (i1b + B).min(n1);
            let mut i0b = 0;
            while i0b < n0 {
                let i0e = (i0b + B).min(n0);
                for i1 in i1b..i1e {
                    for i0 in i0b..i0e {
                        dst[i1 + n1 * i0] = src[i0 + n0 * i1];
                    }
                }
                i0b = i0e;
            }
            i1b = i1e;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::max_abs_diff;
    use crate::fft::dft::naive_dft_3d;

    fn phased(n: usize, seed: u64) -> Vec<Complex> {
        (0..n)
            .map(|i| {
                let t = (i as f64 * 0.17 + seed as f64) * 3.33;
                Complex::new(t.sin(), (0.7 * t).cos())
            })
            .collect()
    }

    #[test]
    fn fft_3d_matches_naive() {
        for shape in [[4usize, 4, 4], [8, 4, 2], [3, 5, 7], [16, 8, 4]] {
            let x = phased(shape.iter().product(), 9);
            let mut got = x.clone();
            fft_3d(&mut got, shape, Direction::Forward);
            let want = naive_dft_3d(&x, shape, Direction::Forward);
            assert!(
                max_abs_diff(&got, &want) < 1e-8 * (shape.iter().product::<usize>() as f64),
                "shape={shape:?}"
            );
        }
    }

    #[test]
    fn fft_3d_round_trip() {
        let shape = [8usize, 8, 8];
        let x = phased(512, 4);
        let mut y = x.clone();
        fft_3d(&mut y, shape, Direction::Forward);
        fft_3d(&mut y, shape, Direction::Inverse);
        assert!(max_abs_diff(&x, &y) < 1e-10);
    }

    #[test]
    fn transpose_round_trip() {
        let (n0, n1, b) = (5usize, 7usize, 3usize);
        let x = phased(n0 * n1 * b, 6);
        let mut t = vec![ZERO; x.len()];
        let mut back = vec![ZERO; x.len()];
        transpose_batch(&x, &mut t, n0, n1, b);
        transpose_batch(&t, &mut back, n1, n0, b);
        assert_eq!(x, back);
    }

    #[test]
    fn transpose_values() {
        // 2x3 column major: [a00 a10 | a01 a11 | a02 a12]
        let x: Vec<Complex> =
            (0..6).map(|i| Complex::new(i as f64, 0.0)).collect();
        let mut t = vec![ZERO; 6];
        transpose_batch(&x, &mut t, 2, 3, 1);
        // transposed is 3x2 column major: element (i1,i0) at i1 + 3*i0
        let want = [0.0, 2.0, 4.0, 1.0, 3.0, 5.0];
        for (v, w) in t.iter().zip(want) {
            assert_eq!(v.re, w);
        }
    }

    #[test]
    fn fft_dim_middle_dimension() {
        let shape = [4usize, 6, 3];
        let x = phased(shape.iter().product(), 12);
        let mut got = x.clone();
        fft_dim(&mut got, &shape, 1, Direction::Forward);
        // Oracle: gather each dim-1 line, naive DFT.
        let mut want = x.clone();
        for i2 in 0..shape[2] {
            for i0 in 0..shape[0] {
                let line: Vec<Complex> = (0..shape[1])
                    .map(|i1| x[i0 + shape[0] * (i1 + shape[1] * i2)])
                    .collect();
                let t = crate::fft::dft::naive_dft(&line, Direction::Forward);
                for i1 in 0..shape[1] {
                    want[i0 + shape[0] * (i1 + shape[1] * i2)] = t[i1];
                }
            }
        }
        assert!(max_abs_diff(&got, &want) < 1e-9);
    }
}
