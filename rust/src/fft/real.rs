//! Real-to-complex (RtoC) and complex-to-real transforms.
//!
//! Table 1 of the paper lists RtoC support as a distinguishing feature among
//! distributed FFT packages (FFTE, heFFTe, FFTX offer it; the paper's FFTB
//! is CtoC). Plane-wave densities and local potentials are real fields, so a
//! production FFTB would want this — we provide it as the natural extension,
//! using the classic two-for-one packing: a length-n real signal is folded
//! into a length-n/2 complex signal, one complex FFT runs, and the spectrum
//! is unpacked with a twiddle pass. Cost: one half-length complex FFT.
//!
//! Shape errors (odd or too-short lengths, wrong bin counts) surface as
//! [`FftbError::Shape`] rather than panics: these functions sit on the hot
//! path of the distributed r2c plan family, where pallas-lint's no-panic
//! rule applies.

use super::batch::Fft1d;
use super::complex::{Complex, ZERO};
use super::dft::Direction;
use super::twiddle::twiddles;
use crate::fftb::error::{FftbError, Result};

/// Forward RtoC: real input of even length `n` -> `n/2 + 1` complex bins
/// (the non-negative frequencies; the rest follow by conjugate symmetry).
///
/// Odd or too-short inputs (`n < 2`) are shape errors, not panics.
pub fn rfft(input: &[f64]) -> Result<Vec<Complex>> {
    let n = input.len();
    if n < 2 || n % 2 != 0 {
        return Err(FftbError::Shape(format!("rfft requires even length >= 2, got {n}")));
    }
    let h = n / 2;

    // Pack: z[k] = x[2k] + i x[2k+1].
    let mut z: Vec<Complex> =
        (0..h).map(|k| Complex::new(input[2 * k], input[2 * k + 1])).collect();
    Fft1d::new(h, Direction::Forward).run_batch_alloc(&mut z);

    // Unpack: X[k] = E[k] + e^{-2 pi i k / n} O[k] where
    // E[k] = (Z[k] + conj(Z[h-k]))/2, O[k] = (Z[k] - conj(Z[h-k]))/(2i).
    let tw = twiddles(n, Direction::Forward);
    let mut out = vec![ZERO; h + 1];
    for k in 0..=h {
        let zk = if k == h { z[0] } else { z[k] };
        let zc = z[(h - k) % h].conj();
        let e = (zk + zc).scale(0.5);
        let o = (zk - zc).scale(0.5).mul_neg_i();
        let w = if k == h { Complex::new(-1.0, 0.0) } else { tw[k] };
        out[k] = e + w * o;
    }
    Ok(out)
}

/// Inverse CtoR: `n/2 + 1` spectrum bins -> real signal of length `n`.
/// Inverse of [`rfft`] (including the 1/n normalization).
///
/// Odd or too-short `n`, or a spectrum that is not exactly `n/2 + 1` bins,
/// are shape errors, not panics.
pub fn irfft(spectrum: &[Complex], n: usize) -> Result<Vec<f64>> {
    if n < 2 || n % 2 != 0 {
        return Err(FftbError::Shape(format!("irfft requires even length >= 2, got {n}")));
    }
    if spectrum.len() != n / 2 + 1 {
        return Err(FftbError::Shape(format!(
            "irfft needs n/2+1 = {} bins for n = {n}, got {}",
            n / 2 + 1,
            spectrum.len()
        )));
    }
    let h = n / 2;

    // Re-pack: Z[k] = E[k] + i O[k] with E/O recovered from X.
    let tw = twiddles(n, Direction::Inverse); // e^{+2 pi i k / n}
    let mut z = vec![ZERO; h];
    for (k, zk) in z.iter_mut().enumerate() {
        let xk = spectrum[k];
        let xc = spectrum[h - k].conj();
        let e = (xk + xc).scale(0.5);
        let o = (xk - xc).scale(0.5) * tw[k];
        *zk = e + o.mul_i();
    }
    Fft1d::new(h, Direction::Inverse).run_batch_alloc(&mut z);

    let mut out = vec![0.0; n];
    for k in 0..h {
        out[2 * k] = z[k].re;
        out[2 * k + 1] = z[k].im;
    }
    Ok(out)
}

/// Batched RtoC over contiguous real lines.
///
/// `input.len()` must be a multiple of `n`; each length-`n` line transforms
/// independently into `n/2 + 1` bins.
pub fn rfft_batch(input: &[f64], n: usize) -> Result<Vec<Complex>> {
    if n == 0 || input.len() % n != 0 {
        return Err(FftbError::Shape(format!(
            "rfft_batch input length {} is not a multiple of line length {n}",
            input.len()
        )));
    }
    let mut out = Vec::with_capacity((input.len() / n) * (n / 2 + 1));
    for line in input.chunks_exact(n) {
        out.extend(rfft(line)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::naive_dft;

    fn reals(n: usize, seed: u64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64 + seed as f64) * 1.318).sin()).collect()
    }

    #[test]
    fn rfft_matches_complex_dft() {
        for n in [2usize, 4, 8, 16, 32, 64, 20, 36] {
            let x = reals(n, n as u64);
            let xc: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let want = naive_dft(&xc, Direction::Forward);
            let got = rfft(&x).unwrap();
            assert_eq!(got.len(), n / 2 + 1);
            for k in 0..=n / 2 {
                assert!(
                    (got[k] - want[k]).abs() < 1e-9 * n as f64,
                    "n={n} k={k}: {:?} vs {:?}",
                    got[k],
                    want[k]
                );
            }
        }
    }

    #[test]
    fn round_trip() {
        for n in [4usize, 8, 32, 48] {
            let x = reals(n, 3);
            let back = irfft(&rfft(&x).unwrap(), n).unwrap();
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn hermitian_symmetry_implicit() {
        // Bin 0 and bin n/2 of a real signal must be purely real.
        let x = reals(16, 7);
        let s = rfft(&x).unwrap();
        assert!(s[0].im.abs() < 1e-12);
        assert!(s[8].im.abs() < 1e-12);
    }

    #[test]
    fn batch_shape() {
        let x = reals(3 * 8, 1);
        let s = rfft_batch(&x, 8).unwrap();
        assert_eq!(s.len(), 3 * 5);
    }

    #[test]
    fn degenerate_lengths_are_shape_errors_not_panics() {
        // Fixtures for the panic-path fix: n in {0, 1, 3} must all come back
        // as FftbError::Shape (the previous assert! would abort the rank).
        for bad in [vec![], vec![1.0], vec![1.0, 2.0, 3.0]] {
            match rfft(&bad) {
                Err(FftbError::Shape(m)) => {
                    assert!(m.contains("even length"), "message: {m}");
                }
                other => panic!("rfft(len={}) returned {other:?}", bad.len()),
            }
        }
    }

    #[test]
    fn irfft_rejects_bad_shapes() {
        for n in [0usize, 1, 3] {
            assert!(matches!(irfft(&[ZERO; 4], n), Err(FftbError::Shape(_))), "n={n}");
        }
        // Right parity, wrong bin count.
        assert!(matches!(irfft(&[ZERO; 4], 8), Err(FftbError::Shape(_))));
    }

    #[test]
    fn batch_rejects_ragged_input() {
        assert!(matches!(rfft_batch(&reals(7, 0), 4), Err(FftbError::Shape(_))));
        assert!(matches!(rfft_batch(&reals(8, 0), 0), Err(FftbError::Shape(_))));
        // A valid multiple of an odd line length still fails per line.
        assert!(matches!(rfft_batch(&reals(9, 0), 3), Err(FftbError::Shape(_))));
    }
}
