//! Real-to-complex (RtoC) and complex-to-real transforms.
//!
//! Table 1 of the paper lists RtoC support as a distinguishing feature among
//! distributed FFT packages (FFTE, heFFTe, FFTX offer it; the paper's FFTB
//! is CtoC). Plane-wave densities and local potentials are real fields, so a
//! production FFTB would want this — we provide it as the natural extension,
//! using the classic two-for-one packing: a length-n real signal is folded
//! into a length-n/2 complex signal, one complex FFT runs, and the spectrum
//! is unpacked with a twiddle pass. Cost: one half-length complex FFT.

use super::batch::Fft1d;
use super::complex::{Complex, ZERO};
use super::dft::Direction;
use super::twiddle::twiddles;

/// Forward RtoC: real input of even length `n` -> `n/2 + 1` complex bins
/// (the non-negative frequencies; the rest follow by conjugate symmetry).
pub fn rfft(input: &[f64]) -> Vec<Complex> {
    let n = input.len();
    assert!(n >= 2 && n % 2 == 0, "rfft requires even length >= 2, got {n}");
    let h = n / 2;

    // Pack: z[k] = x[2k] + i x[2k+1].
    let mut z: Vec<Complex> =
        (0..h).map(|k| Complex::new(input[2 * k], input[2 * k + 1])).collect();
    Fft1d::new(h, Direction::Forward).run_batch_alloc(&mut z);

    // Unpack: X[k] = E[k] + e^{-2 pi i k / n} O[k] where
    // E[k] = (Z[k] + conj(Z[h-k]))/2, O[k] = (Z[k] - conj(Z[h-k]))/(2i).
    let tw = twiddles(n, Direction::Forward);
    let mut out = vec![ZERO; h + 1];
    for k in 0..=h {
        let zk = if k == h { z[0] } else { z[k] };
        let zc = z[(h - k) % h].conj();
        let e = (zk + zc).scale(0.5);
        let o = (zk - zc).scale(0.5).mul_neg_i();
        let w = if k == h { Complex::new(-1.0, 0.0) } else { tw[k] };
        out[k] = e + w * o;
    }
    out
}

/// Inverse CtoR: `n/2 + 1` spectrum bins -> real signal of length `n`.
/// Inverse of [`rfft`] (including the 1/n normalization).
pub fn irfft(spectrum: &[Complex], n: usize) -> Vec<f64> {
    assert_eq!(spectrum.len(), n / 2 + 1, "irfft needs n/2+1 bins");
    assert!(n >= 2 && n % 2 == 0);
    let h = n / 2;

    // Re-pack: Z[k] = E[k] + i O[k] with E/O recovered from X.
    let tw = twiddles(n, Direction::Inverse); // e^{+2 pi i k / n}
    let mut z = vec![ZERO; h];
    for (k, zk) in z.iter_mut().enumerate() {
        let xk = spectrum[k];
        let xc = spectrum[h - k].conj();
        let e = (xk + xc).scale(0.5);
        let o = (xk - xc).scale(0.5) * tw[k];
        *zk = e + o.mul_i();
    }
    Fft1d::new(h, Direction::Inverse).run_batch_alloc(&mut z);

    let mut out = vec![0.0; n];
    for k in 0..h {
        out[2 * k] = z[k].re;
        out[2 * k + 1] = z[k].im;
    }
    out
}

/// Batched RtoC over contiguous real lines.
pub fn rfft_batch(input: &[f64], n: usize) -> Vec<Complex> {
    assert_eq!(input.len() % n, 0);
    let mut out = Vec::with_capacity((input.len() / n) * (n / 2 + 1));
    for line in input.chunks_exact(n) {
        out.extend(rfft(line));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::naive_dft;

    fn reals(n: usize, seed: u64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64 + seed as f64) * 1.318).sin()).collect()
    }

    #[test]
    fn rfft_matches_complex_dft() {
        for n in [2usize, 4, 8, 16, 32, 64, 20, 36] {
            let x = reals(n, n as u64);
            let xc: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let want = naive_dft(&xc, Direction::Forward);
            let got = rfft(&x);
            assert_eq!(got.len(), n / 2 + 1);
            for k in 0..=n / 2 {
                assert!(
                    (got[k] - want[k]).abs() < 1e-9 * n as f64,
                    "n={n} k={k}: {:?} vs {:?}",
                    got[k],
                    want[k]
                );
            }
        }
    }

    #[test]
    fn round_trip() {
        for n in [4usize, 8, 32, 48] {
            let x = reals(n, 3);
            let back = irfft(&rfft(&x), n);
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn hermitian_symmetry_implicit() {
        // Bin 0 and bin n/2 of a real signal must be purely real.
        let x = reals(16, 7);
        let s = rfft(&x);
        assert!(s[0].im.abs() < 1e-12);
        assert!(s[8].im.abs() < 1e-12);
    }

    #[test]
    fn batch_shape() {
        let x = reals(3 * 8, 1);
        let s = rfft_batch(&x, 8);
        assert_eq!(s.len(), 3 * 5);
    }

    #[test]
    #[should_panic(expected = "even length")]
    fn odd_length_rejected() {
        rfft(&[1.0, 2.0, 3.0]);
    }
}
