//! Bluestein (chirp-z) FFT for arbitrary line lengths.
//!
//! Plane-wave DFT grids are not always powers of two (typical FFT grid
//! pickers use 2^a 3^b 5^c sizes); Bluestein re-expresses an arbitrary-`n`
//! DFT as a circular convolution of length `m >= 2n-1`, `m` a power of two,
//! which the Stockham path then handles. This keeps the local-FFT substrate
//! fully general without a mixed-radix codegen.

use std::sync::Arc;

use super::complex::{Complex, ZERO};
use super::dft::Direction;
use super::stockham::StockhamPlan;

/// Precomputed Bluestein plan for one `(n, direction)`.
pub struct BluesteinPlan {
    n: usize,
    dir: Direction,
    m: usize,
    /// Chirp `c[k] = exp(sign * i pi k^2 / n)` for `k in 0..n`.
    chirp: Vec<Complex>,
    /// Forward FFT (size m) of the zero-embedded conjugate chirp.
    kernel_hat: Arc<Vec<Complex>>,
    fwd: StockhamPlan,
    inv: StockhamPlan,
}

impl BluesteinPlan {
    pub fn new(n: usize, dir: Direction) -> Self {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let sign = dir.sign(); // -1 forward, +1 inverse
        // chirp[k] = exp(sign * i * pi * k^2 / n); reduce k^2 mod 2n to keep
        // the trig argument small (k^2 can overflow f64 precision otherwise).
        let chirp: Vec<Complex> = (0..n)
            .map(|k| {
                let k2 = (k * k) % (2 * n);
                Complex::expi(sign * std::f64::consts::PI * k2 as f64 / n as f64)
            })
            .collect();

        // Convolution kernel b[k] = conj(chirp[|k|]) embedded circularly.
        let mut b = vec![ZERO; m];
        for k in 0..n {
            let v = chirp[k].conj();
            b[k] = v;
            if k != 0 {
                b[m - k] = v;
            }
        }
        let fwd = StockhamPlan::new(m, Direction::Forward);
        let inv = StockhamPlan::new(m, Direction::Inverse);
        let mut scratch = vec![ZERO; m];
        fwd.run(&mut b, &mut scratch);
        BluesteinPlan { n, dir, m, chirp, kernel_hat: Arc::new(b), fwd, inv }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Scratch size needed by `run` (two m-buffers).
    pub fn scratch_len(&self) -> usize {
        2 * self.m
    }

    /// Transform one line in place. `scratch.len() >= self.scratch_len()`.
    pub fn run(&self, line: &mut [Complex], scratch: &mut [Complex]) {
        let (n, m) = (self.n, self.m);
        assert_eq!(line.len(), n);
        assert!(scratch.len() >= 2 * m);
        if n == 1 {
            return;
        }
        let (a, rest) = scratch.split_at_mut(m);
        let fft_scratch = &mut rest[..m];

        // a[k] = x[k] * chirp[k], zero-padded to m.
        for k in 0..n {
            a[k] = line[k] * self.chirp[k];
        }
        for v in a[n..].iter_mut() {
            *v = ZERO;
        }
        // Circular convolution with the kernel via the power-of-two FFT.
        self.fwd.run(a, fft_scratch);
        for (v, h) in a.iter_mut().zip(self.kernel_hat.iter()) {
            *v = *v * *h;
        }
        self.inv.run(a, fft_scratch);
        // y[l] = chirp[l] * conv[l]; inverse direction also scales by 1/n.
        let scale = if self.dir == Direction::Inverse { 1.0 / n as f64 } else { 1.0 };
        for l in 0..n {
            line[l] = (self.chirp[l] * a[l]).scale(scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::max_abs_diff;
    use crate::fft::dft::naive_dft;

    fn phased(n: usize, seed: u64) -> Vec<Complex> {
        (0..n)
            .map(|i| {
                let t = (i as f64 * 0.77 + seed as f64) * 1.91;
                Complex::new((2.0 * t).cos(), t.sin())
            })
            .collect()
    }

    fn check(n: usize, dir: Direction) {
        let x = phased(n, 11);
        let want = naive_dft(&x, dir);
        let plan = BluesteinPlan::new(n, dir);
        let mut got = x.clone();
        let mut scratch = vec![ZERO; plan.scratch_len()];
        plan.run(&mut got, &mut scratch);
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-8 * (n as f64).max(1.0), "n={n} dir={dir:?} err={err}");
    }

    #[test]
    fn matches_oracle_odd_and_composite() {
        for n in [1usize, 2, 3, 5, 6, 7, 9, 10, 12, 15, 17, 30, 48, 60, 100, 120, 125] {
            check(n, Direction::Forward);
            check(n, Direction::Inverse);
        }
    }

    #[test]
    fn round_trip_prime() {
        let n = 97;
        let x = phased(n, 1);
        let f = BluesteinPlan::new(n, Direction::Forward);
        let b = BluesteinPlan::new(n, Direction::Inverse);
        let mut y = x.clone();
        let mut scratch = vec![ZERO; f.scratch_len().max(b.scratch_len())];
        f.run(&mut y, &mut scratch);
        b.run(&mut y, &mut scratch);
        assert!(max_abs_diff(&x, &y) < 1e-9);
    }
}
