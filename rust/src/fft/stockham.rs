//! Iterative Stockham autosort FFT for power-of-two sizes.
//!
//! The Stockham formulation is the natural fit for this codebase: it is
//! out-of-place (ping-pong between two buffers), needs no bit-reversal pass,
//! and every stage is a unit-stride sweep — the same access pattern the
//! Pallas kernels use on the TPU side (`python/compile/kernels/stockham.py`),
//! so the rust substrate and the artifact path share an algorithm.
//!
//! The radix-4 path (added in the performance pass, see EXPERIMENTS.md §Perf)
//! halves the number of passes over the data; a single radix-2 stage fixes up
//! odd powers of two.

use std::sync::Arc;

use super::complex::Complex;
use super::dft::Direction;
use super::twiddle::twiddles;

/// Plan for a power-of-two Stockham FFT of one line length.
pub struct StockhamPlan {
    n: usize,
    dir: Direction,
    /// Full-size twiddle table `w_n^k`, indexed with stride per stage.
    table: Arc<Vec<Complex>>,
}

impl StockhamPlan {
    /// `n` must be a power of two (>= 1).
    pub fn new(n: usize, dir: Direction) -> Self {
        assert!(n.is_power_of_two(), "StockhamPlan requires a power-of-two size, got {n}");
        StockhamPlan { n, dir, table: twiddles(n.max(1), dir) }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn direction(&self) -> Direction {
        self.dir
    }

    /// Transform a single line in place; `scratch` must have length `n`.
    ///
    /// The inverse direction applies the conventional `1/n` scaling.
    pub fn run(&self, line: &mut [Complex], scratch: &mut [Complex]) {
        let n = self.n;
        assert_eq!(line.len(), n);
        assert!(scratch.len() >= n, "scratch too small: {} < {}", scratch.len(), n);
        if n == 1 {
            return;
        }

        // Ping-pong between `line` and `scratch`. `len` is the current
        // sub-transform length, `s` the number of interleaved sub-transforms
        // (the Stockham stride).
        let mut src_is_line = true;
        let mut len = n; // current DFT length handled by this stage
        let mut s = 1usize; // stride / batch of interleaved transforms

        // Radix-4 stages while the remaining length is divisible by 4.
        while len % 4 == 0 {
            {
                let (src, dst): (&[Complex], &mut [Complex]) = if src_is_line {
                    (&*line, &mut *scratch)
                } else {
                    (&*scratch, &mut *line)
                };
                self.radix4_stage(src, dst, len, s);
            }
            src_is_line = !src_is_line;
            len /= 4;
            s *= 4;
        }
        // One radix-2 stage if an odd power of two remains.
        while len % 2 == 0 {
            {
                let (src, dst): (&[Complex], &mut [Complex]) = if src_is_line {
                    (&*line, &mut *scratch)
                } else {
                    (&*scratch, &mut *line)
                };
                self.radix2_stage(src, dst, len, s);
            }
            src_is_line = !src_is_line;
            len /= 2;
            s *= 2;
        }
        debug_assert_eq!(len, 1);

        if !src_is_line {
            line.copy_from_slice(&scratch[..n]);
        }
        if self.dir == Direction::Inverse {
            let inv = 1.0 / n as f64;
            for v in line.iter_mut() {
                *v = v.scale(inv);
            }
        }
    }

    /// One radix-2 Stockham stage: `len`-point DFTs, `s` interleaved copies.
    #[inline]
    fn radix2_stage(&self, src: &[Complex], dst: &mut [Complex], len: usize, s: usize) {
        let m = len / 2;
        let tw_stride = self.n / len; // table is for size n
        for p in 0..m {
            let w = self.table[p * tw_stride];
            let src_a = p * s;
            let src_b = (p + m) * s;
            let dst_a = 2 * p * s;
            let dst_b = (2 * p + 1) * s;
            for q in 0..s {
                let a = src[src_a + q];
                let b = src[src_b + q];
                dst[dst_a + q] = a + b;
                dst[dst_b + q] = (a - b) * w;
            }
        }
    }

    /// One radix-4 Stockham stage (decimation in frequency).
    #[inline]
    fn radix4_stage(&self, src: &[Complex], dst: &mut [Complex], len: usize, s: usize) {
        let m = len / 4;
        let tw_stride = self.n / len;
        let forward = self.dir == Direction::Forward;
        for p in 0..m {
            let w1 = self.table[p * tw_stride];
            let w2 = self.table[2 * p * tw_stride];
            let w3 = self.table[3 * p * tw_stride];
            let s0 = p * s;
            let s1 = (p + m) * s;
            let s2 = (p + 2 * m) * s;
            let s3 = (p + 3 * m) * s;
            let d0 = 4 * p * s;
            let d1 = (4 * p + 1) * s;
            let d2 = (4 * p + 2) * s;
            let d3 = (4 * p + 3) * s;
            for q in 0..s {
                let a = src[s0 + q];
                let b = src[s1 + q];
                let c = src[s2 + q];
                let d = src[s3 + q];
                let apc = a + c;
                let amc = a - c;
                let bpd = b + d;
                // (b - d) * (-i) for forward, * (+i) for inverse.
                let bmd_i = if forward { (b - d).mul_neg_i() } else { (b - d).mul_i() };
                dst[d0 + q] = apc + bpd;
                dst[d1 + q] = (amc + bmd_i) * w1;
                dst[d2 + q] = (apc - bpd) * w2;
                dst[d3 + q] = (amc - bmd_i) * w3;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::{max_abs_diff, ZERO};
    use crate::fft::dft::naive_dft;

    fn phased(n: usize, seed: u64) -> Vec<Complex> {
        (0..n)
            .map(|i| {
                let t = (i as f64 + seed as f64 * 0.61) * 1.234;
                Complex::new(t.sin(), (0.9 * t).cos())
            })
            .collect()
    }

    fn check(n: usize, dir: Direction) {
        let x = phased(n, n as u64);
        let want = naive_dft(&x, dir);
        let plan = StockhamPlan::new(n, dir);
        let mut got = x.clone();
        let mut scratch = vec![ZERO; n];
        plan.run(&mut got, &mut scratch);
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-9 * (n as f64), "n={n} dir={dir:?} err={err}");
    }

    #[test]
    fn matches_oracle_all_pow2_up_to_1024() {
        for log_n in 0..=10 {
            check(1 << log_n, Direction::Forward);
            check(1 << log_n, Direction::Inverse);
        }
    }

    #[test]
    fn round_trip() {
        for n in [2usize, 8, 64, 256] {
            let x = phased(n, 5);
            let f = StockhamPlan::new(n, Direction::Forward);
            let b = StockhamPlan::new(n, Direction::Inverse);
            let mut y = x.clone();
            let mut scratch = vec![ZERO; n];
            f.run(&mut y, &mut scratch);
            b.run(&mut y, &mut scratch);
            assert!(max_abs_diff(&x, &y) < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        StockhamPlan::new(12, Direction::Forward);
    }
}
