//! Unified 1D FFT plan + batched / strided application.
//!
//! `Fft1d` picks the right algorithm for a line length (Stockham for powers
//! of two, Bluestein otherwise, direct evaluation for tiny sizes) and offers
//! the three application shapes the distributed executor needs:
//!
//! * contiguous batches of lines (the post-pack hot path),
//! * strided lines gathered through a scratch buffer (in-place dimension-1/2
//!   sweeps of column-major tensors),
//! * single lines.
//!
//! Plans are cheap to clone-share (`Arc` internals) and thread-safe; scratch
//! is caller-provided or thread-local so one plan serves many worker ranks.

use std::sync::Arc;

use super::bluestein::BluesteinPlan;
use super::complex::{Complex, ZERO};
use super::dft::{naive_dft, Direction};
use super::stockham::StockhamPlan;

enum Algo {
    /// Direct O(n^2) — only for n <= 4 where it beats plan overhead.
    Tiny,
    Stockham(StockhamPlan),
    Bluestein(BluesteinPlan),
}

/// A reusable 1D FFT plan for a fixed `(n, direction)`.
pub struct Fft1d {
    n: usize,
    dir: Direction,
    algo: Algo,
}

/// Shareable handle (the executor stores plans per stage).
pub type Fft1dRef = Arc<Fft1d>;

impl Fft1d {
    pub fn new(n: usize, dir: Direction) -> Self {
        assert!(n >= 1, "FFT length must be positive");
        let algo = if n <= 4 {
            Algo::Tiny
        } else if n.is_power_of_two() {
            Algo::Stockham(StockhamPlan::new(n, dir))
        } else {
            Algo::Bluestein(BluesteinPlan::new(n, dir))
        };
        Fft1d { n, dir, algo }
    }

    pub fn shared(n: usize, dir: Direction) -> Fft1dRef {
        Arc::new(Self::new(n, dir))
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn direction(&self) -> Direction {
        self.dir
    }

    /// Scratch length required by `run_line`.
    pub fn scratch_len(&self) -> usize {
        match &self.algo {
            Algo::Tiny => self.n,
            Algo::Stockham(_) => self.n,
            Algo::Bluestein(p) => p.scratch_len(),
        }
    }

    /// Transform a single contiguous line in place.
    pub fn run_line(&self, line: &mut [Complex], scratch: &mut [Complex]) {
        debug_assert_eq!(line.len(), self.n);
        match &self.algo {
            Algo::Tiny => {
                let out = naive_dft(line, self.dir);
                line.copy_from_slice(&out);
            }
            Algo::Stockham(p) => p.run(line, scratch),
            Algo::Bluestein(p) => p.run(line, scratch),
        }
    }

    /// Transform `batch` contiguous lines stored back to back.
    pub fn run_batch(&self, data: &mut [Complex], scratch: &mut [Complex]) {
        assert_eq!(data.len() % self.n, 0, "batch data not a multiple of n");
        for line in data.chunks_exact_mut(self.n) {
            self.run_line(line, scratch);
        }
    }

    /// Convenience: batch transform allocating scratch internally.
    pub fn run_batch_alloc(&self, data: &mut [Complex]) {
        let mut scratch = vec![ZERO; self.scratch_len()];
        self.run_batch(data, &mut scratch);
    }

    /// Transform `count` lines of length `n` that start at
    /// `base + j*line_offset` and step by `stride` between elements.
    ///
    /// Lines are gathered into a contiguous scratch line, transformed and
    /// scattered back. `scratch.len() >= n + scratch_len()`.
    pub fn run_strided(
        &self,
        data: &mut [Complex],
        base: usize,
        line_offset: usize,
        stride: usize,
        count: usize,
        scratch: &mut [Complex],
    ) {
        assert!(scratch.len() >= self.n + self.scratch_len());
        let (line, rest) = scratch.split_at_mut(self.n);
        for j in 0..count {
            let start = base + j * line_offset;
            for k in 0..self.n {
                line[k] = data[start + k * stride];
            }
            self.run_line(line, rest);
            for k in 0..self.n {
                data[start + k * stride] = line[k];
            }
        }
    }
}

/// Flop count of one complex FFT line of length n (5 n log2 n convention).
pub fn fft_flops(n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    5.0 * n as f64 * (n as f64).log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::max_abs_diff;
    use crate::fft::dft::naive_dft_batch;

    fn phased(n: usize, seed: u64) -> Vec<Complex> {
        (0..n)
            .map(|i| {
                let t = (i as f64 + 0.13 * seed as f64) * 2.7183;
                Complex::new(t.cos(), (0.31 * t).sin())
            })
            .collect()
    }

    #[test]
    fn batch_matches_oracle_mixed_sizes() {
        for n in [1usize, 2, 3, 4, 5, 8, 12, 16, 20, 32, 63, 64] {
            for dir in [Direction::Forward, Direction::Inverse] {
                let batch = 3;
                let x = phased(n * batch, n as u64);
                let want = naive_dft_batch(&x, n, dir);
                let plan = Fft1d::new(n, dir);
                let mut got = x.clone();
                plan.run_batch_alloc(&mut got);
                assert!(
                    max_abs_diff(&got, &want) < 1e-8 * (n as f64).max(1.0),
                    "n={n} dir={dir:?}"
                );
            }
        }
    }

    #[test]
    fn strided_equals_contiguous() {
        // Treat an (n0=8, n1=6) column-major matrix; FFT along dim 1
        // (stride n0) must match transposing + contiguous FFT.
        let (n0, n1) = (8usize, 6usize);
        let x = phased(n0 * n1, 2);
        let plan = Fft1d::new(n1, Direction::Forward);

        // Strided in place.
        let mut a = x.clone();
        let mut scratch = vec![ZERO; n1 + plan.scratch_len()];
        plan.run_strided(&mut a, 0, 1, n0, n0, &mut scratch);

        // Reference: gather rows, FFT, scatter.
        let mut b = x.clone();
        for i0 in 0..n0 {
            let mut line: Vec<Complex> = (0..n1).map(|i1| x[i0 + n0 * i1]).collect();
            plan.run_batch_alloc(&mut line);
            for i1 in 0..n1 {
                b[i0 + n0 * i1] = line[i1];
            }
        }
        assert!(max_abs_diff(&a, &b) < 1e-12);
    }

    #[test]
    fn flops_monotone() {
        assert_eq!(fft_flops(1), 0.0);
        assert!(fft_flops(64) > fft_flops(32));
    }
}
