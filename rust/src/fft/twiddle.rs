//! Twiddle-factor tables, cached process-wide.
//!
//! A table for size `n` holds `w_n^k = exp(sign * 2 pi i k / n)` for
//! `k in 0..n`. Tables are built once per `(n, direction)` and shared via
//! `Arc`, so repeated plan construction in the executor and the benches does
//! not re-run `sin_cos`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use super::complex::Complex;
use super::dft::Direction;

static CACHE: Lazy<Mutex<HashMap<(usize, bool), Arc<Vec<Complex>>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Build (or fetch from cache) the full twiddle table for size `n`.
pub fn twiddles(n: usize, dir: Direction) -> Arc<Vec<Complex>> {
    let key = (n, dir == Direction::Forward);
    if let Some(t) = CACHE.lock().unwrap().get(&key) {
        return Arc::clone(t);
    }
    let base = dir.sign() * 2.0 * std::f64::consts::PI / n as f64;
    let table: Vec<Complex> = (0..n).map(|k| Complex::expi(base * k as f64)).collect();
    let arc = Arc::new(table);
    CACHE.lock().unwrap().insert(key, Arc::clone(&arc));
    arc
}

/// Number of distinct tables currently cached (used by tests/metrics).
pub fn cache_len() -> usize {
    CACHE.lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_table_values() {
        let t = twiddles(4, Direction::Forward);
        assert!((t[0] - Complex::new(1.0, 0.0)).abs() < 1e-15);
        assert!((t[1] - Complex::new(0.0, -1.0)).abs() < 1e-15);
        assert!((t[2] - Complex::new(-1.0, 0.0)).abs() < 1e-15);
        assert!((t[3] - Complex::new(0.0, 1.0)).abs() < 1e-15);
    }

    #[test]
    fn inverse_is_conjugate() {
        let f = twiddles(16, Direction::Forward);
        let b = twiddles(16, Direction::Inverse);
        for k in 0..16 {
            assert!((f[k].conj() - b[k]).abs() < 1e-15);
        }
    }

    #[test]
    fn cache_is_shared() {
        let a = twiddles(32, Direction::Forward);
        let b = twiddles(32, Direction::Forward);
        assert!(Arc::ptr_eq(&a, &b));
    }
}
