//! Naive `O(n^2)` discrete Fourier transform — the correctness oracle.
//!
//! Every fast path in this repository (Stockham, Bluestein, the batched and
//! distributed variants, and the Pallas/PJRT artifacts) is validated against
//! this direct evaluation of Eq. (2)/(3) of the paper:
//! `y[l] = sum_k  x[k] * w_n^{l k}`, `w_n = exp(-2 pi i / n)`.

use super::complex::{Complex, ZERO};

/// Transform direction. `Forward` uses the `exp(-2 pi i / n)` kernel (the
/// paper's convention and numpy's); `Inverse` conjugates it and scales the
/// result by `1/n` so that `idft(dft(x)) == x`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    Forward,
    Inverse,
}

impl Direction {
    /// Sign of the exponent: -1 forward, +1 inverse.
    #[inline]
    pub fn sign(self) -> f64 {
        match self {
            Direction::Forward => -1.0,
            Direction::Inverse => 1.0,
        }
    }

    pub fn flip(self) -> Self {
        match self {
            Direction::Forward => Direction::Inverse,
            Direction::Inverse => Direction::Forward,
        }
    }
}

/// Direct `O(n^2)` DFT of a single line.
pub fn naive_dft(input: &[Complex], dir: Direction) -> Vec<Complex> {
    let n = input.len();
    if n == 0 {
        return Vec::new();
    }
    let base = dir.sign() * 2.0 * std::f64::consts::PI / n as f64;
    let mut out = vec![ZERO; n];
    for (l, o) in out.iter_mut().enumerate() {
        let mut acc = ZERO;
        for (k, &x) in input.iter().enumerate() {
            // Reduce l*k mod n before the trig call: keeps the argument small
            // and the oracle accurate for large n.
            let lk = (l * k) % n;
            acc += x * Complex::expi(base * lk as f64);
        }
        *o = acc;
    }
    if dir == Direction::Inverse {
        let s = 1.0 / n as f64;
        for o in out.iter_mut() {
            *o = o.scale(s);
        }
    }
    out
}

/// Naive DFT applied independently to `batch` contiguous lines of length `n`.
pub fn naive_dft_batch(input: &[Complex], n: usize, dir: Direction) -> Vec<Complex> {
    assert!(n > 0 && input.len() % n == 0, "batch input must be a multiple of n");
    let mut out = Vec::with_capacity(input.len());
    for line in input.chunks_exact(n) {
        out.extend(naive_dft(line, dir));
    }
    out
}

/// Naive 3D DFT on a column-major tensor of shape `(n0, n1, n2)` —
/// `index(i0,i1,i2) = i0 + n0*(i1 + n1*i2)`, `i0` fastest (the paper's
/// storage convention, Section 2.1).
pub fn naive_dft_3d(input: &[Complex], shape: [usize; 3], dir: Direction) -> Vec<Complex> {
    let [n0, n1, n2] = shape;
    assert_eq!(input.len(), n0 * n1 * n2);
    let mut data = input.to_vec();

    // Dim 0: contiguous lines.
    for c in 0..n1 * n2 {
        let line: Vec<Complex> = data[c * n0..(c + 1) * n0].to_vec();
        data[c * n0..(c + 1) * n0].copy_from_slice(&naive_dft(&line, dir));
    }
    // Dim 1: stride n0.
    let mut line = vec![ZERO; n1];
    for i2 in 0..n2 {
        for i0 in 0..n0 {
            for i1 in 0..n1 {
                line[i1] = data[i0 + n0 * (i1 + n1 * i2)];
            }
            let t = naive_dft(&line, dir);
            for i1 in 0..n1 {
                data[i0 + n0 * (i1 + n1 * i2)] = t[i1];
            }
        }
    }
    // Dim 2: stride n0*n1.
    let mut line = vec![ZERO; n2];
    for i1 in 0..n1 {
        for i0 in 0..n0 {
            for i2 in 0..n2 {
                line[i2] = data[i0 + n0 * (i1 + n1 * i2)];
            }
            let t = naive_dft(&line, dir);
            for i2 in 0..n2 {
                data[i0 + n0 * (i1 + n1 * i2)] = t[i2];
            }
        }
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::max_abs_diff;

    fn phased(n: usize, seed: u64) -> Vec<Complex> {
        // Deterministic quasi-random data without a rand dependency.
        (0..n)
            .map(|i| {
                let t = (i as f64 + seed as f64 * 0.37) * 2.39996;
                Complex::new(t.sin(), (1.7 * t).cos())
            })
            .collect()
    }

    #[test]
    fn dft_of_delta_is_ones() {
        let mut x = vec![ZERO; 8];
        x[0] = Complex::new(1.0, 0.0);
        let y = naive_dft(&x, Direction::Forward);
        for v in y {
            assert!((v - Complex::new(1.0, 0.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn dft_of_constant_is_delta() {
        let x = vec![Complex::new(1.0, 0.0); 8];
        let y = naive_dft(&x, Direction::Forward);
        assert!((y[0] - Complex::new(8.0, 0.0)).abs() < 1e-12);
        for v in &y[1..] {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn inverse_round_trip() {
        for n in [1usize, 2, 3, 5, 8, 12, 16] {
            let x = phased(n, n as u64);
            let y = naive_dft(&x, Direction::Forward);
            let z = naive_dft(&y, Direction::Inverse);
            assert!(max_abs_diff(&x, &z) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn parseval() {
        let n = 16;
        let x = phased(n, 3);
        let y = naive_dft(&x, Direction::Forward);
        let ex: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let ey: f64 = y.iter().map(|v| v.norm_sqr()).sum::<f64>() / n as f64;
        assert!((ex - ey).abs() < 1e-9 * ex.max(1.0));
    }

    #[test]
    fn pure_tone_lands_in_one_bin() {
        let n = 16;
        let k = 3;
        let x: Vec<Complex> = (0..n)
            .map(|i| Complex::expi(2.0 * std::f64::consts::PI * (k * i) as f64 / n as f64))
            .collect();
        let y = naive_dft(&x, Direction::Forward);
        for (l, v) in y.iter().enumerate() {
            if l == k {
                assert!((v.abs() - n as f64).abs() < 1e-9);
            } else {
                assert!(v.abs() < 1e-9, "leakage at bin {l}");
            }
        }
    }

    #[test]
    fn dft_3d_separable_round_trip() {
        let shape = [4usize, 3, 5];
        let x = phased(shape.iter().product(), 7);
        let y = naive_dft_3d(&x, shape, Direction::Forward);
        let z = naive_dft_3d(&y, shape, Direction::Inverse);
        assert!(max_abs_diff(&x, &z) < 1e-10);
    }

    #[test]
    fn dft_3d_matches_dimension_order_independence() {
        // 3D DFT of a separable product equals product of 1D DFTs.
        let (n0, n1, n2) = (4usize, 4, 4);
        let a = phased(n0, 1);
        let b = phased(n1, 2);
        let c = phased(n2, 3);
        let mut x = vec![ZERO; n0 * n1 * n2];
        for i2 in 0..n2 {
            for i1 in 0..n1 {
                for i0 in 0..n0 {
                    x[i0 + n0 * (i1 + n1 * i2)] = a[i0] * b[i1] * c[i2];
                }
            }
        }
        let y = naive_dft_3d(&x, [n0, n1, n2], Direction::Forward);
        let fa = naive_dft(&a, Direction::Forward);
        let fb = naive_dft(&b, Direction::Forward);
        let fc = naive_dft(&c, Direction::Forward);
        for i2 in 0..n2 {
            for i1 in 0..n1 {
                for i0 in 0..n0 {
                    let want = fa[i0] * fb[i1] * fc[i2];
                    let got = y[i0 + n0 * (i1 + n1 * i2)];
                    assert!((want - got).abs() < 1e-9);
                }
            }
        }
    }
}
