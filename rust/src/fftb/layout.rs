//! Layout strings — the paper's tensor-distribution notation (Fig. 6/8).
//!
//! A layout string lists the tensor dimensions *in memory order, fastest
//! first* (the paper stores column-major; `"b x{0} y z"` means the batch
//! dimension is fastest, then `x` — distributed over grid axis 0 — then `y`,
//! then `z`). A trailing `{k}` marks elemental-cyclic distribution over
//! grid axis `k`; dimensions without a marker are fully local.

use super::error::{FftbError, Result};

/// One dimension of a layout: its name and optional grid-axis mapping.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DimSpec {
    pub name: String,
    pub grid_axis: Option<usize>,
}

/// Parsed layout string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Layout {
    pub dims: Vec<DimSpec>,
}

impl Layout {
    /// Parse `"b x{0} y z"` style strings.
    pub fn parse(s: &str) -> Result<Layout> {
        let mut dims = Vec::new();
        for tok in s.split_whitespace() {
            let (name, axis) = if let Some(open) = tok.find('{') {
                if !tok.ends_with('}') {
                    return Err(FftbError::Layout(format!("malformed token `{tok}`")));
                }
                let name = &tok[..open];
                let axis_str = &tok[open + 1..tok.len() - 1];
                let axis: usize = axis_str.parse().map_err(|_| {
                    FftbError::Layout(format!("bad grid axis `{axis_str}` in `{tok}`"))
                })?;
                (name, Some(axis))
            } else {
                (tok, None)
            };
            if name.is_empty() {
                return Err(FftbError::Layout(format!("empty dimension name in `{tok}`")));
            }
            if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(FftbError::Layout(format!("invalid dimension name `{name}`")));
            }
            if dims.iter().any(|d: &DimSpec| d.name == name) {
                return Err(FftbError::Layout(format!("duplicate dimension `{name}`")));
            }
            dims.push(DimSpec { name: name.to_string(), grid_axis: axis });
        }
        if dims.is_empty() {
            return Err(FftbError::Layout("layout string has no dimensions".into()));
        }
        // No two dimensions may share a grid axis.
        let mut seen = Vec::new();
        for d in &dims {
            if let Some(a) = d.grid_axis {
                if seen.contains(&a) {
                    return Err(FftbError::Layout(format!(
                        "grid axis {a} used by more than one dimension"
                    )));
                }
                seen.push(a);
            }
        }
        Ok(Layout { dims })
    }

    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Index of a dimension by name.
    pub fn find(&self, name: &str) -> Option<usize> {
        self.dims.iter().position(|d| d.name == name)
    }

    /// Names in memory order.
    pub fn names(&self) -> Vec<&str> {
        self.dims.iter().map(|d| d.name.as_str()).collect()
    }

    /// Distributed dimensions as `(dim_index, grid_axis)` pairs.
    pub fn distributed(&self) -> Vec<(usize, usize)> {
        self.dims
            .iter()
            .enumerate()
            .filter_map(|(i, d)| d.grid_axis.map(|a| (i, a)))
            .collect()
    }

    /// Render back to the string form.
    pub fn to_string_form(&self) -> String {
        self.dims
            .iter()
            .map(|d| match d.grid_axis {
                Some(a) => format!("{}{{{}}}", d.name, a),
                None => d.name.clone(),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple() {
        let l = Layout::parse("x{0} y z").unwrap();
        assert_eq!(l.ndim(), 3);
        assert_eq!(l.dims[0], DimSpec { name: "x".into(), grid_axis: Some(0) });
        assert_eq!(l.dims[1], DimSpec { name: "y".into(), grid_axis: None });
        assert_eq!(l.distributed(), vec![(0, 0)]);
    }

    #[test]
    fn parse_batched_planewave() {
        let l = Layout::parse("b x{0} y z").unwrap();
        assert_eq!(l.names(), vec!["b", "x", "y", "z"]);
        assert_eq!(l.find("y"), Some(2));
    }

    #[test]
    fn parse_two_axes() {
        let l = Layout::parse("x y{0} z{1}").unwrap();
        assert_eq!(l.distributed(), vec![(1, 0), (2, 1)]);
    }

    #[test]
    fn round_trip_string() {
        for s in ["x{0} y z", "b x y{1} z{0}", "X Y Z{0}"] {
            assert_eq!(Layout::parse(s).unwrap().to_string_form(), s);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(Layout::parse("").is_err());
        assert!(Layout::parse("x{").is_err());
        assert!(Layout::parse("x{a}").is_err());
        assert!(Layout::parse("x x").is_err());
        assert!(Layout::parse("x{0} y{0}").is_err());
        assert!(Layout::parse("x-y").is_err());
    }
}
