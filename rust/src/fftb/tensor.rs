//! Distributed tensors (paper §3.2, Fig. 6 line 11 / Fig. 8 line 19):
//! a domain list + a layout string + a processing grid, plus this rank's
//! local slice of the data.

use std::sync::Arc;

use super::domain::DomainList;
use super::error::{FftbError, Result};
use super::grid::{cyclic, ProcGrid};
use super::layout::Layout;
use crate::fft::complex::{Complex, ZERO};

/// A distributed tensor descriptor + this rank's local buffer.
///
/// Global element `(g_0, ..., g_{k-1})` (dimension order = layout order,
/// first fastest in memory) lives on the rank whose grid coordinate on each
/// distributed axis equals `g_i % grid.dims[axis]`, at local index
/// `g_i / grid.dims[axis]` (elemental cyclic). Tensors with an offset array
/// store only the sphere points (packed, see `sphere::OffsetArray`).
#[derive(Clone)]
pub struct DistTensor {
    pub domains: DomainList,
    pub layout: Layout,
    pub grid: Arc<ProcGrid>,
    /// Local data slice (dense tensors: column-major local box; sphere
    /// tensors: packed coefficients of the locally-owned columns).
    pub local: Vec<Complex>,
}

impl DistTensor {
    /// Create a zero-initialized distributed tensor (the `tensor ti = ...`
    /// constructor of Fig. 6/8).
    pub fn zeros(domains: DomainList, layout_str: &str, grid: Arc<ProcGrid>) -> Result<Self> {
        let layout = Layout::parse(layout_str)?;
        if layout.ndim() != domains.rank() {
            return Err(FftbError::Shape(format!(
                "layout `{}` has {} dims but domains have rank {}",
                layout.to_string_form(),
                layout.ndim(),
                domains.rank()
            )));
        }
        for (_, axis) in layout.distributed() {
            if axis >= grid.ndim() {
                return Err(FftbError::Grid(format!(
                    "layout references grid axis {axis} but grid is {}D",
                    grid.ndim()
                )));
            }
        }
        let n = Self::local_len(&domains, &layout, &grid)?;
        Ok(DistTensor { domains, layout, grid, local: vec![ZERO; n] })
    }

    /// Local extent of each dimension (dense part; sphere tensors return the
    /// bounding-box extents with the compressed dimension reported as the
    /// *packed* total divided across columns — use `local_len` for storage).
    pub fn local_extents(&self) -> Vec<usize> {
        Self::extents_on(&self.domains, &self.layout, &self.grid)
    }

    fn extents_on(domains: &DomainList, layout: &Layout, grid: &ProcGrid) -> Vec<usize> {
        let glob = domains.extents();
        layout
            .dims
            .iter()
            .zip(glob)
            .map(|(d, n)| match d.grid_axis {
                Some(a) => cyclic::local_count(n, grid.axis_len(a), grid.axis_coord(a)),
                None => n,
            })
            .collect()
    }

    /// Number of locally stored elements.
    pub fn local_len(domains: &DomainList, layout: &Layout, grid: &ProcGrid) -> Result<usize> {
        match domains.offsets() {
            None => Ok(Self::extents_on(domains, layout, grid).iter().product()),
            Some(off) => {
                // Sphere tensors: supported distribution is over the x
                // dimension (or fully local). Batch dims are dense.
                let dist = layout.distributed();
                if dist.len() > 1 {
                    return Err(FftbError::Unsupported(
                        "sphere tensors support at most one distributed dimension".into(),
                    ));
                }
                // Dense (non-offset) dims contribute their full extent; the
                // sphere contributes its packed local total.
                let mut dense: usize = 1;
                for part in &domains.parts {
                    if part.offsets.is_none() {
                        dense *= part.volume();
                    }
                }
                match dist.first() {
                    None => Ok(dense * off.total()),
                    Some(&(dim, axis)) => {
                        // The distributed dim must be the sphere's x.
                        let name = &layout.dims[dim].name;
                        if name != "x" {
                            return Err(FftbError::Unsupported(format!(
                                "sphere tensors must distribute `x`, got `{name}`"
                            )));
                        }
                        let p = grid.axis_len(axis);
                        let r = grid.axis_coord(axis);
                        Ok(dense * off.restrict_x_cyclic(p, r).total())
                    }
                }
            }
        }
    }

    /// Global extents in layout order.
    pub fn global_extents(&self) -> Vec<usize> {
        self.domains.extents()
    }

    /// Does this tensor carry sphere offsets?
    pub fn is_sphere(&self) -> bool {
        self.domains.offsets().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::domain::Domain;
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    #[test]
    fn dense_tensor_local_extents() {
        let outs = run_world(4, |comm| {
            let grid = ProcGrid::new(&[4], comm).unwrap();
            let d = Domain::new(vec![0, 0, 0], vec![15, 15, 15]).unwrap();
            let t = DistTensor::zeros(
                DomainList::new(vec![d]).unwrap(),
                "x{0} y z",
                grid,
            )
            .unwrap();
            (t.local_extents(), t.local.len())
        });
        for (ext, len) in outs {
            assert_eq!(ext, vec![4, 16, 16]);
            assert_eq!(len, 4 * 16 * 16);
        }
    }

    #[test]
    fn uneven_cyclic_extents() {
        let outs = run_world(3, |comm| {
            let grid = ProcGrid::new(&[3], comm).unwrap();
            let d = Domain::new(vec![0, 0, 0], vec![6, 4, 4]).unwrap(); // 7x5x5
            let t = DistTensor::zeros(DomainList::new(vec![d]).unwrap(), "x{0} y z", grid)
                .unwrap();
            t.local_extents()[0]
        });
        assert_eq!(outs, vec![3, 2, 2]); // 7 = 3+2+2 cyclic
    }

    #[test]
    fn sphere_tensor_partitions_points() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
        let total = spec.offsets().total();
        let outs = run_world(2, move |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let off = Arc::new(spec.offsets());
            let b = Domain::new(vec![0], vec![3]).unwrap();
            let c = Domain::with_offsets(vec![0, 0, 0], vec![7, 7, 7], off).unwrap();
            let t = DistTensor::zeros(
                DomainList::new(vec![b, c]).unwrap(),
                "b x{0} y z",
                grid,
            )
            .unwrap();
            t.local.len()
        });
        assert_eq!(outs.iter().sum::<usize>(), 4 * total);
    }

    #[test]
    fn layout_rank_mismatch_rejected() {
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let d = Domain::new(vec![0, 0, 0], vec![7, 7, 7]).unwrap();
            let r = DistTensor::zeros(DomainList::new(vec![d]).unwrap(), "x y", grid);
            assert!(r.is_err());
        });
    }

    #[test]
    fn bad_grid_axis_rejected() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let d = Domain::new(vec![0, 0, 0], vec![7, 7, 7]).unwrap();
            let r = DistTensor::zeros(DomainList::new(vec![d]).unwrap(), "x{1} y z", grid);
            assert!(r.is_err());
        });
    }
}
