//! Bound domains — the paper's way of describing tensor extents
//! (§3.2: "Each domain is defined by specifying the points corresponding to
//! opposite corners of cuboid volume"; §3.3 adds an optional offset array
//! for sphere data, Fig. 8 line 18).

use std::sync::Arc;

use super::error::{FftbError, Result};
use super::sphere::OffsetArray;

/// A bounded (hyper-)rectangular domain `[lower, upper]` (inclusive corners,
/// like the C++ snippets in Fig. 6), optionally carrying a CSR offset array
/// that restricts the last dimension (the compressed z of Fig. 7).
#[derive(Clone, Debug)]
pub struct Domain {
    pub lower: Vec<i64>,
    pub upper: Vec<i64>,
    pub offsets: Option<Arc<OffsetArray>>,
}

impl Domain {
    /// Plain cuboid domain.
    pub fn new(lower: Vec<i64>, upper: Vec<i64>) -> Result<Domain> {
        if lower.len() != upper.len() || lower.is_empty() {
            return Err(FftbError::Shape("domain corners must have equal, nonzero rank".into()));
        }
        for (l, u) in lower.iter().zip(&upper) {
            if l > u {
                return Err(FftbError::Shape(format!("domain lower {l} > upper {u}")));
            }
        }
        Ok(Domain { lower, upper, offsets: None })
    }

    /// Cuboid domain with a CSR offset array restricting the z dimension
    /// (Fig. 8 line 18: `domain(point_in_lower, point_in_upper, offsets)`).
    pub fn with_offsets(
        lower: Vec<i64>,
        upper: Vec<i64>,
        offsets: Arc<OffsetArray>,
    ) -> Result<Domain> {
        let d = Domain::new(lower, upper)?;
        if d.rank() != 3 {
            return Err(FftbError::Shape("offset arrays require a 3D domain".into()));
        }
        let ext = d.extents();
        if offsets.nx != ext[0] || offsets.ny != ext[1] || offsets.nz != ext[2] {
            return Err(FftbError::Shape(format!(
                "offset array grid ({}, {}, {}) does not match domain extents {:?}",
                offsets.nx, offsets.ny, offsets.nz, ext
            )));
        }
        Ok(Domain { offsets: Some(offsets), ..d })
    }

    pub fn rank(&self) -> usize {
        self.lower.len()
    }

    /// Extent (number of points) along each dimension.
    pub fn extents(&self) -> Vec<usize> {
        self.lower.iter().zip(&self.upper).map(|(l, u)| (u - l + 1) as usize).collect()
    }

    /// Total points of the *bounding box*.
    pub fn volume(&self) -> usize {
        self.extents().iter().product()
    }

    /// Points actually stored: the offset-array total if present, else the
    /// full box.
    pub fn stored_points(&self) -> usize {
        match &self.offsets {
            Some(off) => off.total(),
            None => self.volume(),
        }
    }
}

/// Cross product of component domains (Fig. 8: `dom_in` is a vector of
/// domains, "a larger domain obtained as a cross product between the
/// composing domains"; order = memory order, first fastest).
#[derive(Clone, Debug)]
pub struct DomainList {
    pub parts: Vec<Domain>,
}

impl DomainList {
    pub fn new(parts: Vec<Domain>) -> Result<DomainList> {
        if parts.is_empty() {
            return Err(FftbError::Shape("empty domain list".into()));
        }
        if parts.iter().filter(|d| d.offsets.is_some()).count() > 1 {
            return Err(FftbError::Shape("at most one component may carry offsets".into()));
        }
        Ok(DomainList { parts })
    }

    /// Dimension extents flattened in memory order.
    pub fn extents(&self) -> Vec<usize> {
        self.parts.iter().flat_map(|d| d.extents()).collect()
    }

    pub fn rank(&self) -> usize {
        self.parts.iter().map(|d| d.rank()).sum()
    }

    /// The offset array, if any component carries one.
    pub fn offsets(&self) -> Option<&Arc<OffsetArray>> {
        self.parts.iter().find_map(|d| d.offsets.as_ref())
    }

    /// Stored points of the whole cross product.
    pub fn stored_points(&self) -> usize {
        self.parts.iter().map(|d| d.stored_points()).product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    #[test]
    fn extents_inclusive_corners() {
        let d = Domain::new(vec![0, 0, 0], vec![255, 255, 255]).unwrap();
        assert_eq!(d.extents(), vec![256, 256, 256]);
        assert_eq!(d.volume(), 256 * 256 * 256);
    }

    #[test]
    fn rejects_inverted_corners() {
        assert!(Domain::new(vec![0, 5], vec![10, 3]).is_err());
        assert!(Domain::new(vec![], vec![]).is_err());
    }

    #[test]
    fn offsets_must_match_extents() {
        let s = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
        let off = Arc::new(s.offsets());
        assert!(Domain::with_offsets(vec![0, 0, 0], vec![7, 7, 7], off.clone()).is_ok());
        assert!(Domain::with_offsets(vec![0, 0, 0], vec![15, 7, 7], off).is_err());
    }

    #[test]
    fn cross_product_batch_plus_cube() {
        // Fig. 8: batch domain [0,128] x 3D grid domain.
        let b = Domain::new(vec![0], vec![127]).unwrap();
        let c = Domain::new(vec![0, 0, 0], vec![63, 63, 63]).unwrap();
        let dl = DomainList::new(vec![b, c]).unwrap();
        assert_eq!(dl.extents(), vec![128, 64, 64, 64]);
        assert_eq!(dl.rank(), 4);
        assert_eq!(dl.stored_points(), 128 * 64 * 64 * 64);
    }

    #[test]
    fn stored_points_uses_offsets() {
        let s = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
        let total = s.offsets().total();
        let off = Arc::new(s.offsets());
        let b = Domain::new(vec![0], vec![3]).unwrap();
        let c = Domain::with_offsets(vec![0, 0, 0], vec![7, 7, 7], off).unwrap();
        let dl = DomainList::new(vec![b, c]).unwrap();
        assert_eq!(dl.stored_points(), 4 * total);
    }
}
