//! Cut-off spheres and the CSR-like offset array (paper §2.2/§3.3, Fig. 7).
//!
//! Plane-wave wavefunctions keep only the Fourier coefficients with
//! `|g|^2 / 2 <= E_cut` (Eq. 9). Projecting the retained points onto the
//! xy-plane gives, for every `(x, y)` column, a small set of contiguous
//! z-runs — "like a Compressed Sparse Row format because only the z
//! dimension is compressed, while the x and y dimensions are kept as dense"
//! (paper §3.3). `OffsetArray` is that structure; `SphereSpec` builds it for
//! the two sphere conventions used in practice:
//!
//! * `Centered` — the literal sphere of Fig. 2/7, centered in the box
//!   (each column is one contiguous run);
//! * `Wrapped` — the physical G-space convention where negative frequencies
//!   wrap to the top of the grid (up to two runs per column).

use super::grid::cyclic;
use crate::fft::complex::{Complex, ZERO};

/// Sphere placement convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SphereKind {
    Centered,
    Wrapped,
}

/// One contiguous z-run: `z0..z0+len`.
pub type Run = (u32, u32);

/// CSR-like projection of a sphere onto the xy-plane (Fig. 7).
///
/// Columns are indexed `c = x + nx*y`. `col_ptr[c]..col_ptr[c+1]` indexes
/// `runs`; `col_elem[c]` is the element offset of column `c` in the packed
/// coefficient vector (elements ordered column-by-column, z ascending within
/// a column).
#[derive(Clone, Debug)]
pub struct OffsetArray {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    col_ptr: Vec<u32>,
    runs: Vec<Run>,
    col_elem: Vec<u64>,
    /// Structural fingerprint, computed once at construction.
    print: u64,
}

impl OffsetArray {
    /// Build from a per-column list of z-runs (must be sorted, non-adjacent).
    pub fn from_runs(nx: usize, ny: usize, nz: usize, per_col: Vec<Vec<Run>>) -> Self {
        assert_eq!(per_col.len(), nx * ny);
        let mut col_ptr = Vec::with_capacity(nx * ny + 1);
        let mut col_elem = Vec::with_capacity(nx * ny + 1);
        let mut runs = Vec::new();
        let mut elems = 0u64;
        col_ptr.push(0);
        col_elem.push(0);
        for col in &per_col {
            let mut last_end: i64 = -1;
            for &(z0, len) in col {
                assert!(len > 0, "empty run");
                assert!((z0 as usize) + (len as usize) <= nz, "run exceeds nz");
                assert!(z0 as i64 > last_end, "runs must be sorted and non-adjacent");
                last_end = z0 as i64 + len as i64 - 1;
                elems += len as u64;
                runs.push((z0, len));
            }
            col_ptr.push(runs.len() as u32);
            col_elem.push(elems);
        }
        // Structural fingerprint over extents, column pointers and runs,
        // computed once here so key construction is O(1) per request.
        let mut print =
            crate::util::fnv::fnv1a_words([nx as u64, ny as u64, nz as u64]);
        for &ptr in &col_ptr {
            print = crate::util::fnv::fnv1a_word(print, ptr as u64);
        }
        for &(z0, len) in &runs {
            print = crate::util::fnv::fnv1a_word(print, ((z0 as u64) << 32) | len as u64);
        }
        OffsetArray { nx, ny, nz, col_ptr, runs, col_elem, print }
    }

    /// Total number of retained points.
    pub fn total(&self) -> usize {
        self.col_elem.last().copied().unwrap_or(0) as usize
    }

    /// Order-sensitive FNV-1a fingerprint of the full run structure (grid
    /// extents, column pointers, z-runs). Two offset arrays describing
    /// different spheres practically never collide, even when they retain
    /// the same number of points — the tuner keys its plan cache and
    /// wisdom entries with this.
    pub fn fingerprint(&self) -> u64 {
        self.print
    }

    /// z-runs of column `(x, y)`.
    pub fn col_runs(&self, x: usize, y: usize) -> &[Run] {
        let c = x + self.nx * y;
        &self.runs[self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize]
    }

    /// Packed-vector element offset of column `(x, y)`.
    pub fn col_offset(&self, x: usize, y: usize) -> usize {
        self.col_elem[x + self.nx * y] as usize
    }

    /// Number of retained z's in column `(x, y)`.
    pub fn col_len(&self, x: usize, y: usize) -> usize {
        let c = x + self.nx * y;
        (self.col_elem[c + 1] - self.col_elem[c]) as usize
    }

    /// Is any point retained in column `(x, y)`?
    pub fn col_nonempty(&self, x: usize, y: usize) -> bool {
        self.col_len(x, y) > 0
    }

    /// All non-empty `(x, y)` columns — the projection disc.
    pub fn disc_columns(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for y in 0..self.ny {
            for x in 0..self.nx {
                if self.col_nonempty(x, y) {
                    out.push((x, y));
                }
            }
        }
        out
    }

    /// For each x: maximal runs of y with a non-empty column (the disc's
    /// cross-section, used by the staged y-padding pass).
    pub fn y_runs_per_x(&self) -> Vec<Vec<Run>> {
        (0..self.nx)
            .map(|x| {
                runs_of(&(0..self.ny).map(|y| self.col_nonempty(x, y)).collect::<Vec<_>>())
            })
            .collect()
    }

    /// Maximal runs of x that have any non-empty column (staged x-padding).
    pub fn x_runs(&self) -> Vec<Run> {
        runs_of(
            &(0..self.nx)
                .map(|x| (0..self.ny).any(|y| self.col_nonempty(x, y)))
                .collect::<Vec<_>>(),
        )
    }

    /// Fold extra words into the structural fingerprint. Used by the k-point
    /// offset spheres: two k's can carve out the *same* run structure (small
    /// offsets move no grid point across the cutoff), yet their transforms
    /// are distinct workloads that must not share plan-cache or wisdom
    /// entries — so the k bits participate in the print.
    fn salt_fingerprint(mut self, words: &[u64]) -> Self {
        for &w in words {
            self.print = crate::util::fnv::fnv1a_word(self.print, w);
        }
        self
    }

    /// Restrict to the x's owned by rank `r` of a `p`-rank axis under the
    /// elemental-cyclic distribution. Column `(lx, y)` of the result is
    /// global column `(lx*p + r, y)`.
    pub fn restrict_x_cyclic(&self, p: usize, r: usize) -> OffsetArray {
        let lnx = cyclic::local_count(self.nx, p, r);
        let per_col: Vec<Vec<Run>> = (0..self.ny)
            .flat_map(|y| {
                (0..lnx).map(move |lx| (cyclic::local_to_global(lx, p, r), y))
            })
            .map(|(gx, y)| self.col_runs(gx, y).to_vec())
            .collect();
        OffsetArray::from_runs(lnx, self.ny, self.nz, per_col)
    }

    /// Scatter a packed coefficient vector (batch fastest: element `e` of
    /// band `b` at `b + nb*e`) into full z-columns laid out as
    /// `(b, z, column)` — i.e. for each non-empty column a dense z-line of
    /// `nb*nz`, zero-padded outside the runs. Returns the dense buffer and
    /// the column order used.
    pub fn scatter_z(&self, packed: &[Complex], nb: usize) -> (Vec<Complex>, Vec<(usize, usize)>) {
        let cols = self.disc_columns();
        let mut out = vec![ZERO; nb * self.nz * cols.len()];
        self.scatter_z_into(packed, nb, &mut out);
        (out, cols)
    }

    /// [`scatter_z`] into a preallocated (zeroed) buffer — the plans'
    /// allocation-free path. Column order is the disc order of
    /// [`disc_columns`](Self::disc_columns); `out` must hold
    /// `nb * nz * n_disc_columns` elements and is only written inside the
    /// runs, so the caller must provide it zero-filled.
    pub fn scatter_z_into(&self, packed: &[Complex], nb: usize, out: &mut [Complex]) {
        assert_eq!(packed.len(), nb * self.total());
        let mut ci = 0usize;
        for y in 0..self.ny {
            for x in 0..self.nx {
                if !self.col_nonempty(x, y) {
                    continue;
                }
                let mut e = self.col_offset(x, y);
                let base = ci * nb * self.nz;
                for &(z0, len) in self.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        let dst = base + nb * z;
                        let src = nb * e;
                        out[dst..dst + nb].copy_from_slice(&packed[src..src + nb]);
                        e += 1;
                    }
                }
                ci += 1;
            }
        }
        assert_eq!(out.len(), nb * self.nz * ci, "scatter_z_into: wrong dense length");
    }

    /// Inverse of [`scatter_z`]: gather the run elements of each dense
    /// z-column back into packed order (truncation — the inverse transform's
    /// final step).
    pub fn gather_z(&self, dense: &[Complex], nb: usize) -> Vec<Complex> {
        let mut out = vec![ZERO; nb * self.total()];
        self.gather_z_into(dense, nb, &mut out);
        out
    }

    /// [`gather_z`] into a preallocated buffer (every packed element is
    /// written) — the inverse plans' allocation-free truncation step.
    pub fn gather_z_into(&self, dense: &[Complex], nb: usize, out: &mut [Complex]) {
        assert_eq!(out.len(), nb * self.total());
        let mut ci = 0usize;
        for y in 0..self.ny {
            for x in 0..self.nx {
                if !self.col_nonempty(x, y) {
                    continue;
                }
                let mut e = self.col_offset(x, y);
                let base = ci * nb * self.nz;
                for &(z0, len) in self.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        let src = base + nb * z;
                        let dst = nb * e;
                        out[dst..dst + nb].copy_from_slice(&dense[src..src + nb]);
                        e += 1;
                    }
                }
                ci += 1;
            }
        }
        assert_eq!(dense.len(), nb * self.nz * ci, "gather_z_into: wrong dense length");
    }
}

/// Maximal runs of `true` in a boolean mask.
fn runs_of(mask: &[bool]) -> Vec<Run> {
    let mut out = Vec::new();
    let mut start: Option<usize> = None;
    for (i, &m) in mask.iter().enumerate() {
        match (m, start) {
            (true, None) => start = Some(i),
            (false, Some(s)) => {
                out.push((s as u32, (i - s) as u32));
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = start {
        out.push((s as u32, (mask.len() - s) as u32));
    }
    out
}

/// A cut-off sphere specification over an `n0 x n1 x n2` FFT grid.
#[derive(Clone, Debug)]
pub struct SphereSpec {
    pub n: [usize; 3],
    pub radius: f64,
    pub kind: SphereKind,
}

impl SphereSpec {
    pub fn new(n: [usize; 3], radius: f64, kind: SphereKind) -> Self {
        SphereSpec { n, radius, kind }
    }

    /// Signed frequency of grid index `i` on a length-`n` axis.
    fn freq(i: usize, n: usize, kind: SphereKind) -> f64 {
        match kind {
            SphereKind::Centered => i as f64 - (n / 2) as f64,
            SphereKind::Wrapped => {
                if i <= n / 2 {
                    i as f64
                } else {
                    i as f64 - n as f64
                }
            }
        }
    }

    /// Is grid point `(x, y, z)` inside the sphere?
    pub fn contains(&self, x: usize, y: usize, z: usize) -> bool {
        let fx = Self::freq(x, self.n[0], self.kind);
        let fy = Self::freq(y, self.n[1], self.kind);
        let fz = Self::freq(z, self.n[2], self.kind);
        fx * fx + fy * fy + fz * fz <= self.radius * self.radius + 1e-9
    }

    /// Build the CSR offset array (Fig. 7).
    pub fn offsets(&self) -> OffsetArray {
        let [nx, ny, nz] = self.n;
        let mut per_col = Vec::with_capacity(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                let mask: Vec<bool> = (0..nz).map(|z| self.contains(x, y, z)).collect();
                per_col.push(runs_of(&mask));
            }
        }
        // per_col is indexed c = x + nx*y: the inner loop above runs x
        // fastest, matching OffsetArray's convention.
        OffsetArray::from_runs(nx, ny, nz, per_col)
    }

    /// Is grid point `(x, y, z)` inside the sphere shifted by crystal
    /// momentum `k` (grid frequency units): `|G + k|^2 <= radius^2`?
    pub fn contains_offset(&self, x: usize, y: usize, z: usize, k: [f64; 3]) -> bool {
        let fx = Self::freq(x, self.n[0], self.kind) + k[0];
        let fy = Self::freq(y, self.n[1], self.kind) + k[1];
        let fz = Self::freq(z, self.n[2], self.kind) + k[2];
        fx * fx + fy * fy + fz * fz <= self.radius * self.radius + 1e-9
    }

    /// Build the offset sphere `|G + k|^2 <= radius^2` for crystal momentum
    /// `k` in grid frequency units — the per-k-point basis mask of a real
    /// plane-wave code (each k-point keeps its own set of G vectors).
    ///
    /// Two guarantees the tuner and service lanes rely on:
    ///
    /// * `k = Γ = [0, 0, 0]` reduces **exactly** to [`offsets`](Self::offsets)
    ///   — same runs, same [`OffsetArray::fingerprint`], so Γ-point callers
    ///   keep hitting the plans and wisdom they already have;
    /// * distinct `k` always produce distinct fingerprints, even when the
    ///   shift is too small to move any grid point across the cutoff: the k
    ///   bits are folded into the print, so every k-point gets its own
    ///   plan-cache / wisdom / service-lane identity.
    pub fn offset(&self, k: [f64; 3]) -> OffsetArray {
        if k == [0.0; 3] {
            return self.offsets();
        }
        let [nx, ny, nz] = self.n;
        let mut per_col = Vec::with_capacity(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                let mask: Vec<bool> =
                    (0..nz).map(|z| self.contains_offset(x, y, z, k)).collect();
                per_col.push(runs_of(&mask));
            }
        }
        OffsetArray::from_runs(nx, ny, nz, per_col)
            .salt_fingerprint(&[k[0].to_bits(), k[1].to_bits(), k[2].to_bits()])
    }

    /// Sphere built from an energy cutoff (Eq. 9): `|g|^2/2 <= E_cut` with
    /// `g` in grid units — radius `sqrt(2 E_cut)`.
    pub fn from_ecut(n: [usize; 3], ecut: f64, kind: SphereKind) -> Self {
        SphereSpec::new(n, (2.0 * ecut).sqrt(), kind)
    }
}

/// Scatter a packed sphere into the full cube (the paper's Fig. 2 approach:
/// "pad the entire sphere by embedding it into a cube"). Column-major cube
/// `(x fastest)`, batch fastest within each element: `b + nb*(x + nx*(y + ny*z))`.
pub fn sphere_to_cube(off: &OffsetArray, packed: &[Complex], nb: usize) -> Vec<Complex> {
    assert_eq!(packed.len(), nb * off.total());
    let (nx, ny, nz) = (off.nx, off.ny, off.nz);
    let mut cube = vec![ZERO; nb * nx * ny * nz];
    for y in 0..ny {
        for x in 0..nx {
            let mut e = off.col_offset(x, y);
            for &(z0, len) in off.col_runs(x, y) {
                for z in z0 as usize..(z0 + len) as usize {
                    let dst = nb * (x + nx * (y + ny * z));
                    let src = nb * e;
                    cube[dst..dst + nb].copy_from_slice(&packed[src..src + nb]);
                    e += 1;
                }
            }
        }
    }
    cube
}

/// Gather the sphere elements back out of a full cube (truncation).
pub fn cube_to_sphere(off: &OffsetArray, cube: &[Complex], nb: usize) -> Vec<Complex> {
    let (nx, ny, nz) = (off.nx, off.ny, off.nz);
    assert_eq!(cube.len(), nb * nx * ny * nz);
    let mut packed = vec![ZERO; nb * off.total()];
    for y in 0..ny {
        for x in 0..nx {
            let mut e = off.col_offset(x, y);
            for &(z0, len) in off.col_runs(x, y) {
                for z in z0 as usize..(z0 + len) as usize {
                    let src = nb * (x + nx * (y + ny * z));
                    let dst = nb * e;
                    packed[dst..dst + nb].copy_from_slice(&cube[src..src + nb]);
                    e += 1;
                }
            }
        }
    }
    packed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centered_sphere_volume_ratio() {
        // d = n/2 sphere in an n-cube: volume ratio ~ pi/48 ~ 0.0654
        // (the paper's "data increased by almost 16 times").
        let n = 32;
        let s = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = s.offsets();
        let ratio = off.total() as f64 / (n * n * n) as f64;
        assert!(ratio > 0.04 && ratio < 0.09, "ratio={ratio}");
        // Paper: cube is ~16x the sphere data.
        let blowup = (n * n * n) as f64 / off.total() as f64;
        assert!(blowup > 11.0 && blowup < 25.0, "blowup={blowup}");
    }

    #[test]
    fn centered_columns_single_run() {
        let s = SphereSpec::new([16, 16, 16], 4.0, SphereKind::Centered);
        let off = s.offsets();
        for y in 0..16 {
            for x in 0..16 {
                assert!(off.col_runs(x, y).len() <= 1);
            }
        }
        assert!(off.total() > 0);
    }

    #[test]
    fn wrapped_columns_at_most_two_runs() {
        let s = SphereSpec::new([16, 16, 16], 5.0, SphereKind::Wrapped);
        let off = s.offsets();
        let mut saw_two = false;
        for y in 0..16 {
            for x in 0..16 {
                let r = off.col_runs(x, y).len();
                assert!(r <= 2, "column ({x},{y}) has {r} runs");
                saw_two |= r == 2;
            }
        }
        assert!(saw_two, "wrapped sphere should split some columns");
    }

    #[test]
    fn offsets_match_contains() {
        let s = SphereSpec::new([12, 10, 14], 3.7, SphereKind::Wrapped);
        let off = s.offsets();
        let mut count = 0;
        for z in 0..14 {
            for y in 0..10 {
                for x in 0..12 {
                    let inside = s.contains(x, y, z);
                    let in_runs = off
                        .col_runs(x, y)
                        .iter()
                        .any(|&(z0, len)| (z0 as usize..(z0 + len) as usize).contains(&z));
                    assert_eq!(inside, in_runs, "({x},{y},{z})");
                    count += inside as usize;
                }
            }
        }
        assert_eq!(count, off.total());
    }

    #[test]
    fn cube_round_trip() {
        let s = SphereSpec::new([8, 8, 8], 2.5, SphereKind::Centered);
        let off = s.offsets();
        let nb = 3;
        let packed: Vec<Complex> = (0..nb * off.total())
            .map(|i| Complex::new(i as f64, -(i as f64)))
            .collect();
        let cube = sphere_to_cube(&off, &packed, nb);
        let back = cube_to_sphere(&off, &cube, nb);
        assert_eq!(packed, back);
        // Everything outside the sphere is zero.
        let nonzero = cube.iter().filter(|v| v.re != 0.0 || v.im != 0.0).count();
        assert!(nonzero <= nb * off.total());
    }

    #[test]
    fn scatter_gather_z_round_trip() {
        let s = SphereSpec::new([8, 8, 8], 2.9, SphereKind::Wrapped);
        let off = s.offsets();
        let nb = 2;
        let packed: Vec<Complex> =
            (0..nb * off.total()).map(|i| Complex::new(1.0 + i as f64, 0.5)).collect();
        let (dense, cols) = off.scatter_z(&packed, nb);
        assert_eq!(cols.len(), off.disc_columns().len());
        let back = off.gather_z(&dense, nb);
        assert_eq!(packed, back);
    }

    #[test]
    fn restrict_x_cyclic_partitions_totals() {
        let s = SphereSpec::new([16, 16, 16], 6.0, SphereKind::Centered);
        let off = s.offsets();
        for p in [1usize, 2, 3, 4] {
            let total: usize = (0..p).map(|r| off.restrict_x_cyclic(p, r).total()).sum();
            assert_eq!(total, off.total(), "p={p}");
        }
    }

    #[test]
    fn disc_and_x_runs_consistent() {
        let s = SphereSpec::new([16, 16, 16], 5.0, SphereKind::Centered);
        let off = s.offsets();
        let disc = off.disc_columns();
        let yruns = off.y_runs_per_x();
        let count: usize =
            yruns.iter().map(|rs| rs.iter().map(|r| r.1 as usize).sum::<usize>()).sum();
        assert_eq!(count, disc.len());
        let xr = off.x_runs();
        let xs: usize = xr.iter().map(|r| r.1 as usize).sum();
        let disc_xs: std::collections::HashSet<usize> = disc.iter().map(|&(x, _)| x).collect();
        assert_eq!(xs, disc_xs.len());
    }

    #[test]
    fn gamma_offset_is_bit_identical_to_plain_offsets() {
        let s = SphereSpec::new([12, 12, 12], 4.2, SphereKind::Wrapped);
        let plain = s.offsets();
        let gamma = s.offset([0.0, 0.0, 0.0]);
        assert_eq!(plain.fingerprint(), gamma.fingerprint());
        assert_eq!(plain.total(), gamma.total());
        for y in 0..12 {
            for x in 0..12 {
                assert_eq!(plain.col_runs(x, y), gamma.col_runs(x, y), "({x},{y})");
            }
        }
        // -0.0 == 0.0: a signed-zero k is still Γ, not a salted variant.
        assert_eq!(s.offset([-0.0, 0.0, -0.0]).fingerprint(), plain.fingerprint());
    }

    #[test]
    fn offset_membership_matches_shifted_norm() {
        let s = SphereSpec::new([10, 12, 14], 3.9, SphereKind::Wrapped);
        let k = [0.25, -0.5, 0.125];
        let off = s.offset(k);
        for z in 0..14 {
            for y in 0..12 {
                for x in 0..10 {
                    let in_runs = off
                        .col_runs(x, y)
                        .iter()
                        .any(|&(z0, len)| (z0 as usize..(z0 + len) as usize).contains(&z));
                    assert_eq!(s.contains_offset(x, y, z, k), in_runs, "({x},{y},{z})");
                }
            }
        }
    }

    #[test]
    fn distinct_k_get_distinct_fingerprints() {
        let s = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let ks = [
            [0.0, 0.0, 0.0],
            [0.25, 0.0, 0.0],
            [0.0, 0.25, 0.0],
            [0.5, 0.5, 0.5],
            [1e-6, 0.0, 0.0],
        ];
        let prints: Vec<u64> = ks.iter().map(|&k| s.offset(k).fingerprint()).collect();
        for i in 0..prints.len() {
            for j in i + 1..prints.len() {
                assert_ne!(prints[i], prints[j], "k {:?} vs {:?}", ks[i], ks[j]);
            }
        }
        // A shift too small to move any grid point across the cutoff (radius
        // 2.9 sits between the |G|^2 = 8 and 9 shells) keeps the run
        // structure of Γ — only the fingerprint salt tells them apart.
        let s2 = SphereSpec::new([8, 8, 8], 2.9, SphereKind::Wrapped);
        let tiny = s2.offset([1e-6, 0.0, 0.0]);
        assert_eq!(tiny.total(), s2.offsets().total());
        assert_ne!(tiny.fingerprint(), s2.offsets().fingerprint());
    }

    #[test]
    fn ecut_radius() {
        let s = SphereSpec::from_ecut([8, 8, 8], 8.0, SphereKind::Wrapped);
        assert!((s.radius - 4.0).abs() < 1e-12);
    }
}
