//! Processing grids (paper §3.2, Fig. 6 lines 2-3).
//!
//! A `ProcGrid` arranges the ranks of a communicator into a 1D, 2D or 3D
//! cartesian grid. Tensors declare which grid axis each of their dimensions
//! is distributed over; the planner asks the grid for per-axis
//! sub-communicators to run its alltoall stages in.

use std::sync::Arc;

use super::error::{FftbError, Result};
use crate::comm::communicator::Comm;

/// Cartesian processing grid over the ranks of `comm`.
///
/// Rank `r` has coordinates `coords` with axis 0 fastest-varying:
/// `r = c0 + dims[0]*(c1 + dims[1]*c2)` — the same convention as the
/// column-major tensors.
#[derive(Clone)]
pub struct ProcGrid {
    dims: Vec<usize>,
    comm: Comm,
    coords: Vec<usize>,
    /// Sub-communicator along each axis (varying that coordinate only).
    axis_comms: Vec<Comm>,
}

impl ProcGrid {
    /// Build a grid of shape `dims` over all ranks of `comm`.
    /// `dims.iter().product()` must equal `comm.size()`.
    pub fn new(dims: &[usize], comm: Comm) -> Result<Arc<Self>> {
        if dims.is_empty() || dims.len() > 3 {
            return Err(FftbError::Grid(format!(
                "grids must be 1D, 2D or 3D, got {}D",
                dims.len()
            )));
        }
        let p: usize = dims.iter().product();
        if p != comm.size() {
            return Err(FftbError::Grid(format!(
                "grid {:?} needs {} ranks, communicator has {}",
                dims,
                p,
                comm.size()
            )));
        }
        let r = comm.rank();
        let mut coords = Vec::with_capacity(dims.len());
        let mut rem = r;
        for &d in dims {
            coords.push(rem % d);
            rem /= d;
        }

        // Axis communicator a: color = all other coordinates, key = own
        // coordinate on a.
        let mut axis_comms = Vec::with_capacity(dims.len());
        for a in 0..dims.len() {
            let mut color = 0u64;
            let mut mult = 1u64;
            for (i, (&d, &c)) in dims.iter().zip(&coords).enumerate() {
                if i != a {
                    color += c as u64 * mult;
                    mult *= d as u64;
                }
            }
            axis_comms.push(comm.split(color, coords[a] as u64));
        }
        Ok(Arc::new(ProcGrid { dims: dims.to_vec(), comm, coords, axis_comms }))
    }

    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn size(&self) -> usize {
        self.comm.size()
    }

    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    pub fn coords(&self) -> &[usize] {
        &self.coords
    }

    /// Extent of one axis.
    pub fn axis_len(&self, axis: usize) -> usize {
        self.dims[axis]
    }

    /// My coordinate on one axis.
    pub fn axis_coord(&self, axis: usize) -> usize {
        self.coords[axis]
    }

    /// Sub-communicator spanning one axis (my row/column/fiber).
    pub fn axis_comm(&self, axis: usize) -> &Comm {
        &self.axis_comms[axis]
    }

    /// Whole-grid communicator.
    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// Fold a 3D grid `[d0, d1, d2]` into the 2D grid `[d0*d1, d2]` the
    /// planner actually runs pencil plans on (layout-by-plan: the paper's
    /// framework owns intermediate layouts, so the extra grid dimension is
    /// absorbed into the first pencil axis). Tensors taking part in a
    /// 3D-grid plan must be declared against *this* folded grid — the
    /// planner validates their sizes against the folded plan and rejects
    /// tensors declared on the unfolded grid ([`FftbError::Shape`]).
    pub fn fold(&self) -> Result<Arc<Self>> {
        if self.ndim() != 3 {
            return Err(FftbError::Grid(format!(
                "fold() applies to 3D grids only, got {}D",
                self.ndim()
            )));
        }
        ProcGrid::new(&[self.dims[0] * self.dims[1], self.dims[2]], self.comm.clone())
    }
}

/// Elemental-cyclic distribution helpers (paper §3.2: "data in each
/// dimension is distributed in a round robin fashion at the granularity of
/// one element").
pub mod cyclic {
    /// Number of global indices `g < n` with `g % p == r`.
    #[inline]
    pub fn local_count(n: usize, p: usize, r: usize) -> usize {
        debug_assert!(r < p);
        (n + p - 1 - r) / p
    }

    /// Global index of local element `l` on rank `r`.
    #[inline]
    pub fn local_to_global(l: usize, p: usize, r: usize) -> usize {
        l * p + r
    }

    /// Owner rank of global index `g`.
    #[inline]
    pub fn owner(g: usize, p: usize) -> usize {
        g % p
    }

    /// Local index of global `g` on its owner.
    #[inline]
    pub fn global_to_local(g: usize, p: usize) -> usize {
        g / p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;

    #[test]
    fn cyclic_partition_is_exact() {
        for n in [1usize, 5, 16, 37] {
            for p in [1usize, 2, 3, 4, 7] {
                let total: usize = (0..p).map(|r| cyclic::local_count(n, p, r)).sum();
                assert_eq!(total, n, "n={n} p={p}");
                // Round trip for every global index.
                for g in 0..n {
                    let r = cyclic::owner(g, p);
                    let l = cyclic::global_to_local(g, p);
                    assert!(l < cyclic::local_count(n, p, r));
                    assert_eq!(cyclic::local_to_global(l, p, r), g);
                }
            }
        }
    }

    #[test]
    fn grid_coords_and_axis_comms_2d() {
        let outs = run_world(6, |comm| {
            let g = ProcGrid::new(&[2, 3], comm).unwrap();
            (
                g.coords().to_vec(),
                g.axis_comm(0).size(),
                g.axis_comm(1).size(),
                g.axis_comm(0).rank(),
                g.axis_comm(1).rank(),
            )
        });
        for (r, (coords, s0, s1, r0, r1)) in outs.iter().enumerate() {
            assert_eq!(coords, &vec![r % 2, r / 2]);
            assert_eq!(*s0, 2);
            assert_eq!(*s1, 3);
            assert_eq!(*r0, r % 2, "axis-0 rank is axis-0 coord");
            assert_eq!(*r1, r / 2, "axis-1 rank is axis-1 coord");
        }
    }

    #[test]
    fn grid_size_mismatch_rejected() {
        run_world(4, |comm| {
            assert!(ProcGrid::new(&[3], comm.clone()).is_err());
            assert!(ProcGrid::new(&[2, 3], comm.clone()).is_err());
            assert!(ProcGrid::new(&[2, 2], comm).is_ok());
        });
    }

    #[test]
    fn grid_3d_axis_comms() {
        let outs = run_world(8, |comm| {
            let g = ProcGrid::new(&[2, 2, 2], comm).unwrap();
            (g.axis_comm(0).size(), g.axis_comm(1).size(), g.axis_comm(2).size())
        });
        for o in outs {
            assert_eq!(o, (2, 2, 2));
        }
    }
}
