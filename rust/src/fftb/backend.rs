//! Local-FFT backend abstraction (paper §3.1: "The local computation is
//! represented by the 1D or 2D Fourier transforms ... The abstractions are
//! replaced with actual function calls from off-the-shelf libraries like
//! FFTW, cuFFT and rocFFT").
//!
//! Here the two backends are the pure-rust substrate (`RustFft`) and the
//! AOT-compiled Pallas/XLA artifacts executed through PJRT
//! (`crate::runtime::PjrtBackend`). Plans hand every transform to a backend
//! as a *contiguous batch of lines* — the same shape the artifacts are
//! compiled for.

use std::sync::Mutex;

use crate::fft::batch::Fft1d;
use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;

/// A provider of node-local batched 1D FFTs.
///
/// `data` holds `data.len() / n` contiguous lines of length `n`; all are
/// transformed in place. Implementations must be thread-safe: one backend
/// instance is shared by every rank thread.
pub trait LocalFftBackend: Send + Sync {
    fn fft_batch(&self, data: &mut [Complex], n: usize, dir: Direction);
    fn name(&self) -> &str;

    /// Floating-point work of a call, for roofline accounting.
    fn flops(&self, total: usize, n: usize) -> f64 {
        (total / n.max(1)) as f64 * crate::fft::batch::fft_flops(n)
    }
}

/// Pure-rust backend: Stockham / Bluestein plans, cached per line length.
pub struct RustFftBackend {
    plans: Mutex<std::collections::HashMap<(usize, bool), std::sync::Arc<Fft1d>>>,
}

impl Default for RustFftBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl RustFftBackend {
    pub fn new() -> Self {
        RustFftBackend { plans: Mutex::new(std::collections::HashMap::new()) }
    }

    fn plan(&self, n: usize, dir: Direction) -> std::sync::Arc<Fft1d> {
        let key = (n, dir == Direction::Forward);
        let mut plans = self.plans.lock().unwrap();
        std::sync::Arc::clone(
            plans.entry(key).or_insert_with(|| std::sync::Arc::new(Fft1d::new(n, dir))),
        )
    }
}

impl LocalFftBackend for RustFftBackend {
    fn fft_batch(&self, data: &mut [Complex], n: usize, dir: Direction) {
        assert_eq!(data.len() % n, 0, "fft_batch: data not a multiple of n");
        let plan = self.plan(n, dir);
        // Perf (EXPERIMENTS.md §Perf, L3 iteration 1): reuse the per-thread
        // scratch buffer instead of allocating one per call — fft_batch is
        // invoked once per stage per transform in the hot loop.
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<Complex>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        SCRATCH.with(|s| {
            let mut scratch = s.borrow_mut();
            if scratch.len() < plan.scratch_len() {
                scratch.resize(plan.scratch_len(), ZERO);
            }
            plan.run_batch(data, &mut scratch);
        });
    }

    fn name(&self) -> &str {
        "rust-stockham"
    }
}

/// Gather strided lines into a contiguous buffer, run the backend batch
/// transform, scatter back. `starts` lists the flat offset of each line's
/// first element; elements step by `stride`.
///
/// This is the universal "pack + FFT + unpack" building block of every
/// stage — the CPU analogue of the paper's GPU pack/rotate codelets.
pub fn fft_strided_lines(
    backend: &dyn LocalFftBackend,
    data: &mut [Complex],
    n: usize,
    stride: usize,
    starts: &[usize],
    dir: Direction,
) {
    if starts.is_empty() || n == 0 {
        return;
    }
    let mut buf = vec![ZERO; n * starts.len()];
    for (l, &s) in starts.iter().enumerate() {
        for k in 0..n {
            buf[l * n + k] = data[s + k * stride];
        }
    }
    backend.fft_batch(&mut buf, n, dir);
    for (l, &s) in starts.iter().enumerate() {
        for k in 0..n {
            data[s + k * stride] = buf[l * n + k];
        }
    }
}

/// FFT along dimension `dim` of a column-major tensor via the backend
/// (pack/unpack through contiguous line batches). Allocates its own
/// transpose scratch — the convenience entry point for one-off transforms;
/// the plans' hot paths use [`backend_fft_dim_ws`] with a reusable buffer.
pub fn backend_fft_dim(
    backend: &dyn LocalFftBackend,
    data: &mut [Complex],
    shape: &[usize],
    dim: usize,
    dir: Direction,
) {
    let mut scratch = Vec::new();
    let ctr = std::cell::Cell::new(0u64);
    backend_fft_dim_ws(backend, data, shape, dim, dir, &mut scratch, &ctr);
}

/// [`backend_fft_dim`] with the transpose scratch routed through a
/// caller-owned buffer (the plans' [`Workspace`](crate::fftb::plan::workspace::Workspace)),
/// so steady-state executions perform no heap allocation here. Capacity
/// growth of `scratch` is recorded into `ctr`.
pub fn backend_fft_dim_ws(
    backend: &dyn LocalFftBackend,
    data: &mut [Complex],
    shape: &[usize],
    dim: usize,
    dir: Direction,
    scratch: &mut Vec<Complex>,
    ctr: &std::cell::Cell<u64>,
) {
    let n = shape[dim];
    if n <= 1 {
        return;
    }
    let inner: usize = shape[..dim].iter().product();
    let outer: usize = shape[dim + 1..].iter().product();
    // Perf (EXPERIMENTS.md §Perf, L3 iteration 2): when the transformed
    // dimension is innermost the lines are already contiguous and in
    // order — skip the gather/scatter pack entirely.
    if inner == 1 {
        return backend.fft_batch(data, n, dir);
    }
    // Perf (§Perf, L3 iteration 4): each outer block is an (inner, n)
    // column-major panel whose lines are its rows — pack/unpack is a
    // blocked transpose (cache-tiled) instead of a strided gather.
    crate::fftb::plan::workspace::ensure(scratch, inner * n * outer, ctr);
    crate::fft::nd::transpose_batch(data, scratch, inner, n, outer);
    backend.fft_batch(scratch, n, dir);
    crate::fft::nd::transpose_batch(scratch, data, n, inner, outer);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::max_abs_diff;
    use crate::fft::nd;

    fn phased(n: usize, seed: u64) -> Vec<Complex> {
        (0..n)
            .map(|i| {
                let t = (i as f64 + seed as f64) * 0.733;
                Complex::new(t.sin(), t.cos())
            })
            .collect()
    }

    #[test]
    fn backend_batch_matches_substrate() {
        let be = RustFftBackend::new();
        let n = 16;
        let mut a = phased(n * 4, 1);
        let mut b = a.clone();
        be.fft_batch(&mut a, n, Direction::Forward);
        let plan = Fft1d::new(n, Direction::Forward);
        plan.run_batch_alloc(&mut b);
        assert!(max_abs_diff(&a, &b) < 1e-14);
    }

    #[test]
    fn backend_fft_dim_matches_nd() {
        let be = RustFftBackend::new();
        let shape = [3usize, 8, 5, 4];
        for dim in 0..4 {
            let mut a = phased(shape.iter().product(), dim as u64);
            let mut b = a.clone();
            backend_fft_dim(&be, &mut a, &shape, dim, Direction::Forward);
            nd::fft_dim(&mut b, &shape, dim, Direction::Forward);
            assert!(max_abs_diff(&a, &b) < 1e-12, "dim={dim}");
        }
    }

    #[test]
    fn strided_lines_subset() {
        // FFT only lines 0 and 2 of a 4-line buffer; others untouched.
        let be = RustFftBackend::new();
        let n = 8;
        let data0 = phased(4 * n, 3);
        let mut data = data0.clone();
        let starts = vec![0usize, 2 * n];
        fft_strided_lines(&be, &mut data, n, 1, &starts, Direction::Forward);
        assert_eq!(&data[n..2 * n], &data0[n..2 * n]);
        assert_eq!(&data[3 * n..], &data0[3 * n..]);
        let mut want = data0[..n].to_vec();
        be.fft_batch(&mut want, n, Direction::Forward);
        assert!(max_abs_diff(&data[..n], &want) < 1e-14);
    }
}
