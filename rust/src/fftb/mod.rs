//! FFTB — the paper's flexible distributed FFT framework (core library).
//!
//! The module layout mirrors Fig. 4 of the paper:
//!
//! * API (green block): [`grid`], [`domain`], [`layout`], [`tensor`],
//!   [`sphere`] — processing grids, bound domains (+ CSR offset arrays),
//!   distribution strings, distributed tensors.
//! * Intermediate block (yellow): [`plan`] — pattern-matches the tensor
//!   distributions and stitches compute + communication stages.
//! * Local computation (red): [`backend`] — pluggable batched-1D-FFT
//!   providers (pure-rust substrate or PJRT artifacts).
//! * Data movement (orange): `crate::comm` alltoalls, invoked by the plans.

pub mod backend;
pub mod domain;
pub mod error;
pub mod grid;
pub mod layout;
pub mod plan;
pub mod sphere;
pub mod tensor;

pub use backend::{LocalFftBackend, RustFftBackend};
pub use domain::{Domain, DomainList};
pub use error::{FftbError, Result};
pub use grid::ProcGrid;
pub use layout::Layout;
pub use plan::{ExecTrace, Fftb, FftbOptions, PlanKind};
pub use sphere::{OffsetArray, SphereKind, SphereSpec};
pub use tensor::DistTensor;
