//! Error type of the FFTB API.
//!
//! The paper (§3.1): "The current FFTB implementation accepts some predefined
//! patterns ... The framework will raise an exception if the provided
//! patterns are not within the predefined list." `FftbError::Unsupported` is
//! that exception.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum FftbError {
    #[error("unsupported transform pattern: {0}")]
    Unsupported(String),

    #[error("layout string parse error: {0}")]
    Layout(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("processing grid error: {0}")]
    Grid(String),

    #[error("artifact runtime error: {0}")]
    Runtime(String),
}

pub type Result<T> = std::result::Result<T, FftbError>;
