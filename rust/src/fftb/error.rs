//! Error type of the FFTB API.
//!
//! The paper (§3.1): "The current FFTB implementation accepts some predefined
//! patterns ... The framework will raise an exception if the provided
//! patterns are not within the predefined list." `FftbError::Unsupported` is
//! that exception.
//!
//! Display/Error are hand-implemented: the default build of this tree has
//! zero external dependencies (no `thiserror` in the offline set).

use std::fmt;

#[derive(Debug)]
pub enum FftbError {
    Unsupported(String),
    Layout(String),
    Shape(String),
    Grid(String),
    Runtime(String),
}

impl fmt::Display for FftbError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FftbError::Unsupported(m) => write!(f, "unsupported transform pattern: {m}"),
            FftbError::Layout(m) => write!(f, "layout string parse error: {m}"),
            FftbError::Shape(m) => write!(f, "shape mismatch: {m}"),
            FftbError::Grid(m) => write!(f, "processing grid error: {m}"),
            FftbError::Runtime(m) => write!(f, "artifact runtime error: {m}"),
        }
    }
}

impl std::error::Error for FftbError {}

pub type Result<T> = std::result::Result<T, FftbError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        let e = FftbError::Unsupported("bad sig".into());
        assert_eq!(e.to_string(), "unsupported transform pattern: bad sig");
        let e = FftbError::Runtime("no artifacts".into());
        assert!(e.to_string().contains("artifact runtime error"));
    }
}
