//! Execution traces and the fused-exchange contract between the plans and
//! the comm layer.
//!
//! Each distributed transform execution produces an [`ExecTrace`] per rank.
//! The benches aggregate traces across ranks (max per stage ≈ the critical
//! path) and the performance model (`crate::model`) re-prices the recorded
//! communication volumes for a target machine — this is how the Fig. 9
//! projections beyond the live thread count are produced.
//!
//! Besides the per-stage table, a trace carries execution-wide overlap
//! counters fed by the fused windowed alltoall ([`A2aCounters`]):
//! `wait_ns`, the nanoseconds this rank spent blocked in receive waits;
//! `overlap_rounds`, how many exchange rounds were posted ahead of the
//! serial schedule; `pack_overlap_ns` / `unpack_overlap_ns`, the
//! pack/unpack nanoseconds that ran while other rounds were in flight;
//! and `worker_busy_ns` / `pipeline_overlap_ns`, the helper worker
//! thread's busy time inside exchanges and inside the batching driver's
//! two-deep pipeline respectively. `benches/a2a_micro.rs` prints them
//! side by side for the serial, pre-packed and fused disciplines.
//!
//! [`PackKernel`] is the plan-side contract of the fused exchange: a plan
//! hands the engine per-destination pack and unpack movers instead of
//! monolithic pre-packed buffers, so destination block `s + window` is
//! packed straight into its recycled wire buffer after the wait for round
//! `s` completes, and each received block is unpacked as its own wait
//! completes. [`fused_exchange`] bridges a `PackKernel` to the comm
//! layer's [`FusedBlocks`]-driven engine
//! ([`alltoallv_fused`](crate::comm::alltoall::alltoallv_fused)).

use std::time::Duration;

use crate::comm::alltoall::{alltoallv_fused, A2aCounters, CommTuning, FusedBlocks};
use crate::comm::arena::WireBuf;
use crate::comm::communicator::Comm;

/// Per-destination pack/unpack movers of one exchange — what a plan gives
/// the fused windowed engine instead of a monolithic pre-packed buffer.
///
/// Contract (asserted by the engine):
///
/// * `pack(dest, out)` appends **exactly** `send_bytes(dest)` bytes to
///   `out`, in the destination's canonical element order — the same order
///   the old monolithic pack wrote that destination's slice of the flat
///   send buffer, so fused and pre-packed exchanges are bit-identical.
/// * `unpack(src, block)` consumes a block of **exactly**
///   `recv_bytes(src)` bytes and lands it; it must tolerate any call
///   order (blocks arrive round by round, and the self block lands first).
/// * Both must be pure data movement: no allocation, no communication —
///   the engine calls them on the critical path between waits.
pub trait PackKernel {
    /// Bytes of the block headed to rank `dest` (0 allowed).
    fn send_bytes(&self, dest: usize) -> usize;
    /// Bytes expected from rank `src` (0 allowed).
    fn recv_bytes(&self, src: usize) -> usize;
    /// Append rank `dest`'s packed block to `out` (canonical order).
    fn pack(&mut self, dest: usize, out: &mut WireBuf);
    /// Land the block received from rank `src`.
    fn unpack(&mut self, src: usize, block: &[u8]);
    /// Move rank `me`'s self block src→dst directly, without arena
    /// staging, when the kernel can. Return `false` (the default) to have
    /// the engine route it as `pack` → arena staging buffer → `unpack`.
    fn self_move(&mut self, me: usize) -> bool {
        let _ = me;
        false
    }
}

/// Adapter bridging a [`PackKernel`] to the comm layer's [`FusedBlocks`]
/// driver interface (kept separate so the comm layer stays plan-agnostic).
struct KernelBlocks<'a>(&'a mut dyn PackKernel);

impl FusedBlocks for KernelBlocks<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.0.send_bytes(dest)
    }

    fn recv_bytes(&self, src: usize) -> usize {
        self.0.recv_bytes(src)
    }

    fn pack(&mut self, dest: usize, out: &mut WireBuf) {
        self.0.pack(dest, out);
    }

    fn unpack(&mut self, src: usize, block: &[u8]) {
        self.0.unpack(src, block);
    }

    fn self_move(&mut self, me: usize) -> bool {
        self.0.self_move(me)
    }
}

/// Run one fused exchange: drive `kernel`'s per-destination pack/unpack
/// movers through the windowed engine over `comm`. Results are
/// bit-identical for every window size; the returned counters report wait
/// time and how much pack/unpack work overlapped in-flight rounds.
pub fn fused_exchange(
    comm: &Comm,
    kernel: &mut dyn PackKernel,
    tuning: CommTuning,
) -> A2aCounters {
    alltoallv_fused(comm, &mut KernelBlocks(kernel), tuning)
}

/// What kind of work a stage did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageKind {
    /// Local FFT compute.
    Compute,
    /// An alltoall exchange, including the per-destination pack/unpack
    /// fused into its rounds.
    Comm,
    /// Local data reshaping only (scatter/gather, padding, staging).
    Reshape,
}

/// One stage of one execution on one rank.
#[derive(Clone, Debug)]
pub struct StageTrace {
    /// Stage label (e.g. `"a2a_xz"`).
    pub name: &'static str,
    /// What kind of work the stage did.
    pub kind: StageKind,
    /// Wall-clock time of the stage on this rank.
    pub elapsed: Duration,
    /// Bytes this rank sent to *other* ranks in this stage (0 for compute).
    pub bytes_sent: u64,
    /// Number of point-to-point messages sent (0 for compute).
    pub messages: u64,
    /// Complex-FLOP estimate of local compute (0 for comm).
    pub flops: f64,
}

/// Trace of one full transform execution on one rank.
#[derive(Clone, Debug, Default)]
pub struct ExecTrace {
    /// Per-stage records, in execution order.
    pub stages: Vec<StageTrace>,
    /// Bytes of heap storage newly acquired by the plan's reusable
    /// [`Workspace`](super::workspace::Workspace) during this execution.
    /// First executions grow their workspaces; steady-state executions must
    /// report 0 here — the plan-once / execute-many property the paper's
    /// design is built around (and what `tests/workspace_reuse.rs` asserts).
    pub alloc_bytes: u64,
    /// Nanoseconds this rank spent blocked waiting for exchange receives,
    /// summed over every comm stage (see [`A2aCounters::wait_ns`]).
    pub wait_ns: u64,
    /// Exchange rounds posted ahead of the serial schedule, summed over
    /// every comm stage (0 when the serial discipline — or `window == 1` —
    /// ran; see [`A2aCounters::overlap_rounds`]).
    pub overlap_rounds: u64,
    /// Nanoseconds spent packing destination blocks while the exchange was
    /// already in flight, summed over every comm stage (see
    /// [`A2aCounters::pack_overlap_ns`]). 0 for the serial ordering
    /// (`window == 1`) and 2-rank worlds.
    pub pack_overlap_ns: u64,
    /// Nanoseconds spent unpacking received blocks while later rounds were
    /// still outstanding, summed over every comm stage (see
    /// [`A2aCounters::unpack_overlap_ns`]).
    pub unpack_overlap_ns: u64,
    /// Nanoseconds the helper worker thread spent packing and unpacking
    /// inside threaded exchanges, summed over every comm stage (see
    /// [`A2aCounters::worker_busy_ns`]); the batching driver adds the
    /// worker time of pipelined staging tails it attributes to this
    /// execution. 0 on every single-threaded path.
    pub worker_busy_ns: u64,
    /// Nanoseconds of this execution's work that ran on the worker thread
    /// *concurrently with another batch's execution* in the batching
    /// driver's two-deep pipeline (the de-interleave tail of flush `k-1`
    /// overlapping flush `k`'s exchange). 0 at pipeline depth 1 and for
    /// directly-executed plans.
    pub pipeline_overlap_ns: u64,
    /// Whether the plan that produced this execution was served from a
    /// [`PlanCache`](crate::tuner::cache::PlanCache) rather than built
    /// fresh. Set by the caching layer (e.g. the batching driver), not by
    /// the plans themselves; `false` for directly-executed plans.
    pub plan_cache_hit: bool,
}

impl ExecTrace {
    /// Append one stage record.
    pub fn push(
        &mut self,
        name: &'static str,
        kind: StageKind,
        elapsed: Duration,
        bytes_sent: u64,
        messages: u64,
        flops: f64,
    ) {
        self.stages.push(StageTrace { name, kind, elapsed, bytes_sent, messages, flops });
    }

    /// Total wall-clock time across all stages.
    pub fn total_time(&self) -> Duration {
        self.stages.iter().map(|s| s.elapsed).sum()
    }

    /// Total bytes sent to other ranks.
    pub fn comm_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.bytes_sent).sum()
    }

    /// Total point-to-point messages sent.
    pub fn comm_messages(&self) -> u64 {
        self.stages.iter().map(|s| s.messages).sum()
    }

    /// Total complex-FLOP estimate of local compute.
    pub fn compute_flops(&self) -> f64 {
        self.stages.iter().map(|s| s.flops).sum()
    }

    /// Time spent blocked in exchange waits, as a `Duration`.
    pub fn wait_time(&self) -> Duration {
        Duration::from_nanos(self.wait_ns)
    }

    /// Merge per-rank traces into a critical-path view: per stage, the max
    /// elapsed over ranks and the max bytes/messages (the slowest rank
    /// gates an alltoall). The overlap counters also take the per-rank max.
    pub fn critical_path(traces: &[ExecTrace]) -> ExecTrace {
        assert!(!traces.is_empty());
        let nstages = traces[0].stages.len();
        for t in traces {
            assert_eq!(t.stages.len(), nstages, "ranks disagree on stage count");
        }
        let mut out = ExecTrace::default();
        for i in 0..nstages {
            let s0 = &traces[0].stages[i];
            // `max()` is `None` only on an empty iterator, and `traces`
            // was asserted non-empty; fold to the zero default instead of
            // unwrapping so the closed-world claim is structural.
            out.push(
                s0.name,
                s0.kind,
                traces.iter().map(|t| t.stages[i].elapsed).max().unwrap_or_default(),
                traces.iter().map(|t| t.stages[i].bytes_sent).max().unwrap_or_default(),
                traces.iter().map(|t| t.stages[i].messages).max().unwrap_or_default(),
                traces.iter().map(|t| t.stages[i].flops).fold(0.0, f64::max),
            );
        }
        out.alloc_bytes = traces.iter().map(|t| t.alloc_bytes).max().unwrap_or_default();
        out.wait_ns = traces.iter().map(|t| t.wait_ns).max().unwrap_or_default();
        out.overlap_rounds = traces.iter().map(|t| t.overlap_rounds).max().unwrap_or_default();
        out.pack_overlap_ns =
            traces.iter().map(|t| t.pack_overlap_ns).max().unwrap_or_default();
        out.unpack_overlap_ns =
            traces.iter().map(|t| t.unpack_overlap_ns).max().unwrap_or_default();
        out.worker_busy_ns = traces.iter().map(|t| t.worker_busy_ns).max().unwrap_or_default();
        out.pipeline_overlap_ns =
            traces.iter().map(|t| t.pipeline_overlap_ns).max().unwrap_or_default();
        // A cache hit only counts if *every* rank was served from cache.
        out.plan_cache_hit = traces.iter().all(|t| t.plan_cache_hit);
        out
    }

    /// Short human-readable summary, one line per stage.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for st in &self.stages {
            s.push_str(&format!(
                "{:<24} {:?} {:>10.3?} {:>12} B {:>6} msgs {:>12.0} flops\n",
                st.name, st.kind, st.elapsed, st.bytes_sent, st.messages, st.flops
            ));
        }
        if self.wait_ns > 0 || self.overlap_rounds > 0 {
            s.push_str(&format!(
                "(exchange waits: {:?}, {} rounds overlapped)\n",
                self.wait_time(),
                self.overlap_rounds
            ));
        }
        if self.pack_overlap_ns > 0 || self.unpack_overlap_ns > 0 {
            s.push_str(&format!(
                "(fused pack/unpack overlapped: {:?} / {:?})\n",
                Duration::from_nanos(self.pack_overlap_ns),
                Duration::from_nanos(self.unpack_overlap_ns)
            ));
        }
        if self.worker_busy_ns > 0 || self.pipeline_overlap_ns > 0 {
            s.push_str(&format!(
                "(worker busy: {:?}, pipeline overlap: {:?})\n",
                Duration::from_nanos(self.worker_busy_ns),
                Duration::from_nanos(self.pipeline_overlap_ns)
            ));
        }
        if self.alloc_bytes > 0 {
            s.push_str(&format!("(workspace grew by {} B this execution)\n", self.alloc_bytes));
        }
        s
    }
}

/// Helper to time a closure and record the stage in one call.
pub struct StageTimer<'a> {
    trace: &'a mut ExecTrace,
}

impl<'a> StageTimer<'a> {
    /// Wrap a trace for stage-by-stage recording.
    pub fn new(trace: &'a mut ExecTrace) -> Self {
        StageTimer { trace }
    }

    /// Time a compute stage; `flops` is its complex-FLOP estimate.
    pub fn compute<R>(&mut self, name: &'static str, flops: f64, f: impl FnOnce() -> R) -> R {
        let t0 = std::time::Instant::now();
        let r = f();
        self.trace.push(name, StageKind::Compute, t0.elapsed(), 0, 0, flops);
        r
    }

    /// Time a local reshape stage (no traffic, no FLOPs).
    pub fn reshape<R>(&mut self, name: &'static str, f: impl FnOnce() -> R) -> R {
        let t0 = std::time::Instant::now();
        let r = f();
        self.trace.push(name, StageKind::Reshape, t0.elapsed(), 0, 0, 0.0);
        r
    }

    /// Time a comm stage; `f` must return (result, bytes_sent, messages).
    pub fn comm<R>(&mut self, name: &'static str, f: impl FnOnce() -> (R, u64, u64)) -> R {
        let t0 = std::time::Instant::now();
        let (r, bytes, msgs) = f();
        self.trace.push(name, StageKind::Comm, t0.elapsed(), bytes, msgs, 0.0);
        r
    }

    /// Time an exchange stage that also reports overlap counters; `f` must
    /// return (result, bytes_sent, messages, counters). The counters are
    /// accumulated into the trace's `wait_ns` / `overlap_rounds` /
    /// `pack_overlap_ns` / `unpack_overlap_ns` / `worker_busy_ns`.
    pub fn comm_a2a<R>(
        &mut self,
        name: &'static str,
        f: impl FnOnce() -> (R, u64, u64, A2aCounters),
    ) -> R {
        let t0 = std::time::Instant::now();
        let (r, bytes, msgs, c) = f();
        self.trace.push(name, StageKind::Comm, t0.elapsed(), bytes, msgs, 0.0);
        self.trace.wait_ns += c.wait_ns;
        self.trace.overlap_rounds += c.overlap_rounds;
        self.trace.pack_overlap_ns += c.pack_overlap_ns;
        self.trace.unpack_overlap_ns += c.unpack_overlap_ns;
        self.trace.worker_busy_ns += c.worker_busy_ns;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_records_stages() {
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);
        let v = t.compute("fft_z", 100.0, || 42);
        assert_eq!(v, 42);
        t.comm("a2a", || ((), 1024, 3));
        assert_eq!(trace.stages.len(), 2);
        assert_eq!(trace.comm_bytes(), 1024);
        assert_eq!(trace.comm_messages(), 3);
        assert_eq!(trace.compute_flops(), 100.0);
    }

    #[test]
    fn comm_a2a_accumulates_counters() {
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);
        t.comm_a2a("a2a_1", || {
            (
                (),
                10,
                1,
                A2aCounters {
                    wait_ns: 500,
                    overlap_rounds: 3,
                    pack_overlap_ns: 40,
                    unpack_overlap_ns: 7,
                    worker_busy_ns: 12,
                },
            )
        });
        t.comm_a2a("a2a_2", || {
            (
                (),
                20,
                2,
                A2aCounters {
                    wait_ns: 250,
                    overlap_rounds: 2,
                    pack_overlap_ns: 60,
                    unpack_overlap_ns: 3,
                    worker_busy_ns: 8,
                },
            )
        });
        assert_eq!(trace.wait_ns, 750);
        assert_eq!(trace.overlap_rounds, 5);
        assert_eq!(trace.pack_overlap_ns, 100);
        assert_eq!(trace.unpack_overlap_ns, 10);
        assert_eq!(trace.worker_busy_ns, 20);
        assert_eq!(trace.comm_bytes(), 30);
        assert_eq!(trace.wait_time(), Duration::from_nanos(750));
    }

    #[test]
    fn critical_path_takes_max() {
        let mk = |ms: u64, bytes: u64, alloc: u64, wait: u64, busy: u64, pipe: u64| {
            let mut t = ExecTrace::default();
            t.push("s", StageKind::Comm, Duration::from_millis(ms), bytes, 1, 0.0);
            t.alloc_bytes = alloc;
            t.wait_ns = wait;
            t.worker_busy_ns = busy;
            t.pipeline_overlap_ns = pipe;
            t
        };
        let cp = ExecTrace::critical_path(&[
            mk(5, 10, 0, 100, 30, 2),
            mk(9, 3, 64, 900, 10, 9),
            mk(2, 7, 16, 50, 20, 4),
        ]);
        assert_eq!(cp.stages[0].elapsed, Duration::from_millis(9));
        assert_eq!(cp.stages[0].bytes_sent, 10);
        assert_eq!(cp.alloc_bytes, 64, "slowest-allocating rank gates the view");
        assert_eq!(cp.wait_ns, 900, "longest-waiting rank gates the view");
        assert_eq!(cp.worker_busy_ns, 30, "busiest worker gates the view");
        assert_eq!(cp.pipeline_overlap_ns, 9, "deepest pipeline overlap gates the view");
    }
}
