//! The FFTB planner: from tensor descriptions to an executable distributed
//! transform (paper §3.1, the yellow "intermediate" block: "analyses the
//! distribution patterns of the input/output tensors and constructs the
//! necessary compute and communicate stages").
//!
//! `Fftb::plan` is the rust rendering of the paper's constructor
//! (Fig. 6 line 23):
//!
//! ```c++
//! fftb fx = fftb(sizes, to, "X Y Z", ti, "x y z", g);
//! ```
//!
//! Supported patterns (anything else raises [`FftbError::Unsupported`],
//! exactly as the paper specifies):
//!
//! | input                      | output          | grid | plan |
//! |----------------------------|-----------------|------|------|
//! | dense  `[b] x{0} y z`      | `[B] X Y Z{0}`  | 1D   | slab-pencil |
//! | dense  `[b] x y{0} z{1}`   | `[B] X{0} Y{1} Z` | 2D | pencil |
//! | dense, 3D grid             | same as pencil  | 3D (folded) | pencil |
//! | sphere `[b] x{0} y z` + offsets | `[B] X Y Z{0}` | 1D | plane-wave staged padding |
//! | sphere + [`FftbOptions::real`] | `[B] X Y Z{0}` (nz/2+1 unique bins) | 1D | plane-wave r2c (Hermitian half) |
//!
//! Every plan precomputes its exchange schedules ([`A2aSchedule`]) and owns
//! a reusable [`Workspace`](workspace::Workspace); at execute time the
//! alltoalls run the *fused* windowed overlapped pipeline of
//! [`crate::comm::alltoall`] — per-destination [`PackKernel`]s pack each
//! block straight into its recycled wire buffer as its round posts and
//! unpack each received block as its wait completes — tuned per plan via
//! [`CommTuning`](crate::comm::CommTuning) (`FftbOptions::comm`, or
//! `set_tuning` on a concrete plan). See `docs/ARCHITECTURE.md` ("The
//! exchange pipeline") for the timeline and the plan-time vs execute-time
//! contract.
#![warn(missing_docs)]

pub mod batched;
pub mod pencil;
pub mod planewave;
pub mod real;
pub mod redistribute;
pub mod slab_pencil;
pub mod stages;
pub mod testutil;
pub mod workspace;

use std::sync::Arc;

use crate::comm::alltoall::CommTuning;
use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::ProcGrid;
use crate::fftb::tensor::DistTensor;

pub use batched::{NonBatchedLoop, PlaneWaveLoop};
pub use pencil::PencilPlan;
pub use planewave::{PaddedSpherePlan, PlaneWavePlan};
pub use real::RealPlaneWavePlan;
pub use redistribute::{A2aSchedule, SplitMergeKernel};
pub use slab_pencil::SlabPencilPlan;
pub use stages::{fused_exchange, ExecTrace, PackKernel, StageKind, StageTrace};

/// The concrete stage pipeline the planner selected.
pub enum PlanKind {
    /// Batched slab-pencil on a 1D grid.
    SlabPencil(SlabPencilPlan),
    /// Non-batched loop of single slab-pencil transforms.
    SlabPencilLoop(NonBatchedLoop),
    /// Pencil decomposition on a 2D (or folded 3D) grid.
    Pencil(PencilPlan),
    /// Plane-wave sphere transform with staged padding.
    PlaneWave(PlaneWavePlan),
    /// Non-batched loop of single plane-wave sphere transforms.
    PlaneWaveLoop(PlaneWaveLoop),
    /// Pad-to-cube baseline for sphere inputs.
    PaddedSphere(PaddedSpherePlan),
    /// Real-input (r2c/c2r) plane-wave sphere transform carrying only the
    /// `nz/2 + 1` Hermitian-unique z bins through the exchange.
    PlaneWaveR2c(RealPlaneWavePlan),
}

impl PlanKind {
    /// Human-readable name of the selected pipeline.
    pub fn name(&self) -> &'static str {
        match self {
            PlanKind::SlabPencil(_) => "slab-pencil (1D grid, batched)",
            PlanKind::SlabPencilLoop(_) => "slab-pencil (1D grid, non-batched loop)",
            PlanKind::Pencil(_) => "pencil-pencil (2D grid)",
            PlanKind::PlaneWave(_) => "plane-wave staged padding (1D grid)",
            PlanKind::PlaneWaveLoop(_) => "plane-wave staged padding (1D grid, non-batched loop)",
            PlanKind::PaddedSphere(_) => "sphere padded to cube + slab-pencil",
            PlanKind::PlaneWaveR2c(_) => "plane-wave r2c Hermitian half (1D grid)",
        }
    }
}

/// A constructed distributed Fourier transform (the paper's `fftb` object).
pub struct Fftb {
    /// The concrete stage pipeline the planner selected.
    pub kind: PlanKind,
    /// Global transform sizes `[nx, ny, nz]`.
    pub sizes: [usize; 3],
    /// Batch count derived from the unnamed tensor dimension.
    pub nb: usize,
}

/// Planner options beyond what the tensor descriptions imply.
#[derive(Clone, Copy, Debug, Default)]
pub struct FftbOptions {
    /// Run batched transforms as a loop of single transforms (the paper's
    /// non-batched variants; only meaningful with a batch dimension).
    pub force_non_batched: bool,
    /// For sphere inputs: pad the whole sphere up front and run the dense
    /// plan (the paper's Fig. 2 baseline) instead of staged padding.
    pub pad_sphere_to_cube: bool,
    /// Overlap knobs of the windowed exchanges (window size; default 2).
    pub comm: CommTuning,
    /// Let the tuner pick the exchange window from the cost model instead
    /// of taking `comm.window` (see [`FftbOptions::auto`]). The tensors
    /// still pin the decomposition; use [`Fftb::plan_auto`] to free that
    /// too.
    pub auto_window: bool,
    /// For sphere inputs whose coefficients are real (Γ-point
    /// wavefunctions): select the r2c/c2r plan family. The forward packs
    /// real z-lines through the two-for-one trick and the fused exchange
    /// carries only the `nz/2 + 1` Hermitian-unique bins — roughly half
    /// the wire bytes and z-stage flops of c2c. Through [`Fftb::execute`]
    /// the data stays complex-embedded (imaginary parts ignored on the
    /// way in, zero on the way out); [`RealPlaneWavePlan`] exposes the
    /// native `Vec<f64>` entry points.
    pub real: bool,
}

impl FftbOptions {
    /// Options with automatic exchange-window selection: the planner prices
    /// the selected plan's exchanges on
    /// [`Machine::local_cpu`](crate::model::Machine::local_cpu) across the
    /// window ladder and keeps the cheapest — deterministic across ranks
    /// (the model prices worst-rank stage counts, not this rank's).
    pub fn auto() -> Self {
        FftbOptions { auto_window: true, ..Default::default() }
    }

    /// Options selecting the real-input (r2c/c2r) plan family for sphere
    /// inputs (see the [`FftbOptions::real`] field).
    pub fn real() -> Self {
        FftbOptions { real: true, ..Default::default() }
    }
}

impl Fftb {
    /// Plan a transform of `sizes` from `input` to `output` (Fig. 6/8).
    ///
    /// `in_dims` / `out_dims` name the three transformed dimensions of each
    /// tensor (e.g. `"x y z"` / `"X Y Z"`); a batch dimension, if any, is
    /// whatever tensor dimension is not named.
    pub fn plan(
        sizes: [usize; 3],
        output: &DistTensor,
        out_dims: &str,
        input: &DistTensor,
        in_dims: &str,
        grid: Arc<ProcGrid>,
    ) -> Result<Fftb> {
        Self::plan_opt(sizes, output, out_dims, input, in_dims, grid, FftbOptions::default())
    }

    /// [`Fftb::plan`] with explicit [`FftbOptions`] (non-batched loops,
    /// pad-to-cube baseline, exchange overlap tuning).
    pub fn plan_opt(
        sizes: [usize; 3],
        output: &DistTensor,
        out_dims: &str,
        input: &DistTensor,
        in_dims: &str,
        grid: Arc<ProcGrid>,
        opts: FftbOptions,
    ) -> Result<Fftb> {
        let mut fx = Self::plan_inner(sizes, output, out_dims, input, in_dims, grid, opts)?;
        let tuning = if opts.auto_window {
            let m = crate::model::Machine::local_cpu();
            // Auto-resolution picks the window only; the caller's worker
            // choice rides along unchanged.
            CommTuning::with_window(crate::tuner::search::auto_window_for(&fx, &m))
                .with_worker(opts.comm.worker)
        } else {
            opts.comm
        };
        fx.set_comm_tuning(tuning);
        Ok(fx)
    }

    /// Fully automatic planning: pick the decomposition (slab-pencil vs
    /// pencil grid factorizations vs plane-wave staged padding for sphere
    /// workloads) *and* the exchange window from the tuner's cost model,
    /// build the plan on a grid of the tuner's choosing over `comm`, and
    /// serve repeats from the tuner's [`PlanCache`](crate::tuner::PlanCache).
    ///
    /// Pass a `backend` to enable the tuner's empirical mode
    /// (`Tuner::empirical_top_k`: the model's shortlist is executed once
    /// and the measured winner kept); with `None`, the model's pick is
    /// trusted outright and `empirical_top_k` has no effect.
    ///
    /// Collective over `comm`; every rank must call with identical
    /// arguments and every rank gets the same choice (see
    /// [`Tuner::plan_auto`](crate::tuner::Tuner::plan_auto), which this
    /// forwards to, for the wisdom interplay).
    ///
    /// Convenience alias for the request builder:
    /// `Fftb::request(sizes).nb(nb).sphere_opt(sphere).plan(tuner, comm,
    /// backend)`.
    pub fn plan_auto(
        sizes: [usize; 3],
        nb: usize,
        sphere: Option<Arc<crate::fftb::sphere::OffsetArray>>,
        comm: &crate::comm::communicator::Comm,
        tuner: &mut crate::tuner::Tuner,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<crate::tuner::TunedPlan> {
        tuner.plan_auto(sizes, nb, sphere, comm, backend)
    }

    /// [`Fftb::plan_auto`] for SCF-shaped workloads that alternate forward
    /// and inverse transforms every use (the plane-wave DFT density loop):
    /// the request is tuned, cached and remembered under a round-trip
    /// signature, and the tuner's empirical mode — when enabled — measures
    /// one forward *plus* one inverse execution per candidate instead of
    /// the forward-only probe (see
    /// [`Tuner::plan_auto_scf`](crate::tuner::Tuner::plan_auto_scf)).
    ///
    /// Convenience alias for the request builder:
    /// `Fftb::request(sizes).nb(nb).sphere_opt(sphere)
    /// .workload(WorkloadProfile::RoundTrip).plan(tuner, comm, backend)`.
    pub fn plan_auto_scf(
        sizes: [usize; 3],
        nb: usize,
        sphere: Option<Arc<crate::fftb::sphere::OffsetArray>>,
        comm: &crate::comm::communicator::Comm,
        tuner: &mut crate::tuner::Tuner,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<crate::tuner::TunedPlan> {
        tuner.plan_auto_scf(sizes, nb, sphere, comm, backend)
    }

    /// Plan a real-input (r2c/c2r) sphere transform directly from an offset
    /// array — the ergonomic entry for Γ-point plane-wave workloads that
    /// don't want to spell out tensor descriptions. Equivalent to the
    /// sphere pattern of [`Fftb::plan_opt`] with [`FftbOptions::real`] set;
    /// honors `opts.comm` and `opts.auto_window` the same way.
    pub fn plan_real(
        offsets: Arc<crate::fftb::sphere::OffsetArray>,
        nb: usize,
        grid: Arc<ProcGrid>,
        opts: FftbOptions,
    ) -> Result<Fftb> {
        let sizes = [offsets.nx, offsets.ny, offsets.nz];
        let plan = RealPlaneWavePlan::new(offsets, nb, grid)?;
        let mut fx = Fftb { kind: PlanKind::PlaneWaveR2c(plan), sizes, nb };
        let tuning = if opts.auto_window {
            let m = crate::model::Machine::local_cpu();
            CommTuning::with_window(crate::tuner::search::auto_window_for(&fx, &m))
                .with_worker(opts.comm.worker)
        } else {
            opts.comm
        };
        fx.set_comm_tuning(tuning);
        Ok(fx)
    }

    /// Start an auto-tuned plan request: the one builder behind every
    /// `plan_auto*` entry point. Chain the workload description and finish
    /// with [`PlanRequestBuilder::plan`]:
    ///
    /// ```text
    /// Fftb::request(shape)
    ///     .nb(nb)
    ///     .sphere(offsets)
    ///     .workload(WorkloadProfile::RoundTrip)
    ///     .plan(&mut tuner, &comm, Some(&backend))?
    /// ```
    ///
    /// The builder is the only place a
    /// [`TuneRequest`](crate::tuner::TuneRequest) is assembled; the named
    /// wrappers ([`Fftb::plan_auto`], [`Fftb::plan_auto_scf`],
    /// [`Tuner::plan_auto_real`](crate::tuner::Tuner::plan_auto_real)) are
    /// rustdoc'd convenience aliases over it.
    pub fn request(shape: [usize; 3]) -> PlanRequestBuilder {
        PlanRequestBuilder {
            shape,
            nb: 1,
            sphere: None,
            profile: crate::tuner::WorkloadProfile::Forward,
            real: false,
        }
    }

    fn plan_inner(
        sizes: [usize; 3],
        output: &DistTensor,
        out_dims: &str,
        input: &DistTensor,
        in_dims: &str,
        grid: Arc<ProcGrid>,
        opts: FftbOptions,
    ) -> Result<Fftb> {
        let in_names: Vec<&str> = in_dims.split_whitespace().collect();
        let out_names: Vec<&str> = out_dims.split_whitespace().collect();
        if in_names.len() != 3 || out_names.len() != 3 {
            return Err(FftbError::Unsupported(format!(
                "only 3D transforms are supported (got `{in_dims}` -> `{out_dims}`)"
            )));
        }
        // Locate the transformed dims in each tensor and derive the batch.
        let mut batch_ext = 1usize;
        let in_ext = input.global_extents();
        for (i, d) in input.layout.dims.iter().enumerate() {
            if !in_names.contains(&d.name.as_str()) {
                batch_ext = batch_ext.checked_mul(in_ext[i]).ok_or_else(|| {
                    FftbError::Shape(format!(
                        "batch extent overflows usize at dimension `{}`",
                        d.name
                    ))
                })?;
            }
        }
        for name in &in_names {
            if input.layout.find(name).is_none() {
                return Err(FftbError::Unsupported(format!(
                    "input tensor has no dimension `{name}`"
                )));
            }
        }
        for name in &out_names {
            if output.layout.find(name).is_none() {
                return Err(FftbError::Unsupported(format!(
                    "output tensor has no dimension `{name}`"
                )));
            }
        }
        let nb = batch_ext;

        // Distribution signatures of the transformed dims: which of the
        // three (by position in in_names) is on which grid axis.
        let sig = |t: &DistTensor, names: &[&str]| -> Vec<Option<usize>> {
            names
                .iter()
                // pallas-lint: allow(no-panic) — both loops above returned
                // `Unsupported` for any name missing from either layout,
                // so `find` succeeds for every name reaching this closure.
                .map(|n| t.layout.dims[t.layout.find(n).unwrap()].grid_axis)
                .collect()
        };
        let in_sig = sig(input, &in_names);
        let out_sig = sig(output, &out_names);

        // Sphere input → plane-wave plan.
        if input.is_sphere() {
            if grid.ndim() != 1 {
                return Err(FftbError::Unsupported(
                    "plane-wave transforms require a 1D processing grid".into(),
                ));
            }
            if in_sig != vec![Some(0), None, None] || out_sig != vec![None, None, Some(0)] {
                return Err(FftbError::Unsupported(format!(
                    "plane-wave pattern must distribute input x / output z on axis 0 \
                     (got in={in_sig:?}, out={out_sig:?})"
                )));
            }
            // pallas-lint: allow(no-panic) — `is_sphere()` just confirmed
            // the input carries sphere domains, so `offsets()` is `Some`.
            let off = Arc::clone(input.domains.offsets().unwrap());
            let kind = if opts.real {
                PlanKind::PlaneWaveR2c(RealPlaneWavePlan::new(off, nb, grid)?)
            } else if opts.pad_sphere_to_cube {
                PlanKind::PaddedSphere(PaddedSpherePlan::new(off, nb, grid)?)
            } else if opts.force_non_batched && nb > 1 {
                PlanKind::PlaneWaveLoop(PlaneWaveLoop::new(off, nb, grid)?)
            } else {
                PlanKind::PlaneWave(PlaneWavePlan::new(off, nb, grid)?)
            };
            return Ok(Fftb { kind, sizes, nb });
        }

        // Dense cuboid patterns.
        match grid.ndim() {
            1 => {
                if in_sig != vec![Some(0), None, None] || out_sig != vec![None, None, Some(0)] {
                    return Err(FftbError::Unsupported(format!(
                        "1D-grid pattern must be x{{0}} in / z{{0}} out \
                         (got in={in_sig:?}, out={out_sig:?})"
                    )));
                }
                let kind = if opts.force_non_batched && nb > 1 {
                    PlanKind::SlabPencilLoop(NonBatchedLoop::new(sizes, nb, grid)?)
                } else {
                    PlanKind::SlabPencil(SlabPencilPlan::new(sizes, nb, grid)?)
                };
                Ok(Fftb { kind, sizes, nb })
            }
            2 => {
                if in_sig != vec![None, Some(0), Some(1)]
                    || out_sig != vec![Some(0), Some(1), None]
                {
                    return Err(FftbError::Unsupported(format!(
                        "2D-grid pattern must be y{{0}} z{{1}} in / x{{0}} y{{1}} out \
                         (got in={in_sig:?}, out={out_sig:?})"
                    )));
                }
                Ok(Fftb { kind: PlanKind::Pencil(PencilPlan::new(sizes, nb, grid)?), sizes, nb })
            }
            3 => {
                // Same distribution contract as the 2D arm: the tensors must
                // declare the pencil pattern. Silently folding a mismatched
                // signature would produce a wrong layout, so validate first.
                if in_sig != vec![None, Some(0), Some(1)]
                    || out_sig != vec![Some(0), Some(1), None]
                {
                    return Err(FftbError::Unsupported(format!(
                        "3D-grid (folded pencil) pattern must be y{{0}} z{{1}} in / \
                         x{{0}} y{{1}} out (got in={in_sig:?}, out={out_sig:?})"
                    )));
                }
                // Axis folding: run the pencil plan on the (d0*d1, d2) grid
                // ([`ProcGrid::fold`]). Layout-by-plan means the *plan*
                // defines the local layouts — y is cyclic over the folded
                // d0*d1 ranks, not over axis 0 of the declared 3D grid — so
                // the participating tensors must be declared against
                // `grid.fold()` too. A tensor distributed over the unfolded
                // grid has a different local size on most shapes, and
                // executing with it would silently misplace data; validate
                // the declared sizes against the folded plan and refuse.
                let folded = grid.fold()?;
                let plan = PencilPlan::new(sizes, nb, folded)?;
                if input.local.len() != plan.input_len()
                    || output.local.len() != plan.output_len()
                {
                    return Err(FftbError::Shape(format!(
                        "3D-grid tensors must be distributed over the folded grid \
                         (`ProcGrid::fold`): declared local sizes {} -> {} but the \
                         folded pencil plan expects {} -> {}",
                        input.local.len(),
                        output.local.len(),
                        plan.input_len(),
                        plan.output_len()
                    )));
                }
                Ok(Fftb { kind: PlanKind::Pencil(plan), sizes, nb })
            }
            _ => Err(FftbError::Unsupported("grids beyond 3D are not supported".into())),
        }
    }

    /// Override the exchange overlap knobs (window size) of the selected
    /// plan's alltoalls.
    pub fn set_comm_tuning(&mut self, tuning: CommTuning) {
        match &mut self.kind {
            PlanKind::SlabPencil(p) => p.set_tuning(tuning),
            PlanKind::SlabPencilLoop(p) => p.set_tuning(tuning),
            PlanKind::Pencil(p) => p.set_tuning(tuning),
            PlanKind::PlaneWave(p) => p.set_tuning(tuning),
            PlanKind::PlaneWaveLoop(p) => p.set_tuning(tuning),
            PlanKind::PaddedSphere(p) => p.set_tuning(tuning),
            PlanKind::PlaneWaveR2c(p) => p.set_tuning(tuning),
        }
    }

    /// Execute the transform on this rank's local data.
    ///
    /// Thin owned-storage adapter over [`execute_into`](Self::execute_into):
    /// the output is drawn from the selected plan's recycled slot pool
    /// ([`take_buffer`](Self::take_buffer)) and the consumed input's storage
    /// is [`recycle`](Self::recycle)d back into it, so steady-state loops
    /// stay allocation-free through either entry point.
    pub fn execute(
        &self,
        backend: &dyn LocalFftBackend,
        data: Vec<Complex>,
        dir: Direction,
    ) -> (Vec<Complex>, ExecTrace) {
        let out_len = match dir {
            Direction::Forward => self.output_len(),
            Direction::Inverse => self.input_len(),
        };
        let (mut out, grew) = self.take_buffer(out_len);
        let mut trace = self.execute_into(backend, &data, &mut out, dir);
        trace.alloc_bytes += grew;
        self.recycle(data);
        (out, trace)
    }

    /// Execute the transform reading borrowed `input` and writing the
    /// result into caller-provided `output` — the zero-copy primitive
    /// behind [`execute`](Self::execute). `input.len()` / `output.len()`
    /// must match the direction's expected extents
    /// ([`input_len`](Self::input_len) → [`output_len`](Self::output_len)
    /// forward, swapped for `Inverse`). The result is bit-identical to the
    /// owned-storage path; steady-state executions report
    /// `alloc_bytes == 0` exactly like `execute` once the workspace pools
    /// are warm.
    pub fn execute_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        output: &mut [Complex],
        dir: Direction,
    ) -> ExecTrace {
        match (&self.kind, dir) {
            (PlanKind::SlabPencil(p), _) => p.run_into(backend, input, output, dir),
            (PlanKind::SlabPencilLoop(p), _) => {
                p.run_into(backend, input, output, dir == Direction::Forward)
            }
            (PlanKind::Pencil(p), _) => p.run_into(backend, input, output, dir),
            (PlanKind::PlaneWave(p), Direction::Forward) => p.forward_into(backend, input, output),
            (PlanKind::PlaneWave(p), Direction::Inverse) => p.inverse_into(backend, input, output),
            (PlanKind::PlaneWaveLoop(p), _) => {
                p.run_into(backend, input, output, dir == Direction::Forward)
            }
            (PlanKind::PaddedSphere(p), Direction::Forward) => {
                p.forward_into(backend, input, output)
            }
            (PlanKind::PaddedSphere(p), Direction::Inverse) => {
                p.inverse_into(backend, input, output)
            }
            (PlanKind::PlaneWaveR2c(p), Direction::Forward) => {
                p.forward_embedded_into(backend, input, output)
            }
            (PlanKind::PlaneWaveR2c(p), Direction::Inverse) => {
                p.inverse_embedded_into(backend, input, output)
            }
        }
    }

    /// Check out a buffer of `len` elements from the selected plan's slot
    /// pool, returning it with the bytes of fresh capacity the pool had to
    /// mint (`0` once warm). This is the staging step of the owned-storage
    /// [`execute`](Self::execute) adapter, exposed so callers pairing
    /// [`execute_into`](Self::execute_into) with long-lived owned storage
    /// can draw that storage from the same recycled pool.
    pub fn take_buffer(&self, len: usize) -> (Vec<Complex>, u64) {
        match &self.kind {
            PlanKind::SlabPencil(p) => p.take_pooled(len),
            PlanKind::SlabPencilLoop(p) => p.take_pooled(len),
            PlanKind::Pencil(p) => p.take_pooled(len),
            PlanKind::PlaneWave(p) => p.take_pooled(len),
            PlanKind::PlaneWaveLoop(p) => p.take_pooled(len),
            PlanKind::PaddedSphere(p) => p.take_pooled(len),
            PlanKind::PlaneWaveR2c(p) => p.take_pooled(len),
        }
    }

    /// Local input buffer length expected by `execute(.., Forward)`.
    pub fn input_len(&self) -> usize {
        match &self.kind {
            PlanKind::SlabPencil(p) => p.input_len(),
            PlanKind::SlabPencilLoop(p) => p.input_len(),
            PlanKind::Pencil(p) => p.input_len(),
            PlanKind::PlaneWave(p) => p.input_len(),
            PlanKind::PlaneWaveLoop(p) => p.input_len(),
            PlanKind::PaddedSphere(p) => p.input_len(),
            PlanKind::PlaneWaveR2c(p) => p.input_len(),
        }
    }

    /// Local output buffer length produced by `execute(.., Forward)`.
    pub fn output_len(&self) -> usize {
        match &self.kind {
            PlanKind::SlabPencil(p) => p.output_len(),
            PlanKind::SlabPencilLoop(p) => p.output_len(),
            PlanKind::Pencil(p) => p.output_len(),
            PlanKind::PlaneWave(p) => p.output_len(),
            PlanKind::PlaneWaveLoop(p) => p.output_len(),
            PlanKind::PaddedSphere(p) => p.output_len(),
            PlanKind::PlaneWaveR2c(p) => p.output_len(),
        }
    }

    /// Return a finished buffer to the selected plan's slot pool so later
    /// executions reuse its storage. This is what keeps *forward-only*
    /// call patterns (e.g. repeated G→r sphere transforms whose outputs
    /// the caller consumes) allocation-free: without it the plan must mint
    /// a fresh output per call.
    pub fn recycle(&self, buf: Vec<Complex>) {
        match &self.kind {
            PlanKind::SlabPencil(p) => p.recycle(buf),
            PlanKind::SlabPencilLoop(p) => p.recycle(buf),
            PlanKind::Pencil(p) => p.recycle(buf),
            PlanKind::PlaneWave(p) => p.recycle(buf),
            PlanKind::PlaneWaveLoop(p) => p.recycle(buf),
            PlanKind::PaddedSphere(p) => p.recycle(buf),
            PlanKind::PlaneWaveR2c(p) => p.recycle(buf),
        }
    }
}

/// Fluent description of an auto-tuned plan request (see
/// [`Fftb::request`]). Defaults: `nb = 1`, dense cuboid (no sphere),
/// forward-only workload, complex coefficients.
pub struct PlanRequestBuilder {
    shape: [usize; 3],
    nb: usize,
    sphere: Option<Arc<crate::fftb::sphere::OffsetArray>>,
    profile: crate::tuner::WorkloadProfile,
    real: bool,
}

impl PlanRequestBuilder {
    /// Batch count (transforms per execution).
    pub fn nb(mut self, nb: usize) -> Self {
        self.nb = nb;
        self
    }

    /// Transform a cut-off sphere described by `offsets` instead of the
    /// dense cuboid — selects the plane-wave candidate families.
    pub fn sphere(mut self, offsets: Arc<crate::fftb::sphere::OffsetArray>) -> Self {
        self.sphere = Some(offsets);
        self
    }

    /// [`sphere`](Self::sphere) taking an `Option` — handy for callers
    /// whose sphere-ness is itself a parameter.
    pub fn sphere_opt(mut self, offsets: Option<Arc<crate::fftb::sphere::OffsetArray>>) -> Self {
        self.sphere = offsets;
        self
    }

    /// The coefficients are real (Γ-point wavefunctions): enumerate the
    /// r2c/c2r Hermitian half-spectrum family alongside c2c. Requires a
    /// sphere.
    pub fn real(mut self) -> Self {
        self.real = true;
        self
    }

    /// The cadence the plan will be driven at
    /// ([`WorkloadProfile::RoundTrip`](crate::tuner::WorkloadProfile) for
    /// SCF-shaped forward/inverse loops).
    pub fn workload(mut self, profile: crate::tuner::WorkloadProfile) -> Self {
        self.profile = profile;
        self
    }

    /// Assemble the [`TuneRequest`](crate::tuner::TuneRequest) and hand it
    /// to the tuner ([`Tuner::plan_request`](crate::tuner::Tuner)):
    /// wisdom lookup → model ranking → optional empirical probe → plan
    /// cache. Collective over `comm`; every rank must build an identical
    /// request.
    pub fn plan(
        self,
        tuner: &mut crate::tuner::Tuner,
        comm: &crate::comm::communicator::Comm,
        backend: Option<&dyn LocalFftBackend>,
    ) -> Result<crate::tuner::TunedPlan> {
        let req = crate::tuner::TuneRequest {
            shape: self.shape,
            nb: self.nb,
            p: comm.size(),
            sphere: self.sphere,
            profile: self.profile,
            real: self.real,
        };
        tuner.plan_request(req, comm, backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::domain::{Domain, DomainList};
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    fn cube_tensors(
        grid: &Arc<ProcGrid>,
        n: usize,
        in_layout: &str,
        out_layout: &str,
    ) -> (DistTensor, DistTensor) {
        let d = || Domain::new(vec![0, 0, 0], vec![n as i64 - 1; 3]).unwrap();
        let ti = DistTensor::zeros(DomainList::new(vec![d()]).unwrap(), in_layout, grid.clone())
            .unwrap();
        let to = DistTensor::zeros(DomainList::new(vec![d()]).unwrap(), out_layout, grid.clone())
            .unwrap();
        (ti, to)
    }

    #[test]
    fn planner_selects_slab_pencil() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let (ti, to) = cube_tensors(&grid, 8, "x{0} y z", "X Y Z{0}");
            let fx = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).unwrap();
            assert!(matches!(fx.kind, PlanKind::SlabPencil(_)));
            assert_eq!(fx.nb, 1);
        });
    }

    #[test]
    fn planner_selects_pencil_on_2d_grid() {
        run_world(4, |comm| {
            let grid = ProcGrid::new(&[2, 2], comm).unwrap();
            let (ti, to) = cube_tensors(&grid, 8, "x y{0} z{1}", "X{0} Y{1} Z");
            let fx = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).unwrap();
            assert!(matches!(fx.kind, PlanKind::Pencil(_)));
        });
    }

    #[test]
    fn planner_folds_3d_grid() {
        run_world(8, |comm| {
            let grid = ProcGrid::new(&[2, 2, 2], comm).unwrap();
            // Layout-by-plan: tensors taking part in a 3D-grid plan are
            // declared against the folded (d0*d1, d2) grid, because that is
            // the grid the pencil plan actually distributes over.
            let folded = grid.fold().unwrap();
            assert_eq!(folded.dims(), &[4, 2]);
            let (ti, to) = cube_tensors(&folded, 8, "x y{0} z{1}", "X{0} Y{1} Z");
            let fx = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).unwrap();
            assert!(matches!(fx.kind, PlanKind::Pencil(_)));
            // Declared-tensor sizing and the plan's layouts agree.
            assert_eq!(fx.input_len(), ti.local.len());
            assert_eq!(fx.output_len(), to.local.len());
        });
    }

    #[test]
    fn planner_rejects_3d_tensors_on_the_unfolded_grid() {
        run_world(8, |comm| {
            let grid = ProcGrid::new(&[2, 2, 2], comm).unwrap();
            // Previously this planned "successfully": the tensors say
            // 8 * 4 * 4 = 128 local elements (y and z each cyclic over 2
            // ranks) while the folded plan's layouts say 8 * 2 * 4 = 64
            // (y cyclic over the folded 4 ranks) — executing would read
            // out of step with the declared data. Now it is a typed error.
            let (ti, to) = cube_tensors(&grid, 8, "x y{0} z{1}", "X{0} Y{1} Z");
            assert_ne!(ti.local.len(), 64, "shape chosen so the sizes disagree");
            let e = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).err().unwrap();
            assert!(matches!(e, FftbError::Shape(_)), "got {e:?}");
        });
    }

    #[test]
    fn planner_selects_planewave_for_sphere() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
            let off = Arc::new(spec.offsets());
            let b = Domain::new(vec![0], vec![3]).unwrap();
            let c = Domain::with_offsets(vec![0, 0, 0], vec![7, 7, 7], off).unwrap();
            let ti = DistTensor::zeros(
                DomainList::new(vec![b.clone(), c]).unwrap(),
                "b x{0} y z",
                grid.clone(),
            )
            .unwrap();
            let co = Domain::new(vec![0, 0, 0], vec![7, 7, 7]).unwrap();
            let to = DistTensor::zeros(
                DomainList::new(vec![b, co]).unwrap(),
                "B X Y Z{0}",
                grid.clone(),
            )
            .unwrap();
            let fx = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).unwrap();
            assert!(matches!(fx.kind, PlanKind::PlaneWave(_)));
            assert_eq!(fx.nb, 4);
            assert_eq!(fx.input_len(), ti.local.len());
            assert_eq!(fx.output_len(), to.local.len());
        });
    }

    #[test]
    fn real_option_selects_r2c_plan() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
            let off = Arc::new(spec.offsets());
            // The ergonomic entry point.
            let fx = Fftb::plan_real(
                Arc::clone(&off),
                2,
                grid.clone(),
                FftbOptions::real(),
            )
            .unwrap();
            assert!(matches!(fx.kind, PlanKind::PlaneWaveR2c(_)));
            assert_eq!(fx.sizes, [8, 8, 8]);
            // The tensor pattern with the `real` option routes the same way.
            let b = Domain::new(vec![0], vec![1]).unwrap();
            let c = Domain::with_offsets(vec![0, 0, 0], vec![7, 7, 7], Arc::clone(&off))
                .unwrap();
            let ti = DistTensor::zeros(
                DomainList::new(vec![b.clone(), c]).unwrap(),
                "b x{0} y z",
                grid.clone(),
            )
            .unwrap();
            let co = Domain::new(vec![0, 0, 0], vec![7, 7, 7]).unwrap();
            let to = DistTensor::zeros(
                DomainList::new(vec![b, co]).unwrap(),
                "B X Y Z{0}",
                grid.clone(),
            )
            .unwrap();
            let fx2 = Fftb::plan_opt(
                [8, 8, 8],
                &to,
                "X Y Z",
                &ti,
                "x y z",
                grid,
                FftbOptions::real(),
            )
            .unwrap();
            assert!(matches!(fx2.kind, PlanKind::PlaneWaveR2c(_)));
            assert_eq!(fx2.input_len(), ti.local.len());
            // Output carries only the nz/2+1 Hermitian-unique z bins.
            assert!(fx2.output_len() < to.local.len());
        });
    }

    #[test]
    fn planner_rejects_bad_3d_layout() {
        run_world(8, |comm| {
            let grid = ProcGrid::new(&[2, 2, 2], comm).unwrap();
            // x distributed on axis 0 / z on axis 2 is NOT the folded pencil
            // pattern — the planner used to fold it silently into a wrong
            // layout; now it must refuse.
            let (ti, to) = cube_tensors(&grid, 8, "x{0} y z{1}", "X{0} Y{1} Z");
            let e = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)));
        });
    }

    #[test]
    fn planner_rejects_unknown_patterns() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            // Output distributed in y: not a predefined pattern.
            let (ti, to) = cube_tensors(&grid, 8, "x{0} y z", "X Y{0} Z");
            let e = Fftb::plan([8, 8, 8], &to, "X Y Z", &ti, "x y z", grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)));
        });
    }

    #[test]
    fn planner_rejects_missing_dimension_names() {
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let (ti, to) = cube_tensors(&grid, 4, "x y z", "X Y Z");
            let e = Fftb::plan([4, 4, 4], &to, "X Y Z", &ti, "x y w", grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)));
        });
    }

    #[test]
    fn non_batched_option_changes_kind() {
        run_world(2, |comm| {
            let grid = ProcGrid::new(&[2], comm).unwrap();
            let b = Domain::new(vec![0], vec![3]).unwrap();
            let c = Domain::new(vec![0, 0, 0], vec![7, 7, 7]).unwrap();
            let ti = DistTensor::zeros(
                DomainList::new(vec![b.clone(), c.clone()]).unwrap(),
                "b x{0} y z",
                grid.clone(),
            )
            .unwrap();
            let to = DistTensor::zeros(
                DomainList::new(vec![b, c]).unwrap(),
                "B X Y Z{0}",
                grid.clone(),
            )
            .unwrap();
            let fx = Fftb::plan_opt(
                [8, 8, 8],
                &to,
                "X Y Z",
                &ti,
                "x y z",
                grid,
                FftbOptions { force_non_batched: true, ..Default::default() },
            )
            .unwrap();
            assert!(matches!(fx.kind, PlanKind::SlabPencilLoop(_)));
        });
    }
}
