//! Scatter/gather helpers between a *global* batched cube and the per-rank
//! local layouts of the distributed plans. Used by tests, examples and the
//! benches to stage inputs and validate outputs against the single-node
//! substrate. (Not a test-only module: the examples use it to build
//! demonstration workloads.)
//!
//! Global cubes are `[nb, nx, ny, nz]` column-major, batch fastest.

use crate::fft::complex::{Complex, ZERO};
use crate::fftb::grid::cyclic;

/// Deterministic quasi-random data (no rand dependency).
pub fn phased(n: usize, seed: u64) -> Vec<Complex> {
    (0..n)
        .map(|i| {
            let t = (i as f64 + 0.21 * seed as f64) * 1.618_033_9;
            Complex::new((2.0 * t).sin(), (0.5 + t).cos())
        })
        .collect()
}

/// Extract rank `r`'s x-distributed slice `[nb, lxc, ny, nz]`.
pub fn scatter_cube_x(
    global: &[Complex],
    nb: usize,
    shape: [usize; 3],
    p: usize,
    r: usize,
) -> Vec<Complex> {
    let [nx, ny, nz] = shape;
    assert_eq!(global.len(), nb * nx * ny * nz);
    let lxc = cyclic::local_count(nx, p, r);
    let mut out = Vec::with_capacity(nb * lxc * ny * nz);
    for iz in 0..nz {
        for iy in 0..ny {
            for lx in 0..lxc {
                let gx = cyclic::local_to_global(lx, p, r);
                let src = nb * (gx + nx * (iy + ny * iz));
                out.extend_from_slice(&global[src..src + nb]);
            }
        }
    }
    out
}

/// Assemble the global cube from all ranks' z-distributed slabs
/// `[nb, nx, ny, lzc_r]`.
pub fn gather_cube_z(
    slabs: &[Vec<Complex>],
    nb: usize,
    shape: [usize; 3],
    p: usize,
) -> Vec<Complex> {
    let [nx, ny, nz] = shape;
    assert_eq!(slabs.len(), p);
    let mut out = vec![ZERO; nb * nx * ny * nz];
    for (r, slab) in slabs.iter().enumerate() {
        let lzc = cyclic::local_count(nz, p, r);
        assert_eq!(slab.len(), nb * nx * ny * lzc, "rank {r} slab size");
        for lz in 0..lzc {
            let gz = cyclic::local_to_global(lz, p, r);
            for iy in 0..ny {
                for ix in 0..nx {
                    let src = nb * (ix + nx * (iy + ny * lz));
                    let dst = nb * (ix + nx * (iy + ny * gz));
                    out[dst..dst + nb].copy_from_slice(&slab[src..src + nb]);
                }
            }
        }
    }
    out
}

/// Extract rank `r`'s z-distributed slab `[nb, nx, ny, lzc]`.
pub fn scatter_cube_z(
    global: &[Complex],
    nb: usize,
    shape: [usize; 3],
    p: usize,
    r: usize,
) -> Vec<Complex> {
    let [nx, ny, nz] = shape;
    assert_eq!(global.len(), nb * nx * ny * nz);
    let lzc = cyclic::local_count(nz, p, r);
    let mut out = Vec::with_capacity(nb * nx * ny * lzc);
    for lz in 0..lzc {
        let gz = cyclic::local_to_global(lz, p, r);
        for iy in 0..ny {
            for ix in 0..nx {
                let src = nb * (ix + nx * (iy + ny * gz));
                out.extend_from_slice(&global[src..src + nb]);
            }
        }
    }
    out
}

/// Assemble the global cube from all ranks' x-distributed slices.
pub fn gather_cube_x(
    slices: &[Vec<Complex>],
    nb: usize,
    shape: [usize; 3],
    p: usize,
) -> Vec<Complex> {
    let [nx, ny, nz] = shape;
    let mut out = vec![ZERO; nb * nx * ny * nz];
    for (r, slice) in slices.iter().enumerate() {
        let lxc = cyclic::local_count(nx, p, r);
        assert_eq!(slice.len(), nb * lxc * ny * nz, "rank {r} slice size");
        let mut src = 0;
        for iz in 0..nz {
            for iy in 0..ny {
                for lx in 0..lxc {
                    let gx = cyclic::local_to_global(lx, p, r);
                    let dst = nb * (gx + nx * (iy + ny * iz));
                    out[dst..dst + nb].copy_from_slice(&slice[src..src + nb]);
                    src += nb;
                }
            }
        }
    }
    out
}

/// Extract rank `(r0, r1)`'s slice `[nb, nx, lyc0, lzc1]` for the 2D-grid
/// pencil plan (y cyclic over axis 0, z cyclic over axis 1).
pub fn scatter_cube_yz(
    global: &[Complex],
    nb: usize,
    shape: [usize; 3],
    p0: usize,
    r0: usize,
    p1: usize,
    r1: usize,
) -> Vec<Complex> {
    let [nx, ny, nz] = shape;
    let lyc = cyclic::local_count(ny, p0, r0);
    let lzc = cyclic::local_count(nz, p1, r1);
    let mut out = Vec::with_capacity(nb * nx * lyc * lzc);
    for lz in 0..lzc {
        let gz = cyclic::local_to_global(lz, p1, r1);
        for ly in 0..lyc {
            let gy = cyclic::local_to_global(ly, p0, r0);
            for ix in 0..nx {
                let src = nb * (ix + nx * (gy + ny * gz));
                out.extend_from_slice(&global[src..src + nb]);
            }
        }
    }
    out
}

/// Assemble the global cube from the pencil plan's outputs
/// `[nb, lxc0, lyc1, nz]` (x cyclic over axis 0, y cyclic over axis 1).
/// `slices[r]` comes from grid rank `r = r0 + p0*r1`.
pub fn gather_cube_xy(
    slices: &[Vec<Complex>],
    nb: usize,
    shape: [usize; 3],
    p0: usize,
    p1: usize,
) -> Vec<Complex> {
    let [nx, ny, nz] = shape;
    assert_eq!(slices.len(), p0 * p1);
    let mut out = vec![ZERO; nb * nx * ny * nz];
    for r1 in 0..p1 {
        for r0 in 0..p0 {
            let slice = &slices[r0 + p0 * r1];
            let lxc = cyclic::local_count(nx, p0, r0);
            let lyc = cyclic::local_count(ny, p1, r1);
            assert_eq!(slice.len(), nb * lxc * lyc * nz);
            let mut src = 0;
            for gz in 0..nz {
                for ly in 0..lyc {
                    let gy = cyclic::local_to_global(ly, p1, r1);
                    for lx in 0..lxc {
                        let gx = cyclic::local_to_global(lx, p0, r0);
                        let dst = nb * (gx + nx * (gy + ny * gz));
                        out[dst..dst + nb].copy_from_slice(&slice[src..src + nb]);
                        src += nb;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_gather_x_round_trip() {
        let shape = [5usize, 3, 4];
        let nb = 2;
        let global = phased(nb * 60, 1);
        for p in [1usize, 2, 3] {
            let slices: Vec<_> =
                (0..p).map(|r| scatter_cube_x(&global, nb, shape, p, r)).collect();
            let back = gather_cube_x(&slices, nb, shape, p);
            assert_eq!(back, global, "p={p}");
        }
    }

    #[test]
    fn scatter_gather_z_round_trip() {
        let shape = [4usize, 4, 6];
        let nb = 3;
        let global = phased(nb * 96, 2);
        for p in [1usize, 2, 4] {
            let slabs: Vec<_> =
                (0..p).map(|r| scatter_cube_z(&global, nb, shape, p, r)).collect();
            let back = gather_cube_z(&slabs, nb, shape, p);
            assert_eq!(back, global, "p={p}");
        }
    }

    #[test]
    fn scatter_gather_2d_grid_round_trip() {
        let shape = [4usize, 6, 6];
        let nb = 1;
        let global = phased(144, 3);
        let (p0, p1) = (2usize, 3usize);
        // Build xy-distributed slices by scattering with the output layout,
        // then gather.
        let mut slices = Vec::new();
        for r1 in 0..p1 {
            for r0 in 0..p0 {
                // output layout [nb, lxc0, lyc1, nz]
                let lxc = cyclic::local_count(shape[0], p0, r0);
                let lyc = cyclic::local_count(shape[1], p1, r1);
                let mut s = Vec::new();
                for gz in 0..shape[2] {
                    for ly in 0..lyc {
                        let gy = cyclic::local_to_global(ly, p1, r1);
                        for lx in 0..lxc {
                            let gx = cyclic::local_to_global(lx, p0, r0);
                            let src = nb * (gx + shape[0] * (gy + shape[1] * gz));
                            s.extend_from_slice(&global[src..src + nb]);
                        }
                    }
                }
                slices.push(s);
            }
        }
        let back = gather_cube_xy(&slices, nb, shape, p0, p1);
        assert_eq!(back, global);
    }
}
