//! Batched vs. non-batched execution (paper §4.2, Fig. 9's four cube
//! variants).
//!
//! A batched plan pushes all `nb` transforms through each stage together —
//! one alltoall per stage carrying `nb`-element runs. The non-batched
//! variant "loops 256 times around a distributed 3D Fourier transform"
//! (paper): same total bytes, but `nb`x as many messages, each `nb`x
//! smaller — which is exactly what falls off the latency cliff at scale.
//! [`NonBatchedLoop`] is that cadence over the dense slab-pencil plan;
//! [`PlaneWaveLoop`] is the same cadence over the plane-wave sphere plan
//! (per-band sphere exchanges vs one fused exchange — the pair
//! `tuner::search` prices distinctly through the round count of
//! `model::cost::planewave`).
//!
//! Band staging and the batch-wide output run through the loop's own
//! [`Workspace`]; the inner single-band plan recycles each band vector, so
//! steady-state loops allocate nothing either. Each inner transform drives
//! the fused windowed exchange of its inner plan (per-destination
//! pack kernels, `CommTuning` forwarded through `set_tuning`), and the
//! loop's accumulated trace sums the per-iteration overlap counters
//! (`wait_ns`, `overlap_rounds`, `pack_overlap_ns`, `unpack_overlap_ns`).

use std::sync::{Arc, Mutex};

use crate::comm::alltoall::CommTuning;
use crate::fft::complex::Complex;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::error::Result;
use crate::fftb::grid::ProcGrid;
use crate::fftb::sphere::OffsetArray;

use super::planewave::PlaneWavePlan;
use super::redistribute::{extract_band_into, insert_band};
use super::slab_pencil::SlabPencilPlan;
use super::stages::ExecTrace;
use super::workspace::{ensure, Workspace};

/// Accumulate iteration traces stage-by-stage so a band loop's trace shape
/// matches its batched sibling, with summed time/bytes/messages/counters.
fn accumulate(total: &mut ExecTrace, it: ExecTrace) {
    total.alloc_bytes += it.alloc_bytes;
    total.wait_ns += it.wait_ns;
    total.overlap_rounds += it.overlap_rounds;
    total.pack_overlap_ns += it.pack_overlap_ns;
    total.unpack_overlap_ns += it.unpack_overlap_ns;
    total.worker_busy_ns += it.worker_busy_ns;
    total.pipeline_overlap_ns += it.pipeline_overlap_ns;
    if total.stages.is_empty() {
        total.stages = it.stages;
    } else {
        for (acc, s) in total.stages.iter_mut().zip(it.stages) {
            debug_assert_eq!(acc.name, s.name);
            acc.elapsed += s.elapsed;
            acc.bytes_sent += s.bytes_sent;
            acc.messages += s.messages;
            acc.flops += s.flops;
        }
    }
}

/// Runs an `nb`-batched slab-pencil transform as `nb` independent
/// single-band transforms, each with its own communication stages.
pub struct NonBatchedLoop {
    /// Batch count (independent single transforms per execution).
    pub nb: usize,
    single: SlabPencilPlan,
    ws: Mutex<Workspace>,
}

impl NonBatchedLoop {
    /// Plan `nb` independent single-band slab-pencil transforms of `shape`
    /// on the 1D `grid`.
    pub fn new(shape: [usize; 3], nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        Ok(NonBatchedLoop {
            nb,
            single: SlabPencilPlan::new(shape, 1, grid)?,
            ws: Mutex::new(Workspace::new()),
        })
    }

    /// Override the exchange overlap knobs of the inner single-band plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.single.set_tuning(tuning);
    }

    /// Return a finished batch-wide output buffer to the loop's slot pool.
    pub fn recycle(&self, buf: Vec<Complex>) {
        self.ws.lock().unwrap().slots.recycle(buf);
    }

    /// Check out a batch-wide buffer from the loop's slot pool, reporting
    /// any fresh allocation the take caused.
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        let ctr = std::cell::Cell::new(0u64);
        let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
        (buf, ctr.get())
    }

    /// Rank count of the 1D processing grid the inner plan runs on.
    pub fn grid_size(&self) -> usize {
        self.single.grid_size()
    }

    /// Local input length (`nb` x the single-band input).
    pub fn input_len(&self) -> usize {
        self.nb * self.single.input_len()
    }

    /// Local output length (`nb` x the single-band output).
    pub fn output_len(&self) -> usize {
        self.nb * self.single.output_len()
    }

    /// Owned-storage adapter over [`NonBatchedLoop::run_into`].
    fn run(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
        forward: bool,
    ) -> (Vec<Complex>, ExecTrace) {
        let out_len = if forward { self.output_len() } else { self.input_len() };
        let (mut out, grew) = self.take_pooled(out_len);
        let mut trace = self.run_into(backend, &input, &mut out, forward);
        trace.alloc_bytes += grew;
        self.recycle(input);
        (out, trace)
    }

    /// Band-looped execution into a caller-owned slice: each band is
    /// extracted straight out of the borrowed input and every single-band
    /// result lands in its batch-strided position of `out`.
    pub(crate) fn run_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
        forward: bool,
    ) -> ExecTrace {
        let (in_band, out_band) = if forward {
            (self.single.input_len(), self.single.output_len())
        } else {
            (self.single.output_len(), self.single.input_len())
        };
        assert_eq!(input.len(), self.nb * in_band);
        assert_eq!(out.len(), self.nb * out_band);

        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        // steady-state: non-batched band loop
        // Band staging buffers circulate through the loop workspace; the
        // inner single-band plan audits its own region.
        let mut band = std::mem::take(&mut ws.work);
        let mut trace = ExecTrace::default();
        for b in 0..self.nb {
            ensure(&mut band, in_band, &ws.alloc);
            extract_band_into(input, self.nb, b, &mut band);
            let (res, tr) = if forward {
                self.single.forward(backend, band)
            } else {
                self.single.inverse(backend, band)
            };
            insert_band(out, self.nb, b, &res);
            band = res; // recycle the single plan's output as the next band
            accumulate(&mut trace, tr);
        }
        ws.work = band;
        // steady-state: end
        trace.alloc_bytes += ws.allocated();
        trace
    }

    /// Forward transform: `nb` single-band forward passes, traces summed.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, true)
    }

    /// Inverse transform: `nb` single-band inverse passes, traces summed.
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, false)
    }
}

/// Runs an `nb`-batched plane-wave sphere transform as `nb` independent
/// single-band transforms — the per-band exchange cadence of a DFT code
/// that transforms one wavefunction at a time instead of batching the
/// whole band block (same wire bytes as [`PlaneWavePlan`], `nb`x the
/// messages at `1/nb` the size).
pub struct PlaneWaveLoop {
    /// Batch count (independent single transforms per execution).
    pub nb: usize,
    single: PlaneWavePlan,
    ws: Mutex<Workspace>,
}

impl PlaneWaveLoop {
    /// Plan `nb` independent single-band plane-wave transforms of the
    /// sphere described by `offsets` on the 1D `grid`.
    pub fn new(offsets: Arc<OffsetArray>, nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        Ok(PlaneWaveLoop {
            nb,
            single: PlaneWavePlan::new(offsets, 1, grid)?,
            ws: Mutex::new(Workspace::new()),
        })
    }

    /// Override the exchange overlap knobs of the inner single-band plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.single.set_tuning(tuning);
    }

    /// Return a finished batch-wide output buffer to the loop's slot pool.
    pub fn recycle(&self, buf: Vec<Complex>) {
        self.ws.lock().unwrap().slots.recycle(buf);
    }

    /// Check out a batch-wide buffer from the loop's slot pool, reporting
    /// any fresh allocation the take caused.
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        let ctr = std::cell::Cell::new(0u64);
        let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
        (buf, ctr.get())
    }

    /// Rank count of the 1D processing grid the inner plan runs on.
    pub fn grid_size(&self) -> usize {
        self.single.grid_size()
    }

    /// The sphere offsets the inner single-band plan was built from.
    pub fn offsets(&self) -> &Arc<OffsetArray> {
        &self.single.offsets
    }

    /// Packed local input length (`nb` x the single-band sphere points).
    pub fn input_len(&self) -> usize {
        self.nb * self.single.input_len()
    }

    /// Dense local output length (`nb` x the single-band slab).
    pub fn output_len(&self) -> usize {
        self.nb * self.single.output_len()
    }

    /// Owned-storage adapter over [`PlaneWaveLoop::run_into`].
    fn run(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
        forward: bool,
    ) -> (Vec<Complex>, ExecTrace) {
        let out_len = if forward { self.output_len() } else { self.input_len() };
        let (mut out, grew) = self.take_pooled(out_len);
        let mut trace = self.run_into(backend, &input, &mut out, forward);
        trace.alloc_bytes += grew;
        self.recycle(input);
        (out, trace)
    }

    /// Band-looped execution into a caller-owned slice: each band is
    /// extracted straight out of the borrowed input and every single-band
    /// result lands in its batch-strided position of `out`.
    pub(crate) fn run_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
        forward: bool,
    ) -> ExecTrace {
        let (in_band, out_band) = if forward {
            (self.single.input_len(), self.single.output_len())
        } else {
            (self.single.output_len(), self.single.input_len())
        };
        assert_eq!(input.len(), self.nb * in_band);
        assert_eq!(out.len(), self.nb * out_band);

        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        // steady-state: non-batched band loop
        // Band staging buffers circulate through the loop workspace; the
        // inner single-band plan audits its own region.
        let mut band = std::mem::take(&mut ws.work);
        let mut trace = ExecTrace::default();
        for b in 0..self.nb {
            ensure(&mut band, in_band, &ws.alloc);
            extract_band_into(input, self.nb, b, &mut band);
            let (res, tr) = if forward {
                self.single.forward(backend, band)
            } else {
                self.single.inverse(backend, band)
            };
            insert_band(out, self.nb, b, &res);
            band = res; // recycle the single plan's output as the next band
            accumulate(&mut trace, tr);
        }
        ws.work = band;
        // steady-state: end
        trace.alloc_bytes += ws.allocated();
        trace
    }

    /// Forward transform: `nb` single-band forward passes, traces summed.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, true)
    }

    /// Inverse transform: `nb` single-band inverse passes, traces summed.
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::max_abs_diff;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{phased, scatter_cube_x};

    #[test]
    fn non_batched_matches_batched() {
        let shape = [8usize, 8, 8];
        let nb = 3;
        let p = 2;
        let global = phased(nb * 512, 77);
        let outs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let local = scatter_cube_x(&global, nb, shape, p, grid.rank());
            let backend = RustFftBackend::new();
            let batched = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new(shape, nb, Arc::clone(&grid)).unwrap();
            let (a, tr_a) = batched.forward(&backend, local.clone());
            let (b, tr_b) = looped.forward(&backend, local);
            (max_abs_diff(&a, &b), tr_a.comm_messages(), tr_b.comm_messages())
        });
        for (err, msgs_batched, msgs_looped) in outs {
            assert!(err < 1e-9);
            // Same exchange repeated nb times => nb x the messages.
            assert_eq!(msgs_looped, nb as u64 * msgs_batched);
        }
    }

    #[test]
    fn planewave_loop_matches_batched_planewave() {
        use crate::fftb::sphere::{SphereKind, SphereSpec};
        let n = 8usize;
        let nb = 3;
        let p = 2;
        let spec = SphereSpec::new([n, n, n], 3.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let batched = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
            let looped = PlaneWaveLoop::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
            assert_eq!(batched.input_len(), looped.input_len());
            assert_eq!(batched.output_len(), looped.output_len());
            // Both plans read the same batch-fastest packed-sphere layout.
            let local = phased(batched.input_len(), 5 + grid.rank() as u64);
            let (a, tr_a) = batched.forward(&backend, local.clone());
            let (b, tr_b) = looped.forward(&backend, local);
            let fwd_err = max_abs_diff(&a, &b);
            // Round trip through the loop restores the sphere coefficients.
            let (back, _) = looped.inverse(&backend, b);
            let (want, _) = batched.inverse(&backend, a);
            (fwd_err, max_abs_diff(&back, &want), tr_a.comm_messages(), tr_b.comm_messages())
        });
        for (fwd_err, rt_err, msgs_batched, msgs_looped) in outs {
            assert!(fwd_err < 1e-9, "forward mismatch {fwd_err}");
            assert!(rt_err < 1e-9, "round-trip mismatch {rt_err}");
            // Same exchange repeated nb times => nb x the messages.
            assert_eq!(msgs_looped, nb as u64 * msgs_batched);
        }
    }

    #[test]
    fn non_batched_round_trip() {
        let shape = [4usize, 4, 4];
        let nb = 2;
        let p = 2;
        let global = phased(nb * 64, 8);
        let errs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let local = scatter_cube_x(&global, nb, shape, p, grid.rank());
            let backend = RustFftBackend::new();
            let plan = NonBatchedLoop::new(shape, nb, Arc::clone(&grid)).unwrap();
            let (spec, _) = plan.forward(&backend, local.clone());
            let (back, _) = plan.inverse(&backend, spec);
            max_abs_diff(&back, &local)
        });
        for e in errs {
            assert!(e < 1e-10);
        }
    }
}
