//! The distributed real-input (r2c/c2r) plane-wave sphere transform —
//! Γ-point wavefunctions and densities are real fields, so the full complex
//! pipeline of [`PlaneWavePlan`](super::planewave::PlaneWavePlan) wastes a
//! factor of two everywhere: the z-spectrum of a real line obeys
//! `X[k] == conj(X[n-k])`, meaning only the `nz/2 + 1` Hermitian-unique
//! bins carry information. This plan keeps exactly those bins:
//!
//! 1. `scatter_rz`     — scatter each owned CSR column's *real* z-runs
//!                       pair-packed into a half-length complex line
//!                       (`z[k] = x[2k] + i·x[2k+1]`, the classic
//!                       two-for-one trick of [`crate::fft::real::rfft`]),
//! 2. `pad_rfft_z`     — one *half-length* batched FFT per column,
//! 3. `herm_unpack_z`  — the twiddle pass splitting even/odd parts into the
//!                       `nh = nz/2 + 1` Hermitian-unique bins,
//! 4. `a2a_herm`       — the fused windowed exchange carries **only the
//!                       half spectrum**: send/recv extents are sized on
//!                       `nh`, so wire bytes drop to ~`(nz/2+1)/nz` ≈ 0.5×
//!                       of the c2c exchange for the same sphere,
//! 5. `pad_fft_y`/`fft_x` — ordinary c2c stages over the half-depth slab
//!                       `[nb, nx, ny, lzc_h]` (z cyclic over the `nh` bins).
//!
//! The inverse (`c2r`) mirrors every stage: truncating y/x passes, the
//! half-spectrum exchange reversed, the twiddle re-pack, a half-length
//! inverse FFT and a de-interleaving gather back to packed real
//! coefficients. Output bins `kz < nz/2 + 1` are numerically identical to
//! the c2c plan's — the redundant `kz > nz/2` planes are implied by
//! `X[kx,ky,kz] == conj(X[-kx,-ky,-kz])` and never materialize.
//!
//! The exchange walks are shared with the c2c plan (parameterized on the
//! bin count), but the kernels handed to the fused engine are this module's
//! own Hermitian-aware movers — [`HermFwdKernel`]/[`HermInvKernel`] for the
//! single-threaded engine and the `Herm*Half` pack/unpack splits for the
//! helper-worker engine — so both engines price and move the half spectrum
//! only. All scratch routes through the plan's [`Workspace`] plus a small
//! pool of recycled *real* coefficient buffers, keeping steady-state
//! executions allocation-free like every other plan.

use std::cell::Cell;
use std::sync::{Arc, Mutex};

use crate::comm::alltoall::{
    alltoallv_fused_threaded, CommTuning, PackHalf, UnpackHalf,
};
use crate::comm::arena::WireBuf;
use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;
use crate::fft::twiddle::twiddles;
use crate::fftb::backend::{backend_fft_dim_ws, LocalFftBackend};
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::{cyclic, ProcGrid};
use crate::fftb::sphere::OffsetArray;

use super::planewave::{
    fft_y_disc_panel, pack_col_residues, pack_cols_from_cube, stage_self_block,
    unpack_col_residues, unpack_cols_into_cube,
};
use super::redistribute::A2aSchedule;
use super::stages::{fused_exchange, ExecTrace, PackKernel, StageTimer};
use super::workspace::{ensure, ensure_zeroed, Workspace};

/// Bytes per complex element on the wire.
const ELEM: usize = std::mem::size_of::<Complex>();
/// Recycled real-coefficient buffers retained by the plan.
const MAX_REAL_SLOTS: usize = 4;

/// Batched r2c/c2r plane-wave transform plan for one sphere on a 1D grid.
pub struct RealPlaneWavePlan {
    /// Global offset array of the cut-off sphere.
    pub offsets: Arc<OffsetArray>,
    /// Batch count (transforms per execution).
    pub nb: usize,
    grid: Arc<ProcGrid>,
    /// This rank's restriction of the offset array (x cyclic).
    local_off: OffsetArray,
    /// Sorted distinct x's of the global disc (for the staged y pass).
    disc_xs: Vec<usize>,
    /// Disc columns owned by each rank `q`, in q's local packing order.
    cols_by_rank: Vec<Vec<(usize, usize)>>,
    /// Number of disc columns this rank owns.
    ncols: usize,
    /// Half length of the two-for-one z FFT (`nz / 2`).
    h: usize,
    /// Hermitian-unique z-bin count (`nz / 2 + 1`).
    nh: usize,
    /// This rank's cyclic share of the `nh` unique bins.
    lzc: usize,
    /// Forward half-spectrum exchange (extents sized on `nh`, not `nz`).
    fwd: A2aSchedule,
    /// Inverse exchange (the forward schedule mirrored).
    inv: A2aSchedule,
    /// Overlap knobs of the windowed exchange.
    tuning: CommTuning,
    ws: Mutex<Workspace>,
    /// Recycled real-coefficient buffers: forward consumes one, the inverse
    /// gather draws one — they circulate here so the steady state of a
    /// round-trip loop allocates no real storage either.
    rpool: Mutex<Vec<Vec<f64>>>,
}

/// Fused movers of the forward Hermitian exchange: destination `s`'s
/// z-residues *of the half spectrum* are packed as round `s` posts; each
/// source rank's disc columns land in the zeroed half-depth slab as that
/// round's wait completes. Identical walk to the c2c kernel with the bin
/// count `nh` in place of `nz` — which is exactly what halves the wire.
struct HermFwdKernel<'a> {
    plan: &'a RealPlaneWavePlan,
    /// Hermitian-unique bins `[nb, nh, ncols]` (after `herm_unpack_z`).
    half: &'a [Complex],
    /// Zeroed half-depth output slab `[nb, nx, ny, lzc]`.
    cube: &'a mut [Complex],
}

impl PackKernel for HermFwdKernel<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.plan.fwd.send_counts[dest] * ELEM
    }

    fn recv_bytes(&self, src: usize) -> usize {
        self.plan.fwd.recv_counts[src] * ELEM
    }

    fn pack(&mut self, s: usize, out: &mut WireBuf) {
        let (nb, nh) = (self.plan.nb, self.plan.nh);
        pack_col_residues(self.half, nb, nh, self.plan.ncols, self.plan.p(), s, out);
    }

    fn unpack(&mut self, q: usize, block: &[u8]) {
        let (nb, nx, ny) = (self.plan.nb, self.plan.offsets.nx, self.plan.offsets.ny);
        let cols = &self.plan.cols_by_rank[q];
        unpack_cols_into_cube(block, cols, nb, nx, ny, self.plan.lzc, self.cube);
    }
}

/// Fused movers of the inverse Hermitian exchange (half-depth slab back to
/// half-spectrum columns).
struct HermInvKernel<'a> {
    plan: &'a RealPlaneWavePlan,
    /// The half-depth slab (after the truncating y pass).
    cube: &'a [Complex],
    /// Hermitian-unique bins `[nb, nh, ncols]` being reassembled.
    half: &'a mut [Complex],
}

impl PackKernel for HermInvKernel<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.plan.inv.send_counts[dest] * ELEM
    }

    fn recv_bytes(&self, src: usize) -> usize {
        self.plan.inv.recv_counts[src] * ELEM
    }

    fn pack(&mut self, q: usize, out: &mut WireBuf) {
        let (nb, nx, ny) = (self.plan.nb, self.plan.offsets.nx, self.plan.offsets.ny);
        let cols = &self.plan.cols_by_rank[q];
        pack_cols_from_cube(self.cube, cols, nb, nx, ny, self.plan.lzc, out);
    }

    fn unpack(&mut self, s: usize, block: &[u8]) {
        let (nb, nh) = (self.plan.nb, self.plan.nh);
        unpack_col_residues(block, nb, nh, self.plan.ncols, self.plan.p(), s, self.half);
    }
}

/// Read-only pack half of the forward Hermitian exchange for the threaded
/// engine (worker mode): shares only `Sync` slices with the helper.
struct HermFwdPackHalf<'a> {
    counts: &'a [usize],
    nb: usize,
    nh: usize,
    ncols: usize,
    p: usize,
    half: &'a [Complex],
}

impl PackHalf for HermFwdPackHalf<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.counts[dest] * ELEM
    }

    fn pack(&self, s: usize, out: &mut WireBuf) {
        pack_col_residues(self.half, self.nb, self.nh, self.ncols, self.p, s, out);
    }
}

/// Write-only unpack half of the forward Hermitian exchange: exclusively
/// owns the half-depth output slab.
struct HermFwdUnpackHalf<'a> {
    counts: &'a [usize],
    cols_by_rank: &'a [Vec<(usize, usize)>],
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    cube: &'a mut [Complex],
}

impl UnpackHalf for HermFwdUnpackHalf<'_> {
    fn recv_bytes(&self, src: usize) -> usize {
        self.counts[src] * ELEM
    }

    fn unpack(&mut self, q: usize, block: &[u8]) {
        let cols = &self.cols_by_rank[q];
        unpack_cols_into_cube(block, cols, self.nb, self.nx, self.ny, self.lzc, self.cube);
    }
}

/// Read-only pack half of the inverse Hermitian exchange.
struct HermInvPackHalf<'a> {
    counts: &'a [usize],
    cols_by_rank: &'a [Vec<(usize, usize)>],
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    cube: &'a [Complex],
}

impl PackHalf for HermInvPackHalf<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.counts[dest] * ELEM
    }

    fn pack(&self, q: usize, out: &mut WireBuf) {
        let cols = &self.cols_by_rank[q];
        pack_cols_from_cube(self.cube, cols, self.nb, self.nx, self.ny, self.lzc, out);
    }
}

/// Write-only unpack half of the inverse Hermitian exchange.
struct HermInvUnpackHalf<'a> {
    counts: &'a [usize],
    nb: usize,
    nh: usize,
    ncols: usize,
    p: usize,
    half: &'a mut [Complex],
}

impl UnpackHalf for HermInvUnpackHalf<'_> {
    fn recv_bytes(&self, src: usize) -> usize {
        self.counts[src] * ELEM
    }

    fn unpack(&mut self, s: usize, block: &[u8]) {
        unpack_col_residues(block, self.nb, self.nh, self.ncols, self.p, s, self.half);
    }
}

impl RealPlaneWavePlan {
    /// Plan a batched real-input plane-wave sphere transform for `offsets`
    /// with batch `nb` on the 1D `grid`. Requires even `nz >= 2` (the
    /// two-for-one z packing) and `p <= nx`, `p <= nz/2 + 1` (every rank
    /// must own at least one x column and one Hermitian-unique z bin).
    pub fn new(offsets: Arc<OffsetArray>, nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        assert_eq!(grid.ndim(), 1, "r2c plane-wave plan requires a 1D processing grid");
        let nz = offsets.nz;
        if nz < 2 || nz % 2 != 0 {
            return Err(FftbError::Shape(format!(
                "r2c plane-wave plan requires even nz >= 2 for the two-for-one \
                 z packing, got nz={nz}"
            )));
        }
        let h = nz / 2;
        let nh = h + 1;
        let p = grid.size();
        if p > offsets.nx || p > nh {
            return Err(FftbError::Unsupported(format!(
                "r2c plane-wave plan needs p <= nx and p <= nz/2+1 \
                 (p={p}, grid {}x{}x{}, {nh} Hermitian-unique bins)",
                offsets.nx, offsets.ny, offsets.nz
            )));
        }
        let r = grid.rank();
        let local_off = offsets.restrict_x_cyclic(p, r);
        let mut disc_xs: Vec<usize> = offsets
            .x_runs()
            .iter()
            .flat_map(|&(x0, len)| x0 as usize..(x0 as usize + len as usize))
            .collect();
        disc_xs.sort_unstable();

        let cols_by_rank: Vec<Vec<(usize, usize)>> = (0..p)
            .map(|q| {
                let lnx = cyclic::local_count(offsets.nx, p, q);
                let mut cols = Vec::new();
                for y in 0..offsets.ny {
                    for lx in 0..lnx {
                        let gx = cyclic::local_to_global(lx, p, q);
                        if offsets.col_nonempty(gx, y) {
                            cols.push((gx, y));
                        }
                    }
                }
                cols
            })
            .collect();
        let ncols = cols_by_rank[r].len();
        let lzc = cyclic::local_count(nh, p, r);

        // Forward: to rank s go, for each owned column, s's residues of the
        // nh unique bins — the c2c schedule with nz replaced by nh, which is
        // the entire wire saving.
        let send_counts: Vec<usize> =
            (0..p).map(|s| nb * ncols * cyclic::local_count(nh, p, s)).collect();
        let recv_counts: Vec<usize> =
            (0..p).map(|q| nb * cols_by_rank[q].len() * lzc).collect();
        let fwd = A2aSchedule::new(send_counts, recv_counts, r);
        let inv = fwd.reversed();

        Ok(RealPlaneWavePlan {
            offsets,
            nb,
            grid,
            local_off,
            disc_xs,
            cols_by_rank,
            ncols,
            h,
            nh,
            lzc,
            fwd,
            inv,
            tuning: CommTuning::default(),
            ws: Mutex::new(Workspace::new()),
            rpool: Mutex::new(Vec::new()),
        })
    }

    /// Override the exchange overlap knobs (window size, worker) for this
    /// plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.tuning = tuning;
    }

    /// Return a finished complex output buffer (the half-depth slab) to the
    /// plan's slot pool.
    pub fn recycle(&self, buf: Vec<Complex>) {
        self.ws.lock().unwrap().slots.recycle(buf);
    }

    /// Check out a complex buffer of `len` elements from the slot pool,
    /// reporting capacity growth — the staging step of the owned-storage
    /// adapters wrapped around the `_into` primitives.
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        let ctr = Cell::new(0u64);
        let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
        (buf, ctr.get())
    }

    /// Return a finished real coefficient buffer (an inverse output the
    /// caller is done with) to the plan's real pool.
    pub fn recycle_real(&self, buf: Vec<f64>) {
        let mut pool = self.rpool.lock().unwrap();
        if pool.len() < MAX_REAL_SLOTS && buf.capacity() > 0 {
            pool.push(buf);
        }
    }

    /// Check out a real buffer of exactly `len` elements from the pool,
    /// counting capacity growth into `ctr` (the real-side analogue of
    /// [`super::workspace::SlotPool::take`]).
    fn take_real(&self, len: usize, ctr: &Cell<u64>) -> Vec<f64> {
        let mut buf = self.rpool.lock().unwrap().pop().unwrap_or_default();
        let cap0 = buf.capacity();
        buf.resize(len, 0.0);
        buf.truncate(len);
        if buf.capacity() > cap0 {
            let grown = (buf.capacity() - cap0) * std::mem::size_of::<f64>();
            ctr.set(ctr.get() + grown as u64);
        }
        buf
    }

    fn p(&self) -> usize {
        self.grid.size()
    }

    /// Rank count of the 1D processing grid this plan runs on.
    pub fn grid_size(&self) -> usize {
        self.grid.size()
    }

    /// Packed local input length in *real* coefficients
    /// (`nb` x locally-owned sphere points).
    pub fn input_len(&self) -> usize {
        self.nb * self.local_off.total()
    }

    /// Dense local output length `[nb, nx, ny, lzc]`, z cyclic over the
    /// `nz/2 + 1` Hermitian-unique bins.
    pub fn output_len(&self) -> usize {
        self.nb * self.offsets.nx * self.offsets.ny * self.lzc
    }

    /// Hermitian-unique z-bin count (`nz/2 + 1`) — the z extent of the
    /// distributed output.
    pub fn unique_bins(&self) -> usize {
        self.nh
    }

    /// Scatter packed real coefficients into pair-packed half-length
    /// complex z-lines: run element at global `z` lands in slot `z/2`,
    /// even z's in the real part, odd z's in the imaginary part
    /// (`z[k] = x[2k] + i·x[2k+1]`).
    fn scatter_real_pairs(&self, input: &[f64], work: &mut [Complex]) {
        let (nb, h) = (self.nb, self.h);
        let loc = &self.local_off;
        let mut ci = 0usize;
        for y in 0..loc.ny {
            for x in 0..loc.nx {
                if !loc.col_nonempty(x, y) {
                    continue;
                }
                let mut e = loc.col_offset(x, y);
                let base = ci * nb * h;
                for &(z0, len) in loc.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        let dst = base + nb * (z / 2);
                        let src = nb * e;
                        if z % 2 == 0 {
                            for b in 0..nb {
                                work[dst + b].re = input[src + b];
                            }
                        } else {
                            for b in 0..nb {
                                work[dst + b].im = input[src + b];
                            }
                        }
                        e += 1;
                    }
                }
                ci += 1;
            }
        }
    }

    /// De-interleave the half-length inverse-FFT output back into packed
    /// real coefficients — the exact inverse walk of
    /// [`scatter_real_pairs`](Self::scatter_real_pairs).
    fn gather_real_pairs(&self, work: &[Complex], out: &mut [f64]) {
        let (nb, h) = (self.nb, self.h);
        let loc = &self.local_off;
        let mut ci = 0usize;
        for y in 0..loc.ny {
            for x in 0..loc.nx {
                if !loc.col_nonempty(x, y) {
                    continue;
                }
                let mut e = loc.col_offset(x, y);
                let base = ci * nb * h;
                for &(z0, len) in loc.col_runs(x, y) {
                    for z in z0 as usize..(z0 + len) as usize {
                        let src = base + nb * (z / 2);
                        let dst = nb * e;
                        if z % 2 == 0 {
                            for b in 0..nb {
                                out[dst + b] = work[src + b].re;
                            }
                        } else {
                            for b in 0..nb {
                                out[dst + b] = work[src + b].im;
                            }
                        }
                        e += 1;
                    }
                }
                ci += 1;
            }
        }
    }

    /// Forward r2c: packed real sphere coefficients → half-depth complex
    /// slab `[nb, nx, ny, lzc]`, z cyclic over the `nz/2 + 1` unique bins.
    /// The consumed input's storage joins the plan's real pool. Thin
    /// owned-storage adapter over [`forward_into`](Self::forward_into).
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<f64>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut out, grew) = self.take_pooled(self.output_len());
        let mut trace = self.forward_into(backend, &input, &mut out);
        trace.alloc_bytes += grew;
        // The consumed real input's storage joins the plan's real pool.
        self.recycle_real(input);
        (out, trace)
    }

    /// Forward r2c into caller-provided storage: borrowed packed real
    /// coefficients in, the half-depth slab overwritten in place. The
    /// pair-pack scatter reads the borrowed input directly and the fused
    /// exchange lands received columns straight in `out`, so neither end of
    /// the transform is ever copied into owned storage.
    pub fn forward_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[f64],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(input.len(), self.input_len(), "r2c forward: wrong input length");
        assert_eq!(out.len(), self.output_len(), "r2c forward: wrong output length");
        let comm = self.grid.axis_comm(0);
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let (ncols, h, nh, lzc) = (self.ncols, self.h, self.nh, self.lzc);
        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        let Workspace { fft, work, panel, slots, alloc, .. } = ws;
        let alloc = &*alloc;
        let mut half = Vec::new();
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);

        // steady-state: r2c plane-wave forward
        // All storage below is workspace-pooled, plan-pooled (the real
        // buffers), caller-owned or arena-backed; `trace.alloc_bytes`
        // audits the pooled part.
        // 1. Pair-pack the real z-runs: [nb, h, ncols] with
        //    z[k] = x[2k] + i·x[2k+1] per column line, zero-padded.
        t.reshape("scatter_rz", || {
            ensure_zeroed(&mut *work, nb * h * ncols, alloc);
            self.scatter_real_pairs(input, &mut *work);
        });

        // 2. One *half-length* FFT per (band, column) line — the flop half
        //    of the two-for-one saving.
        t.compute("pad_rfft_z", backend.flops(nb * h * ncols, h), || {
            backend_fft_dim_ws(
                backend,
                &mut *work,
                &[nb, h, ncols],
                1,
                Direction::Forward,
                &mut *fft,
                alloc,
            );
        });

        // 3. Twiddle unpack into the nh = h+1 Hermitian-unique bins:
        //    X[k] = E[k] + w^k·O[k] with E/O the even/odd parts recovered
        //    from Z[k] and conj(Z[h-k]). Every element is written.
        let tw = twiddles(nz, Direction::Forward);
        t.reshape("stage_half", || {
            half = slots.take(nb * nh * ncols, alloc);
        });
        t.compute("herm_unpack_z", 8.0 * (nb * nh * ncols) as f64, || {
            for c in 0..ncols {
                let zbase = c * nb * h;
                let hbase = c * nb * nh;
                for k in 0..=h {
                    let src_k = zbase + nb * (if k == h { 0 } else { k });
                    let src_c = zbase + nb * ((h - k) % h);
                    let dst = hbase + nb * k;
                    let w = if k == h { Complex::new(-1.0, 0.0) } else { tw[k] };
                    for b in 0..nb {
                        let zk = work[src_k + b];
                        let zc = work[src_c + b].conj();
                        let e = (zk + zc).scale(0.5);
                        let o = (zk - zc).scale(0.5).mul_neg_i();
                        half[dst + b] = e + w * o;
                    }
                }
            }
        });

        // 4. Zero the half-depth slab the received columns land in — the
        //    caller's storage, so nothing is staged from the pool here.
        t.reshape("stage_cube", || {
            out.fill(ZERO);
        });

        // 5. Fused Hermitian exchange — identical discipline to the c2c
        //    sphere exchange, but every extent is sized on nh, so the wire
        //    carries ~(nz/2+1)/nz of the c2c bytes.
        t.comm_a2a("a2a_herm", || {
            let c = if self.tuning.worker {
                let pack = HermFwdPackHalf {
                    counts: &self.fwd.send_counts,
                    nb,
                    nh,
                    ncols,
                    p: self.p(),
                    half: &half[..],
                };
                let mut unpack = HermFwdUnpackHalf {
                    counts: &self.fwd.recv_counts,
                    cols_by_rank: &self.cols_by_rank,
                    nb,
                    nx,
                    ny,
                    lzc,
                    cube: &mut out[..],
                };
                stage_self_block(comm, &pack, &mut unpack);
                alltoallv_fused_threaded(comm, &pack, &mut unpack, self.tuning)
            } else {
                let mut k = HermFwdKernel { plan: self, half: &half[..], cube: &mut out[..] };
                fused_exchange(comm, &mut k, self.tuning)
            };
            ((), self.fwd.bytes_remote(), self.fwd.msgs(), c)
        });
        slots.recycle(std::mem::take(&mut half));

        // 6. y lines only where the disc has data, over the half-depth slab.
        let y_lines: f64 =
            (nb * self.disc_xs.len() * lzc) as f64 * crate::fft::batch::fft_flops(ny);
        t.compute("pad_fft_y", y_lines, || {
            fft_y_disc_panel(
                backend,
                out,
                Direction::Forward,
                nb,
                nx,
                ny,
                lzc,
                &self.disc_xs,
                &mut *panel,
                &mut *fft,
                alloc,
            );
        });

        // 7. Dense FFT along x.
        t.compute("fft_x", backend.flops(out.len(), nx), || {
            backend_fft_dim_ws(
                backend,
                out,
                &[nb, nx, ny, lzc],
                1,
                Direction::Forward,
                &mut *fft,
                alloc,
            );
        });
        // steady-state: end
        trace.alloc_bytes = alloc.get();
        trace
    }

    /// Inverse c2r: half-depth complex slab → packed real sphere
    /// coefficients. Exact inverse of [`forward`](Self::forward) (including
    /// the 1/n normalization); the consumed slab joins the slot pool. Thin
    /// owned-storage adapter over [`inverse_into`](Self::inverse_into).
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        cube: Vec<Complex>,
    ) -> (Vec<f64>, ExecTrace) {
        let ctr = Cell::new(0u64);
        let mut packed = self.take_real(self.input_len(), &ctr);
        let mut trace = self.inverse_into(backend, &cube, &mut packed);
        trace.alloc_bytes += ctr.get();
        self.recycle(cube);
        (packed, trace)
    }

    /// Inverse c2r into caller-provided storage: the borrowed half-depth
    /// slab is copied once into workspace staging (the truncating x/y
    /// passes mutate in place), and the de-interleaving gather writes the
    /// packed real coefficients straight into `out`.
    pub fn inverse_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [f64],
    ) -> ExecTrace {
        assert_eq!(input.len(), self.output_len(), "c2r inverse: wrong input length");
        assert_eq!(out.len(), self.input_len(), "c2r inverse: wrong output length");
        let comm = self.grid.axis_comm(0);
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let (ncols, h, nh, lzc) = (self.ncols, self.h, self.nh, self.lzc);
        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        let Workspace { fft, work, panel, slots, stage, alloc, .. } = ws;
        let alloc = &*alloc;
        let mut half = Vec::new();
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);

        // steady-state: r2c plane-wave inverse
        // 1. Copy the borrowed slab into workspace staging, then the dense
        //    inverse FFT along x.
        t.compute("ifft_x", backend.flops(input.len(), nx), || {
            ensure(&mut *stage, input.len(), alloc);
            stage.copy_from_slice(input);
            backend_fft_dim_ws(
                backend,
                stage,
                &[nb, nx, ny, lzc],
                1,
                Direction::Inverse,
                &mut *fft,
                alloc,
            );
        });

        // 2. Inverse FFT along y, only the disc x-extent.
        let y_lines: f64 =
            (nb * self.disc_xs.len() * lzc) as f64 * crate::fft::batch::fft_flops(ny);
        t.compute("trunc_ifft_y", y_lines, || {
            fft_y_disc_panel(
                backend,
                stage,
                Direction::Inverse,
                nb,
                nx,
                ny,
                lzc,
                &self.disc_xs,
                &mut *panel,
                &mut *fft,
                alloc,
            );
        });

        // 3. Stage the half-spectrum column buffer the merge lands in
        //    (every element is overwritten by the unpacks across source
        //    ranks — the s-residues of 0..p cover all nh bins).
        t.reshape("stage_half", || {
            half = slots.take(nb * nh * ncols, alloc);
        });

        // 4. Fused Hermitian exchange, reversed.
        t.comm_a2a("a2a_herm", || {
            let c = if self.tuning.worker {
                let pack = HermInvPackHalf {
                    counts: &self.inv.send_counts,
                    cols_by_rank: &self.cols_by_rank,
                    nb,
                    nx,
                    ny,
                    lzc,
                    cube: &stage[..],
                };
                let mut unpack = HermInvUnpackHalf {
                    counts: &self.inv.recv_counts,
                    nb,
                    nh,
                    ncols,
                    p: self.p(),
                    half: &mut half[..],
                };
                stage_self_block(comm, &pack, &mut unpack);
                alltoallv_fused_threaded(comm, &pack, &mut unpack, self.tuning)
            } else {
                let mut k = HermInvKernel { plan: self, cube: &stage[..], half: &mut half[..] };
                fused_exchange(comm, &mut k, self.tuning)
            };
            ((), self.inv.bytes_remote(), self.inv.msgs(), c)
        });

        // 5. Twiddle re-pack: Z[k] = E[k] + i·O[k] with E/O recovered from
        //    the half spectrum (every element of the h-line is written).
        let tw = twiddles(nz, Direction::Inverse);
        t.compute("herm_pack_z", 8.0 * (nb * h * ncols) as f64, || {
            ensure(&mut *work, nb * h * ncols, alloc);
            for c in 0..ncols {
                let zbase = c * nb * h;
                let hbase = c * nb * nh;
                for k in 0..h {
                    let src_k = hbase + nb * k;
                    let src_c = hbase + nb * (h - k);
                    let dst = zbase + nb * k;
                    for b in 0..nb {
                        let xk = half[src_k + b];
                        let xc = half[src_c + b].conj();
                        let e = (xk + xc).scale(0.5);
                        let o = (xk - xc).scale(0.5) * tw[k];
                        work[dst + b] = e + o.mul_i();
                    }
                }
            }
        });

        // 6. Half-length inverse FFT per line (includes the 1/h factor; the
        //    twiddle pass supplies the rest of the 1/nz normalization).
        t.compute("irfft_z", backend.flops(nb * h * ncols, h), || {
            backend_fft_dim_ws(
                backend,
                &mut *work,
                &[nb, h, ncols],
                1,
                Direction::Inverse,
                &mut *fft,
                alloc,
            );
        });

        // 7. De-interleave straight into the caller's packed real output.
        t.reshape("gather_rz", || {
            self.gather_real_pairs(work, out);
        });
        slots.recycle(std::mem::take(&mut half));
        // steady-state: end
        trace.alloc_bytes = alloc.get();
        trace
    }

    /// Forward r2c on complex-embedded input (imaginary parts ignored) —
    /// the adapter behind [`Fftb::execute`](crate::fftb::plan::Fftb) so the
    /// tuner's empirical probes and the service lanes drive this plan
    /// through the same `Vec<Complex>` interface as every other plan.
    pub fn forward_embedded(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut out, grew) = self.take_pooled(self.output_len());
        let mut trace = self.forward_embedded_into(backend, &input, &mut out);
        trace.alloc_bytes += grew;
        self.ws.lock().unwrap().slots.recycle(input);
        (out, trace)
    }

    /// Borrowed-storage form of [`forward_embedded`](Self::forward_embedded):
    /// the real parts of the borrowed complex coefficients are strided into
    /// a pooled real buffer, and the transform lands in `out` directly.
    pub fn forward_embedded_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(input.len(), self.input_len(), "r2c forward: wrong input length");
        let ctr = Cell::new(0u64);
        let mut reals = self.take_real(self.input_len(), &ctr);
        for (r, c) in reals.iter_mut().zip(input) {
            *r = c.re;
        }
        let mut trace = self.forward_into(backend, &reals, out);
        self.recycle_real(reals);
        trace.alloc_bytes += ctr.get();
        trace
    }

    /// Inverse c2r returning complex-embedded output (`re` carries the real
    /// coefficients, `im` is zero) — the [`Fftb::execute`] adapter's mirror.
    pub fn inverse_embedded(
        &self,
        backend: &dyn LocalFftBackend,
        cube: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut out, grew) = self.take_pooled(self.input_len());
        let mut trace = self.inverse_embedded_into(backend, &cube, &mut out);
        trace.alloc_bytes += grew;
        self.recycle(cube);
        (out, trace)
    }

    /// Borrowed-storage form of [`inverse_embedded`](Self::inverse_embedded):
    /// the packed real coefficients are gathered into a pooled real buffer
    /// and re-embedded (`im == 0`) into the caller's complex output.
    pub fn inverse_embedded_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(out.len(), self.input_len(), "c2r inverse: wrong output length");
        let ctr = Cell::new(0u64);
        let mut reals = self.take_real(self.input_len(), &ctr);
        let mut trace = self.inverse_into(backend, input, &mut reals);
        for (o, &r) in out.iter_mut().zip(&reals) {
            *o = Complex::new(r, 0.0);
        }
        self.recycle_real(reals);
        trace.alloc_bytes += ctr.get();
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::max_abs_diff;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::planewave::PlaneWavePlan;
    use crate::fftb::plan::testutil::gather_cube_z;
    use crate::fftb::sphere::{SphereKind, SphereSpec};

    /// Deterministic real sphere coefficients.
    fn real_coeffs(n: usize, seed: u64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64 + 1.0) * 0.7341 + seed as f64 * 0.377).sin()).collect()
    }

    /// Split global packed real coefficients into rank `r`'s packed vector
    /// (x cyclic), batch fastest — mirror of the c2c test scatter.
    fn scatter_sphere_real(
        off: &OffsetArray,
        packed: &[f64],
        nb: usize,
        p: usize,
        r: usize,
    ) -> Vec<f64> {
        let loc = off.restrict_x_cyclic(p, r);
        let mut out = Vec::with_capacity(nb * loc.total());
        for y in 0..off.ny {
            for lx in 0..loc.nx {
                let gx = cyclic::local_to_global(lx, p, r);
                let e0 = off.col_offset(gx, y);
                let n = off.col_len(gx, y);
                out.extend_from_slice(&packed[nb * e0..nb * (e0 + n)]);
            }
        }
        out
    }

    /// Acceptance: the distributed r2c forward agrees with the c2c plan on
    /// every Hermitian-unique bin to <= 1e-12, the round trip restores the
    /// real input to <= 1e-12, and the fused exchange moves strictly under
    /// 0.6x the c2c plan's bytes — on p in {1, 2, 4}.
    #[test]
    fn r2c_matches_c2c_and_halves_the_wire() {
        let n = 16;
        let nh = n / 2 + 1;
        let spec = SphereSpec::new([n, n, n], 4.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let reals = real_coeffs(nb * off.total(), 11);
        for p in [1usize, 2, 4] {
            let off2 = Arc::clone(&off);
            let reals2 = reals.clone();
            let outs = run_world(p, move |comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let local = scatter_sphere_real(&off2, &reals2, nb, p, grid.rank());

                let rp =
                    RealPlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
                let (hcube, tr_r) = rp.forward(&backend, local.clone());

                let cp = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
                let clocal: Vec<Complex> =
                    local.iter().map(|&v| Complex::new(v, 0.0)).collect();
                let (ccube, tr_c) = cp.forward(&backend, clocal);

                // Round trip back to the packed real coefficients.
                let (back, _) = rp.inverse(&backend, hcube.clone());
                let rt_err = back
                    .iter()
                    .zip(&local)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                (hcube, ccube, tr_r.comm_bytes(), tr_c.comm_bytes(), rt_err)
            });

            let hcubes: Vec<Vec<Complex>> = outs.iter().map(|o| o.0.clone()).collect();
            let ccubes: Vec<Vec<Complex>> = outs.iter().map(|o| o.1.clone()).collect();
            let half = gather_cube_z(&hcubes, nb, [n, n, nh], p);
            let full = gather_cube_z(&ccubes, nb, [n, n, n], p);
            // Every Hermitian-unique bin matches the c2c transform.
            let mut err = 0.0f64;
            for kz in 0..nh {
                for y in 0..n {
                    for x in 0..n {
                        for b in 0..nb {
                            let hval = half[b + nb * (x + n * (y + n * kz))];
                            let fval = full[b + nb * (x + n * (y + n * kz))];
                            err = err.max((hval - fval).abs());
                        }
                    }
                }
            }
            assert!(err < 1e-12, "p={p}: r2c vs c2c forward err {err}");

            // Round trip and wire bytes (summed over the world: per-rank
            // cyclic remainders of nh vs nz wobble around the ratio).
            let rt_err = outs.iter().map(|o| o.4).fold(0.0f64, f64::max);
            assert!(rt_err < 1e-12, "p={p}: r2c round trip err {rt_err}");
            let r2c_bytes: u64 = outs.iter().map(|o| o.2).sum();
            let c2c_bytes: u64 = outs.iter().map(|o| o.3).sum();
            if p > 1 {
                assert!(
                    (r2c_bytes as f64) < 0.6 * c2c_bytes as f64,
                    "p={p}: r2c moved {r2c_bytes} B, c2c {c2c_bytes} B"
                );
            } else {
                assert_eq!(r2c_bytes, 0, "p=1 moves no remote bytes");
            }
        }
    }

    /// The redundant half of the spectrum really is implied: gathering the
    /// distributed r2c output and mirroring it with
    /// X[kx,ky,nz-kz] = conj(X[-kx,-ky,kz]) reproduces the full c2c cube.
    #[test]
    fn mirrored_half_reconstructs_full_spectrum() {
        let n = 8;
        let nh = n / 2 + 1;
        let spec = SphereSpec::new([n, n, n], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 1;
        let p = 2;
        let reals = real_coeffs(off.total(), 3);
        let off2 = Arc::clone(&off);
        let reals2 = reals.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let local = scatter_sphere_real(&off2, &reals2, nb, p, grid.rank());
            let rp = RealPlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let cp = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let (hcube, _) = rp.forward(&backend, local.clone());
            let clocal: Vec<Complex> = local.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let (ccube, _) = cp.forward(&backend, clocal);
            (hcube, ccube)
        });
        let hcubes: Vec<Vec<Complex>> = outs.iter().map(|o| o.0.clone()).collect();
        let ccubes: Vec<Vec<Complex>> = outs.iter().map(|o| o.1.clone()).collect();
        let half = gather_cube_z(&hcubes, nb, [n, n, nh], p);
        let full = gather_cube_z(&ccubes, nb, [n, n, n], p);
        let mut recon = vec![crate::fft::complex::ZERO; n * n * n];
        for kz in 0..n {
            for y in 0..n {
                for x in 0..n {
                    recon[x + n * (y + n * kz)] = if kz < nh {
                        half[x + n * (y + n * kz)]
                    } else {
                        let (mx, my, mz) = ((n - x) % n, (n - y) % n, n - kz);
                        half[mx + n * (my + n * mz)].conj()
                    };
                }
            }
        }
        assert!(max_abs_diff(&recon, &full) < 1e-12);
    }

    #[test]
    fn steady_state_round_trips_do_not_allocate() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let p = 2;
        let reals = real_coeffs(nb * off.total(), 5);
        let off2 = Arc::clone(&off);
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let rp = RealPlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let mut local = scatter_sphere_real(&off2, &reals, nb, p, grid.rank());
            let mut steady = 0u64;
            for it in 0..3 {
                let (cube, tf) = rp.forward(&backend, local);
                let (back, ti) = rp.inverse(&backend, cube);
                local = back;
                if it > 0 {
                    steady += tf.alloc_bytes + ti.alloc_bytes;
                }
            }
            steady
        });
        for s in outs {
            assert_eq!(s, 0, "steady-state r2c round trips must not allocate");
        }
    }

    #[test]
    fn worker_mode_is_bit_identical_to_serial() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let p = 3;
        let reals = real_coeffs(nb * off.total(), 9);
        let run = |worker: bool| {
            let off2 = Arc::clone(&off);
            let reals2 = reals.clone();
            run_world(p, move |comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let mut rp =
                    RealPlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
                rp.set_tuning(CommTuning::with_window(2).with_worker(worker));
                let local = scatter_sphere_real(&off2, &reals2, nb, p, grid.rank());
                let (cube, _) = rp.forward(&backend, local);
                let (back, _) = rp.inverse(&backend, cube.clone());
                (cube, back)
            })
        };
        let serial = run(false);
        let threaded = run(true);
        for (r, (s, t)) in serial.iter().zip(&threaded).enumerate() {
            assert!(s.0.iter().zip(&t.0).all(|(a, b)| a.re == b.re && a.im == b.im), "rank {r}");
            assert!(s.1.iter().zip(&t.1).all(|(a, b)| a == b), "rank {r} inverse");
        }
    }

    #[test]
    fn embedded_adapters_round_trip() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 1;
        let p = 2;
        let reals = real_coeffs(off.total(), 21);
        let off2 = Arc::clone(&off);
        let errs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let rp = RealPlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let local = scatter_sphere_real(&off2, &reals, nb, p, grid.rank());
            let embedded: Vec<Complex> = local.iter().map(|&v| Complex::new(v, 7.5)).collect();
            // Imaginary parts must be ignored on the way in and zero on the
            // way out.
            let (cube, _) = rp.forward_embedded(&backend, embedded);
            let (back, _) = rp.inverse_embedded(&backend, cube);
            assert!(back.iter().all(|c| c.im == 0.0));
            back.iter()
                .zip(&local)
                .map(|(a, b)| (a.re - b).abs())
                .fold(0.0f64, f64::max)
        });
        for e in errs {
            assert!(e < 1e-12, "embedded round trip err {e}");
        }
    }

    #[test]
    fn odd_nz_is_a_shape_error() {
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let spec = SphereSpec::new([8, 8, 7], 2.0, SphereKind::Wrapped);
            let off = Arc::new(spec.offsets());
            let e = RealPlaneWavePlan::new(off, 1, grid).err().unwrap();
            assert!(matches!(e, FftbError::Shape(_)), "{e}");
        });
    }

    #[test]
    fn oversubscribed_half_spectrum_rejected() {
        // nz = 4 has only 3 Hermitian-unique bins: p = 4 must be refused
        // even though p <= nx and p <= nz would pass the c2c check.
        run_world(4, |comm| {
            let grid = ProcGrid::new(&[4], comm).unwrap();
            let spec = SphereSpec::new([8, 8, 4], 1.5, SphereKind::Wrapped);
            let off = Arc::new(spec.offsets());
            let e = RealPlaneWavePlan::new(off, 1, grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)), "{e}");
        });
    }
}
