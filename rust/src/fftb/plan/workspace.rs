//! Reusable per-plan scratch memory — the "preallocated exchange buffers"
//! of P3DFFT-style persistent plans, rendered for this testbed.
//!
//! Every plan owns one `Workspace` behind a `Mutex` and routes all stage
//! scratch through it: the transpose buffer of `backend_fft_dim_ws`, the
//! plane-wave panel and dense-column buffers, and the size-classed
//! [`SlotPool`] of output buffers. (Flat alltoall send/recv staging is
//! gone: the fused exchange packs each destination block straight into a
//! recycled wire buffer from the comm layer's
//! [`BufferArena`](crate::comm::arena::BufferArena) and unpacks straight
//! off the received one.) Buffers are sized with
//! [`ensure`]/[`ensure_zeroed`], which record any *capacity growth*
//! into the workspace's `alloc` cell — the number the plans publish as
//! [`ExecTrace::alloc_bytes`](super::stages::ExecTrace). After the first
//! execution every buffer has reached its high-water mark, so steady-state
//! executions report zero: the plan-once / execute-many property the
//! paper's SCF-loop workload depends on.
//!
//! The slot pool closes the two residual allocation corners of the single
//! recycled result slot this module used to carry: non-cube shapes with
//! unequal local input/output extents no longer regrow the caller's vector
//! on every direction change (each size class keeps its own buffers), and
//! forward-only sphere transforms become allocation-free once the caller
//! returns finished cubes through `Fftb::recycle` (the pool is where they
//! land).

use std::cell::Cell;

use crate::fft::complex::{Complex, ZERO};

/// Free output buffers retained per size class; recycles beyond this are
/// dropped so a burst of oversized outputs cannot pin memory forever.
const MAX_SLOTS_PER_CLASS: usize = 4;
/// Smallest capacity class, in elements (everything below rounds up).
const MIN_CLASS_ELEMS: usize = 16;

/// Size-classed pool of recycled output buffers — the plan-side counterpart
/// of the comm layer's [`BufferArena`](crate::comm::arena::BufferArena).
///
/// Plans draw every vector they *return* from here ([`SlotPool::take`]) and
/// feed every vector they *consume* back in ([`SlotPool::recycle`]), so
/// buffers circulate between input and output roles across calls and
/// direction changes. Classes are power-of-two capacities: a request is
/// served by its ceiling class or any larger one, allocating (and counting
/// into the workspace's `alloc` cell) only when every fitting class is
/// empty.
#[derive(Default)]
pub struct SlotPool {
    /// Free buffers, kept sorted by capacity (ascending) for best-fit pops.
    free: Vec<Vec<Complex>>,
    /// Optional byte budget on *checked-out* capacity (see
    /// [`SlotPool::with_budget`]); `None` = unbounded, the historical
    /// behaviour.
    budget: Option<usize>,
    /// Bytes of capacity currently checked out against `budget`.
    charged: usize,
}

impl SlotPool {
    /// Ceiling power-of-two capacity class serving a request of `len`.
    fn class_for(len: usize) -> usize {
        len.max(MIN_CLASS_ELEMS).next_power_of_two()
    }

    /// A pool whose *checked-out* capacity is capped at `bytes`: the
    /// service layer gives each tenant one budgeted pool, so one tenant's
    /// steady-state memory is bounded no matter how many requests it has in
    /// flight. [`SlotPool::try_take`] refuses (returns `None`) instead of
    /// allocating past the cap; [`SlotPool::recycle`] releases the charge.
    /// The infallible [`SlotPool::take`] ignores the budget — plans
    /// internally size their own scratch and must never fail mid-execute.
    pub fn with_budget(bytes: usize) -> Self {
        SlotPool { budget: Some(bytes), ..Default::default() }
    }

    /// Bytes of checked-out capacity currently charged against the budget.
    pub fn charged(&self) -> usize {
        self.charged
    }

    /// Bytes one checked-out buffer of `len` elements charges against a
    /// budget — its capacity class times the element size. The unit the
    /// service layer sizes tenant quotas in.
    pub fn class_bytes(len: usize) -> usize {
        Self::class_for(len) * std::mem::size_of::<Complex>()
    }

    /// Budget-checked checkout: like [`SlotPool::take`], but when the pool
    /// has a budget and serving `len` would push the checked-out capacity
    /// past it, returns `None` without allocating (the admission layer
    /// turns that into a typed quota error). Unbudgeted pools never refuse.
    pub fn try_take(&mut self, len: usize, ctr: &Cell<u64>) -> Option<Vec<Complex>> {
        if let Some(budget) = self.budget {
            // Charge what the checkout will actually pin: the capacity
            // class of the free buffer that best-fit will hand out, or of
            // a fresh exact-size buffer if none fits. Recycle releases the
            // same class off the returned buffer's capacity, so the charge
            // is symmetric.
            let cap =
                self.free.iter().find(|b| b.capacity() >= len).map_or(len, |b| b.capacity());
            let cost = Self::class_for(cap) * std::mem::size_of::<Complex>();
            if self.charged.saturating_add(cost) > budget {
                return None;
            }
            self.charged += cost;
        }
        Some(self.take(len, ctr))
    }

    /// Check out a buffer resized to exactly `len` elements, preferring the
    /// smallest free buffer whose capacity already fits (contents are
    /// unspecified). Allocation — a fresh buffer or growth of a recycled
    /// one — is recorded into `ctr`.
    pub fn take(&mut self, len: usize, ctr: &Cell<u64>) -> Vec<Complex> {
        let pos = self.free.iter().position(|b| b.capacity() >= len);
        let mut buf = match pos {
            Some(i) => self.free.remove(i),
            None => Vec::new(),
        };
        ensure(&mut buf, len, ctr);
        buf
    }

    /// Like [`SlotPool::take`] but the returned buffer is zero-filled.
    pub fn take_zeroed(&mut self, len: usize, ctr: &Cell<u64>) -> Vec<Complex> {
        let mut buf = self.take(len, ctr);
        buf.fill(ZERO);
        buf
    }

    /// Return a finished buffer's storage to the pool. Buffers beyond
    /// `MAX_SLOTS_PER_CLASS` in the same capacity class are dropped. On a
    /// budgeted pool this also releases the buffer's capacity class from
    /// the checked-out charge (whether or not the storage is retained).
    pub fn recycle(&mut self, buf: Vec<Complex>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.budget.is_some() {
            let cost = Self::class_for(buf.capacity()) * std::mem::size_of::<Complex>();
            self.charged = self.charged.saturating_sub(cost);
        }
        let class = Self::class_for(buf.capacity());
        let in_class =
            self.free.iter().filter(|b| Self::class_for(b.capacity()) == class).count();
        if in_class >= MAX_SLOTS_PER_CLASS {
            return;
        }
        let at = self.free.partition_point(|b| b.capacity() < buf.capacity());
        self.free.insert(at, buf);
    }

    /// Number of free buffers currently pooled (test/diagnostic hook).
    pub fn len(&self) -> usize {
        self.free.len()
    }

    /// Whether the pool currently holds no free buffers.
    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

/// Named scratch buffers of one plan. Fields are public so the plans can
/// split-borrow them independently inside one execution (edition-2021
/// disjoint closure captures).
#[derive(Default)]
pub struct Workspace {
    /// Transpose scratch for `backend_fft_dim_ws`.
    pub fft: Vec<Complex>,
    /// General stage scratch (dense z-columns, band staging, ...).
    pub work: Vec<Complex>,
    /// Borrowed-input staging for the `execute_into` paths: plans whose
    /// pipelines mutate their first buffer in place copy the caller's
    /// read-only slice here once, then run unchanged. Kept separate from
    /// `work` because both can be live inside one execution.
    pub stage: Vec<Complex>,
    /// Panel buffer of the plane-wave staged-y pass.
    pub panel: Vec<Complex>,
    /// Size-classed pool of output buffers: every vector a plan returns is
    /// taken from here and every vector it consumes is recycled into it,
    /// so buffers circulate across calls and direction changes.
    pub slots: SlotPool,
    /// Bytes of capacity newly acquired since [`Workspace::begin`].
    pub alloc: Cell<u64>,
}

impl Workspace {
    /// Create an empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the allocation counter at the start of one execution.
    pub fn begin(&self) {
        self.alloc.set(0);
    }

    /// Bytes allocated since the last [`Workspace::begin`].
    pub fn allocated(&self) -> u64 {
        self.alloc.get()
    }
}

/// Size `buf` to exactly `len` elements, counting any capacity growth into
/// `ctr`. Contents of elements the caller does not overwrite are
/// unspecified (stale from the previous stage) — use [`ensure_zeroed`] when
/// the stage relies on zero padding.
pub fn ensure(buf: &mut Vec<Complex>, len: usize, ctr: &Cell<u64>) {
    let cap0 = buf.capacity();
    if buf.len() > len {
        buf.truncate(len);
    } else if buf.len() < len {
        buf.resize(len, ZERO);
    }
    if buf.capacity() > cap0 {
        let grown = (buf.capacity() - cap0) * std::mem::size_of::<Complex>();
        ctr.set(ctr.get() + grown as u64);
    }
}

/// Like [`ensure`] but the whole buffer is zero-filled (the memset every
/// freshly `vec![ZERO; ..]`-allocated stage buffer paid anyway).
pub fn ensure_zeroed(buf: &mut Vec<Complex>, len: usize, ctr: &Cell<u64>) {
    ensure(buf, len, ctr);
    buf.fill(ZERO);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_counts_growth_once() {
        let ctr = Cell::new(0u64);
        let mut buf = Vec::new();
        ensure(&mut buf, 8, &ctr);
        assert_eq!(buf.len(), 8);
        let first = ctr.get();
        assert!(first >= 8 * 16, "growth must be recorded");
        // Shrink then regrow within capacity: no new bytes.
        ensure(&mut buf, 2, &ctr);
        ensure(&mut buf, 8, &ctr);
        assert_eq!(ctr.get(), first, "steady-state resizes are free");
        // Growing past capacity records again.
        ensure(&mut buf, 4096, &ctr);
        assert!(ctr.get() > first);
    }

    #[test]
    fn ensure_zeroed_clears_stale_contents() {
        let ctr = Cell::new(0u64);
        let mut buf = vec![Complex::new(3.0, -1.0); 4];
        ensure_zeroed(&mut buf, 4, &ctr);
        assert!(buf.iter().all(|c| c.re == 0.0 && c.im == 0.0));
    }

    #[test]
    fn workspace_begin_resets() {
        let ws = Workspace::new();
        ws.alloc.set(100);
        ws.begin();
        assert_eq!(ws.allocated(), 0);
    }

    #[test]
    fn slot_pool_reuses_recycled_capacity() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let a = pool.take(100, &ctr);
        let first = ctr.get();
        assert!(first > 0, "fresh take must allocate");
        pool.recycle(a);
        let b = pool.take(90, &ctr);
        assert_eq!(b.len(), 90);
        assert_eq!(ctr.get(), first, "recycled capacity serves smaller takes for free");
    }

    #[test]
    fn slot_pool_best_fit_prefers_smallest_fitting() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let small = pool.take(64, &ctr);
        let big = pool.take(4096, &ctr);
        let (small_cap, big_cap) = (small.capacity(), big.capacity());
        pool.recycle(big);
        pool.recycle(small);
        let got = pool.take(32, &ctr);
        assert!(got.capacity() <= small_cap, "best fit must not hand out the big slot");
        pool.recycle(got);
        let got = pool.take(2048, &ctr);
        assert!(got.capacity() >= 2048 && got.capacity() <= big_cap);
    }

    #[test]
    fn slot_pool_two_classes_alternate_freely() {
        // The non-cube corner: alternating takes of two different sizes must
        // stop allocating once each class holds one buffer.
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let a = pool.take(72, &ctr);
        let b = pool.take(600, &ctr);
        pool.recycle(a);
        pool.recycle(b);
        let warm = ctr.get();
        for _ in 0..5 {
            let a = pool.take(72, &ctr);
            let b = pool.take(600, &ctr);
            pool.recycle(b);
            pool.recycle(a);
        }
        assert_eq!(ctr.get(), warm, "steady-state alternation must not allocate");
    }

    #[test]
    fn budgeted_pool_refuses_past_the_cap_and_recovers_on_recycle() {
        let ctr = Cell::new(0u64);
        // Room for exactly two 64-element class buffers (class 64, 16 B
        // per element).
        let mut pool = SlotPool::with_budget(2 * 64 * std::mem::size_of::<Complex>());
        let a = pool.try_take(60, &ctr).expect("first checkout fits");
        let b = pool.try_take(64, &ctr).expect("second checkout fits");
        assert_eq!(pool.charged(), 2 * 64 * std::mem::size_of::<Complex>());
        assert!(pool.try_take(1, &ctr).is_none(), "third checkout must refuse");
        pool.recycle(a);
        assert!(pool.try_take(16, &ctr).is_some(), "recycle frees quota");
        pool.recycle(b);
    }

    #[test]
    fn unbudgeted_pool_never_refuses() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        for _ in 0..8 {
            assert!(pool.try_take(1024, &ctr).is_some());
        }
        assert_eq!(pool.charged(), 0, "no budget, no accounting");
    }

    #[test]
    fn budget_charge_is_symmetric_across_classes() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::with_budget(1 << 20);
        // A big recycled buffer serving a small request charges (and later
        // releases) the big buffer's class, not the request's.
        let big = pool.try_take(4096, &ctr).unwrap();
        pool.recycle(big);
        assert_eq!(pool.charged(), 0);
        let served = pool.try_take(16, &ctr).unwrap();
        assert!(served.capacity() >= 4096, "best fit hands out the pooled big slot");
        assert_eq!(pool.charged(), 4096 * std::mem::size_of::<Complex>());
        pool.recycle(served);
        assert_eq!(pool.charged(), 0, "release matches the charge exactly");
    }

    #[test]
    fn slot_pool_bounds_retained_buffers() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let bufs: Vec<_> = (0..10).map(|_| pool.take(256, &ctr)).collect();
        for b in bufs {
            pool.recycle(b);
        }
        assert!(pool.len() <= MAX_SLOTS_PER_CLASS, "pool retained {} buffers", pool.len());
    }
}
