//! Reusable per-plan scratch memory — the "preallocated exchange buffers"
//! of P3DFFT-style persistent plans, rendered for this testbed.
//!
//! Every plan owns one `Workspace` behind a `Mutex` and routes all stage
//! scratch through it: the transpose buffer of `backend_fft_dim_ws`, the
//! plane-wave panel and dense-column buffers, and the size-classed
//! [`SlotPool`] of output buffers. (Flat alltoall send/recv staging is
//! gone: the fused exchange packs each destination block straight into a
//! recycled wire buffer from the comm layer's
//! [`BufferArena`](crate::comm::arena::BufferArena) and unpacks straight
//! off the received one.) Buffers are sized with
//! [`ensure`]/[`ensure_zeroed`], which record any *capacity growth*
//! into the workspace's `alloc` cell — the number the plans publish as
//! [`ExecTrace::alloc_bytes`](super::stages::ExecTrace). After the first
//! execution every buffer has reached its high-water mark, so steady-state
//! executions report zero: the plan-once / execute-many property the
//! paper's SCF-loop workload depends on.
//!
//! The slot pool closes the two residual allocation corners of the single
//! recycled result slot this module used to carry: non-cube shapes with
//! unequal local input/output extents no longer regrow the caller's vector
//! on every direction change (each size class keeps its own buffers), and
//! forward-only sphere transforms become allocation-free once the caller
//! returns finished cubes through `Fftb::recycle` (the pool is where they
//! land).

use std::cell::Cell;

use crate::fft::complex::{Complex, ZERO};

/// Free output buffers retained per size class; recycles beyond this are
/// dropped so a burst of oversized outputs cannot pin memory forever.
const MAX_SLOTS_PER_CLASS: usize = 4;
/// Smallest capacity class, in elements (everything below rounds up).
const MIN_CLASS_ELEMS: usize = 16;

/// Size-classed pool of recycled output buffers — the plan-side counterpart
/// of the comm layer's [`BufferArena`](crate::comm::arena::BufferArena).
///
/// Plans draw every vector they *return* from here ([`SlotPool::take`]) and
/// feed every vector they *consume* back in ([`SlotPool::recycle`]), so
/// buffers circulate between input and output roles across calls and
/// direction changes. Classes are power-of-two capacities: a request is
/// served by its ceiling class or any larger one, allocating (and counting
/// into the workspace's `alloc` cell) only when every fitting class is
/// empty.
#[derive(Default)]
pub struct SlotPool {
    /// Free buffers, kept sorted by capacity (ascending) for best-fit pops.
    free: Vec<Vec<Complex>>,
}

impl SlotPool {
    /// Ceiling power-of-two capacity class serving a request of `len`.
    fn class_for(len: usize) -> usize {
        len.max(MIN_CLASS_ELEMS).next_power_of_two()
    }

    /// Check out a buffer resized to exactly `len` elements, preferring the
    /// smallest free buffer whose capacity already fits (contents are
    /// unspecified). Allocation — a fresh buffer or growth of a recycled
    /// one — is recorded into `ctr`.
    pub fn take(&mut self, len: usize, ctr: &Cell<u64>) -> Vec<Complex> {
        let pos = self.free.iter().position(|b| b.capacity() >= len);
        let mut buf = match pos {
            Some(i) => self.free.remove(i),
            None => Vec::new(),
        };
        ensure(&mut buf, len, ctr);
        buf
    }

    /// Like [`SlotPool::take`] but the returned buffer is zero-filled.
    pub fn take_zeroed(&mut self, len: usize, ctr: &Cell<u64>) -> Vec<Complex> {
        let mut buf = self.take(len, ctr);
        buf.fill(ZERO);
        buf
    }

    /// Return a finished buffer's storage to the pool. Buffers beyond
    /// `MAX_SLOTS_PER_CLASS` in the same capacity class are dropped.
    pub fn recycle(&mut self, buf: Vec<Complex>) {
        if buf.capacity() == 0 {
            return;
        }
        let class = Self::class_for(buf.capacity());
        let in_class =
            self.free.iter().filter(|b| Self::class_for(b.capacity()) == class).count();
        if in_class >= MAX_SLOTS_PER_CLASS {
            return;
        }
        let at = self.free.partition_point(|b| b.capacity() < buf.capacity());
        self.free.insert(at, buf);
    }

    /// Number of free buffers currently pooled (test/diagnostic hook).
    pub fn len(&self) -> usize {
        self.free.len()
    }

    /// Whether the pool currently holds no free buffers.
    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

/// Named scratch buffers of one plan. Fields are public so the plans can
/// split-borrow them independently inside one execution (edition-2021
/// disjoint closure captures).
#[derive(Default)]
pub struct Workspace {
    /// Transpose scratch for `backend_fft_dim_ws`.
    pub fft: Vec<Complex>,
    /// General stage scratch (dense z-columns, band staging, ...).
    pub work: Vec<Complex>,
    /// Panel buffer of the plane-wave staged-y pass.
    pub panel: Vec<Complex>,
    /// Size-classed pool of output buffers: every vector a plan returns is
    /// taken from here and every vector it consumes is recycled into it,
    /// so buffers circulate across calls and direction changes.
    pub slots: SlotPool,
    /// Bytes of capacity newly acquired since [`Workspace::begin`].
    pub alloc: Cell<u64>,
}

impl Workspace {
    /// Create an empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the allocation counter at the start of one execution.
    pub fn begin(&self) {
        self.alloc.set(0);
    }

    /// Bytes allocated since the last [`Workspace::begin`].
    pub fn allocated(&self) -> u64 {
        self.alloc.get()
    }
}

/// Size `buf` to exactly `len` elements, counting any capacity growth into
/// `ctr`. Contents of elements the caller does not overwrite are
/// unspecified (stale from the previous stage) — use [`ensure_zeroed`] when
/// the stage relies on zero padding.
pub fn ensure(buf: &mut Vec<Complex>, len: usize, ctr: &Cell<u64>) {
    let cap0 = buf.capacity();
    if buf.len() > len {
        buf.truncate(len);
    } else if buf.len() < len {
        buf.resize(len, ZERO);
    }
    if buf.capacity() > cap0 {
        let grown = (buf.capacity() - cap0) * std::mem::size_of::<Complex>();
        ctr.set(ctr.get() + grown as u64);
    }
}

/// Like [`ensure`] but the whole buffer is zero-filled (the memset every
/// freshly `vec![ZERO; ..]`-allocated stage buffer paid anyway).
pub fn ensure_zeroed(buf: &mut Vec<Complex>, len: usize, ctr: &Cell<u64>) {
    ensure(buf, len, ctr);
    buf.fill(ZERO);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_counts_growth_once() {
        let ctr = Cell::new(0u64);
        let mut buf = Vec::new();
        ensure(&mut buf, 8, &ctr);
        assert_eq!(buf.len(), 8);
        let first = ctr.get();
        assert!(first >= 8 * 16, "growth must be recorded");
        // Shrink then regrow within capacity: no new bytes.
        ensure(&mut buf, 2, &ctr);
        ensure(&mut buf, 8, &ctr);
        assert_eq!(ctr.get(), first, "steady-state resizes are free");
        // Growing past capacity records again.
        ensure(&mut buf, 4096, &ctr);
        assert!(ctr.get() > first);
    }

    #[test]
    fn ensure_zeroed_clears_stale_contents() {
        let ctr = Cell::new(0u64);
        let mut buf = vec![Complex::new(3.0, -1.0); 4];
        ensure_zeroed(&mut buf, 4, &ctr);
        assert!(buf.iter().all(|c| c.re == 0.0 && c.im == 0.0));
    }

    #[test]
    fn workspace_begin_resets() {
        let ws = Workspace::new();
        ws.alloc.set(100);
        ws.begin();
        assert_eq!(ws.allocated(), 0);
    }

    #[test]
    fn slot_pool_reuses_recycled_capacity() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let a = pool.take(100, &ctr);
        let first = ctr.get();
        assert!(first > 0, "fresh take must allocate");
        pool.recycle(a);
        let b = pool.take(90, &ctr);
        assert_eq!(b.len(), 90);
        assert_eq!(ctr.get(), first, "recycled capacity serves smaller takes for free");
    }

    #[test]
    fn slot_pool_best_fit_prefers_smallest_fitting() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let small = pool.take(64, &ctr);
        let big = pool.take(4096, &ctr);
        let (small_cap, big_cap) = (small.capacity(), big.capacity());
        pool.recycle(big);
        pool.recycle(small);
        let got = pool.take(32, &ctr);
        assert!(got.capacity() <= small_cap, "best fit must not hand out the big slot");
        pool.recycle(got);
        let got = pool.take(2048, &ctr);
        assert!(got.capacity() >= 2048 && got.capacity() <= big_cap);
    }

    #[test]
    fn slot_pool_two_classes_alternate_freely() {
        // The non-cube corner: alternating takes of two different sizes must
        // stop allocating once each class holds one buffer.
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let a = pool.take(72, &ctr);
        let b = pool.take(600, &ctr);
        pool.recycle(a);
        pool.recycle(b);
        let warm = ctr.get();
        for _ in 0..5 {
            let a = pool.take(72, &ctr);
            let b = pool.take(600, &ctr);
            pool.recycle(b);
            pool.recycle(a);
        }
        assert_eq!(ctr.get(), warm, "steady-state alternation must not allocate");
    }

    #[test]
    fn slot_pool_bounds_retained_buffers() {
        let ctr = Cell::new(0u64);
        let mut pool = SlotPool::default();
        let bufs: Vec<_> = (0..10).map(|_| pool.take(256, &ctr)).collect();
        for b in bufs {
            pool.recycle(b);
        }
        assert!(pool.len() <= MAX_SLOTS_PER_CLASS, "pool retained {} buffers", pool.len());
    }
}
