//! Reusable per-plan scratch memory — the "preallocated exchange buffers"
//! of P3DFFT-style persistent plans, rendered for this testbed.
//!
//! Every plan owns one `Workspace` behind a `Mutex` and routes all stage
//! scratch through it: flat alltoall send/recv staging, the transpose
//! buffer of `backend_fft_dim_ws`, the plane-wave panel buffer, and the
//! result slot that recycles the caller's input vector. Buffers are sized
//! with [`ensure`]/[`ensure_zeroed`], which record any *capacity growth*
//! into the workspace's `alloc` cell — the number the plans publish as
//! [`ExecTrace::alloc_bytes`](super::stages::ExecTrace). After the first
//! execution every buffer has reached its high-water mark, so steady-state
//! executions report zero: the plan-once / execute-many property the
//! paper's SCF-loop workload depends on.

use std::cell::Cell;

use crate::fft::complex::{Complex, ZERO};

/// Named scratch buffers of one plan. Fields are public so the plans can
/// split-borrow them independently inside one execution (edition-2021
/// disjoint closure captures).
#[derive(Default)]
pub struct Workspace {
    /// Flat send staging for the alltoall pack stage.
    pub send: Vec<Complex>,
    /// Flat receive buffer for the alltoall.
    pub recv: Vec<Complex>,
    /// Transpose scratch for `backend_fft_dim_ws`.
    pub fft: Vec<Complex>,
    /// General stage scratch (dense z-columns, band staging, ...).
    pub work: Vec<Complex>,
    /// Panel buffer of the plane-wave staged-y pass.
    pub panel: Vec<Complex>,
    /// Result slot: holds a recycled vector the next execution returns;
    /// refilled with the caller's consumed input (the swap that makes
    /// alternating forward/inverse round trips buffer-neutral).
    pub out: Vec<Complex>,
    /// Bytes of capacity newly acquired since [`Workspace::begin`].
    pub alloc: Cell<u64>,
}

impl Workspace {
    /// Create an empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the allocation counter at the start of one execution.
    pub fn begin(&self) {
        self.alloc.set(0);
    }

    /// Bytes allocated since the last [`Workspace::begin`].
    pub fn allocated(&self) -> u64 {
        self.alloc.get()
    }
}

/// Size `buf` to exactly `len` elements, counting any capacity growth into
/// `ctr`. Contents of elements the caller does not overwrite are
/// unspecified (stale from the previous stage) — use [`ensure_zeroed`] when
/// the stage relies on zero padding.
pub fn ensure(buf: &mut Vec<Complex>, len: usize, ctr: &Cell<u64>) {
    let cap0 = buf.capacity();
    if buf.len() > len {
        buf.truncate(len);
    } else if buf.len() < len {
        buf.resize(len, ZERO);
    }
    if buf.capacity() > cap0 {
        let grown = (buf.capacity() - cap0) * std::mem::size_of::<Complex>();
        ctr.set(ctr.get() + grown as u64);
    }
}

/// Like [`ensure`] but the whole buffer is zero-filled (the memset every
/// freshly `vec![ZERO; ..]`-allocated stage buffer paid anyway).
pub fn ensure_zeroed(buf: &mut Vec<Complex>, len: usize, ctr: &Cell<u64>) {
    ensure(buf, len, ctr);
    buf.fill(ZERO);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_counts_growth_once() {
        let ctr = Cell::new(0u64);
        let mut buf = Vec::new();
        ensure(&mut buf, 8, &ctr);
        assert_eq!(buf.len(), 8);
        let first = ctr.get();
        assert!(first >= 8 * 16, "growth must be recorded");
        // Shrink then regrow within capacity: no new bytes.
        ensure(&mut buf, 2, &ctr);
        ensure(&mut buf, 8, &ctr);
        assert_eq!(ctr.get(), first, "steady-state resizes are free");
        // Growing past capacity records again.
        ensure(&mut buf, 4096, &ctr);
        assert!(ctr.get() > first);
    }

    #[test]
    fn ensure_zeroed_clears_stale_contents() {
        let ctr = Cell::new(0u64);
        let mut buf = vec![Complex::new(3.0, -1.0); 4];
        ensure_zeroed(&mut buf, 4, &ctr);
        assert!(buf.iter().all(|c| c.re == 0.0 && c.im == 0.0));
    }

    #[test]
    fn workspace_begin_resets() {
        let ws = Workspace::new();
        ws.alloc.set(100);
        ws.begin();
        assert_eq!(ws.allocated(), 0);
    }
}
