//! The plane-wave batched sphere transform (paper §2.2/§3.3, Fig. 3) — the
//! headline contribution: zero-padding done *in stages*, each fused with the
//! 1D FFT of that dimension, so redundant compute and communication never
//! materialize.
//!
//! Forward (`G`-sphere → `r`-cube), 1D processing grid, sphere columns
//! distributed cyclically over `x`:
//!
//! 1. `pad_fft_z`  — scatter each owned CSR column's z-runs into a dense,
//!                   zero-padded z-line and FFT it (only `|disc|` columns,
//!                   not `nx*ny`),
//! 2. `a2a_sphere` — one alltoall moving *only disc columns* (a ~pi/4 ·
//!                   (d/n)^2 fraction of the full-cube exchange) to a
//!                   z-slab distribution,
//! 3. `pad_fft_y`  — the received columns land in a zeroed cube slab;
//!                   FFT along `y` only for the disc's x-extent,
//! 4. `fft_x`      — dense FFT along `x` (every line now carries data).
//!
//! The inverse runs the mirror image with truncation instead of padding.
//! Output layout matches the slab-pencil plan: `[nb, nx, ny, lzc]`,
//! z cyclic — so plane-wave and cuboid transforms compose downstream
//! (density builds, potentials) identically.
//!
//! Everything shape-dependent is computed once at plan time: the
//! `cols_of_rank(q)` tables for every rank (previously rebuilt inside each
//! forward *and* inverse call), the alltoall block extents, and the disc
//! x-extent. The exchange runs **fused**: `SphereFwdKernel` /
//! `SphereInvKernel` (this module's `PackKernel` implementations) pack
//! each destination's z-residue columns straight into a recycled wire
//! buffer as that round posts, and land each received block as its wait
//! completes — no monolithic pack/unpack stages, no flat send/recv
//! staging at all. Execution routes all scratch — dense
//! z-columns, panel buffers, the output cube — through the plan's
//! [`Workspace`], so the steady state of an SCF loop (alternating
//! forward/inverse) allocates nothing.

use std::cell::Cell;
use std::sync::{Arc, Mutex};

use crate::comm::alltoall::{
    alltoallv_fused_threaded, CommTuning, PackHalf, UnpackHalf,
};
use crate::comm::arena::WireBuf;
use crate::comm::communicator::Comm;
use crate::fft::complex::{self, Complex};
use crate::fft::dft::Direction;
use crate::fftb::backend::{backend_fft_dim_ws, LocalFftBackend};
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::{cyclic, ProcGrid};
use crate::fftb::sphere::OffsetArray;

use super::redistribute::A2aSchedule;
use super::stages::{fused_exchange, ExecTrace, PackKernel, StageTimer};
use super::workspace::{ensure, ensure_zeroed, Workspace};

/// Bytes per complex element on the wire.
const ELEM: usize = std::mem::size_of::<Complex>();

/// Batched plane-wave transform plan for one sphere on a 1D grid.
pub struct PlaneWavePlan {
    /// Global offset array of the cut-off sphere.
    pub offsets: Arc<OffsetArray>,
    /// Batch count (transforms per execution).
    pub nb: usize,
    grid: Arc<ProcGrid>,
    /// This rank's restriction of the offset array (x cyclic).
    local_off: OffsetArray,
    /// Sorted distinct x's of the global disc (for the staged y pass).
    disc_xs: Vec<usize>,
    /// Disc columns owned by each rank `q`, in q's local packing order
    /// (y outer, local-x inner), as global `(gx, y)` pairs — precomputed
    /// for all q so neither direction rebuilds them per execution.
    cols_by_rank: Vec<Vec<(usize, usize)>>,
    /// Number of disc columns this rank owns (`cols_by_rank[rank].len()`).
    ncols: usize,
    /// This rank's cyclic z-count.
    lzc: usize,
    /// Forward exchange: z-residue blocks of the owned columns out, this
    /// rank's z-slab share of every rank's columns in.
    fwd: A2aSchedule,
    /// Inverse exchange (the forward schedule mirrored).
    inv: A2aSchedule,
    /// Overlap knobs of the windowed exchange.
    tuning: CommTuning,
    ws: Mutex<Workspace>,
}

/// Pack destination `s`'s z-residues of the dense z-columns `[nb, nz,
/// ncols]`: for each column, each `lz` with `gz = lz*p + s`, one `nb`-run.
/// Shared by the fused forward kernel and its threaded pack half, so both
/// engines produce identical wire bytes. The walk is parameterized on `nz`
/// only, so the r2c plan reuses it verbatim with the Hermitian-unique bin
/// count `nz/2 + 1` in its place.
pub(crate) fn pack_col_residues(
    work: &[Complex],
    nb: usize,
    nz: usize,
    ncols: usize,
    p: usize,
    s: usize,
    out: &mut WireBuf,
) {
    let lzc_s = cyclic::local_count(nz, p, s);
    for c in 0..ncols {
        let base = c * nb * nz;
        for lz in 0..lzc_s {
            let gz = cyclic::local_to_global(lz, p, s);
            let src = base + nb * gz;
            out.extend_from_slice(complex::as_bytes(&work[src..src + nb]));
        }
    }
}

/// Merge source rank's z-residue block back into the dense z-columns —
/// the exact inverse walk of [`pack_col_residues`].
pub(crate) fn unpack_col_residues(
    block: &[u8],
    nb: usize,
    nz: usize,
    ncols: usize,
    p: usize,
    s: usize,
    work: &mut [Complex],
) {
    let lzc_s = cyclic::local_count(nz, p, s);
    let mut src = 0usize;
    for c in 0..ncols {
        let base = c * nb * nz;
        for lz in 0..lzc_s {
            let gz = cyclic::local_to_global(lz, p, s);
            let dst = base + nb * gz;
            complex::copy_from_bytes(&block[src..src + nb * ELEM], &mut work[dst..dst + nb]);
            src += nb * ELEM;
        }
    }
}

/// Land one source rank's disc columns (this rank's z-slab share) in the
/// `[nb, nx, ny, lzc]` cube, in that rank's packing order.
pub(crate) fn unpack_cols_into_cube(
    block: &[u8],
    cols: &[(usize, usize)],
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    cube: &mut [Complex],
) {
    let mut src = 0usize;
    for &(gx, y) in cols {
        for lz in 0..lzc {
            let dst = nb * (gx + nx * (y + ny * lz));
            complex::copy_from_bytes(&block[src..src + nb * ELEM], &mut cube[dst..dst + nb]);
            src += nb * ELEM;
        }
    }
}

/// Gather one destination rank's disc columns out of the cube — the exact
/// inverse walk of [`unpack_cols_into_cube`].
pub(crate) fn pack_cols_from_cube(
    cube: &[Complex],
    cols: &[(usize, usize)],
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    out: &mut WireBuf,
) {
    for &(gx, y) in cols {
        for lz in 0..lzc {
            let src = nb * (gx + nx * (y + ny * lz));
            out.extend_from_slice(complex::as_bytes(&cube[src..src + nb]));
        }
    }
}

/// Fused pack/unpack movers of the forward sphere exchange (`G`-sphere →
/// `r`-cube): destination `s`'s z-residues are packed straight from the
/// dense z-columns as round `s` posts, and each source rank's disc columns
/// land in the zeroed output slab as that round's wait completes.
struct SphereFwdKernel<'a> {
    plan: &'a PlaneWavePlan,
    /// Dense z-columns `[nb, nz, ncols]` (after `pad_fft_z`).
    work: &'a [Complex],
    /// Zeroed output slab `[nb, nx, ny, lzc]` the columns land in.
    cube: &'a mut [Complex],
}

impl PackKernel for SphereFwdKernel<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.plan.fwd.send_counts[dest] * ELEM
    }

    fn recv_bytes(&self, src: usize) -> usize {
        self.plan.fwd.recv_counts[src] * ELEM
    }

    fn pack(&mut self, s: usize, out: &mut WireBuf) {
        let (nb, nz) = (self.plan.nb, self.plan.offsets.nz);
        pack_col_residues(self.work, nb, nz, self.plan.ncols, self.plan.p(), s, out);
    }

    fn unpack(&mut self, q: usize, block: &[u8]) {
        let (nb, nx, ny) = (self.plan.nb, self.plan.offsets.nx, self.plan.offsets.ny);
        let cols = &self.plan.cols_by_rank[q];
        unpack_cols_into_cube(block, cols, nb, nx, ny, self.plan.lzc, self.cube);
    }
}

/// Fused movers of the inverse sphere exchange (`r`-cube → `G`-sphere):
/// destination rank `q`'s disc columns (this rank's z-slab share) are
/// gathered from the cube as round `q` posts; each source rank's
/// z-residues merge into the dense z-columns as its wait completes.
struct SphereInvKernel<'a> {
    plan: &'a PlaneWavePlan,
    /// The z-distributed cube (after the truncating y pass).
    cube: &'a [Complex],
    /// Dense z-columns `[nb, nz, ncols]` being reassembled.
    work: &'a mut [Complex],
}

impl PackKernel for SphereInvKernel<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.plan.inv.send_counts[dest] * ELEM
    }

    fn recv_bytes(&self, src: usize) -> usize {
        self.plan.inv.recv_counts[src] * ELEM
    }

    fn pack(&mut self, q: usize, out: &mut WireBuf) {
        let (nb, nx, ny) = (self.plan.nb, self.plan.offsets.nx, self.plan.offsets.ny);
        let cols = &self.plan.cols_by_rank[q];
        pack_cols_from_cube(self.cube, cols, nb, nx, ny, self.plan.lzc, out);
    }

    fn unpack(&mut self, s: usize, block: &[u8]) {
        let (nb, nz) = (self.plan.nb, self.plan.offsets.nz);
        unpack_col_residues(block, nb, nz, self.plan.ncols, self.plan.p(), s, self.work);
    }
}

/// Read-only pack half of the forward sphere exchange for the threaded
/// engine: plain borrowed data (counts, geometry, the dense columns), so
/// the helper thread shares only `Sync` slices — never the plan itself.
struct SphereFwdPackHalf<'a> {
    counts: &'a [usize],
    nb: usize,
    nz: usize,
    ncols: usize,
    p: usize,
    work: &'a [Complex],
}

impl PackHalf for SphereFwdPackHalf<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.counts[dest] * ELEM
    }

    fn pack(&self, s: usize, out: &mut WireBuf) {
        pack_col_residues(self.work, self.nb, self.nz, self.ncols, self.p, s, out);
    }
}

/// Write-only unpack half of the forward sphere exchange: exclusively
/// owns the output cube while the pack half is shared with the helper.
struct SphereFwdUnpackHalf<'a> {
    counts: &'a [usize],
    cols_by_rank: &'a [Vec<(usize, usize)>],
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    cube: &'a mut [Complex],
}

impl UnpackHalf for SphereFwdUnpackHalf<'_> {
    fn recv_bytes(&self, src: usize) -> usize {
        self.counts[src] * ELEM
    }

    fn unpack(&mut self, q: usize, block: &[u8]) {
        let cols = &self.cols_by_rank[q];
        unpack_cols_into_cube(block, cols, self.nb, self.nx, self.ny, self.lzc, self.cube);
    }
}

/// Read-only pack half of the inverse sphere exchange (gathers disc
/// columns from the shared cube).
struct SphereInvPackHalf<'a> {
    counts: &'a [usize],
    cols_by_rank: &'a [Vec<(usize, usize)>],
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    cube: &'a [Complex],
}

impl PackHalf for SphereInvPackHalf<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.counts[dest] * ELEM
    }

    fn pack(&self, q: usize, out: &mut WireBuf) {
        let cols = &self.cols_by_rank[q];
        pack_cols_from_cube(self.cube, cols, self.nb, self.nx, self.ny, self.lzc, out);
    }
}

/// Write-only unpack half of the inverse sphere exchange (merges
/// z-residues into the exclusively-owned dense columns).
struct SphereInvUnpackHalf<'a> {
    counts: &'a [usize],
    nb: usize,
    nz: usize,
    ncols: usize,
    p: usize,
    work: &'a mut [Complex],
}

impl UnpackHalf for SphereInvUnpackHalf<'_> {
    fn recv_bytes(&self, src: usize) -> usize {
        self.counts[src] * ELEM
    }

    fn unpack(&mut self, s: usize, block: &[u8]) {
        unpack_col_residues(block, self.nb, self.nz, self.ncols, self.p, s, self.work);
    }
}

/// Stage the self block through an arena wire buffer (pack → unpack),
/// exactly as the single-threaded engine does internally — the sphere
/// movers have no direct src→dst self move, so worker mode reproduces the
/// staged bytes before handing the remote rounds to the threaded engine.
pub(crate) fn stage_self_block(comm: &Comm, pack: &dyn PackHalf, unpack: &mut dyn UnpackHalf) {
    let me = comm.rank();
    let n = pack.send_bytes(me);
    assert_eq!(n, unpack.recv_bytes(me), "alltoall: self block extents disagree");
    let mut buf = comm.arena().checkout(n);
    pack.pack(me, &mut buf);
    assert_eq!(buf.len(), n, "alltoall: self pack wrote unexpected byte count");
    unpack.unpack(me, &buf);
    comm.arena().recycle(buf);
}

/// FFT along y for the disc's x-extent only, over a `[nb, nx, ny, lzc]`
/// slab. Perf (EXPERIMENTS.md §Perf, L3 iteration 5): instead of a scalar
/// gather per (b, y) element with stride nb*nx, copy nb-contiguous runs
/// into an [nb, ny, n_panels] buffer and reuse the cache-tiled panel path
/// of `backend_fft_dim_ws`. The panel and transpose buffers come from the
/// caller's workspace. `lzc` is whatever z-depth the caller's slab carries:
/// the c2c plan's cyclic share of `nz`, or the r2c plan's share of the
/// `nz/2 + 1` Hermitian-unique bins.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fft_y_disc_panel(
    backend: &dyn LocalFftBackend,
    cube: &mut [Complex],
    dir: Direction,
    nb: usize,
    nx: usize,
    ny: usize,
    lzc: usize,
    disc_xs: &[usize],
    panel: &mut Vec<Complex>,
    fft: &mut Vec<Complex>,
    ctr: &Cell<u64>,
) {
    let npanels = disc_xs.len() * lzc;
    if npanels == 0 {
        return;
    }
    ensure(&mut *panel, nb * ny * npanels, ctr);
    let mut pi = 0;
    for lz in 0..lzc {
        for &x in disc_xs {
            let base = nb * (x + nx * ny * lz);
            let dst0 = pi * nb * ny;
            for k in 0..ny {
                let src = base + k * nb * nx;
                let dst = dst0 + k * nb;
                panel[dst..dst + nb].copy_from_slice(&cube[src..src + nb]);
            }
            pi += 1;
        }
    }
    backend_fft_dim_ws(backend, &mut *panel, &[nb, ny, npanels], 1, dir, &mut *fft, ctr);
    let mut pi = 0;
    for lz in 0..lzc {
        for &x in disc_xs {
            let base = nb * (x + nx * ny * lz);
            let src0 = pi * nb * ny;
            for k in 0..ny {
                let dst = base + k * nb * nx;
                let src = src0 + k * nb;
                cube[dst..dst + nb].copy_from_slice(&panel[src..src + nb]);
            }
            pi += 1;
        }
    }
}

impl PlaneWavePlan {
    /// Plan a batched plane-wave sphere transform for `offsets` with batch
    /// `nb` on the 1D `grid`.
    pub fn new(offsets: Arc<OffsetArray>, nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        assert_eq!(grid.ndim(), 1, "plane-wave plan requires a 1D processing grid");
        let p = grid.size();
        if p > offsets.nx || p > offsets.nz {
            return Err(FftbError::Unsupported(format!(
                "plane-wave plan needs p <= nx and p <= nz (p={p}, grid {}x{}x{})",
                offsets.nx, offsets.ny, offsets.nz
            )));
        }
        let r = grid.rank();
        let local_off = offsets.restrict_x_cyclic(p, r);
        let mut disc_xs: Vec<usize> = offsets
            .x_runs()
            .iter()
            .flat_map(|&(x0, len)| x0 as usize..(x0 as usize + len as usize))
            .collect();
        disc_xs.sort_unstable();

        // cols_of_rank(q) for every q, once.
        let cols_by_rank: Vec<Vec<(usize, usize)>> = (0..p)
            .map(|q| {
                let lnx = cyclic::local_count(offsets.nx, p, q);
                let mut cols = Vec::new();
                for y in 0..offsets.ny {
                    for lx in 0..lnx {
                        let gx = cyclic::local_to_global(lx, p, q);
                        if offsets.col_nonempty(gx, y) {
                            cols.push((gx, y));
                        }
                    }
                }
                cols
            })
            .collect();
        let ncols = cols_by_rank[r].len();
        let lzc = cyclic::local_count(offsets.nz, p, r);

        // Forward: to rank s go, for each owned column, s's z residues.
        let send_counts: Vec<usize> = (0..p)
            .map(|s| nb * ncols * cyclic::local_count(offsets.nz, p, s))
            .collect();
        // From rank q arrive q's columns, this rank's z residues.
        let recv_counts: Vec<usize> =
            (0..p).map(|q| nb * cols_by_rank[q].len() * lzc).collect();
        let fwd = A2aSchedule::new(send_counts, recv_counts, r);
        let inv = fwd.reversed();

        Ok(PlaneWavePlan {
            offsets,
            nb,
            grid,
            local_off,
            disc_xs,
            cols_by_rank,
            ncols,
            lzc,
            fwd,
            inv,
            tuning: CommTuning::default(),
            ws: Mutex::new(Workspace::new()),
        })
    }

    /// Override the exchange overlap knobs (window size) for this plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.tuning = tuning;
    }

    /// Return a finished output buffer (typically a dense cube the caller
    /// is done with) to the plan's slot pool — this is what makes
    /// *forward-only* sphere transforms allocation-free in steady state:
    /// without it the plan must mint a fresh output cube per call.
    pub fn recycle(&self, buf: Vec<Complex>) {
        self.ws.lock().unwrap().slots.recycle(buf);
    }

    /// Check out a buffer from this plan's slot pool, reporting the bytes
    /// of fresh allocation the take caused (zero once the pool is warm).
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        let ctr = Cell::new(0u64);
        let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
        (buf, ctr.get())
    }

    fn p(&self) -> usize {
        self.grid.size()
    }

    /// Rank count of the 1D processing grid this plan runs on.
    pub fn grid_size(&self) -> usize {
        self.grid.size()
    }

    /// Packed local input length (`nb` x locally-owned sphere points).
    pub fn input_len(&self) -> usize {
        self.nb * self.local_off.total()
    }

    /// Dense local output length `[nb, nx, ny, lzc]`.
    pub fn output_len(&self) -> usize {
        self.nb * self.offsets.nx * self.offsets.ny * self.lzc
    }

    /// FFT along y for the disc's x-extent only (the staged pad/truncate
    /// pass) — see [`fft_y_disc_panel`], which the r2c plan shares with
    /// its half-depth (`lzc` over `nz/2+1` bins) slab.
    #[allow(clippy::too_many_arguments)]
    fn fft_y_disc(
        &self,
        backend: &dyn LocalFftBackend,
        cube: &mut [Complex],
        dir: Direction,
        panel: &mut Vec<Complex>,
        fft: &mut Vec<Complex>,
        ctr: &Cell<u64>,
    ) {
        let (nx, ny) = (self.offsets.nx, self.offsets.ny);
        fft_y_disc_panel(
            backend,
            cube,
            dir,
            self.nb,
            nx,
            ny,
            self.lzc,
            &self.disc_xs,
            panel,
            fft,
            ctr,
        );
    }

    /// Forward: packed sphere coefficients → dense z-distributed cube.
    /// Owned-storage adapter over [`PlaneWavePlan::forward_into`]: the
    /// output cube comes from the plan pool and the consumed input's
    /// storage joins it for later calls.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut out, grew) = self.take_pooled(self.output_len());
        let mut trace = self.forward_into(backend, &input, &mut out);
        trace.alloc_bytes += grew;
        self.recycle(input);
        (out, trace)
    }

    /// Forward into a caller-owned dense slab — the fully zero-copy path
    /// of the `execute_into` surface: the borrowed packed input is read in
    /// place by the scatter stage, the padding memset lands directly in
    /// `out`, and the fused exchange plus both padded FFT passes run on
    /// the caller's storage. `out` must hold exactly `output_len()`
    /// elements.
    pub fn forward_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(input.len(), self.input_len(), "forward: wrong input length");
        assert_eq!(out.len(), self.output_len(), "forward: wrong output length");
        let comm = self.grid.axis_comm(0);
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let (ncols, lzc) = (self.ncols, self.lzc);
        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        let Workspace { fft, work, panel, alloc, .. } = ws;
        let alloc = &*alloc;
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);

        // steady-state: plane-wave forward
        // All storage below is workspace-pooled, arena-backed or owned by
        // the caller; pallas-lint rejects allocating calls in this region
        // and `trace.alloc_bytes` audits it at run time.
        // 1. Scatter z-runs to dense columns + FFT z.
        //    Dense layout: [nb, nz, C_loc], one zero-padded line per disc col.
        t.reshape("scatter_z", || {
            ensure_zeroed(&mut *work, nb * nz * ncols, alloc);
            self.local_off.scatter_z_into(input, nb, &mut *work);
        });
        t.compute("pad_fft_z", backend.flops(nb * nz * ncols, nz), || {
            backend_fft_dim_ws(
                backend,
                &mut *work,
                &[nb, nz, ncols],
                1,
                Direction::Forward,
                &mut *fft,
                alloc,
            );
        });

        // 2. Zero the caller's slab the received columns land in (the zero
        //    fill is the padding memset).
        t.reshape("stage_cube", || {
            out.fill(complex::ZERO);
        });

        // 3. Fused exchange: destination s's z-residue block (for each
        //    column c, each lz with gz = lz*p + s, one nb-run) is packed
        //    into its wire buffer as round s posts; each rank's columns
        //    land in the slab as that round's wait completes.
        t.comm_a2a("a2a_sphere", || {
            let c = if self.tuning.worker {
                let pack = SphereFwdPackHalf {
                    counts: &self.fwd.send_counts,
                    nb,
                    nz,
                    ncols,
                    p: self.p(),
                    work: &work[..],
                };
                let mut unpack = SphereFwdUnpackHalf {
                    counts: &self.fwd.recv_counts,
                    cols_by_rank: &self.cols_by_rank,
                    nb,
                    nx,
                    ny,
                    lzc,
                    cube: &mut out[..],
                };
                stage_self_block(comm, &pack, &mut unpack);
                alltoallv_fused_threaded(comm, &pack, &mut unpack, self.tuning)
            } else {
                let mut k = SphereFwdKernel { plan: self, work: &work[..], cube: &mut out[..] };
                fused_exchange(comm, &mut k, self.tuning)
            };
            ((), self.fwd.bytes_remote(), self.fwd.msgs(), c)
        });

        // y lines only where the disc has data: one line per (b, x in
        // disc_xs, lz); stride between y's is nb*nx.
        let y_lines: f64 =
            (nb * self.disc_xs.len() * lzc) as f64 * crate::fft::batch::fft_flops(ny);
        t.compute("pad_fft_y", y_lines, || {
            self.fft_y_disc(backend, out, Direction::Forward, &mut *panel, &mut *fft, alloc);
        });

        // 4. Dense FFT along x.
        t.compute("fft_x", backend.flops(out.len(), nx), || {
            backend_fft_dim_ws(
                backend,
                out,
                &[nb, nx, ny, lzc],
                1,
                Direction::Forward,
                &mut *fft,
                alloc,
            );
        });
        // steady-state: end
        trace.alloc_bytes = alloc.get();
        trace
    }

    /// Inverse: dense z-distributed cube → packed sphere coefficients
    /// (truncation, the r→G half of a DFT step). Owned-storage adapter
    /// over [`PlaneWavePlan::inverse_into`]: the packed output comes from
    /// the plan pool and the consumed cube's storage joins it.
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        cube: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut packed, grew) = self.take_pooled(self.input_len());
        let mut trace = self.inverse_into(backend, &cube, &mut packed);
        trace.alloc_bytes += grew;
        self.recycle(cube);
        (packed, trace)
    }

    /// Inverse into a caller-owned packed slice: the borrowed cube is
    /// staged once into workspace scratch (the x pass mutates in place),
    /// and the final truncating gather writes straight into `out`. `out`
    /// must hold exactly `input_len()` elements.
    pub fn inverse_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(input.len(), self.output_len(), "inverse: wrong input length");
        assert_eq!(out.len(), self.input_len(), "inverse: wrong output length");
        let comm = self.grid.axis_comm(0);
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let (ncols, lzc) = (self.ncols, self.lzc);
        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        let Workspace { fft, work, panel, stage, alloc, .. } = ws;
        let alloc = &*alloc;
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);

        // steady-state: plane-wave inverse
        // 1. Stage the borrowed cube, dense inverse FFT along x.
        t.compute("ifft_x", backend.flops(input.len(), nx), || {
            ensure(stage, input.len(), alloc);
            stage.copy_from_slice(input);
            backend_fft_dim_ws(
                backend,
                stage,
                &[nb, nx, ny, lzc],
                1,
                Direction::Inverse,
                &mut *fft,
                alloc,
            );
        });

        // 2. Inverse FFT along y, only the disc x-extent (the other lines
        //    would be truncated away anyway).
        let y_lines: f64 =
            (nb * self.disc_xs.len() * lzc) as f64 * crate::fft::batch::fft_flops(ny);
        t.compute("trunc_ifft_y", y_lines, || {
            self.fft_y_disc(backend, stage, Direction::Inverse, &mut *panel, &mut *fft, alloc);
        });

        // 3. Stage the dense-column buffer the merge lands in (every
        //    element is overwritten by the unpacks, so plain `ensure`).
        t.reshape("stage_cols", || {
            ensure(&mut *work, nb * nz * ncols, alloc);
        });

        // 4. Fused exchange: each owner's disc columns (my z residue) are
        //    gathered from the cube as that round posts; each rank's
        //    z-residues merge into the dense columns as its wait completes.
        t.comm_a2a("a2a_sphere", || {
            let c = if self.tuning.worker {
                let pack = SphereInvPackHalf {
                    counts: &self.inv.send_counts,
                    cols_by_rank: &self.cols_by_rank,
                    nb,
                    nx,
                    ny,
                    lzc,
                    cube: &stage[..],
                };
                let mut unpack = SphereInvUnpackHalf {
                    counts: &self.inv.recv_counts,
                    nb,
                    nz,
                    ncols,
                    p: self.p(),
                    work: &mut work[..],
                };
                stage_self_block(comm, &pack, &mut unpack);
                alltoallv_fused_threaded(comm, &pack, &mut unpack, self.tuning)
            } else {
                let mut k = SphereInvKernel { plan: self, cube: &stage[..], work: &mut work[..] };
                fused_exchange(comm, &mut k, self.tuning)
            };
            ((), self.inv.bytes_remote(), self.inv.msgs(), c)
        });

        // 5. Inverse FFT along z, truncate to the sphere runs — straight
        //    into the caller's packed slice.
        t.compute("ifft_z", backend.flops(nb * nz * ncols, nz), || {
            backend_fft_dim_ws(
                backend,
                &mut *work,
                &[nb, nz, ncols],
                1,
                Direction::Inverse,
                &mut *fft,
                alloc,
            );
        });
        t.reshape("gather_z", || {
            self.local_off.gather_z_into(&*work, nb, out);
        });
        // steady-state: end
        trace.alloc_bytes = alloc.get();
        trace
    }
}

/// The baseline the paper contrasts against (Fig. 2): zero-pad the whole
/// sphere into the cube up front and run the ordinary batched slab-pencil
/// transform — ~16x more data through every stage.
pub struct PaddedSpherePlan {
    /// Global offset array of the cut-off sphere.
    pub offsets: Arc<OffsetArray>,
    /// Batch count (transforms per execution).
    pub nb: usize,
    slab: super::slab_pencil::SlabPencilPlan,
    local_off: OffsetArray,
    ws: Mutex<Workspace>,
}

impl PaddedSpherePlan {
    /// Plan the pad-to-cube baseline for `offsets` with batch `nb` on the
    /// 1D `grid`.
    pub fn new(offsets: Arc<OffsetArray>, nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        let shape = [offsets.nx, offsets.ny, offsets.nz];
        let slab = super::slab_pencil::SlabPencilPlan::new(shape, nb, Arc::clone(&grid))?;
        let local_off = offsets.restrict_x_cyclic(grid.size(), grid.rank());
        Ok(PaddedSpherePlan { offsets, nb, slab, local_off, ws: Mutex::new(Workspace::new()) })
    }

    /// Override the exchange overlap knobs of the inner dense plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.slab.set_tuning(tuning);
    }

    /// Return a finished output buffer for reuse. Routed by the buffer's
    /// *length* (outputs come back with their content length intact):
    /// buffers of the dense output length — forward outputs, and inverse
    /// outputs of the degenerate full-cube sphere — circulate through the
    /// inner slab plan's pool (where the truncation stage also draws in
    /// that degenerate case); ordinary packed inverse outputs refill the
    /// wrapper's own pool, which serves the truncation stage. Capacity
    /// would misroute here: on uneven splits a packed buffer can be
    /// *larger* than the local cube.
    pub fn recycle(&self, buf: Vec<Complex>) {
        if buf.len() == self.output_len() {
            self.slab.recycle(buf);
        } else {
            self.ws.lock().unwrap().slots.recycle(buf);
        }
    }

    /// Rank count of the 1D processing grid the inner dense plan runs on.
    pub fn grid_size(&self) -> usize {
        self.slab.grid_size()
    }

    /// Packed local input length (`nb` x locally-owned sphere points).
    pub fn input_len(&self) -> usize {
        self.nb * self.local_off.total()
    }

    /// Dense local output length (the inner slab plan's output).
    pub fn output_len(&self) -> usize {
        self.slab.output_len()
    }

    /// Check out a buffer, routed by *length* exactly like
    /// [`PaddedSpherePlan::recycle`]: dense cube-length requests draw from
    /// the inner slab plan's pool, packed-length requests from the
    /// wrapper's own.
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        if len == self.output_len() {
            self.slab.take_pooled(len)
        } else {
            let ctr = Cell::new(0u64);
            let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
            (buf, ctr.get())
        }
    }

    /// Forward: scatter the sphere into the local slice of the full cube,
    /// then run the dense distributed FFT on everything (padding
    /// included). Owned-storage adapter over
    /// [`PaddedSpherePlan::forward_into`]; the consumed input is recycled
    /// with the same length routing `recycle` documents.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut out, grew) = self.take_pooled(self.output_len());
        let mut trace = self.forward_into(backend, &input, &mut out);
        trace.alloc_bytes += grew;
        self.recycle(input);
        (out, trace)
    }

    /// Forward into a caller-owned dense slab: the borrowed sphere is
    /// scattered into a pooled full cube, and the inner dense plan's
    /// borrowed-slice path runs straight into `out`.
    pub fn forward_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(input.len(), self.input_len(), "forward: wrong input length");
        let nb = self.nb;
        let (lxc, ny, nz) = (self.local_off.nx, self.local_off.ny, self.local_off.nz);
        let mut trace = ExecTrace::default();
        let mut cube = Vec::new();
        let grew = Cell::new(0u64);
        let mut t = StageTimer::new(&mut trace);
        // steady-state: padded-sphere forward (pad stage)
        // Pad up front: local dense [nb, lxc, ny, nz]. The cube comes
        // from the *inner slab plan's* pool — that is where the
        // consumed cube and caller-recycled outputs land, so
        // cube-sized storage circulates through one pool.
        t.reshape("pad_full", || {
            let (mut c, g) = self.slab.take_pooled(nb * lxc * ny * nz);
            grew.set(grew.get() + g);
            c.fill(crate::fft::complex::ZERO);
            cube = c;
            for y in 0..ny {
                for lx in 0..lxc {
                    let mut e = self.local_off.col_offset(lx, y);
                    for &(z0, len) in self.local_off.col_runs(lx, y) {
                        for z in z0 as usize..(z0 + len) as usize {
                            let dst = nb * (lx + lxc * (y + ny * z));
                            let src = nb * e;
                            cube[dst..dst + nb].copy_from_slice(&input[src..src + nb]);
                            e += 1;
                        }
                    }
                }
            }
        });
        // steady-state: end
        trace.alloc_bytes = grew.get();
        let slab_trace = self.slab.run_into(backend, &cube, out, Direction::Forward);
        // Cube-sized storage belongs to the inner slab plan's pool.
        self.slab.recycle(cube);
        merge_trace(&mut trace, slab_trace);
        trace
    }

    /// Inverse: dense distributed inverse FFT, then truncate to the
    /// sphere. Owned-storage adapter over
    /// [`PaddedSpherePlan::inverse_into`]; output and consumed cube are
    /// length-routed between the slab and wrapper pools (see `recycle`).
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        cube: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (mut packed, grew) = self.take_pooled(self.input_len());
        let mut trace = self.inverse_into(backend, &cube, &mut packed);
        trace.alloc_bytes += grew;
        self.recycle(cube);
        (packed, trace)
    }

    /// Inverse into a caller-owned packed slice: the inner dense plan's
    /// borrowed-slice inverse lands in a pooled full cube, which the
    /// truncation stage gathers straight into `out`.
    pub fn inverse_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
    ) -> ExecTrace {
        assert_eq!(out.len(), self.input_len(), "inverse: wrong output length");
        let (mut back, grew) = self.slab.take_pooled(self.slab.input_len());
        let mut trace = self.slab.run_into(backend, input, &mut back, Direction::Inverse);
        trace.alloc_bytes += grew;
        let nb = self.nb;
        let (lxc, ny) = (self.local_off.nx, self.local_off.ny);
        let mut t = StageTimer::new(&mut trace);
        // steady-state: padded-sphere inverse (truncate stage)
        t.reshape("trunc_full", || {
            for y in 0..ny {
                for lx in 0..lxc {
                    let mut e = self.local_off.col_offset(lx, y);
                    for &(z0, len) in self.local_off.col_runs(lx, y) {
                        for z in z0 as usize..(z0 + len) as usize {
                            let src = nb * (lx + lxc * (y + ny * z));
                            let dst = nb * e;
                            out[dst..dst + nb].copy_from_slice(&back[src..src + nb]);
                            e += 1;
                        }
                    }
                }
            }
        });
        // Cube-sized storage belongs to the inner slab plan's pool.
        self.slab.recycle(back);
        // steady-state: end
        trace
    }
}

/// Fold one sub-plan's trace (stages and overlap counters) into `total` —
/// the padded-sphere wrapper composes its pad/truncate stages with the
/// inner dense plan's trace this way.
fn merge_trace(total: &mut ExecTrace, piece: ExecTrace) {
    total.alloc_bytes += piece.alloc_bytes;
    total.wait_ns += piece.wait_ns;
    total.overlap_rounds += piece.overlap_rounds;
    total.pack_overlap_ns += piece.pack_overlap_ns;
    total.unpack_overlap_ns += piece.unpack_overlap_ns;
    total.worker_busy_ns += piece.worker_busy_ns;
    total.pipeline_overlap_ns += piece.pipeline_overlap_ns;
    total.stages.extend(piece.stages);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::max_abs_diff;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{gather_cube_z, phased};
    use crate::fftb::sphere::{sphere_to_cube, SphereKind, SphereSpec};

    /// Oracle: pad the full sphere into the cube, dense 3D FFT per band.
    fn oracle_forward(
        off: &OffsetArray,
        packed: &[Complex],
        nb: usize,
    ) -> Vec<Complex> {
        let mut cube = sphere_to_cube(off, packed, nb);
        let sh = [nb, off.nx, off.ny, off.nz];
        for dim in 1..4 {
            crate::fft::nd::fft_dim(&mut cube, &sh, dim, Direction::Forward);
        }
        cube
    }

    /// Split the global packed sphere coefficients into per-rank packed
    /// vectors (x cyclic), batch fastest.
    fn scatter_sphere(
        off: &OffsetArray,
        packed: &[Complex],
        nb: usize,
        p: usize,
        r: usize,
    ) -> Vec<Complex> {
        let loc = off.restrict_x_cyclic(p, r);
        let mut out = Vec::with_capacity(nb * loc.total());
        for y in 0..off.ny {
            for lx in 0..loc.nx {
                let gx = cyclic::local_to_global(lx, p, r);
                let e0 = off.col_offset(gx, y);
                let n = off.col_len(gx, y);
                out.extend_from_slice(&packed[nb * e0..nb * (e0 + n)]);
            }
        }
        out
    }

    fn check(kind: SphereKind, n: usize, radius: f64, nb: usize, p: usize) {
        let spec = SphereSpec::new([n, n, n], radius, kind);
        let off = Arc::new(spec.offsets());
        assert!(off.total() > 0);
        let packed = phased(nb * off.total(), 31);
        let want = oracle_forward(&off, &packed, nb);

        let off2 = Arc::clone(&off);
        let packed2 = packed.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let local = scatter_sphere(&off2, &packed2, nb, p, grid.rank());
            let backend = RustFftBackend::new();
            let (out, _) = plan.forward(&backend, local);
            out
        });
        let got = gather_cube_z(&outs, nb, [n, n, n], p);
        assert!(
            max_abs_diff(&got, &want) < 1e-8 * (n * n * n) as f64,
            "kind={kind:?} n={n} nb={nb} p={p}"
        );
    }

    #[test]
    fn forward_matches_padded_oracle() {
        check(SphereKind::Centered, 8, 3.2, 1, 1);
        check(SphereKind::Centered, 8, 3.2, 2, 2);
        check(SphereKind::Centered, 16, 4.0, 1, 4);
        check(SphereKind::Wrapped, 8, 3.0, 2, 2);
        check(SphereKind::Wrapped, 12, 4.5, 1, 3);
    }

    #[test]
    fn forward_inverse_round_trip() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let p = 2;
        let packed = phased(nb * off.total(), 5);
        let off2 = Arc::clone(&off);
        let packed2 = packed.clone();
        let errs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let local = scatter_sphere(&off2, &packed2, nb, p, grid.rank());
            let backend = RustFftBackend::new();
            let (cube, _) = plan.forward(&backend, local.clone());
            let (back, _) = plan.inverse(&backend, cube);
            max_abs_diff(&back, &local)
        });
        for e in errs {
            assert!(e < 1e-10, "round trip err {e}");
        }
    }

    #[test]
    fn padded_plan_matches_planewave_plan() {
        // d = n/2 sphere: the staged exchange moves ~pi/16 of the dense one.
        let spec = SphereSpec::new([16, 16, 16], 4.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let p = 2;
        let packed = phased(nb * off.total(), 9);
        let off2 = Arc::clone(&off);
        let packed2 = packed.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let local = scatter_sphere(&off2, &packed2, nb, p, grid.rank());
            let backend = RustFftBackend::new();
            let pw = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let (a, tr_a) = pw.forward(&backend, local.clone());
            let padded =
                PaddedSpherePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let (b, tr_b) = padded.forward(&backend, local);
            // Identical numerics...
            assert!(max_abs_diff(&a, &b) < 1e-8);
            // ...but the staged plan moves strictly fewer bytes.
            (tr_a.comm_bytes(), tr_b.comm_bytes())
        });
        for (staged, padded) in outs {
            assert!(
                staged * 3 < padded,
                "staged ({staged} B) should be well under padded ({padded} B)"
            );
        }
    }

    #[test]
    fn comm_savings_scale_with_disc_fraction() {
        // d = n/2 sphere: disc fraction = pi/16 of the xy plane; the staged
        // alltoall should move roughly that fraction of the dense exchange.
        let n = 16;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let disc_frac = off.disc_columns().len() as f64 / (n * n) as f64;
        assert!(disc_frac < 0.3, "disc fraction {disc_frac}");
    }

    #[test]
    fn oversubscribed_grid_rejected() {
        run_world(4, |comm| {
            let grid = ProcGrid::new(&[4], comm).unwrap();
            let spec = SphereSpec::new([2, 8, 8], 1.0, SphereKind::Centered);
            let off = Arc::new(spec.offsets());
            let e = PlaneWavePlan::new(off, 1, grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)));
        });
    }
}
