//! The plane-wave batched sphere transform (paper §2.2/§3.3, Fig. 3) — the
//! headline contribution: zero-padding done *in stages*, each fused with the
//! 1D FFT of that dimension, so redundant compute and communication never
//! materialize.
//!
//! Forward (`G`-sphere → `r`-cube), 1D processing grid, sphere columns
//! distributed cyclically over `x`:
//!
//! 1. `pad_fft_z`  — scatter each owned CSR column's z-runs into a dense,
//!                   zero-padded z-line and FFT it (only `|disc|` columns,
//!                   not `nx*ny`),
//! 2. `a2a_sphere` — one alltoall moving *only disc columns* (a ~pi/4 ·
//!                   (d/n)^2 fraction of the full-cube exchange) to a
//!                   z-slab distribution,
//! 3. `pad_fft_y`  — the received columns land in a zeroed cube slab;
//!                   FFT along `y` only for the disc's x-extent,
//! 4. `fft_x`      — dense FFT along `x` (every line now carries data).
//!
//! The inverse runs the mirror image with truncation instead of padding.
//! Output layout matches the slab-pencil plan: `[nb, nx, ny, lzc]`,
//! z cyclic — so plane-wave and cuboid transforms compose downstream
//! (density builds, potentials) identically.

use std::sync::Arc;

use crate::comm::alltoall::alltoallv_complex;
use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;
use crate::fftb::backend::{backend_fft_dim, LocalFftBackend};
use crate::fftb::grid::{cyclic, ProcGrid};
use crate::fftb::sphere::OffsetArray;

use super::stages::{ExecTrace, StageTimer};

/// Batched plane-wave transform plan for one sphere on a 1D grid.
pub struct PlaneWavePlan {
    /// Global offset array of the cut-off sphere.
    pub offsets: Arc<OffsetArray>,
    pub nb: usize,
    grid: Arc<ProcGrid>,
    /// This rank's restriction of the offset array (x cyclic).
    local_off: OffsetArray,
    /// Sorted distinct x's of the global disc (for the staged y pass).
    disc_xs: Vec<usize>,
}

impl PlaneWavePlan {
    pub fn new(offsets: Arc<OffsetArray>, nb: usize, grid: Arc<ProcGrid>) -> Self {
        assert_eq!(grid.ndim(), 1, "plane-wave plan requires a 1D processing grid");
        let p = grid.size();
        assert!(
            p <= offsets.nx && p <= offsets.nz,
            "plane-wave plan needs p <= nx and p <= nz (p={p}, grid {}x{}x{})",
            offsets.nx,
            offsets.ny,
            offsets.nz
        );
        let local_off = offsets.restrict_x_cyclic(p, grid.rank());
        let mut disc_xs: Vec<usize> = offsets
            .x_runs()
            .iter()
            .flat_map(|&(x0, len)| x0 as usize..(x0 as usize + len as usize))
            .collect();
        disc_xs.sort_unstable();
        PlaneWavePlan { offsets, nb, grid, local_off, disc_xs }
    }

    fn p(&self) -> usize {
        self.grid.size()
    }

    fn r(&self) -> usize {
        self.grid.rank()
    }

    /// Packed local input length (`nb` x locally-owned sphere points).
    pub fn input_len(&self) -> usize {
        self.nb * self.local_off.total()
    }

    /// Dense local output length `[nb, nx, ny, lzc]`.
    pub fn output_len(&self) -> usize {
        let lzc = cyclic::local_count(self.offsets.nz, self.p(), self.r());
        self.nb * self.offsets.nx * self.offsets.ny * lzc
    }

    /// Disc columns owned by rank `q`, in q's local packing order
    /// (y outer, local-x inner), as global `(gx, y)` pairs.
    fn cols_of_rank(&self, q: usize) -> Vec<(usize, usize)> {
        let p = self.p();
        let lnx = cyclic::local_count(self.offsets.nx, p, q);
        let mut cols = Vec::new();
        for y in 0..self.offsets.ny {
            for lx in 0..lnx {
                let gx = cyclic::local_to_global(lx, p, q);
                if self.offsets.col_nonempty(gx, y) {
                    cols.push((gx, y));
                }
            }
        }
        cols
    }

    /// FFT along y for the disc's x-extent only (the staged pad/truncate
    /// pass). Perf (EXPERIMENTS.md §Perf, L3 iteration 5): instead of a
    /// scalar gather per (b, y) element with stride nb*nx, copy
    /// nb-contiguous runs into an [nb, ny, n_panels] buffer and reuse the
    /// cache-tiled panel path of `backend_fft_dim`.
    fn fft_y_disc(
        &self,
        backend: &dyn LocalFftBackend,
        cube: &mut [Complex],
        lzc: usize,
        dir: Direction,
    ) {
        let (nx, ny) = (self.offsets.nx, self.offsets.ny);
        let nb = self.nb;
        let npanels = self.disc_xs.len() * lzc;
        if npanels == 0 {
            return;
        }
        let mut buf = vec![ZERO; nb * ny * npanels];
        let mut panel = 0;
        for lz in 0..lzc {
            for &x in &self.disc_xs {
                let base = nb * (x + nx * ny * lz);
                let dst0 = panel * nb * ny;
                for k in 0..ny {
                    let src = base + k * nb * nx;
                    let dst = dst0 + k * nb;
                    buf[dst..dst + nb].copy_from_slice(&cube[src..src + nb]);
                }
                panel += 1;
            }
        }
        backend_fft_dim(backend, &mut buf, &[nb, ny, npanels], 1, dir);
        let mut panel = 0;
        for lz in 0..lzc {
            for &x in &self.disc_xs {
                let base = nb * (x + nx * ny * lz);
                let src0 = panel * nb * ny;
                for k in 0..ny {
                    let dst = base + k * nb * nx;
                    let src = src0 + k * nb;
                    cube[dst..dst + nb].copy_from_slice(&buf[src..src + nb]);
                }
                panel += 1;
            }
        }
    }

    /// Forward: packed sphere coefficients → dense z-distributed cube.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        assert_eq!(input.len(), self.input_len(), "forward: wrong input length");
        let (p, r) = (self.p(), self.r());
        let comm = self.grid.axis_comm(0);
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let lzc = cyclic::local_count(nz, p, r);
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);

        // 1. Scatter z-runs to dense columns + FFT z.
        //    Dense layout: [nb, nz, C_loc], one zero-padded line per disc col.
        let (mut cylin, my_cols) = t.reshape("scatter_z", || self.local_off.scatter_z(&input, nb));
        let ncols = my_cols.len();
        t.compute("pad_fft_z", backend.flops(cylin.len(), nz), || {
            backend_fft_dim(backend, &mut cylin, &[nb, nz, ncols], 1, Direction::Forward);
        });

        // 2. Pack per-destination z-residue blocks and exchange.
        //    Block to s: for each column c, for each lz (gz = lz*p + s), nb-run.
        let blocks = t.reshape("pack_cols", || {
            let mut blocks: Vec<Vec<Complex>> = (0..p)
                .map(|s| {
                    Vec::with_capacity(nb * ncols * cyclic::local_count(nz, p, s))
                })
                .collect();
            for (s, block) in blocks.iter_mut().enumerate() {
                let lzc_s = cyclic::local_count(nz, p, s);
                for c in 0..ncols {
                    let base = c * nb * nz;
                    for lz in 0..lzc_s {
                        let gz = cyclic::local_to_global(lz, p, s);
                        let src = base + nb * gz;
                        block.extend_from_slice(&cylin[src..src + nb]);
                    }
                }
            }
            blocks
        });
        drop(cylin);
        let recv = t.comm("a2a_sphere", || {
            let sent: u64 = blocks
                .iter()
                .enumerate()
                .filter(|(s, _)| *s != r)
                .map(|(_, b)| (b.len() * 16) as u64)
                .sum();
            (alltoallv_complex(comm, blocks), sent, (p - 1) as u64)
        });

        // 3. Land the columns in a zeroed slab; FFT y over the disc x-extent.
        let mut cube = t.reshape("unpack_cube", || {
            let mut cube = vec![ZERO; nb * nx * ny * lzc];
            for (q, block) in recv.iter().enumerate() {
                let cols_q = self.cols_of_rank(q);
                assert_eq!(block.len(), nb * cols_q.len() * lzc, "bad block from rank {q}");
                let mut src = 0;
                for &(gx, y) in &cols_q {
                    for lz in 0..lzc {
                        let dst = nb * (gx + nx * (y + ny * lz));
                        cube[dst..dst + nb].copy_from_slice(&block[src..src + nb]);
                        src += nb;
                    }
                }
            }
            cube
        });
        drop(recv);

        // y lines only where the disc has data: one line per (b, x in
        // disc_xs, lz); stride between y's is nb*nx.
        let y_lines: f64 = (nb * self.disc_xs.len() * lzc) as f64
            * crate::fft::batch::fft_flops(ny);
        t.compute("pad_fft_y", y_lines, || {
            self.fft_y_disc(backend, &mut cube, lzc, Direction::Forward);
        });

        // 4. Dense FFT along x.
        t.compute("fft_x", backend.flops(cube.len(), nx), || {
            backend_fft_dim(backend, &mut cube, &[nb, nx, ny, lzc], 1, Direction::Forward);
        });
        (cube, trace)
    }

    /// Inverse: dense z-distributed cube → packed sphere coefficients
    /// (truncation, the r→G half of a DFT step).
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        mut cube: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        assert_eq!(cube.len(), self.output_len(), "inverse: wrong input length");
        let (p, r) = (self.p(), self.r());
        let comm = self.grid.axis_comm(0);
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let lzc = cyclic::local_count(nz, p, r);
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);

        // 1. Dense inverse FFT along x.
        t.compute("ifft_x", backend.flops(cube.len(), nx), || {
            backend_fft_dim(backend, &mut cube, &[nb, nx, ny, lzc], 1, Direction::Inverse);
        });

        // 2. Inverse FFT along y, only the disc x-extent (the other lines
        //    would be truncated away anyway).
        let y_lines: f64 = (nb * self.disc_xs.len() * lzc) as f64
            * crate::fft::batch::fft_flops(ny);
        t.compute("trunc_ifft_y", y_lines, || {
            self.fft_y_disc(backend, &mut cube, lzc, Direction::Inverse);
        });

        // 3. Gather each owner's disc columns (my z residue) and exchange.
        let blocks = t.reshape("pack_cols", || {
            let mut blocks: Vec<Vec<Complex>> = Vec::with_capacity(p);
            for q in 0..p {
                let cols_q = self.cols_of_rank(q);
                let mut block = Vec::with_capacity(nb * cols_q.len() * lzc);
                for &(gx, y) in &cols_q {
                    for lz in 0..lzc {
                        let src = nb * (gx + nx * (y + ny * lz));
                        block.extend_from_slice(&cube[src..src + nb]);
                    }
                }
                blocks.push(block);
            }
            blocks
        });
        drop(cube);
        let recv = t.comm("a2a_sphere", || {
            let sent: u64 = blocks
                .iter()
                .enumerate()
                .filter(|(s, _)| *s != r)
                .map(|(_, b)| (b.len() * 16) as u64)
                .sum();
            (alltoallv_complex(comm, blocks), sent, (p - 1) as u64)
        });

        // 4. Merge z residues into dense local columns.
        let my_cols = self.cols_of_rank(r);
        let ncols = my_cols.len();
        let mut cylin = t.reshape("unpack_cols", || {
            let mut cylin = vec![ZERO; nb * nz * ncols];
            for (s, block) in recv.iter().enumerate() {
                let lzc_s = cyclic::local_count(nz, p, s);
                assert_eq!(block.len(), nb * ncols * lzc_s, "bad block from rank {s}");
                let mut src = 0;
                for c in 0..ncols {
                    let base = c * nb * nz;
                    for lz in 0..lzc_s {
                        let gz = cyclic::local_to_global(lz, p, s);
                        let dst = base + nb * gz;
                        cylin[dst..dst + nb].copy_from_slice(&block[src..src + nb]);
                        src += nb;
                    }
                }
            }
            cylin
        });
        drop(recv);

        // 5. Inverse FFT along z, truncate to the sphere runs.
        t.compute("ifft_z", backend.flops(cylin.len(), nz), || {
            backend_fft_dim(backend, &mut cylin, &[nb, nz, ncols], 1, Direction::Inverse);
        });
        let packed = t.reshape("gather_z", || self.local_off.gather_z(&cylin, nb));
        (packed, trace)
    }
}

/// The baseline the paper contrasts against (Fig. 2): zero-pad the whole
/// sphere into the cube up front and run the ordinary batched slab-pencil
/// transform — ~16x more data through every stage.
pub struct PaddedSpherePlan {
    pub offsets: Arc<OffsetArray>,
    pub nb: usize,
    slab: super::slab_pencil::SlabPencilPlan,
    local_off: OffsetArray,
    grid: Arc<ProcGrid>,
}

impl PaddedSpherePlan {
    pub fn new(offsets: Arc<OffsetArray>, nb: usize, grid: Arc<ProcGrid>) -> Self {
        let shape = [offsets.nx, offsets.ny, offsets.nz];
        let slab = super::slab_pencil::SlabPencilPlan::new(shape, nb, Arc::clone(&grid));
        let local_off = offsets.restrict_x_cyclic(grid.size(), grid.rank());
        PaddedSpherePlan { offsets, nb, slab, local_off, grid }
    }

    pub fn input_len(&self) -> usize {
        self.nb * self.local_off.total()
    }

    pub fn output_len(&self) -> usize {
        self.slab.output_len()
    }

    /// Forward: scatter the sphere into the local slice of the full cube,
    /// then run the dense distributed FFT on everything (padding included).
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        assert_eq!(input.len(), self.input_len());
        let (p, r) = (self.grid.size(), self.grid.rank());
        let (nx, ny, nz) = (self.offsets.nx, self.offsets.ny, self.offsets.nz);
        let nb = self.nb;
        let lxc = cyclic::local_count(nx, p, r);
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);
        // Pad up front: local dense [nb, lxc, ny, nz].
        let cube = t.reshape("pad_full", || {
            let mut cube = vec![ZERO; nb * lxc * ny * nz];
            for y in 0..ny {
                for lx in 0..lxc {
                    let mut e = self.local_off.col_offset(lx, y);
                    for &(z0, len) in self.local_off.col_runs(lx, y) {
                        for z in z0 as usize..(z0 + len) as usize {
                            let dst = nb * (lx + lxc * (y + ny * z));
                            let src = nb * e;
                            cube[dst..dst + nb].copy_from_slice(&input[src..src + nb]);
                            e += 1;
                        }
                    }
                }
            }
            cube
        });
        let (out, slab_trace) = self.slab.forward(backend, cube);
        trace.stages.extend(slab_trace.stages);
        (out, trace)
    }

    /// Inverse: dense distributed inverse FFT, then truncate to the sphere.
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        cube: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        let (back, mut trace) = self.slab.inverse(backend, cube);
        let nb = self.nb;
        let (lxc, ny) = (self.local_off.nx, self.local_off.ny);
        let mut t = StageTimer::new(&mut trace);
        let packed = t.reshape("trunc_full", || {
            let mut packed = vec![ZERO; nb * self.local_off.total()];
            for y in 0..ny {
                for lx in 0..lxc {
                    let mut e = self.local_off.col_offset(lx, y);
                    for &(z0, len) in self.local_off.col_runs(lx, y) {
                        for z in z0 as usize..(z0 + len) as usize {
                            let src = nb * (lx + lxc * (y + ny * z));
                            let dst = nb * e;
                            packed[dst..dst + nb].copy_from_slice(&back[src..src + nb]);
                            e += 1;
                        }
                    }
                }
            }
            packed
        });
        (packed, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::max_abs_diff;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{gather_cube_z, phased};
    use crate::fftb::sphere::{sphere_to_cube, SphereKind, SphereSpec};

    /// Oracle: pad the full sphere into the cube, dense 3D FFT per band.
    fn oracle_forward(
        off: &OffsetArray,
        packed: &[Complex],
        nb: usize,
    ) -> Vec<Complex> {
        let mut cube = sphere_to_cube(off, packed, nb);
        let sh = [nb, off.nx, off.ny, off.nz];
        for dim in 1..4 {
            crate::fft::nd::fft_dim(&mut cube, &sh, dim, Direction::Forward);
        }
        cube
    }

    /// Split the global packed sphere coefficients into per-rank packed
    /// vectors (x cyclic), batch fastest.
    fn scatter_sphere(
        off: &OffsetArray,
        packed: &[Complex],
        nb: usize,
        p: usize,
        r: usize,
    ) -> Vec<Complex> {
        let loc = off.restrict_x_cyclic(p, r);
        let mut out = Vec::with_capacity(nb * loc.total());
        for y in 0..off.ny {
            for lx in 0..loc.nx {
                let gx = cyclic::local_to_global(lx, p, r);
                let e0 = off.col_offset(gx, y);
                let n = off.col_len(gx, y);
                out.extend_from_slice(&packed[nb * e0..nb * (e0 + n)]);
            }
        }
        out
    }

    fn check(kind: SphereKind, n: usize, radius: f64, nb: usize, p: usize) {
        let spec = SphereSpec::new([n, n, n], radius, kind);
        let off = Arc::new(spec.offsets());
        assert!(off.total() > 0);
        let packed = phased(nb * off.total(), 31);
        let want = oracle_forward(&off, &packed, nb);

        let off2 = Arc::clone(&off);
        let packed2 = packed.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid));
            let local = scatter_sphere(&off2, &packed2, nb, p, grid.rank());
            let backend = RustFftBackend::new();
            let (out, _) = plan.forward(&backend, local);
            out
        });
        let got = gather_cube_z(&outs, nb, [n, n, n], p);
        assert!(
            max_abs_diff(&got, &want) < 1e-8 * (n * n * n) as f64,
            "kind={kind:?} n={n} nb={nb} p={p}"
        );
    }

    #[test]
    fn forward_matches_padded_oracle() {
        check(SphereKind::Centered, 8, 3.2, 1, 1);
        check(SphereKind::Centered, 8, 3.2, 2, 2);
        check(SphereKind::Centered, 16, 4.0, 1, 4);
        check(SphereKind::Wrapped, 8, 3.0, 2, 2);
        check(SphereKind::Wrapped, 12, 4.5, 1, 3);
    }

    #[test]
    fn forward_inverse_round_trip() {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let p = 2;
        let packed = phased(nb * off.total(), 5);
        let off2 = Arc::clone(&off);
        let packed2 = packed.clone();
        let errs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid));
            let local = scatter_sphere(&off2, &packed2, nb, p, grid.rank());
            let backend = RustFftBackend::new();
            let (cube, _) = plan.forward(&backend, local.clone());
            let (back, _) = plan.inverse(&backend, cube);
            max_abs_diff(&back, &local)
        });
        for e in errs {
            assert!(e < 1e-10, "round trip err {e}");
        }
    }

    #[test]
    fn padded_plan_matches_planewave_plan() {
        // d = n/2 sphere: the staged exchange moves ~pi/16 of the dense one.
        let spec = SphereSpec::new([16, 16, 16], 4.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let nb = 2;
        let p = 2;
        let packed = phased(nb * off.total(), 9);
        let off2 = Arc::clone(&off);
        let packed2 = packed.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let local = scatter_sphere(&off2, &packed2, nb, p, grid.rank());
            let backend = RustFftBackend::new();
            let pw = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid));
            let (a, tr_a) = pw.forward(&backend, local.clone());
            let padded = PaddedSpherePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid));
            let (b, tr_b) = padded.forward(&backend, local);
            // Identical numerics...
            assert!(max_abs_diff(&a, &b) < 1e-8);
            // ...but the staged plan moves strictly fewer bytes.
            (tr_a.comm_bytes(), tr_b.comm_bytes())
        });
        for (staged, padded) in outs {
            assert!(
                staged * 3 < padded,
                "staged ({staged} B) should be well under padded ({padded} B)"
            );
        }
    }

    #[test]
    fn comm_savings_scale_with_disc_fraction() {
        // d = n/2 sphere: disc fraction = pi/16 of the xy plane; the staged
        // alltoall should move roughly that fraction of the dense exchange.
        let n = 16;
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let disc_frac = off.disc_columns().len() as f64 / (n * n) as f64;
        assert!(disc_frac < 0.3, "disc fraction {disc_frac}");
    }
}
