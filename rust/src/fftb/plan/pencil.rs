//! Pencil-pencil-pencil distributed 3D FFT on a 2D processing grid
//! (paper Fig. 1b): three batches of 1D FFTs with two alltoall exchanges in
//! the row/column sub-communicators.
//!
//! Input layout: `"b x y{0} z{1}"` — x dense, y cyclic over grid axis 0,
//! z cyclic over grid axis 1. Local `[nb, nx, lyc0, lzc1]`.
//!
//! Forward stages:
//! 1. `fft_x`    — x lines are complete locally,
//! 2. `a2a_xy`   — row-comm alltoall trading the x split for a y split,
//!    `fft_y`,
//! 3. `a2a_yz`   — column-comm alltoall trading the y split for a z split,
//!    `fft_z`.
//!
//! Output layout: `"b x{0} y{1} z"` — local `[nb, lxc0, lyc1, nz]`.
//!
//! 3D processing grids are supported by axis folding: a `(p0, p1, p2)` grid
//! runs the pencil plan on the folded `(p0*p1, p2)` grid (see
//! `Fftb::plan` in `plan/mod.rs`), which preserves the paper's API surface
//! (Table 1: processing grid 1D/2D/3D) with the same communication volume.

use std::sync::Arc;

use crate::comm::alltoall::alltoallv_complex;
use crate::comm::communicator::Comm;
use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::{backend_fft_dim, LocalFftBackend};
use crate::fftb::grid::{cyclic, ProcGrid};

use super::redistribute::{merge_dim, split_dim};
use super::stages::{ExecTrace, StageTimer};

/// Batched pencil-decomposition 3D FFT plan on a 2D grid.
pub struct PencilPlan {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    pub nb: usize,
    grid: Arc<ProcGrid>,
}

impl PencilPlan {
    pub fn new(shape: [usize; 3], nb: usize, grid: Arc<ProcGrid>) -> Self {
        assert_eq!(grid.ndim(), 2, "pencil plan requires a 2D processing grid");
        let (p0, p1) = (grid.axis_len(0), grid.axis_len(1));
        assert!(
            p0 <= shape[0] && p0 <= shape[1] && p1 <= shape[1] && p1 <= shape[2],
            "pencil plan needs p0 <= min(nx, ny) and p1 <= min(ny, nz) \
             (p0={p0}, p1={p1}, shape={shape:?})"
        );
        PencilPlan { nx: shape[0], ny: shape[1], nz: shape[2], nb, grid }
    }

    fn coords(&self) -> (usize, usize) {
        (self.grid.axis_coord(0), self.grid.axis_coord(1))
    }

    fn sizes(&self) -> (usize, usize) {
        (self.grid.axis_len(0), self.grid.axis_len(1))
    }

    /// Local input length `[nb, nx, lyc0, lzc1]`.
    pub fn input_len(&self) -> usize {
        let (p0, p1) = self.sizes();
        let (r0, r1) = self.coords();
        self.nb
            * self.nx
            * cyclic::local_count(self.ny, p0, r0)
            * cyclic::local_count(self.nz, p1, r1)
    }

    /// Local output length `[nb, lxc0, lyc1, nz]`.
    pub fn output_len(&self) -> usize {
        let (p0, p1) = self.sizes();
        let (r0, r1) = self.coords();
        self.nb
            * cyclic::local_count(self.nx, p0, r0)
            * cyclic::local_count(self.ny, p1, r1)
            * self.nz
    }

    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, Direction::Forward)
    }

    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, Direction::Inverse)
    }

    fn exchange(
        t: &mut StageTimer,
        name: &'static str,
        comm: &Comm,
        blocks: Vec<Vec<Complex>>,
    ) -> Vec<Vec<Complex>> {
        let me = comm.rank();
        t.comm(name, || {
            let sent: u64 = blocks
                .iter()
                .enumerate()
                .filter(|(s, _)| *s != me)
                .map(|(_, b)| (b.len() * 16) as u64)
                .sum();
            let msgs = (comm.size() - 1) as u64;
            (alltoallv_complex(comm, blocks), sent, msgs)
        })
    }

    fn run(
        &self,
        backend: &dyn LocalFftBackend,
        mut data: Vec<Complex>,
        dir: Direction,
    ) -> (Vec<Complex>, ExecTrace) {
        let (p0, p1) = self.sizes();
        let (r0, r1) = self.coords();
        let row = self.grid.axis_comm(0);
        let col = self.grid.axis_comm(1);
        let lxc = cyclic::local_count(self.nx, p0, r0);
        let lyc0 = cyclic::local_count(self.ny, p0, r0);
        let lyc1 = cyclic::local_count(self.ny, p1, r1);
        let lzc1 = cyclic::local_count(self.nz, p1, r1);
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);
        let lines = |total: usize, n: usize| backend.flops(total, n);

        match dir {
            Direction::Forward => {
                assert_eq!(data.len(), self.input_len(), "forward: wrong input length");
                // 1. FFT x (dense locally).
                let sh1 = [self.nb, self.nx, lyc0, lzc1];
                t.compute("fft_x", lines(data.len(), self.nx), || {
                    backend_fft_dim(backend, &mut data, &sh1, 1, dir);
                });
                // 2. Row alltoall: split x, merge y.
                let blocks = t.reshape("pack_x", || split_dim(&data, sh1, 1, p0));
                let recv = Self::exchange(&mut t, "a2a_xy", row, blocks);
                let sh2 = [self.nb, lxc, self.ny, lzc1];
                data = t.reshape("unpack_y", || merge_dim(&recv, sh2, 2, p0));
                t.compute("fft_y", lines(data.len(), self.ny), || {
                    backend_fft_dim(backend, &mut data, &sh2, 2, dir);
                });
                // 3. Column alltoall: split y, merge z.
                let blocks = t.reshape("pack_y", || split_dim(&data, sh2, 2, p1));
                let recv = Self::exchange(&mut t, "a2a_yz", col, blocks);
                let sh3 = [self.nb, lxc, lyc1, self.nz];
                data = t.reshape("unpack_z", || merge_dim(&recv, sh3, 3, p1));
                t.compute("fft_z", lines(data.len(), self.nz), || {
                    backend_fft_dim(backend, &mut data, &sh3, 3, dir);
                });
            }
            Direction::Inverse => {
                assert_eq!(data.len(), self.output_len(), "inverse: wrong input length");
                let sh3 = [self.nb, lxc, lyc1, self.nz];
                t.compute("ifft_z", lines(data.len(), self.nz), || {
                    backend_fft_dim(backend, &mut data, &sh3, 3, dir);
                });
                let blocks = t.reshape("pack_z", || split_dim(&data, sh3, 3, p1));
                let recv = Self::exchange(&mut t, "a2a_zy", col, blocks);
                let sh2 = [self.nb, lxc, self.ny, lzc1];
                data = t.reshape("unpack_y", || merge_dim(&recv, sh2, 2, p1));
                t.compute("ifft_y", lines(data.len(), self.ny), || {
                    backend_fft_dim(backend, &mut data, &sh2, 2, dir);
                });
                let blocks = t.reshape("pack_y", || split_dim(&data, sh2, 2, p0));
                let recv = Self::exchange(&mut t, "a2a_yx", row, blocks);
                let sh1 = [self.nb, self.nx, lyc0, lzc1];
                data = t.reshape("unpack_x", || merge_dim(&recv, sh1, 1, p0));
                t.compute("ifft_x", lines(data.len(), self.nx), || {
                    backend_fft_dim(backend, &mut data, &sh1, 1, dir);
                });
            }
        }
        (data, trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::max_abs_diff;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{gather_cube_xy, phased, scatter_cube_yz};

    fn check(shape: [usize; 3], nb: usize, p0: usize, p1: usize) {
        let [nx, ny, nz] = shape;
        let global = phased(nb * nx * ny * nz, 17);
        let mut want = global.clone();
        let sh = [nb, nx, ny, nz];
        for dim in 1..4 {
            crate::fft::nd::fft_dim(&mut want, &sh, dim, Direction::Forward);
        }
        let outs = run_world(p0 * p1, |comm| {
            let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
            let plan = PencilPlan::new(shape, nb, Arc::clone(&grid));
            let local = scatter_cube_yz(
                &global,
                nb,
                shape,
                p0,
                grid.axis_coord(0),
                p1,
                grid.axis_coord(1),
            );
            let backend = RustFftBackend::new();
            let (out, trace) = plan.forward(&backend, local);
            assert_eq!(trace.stages.len(), 9);
            out
        });
        let got = gather_cube_xy(&outs, nb, shape, p0, p1);
        assert!(
            max_abs_diff(&got, &want) < 1e-8 * (nx * ny * nz) as f64,
            "shape={shape:?} nb={nb} grid=({p0},{p1})"
        );
    }

    #[test]
    fn matches_local_fft_various_grids() {
        check([8, 8, 8], 1, 1, 1);
        check([8, 8, 8], 1, 2, 2);
        check([8, 8, 8], 2, 2, 3);
        check([4, 6, 8], 1, 2, 2);
        check([8, 8, 8], 1, 4, 2);
        check([5, 6, 7], 2, 3, 2); // uneven everything
    }

    #[test]
    fn round_trip_2d_grid() {
        let shape = [8usize, 8, 8];
        let nb = 2;
        let (p0, p1) = (2usize, 2usize);
        let global = phased(nb * 512, 23);
        let errs = run_world(p0 * p1, |comm| {
            let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
            let plan = PencilPlan::new(shape, nb, Arc::clone(&grid));
            let local = scatter_cube_yz(
                &global,
                nb,
                shape,
                p0,
                grid.axis_coord(0),
                p1,
                grid.axis_coord(1),
            );
            let backend = RustFftBackend::new();
            let (spec, _) = plan.forward(&backend, local.clone());
            let (back, _) = plan.inverse(&backend, spec);
            max_abs_diff(&back, &local)
        });
        for e in errs {
            assert!(e < 1e-10);
        }
    }

    #[test]
    fn two_alltoalls_per_forward() {
        let traces = run_world(4, |comm| {
            let grid = ProcGrid::new(&[2, 2], comm).unwrap();
            let plan = PencilPlan::new([4, 4, 4], 1, Arc::clone(&grid));
            let local = vec![crate::fft::complex::ZERO; plan.input_len()];
            let backend = RustFftBackend::new();
            plan.forward(&backend, local).1
        });
        for tr in traces {
            let comms = tr
                .stages
                .iter()
                .filter(|s| s.kind == super::super::stages::StageKind::Comm)
                .count();
            assert_eq!(comms, 2);
        }
    }
}
