//! Pencil-pencil-pencil distributed 3D FFT on a 2D processing grid
//! (paper Fig. 1b): three batches of 1D FFTs with two alltoall exchanges in
//! the row/column sub-communicators.
//!
//! Input layout: `"b x y{0} z{1}"` — x dense, y cyclic over grid axis 0,
//! z cyclic over grid axis 1. Local `[nb, nx, lyc0, lzc1]`.
//!
//! Forward stages:
//! 1. `fft_x`    — x lines are complete locally,
//! 2. `a2a_xy`   — row-comm alltoall trading the x split for a y split,
//!    `fft_y`,
//! 3. `a2a_yz`   — column-comm alltoall trading the y split for a z split,
//!    `fft_z`.
//!
//! Output layout: `"b x{0} y{1} z"` — local `[nb, lxc0, lyc1, nz]`.
//!
//! 3D processing grids are supported by axis folding: a `(p0, p1, p2)` grid
//! runs the pencil plan on the folded `(p0*p1, p2)` grid (see
//! `Fftb::plan` in `plan/mod.rs`), which preserves the paper's API surface
//! (Table 1: processing grid 1D/2D/3D) with the same communication volume.
//!
//! All four exchanges (two per direction) have plan-time [`A2aSchedule`]s
//! and run **fused**: each destination's residue block is packed by a
//! [`SplitMergeKernel`] straight into its recycled wire buffer as its
//! round posts, and each received block merges into the next stage tensor
//! as its wait completes — no monolithic pack/unpack stages around the
//! exchange, zero steady-state allocation (buffers ping-pong through the
//! plan's [`Workspace`] slot pool).

use std::cell::Cell;
use std::sync::{Arc, Mutex};

use crate::comm::alltoall::CommTuning;
use crate::comm::communicator::Comm;
use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::{backend_fft_dim_ws, LocalFftBackend};
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::{cyclic, ProcGrid};

use super::redistribute::{volume, A2aSchedule, Shape4, SplitMergeKernel};
use super::stages::{ExecTrace, StageTimer};
use super::workspace::{ensure, SlotPool, Workspace};

/// Batched pencil-decomposition 3D FFT plan on a 2D grid.
pub struct PencilPlan {
    /// Global extent of the x dimension.
    pub nx: usize,
    /// Global extent of the y dimension.
    pub ny: usize,
    /// Global extent of the z dimension.
    pub nz: usize,
    /// Batch count (transforms per execution).
    pub nb: usize,
    grid: Arc<ProcGrid>,
    /// `[nb, nx, lyc0, lzc1]` — input.
    sh1: Shape4,
    /// `[nb, lxc0, ny, lzc1]` — after the row exchange.
    sh2: Shape4,
    /// `[nb, lxc0, lyc1, nz]` — output.
    sh3: Shape4,
    /// Row exchange (axis 0): split x of sh1, merge y of sh2.
    fwd_xy: A2aSchedule,
    /// Column exchange (axis 1): split y of sh2, merge z of sh3.
    fwd_yz: A2aSchedule,
    /// Inverse column exchange: split z of sh3, merge y of sh2.
    inv_zy: A2aSchedule,
    /// Inverse row exchange: split y of sh2, merge x of sh1.
    inv_yx: A2aSchedule,
    /// Overlap knobs of the windowed exchanges.
    tuning: CommTuning,
    ws: Mutex<Workspace>,
}

impl PencilPlan {
    /// Plan a batched pencil transform of `shape` with batch `nb` on the
    /// 2D `grid`.
    pub fn new(shape: [usize; 3], nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        assert_eq!(grid.ndim(), 2, "pencil plan requires a 2D processing grid");
        let (p0, p1) = (grid.axis_len(0), grid.axis_len(1));
        if p0 > shape[0] || p0 > shape[1] || p1 > shape[1] || p1 > shape[2] {
            return Err(FftbError::Unsupported(format!(
                "pencil plan needs p0 <= min(nx, ny) and p1 <= min(ny, nz) \
                 (p0={p0}, p1={p1}, shape={shape:?})"
            )));
        }
        let [nx, ny, nz] = shape;
        let (r0, r1) = (grid.axis_coord(0), grid.axis_coord(1));
        let lxc = cyclic::local_count(nx, p0, r0);
        let lyc0 = cyclic::local_count(ny, p0, r0);
        let lyc1 = cyclic::local_count(ny, p1, r1);
        let lzc1 = cyclic::local_count(nz, p1, r1);
        let sh1 = [nb, nx, lyc0, lzc1];
        let sh2 = [nb, lxc, ny, lzc1];
        let sh3 = [nb, lxc, lyc1, nz];
        let fwd_xy = A2aSchedule::for_split_merge(sh1, 1, sh2, 2, p0, r0);
        let fwd_yz = A2aSchedule::for_split_merge(sh2, 2, sh3, 3, p1, r1);
        let inv_zy = A2aSchedule::for_split_merge(sh3, 3, sh2, 2, p1, r1);
        let inv_yx = A2aSchedule::for_split_merge(sh2, 2, sh1, 1, p0, r0);
        Ok(PencilPlan {
            nx,
            ny,
            nz,
            nb,
            grid,
            sh1,
            sh2,
            sh3,
            fwd_xy,
            fwd_yz,
            inv_zy,
            inv_yx,
            tuning: CommTuning::default(),
            ws: Mutex::new(Workspace::new()),
        })
    }

    /// Override the exchange overlap knobs (window size) for this plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.tuning = tuning;
    }

    /// Return a finished output buffer to the plan's slot pool so repeated
    /// executions reuse its storage.
    pub fn recycle(&self, buf: Vec<Complex>) {
        self.ws.lock().unwrap().slots.recycle(buf);
    }

    /// Check out a buffer from this plan's slot pool, reporting the bytes
    /// of fresh allocation the take caused (zero once the pool is warm).
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        let ctr = Cell::new(0u64);
        let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
        (buf, ctr.get())
    }

    /// `(p0, p1)` extents of the 2D processing grid this plan runs on.
    pub fn grid_dims(&self) -> (usize, usize) {
        (self.grid.axis_len(0), self.grid.axis_len(1))
    }

    /// Local input length `[nb, nx, lyc0, lzc1]`.
    pub fn input_len(&self) -> usize {
        volume(self.sh1)
    }

    /// Local output length `[nb, lxc0, lyc1, nz]`.
    pub fn output_len(&self) -> usize {
        volume(self.sh3)
    }

    /// Forward transform: consumes the yz-distributed input, returns the
    /// xy-distributed spectrum and the per-rank execution trace.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, Direction::Forward)
    }

    /// Inverse transform: consumes the xy-distributed spectrum, returns
    /// the yz-distributed data.
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, Direction::Inverse)
    }

    /// One fused scheduled exchange: take the destination tensor from the
    /// slot pool, drive the [`SplitMergeKernel`] (split `dim_src` of
    /// `data`, merge `dim_dst` of the new tensor) through the fused
    /// windowed engine, swap the new tensor in and recycle the old one.
    /// Records wire traffic and overlap counters.
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        t: &mut StageTimer,
        name: &'static str,
        comm: &Comm,
        sched: &A2aSchedule,
        data: &mut Vec<Complex>,
        sh_src: Shape4,
        dim_src: usize,
        sh_dst: Shape4,
        dim_dst: usize,
        slots: &mut SlotPool,
        alloc: &Cell<u64>,
        tuning: CommTuning,
    ) {
        t.comm_a2a(name, || {
            let mut out = slots.take(volume(sh_dst), alloc);
            let c =
                SplitMergeKernel::new(sched, &data[..], sh_src, dim_src, &mut out, sh_dst, dim_dst)
                    .exchange(comm, tuning);
            slots.recycle(std::mem::replace(data, out));
            ((), sched.bytes_remote(), sched.msgs(), c)
        });
    }

    /// Owned-storage adapter over [`PencilPlan::run_into`]: checks a
    /// destination slot out of the plan pool, runs the borrowed-slice path,
    /// and recycles the consumed caller vector.
    fn run(
        &self,
        backend: &dyn LocalFftBackend,
        data: Vec<Complex>,
        dir: Direction,
    ) -> (Vec<Complex>, ExecTrace) {
        let out_len = match dir {
            Direction::Forward => self.output_len(),
            Direction::Inverse => self.input_len(),
        };
        let (mut out, grew) = self.take_pooled(out_len);
        let mut trace = self.run_into(backend, &data, &mut out, dir);
        trace.alloc_bytes += grew;
        self.recycle(data);
        (out, trace)
    }

    /// Execute into a caller-owned output slice. The borrowed input is
    /// staged once into workspace scratch; the middle stages ping-pong
    /// through the slot pool as before and the *final* fused exchange
    /// merges its received blocks directly into `out`, so the caller's
    /// storage is written exactly once. `out` must hold exactly
    /// `output_len()` (forward) / `input_len()` (inverse) elements.
    pub fn run_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
        dir: Direction,
    ) -> ExecTrace {
        let row = self.grid.axis_comm(0);
        let col = self.grid.axis_comm(1);
        let (sh1, sh2, sh3) = (self.sh1, self.sh2, self.sh3);
        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        let Workspace { fft, stage, slots, alloc, .. } = ws;
        let alloc = &*alloc;
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);
        let lines = |total: usize, n: usize| backend.flops(total, n);

        // steady-state: pencil execute
        // Buffers come from the workspace slot pool / wire arena only;
        // pallas-lint rejects allocating calls here and `trace.alloc_bytes`
        // audits the contract at run time.
        match dir {
            Direction::Forward => {
                assert_eq!(input.len(), self.input_len(), "forward: wrong input length");
                assert_eq!(out.len(), self.output_len(), "forward: wrong output length");
                // 1. Stage the borrowed input, FFT x (dense locally).
                t.compute("fft_x", lines(input.len(), self.nx), || {
                    ensure(stage, input.len(), alloc);
                    stage.copy_from_slice(input);
                    backend_fft_dim_ws(backend, stage, &sh1, 1, dir, &mut *fft, alloc);
                });
                // 2. Fused row alltoall: split x, merge y.
                Self::exchange(
                    &mut t, "a2a_xy", row, &self.fwd_xy, stage, sh1, 1, sh2, 2, slots, alloc,
                    self.tuning,
                );
                t.compute("fft_y", lines(stage.len(), self.ny), || {
                    backend_fft_dim_ws(backend, stage, &sh2, 2, dir, &mut *fft, alloc);
                });
                // 3. Fused column alltoall into the caller's output: split
                //    y, merge z.
                t.comm_a2a("a2a_yz", || {
                    let dst = &mut out[..];
                    let c = SplitMergeKernel::new(&self.fwd_yz, stage, sh2, 2, dst, sh3, 3)
                        .exchange(col, self.tuning);
                    ((), self.fwd_yz.bytes_remote(), self.fwd_yz.msgs(), c)
                });
                t.compute("fft_z", lines(out.len(), self.nz), || {
                    backend_fft_dim_ws(backend, out, &sh3, 3, dir, &mut *fft, alloc);
                });
            }
            Direction::Inverse => {
                assert_eq!(input.len(), self.output_len(), "inverse: wrong input length");
                assert_eq!(out.len(), self.input_len(), "inverse: wrong output length");
                t.compute("ifft_z", lines(input.len(), self.nz), || {
                    ensure(stage, input.len(), alloc);
                    stage.copy_from_slice(input);
                    backend_fft_dim_ws(backend, stage, &sh3, 3, dir, &mut *fft, alloc);
                });
                Self::exchange(
                    &mut t, "a2a_zy", col, &self.inv_zy, stage, sh3, 3, sh2, 2, slots, alloc,
                    self.tuning,
                );
                t.compute("ifft_y", lines(stage.len(), self.ny), || {
                    backend_fft_dim_ws(backend, stage, &sh2, 2, dir, &mut *fft, alloc);
                });
                t.comm_a2a("a2a_yx", || {
                    let dst = &mut out[..];
                    let c = SplitMergeKernel::new(&self.inv_yx, stage, sh2, 2, dst, sh1, 1)
                        .exchange(row, self.tuning);
                    ((), self.inv_yx.bytes_remote(), self.inv_yx.msgs(), c)
                });
                t.compute("ifft_x", lines(out.len(), self.nx), || {
                    backend_fft_dim_ws(backend, out, &sh1, 1, dir, &mut *fft, alloc);
                });
            }
        }
        // steady-state: end
        trace.alloc_bytes = alloc.get();
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::max_abs_diff;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{gather_cube_xy, phased, scatter_cube_yz};

    fn check(shape: [usize; 3], nb: usize, p0: usize, p1: usize) {
        let [nx, ny, nz] = shape;
        let global = phased(nb * nx * ny * nz, 17);
        let mut want = global.clone();
        let sh = [nb, nx, ny, nz];
        for dim in 1..4 {
            crate::fft::nd::fft_dim(&mut want, &sh, dim, Direction::Forward);
        }
        let outs = run_world(p0 * p1, |comm| {
            let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
            let plan = PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_yz(
                &global,
                nb,
                shape,
                p0,
                grid.axis_coord(0),
                p1,
                grid.axis_coord(1),
            );
            let backend = RustFftBackend::new();
            let (out, trace) = plan.forward(&backend, local);
            // fft_x, fused a2a_xy, fft_y, fused a2a_yz, fft_z.
            assert_eq!(trace.stages.len(), 5);
            out
        });
        let got = gather_cube_xy(&outs, nb, shape, p0, p1);
        assert!(
            max_abs_diff(&got, &want) < 1e-8 * (nx * ny * nz) as f64,
            "shape={shape:?} nb={nb} grid=({p0},{p1})"
        );
    }

    #[test]
    fn matches_local_fft_various_grids() {
        check([8, 8, 8], 1, 1, 1);
        check([8, 8, 8], 1, 2, 2);
        check([8, 8, 8], 2, 2, 3);
        check([4, 6, 8], 1, 2, 2);
        check([8, 8, 8], 1, 4, 2);
        check([5, 6, 7], 2, 3, 2); // uneven everything
    }

    #[test]
    fn round_trip_2d_grid() {
        let shape = [8usize, 8, 8];
        let nb = 2;
        let (p0, p1) = (2usize, 2usize);
        let global = phased(nb * 512, 23);
        let errs = run_world(p0 * p1, |comm| {
            let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
            let plan = PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_yz(
                &global,
                nb,
                shape,
                p0,
                grid.axis_coord(0),
                p1,
                grid.axis_coord(1),
            );
            let backend = RustFftBackend::new();
            let (spec, _) = plan.forward(&backend, local.clone());
            let (back, _) = plan.inverse(&backend, spec);
            max_abs_diff(&back, &local)
        });
        for e in errs {
            assert!(e < 1e-10);
        }
    }

    #[test]
    fn two_alltoalls_per_forward() {
        let traces = run_world(4, |comm| {
            let grid = ProcGrid::new(&[2, 2], comm).unwrap();
            let plan = PencilPlan::new([4, 4, 4], 1, Arc::clone(&grid)).unwrap();
            let local = vec![crate::fft::complex::ZERO; plan.input_len()];
            let backend = RustFftBackend::new();
            plan.forward(&backend, local).1
        });
        for tr in traces {
            let comms = tr
                .stages
                .iter()
                .filter(|s| s.kind == super::super::stages::StageKind::Comm)
                .count();
            assert_eq!(comms, 2);
        }
    }

    #[test]
    fn oversubscribed_grid_rejected() {
        run_world(8, |comm| {
            let grid = ProcGrid::new(&[4, 2], comm).unwrap();
            // p0 = 4 > ny = 3.
            let e = PencilPlan::new([8, 3, 8], 1, grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)));
        });
    }
}
