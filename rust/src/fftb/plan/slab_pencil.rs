//! Slab-pencil distributed 3D FFT on a 1D processing grid (paper Fig. 1a,
//! Fig. 5/6): input distributed in `x`, output distributed in `z`.
//!
//! Forward stages (batched over `nb` transforms, batch fastest in memory):
//!
//! 1. `fft_yz`   — local FFTs along `y` and `z` (each rank owns full y/z for
//!                 its cyclic x-pencils),
//! 2. `a2a_xz`   — one alltoall exchanging the `x` split for a `z` split
//!                 (blocks carry all `nb` bands at once — the batched
//!                 aggregation of §4.2),
//! 3. `fft_x`    — local FFT along the now-dense `x`.
//!
//! The inverse runs the mirror image. Local tensors are 4D
//! `[nb, local_x, ny, nz]` / `[nb, nx, ny, local_z]`, column-major.
//!
//! Communication schedules (block extents) are computed once at plan time;
//! every execution drives the exchange through a fused
//! [`SplitMergeKernel`]: destination block `s`'s z-residues are packed
//! straight into a recycled wire buffer when round `s` posts (no
//! monolithic pre-pack gating the first send), and each received block
//! merges into the output tensor as its wait completes. With all scratch
//! routed through the plan's [`Workspace`] slot pool, steady-state
//! executions perform zero heap allocation (`ExecTrace::alloc_bytes`
//! reports any workspace growth). The exchange runs the fused windowed
//! overlapped pipeline (`CommTuning`, default window 2; `set_tuning` to
//! change), reporting wait and overlapped pack/unpack time through
//! `ExecTrace::wait_ns` / `pack_overlap_ns` / `unpack_overlap_ns`.

use std::sync::Arc;
use std::sync::Mutex;

use crate::comm::alltoall::CommTuning;
use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::{backend_fft_dim_ws, LocalFftBackend};
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::{cyclic, ProcGrid};

use super::redistribute::{volume, A2aSchedule, Shape4, SplitMergeKernel};
use super::stages::{ExecTrace, StageTimer};
use super::workspace::{ensure, Workspace};

/// Plan for a batched slab-pencil 3D FFT of global shape `(nx, ny, nz)` on a
/// 1D grid.
pub struct SlabPencilPlan {
    /// Global extent of the x dimension.
    pub nx: usize,
    /// Global extent of the y dimension.
    pub ny: usize,
    /// Global extent of the z dimension.
    pub nz: usize,
    /// Batch count (transforms per execution).
    pub nb: usize,
    grid: Arc<ProcGrid>,
    /// Local input shape `[nb, lxc, ny, nz]`.
    sh_in: Shape4,
    /// Local output shape `[nb, nx, ny, lzc]`.
    sh_out: Shape4,
    /// Forward exchange: split z of `sh_in`, merge x of `sh_out`.
    fwd: A2aSchedule,
    /// Inverse exchange: split x of `sh_out`, merge z of `sh_in`.
    inv: A2aSchedule,
    /// Overlap knobs of the windowed exchange.
    tuning: CommTuning,
    ws: Mutex<Workspace>,
}

impl SlabPencilPlan {
    /// Plan a batched slab-pencil transform of `shape` with batch `nb` on
    /// the 1D `grid`.
    pub fn new(shape: [usize; 3], nb: usize, grid: Arc<ProcGrid>) -> Result<Self> {
        assert_eq!(grid.ndim(), 1, "slab-pencil requires a 1D processing grid");
        let p = grid.size();
        if p > shape[0] || p > shape[2] {
            return Err(FftbError::Unsupported(format!(
                "slab-pencil needs p <= nx and p <= nz (p={p}, shape={shape:?}); \
                 parallelize the batch dimension beyond that (see BatchedLoop)"
            )));
        }
        let r = grid.rank();
        let [nx, ny, nz] = shape;
        let lxc = cyclic::local_count(nx, p, r);
        let lzc = cyclic::local_count(nz, p, r);
        let sh_in = [nb, lxc, ny, nz];
        let sh_out = [nb, nx, ny, lzc];
        let fwd = A2aSchedule::for_split_merge(sh_in, 3, sh_out, 1, p, r);
        let inv = A2aSchedule::for_split_merge(sh_out, 1, sh_in, 3, p, r);
        Ok(SlabPencilPlan {
            nx,
            ny,
            nz,
            nb,
            grid,
            sh_in,
            sh_out,
            fwd,
            inv,
            tuning: CommTuning::default(),
            ws: Mutex::new(Workspace::new()),
        })
    }

    /// Override the exchange overlap knobs (window size) for this plan.
    pub fn set_tuning(&mut self, tuning: CommTuning) {
        self.tuning = tuning;
    }

    /// Return a finished output buffer to the plan's slot pool so repeated
    /// executions reuse its storage (keeps forward-only call patterns
    /// allocation-free).
    pub fn recycle(&self, buf: Vec<Complex>) {
        self.ws.lock().unwrap().slots.recycle(buf);
    }

    /// Check out a buffer from this plan's slot pool. Crate-internal: the
    /// padded-sphere wrapper stages its full cube here so that cube-sized
    /// storage circulates through *one* pool (the consumed cube and
    /// caller-recycled outputs land in this plan's pool too). Returns the
    /// buffer and the bytes of fresh allocation the take caused.
    pub(crate) fn take_pooled(&self, len: usize) -> (Vec<Complex>, u64) {
        let ctr = std::cell::Cell::new(0u64);
        let buf = self.ws.lock().unwrap().slots.take(len, &ctr);
        (buf, ctr.get())
    }

    /// Rank count of the 1D processing grid this plan runs on.
    pub fn grid_size(&self) -> usize {
        self.grid.size()
    }

    /// Local input length: `[nb, lxc, ny, nz]`.
    pub fn input_len(&self) -> usize {
        volume(self.sh_in)
    }

    /// Local output length: `[nb, nx, ny, lzc]`.
    pub fn output_len(&self) -> usize {
        volume(self.sh_out)
    }

    /// Forward transform: consumes the x-distributed input, returns the
    /// z-distributed spectrum and the per-rank execution trace.
    pub fn forward(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, Direction::Forward)
    }

    /// Inverse transform: consumes the z-distributed spectrum, returns the
    /// x-distributed data.
    pub fn inverse(
        &self,
        backend: &dyn LocalFftBackend,
        input: Vec<Complex>,
    ) -> (Vec<Complex>, ExecTrace) {
        self.run(backend, input, Direction::Inverse)
    }

    /// Owned-storage adapter over [`SlabPencilPlan::run_into`]: checks a
    /// destination slot out of the plan pool, runs the borrowed-slice path,
    /// and recycles the consumed caller vector so buffers keep circulating.
    fn run(
        &self,
        backend: &dyn LocalFftBackend,
        data: Vec<Complex>,
        dir: Direction,
    ) -> (Vec<Complex>, ExecTrace) {
        let out_len = match dir {
            Direction::Forward => self.output_len(),
            Direction::Inverse => self.input_len(),
        };
        let (mut out, grew) = self.take_pooled(out_len);
        let mut trace = self.run_into(backend, &data, &mut out, dir);
        trace.alloc_bytes += grew;
        self.recycle(data);
        (out, trace)
    }

    /// Execute into a caller-owned output slice: `input` is read-only
    /// (staged once into workspace scratch for the in-place local FFTs) and
    /// the fused exchange merges its received blocks directly into `out` —
    /// the copy-free surface the SCF Hamiltonian apply runs on. `out` must
    /// hold exactly `output_len()` (forward) / `input_len()` (inverse)
    /// elements.
    pub fn run_into(
        &self,
        backend: &dyn LocalFftBackend,
        input: &[Complex],
        out: &mut [Complex],
        dir: Direction,
    ) -> ExecTrace {
        let comm = self.grid.axis_comm(0);
        let mut guard = self.ws.lock().unwrap();
        let ws = &mut *guard;
        ws.begin();
        let Workspace { fft, stage, alloc, .. } = ws;
        let alloc = &*alloc;
        let (sh_in, sh_out) = (self.sh_in, self.sh_out);
        let mut trace = ExecTrace::default();
        let mut t = StageTimer::new(&mut trace);
        let lines = |total: usize, n: usize| backend.flops(total, n);

        // steady-state: slab-pencil execute
        // Every buffer below comes from the plan workspace or the wire
        // arena; pallas-lint rejects allocating calls in this region and
        // the `alloc` counter audits anything that slips through at run
        // time (`trace.alloc_bytes` must stay 0 after warm-up).
        match dir {
            Direction::Forward => {
                assert_eq!(input.len(), self.input_len(), "forward: wrong input length");
                assert_eq!(out.len(), self.output_len(), "forward: wrong output length");
                // 1. Stage the borrowed input, local FFT along y and z.
                t.compute(
                    "fft_yz",
                    lines(input.len(), self.ny) + lines(input.len(), self.nz),
                    || {
                        ensure(stage, input.len(), alloc);
                        stage.copy_from_slice(input);
                        backend_fft_dim_ws(backend, stage, &sh_in, 2, dir, &mut *fft, alloc);
                        backend_fft_dim_ws(backend, stage, &sh_in, 3, dir, &mut *fft, alloc);
                    },
                );
                // 2. Fused alltoall: trade x split for z split. Each
                //    destination's z-residue block is packed into its wire
                //    buffer as its round posts; the block from rank q
                //    ([nb, lxc_q, ny, lzc_me]) merges along dim 1 straight
                //    into the caller's output slice as its wait completes.
                t.comm_a2a("a2a_xz", || {
                    let dst = &mut out[..];
                    let c = SplitMergeKernel::new(&self.fwd, stage, sh_in, 3, dst, sh_out, 1)
                        .exchange(comm, self.tuning);
                    ((), self.fwd.bytes_remote(), self.fwd.msgs(), c)
                });
                // 3. Local FFT along dense x.
                t.compute("fft_x", lines(out.len(), self.nx), || {
                    backend_fft_dim_ws(backend, out, &sh_out, 1, dir, &mut *fft, alloc);
                });
            }
            Direction::Inverse => {
                assert_eq!(input.len(), self.output_len(), "inverse: wrong input length");
                assert_eq!(out.len(), self.input_len(), "inverse: wrong output length");
                t.compute("ifft_x", lines(input.len(), self.nx), || {
                    ensure(stage, input.len(), alloc);
                    stage.copy_from_slice(input);
                    backend_fft_dim_ws(backend, stage, &sh_out, 1, dir, &mut *fft, alloc);
                });
                t.comm_a2a("a2a_zx", || {
                    let dst = &mut out[..];
                    let c = SplitMergeKernel::new(&self.inv, stage, sh_out, 1, dst, sh_in, 3)
                        .exchange(comm, self.tuning);
                    ((), self.inv.bytes_remote(), self.inv.msgs(), c)
                });
                t.compute(
                    "ifft_yz",
                    lines(out.len(), self.ny) + lines(out.len(), self.nz),
                    || {
                        backend_fft_dim_ws(backend, out, &sh_in, 2, dir, &mut *fft, alloc);
                        backend_fft_dim_ws(backend, out, &sh_in, 3, dir, &mut *fft, alloc);
                    },
                );
            }
        }
        // steady-state: end
        trace.alloc_bytes = alloc.get();
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fft::complex::{max_abs_diff, ZERO};
    use crate::fft::nd::fft_nd;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{gather_cube_z, phased, scatter_cube_x};

    /// Distributed forward FFT must equal the local 4D reference
    /// (FFT over dims 1..3 of [nb, nx, ny, nz]).
    fn check(shape: [usize; 3], nb: usize, p: usize) {
        let [nx, ny, nz] = shape;
        let global: Vec<Complex> = phased(nb * nx * ny * nz, 42);
        // Local oracle.
        let mut want = global.clone();
        let sh = [nb, nx, ny, nz];
        for dim in 1..4 {
            crate::fft::nd::fft_dim(&mut want, &sh, dim, Direction::Forward);
        }

        let got_slabs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_x(&global, nb, shape, p, grid.rank());
            let backend = RustFftBackend::new();
            let (out, trace) = plan.forward(&backend, local);
            // fft_yz, fused a2a_xz (pack + exchange + unpack), fft_x.
            assert_eq!(trace.stages.len(), 3);
            out
        });
        let got = gather_cube_z(&got_slabs, nb, shape, p);
        assert!(
            max_abs_diff(&got, &want) < 1e-8 * (nx * ny * nz) as f64,
            "shape={shape:?} nb={nb} p={p}"
        );
    }

    #[test]
    fn matches_local_fft_various() {
        check([8, 8, 8], 1, 1);
        check([8, 8, 8], 1, 2);
        check([8, 8, 8], 1, 4);
        check([8, 4, 8], 2, 2);
        check([16, 8, 8], 3, 4);
        check([6, 5, 6], 2, 3); // non-pow2, uneven cyclic
    }

    #[test]
    fn forward_inverse_round_trip() {
        let shape = [8usize, 8, 8];
        let nb = 2;
        let p = 4;
        let global = phased(nb * 512, 7);
        let outs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_x(&global, nb, shape, p, grid.rank());
            let backend = RustFftBackend::new();
            let (spec, _) = plan.forward(&backend, local.clone());
            let (back, _) = plan.inverse(&backend, spec);
            max_abs_diff(&back, &local)
        });
        for e in outs {
            assert!(e < 1e-10, "round-trip error {e}");
        }
    }

    #[test]
    fn trace_accounts_comm_volume() {
        // p=2, each rank sends half its data (minus the self block).
        let shape = [4usize, 4, 4];
        let nb = 2;
        let p = 2;
        let traces = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = vec![ZERO; plan.input_len()];
            let backend = RustFftBackend::new();
            let (_, trace) = plan.forward(&backend, local);
            trace
        });
        for tr in traces {
            // Local data = nb*2*4*4 = 64 elems; one of two z-residue blocks
            // goes remote: 32 elems = 512 bytes.
            assert_eq!(tr.comm_bytes(), 512);
            assert_eq!(tr.comm_messages(), 1);
        }
    }

    #[test]
    fn too_many_ranks_rejected() {
        let outs = run_world(4, |comm| {
            let grid = ProcGrid::new(&[4], comm).unwrap();
            SlabPencilPlan::new([2, 8, 8], 1, grid).is_err()
        });
        assert!(outs.iter().all(|&rejected| rejected));
    }

    #[test]
    fn rejection_is_unsupported_error() {
        run_world(4, |comm| {
            let grid = ProcGrid::new(&[4], comm).unwrap();
            let e = SlabPencilPlan::new([8, 8, 2], 1, grid).err().unwrap();
            assert!(matches!(e, FftbError::Unsupported(_)));
        });
    }

    #[test]
    fn single_rank_equals_local_fft3() {
        let shape = [8usize, 4, 2];
        let x = phased(64, 3);
        let outs = run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, 1, Arc::clone(&grid)).unwrap();
            let backend = RustFftBackend::new();
            plan.forward(&backend, x.clone()).0
        });
        let mut want = x;
        fft_nd(&mut want, &shape, Direction::Forward);
        assert!(max_abs_diff(&outs[0], &want) < 1e-10);
    }
}
