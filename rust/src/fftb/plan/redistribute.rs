//! Pack/unpack helpers for the alltoall stages.
//!
//! Every distributed FFT stage exchanges one tensor dimension for another:
//! the sending side *splits* a dense dimension by elemental-cyclic residue
//! (one block per destination rank), the receiving side *merges* blocks back
//! into a dense dimension. These are the CPU equivalents of the paper's
//! "small codelets that pack and rotate the data locally on the GPU before
//! communicating it over the network" (§4.1).
//!
//! Tensors are 4D `[nb, d1, d2, d3]`, column-major, batch fastest:
//! `flat = b + nb*(i1 + d1*(i2 + d2*i3))`. Copies move whole `nb`-runs, so
//! batching directly increases the contiguity of every pack/unpack — the
//! mechanical reason batched transforms win in Fig. 9.

use crate::fft::complex::{Complex, ZERO};
use crate::fftb::grid::cyclic;

/// Shape of a 4D local tensor.
pub type Shape4 = [usize; 4];

#[inline]
pub fn volume(sh: Shape4) -> usize {
    sh[0] * sh[1] * sh[2] * sh[3]
}

/// Split dimension `dim` (1, 2 or 3) by cyclic residue into `p` blocks.
/// Block `s` keeps the indices `i ≡ s (mod p)` of `dim`, order preserved.
pub fn split_dim(data: &[Complex], sh: Shape4, dim: usize, p: usize) -> Vec<Vec<Complex>> {
    assert!((1..=3).contains(&dim), "cannot split the batch dimension");
    assert_eq!(data.len(), volume(sh));
    let [nb, d1, d2, d3] = sh;
    let mut blocks: Vec<Vec<Complex>> = (0..p)
        .map(|s| {
            let mut bsh = sh;
            bsh[dim] = cyclic::local_count(sh[dim], p, s);
            Vec::with_capacity(volume(bsh))
        })
        .collect();
    // Perf (EXPERIMENTS.md §Perf, L3 iteration 3): dim 3 splits whole
    // contiguous (nb*d1*d2)-element planes — memcpy per plane instead of a
    // per-element loop. This is the pack stage of every slab alltoall.
    if dim == 3 {
        let plane = nb * d1 * d2;
        for i3 in 0..d3 {
            blocks[i3 % p].extend_from_slice(&data[i3 * plane..(i3 + 1) * plane]);
        }
        return blocks;
    }
    // Iterate in destination-write order per block: (i3, i2, i1) outer to
    // inner, nb contiguous. Pushing in this order yields each block already
    // in canonical column-major order.
    for i3 in 0..d3 {
        for i2 in 0..d2 {
            for i1 in 0..d1 {
                let s = match dim {
                    1 => i1 % p,
                    2 => i2 % p,
                    _ => i3 % p,
                };
                let src = nb * (i1 + d1 * (i2 + d2 * i3));
                blocks[s].extend_from_slice(&data[src..src + nb]);
            }
        }
    }
    blocks
}

/// Merge `p` blocks into dense dimension `dim` of shape `sh_out`.
/// Block `r` supplies the indices `i = j*p + r`. Inverse of [`split_dim`].
pub fn merge_dim(blocks: &[Vec<Complex>], sh_out: Shape4, dim: usize, p: usize) -> Vec<Complex> {
    assert!((1..=3).contains(&dim));
    assert_eq!(blocks.len(), p);
    let [nb, d1, d2, _d3] = sh_out;
    let mut out = vec![ZERO; volume(sh_out)];
    // Perf (§Perf, L3 iteration 3): dim-3 merges interleave whole
    // contiguous planes — memcpy per plane (the unpack stage of the
    // inverse slab alltoall).
    if dim == 3 {
        let plane = nb * d1 * d2;
        for (r, block) in blocks.iter().enumerate() {
            let b3 = cyclic::local_count(sh_out[3], p, r);
            assert_eq!(block.len(), plane * b3, "merge_dim: block {r} has wrong size");
            for (j3, src) in block.chunks_exact(plane).enumerate() {
                let i3 = j3 * p + r;
                out[i3 * plane..(i3 + 1) * plane].copy_from_slice(src);
            }
            let _ = b3;
        }
        return out;
    }
    // Walk each block in its canonical order and scatter.
    for (r, block) in blocks.iter().enumerate() {
        let mut bsh = sh_out;
        bsh[dim] = cyclic::local_count(sh_out[dim], p, r);
        assert_eq!(
            block.len(),
            volume(bsh),
            "merge_dim: block {r} has wrong size (expected shape {bsh:?})"
        );
        let [_, b1, b2, b3] = bsh;
        let mut src = 0;
        for j3 in 0..b3 {
            let i3 = if dim == 3 { j3 * p + r } else { j3 };
            for j2 in 0..b2 {
                let i2 = if dim == 2 { j2 * p + r } else { j2 };
                for j1 in 0..b1 {
                    let i1 = if dim == 1 { j1 * p + r } else { j1 };
                    let dst = nb * (i1 + d1 * (i2 + d2 * i3));
                    out[dst..dst + nb].copy_from_slice(&block[src..src + nb]);
                    src += nb;
                }
            }
        }
    }
    out
}

/// Extract one batch entry `b` from a batch-fastest tensor (used by the
/// non-batched variants that loop over single transforms).
pub fn extract_band(data: &[Complex], nb: usize, b: usize) -> Vec<Complex> {
    assert!(b < nb);
    data.iter().skip(b).step_by(nb).copied().collect()
}

/// Write one batch entry back.
pub fn insert_band(data: &mut [Complex], nb: usize, b: usize, band: &[Complex]) {
    assert_eq!(data.len(), nb * band.len());
    for (i, v) in band.iter().enumerate() {
        data[b + nb * i] = *v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize) -> Vec<Complex> {
        (0..n).map(|i| Complex::new(i as f64, -(i as f64) * 0.5)).collect()
    }

    #[test]
    fn split_merge_round_trip_every_dim() {
        let sh: Shape4 = [2, 5, 4, 6];
        let data = seq(volume(sh));
        for dim in 1..=3 {
            for p in [1usize, 2, 3, 4] {
                let blocks = split_dim(&data, sh, dim, p);
                assert_eq!(blocks.len(), p);
                let total: usize = blocks.iter().map(|b| b.len()).sum();
                assert_eq!(total, data.len());
                let back = merge_dim(&blocks, sh, dim, p);
                assert_eq!(back, data, "dim={dim} p={p}");
            }
        }
    }

    #[test]
    fn split_block_sizes_are_cyclic_counts() {
        let sh: Shape4 = [1, 7, 3, 2];
        let data = seq(volume(sh));
        let blocks = split_dim(&data, sh, 1, 3);
        for (s, b) in blocks.iter().enumerate() {
            assert_eq!(b.len(), cyclic::local_count(7, 3, s) * 3 * 2);
        }
    }

    #[test]
    fn split_dim1_values() {
        // [nb=1, d1=4, d2=1, d3=1], p=2: block 0 = indices 0,2; block 1 = 1,3.
        let data = seq(4);
        let blocks = split_dim(&data, [1, 4, 1, 1], 1, 2);
        assert_eq!(blocks[0], vec![data[0], data[2]]);
        assert_eq!(blocks[1], vec![data[1], data[3]]);
    }

    #[test]
    fn band_extract_insert_round_trip() {
        let nb = 3;
        let data = seq(nb * 5);
        let mut rebuilt = vec![Complex::new(0.0, 0.0); data.len()];
        for b in 0..nb {
            let band = extract_band(&data, nb, b);
            assert_eq!(band.len(), 5);
            insert_band(&mut rebuilt, nb, b, &band);
        }
        assert_eq!(rebuilt, data);
    }
}
