//! Pack/unpack helpers and plan-time schedules for the alltoall stages.
//!
//! Every distributed FFT stage exchanges one tensor dimension for another:
//! the sending side *splits* a dense dimension by elemental-cyclic residue
//! (one block per destination rank), the receiving side *merges* blocks back
//! into a dense dimension. These are the CPU equivalents of the paper's
//! "small codelets that pack and rotate the data locally on the GPU before
//! communicating it over the network" (§4.1).
//!
//! An [`A2aSchedule`] captures, at plan time, everything the exchange needs
//! per execution: per-destination block extents and flat-buffer offsets for
//! both the pack and the unpack side, plus the wire accounting the traces
//! report. At execute time the plans drive [`SplitMergeKernel`] — the
//! shared [`PackKernel`] of every cyclic split/merge exchange — through the
//! fused windowed engine: destination block `s` is packed by
//! [`pack_block_bytes`] straight into its recycled wire buffer when round
//! `s` posts, and each received block is landed by [`unpack_block_bytes`]
//! as its wait completes. The monolithic
//! [`split_dim_into`]/[`merge_dim_from`] pair remains as the pre-packed
//! flat-buffer path (and the bit-identity reference the fused tests
//! compare against).
//!
//! Tensors are 4D `[nb, d1, d2, d3]`, column-major, batch fastest:
//! `flat = b + nb*(i1 + d1*(i2 + d2*i3))`. Copies move whole `nb`-runs, so
//! batching directly increases the contiguity of every pack/unpack — the
//! mechanical reason batched transforms win in Fig. 9.

use crate::comm::alltoall::{
    alltoallv_fused_threaded, A2aCounters, CommTuning, PackHalf, UnpackHalf,
};
use crate::comm::arena::WireBuf;
use crate::comm::communicator::Comm;
use crate::fft::complex::{self, Complex, ZERO};
use crate::fftb::grid::cyclic;

use super::stages::{fused_exchange, PackKernel};

/// Bytes per complex element on the wire.
const ELEM: usize = std::mem::size_of::<Complex>();

/// Shape of a 4D local tensor.
pub type Shape4 = [usize; 4];

/// Element count of a 4D shape.
#[inline]
pub fn volume(sh: Shape4) -> usize {
    sh[0] * sh[1] * sh[2] * sh[3]
}

/// Plan-time schedule of one alltoall exchange: block extents (in complex
/// elements) and prefix-sum offsets for the flat send and receive buffers,
/// plus the rank whose self-block bypasses the wire.
pub struct A2aSchedule {
    /// Communicator size.
    pub p: usize,
    /// This rank (its block bypasses the wire).
    pub me: usize,
    /// Block extent (complex elements) sent to each rank.
    pub send_counts: Vec<usize>,
    /// `send_offs[j]..send_offs[j+1]` is rank j's slice of the send buffer.
    pub send_offs: Vec<usize>,
    /// Block extent (complex elements) received from each rank.
    pub recv_counts: Vec<usize>,
    /// `recv_offs[q]..recv_offs[q+1]` is rank q's slice of the recv buffer.
    pub recv_offs: Vec<usize>,
}

fn prefix_sums(counts: &[usize]) -> Vec<usize> {
    let mut offs = Vec::with_capacity(counts.len() + 1);
    let mut acc = 0usize;
    offs.push(0);
    for &c in counts {
        acc += c;
        offs.push(acc);
    }
    offs
}

impl A2aSchedule {
    /// Build a schedule from per-rank block extents (offsets are their
    /// prefix sums).
    pub fn new(send_counts: Vec<usize>, recv_counts: Vec<usize>, me: usize) -> Self {
        assert_eq!(send_counts.len(), recv_counts.len());
        assert!(me < send_counts.len());
        let send_offs = prefix_sums(&send_counts);
        let recv_offs = prefix_sums(&recv_counts);
        A2aSchedule { p: send_counts.len(), me, send_counts, send_offs, recv_counts, recv_offs }
    }

    /// Schedule for a cyclic split/merge exchange: this rank splits
    /// `sh_send` along `dim_s` into `p` residue blocks, and receives blocks
    /// that merge into `sh_recv` along `dim_r` (block from rank q has
    /// `sh_recv[dim_r]` replaced by q's cyclic count — the same convention
    /// [`merge_dim`] documents).
    pub fn for_split_merge(
        sh_send: Shape4,
        dim_s: usize,
        sh_recv: Shape4,
        dim_r: usize,
        p: usize,
        me: usize,
    ) -> Self {
        let count = |sh: Shape4, dim: usize, r: usize| {
            let mut bsh = sh;
            bsh[dim] = cyclic::local_count(sh[dim], p, r);
            volume(bsh)
        };
        let send_counts = (0..p).map(|s| count(sh_send, dim_s, s)).collect();
        let recv_counts = (0..p).map(|q| count(sh_recv, dim_r, q)).collect();
        Self::new(send_counts, recv_counts, me)
    }

    /// The mirror schedule (send and receive sides swapped) — the inverse
    /// transform of an exchange whose block extents are direction-symmetric.
    pub fn reversed(&self) -> A2aSchedule {
        A2aSchedule::new(self.recv_counts.clone(), self.send_counts.clone(), self.me)
    }

    /// Total flat send-buffer length (complex elements).
    pub fn send_total(&self) -> usize {
        self.send_offs[self.p]
    }

    /// Total flat recv-buffer length (complex elements).
    pub fn recv_total(&self) -> usize {
        self.recv_offs[self.p]
    }

    /// Bytes this rank puts on the wire (self block excluded).
    pub fn bytes_remote(&self) -> u64 {
        self.send_counts
            .iter()
            .enumerate()
            .filter(|(s, _)| *s != self.me)
            .map(|(_, &c)| (c * std::mem::size_of::<Complex>()) as u64)
            .sum()
    }

    /// Point-to-point messages this rank sends.
    pub fn msgs(&self) -> u64 {
        (self.p - 1) as u64
    }
}

/// Split dimension `dim` (1, 2 or 3) by cyclic residue into `p` blocks.
/// Block `s` keeps the indices `i ≡ s (mod p)` of `dim`, order preserved.
pub fn split_dim(data: &[Complex], sh: Shape4, dim: usize, p: usize) -> Vec<Vec<Complex>> {
    assert!((1..=3).contains(&dim), "cannot split the batch dimension");
    assert_eq!(data.len(), volume(sh));
    let [nb, d1, d2, d3] = sh;
    let mut blocks: Vec<Vec<Complex>> = (0..p)
        .map(|s| {
            let mut bsh = sh;
            bsh[dim] = cyclic::local_count(sh[dim], p, s);
            Vec::with_capacity(volume(bsh))
        })
        .collect();
    // Perf (EXPERIMENTS.md §Perf, L3 iteration 3): dim 3 splits whole
    // contiguous (nb*d1*d2)-element planes — memcpy per plane instead of a
    // per-element loop. This is the pack stage of every slab alltoall.
    if dim == 3 {
        let plane = nb * d1 * d2;
        for i3 in 0..d3 {
            blocks[i3 % p].extend_from_slice(&data[i3 * plane..(i3 + 1) * plane]);
        }
        return blocks;
    }
    // Iterate in destination-write order per block: (i3, i2, i1) outer to
    // inner, nb contiguous. Pushing in this order yields each block already
    // in canonical column-major order.
    for i3 in 0..d3 {
        for i2 in 0..d2 {
            for i1 in 0..d1 {
                let s = if dim == 1 { i1 % p } else { i2 % p };
                let src = nb * (i1 + d1 * (i2 + d2 * i3));
                blocks[s].extend_from_slice(&data[src..src + nb]);
            }
        }
    }
    blocks
}

/// [`split_dim`] into a preallocated flat buffer: block `s` is written at
/// `out[offs[s]..offs[s+1]]` in the same canonical order. Destination
/// positions are computed analytically, so the pack performs zero heap
/// allocation.
pub fn split_dim_into(
    data: &[Complex],
    sh: Shape4,
    dim: usize,
    p: usize,
    out: &mut [Complex],
    offs: &[usize],
) {
    assert!((1..=3).contains(&dim), "cannot split the batch dimension");
    assert_eq!(data.len(), volume(sh));
    assert_eq!(offs.len(), p + 1);
    assert_eq!(out.len(), offs[p], "split_dim_into: flat buffer length");
    let [nb, d1, d2, d3] = sh;
    if dim == 3 {
        let plane = nb * d1 * d2;
        for i3 in 0..d3 {
            let (s, j3) = (i3 % p, i3 / p);
            let dst = offs[s] + j3 * plane;
            out[dst..dst + plane].copy_from_slice(&data[i3 * plane..(i3 + 1) * plane]);
        }
        return;
    }
    // Per-destination extent of the split dim without a div per element:
    // local_count(d, p, s) = d/p + (s < d%p).
    let (base, rem) = (sh[dim] / p, sh[dim] % p);
    let lc = |s: usize| base + usize::from(s < rem);
    if dim == 1 {
        for i3 in 0..d3 {
            for i2 in 0..d2 {
                let plane = d2 * i3 + i2;
                let mut src = nb * d1 * plane;
                let (mut s, mut j1) = (0usize, 0usize);
                for _i1 in 0..d1 {
                    let dst = offs[s] + nb * (j1 + lc(s) * plane);
                    out[dst..dst + nb].copy_from_slice(&data[src..src + nb]);
                    src += nb;
                    s += 1;
                    if s == p {
                        s = 0;
                        j1 += 1;
                    }
                }
            }
        }
    } else {
        for i3 in 0..d3 {
            for i2 in 0..d2 {
                let (s, j2) = (i2 % p, i2 / p);
                let b2 = lc(s);
                for i1 in 0..d1 {
                    let dst = offs[s] + nb * (i1 + d1 * (j2 + b2 * i3));
                    let src = nb * (i1 + d1 * (i2 + d2 * i3));
                    out[dst..dst + nb].copy_from_slice(&data[src..src + nb]);
                }
            }
        }
    }
}

/// Merge `p` blocks into dense dimension `dim` of shape `sh_out`.
/// Block `r` supplies the indices `i = j*p + r`. Inverse of [`split_dim`].
pub fn merge_dim(blocks: &[Vec<Complex>], sh_out: Shape4, dim: usize, p: usize) -> Vec<Complex> {
    assert!((1..=3).contains(&dim));
    assert_eq!(blocks.len(), p);
    let mut out = vec![ZERO; volume(sh_out)];
    for (r, block) in blocks.iter().enumerate() {
        let mut bsh = sh_out;
        bsh[dim] = cyclic::local_count(sh_out[dim], p, r);
        assert_eq!(
            block.len(),
            volume(bsh),
            "merge_dim: block {r} has wrong size (expected shape {bsh:?})"
        );
        merge_block(block, sh_out, dim, p, r, &mut out);
    }
    out
}

/// [`merge_dim`] from a preallocated flat receive buffer: block `q` is read
/// from `recv[offs[q]..offs[q+1]]` and scattered into `out` in place — no
/// allocation on the unpack path.
pub fn merge_dim_from(
    recv: &[Complex],
    offs: &[usize],
    sh_out: Shape4,
    dim: usize,
    p: usize,
    out: &mut [Complex],
) {
    assert!((1..=3).contains(&dim));
    assert_eq!(offs.len(), p + 1);
    assert_eq!(recv.len(), offs[p], "merge_dim_from: flat buffer length");
    assert_eq!(out.len(), volume(sh_out), "merge_dim_from: output length");
    for r in 0..p {
        let block = &recv[offs[r]..offs[r + 1]];
        let mut bsh = sh_out;
        bsh[dim] = cyclic::local_count(sh_out[dim], p, r);
        assert_eq!(
            block.len(),
            volume(bsh),
            "merge_dim_from: block {r} has wrong size (expected shape {bsh:?})"
        );
        merge_block(block, sh_out, dim, p, r, out);
    }
}

/// Scatter one canonical-order residue block into the dense tensor.
fn merge_block(
    block: &[Complex],
    sh_out: Shape4,
    dim: usize,
    p: usize,
    r: usize,
    out: &mut [Complex],
) {
    let [nb, d1, d2, _d3] = sh_out;
    // Perf (§Perf, L3 iteration 3): dim-3 merges interleave whole
    // contiguous planes — memcpy per plane (the unpack stage of the
    // inverse slab alltoall).
    if dim == 3 {
        let plane = nb * d1 * d2;
        for (j3, src) in block.chunks_exact(plane).enumerate() {
            let i3 = j3 * p + r;
            out[i3 * plane..(i3 + 1) * plane].copy_from_slice(src);
        }
        return;
    }
    let mut bsh = sh_out;
    bsh[dim] = cyclic::local_count(sh_out[dim], p, r);
    let [_, b1, b2, b3] = bsh;
    let mut src = 0;
    for i3 in 0..b3 {
        for j2 in 0..b2 {
            let i2 = if dim == 2 { j2 * p + r } else { j2 };
            for j1 in 0..b1 {
                let i1 = if dim == 1 { j1 * p + r } else { j1 };
                let dst = nb * (i1 + d1 * (i2 + d2 * i3));
                out[dst..dst + nb].copy_from_slice(&block[src..src + nb]);
                src += nb;
            }
        }
    }
}

/// Append destination `s`'s residue block of dimension `dim` to a wire
/// buffer, as raw bytes in canonical block order — the per-destination
/// twin of [`split_dim_into`] (bit-identical bytes to that destination's
/// slice of the flat send buffer). This is the pack side of the fused
/// exchange: it runs right before round `s`'s send posts, not inside a
/// monolithic pre-pack.
pub fn pack_block_bytes(
    data: &[Complex],
    sh: Shape4,
    dim: usize,
    p: usize,
    s: usize,
    out: &mut WireBuf,
) {
    assert!((1..=3).contains(&dim), "cannot pack the batch dimension");
    assert!(s < p);
    assert_eq!(data.len(), volume(sh));
    let [nb, d1, d2, d3] = sh;
    match dim {
        // Whole contiguous planes (the slab exchanges): memcpy per plane.
        3 => {
            let plane = nb * d1 * d2;
            let mut i3 = s;
            while i3 < d3 {
                out.extend_from_slice(complex::as_bytes(&data[i3 * plane..(i3 + 1) * plane]));
                i3 += p;
            }
        }
        // Whole contiguous rows of nb*d1 elements.
        2 => {
            let row = nb * d1;
            for i3 in 0..d3 {
                let mut i2 = s;
                while i2 < d2 {
                    let src = row * (i2 + d2 * i3);
                    out.extend_from_slice(complex::as_bytes(&data[src..src + row]));
                    i2 += p;
                }
            }
        }
        // nb-contiguous runs, stride p along dim 1.
        _ => {
            for i3 in 0..d3 {
                for i2 in 0..d2 {
                    let base = nb * d1 * (i2 + d2 * i3);
                    let mut i1 = s;
                    while i1 < d1 {
                        let src = base + nb * i1;
                        out.extend_from_slice(complex::as_bytes(&data[src..src + nb]));
                        i1 += p;
                    }
                }
            }
        }
    }
}

/// Scatter the block received from rank `r` — raw bytes in canonical block
/// order — into dense dimension `dim` of `out`: the byte-source twin of
/// the per-block scatter inside [`merge_dim_from`], and the unpack side of
/// the fused exchange (runs as round `r`'s wait completes, straight off
/// the wire buffer).
pub fn unpack_block_bytes(
    block: &[u8],
    sh_out: Shape4,
    dim: usize,
    p: usize,
    r: usize,
    out: &mut [Complex],
) {
    assert!((1..=3).contains(&dim));
    assert!(r < p);
    assert_eq!(out.len(), volume(sh_out), "unpack_block_bytes: output length");
    let [nb, d1, d2, d3] = sh_out;
    let mut bsh = sh_out;
    bsh[dim] = cyclic::local_count(sh_out[dim], p, r);
    assert_eq!(
        block.len(),
        volume(bsh) * ELEM,
        "unpack_block_bytes: block from rank {r} has the wrong size (expected shape {bsh:?})"
    );
    match dim {
        3 => {
            let plane = nb * d1 * d2;
            let mut src = 0usize;
            let mut i3 = r;
            while i3 < d3 {
                complex::copy_from_bytes(
                    &block[src..src + plane * ELEM],
                    &mut out[i3 * plane..(i3 + 1) * plane],
                );
                src += plane * ELEM;
                i3 += p;
            }
        }
        2 => {
            let row = nb * d1;
            let mut src = 0usize;
            for i3 in 0..d3 {
                let mut i2 = r;
                while i2 < d2 {
                    let dst = row * (i2 + d2 * i3);
                    complex::copy_from_bytes(
                        &block[src..src + row * ELEM],
                        &mut out[dst..dst + row],
                    );
                    src += row * ELEM;
                    i2 += p;
                }
            }
        }
        _ => {
            let mut src = 0usize;
            for i3 in 0..d3 {
                for i2 in 0..d2 {
                    let base = nb * d1 * (i2 + d2 * i3);
                    let mut i1 = r;
                    while i1 < d1 {
                        let dst = base + nb * i1;
                        complex::copy_from_bytes(
                            &block[src..src + nb * ELEM],
                            &mut out[dst..dst + nb],
                        );
                        src += nb * ELEM;
                        i1 += p;
                    }
                }
            }
        }
    }
}

/// Cursor over the contiguous element runs of one residue block, in
/// canonical block order — the run geometry of [`pack_block_bytes`] /
/// [`unpack_block_bytes`] (planes for dim 3, rows for dim 2, `nb`-runs
/// for dim 1) expressed as an iterator of `(start_elem, len)` pairs.
/// Pairing a source walker with a destination walker lets the self block
/// stream src→dst directly, with no wire-buffer staging and no byte
/// reinterpretation.
struct RunWalker {
    sh: Shape4,
    dim: usize,
    p: usize,
    r: usize,
    i1: usize,
    i2: usize,
    i3: usize,
}

impl RunWalker {
    fn new(sh: Shape4, dim: usize, p: usize, r: usize) -> Self {
        assert!((1..=3).contains(&dim));
        assert!(r < p);
        RunWalker {
            sh,
            dim,
            p,
            r,
            i1: r,
            i2: if dim == 2 { r } else { 0 },
            i3: if dim == 3 { r } else { 0 },
        }
    }
}

impl Iterator for RunWalker {
    type Item = (usize, usize);

    fn next(&mut self) -> Option<(usize, usize)> {
        let [nb, d1, d2, d3] = self.sh;
        match self.dim {
            // Whole contiguous planes, stride p along dim 3.
            3 => {
                if self.i3 >= d3 {
                    return None;
                }
                let plane = nb * d1 * d2;
                let run = (self.i3 * plane, plane);
                self.i3 += self.p;
                Some(run)
            }
            // Whole contiguous rows of nb*d1 elements.
            2 => {
                let row = nb * d1;
                loop {
                    if self.i3 >= d3 {
                        return None;
                    }
                    if self.i2 < d2 {
                        let run = (row * (self.i2 + d2 * self.i3), row);
                        self.i2 += self.p;
                        return Some(run);
                    }
                    self.i2 = self.r;
                    self.i3 += 1;
                }
            }
            // nb-contiguous runs, stride p along dim 1.
            _ => loop {
                if self.i3 >= d3 {
                    return None;
                }
                if self.i2 >= d2 {
                    self.i2 = 0;
                    self.i3 += 1;
                    continue;
                }
                if self.i1 < d1 {
                    let run = (nb * (self.i1 + d1 * (self.i2 + d2 * self.i3)), nb);
                    self.i1 += self.p;
                    return Some(run);
                }
                self.i1 = self.r;
                self.i2 += 1;
            },
        }
    }
}

/// The [`PackKernel`] of every cyclic split/merge exchange — shared by the
/// slab-pencil plan (and everything stacked on it: the non-batched loop,
/// the pad-to-cube baseline) and both exchanges of the pencil plan. Packs
/// destination residue blocks straight out of the source tensor
/// ([`pack_block_bytes`]) and merges each received block into the dense
/// destination dimension of the output tensor ([`unpack_block_bytes`]) as
/// its wait completes.
pub struct SplitMergeKernel<'a> {
    sched: &'a A2aSchedule,
    src: &'a [Complex],
    sh_src: Shape4,
    dim_src: usize,
    dst: &'a mut [Complex],
    sh_dst: Shape4,
    dim_dst: usize,
}

impl<'a> SplitMergeKernel<'a> {
    /// Kernel for one exchange: split `dim_src` of `src` (shape `sh_src`)
    /// into `sched.p` residue blocks, merge received blocks into `dim_dst`
    /// of `dst` (shape `sh_dst`). `sched` must be the plan-time schedule of
    /// this exact exchange (its block extents size the wire buffers).
    pub fn new(
        sched: &'a A2aSchedule,
        src: &'a [Complex],
        sh_src: Shape4,
        dim_src: usize,
        dst: &'a mut [Complex],
        sh_dst: Shape4,
        dim_dst: usize,
    ) -> Self {
        assert_eq!(src.len(), volume(sh_src), "split-merge kernel: source length");
        assert_eq!(dst.len(), volume(sh_dst), "split-merge kernel: destination length");
        SplitMergeKernel { sched, src, sh_src, dim_src, dst, sh_dst, dim_dst }
    }

    /// Move the self block src→dst directly: pair the source walker
    /// (residue `me` of `dim_src`) with the destination walker (block `me`
    /// merging into `dim_dst`), streaming the shorter of the two current
    /// runs at each step. Both walkers enumerate elements in canonical
    /// block order, so this is bit-identical to
    /// pack → arena staging buffer → unpack — with zero staging.
    fn self_move_impl(&mut self) {
        let me = self.sched.me;
        assert_eq!(
            self.sched.send_counts[me], self.sched.recv_counts[me],
            "alltoall: self block extents disagree"
        );
        let mut src_runs = RunWalker::new(self.sh_src, self.dim_src, self.sched.p, me);
        let mut dst_runs = RunWalker::new(self.sh_dst, self.dim_dst, self.sched.p, me);
        let (mut ss, mut sl) = (0usize, 0usize);
        let (mut ds, mut dl) = (0usize, 0usize);
        loop {
            if sl == 0 {
                match src_runs.next() {
                    Some((s, l)) => (ss, sl) = (s, l),
                    None => break,
                }
                continue;
            }
            if dl == 0 {
                match dst_runs.next() {
                    Some((d, l)) => (ds, dl) = (d, l),
                    None => break,
                }
                continue;
            }
            let n = sl.min(dl);
            self.dst[ds..ds + n].copy_from_slice(&self.src[ss..ss + n]);
            (ss, sl) = (ss + n, sl - n);
            (ds, dl) = (ds + n, dl - n);
        }
    }

    /// Consume the kernel into its read-only pack half and write-only
    /// unpack half — the two-borrow contract of the threaded engine
    /// ([`alltoallv_fused_threaded`]): the pack half is shared with the
    /// helper thread (it only reads `src`), the unpack half moves into it
    /// (it exclusively owns `dst`).
    pub fn into_halves(self) -> (SplitPackHalf<'a>, SplitUnpackHalf<'a>) {
        let SplitMergeKernel { sched, src, sh_src, dim_src, dst, sh_dst, dim_dst } = self;
        (
            SplitPackHalf { sched, src, sh_src, dim_src },
            SplitUnpackHalf { sched, dst, sh_dst, dim_dst },
        )
    }

    /// Run this kernel's exchange under `tuning`: the single-threaded
    /// fused windowed engine, or — with [`CommTuning::worker`] — the self
    /// block moved src→dst directly (no arena staging) followed by the
    /// threaded engine, whose helper thread packs and unpacks while the
    /// communicating thread is blocked in waits. Results are bit-identical
    /// either way; only the counters differ.
    pub fn exchange(mut self, comm: &Comm, tuning: CommTuning) -> A2aCounters {
        if tuning.worker {
            self.self_move_impl();
            let (pack, mut unpack) = self.into_halves();
            alltoallv_fused_threaded(comm, &pack, &mut unpack, tuning)
        } else {
            fused_exchange(comm, &mut self, tuning)
        }
    }
}

/// The read-only pack half of a [`SplitMergeKernel`] (see
/// [`SplitMergeKernel::into_halves`]): packs destination residue blocks
/// straight out of the shared source tensor.
pub struct SplitPackHalf<'a> {
    sched: &'a A2aSchedule,
    src: &'a [Complex],
    sh_src: Shape4,
    dim_src: usize,
}

impl PackHalf for SplitPackHalf<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.sched.send_counts[dest] * ELEM
    }

    fn pack(&self, dest: usize, out: &mut WireBuf) {
        pack_block_bytes(self.src, self.sh_src, self.dim_src, self.sched.p, dest, out);
    }
}

/// The write-only unpack half of a [`SplitMergeKernel`] (see
/// [`SplitMergeKernel::into_halves`]): merges each received block into the
/// exclusively-owned destination tensor.
pub struct SplitUnpackHalf<'a> {
    sched: &'a A2aSchedule,
    dst: &'a mut [Complex],
    sh_dst: Shape4,
    dim_dst: usize,
}

impl UnpackHalf for SplitUnpackHalf<'_> {
    fn recv_bytes(&self, src: usize) -> usize {
        self.sched.recv_counts[src] * ELEM
    }

    fn unpack(&mut self, src: usize, block: &[u8]) {
        unpack_block_bytes(block, self.sh_dst, self.dim_dst, self.sched.p, src, self.dst);
    }
}

impl PackKernel for SplitMergeKernel<'_> {
    fn send_bytes(&self, dest: usize) -> usize {
        self.sched.send_counts[dest] * ELEM
    }

    fn recv_bytes(&self, src: usize) -> usize {
        self.sched.recv_counts[src] * ELEM
    }

    fn pack(&mut self, dest: usize, out: &mut WireBuf) {
        pack_block_bytes(self.src, self.sh_src, self.dim_src, self.sched.p, dest, out);
    }

    fn unpack(&mut self, src: usize, block: &[u8]) {
        unpack_block_bytes(block, self.sh_dst, self.dim_dst, self.sched.p, src, self.dst);
    }

    fn self_move(&mut self, me: usize) -> bool {
        debug_assert_eq!(me, self.sched.me);
        self.self_move_impl();
        true
    }
}

/// Extract one batch entry `b` from a batch-fastest tensor (used by the
/// non-batched variants that loop over single transforms).
pub fn extract_band(data: &[Complex], nb: usize, b: usize) -> Vec<Complex> {
    assert!(b < nb);
    data.iter().skip(b).step_by(nb).copied().collect()
}

/// [`extract_band`] into a preallocated buffer (the loop variant's
/// allocation-free band staging).
pub fn extract_band_into(data: &[Complex], nb: usize, b: usize, out: &mut [Complex]) {
    assert!(b < nb);
    assert_eq!(data.len(), nb * out.len());
    for (i, v) in out.iter_mut().enumerate() {
        *v = data[b + nb * i];
    }
}

/// Write one batch entry back.
pub fn insert_band(data: &mut [Complex], nb: usize, b: usize, band: &[Complex]) {
    assert_eq!(data.len(), nb * band.len());
    for (i, v) in band.iter().enumerate() {
        data[b + nb * i] = *v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize) -> Vec<Complex> {
        (0..n).map(|i| Complex::new(i as f64, -(i as f64) * 0.5)).collect()
    }

    #[test]
    fn split_merge_round_trip_every_dim() {
        let sh: Shape4 = [2, 5, 4, 6];
        let data = seq(volume(sh));
        for dim in 1..=3 {
            for p in [1usize, 2, 3, 4] {
                let blocks = split_dim(&data, sh, dim, p);
                assert_eq!(blocks.len(), p);
                let total: usize = blocks.iter().map(|b| b.len()).sum();
                assert_eq!(total, data.len());
                let back = merge_dim(&blocks, sh, dim, p);
                assert_eq!(back, data, "dim={dim} p={p}");
            }
        }
    }

    #[test]
    fn split_block_sizes_are_cyclic_counts() {
        let sh: Shape4 = [1, 7, 3, 2];
        let data = seq(volume(sh));
        let blocks = split_dim(&data, sh, 1, 3);
        for (s, b) in blocks.iter().enumerate() {
            assert_eq!(b.len(), cyclic::local_count(7, 3, s) * 3 * 2);
        }
    }

    #[test]
    fn split_dim1_values() {
        // [nb=1, d1=4, d2=1, d3=1], p=2: block 0 = indices 0,2; block 1 = 1,3.
        let data = seq(4);
        let blocks = split_dim(&data, [1, 4, 1, 1], 1, 2);
        assert_eq!(blocks[0], vec![data[0], data[2]]);
        assert_eq!(blocks[1], vec![data[1], data[3]]);
    }

    #[test]
    fn flat_split_matches_nested() {
        let sh: Shape4 = [2, 5, 4, 6];
        let data = seq(volume(sh));
        for dim in 1..=3 {
            for p in [1usize, 2, 3, 4] {
                let sched = A2aSchedule::for_split_merge(sh, dim, sh, dim, p, 0);
                let nested = split_dim(&data, sh, dim, p);
                let mut flat = vec![ZERO; sched.send_total()];
                split_dim_into(&data, sh, dim, p, &mut flat, &sched.send_offs);
                for (s, block) in nested.iter().enumerate() {
                    assert_eq!(
                        &flat[sched.send_offs[s]..sched.send_offs[s + 1]],
                        &block[..],
                        "dim={dim} p={p} block={s}"
                    );
                }
                // Flat merge inverts the flat split.
                let mut back = vec![ZERO; data.len()];
                merge_dim_from(&flat, &sched.recv_offs, sh, dim, p, &mut back);
                assert_eq!(back, data, "dim={dim} p={p}");
            }
        }
    }

    #[test]
    fn schedule_extents_match_split_blocks() {
        let sh_send: Shape4 = [3, 5, 4, 7];
        let sh_recv: Shape4 = [3, 6, 4, 5];
        let p = 3;
        let sched = A2aSchedule::for_split_merge(sh_send, 3, sh_recv, 1, p, 1);
        let data = seq(volume(sh_send));
        let blocks = split_dim(&data, sh_send, 3, p);
        for (s, block) in blocks.iter().enumerate() {
            assert_eq!(sched.send_counts[s], block.len());
        }
        assert_eq!(sched.send_total(), data.len());
        assert_eq!(sched.recv_total(), volume(sh_recv));
        // me=1 of 3 sends blocks 0 and 2 remotely.
        let remote: usize = sched.send_counts[0] + sched.send_counts[2];
        assert_eq!(sched.bytes_remote(), (remote * 16) as u64);
        assert_eq!(sched.msgs(), 2);
        // The reversed schedule swaps the two sides.
        let rev = sched.reversed();
        assert_eq!(rev.send_counts, sched.recv_counts);
        assert_eq!(rev.recv_counts, sched.send_counts);
    }

    #[test]
    fn per_block_pack_matches_monolithic_split() {
        // The fused pack must produce, per destination, exactly the bytes
        // the monolithic split writes into that destination's slice of the
        // flat send buffer — this is the bit-identity anchor of the fused
        // exchange.
        use crate::comm::arena::BufferArena;
        let sh: Shape4 = [2, 5, 4, 6];
        let data = seq(volume(sh));
        let arena = BufferArena::new();
        for dim in 1..=3 {
            for p in [1usize, 2, 3, 4] {
                let sched = A2aSchedule::for_split_merge(sh, dim, sh, dim, p, 0);
                let mut flat = vec![ZERO; sched.send_total()];
                split_dim_into(&data, sh, dim, p, &mut flat, &sched.send_offs);
                for s in 0..p {
                    let mut buf = arena.checkout(sched.send_counts[s] * ELEM);
                    pack_block_bytes(&data, sh, dim, p, s, &mut buf);
                    assert_eq!(
                        &buf[..],
                        crate::fft::complex::as_bytes(
                            &flat[sched.send_offs[s]..sched.send_offs[s + 1]]
                        ),
                        "dim={dim} p={p} block={s}"
                    );
                }
            }
        }
    }

    #[test]
    fn per_block_unpack_inverts_per_block_pack() {
        use crate::comm::arena::BufferArena;
        let sh: Shape4 = [3, 4, 5, 6];
        let data = seq(volume(sh));
        let arena = BufferArena::new();
        for dim in 1..=3 {
            for p in [1usize, 2, 3] {
                let mut back = vec![ZERO; data.len()];
                for r in 0..p {
                    let mut buf = arena.checkout(0);
                    pack_block_bytes(&data, sh, dim, p, r, &mut buf);
                    unpack_block_bytes(&buf, sh, dim, p, r, &mut back);
                }
                assert_eq!(back, data, "dim={dim} p={p}");
            }
        }
    }

    /// The direct src→dst self move (paired [`RunWalker`]s, no staging)
    /// produces exactly the elements the staged path writes — pack the
    /// self block into an arena buffer, then unpack it — across the
    /// slab-style (split dim 3, merge dim 1) and pencil-style (split dim
    /// 2, merge dim 3) exchanges, including ranks whose residue is beyond
    /// the extent (zero-length self block).
    #[test]
    fn direct_self_move_matches_staged_self_block() {
        use crate::comm::arena::BufferArena;
        let arena = BufferArena::new();
        let nb = 2usize;
        for (nx, ny, nz) in [(5usize, 3usize, 7usize), (2, 3, 7), (4, 1, 3)] {
            for p in [1usize, 2, 3] {
                for me in 0..p {
                    let lxc = cyclic::local_count(nx, p, me);
                    let lyc = cyclic::local_count(ny, p, me);
                    let lzc = cyclic::local_count(nz, p, me);
                    // Slab-pencil forward: split z of [nb,lxc,ny,nz], merge
                    // x of [nb,nx,ny,lzc]; pencil column exchange: split y
                    // of [nb,lxc,ny,lzc], merge z of [nb,lxc,lyc,nz].
                    let cases: [(Shape4, usize, Shape4, usize); 2] = [
                        ([nb, lxc, ny, nz], 3, [nb, nx, ny, lzc], 1),
                        ([nb, lxc, ny, lzc], 2, [nb, lxc, lyc, nz], 3),
                    ];
                    for (sh_src, dim_src, sh_dst, dim_dst) in cases {
                        let sched =
                            A2aSchedule::for_split_merge(sh_src, dim_src, sh_dst, dim_dst, p, me);
                        let data = seq(volume(sh_src));
                        // Staged reference: pack → wire buffer → unpack.
                        let mut want = vec![ZERO; volume(sh_dst)];
                        let mut buf = arena.checkout(sched.send_counts[me] * ELEM);
                        pack_block_bytes(&data, sh_src, dim_src, p, me, &mut buf);
                        unpack_block_bytes(&buf, sh_dst, dim_dst, p, me, &mut want);
                        arena.recycle(buf);
                        // Direct move through the kernel's PackKernel hook.
                        let mut got = vec![ZERO; volume(sh_dst)];
                        let mut k = SplitMergeKernel::new(
                            &sched, &data, sh_src, dim_src, &mut got, sh_dst, dim_dst,
                        );
                        assert!(k.self_move(me), "split-merge kernel moves its self block");
                        assert_eq!(got, want, "dims {dim_src}->{dim_dst} p={p} me={me}");
                    }
                }
            }
        }
    }

    /// The worker-threaded exchange (direct self move, then helper-thread
    /// pack/unpack via the split halves) is bit-identical to the
    /// single-threaded fused engine on every rank.
    #[test]
    fn worker_exchange_is_bit_identical_to_fused() {
        use crate::comm::communicator::run_world;
        let p = 3usize;
        run_world(p, move |comm| {
            let me = comm.rank();
            let nb = 2usize;
            let (nx, ny, nz) = (5usize, 3usize, 7usize);
            let lxc = cyclic::local_count(nx, p, me);
            let lzc = cyclic::local_count(nz, p, me);
            let sh_src: Shape4 = [nb, lxc, ny, nz];
            let sh_dst: Shape4 = [nb, nx, ny, lzc];
            let sched = A2aSchedule::for_split_merge(sh_src, 3, sh_dst, 1, p, me);
            let data: Vec<Complex> = (0..volume(sh_src))
                .map(|i| Complex::new((me * 10_000 + i) as f64, -0.25 * i as f64))
                .collect();
            for w in [1usize, 2] {
                let mut base = vec![ZERO; volume(sh_dst)];
                let mut k =
                    SplitMergeKernel::new(&sched, &data, sh_src, 3, &mut base, sh_dst, 1);
                let c0 = fused_exchange(&comm, &mut k, CommTuning::with_window(w));
                assert_eq!(c0.worker_busy_ns, 0, "single-threaded path has no worker");
                let mut threaded = vec![ZERO; volume(sh_dst)];
                let k =
                    SplitMergeKernel::new(&sched, &data, sh_src, 3, &mut threaded, sh_dst, 1);
                let c1 = k.exchange(&comm, CommTuning::with_window(w).with_worker(true));
                assert_eq!(
                    c1.worker_busy_ns,
                    c1.pack_overlap_ns + c1.unpack_overlap_ns,
                    "helper busy time is its pack + unpack time"
                );
                for (a, b) in base.iter().zip(threaded.iter()) {
                    assert_eq!(a.re.to_bits(), b.re.to_bits(), "w={w} me={me}");
                    assert_eq!(a.im.to_bits(), b.im.to_bits(), "w={w} me={me}");
                }
            }
        });
    }

    #[test]
    fn band_extract_insert_round_trip() {
        let nb = 3;
        let data = seq(nb * 5);
        let mut rebuilt = vec![Complex::new(0.0, 0.0); data.len()];
        for b in 0..nb {
            let band = extract_band(&data, nb, b);
            assert_eq!(band.len(), 5);
            let mut band2 = vec![ZERO; 5];
            extract_band_into(&data, nb, b, &mut band2);
            assert_eq!(band, band2);
            insert_band(&mut rebuilt, nb, b, &band);
        }
        assert_eq!(rebuilt, data);
    }
}
