//! Per-rank helper worker thread: a single persistent `std::thread` fed
//! closures over an mpsc channel, used by the coordinator's
//! [`BatchingDriver`](crate::coordinator::BatchingDriver) to run one
//! batch's staging tail concurrently with the next batch's exchange (the
//! two-deep software pipeline).
//!
//! The exchange-level overlap worker is different machinery: the fused
//! threaded engine (`alltoallv_fused_threaded`) spawns a *scoped* helper
//! per exchange so it can borrow the plan's tensors directly. This module
//! is the `'static` variant for work that outlives any one call: jobs own
//! their data (buffers move through the channel) and the thread persists
//! across flushes so steady state spawns nothing.
//!
//! Channel contract: `submit` enqueues a boxed `FnOnce`; the worker runs
//! jobs strictly in submission order (mpsc FIFO), so a later harvest
//! observes every effect of earlier jobs once its own job's completion is
//! observed. Shutdown is drop-driven: dropping the `Worker` closes the
//! channel, the thread drains what is queued and exits, and the `Drop`
//! impl joins it — no sentinel messages, no leaked threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A unit of work shipped to the worker thread. Jobs own everything they
/// touch; results travel back through whatever channel the job captured.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent helper thread consuming [`Job`]s from an mpsc queue.
///
/// One `Worker` per driver; jobs run in submission order; dropping the
/// worker shuts the thread down cleanly (close channel → drain → join).
pub struct Worker {
    /// `Some` while the thread is accepting work; taken on drop so the
    /// channel closes and the receive loop ends.
    tx: Option<mpsc::Sender<Job>>,
    /// `Some` until joined on drop.
    handle: Option<JoinHandle<()>>,
    /// Nanoseconds the thread has spent inside jobs, accumulated across
    /// the worker's lifetime. Written by the worker, read by harvesters.
    busy_ns: Arc<AtomicU64>,
}

impl Worker {
    /// Spawn the helper thread. The thread blocks in `recv` while idle
    /// (no spinning) and exits when the `Worker` is dropped.
    pub fn spawn() -> Worker {
        let (tx, rx) = mpsc::channel::<Job>();
        let busy_ns = Arc::new(AtomicU64::new(0));
        let handle = {
            let busy_ns = Arc::clone(&busy_ns);
            std::thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    job();
                    // Relaxed: `busy_ns` is a monotone reporting tally read
                    // for trace attribution only; the job's *effects* are
                    // ordered by the response channel the job itself
                    // signals on, never by this counter.
                    busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            })
        };
        Worker { tx: Some(tx), handle: Some(handle), busy_ns }
    }

    /// Enqueue `job` for execution on the worker thread. Jobs run in
    /// submission order.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            // The receiver lives for as long as `tx` is `Some` (the thread
            // only exits once the sender drops), so this send cannot fail;
            // swallow the theoretical error rather than panic in a
            // library path.
            let _ = tx.send(Box::new(job));
        }
    }

    /// Nanoseconds the worker has spent executing jobs so far.
    pub fn busy_ns(&self) -> u64 {
        // Relaxed: see the comment at the `busy_ns` fetch_add — a
        // monotone reporting tally, not a synchronization edge.
        self.busy_ns.load(Ordering::Relaxed)
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        // Close the channel first so the receive loop sees `Err` and
        // returns after draining queued jobs...
        drop(self.tx.take());
        // ...then join so no job outlives the owner's borrow horizon.
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::arena::BufferArena;
    use std::sync::mpsc::channel;

    /// A buffer handed off through the job channel is filled by the worker
    /// and comes back intact through a response channel — the exact
    /// ownership dance the driver's pipeline tail uses (buffers move, no
    /// shared mutation).
    #[test]
    fn channel_handoff_round_trips_a_buffer() {
        let arena = BufferArena::new();
        let mut buf = arena.checkout(64);
        buf.extend_from_slice(&[0xAB; 64]);
        let w = Worker::spawn();
        let (tx, rx) = channel();
        w.submit(move || {
            let ok = buf.as_slice().iter().all(|&b| b == 0xAB);
            let _ = tx.send((ok, buf));
        });
        let (ok, buf) = rx.recv().expect("worker must run the job");
        assert!(ok, "worker saw the bytes the submitter wrote");
        arena.recycle(buf);
        let (minted, _reused) = arena.stats();
        assert_eq!(minted, 1, "the handoff moves one buffer, mints nothing");
    }

    /// Jobs run in submission order (mpsc FIFO): a later job observes every
    /// effect of earlier ones.
    #[test]
    fn jobs_run_in_submission_order() {
        let w = Worker::spawn();
        let (tx, rx) = channel();
        for i in 0..16u32 {
            let tx = tx.clone();
            w.submit(move || {
                let _ = tx.send(i);
            });
        }
        let got: Vec<u32> = (0..16).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, (0..16).collect::<Vec<u32>>());
    }

    /// Dropping the worker drains queued jobs, then joins the thread:
    /// every submitted job runs exactly once, and drop returns (join
    /// completes) rather than leaking the thread.
    #[test]
    fn shutdown_on_drop_drains_then_joins() {
        let (tx, rx) = channel();
        {
            let w = Worker::spawn();
            for i in 0..8u32 {
                let tx = tx.clone();
                w.submit(move || {
                    let _ = tx.send(i);
                });
            }
            // `w` drops here: channel closes, queued jobs drain, join.
        }
        drop(tx);
        let got: Vec<u32> = rx.iter().collect();
        assert_eq!(got, (0..8).collect::<Vec<u32>>(), "drop drained the queue");
    }

    /// `busy_ns` accumulates monotonically once jobs have run.
    #[test]
    fn busy_ns_accumulates() {
        let w = Worker::spawn();
        let (tx, rx) = channel();
        w.submit(move || {
            // Enough work that even a coarse clock ticks.
            let mut acc = 0u64;
            for i in 0..200_000u64 {
                acc = acc.wrapping_add(i.wrapping_mul(2654435761));
            }
            let _ = tx.send(acc);
        });
        let _ = rx.recv().unwrap();
        // The job has signalled completion, so its busy time is recorded.
        assert!(w.busy_ns() > 0, "worker recorded busy time");
    }
}
