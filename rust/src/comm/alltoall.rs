//! All-to-all exchanges — the data-movement primitive of every distributed
//! FFT stage (paper §3.1: "typically, Fourier transforms required alltoall
//! MPI collectives").
//!
//! `alltoallv` uses the pairwise-exchange schedule (`p-1` rounds, partner
//! `rank ± round` generalized to non-powers of two), matching what Cray
//! MPICH does for large messages; the message/byte counts it produces are
//! what `crate::model::netmodel` prices.
//!
//! Two execution disciplines are provided for the flat-buffer variant:
//!
//! * **serial** ([`alltoallv_complex_flat_serial`]) — round `s` blocks on
//!   its receive before round `s+1`'s send is even posted. One slow rank
//!   convoys everyone behind it, round after round.
//! * **overlapped** ([`alltoallv_complex_flat_tuned`]) — the windowed
//!   pipeline of P3DFFT-style overlap: every receive is posted up front as
//!   an `irecv`, sends run up to [`CommTuning::window`] rounds ahead of the
//!   oldest un-waited receive, and the wait for round `s` proceeds while
//!   the wire (and the partners) chew on rounds `s+1..s+window`. Self
//!   blocks never touch the mailboxes in either discipline.
//!
//! The windowed engine itself is **fused** ([`alltoallv_fused`]): instead
//! of taking a pre-packed flat buffer, it drives per-destination
//! [`FusedBlocks`] pack/unpack movers round by round — destination block
//! `s + window` is packed *directly into its recycled wire buffer* after
//! the wait for round `s` completes (while rounds `s+1..s+window` are
//! still in flight), and each received block is unpacked as its own wait
//! completes instead of after a full-exchange barrier. The first send
//! therefore leaves after packing **one** block, not all `p`; see
//! `docs/ARCHITECTURE.md` ("The exchange pipeline") for the timeline. The
//! flat-buffer variants are thin [`FusedBlocks`] adapters over the same
//! engine, and the plan layer bridges its `PackKernel` trait
//! (`fftb::plan::stages`) to it, so one engine serves every caller.
//!
//! The fused engine also has a **threaded** variant
//! ([`alltoallv_fused_threaded`], selected by [`CommTuning::worker`]): a
//! scoped helper thread takes over all pack/unpack work — it packs and
//! posts each round's block and lands each received one — while the
//! communicating thread does nothing but complete waits in schedule order
//! and forward payloads over a channel. Pack/unpack then overlap the waits
//! *in real time* instead of merely interleaving with them. The mover
//! contract splits into a read-only [`PackHalf`] (shared with the helper)
//! and a write-only [`UnpackHalf`] (moved into it), so no `unsafe` and no
//! aliasing: the source tensor is only ever read, the destination only
//! ever written, and the self block is the caller's job before the call.
//! Results are bit-identical to the single-threaded engine — the helper
//! changes *when* blocks move, never where they land — which
//! `tests/comm_schedules.rs` pins across the perturbation seed matrix.
//!
//! All disciplines report [`A2aCounters`]: nanoseconds spent blocked in
//! waits, rounds posted ahead of the serial schedule, the pack/unpack
//! nanoseconds that ran *overlapped* with in-flight rounds, and the helper
//! thread's busy time — the numbers `ExecTrace` surfaces as `wait_ns` /
//! `overlap_rounds` / `pack_overlap_ns` / `unpack_overlap_ns` /
//! `worker_busy_ns` and `benches/a2a_micro.rs` prints side by side.

use std::time::Instant;

use super::arena::WireBuf;
use super::communicator::Comm;
use crate::fft::complex::{self, Complex};

const T_A2A: u64 = 0x20;

/// Bytes per complex element on the wire.
const ELEM: usize = std::mem::size_of::<Complex>();

/// Execution knobs of the overlapped exchange, threaded from the plans
/// (`FftbOptions::comm` / `set_tuning` on each plan).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommTuning {
    /// How many rounds of sends may be in flight ahead of the oldest
    /// un-waited receive. `1` reproduces the serial schedule's ordering
    /// (send `s`, wait `s`); larger windows let pack-and-send of future
    /// rounds overlap the wait for the current one. Clamped to
    /// `[1, p - 1]` at execution.
    pub window: usize,
    /// Run the exchange's pack/unpack work on a helper worker thread
    /// ([`alltoallv_fused_threaded`]): packing and unpacking proceed while
    /// the communicating thread is blocked in waits, instead of
    /// interleaving with them. Bit-identical to the single-threaded
    /// engine; whether it is *faster* depends on the machine profile,
    /// which is exactly what `Machine::alltoall_time_fused_threaded`
    /// prices and `tuner::search` decides.
    pub worker: bool,
}

impl Default for CommTuning {
    fn default() -> Self {
        CommTuning { window: 2, worker: false }
    }
}

impl CommTuning {
    /// Tuning with an explicit window.
    pub fn with_window(window: usize) -> Self {
        CommTuning { window, worker: false }
    }

    /// The same tuning with the helper worker thread switched on or off.
    pub fn with_worker(mut self, worker: bool) -> Self {
        self.worker = worker;
        self
    }

    /// The serial-ordering window (no sends ahead of the current wait).
    pub fn serial() -> Self {
        CommTuning { window: 1, worker: false }
    }
}

/// Per-exchange overlap accounting, accumulated into
/// [`ExecTrace`](crate::fftb::plan::ExecTrace) by the plans.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct A2aCounters {
    /// Nanoseconds this rank spent blocked waiting for receives.
    pub wait_ns: u64,
    /// Rounds whose send was posted ahead of the serial schedule (0 for
    /// the serial discipline and for `window == 1`).
    pub overlap_rounds: u64,
    /// Nanoseconds spent in per-destination pack movers for every round
    /// after the first send was posted — pack work that ran while the
    /// exchange was already in flight. 0 for a 2-rank world (one remote
    /// round), for the serial ordering (`window == 1`, where no round of
    /// this rank is outstanding when the next pack runs — matching how
    /// the cost model prices window 1 as hiding nothing), and for the
    /// pre-packed serial baseline.
    pub pack_overlap_ns: u64,
    /// Nanoseconds spent unpacking received blocks while later rounds were
    /// still outstanding (every round but the last). 0 for a 2-rank world,
    /// for the serial ordering (`window == 1`), and for the barrier-style
    /// unpack of the serial baseline.
    pub unpack_overlap_ns: u64,
    /// Nanoseconds the helper worker thread spent packing and unpacking
    /// in the threaded engine ([`alltoallv_fused_threaded`]) — its total
    /// busy time for this exchange. 0 on every single-threaded path.
    pub worker_busy_ns: u64,
}

/// Per-destination block movers driven by the fused windowed engine
/// ([`alltoallv_fused`]): block sizes for wire-buffer checkout, a `pack`
/// that appends one destination's block to its wire buffer, and an
/// `unpack` that lands one received block.
///
/// This is the comm-layer face of the contract; plans implement the
/// `PackKernel` trait (`fftb::plan::stages`), which bridges here, so the
/// comm layer stays plan-agnostic. Invariants the engine asserts:
/// `pack(dest, out)` must append exactly `send_bytes(dest)` bytes, and the
/// block handed to `unpack(src, ..)` always has `recv_bytes(src)` bytes.
pub trait FusedBlocks {
    /// Bytes of the block headed to rank `dest` (0 allowed).
    fn send_bytes(&self, dest: usize) -> usize;
    /// Bytes expected from rank `src` (0 allowed).
    fn recv_bytes(&self, src: usize) -> usize;
    /// Append rank `dest`'s packed block to `out`, in the destination's
    /// canonical element order.
    fn pack(&mut self, dest: usize, out: &mut WireBuf);
    /// Land the block received from rank `src`.
    fn unpack(&mut self, src: usize, block: &[u8]);
    /// Move rank `me`'s self block end to end without wire staging, when
    /// the implementation can (flat buffers: one memcpy). Return `false`
    /// (the default) to have the engine route it as
    /// `pack` → arena staging buffer → `unpack`.
    fn self_move(&mut self, me: usize) -> bool {
        let _ = me;
        false
    }
}

/// Pack round `round`'s destination block straight into a recycled wire
/// buffer and post it. With a window of two or more, pack time for every
/// round after the first counts as overlapped: at least one earlier round
/// is still in flight while this block is being packed. At window 1 (the
/// serial ordering) nothing of this rank is outstanding, so nothing is
/// charged — mirroring the cost model, which prices window 1 as hiding
/// no pack time.
fn pack_and_send(
    comm: &Comm,
    blocks: &mut dyn FusedBlocks,
    me: usize,
    p: usize,
    round: usize,
    w: usize,
    c: &mut A2aCounters,
) {
    let to = (me + round) % p;
    let n = blocks.send_bytes(to);
    let mut buf = comm.arena().checkout(n);
    let t0 = Instant::now();
    blocks.pack(to, &mut buf);
    if w > 1 && round > 1 {
        c.pack_overlap_ns += t0.elapsed().as_nanos() as u64;
    }
    assert_eq!(buf.len(), n, "alltoall: pack for rank {to} produced the wrong block size");
    comm.send_coll_buf(to, T_A2A, buf);
}

/// The fused windowed pairwise exchange — the one engine behind every
/// alltoall variant in this module.
///
/// Discipline: all `p - 1` receives are logically posted up front; sends
/// are primed [`CommTuning::window`] rounds deep, each packed by
/// `blocks.pack` *directly into its recycled wire buffer* immediately
/// before posting (the first send leaves after packing one block, not all
/// `p`). After the wait for round `s` completes, its block is unpacked in
/// place by `blocks.unpack` — while rounds `s+1..s+window` are still in
/// flight — and the send for round `s + window` is packed and posted. The
/// self block moves through an arena staging buffer and never touches the
/// mailboxes. Wire buffers come from the world's shared arena and block
/// geometry is a plan-time constant, so steady-state exchanges allocate
/// nothing.
///
/// `window == 1` reproduces the serial schedule's ordering (pack `s`, send
/// `s`, wait `s`, unpack `s`); results are bit-identical for every window
/// because the window changes only *when* blocks move, never where they
/// land.
pub fn alltoallv_fused(
    comm: &Comm,
    blocks: &mut dyn FusedBlocks,
    tuning: CommTuning,
) -> A2aCounters {
    let p = comm.size();
    let me = comm.rank();
    let mut c = A2aCounters::default();

    // Self block: moved directly when the implementation can, otherwise
    // packed into an arena staging buffer and landed right away — never
    // touches the mailboxes either way.
    let n_self = blocks.send_bytes(me);
    assert_eq!(n_self, blocks.recv_bytes(me), "alltoall: self block extents disagree");
    if !blocks.self_move(me) {
        let mut staging = comm.arena().checkout(n_self);
        blocks.pack(me, &mut staging);
        assert_eq!(staging.len(), n_self, "alltoall: self pack produced the wrong block size");
        blocks.unpack(me, &staging);
        // The staging buffer returns to the shared arena on drop.
    }
    if p == 1 {
        return c;
    }

    let rounds = p - 1;
    let w = tuning.window.clamp(1, rounds);

    // Schedule-perturbation mode (verification worlds only, see
    // `run_world_perturbed`): post every send up front — sends are
    // eager/buffered, so posting all of them cannot deadlock, whereas
    // permuting waits *inside* the windowed schedule could cross-block
    // between ranks — then complete the waits in a seeded pseudo-random
    // order. Distinct rounds unpack into disjoint destinations, so the
    // result must stay bit-identical to the windowed schedule; that is
    // exactly what tests/comm_schedules.rs pins across seeds.
    if let Some(order) = comm.perturb_order(rounds) {
        for round in 1..=rounds {
            pack_and_send(comm, blocks, me, p, round, w, &mut c);
        }
        for &s in &order {
            let from = (me + p - s) % p;
            let req = comm.irecv_coll(from, T_A2A);
            let t0 = Instant::now();
            // pallas-lint: allow(no-panic) — receive requests always
            // carry a payload (see Request::wait).
            let buf = req.wait().expect("irecv requests always carry a payload");
            c.wait_ns += t0.elapsed().as_nanos() as u64;
            assert_eq!(
                buf.len(),
                blocks.recv_bytes(from),
                "alltoall: peer {from} sent a block of the wrong size"
            );
            blocks.unpack(from, &buf);
        }
        return c;
    }

    // All receives are logically posted up front: in this mailbox model an
    // `irecv` has no post-time side effect (a `Request` is just a routing
    // key; matching is by per-channel FIFO), so the pre-posting is fully
    // captured by the fixed round schedule and each round's request is
    // materialized at its wait site — identical semantics, and the engine
    // stays allocation-free (no request array).

    // Prime the send window: rounds 1..=w, each packed into its wire
    // buffer at post time.
    let mut posted = 0usize;
    while posted < w {
        posted += 1;
        pack_and_send(comm, blocks, me, p, posted, w, &mut c);
        if posted > 1 {
            c.overlap_rounds += 1;
        }
    }

    // Drain: wait for round s's payload, unpack it in place, top the
    // window back up with a freshly packed send.
    for s in 1..p {
        let from = (me + p - s) % p;
        let req = comm.irecv_coll(from, T_A2A);
        let t0 = Instant::now();
        // pallas-lint: allow(no-panic) — receive requests always carry a
        // payload (see Request::wait).
        let buf = req.wait().expect("irecv requests always carry a payload");
        c.wait_ns += t0.elapsed().as_nanos() as u64;
        assert_eq!(
            buf.len(),
            blocks.recv_bytes(from),
            "alltoall: peer {from} sent a block of the wrong size"
        );
        let t1 = Instant::now();
        blocks.unpack(from, &buf);
        if w > 1 && s < rounds {
            // Later rounds of this rank are still outstanding: this
            // unpack overlapped the exchange instead of running after a
            // barrier. (At window 1 nothing of ours is in flight here.)
            c.unpack_overlap_ns += t1.elapsed().as_nanos() as u64;
        }
        drop(buf); // the wire buffer returns to the shared arena
        if posted < rounds {
            posted += 1;
            pack_and_send(comm, blocks, me, p, posted, w, &mut c);
            if w > 1 {
                c.overlap_rounds += 1;
            }
        }
    }
    c
}

/// The read-only pack side of a fused exchange, for the threaded engine
/// ([`alltoallv_fused_threaded`]).
///
/// `pack` takes `&self` — packing must only *read* the source tensor —
/// and the trait requires `Sync` because the reference is shared with the
/// helper thread. Together with [`UnpackHalf`]'s exclusive borrow of the
/// destination, this splits [`FusedBlocks`]'s single `&mut` mover into
/// two disjoint halves that can run concurrently without `unsafe`.
pub trait PackHalf: Sync {
    /// Bytes of the block headed to rank `dest` (0 allowed).
    fn send_bytes(&self, dest: usize) -> usize;
    /// Append rank `dest`'s packed block to `out`, in the destination's
    /// canonical element order. Must append exactly `send_bytes(dest)`
    /// bytes (the engine asserts it).
    fn pack(&self, dest: usize, out: &mut WireBuf);
}

/// The write-only unpack side of a fused exchange, for the threaded
/// engine ([`alltoallv_fused_threaded`]). Requires `Send` because the
/// engine moves the exclusive borrow of the destination tensor into the
/// helper thread for the duration of the exchange.
pub trait UnpackHalf: Send {
    /// Bytes expected from rank `src` (0 allowed).
    fn recv_bytes(&self, src: usize) -> usize;
    /// Land the block received from rank `src`.
    fn unpack(&mut self, src: usize, block: &[u8]);
}

/// The **threaded** fused windowed exchange: a scoped helper thread owns
/// all pack/unpack work while the calling thread only completes waits.
///
/// Division of labor:
///
/// * **helper thread** — primes [`CommTuning::window`] rounds of sends
///   (packing each block straight into its recycled wire buffer), then
///   loops: receive a completed payload over the channel, unpack it, post
///   the next round's freshly packed send.
/// * **calling thread** — completes the waits in schedule order (the
///   seeded perturbation order in verification worlds) and forwards each
///   payload `(from, WireBuf)` to the helper. While it is blocked in a
///   wait, the helper is packing and unpacking — true concurrency where
///   the single-threaded engine merely interleaves.
///
/// The dependency structure (send `s + w` is posted only after round `s`'s
/// payload arrived) is exactly the single-threaded windowed engine's, so
/// the schedule stays deadlock-free; and since distinct rounds pack from /
/// unpack into disjoint regions, results are bit-identical to
/// [`alltoallv_fused`] under every seed — `tests/comm_schedules.rs` pins
/// this.
///
/// **The self block is the caller's job**: move it src→dst *before* the
/// call (plans do a direct move with no arena staging — see
/// `SplitMergeKernel::exchange`). This engine touches remote rounds only
/// and returns immediately for a single-rank world.
pub fn alltoallv_fused_threaded(
    comm: &Comm,
    pack: &dyn PackHalf,
    unpack: &mut dyn UnpackHalf,
    tuning: CommTuning,
) -> A2aCounters {
    let p = comm.size();
    let me = comm.rank();
    let mut c = A2aCounters::default();
    if p == 1 {
        return c;
    }
    let rounds = p - 1;
    let w = tuning.window.clamp(1, rounds);
    // Perturbation worlds post every send up front (eager sends cannot
    // deadlock) and complete waits in the seeded order — the same
    // discipline as the single-threaded engine. `perturb_order` is drawn
    // once, here, so the helper never touches the perturbation state.
    let perturb = comm.perturb_order(rounds);
    let prime = if perturb.is_some() { rounds } else { w };

    let helper_comm = comm.clone();
    let (tx, rx) = std::sync::mpsc::channel::<(usize, WireBuf)>();

    let (pack_ns, unpack_ns) = std::thread::scope(|scope| {
        let helper = scope.spawn(move || {
            let comm = helper_comm;
            let mut pack_ns = 0u64;
            let mut unpack_ns = 0u64;
            let mut posted = 0usize;
            let mut post_next = |posted: &mut usize, pack_ns: &mut u64| {
                *posted += 1;
                let to = (me + *posted) % p;
                let n = pack.send_bytes(to);
                let mut buf = comm.arena().checkout(n);
                let t0 = Instant::now();
                pack.pack(to, &mut buf);
                *pack_ns += t0.elapsed().as_nanos() as u64;
                assert_eq!(
                    buf.len(),
                    n,
                    "alltoall: pack for rank {to} produced the wrong block size"
                );
                comm.send_coll_buf(to, T_A2A, buf);
            };
            while posted < prime {
                post_next(&mut posted, &mut pack_ns);
            }
            for _ in 0..rounds {
                let Ok((from, buf)) = rx.recv() else { break };
                assert_eq!(
                    buf.len(),
                    unpack.recv_bytes(from),
                    "alltoall: peer {from} sent a block of the wrong size"
                );
                let t1 = Instant::now();
                unpack.unpack(from, &buf);
                unpack_ns += t1.elapsed().as_nanos() as u64;
                drop(buf); // the wire buffer returns to the shared arena
                if posted < rounds {
                    post_next(&mut posted, &mut pack_ns);
                }
            }
            (pack_ns, unpack_ns)
        });

        let mut wait_round = |s: usize| {
            let from = (me + p - s) % p;
            let req = comm.irecv_coll(from, T_A2A);
            let t0 = Instant::now();
            // pallas-lint: allow(no-panic) — receive requests always
            // carry a payload (see Request::wait).
            let buf = req.wait().expect("irecv requests always carry a payload");
            c.wait_ns += t0.elapsed().as_nanos() as u64;
            // A send error means the helper exited early (it panicked);
            // the join below surfaces that.
            let _ = tx.send((from, buf));
        };
        match &perturb {
            Some(order) => {
                for &s in order {
                    wait_round(s);
                }
            }
            None => {
                for s in 1..p {
                    wait_round(s);
                }
            }
        }
        drop(tx); // closes the channel: the helper drains and returns
        // pallas-lint: allow(no-panic) — the helper only panics if a peer
        // sent a malformed block, which is already a broken world; the
        // join then re-raises that panic on the calling thread.
        helper.join().expect("exchange helper thread panicked")
    });

    // With the worker, *every* remote round's pack and unpack ran
    // concurrently with the communicating thread's waits.
    c.overlap_rounds = rounds as u64;
    c.pack_overlap_ns = pack_ns;
    c.unpack_overlap_ns = unpack_ns;
    c.worker_busy_ns = pack_ns + unpack_ns;
    c
}

/// [`FusedBlocks`] adapter for pre-packed flat byte buffers: pack is a
/// straight copy out of `send[soff(j)..soff(j+1)]`, unpack a straight copy
/// into `recv[roff(q)..roff(q+1)]`.
struct FlatBlocks<'a, FS, FR> {
    send: &'a [u8],
    recv: &'a mut [u8],
    soff: FS,
    roff: FR,
}

impl<FS, FR> FusedBlocks for FlatBlocks<'_, FS, FR>
where
    FS: Fn(usize) -> usize,
    FR: Fn(usize) -> usize,
{
    fn send_bytes(&self, dest: usize) -> usize {
        (self.soff)(dest + 1) - (self.soff)(dest)
    }

    fn recv_bytes(&self, src: usize) -> usize {
        (self.roff)(src + 1) - (self.roff)(src)
    }

    fn pack(&mut self, dest: usize, out: &mut WireBuf) {
        out.extend_from_slice(&self.send[(self.soff)(dest)..(self.soff)(dest + 1)]);
    }

    fn unpack(&mut self, src: usize, block: &[u8]) {
        self.recv[(self.roff)(src)..(self.roff)(src + 1)].copy_from_slice(block);
    }

    fn self_move(&mut self, me: usize) -> bool {
        let (s0, s1) = ((self.soff)(me), (self.soff)(me + 1));
        let (r0, r1) = ((self.roff)(me), (self.roff)(me + 1));
        self.recv[r0..r1].copy_from_slice(&self.send[s0..s1]);
        true
    }
}

/// [`PackHalf`] adapter over a pre-packed flat send buffer (the read-only
/// half of [`FlatBlocks`]).
struct FlatPackHalf<'a, FS> {
    send: &'a [u8],
    soff: FS,
}

impl<FS> PackHalf for FlatPackHalf<'_, FS>
where
    FS: Fn(usize) -> usize + Sync,
{
    fn send_bytes(&self, dest: usize) -> usize {
        (self.soff)(dest + 1) - (self.soff)(dest)
    }

    fn pack(&self, dest: usize, out: &mut WireBuf) {
        out.extend_from_slice(&self.send[(self.soff)(dest)..(self.soff)(dest + 1)]);
    }
}

/// [`UnpackHalf`] adapter over a flat receive buffer (the write-only half
/// of [`FlatBlocks`]).
struct FlatUnpackHalf<'a, FR> {
    recv: &'a mut [u8],
    roff: FR,
}

impl<FR> UnpackHalf for FlatUnpackHalf<'_, FR>
where
    FR: Fn(usize) -> usize + Send,
{
    fn recv_bytes(&self, src: usize) -> usize {
        (self.roff)(src + 1) - (self.roff)(src)
    }

    fn unpack(&mut self, src: usize, block: &[u8]) {
        self.recv[(self.roff)(src)..(self.roff)(src + 1)].copy_from_slice(block);
    }
}

/// The windowed pairwise exchange over flat byte buffers — a
/// [`FlatBlocks`] adapter over [`alltoallv_fused`], or, with
/// [`CommTuning::worker`], a [`FlatPackHalf`]/[`FlatUnpackHalf`] split
/// over [`alltoallv_fused_threaded`] (self block moved directly first).
/// `soff`/`roff` map block index `j` (0..=p) to byte offsets into
/// `send`/`recv`; block `j` of `send` goes to rank `j`, and rank `q`'s
/// block lands at `recv[roff(q)..roff(q + 1)]`.
fn exchange_flat<FS, FR>(
    comm: &Comm,
    send: &[u8],
    recv: &mut [u8],
    soff: FS,
    roff: FR,
    tuning: CommTuning,
) -> A2aCounters
where
    FS: Fn(usize) -> usize + Sync,
    FR: Fn(usize) -> usize + Send,
{
    if tuning.worker {
        let me = comm.rank();
        let (s0, s1) = (soff(me), soff(me + 1));
        let (r0, r1) = (roff(me), roff(me + 1));
        assert_eq!(s1 - s0, r1 - r0, "alltoall: self block extents disagree");
        recv[r0..r1].copy_from_slice(&send[s0..s1]);
        let pack = FlatPackHalf { send, soff };
        let mut unpack = FlatUnpackHalf { recv, roff };
        alltoallv_fused_threaded(comm, &pack, &mut unpack, tuning)
    } else {
        let mut blocks = FlatBlocks { send, recv, soff, roff };
        alltoallv_fused(comm, &mut blocks, tuning)
    }
}

fn validate_flat(
    comm: &Comm,
    send_len: usize,
    send_offs: &[usize],
    recv_len: usize,
    recv_offs: &[usize],
) {
    let p = comm.size();
    assert_eq!(send_offs.len(), p + 1, "alltoallv_flat: need p+1 send offsets");
    assert_eq!(recv_offs.len(), p + 1, "alltoallv_flat: need p+1 recv offsets");
    assert_eq!(send_len, send_offs[p], "alltoallv_flat: send buffer length");
    assert_eq!(recv_len, recv_offs[p], "alltoallv_flat: recv buffer length");
}

/// Flat-buffer alltoallv over complex elements — the allocation-free
/// primitive the plans drive from their precomputed communication
/// schedules, using the **overlapped** windowed pipeline with default
/// tuning.
///
/// `send[send_offs[j]..send_offs[j + 1]]` goes to rank `j`; the block from
/// rank `q` lands in `recv[recv_offs[q]..recv_offs[q + 1]]`. Both offset
/// tables are plan-time constants (`len == p + 1`, prefix sums of the
/// block extents).
pub fn alltoallv_complex_flat(
    comm: &Comm,
    send: &[Complex],
    send_offs: &[usize],
    recv: &mut [Complex],
    recv_offs: &[usize],
) {
    let _ = alltoallv_complex_flat_tuned(
        comm,
        send,
        send_offs,
        recv,
        recv_offs,
        CommTuning::default(),
    );
}

/// [`alltoallv_complex_flat`] with explicit [`CommTuning`], returning the
/// overlap counters. Results are bit-identical for every window size: the
/// window changes only *when* blocks move, never where they land.
pub fn alltoallv_complex_flat_tuned(
    comm: &Comm,
    send: &[Complex],
    send_offs: &[usize],
    recv: &mut [Complex],
    recv_offs: &[usize],
    tuning: CommTuning,
) -> A2aCounters {
    validate_flat(comm, send.len(), send_offs, recv.len(), recv_offs);
    exchange_flat(
        comm,
        complex::as_bytes(send),
        complex::as_bytes_mut(recv),
        |j| send_offs[j] * ELEM,
        |j| recv_offs[j] * ELEM,
        tuning,
    )
}

/// The fully serial reference schedule: in round `s`, send block `s` and
/// block on its receive before round `s + 1` begins. Kept as the baseline
/// the overlapped pipeline is benchmarked (and bit-compared) against.
pub fn alltoallv_complex_flat_serial(
    comm: &Comm,
    send: &[Complex],
    send_offs: &[usize],
    recv: &mut [Complex],
    recv_offs: &[usize],
) -> A2aCounters {
    validate_flat(comm, send.len(), send_offs, recv.len(), recv_offs);
    let p = comm.size();
    let me = comm.rank();
    let mut c = A2aCounters::default();

    let self_send = &send[send_offs[me]..send_offs[me + 1]];
    assert_eq!(
        self_send.len(),
        recv_offs[me + 1] - recv_offs[me],
        "alltoallv_flat: self block extents disagree"
    );
    recv[recv_offs[me]..recv_offs[me + 1]].copy_from_slice(self_send);

    // Posting the send before the recv keeps the schedule deadlock-free on
    // the buffered mailboxes.
    for s in 1..p {
        let to = (me + s) % p;
        let from = (me + p - s) % p;
        let _ = comm.isend_coll(
            to,
            T_A2A,
            complex::as_bytes(&send[send_offs[to]..send_offs[to + 1]]),
        );
        let t0 = Instant::now();
        let bytes = comm.recv_coll(from, T_A2A);
        c.wait_ns += t0.elapsed().as_nanos() as u64;
        let dst = &mut recv[recv_offs[from]..recv_offs[from + 1]];
        assert_eq!(
            bytes.len(),
            std::mem::size_of_val(dst),
            "alltoallv_flat: peer {from} sent a block of the wrong size"
        );
        complex::copy_from_bytes(&bytes, dst);
    }
    c
}

/// Exchange variable-size byte blocks: `send[j]` goes to rank `j`; returns
/// `recv` where `recv[j]` came from rank `j`.
///
/// This is the boundary-friendly nested-`Vec` API (each block's storage
/// travels as its own wire buffer, zero-copy in both directions); the hot
/// paths use the flat variants above.
pub fn alltoallv(comm: &Comm, send: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
    let p = comm.size();
    assert_eq!(send.len(), p, "alltoallv: need one block per rank");
    let me = comm.rank();
    let mut recv: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();

    let mut send = send;
    // Self-block first.
    recv[me] = std::mem::take(&mut send[me]);

    // Pairwise exchange: in round s, talk to (me + s) % p / (me - s) % p.
    // Posting the send before the recv keeps the schedule deadlock-free on
    // the buffered mailboxes.
    let arena = comm.arena().clone();
    for s in 1..p {
        let to = (me + s) % p;
        let from = (me + p - s) % p;
        comm.send_coll_buf(to, T_A2A, arena.adopt(std::mem::take(&mut send[to])));
        recv[from] = comm.recv_coll(from, T_A2A).into_vec();
    }
    recv
}

/// Typed alltoallv over complex blocks.
pub fn alltoallv_complex(comm: &Comm, send: Vec<Vec<Complex>>) -> Vec<Vec<Complex>> {
    let bytes: Vec<Vec<u8>> = send.iter().map(|b| complex::as_bytes(b).to_vec()).collect();
    alltoallv(comm, bytes).into_iter().map(|b| complex::from_bytes(&b)).collect()
}

/// Regular alltoall: every block has the same `block` length in bytes.
/// Routed through the flat windowed engine — no per-rank nested vectors.
pub fn alltoall(comm: &Comm, send: &[u8], block: usize) -> Vec<u8> {
    let p = comm.size();
    assert_eq!(send.len(), block * p, "alltoall: send must be block*p bytes");
    let mut out = vec![0u8; block * p];
    let _ = alltoall_into(comm, send, block, &mut out, CommTuning::default());
    out
}

/// [`alltoall`] into a caller-provided buffer with explicit tuning — the
/// fully allocation-free regular exchange.
pub fn alltoall_into(
    comm: &Comm,
    send: &[u8],
    block: usize,
    recv: &mut [u8],
    tuning: CommTuning,
) -> A2aCounters {
    let p = comm.size();
    assert_eq!(send.len(), block * p, "alltoall: send must be block*p bytes");
    assert_eq!(recv.len(), block * p, "alltoall: recv must be block*p bytes");
    exchange_flat(comm, send, recv, |j| j * block, |j| j * block, tuning)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::{run_world, run_world_with_stats};

    #[test]
    fn alltoallv_identity_pattern() {
        // Rank r sends [r, j] to rank j; so rank j receives [r, j] from r.
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let send: Vec<Vec<u8>> =
                (0..p).map(|j| vec![comm.rank() as u8, j as u8]).collect();
            alltoallv(&comm, send)
        });
        for (j, recv) in outs.iter().enumerate() {
            for (r, b) in recv.iter().enumerate() {
                assert_eq!(b, &vec![r as u8, j as u8]);
            }
        }
    }

    #[test]
    fn alltoallv_variable_sizes() {
        let outs = run_world(3, |comm| {
            let p = comm.size();
            // Block to rank j has length r + 2*j.
            let send: Vec<Vec<u8>> =
                (0..p).map(|j| vec![9u8; comm.rank() + 2 * j]).collect();
            alltoallv(&comm, send)
        });
        for (j, recv) in outs.iter().enumerate() {
            for (r, b) in recv.iter().enumerate() {
                assert_eq!(b.len(), r + 2 * j);
            }
        }
    }

    #[test]
    fn alltoall_regular() {
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let send: Vec<u8> =
                (0..p).flat_map(|j| vec![(10 * comm.rank() + j) as u8; 2]).collect();
            alltoall(&comm, &send, 2)
        });
        for (j, recv) in outs.iter().enumerate() {
            for r in 0..4 {
                assert_eq!(recv[2 * r], (10 * r + j) as u8);
            }
        }
    }

    #[test]
    fn alltoall_into_is_allocation_recycled() {
        // Once the arena holds as many buffers as the peak concurrent
        // demand (window sends in flight per rank, plus barrier traffic),
        // repeated exchanges mint no new wire buffers.
        run_world(4, |comm| {
            let p = comm.size();
            let block = 256usize;
            // Pre-warm: hold peak-demand buffers simultaneously on every
            // rank so the free lists deterministically cover the loop below.
            let held: Vec<_> = (0..2)
                .map(|_| comm.arena().checkout(block))
                .chain((0..4).map(|_| comm.arena().checkout(1)))
                .collect();
            crate::comm::collectives::barrier(&comm);
            drop(held);
            crate::comm::collectives::barrier(&comm);

            let send = vec![comm.rank() as u8; block * p];
            let mut recv = vec![0u8; block * p];
            let (minted_before, _) = comm.arena().stats();
            for _ in 0..5 {
                let _ = alltoall_into(&comm, &send, block, &mut recv, CommTuning::default());
            }
            crate::comm::collectives::barrier(&comm);
            let (minted_after, _) = comm.arena().stats();
            assert_eq!(
                minted_before, minted_after,
                "steady-state exchanges must reuse arena buffers"
            );
        });
    }

    #[test]
    fn traffic_accounting_excludes_self() {
        let p = 4usize;
        let block = 64usize;
        let (_, (msgs, bytes)) = run_world_with_stats(p, |comm| {
            let send: Vec<Vec<u8>> = (0..comm.size()).map(|_| vec![0u8; block]).collect();
            alltoallv(&comm, send);
        });
        // Each rank sends p-1 remote blocks.
        assert_eq!(msgs as usize, p * (p - 1));
        assert_eq!(bytes as usize, p * (p - 1) * block);
    }

    #[test]
    fn flat_alltoall_matches_nested() {
        use crate::fft::complex::{Complex, ZERO};
        // Variable block sizes: rank r sends r + j + 1 elements to rank j.
        let p = 3usize;
        let outs = run_world(p, |comm| {
            let me = comm.rank();
            let blocks: Vec<Vec<Complex>> = (0..p)
                .map(|j| {
                    (0..me + j + 1)
                        .map(|k| Complex::new((10 * me + j) as f64, k as f64))
                        .collect()
                })
                .collect();
            // Nested reference.
            let want = alltoallv_complex(&comm, blocks.clone());

            // Flat path with precomputed offsets.
            let mut send_offs = vec![0usize];
            let mut send = Vec::new();
            for b in &blocks {
                send.extend_from_slice(b);
                send_offs.push(send.len());
            }
            // Block arriving from rank q has q + me + 1 elements.
            let mut recv_offs = vec![0usize];
            for q in 0..p {
                recv_offs.push(recv_offs[q] + q + me + 1);
            }
            let mut recv = vec![ZERO; *recv_offs.last().unwrap()];
            alltoallv_complex_flat(&comm, &send, &send_offs, &mut recv, &recv_offs);

            let flat_as_blocks: Vec<Vec<Complex>> = (0..p)
                .map(|q| recv[recv_offs[q]..recv_offs[q + 1]].to_vec())
                .collect();
            (want, flat_as_blocks)
        });
        for (want, got) in outs {
            assert_eq!(want, got);
        }
    }

    // Serial-vs-windowed bit-identity (incl. empty blocks, non-pow2
    // worlds, overlap-counter invariants) is covered end-to-end by
    // `tests/overlapped_exchange.rs`.

    /// The threaded (worker) flat exchange must be bit-identical to the
    /// single-threaded one for every window, including uneven block sizes
    /// and a non-power-of-two world. The perturbed-seed matrix lives in
    /// `tests/comm_schedules.rs`; this is the direct unit-level pin.
    #[test]
    fn worker_flat_exchange_is_bit_identical() {
        use crate::fft::complex::{Complex, ZERO};
        let p = 3usize;
        for w in [1usize, 2] {
            let outs = run_world(p, move |comm| {
                let me = comm.rank();
                // Block to rank j carries me + 2j + 1 elements.
                let mut send_offs = vec![0usize];
                let mut send: Vec<Complex> = Vec::new();
                for j in 0..p {
                    for k in 0..(me + 2 * j + 1) {
                        send.push(Complex::new((me * 7 + j) as f64, k as f64 + 0.5));
                    }
                    send_offs.push(send.len());
                }
                let mut recv_offs = vec![0usize];
                for q in 0..p {
                    recv_offs.push(recv_offs[q] + q + 2 * me + 1);
                }
                let mut base = vec![ZERO; *recv_offs.last().unwrap()];
                let _ = alltoallv_complex_flat_tuned(
                    &comm,
                    &send,
                    &send_offs,
                    &mut base,
                    &recv_offs,
                    CommTuning::with_window(w),
                );
                let mut got = vec![ZERO; base.len()];
                let c = alltoallv_complex_flat_tuned(
                    &comm,
                    &send,
                    &send_offs,
                    &mut got,
                    &recv_offs,
                    CommTuning::with_window(w).with_worker(true),
                );
                // The helper's busy time is exactly its pack + unpack time,
                // and every remote round overlapped the waits.
                assert_eq!(c.worker_busy_ns, c.pack_overlap_ns + c.unpack_overlap_ns);
                assert_eq!(c.overlap_rounds, (p - 1) as u64);
                (base, got)
            });
            for (want, got) in outs {
                assert_eq!(want.len(), got.len());
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(
                        (a.re.to_bits(), a.im.to_bits()),
                        (b.re.to_bits(), b.im.to_bits()),
                        "worker exchange diverged at window {w}"
                    );
                }
            }
        }
    }

    /// Single-rank worlds short-circuit the threaded engine: the self
    /// block is the caller's job and no helper is spawned.
    #[test]
    fn worker_single_rank_is_trivial() {
        run_world(1, |comm| {
            let send = [5u8; 16];
            let mut recv = [0u8; 16];
            let c = alltoall_into(
                &comm,
                &send,
                16,
                &mut recv,
                CommTuning::default().with_worker(true),
            );
            assert_eq!(recv, send);
            assert_eq!(c.worker_busy_ns, 0);
        });
    }

    #[test]
    fn complex_alltoall_round_values() {
        use crate::fft::complex::Complex;
        let outs = run_world(2, |comm| {
            let send: Vec<Vec<Complex>> = (0..2)
                .map(|j| vec![Complex::new(comm.rank() as f64, j as f64)])
                .collect();
            alltoallv_complex(&comm, send)
        });
        assert_eq!(outs[0][1][0], crate::fft::complex::Complex::new(1.0, 0.0));
        assert_eq!(outs[1][0][0], crate::fft::complex::Complex::new(0.0, 1.0));
    }
}
