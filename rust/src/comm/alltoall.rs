//! All-to-all exchanges — the data-movement primitive of every distributed
//! FFT stage (paper §3.1: "typically, Fourier transforms required alltoall
//! MPI collectives").
//!
//! `alltoallv` here uses the pairwise-exchange schedule (`p-1` rounds,
//! partner `rank XOR round` generalized to non-powers of two), matching what
//! Cray MPICH does for large messages; the message/byte counts it produces
//! are what `crate::model::netmodel` prices. Self-blocks never touch the
//! mailboxes.

use super::communicator::Comm;
use crate::fft::complex::{self, Complex};

const T_A2A: u64 = 0x20;

/// Exchange variable-size byte blocks: `send[j]` goes to rank `j`; returns
/// `recv` where `recv[j]` came from rank `j`.
pub fn alltoallv(comm: &Comm, send: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
    let p = comm.size();
    assert_eq!(send.len(), p, "alltoallv: need one block per rank");
    let me = comm.rank();
    let mut recv: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();

    let mut send = send;
    // Self-block first.
    recv[me] = std::mem::take(&mut send[me]);

    // Pairwise exchange: in round s, talk to (me + s) % p / (me - s) % p.
    // Posting the send before the recv keeps the schedule deadlock-free on
    // the buffered mailboxes.
    for s in 1..p {
        let to = (me + s) % p;
        let from = (me + p - s) % p;
        comm.send_coll(to, T_A2A, std::mem::take(&mut send[to]));
        recv[from] = comm.recv_coll(from, T_A2A);
    }
    recv
}

/// Typed alltoallv over complex blocks.
pub fn alltoallv_complex(comm: &Comm, send: Vec<Vec<Complex>>) -> Vec<Vec<Complex>> {
    let bytes: Vec<Vec<u8>> = send.iter().map(|b| complex::as_bytes(b).to_vec()).collect();
    alltoallv(comm, bytes).into_iter().map(|b| complex::from_bytes(&b)).collect()
}

/// Flat-buffer alltoallv over complex elements — the allocation-free variant
/// the plans drive from their precomputed communication schedules.
///
/// `send[send_offs[j]..send_offs[j + 1]]` goes to rank `j`; the block from
/// rank `q` lands in `recv[recv_offs[q]..recv_offs[q + 1]]`. Both offset
/// tables are plan-time constants (`len == p + 1`, prefix sums of the block
/// extents), so the only per-call heap traffic is the wire copy through the
/// mailboxes — the in-process stand-in for the NIC buffers.
pub fn alltoallv_complex_flat(
    comm: &Comm,
    send: &[Complex],
    send_offs: &[usize],
    recv: &mut [Complex],
    recv_offs: &[usize],
) {
    let p = comm.size();
    let me = comm.rank();
    assert_eq!(send_offs.len(), p + 1, "alltoallv_flat: need p+1 send offsets");
    assert_eq!(recv_offs.len(), p + 1, "alltoallv_flat: need p+1 recv offsets");
    assert_eq!(send.len(), send_offs[p], "alltoallv_flat: send buffer length");
    assert_eq!(recv.len(), recv_offs[p], "alltoallv_flat: recv buffer length");

    // Self block: straight copy, never touches the mailboxes.
    let self_send = &send[send_offs[me]..send_offs[me + 1]];
    let self_recv = &mut recv[recv_offs[me]..recv_offs[me + 1]];
    assert_eq!(
        self_send.len(),
        self_recv.len(),
        "alltoallv_flat: self block extents disagree"
    );
    self_recv.copy_from_slice(self_send);

    // Pairwise exchange, same deadlock-free schedule as `alltoallv`.
    for s in 1..p {
        let to = (me + s) % p;
        let from = (me + p - s) % p;
        comm.send_coll(
            to,
            T_A2A,
            complex::as_bytes(&send[send_offs[to]..send_offs[to + 1]]).to_vec(),
        );
        let bytes = comm.recv_coll(from, T_A2A);
        let dst = &mut recv[recv_offs[from]..recv_offs[from + 1]];
        assert_eq!(
            bytes.len(),
            std::mem::size_of_val(dst),
            "alltoallv_flat: peer {from} sent a block of the wrong size"
        );
        complex::copy_from_bytes(&bytes, dst);
    }
}

/// Regular alltoall: every block has the same `block` length in bytes.
pub fn alltoall(comm: &Comm, send: &[u8], block: usize) -> Vec<u8> {
    let p = comm.size();
    assert_eq!(send.len(), block * p, "alltoall: send must be block*p bytes");
    let blocks: Vec<Vec<u8>> =
        (0..p).map(|j| send[j * block..(j + 1) * block].to_vec()).collect();
    let recv = alltoallv(comm, blocks);
    let mut out = Vec::with_capacity(block * p);
    for b in recv {
        assert_eq!(b.len(), block, "alltoall: peer sent wrong block size");
        out.extend_from_slice(&b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::{run_world, run_world_with_stats};

    #[test]
    fn alltoallv_identity_pattern() {
        // Rank r sends [r, j] to rank j; so rank j receives [r, j] from r.
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let send: Vec<Vec<u8>> =
                (0..p).map(|j| vec![comm.rank() as u8, j as u8]).collect();
            alltoallv(&comm, send)
        });
        for (j, recv) in outs.iter().enumerate() {
            for (r, b) in recv.iter().enumerate() {
                assert_eq!(b, &vec![r as u8, j as u8]);
            }
        }
    }

    #[test]
    fn alltoallv_variable_sizes() {
        let outs = run_world(3, |comm| {
            let p = comm.size();
            // Block to rank j has length r + 2*j.
            let send: Vec<Vec<u8>> =
                (0..p).map(|j| vec![9u8; comm.rank() + 2 * j]).collect();
            alltoallv(&comm, send)
        });
        for (j, recv) in outs.iter().enumerate() {
            for (r, b) in recv.iter().enumerate() {
                assert_eq!(b.len(), r + 2 * j);
            }
        }
    }

    #[test]
    fn alltoall_regular() {
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let send: Vec<u8> = (0..p).flat_map(|j| vec![(10 * comm.rank() + j) as u8; 2]).collect();
            alltoall(&comm, &send, 2)
        });
        for (j, recv) in outs.iter().enumerate() {
            for r in 0..4 {
                assert_eq!(recv[2 * r], (10 * r + j) as u8);
            }
        }
    }

    #[test]
    fn traffic_accounting_excludes_self() {
        let p = 4usize;
        let block = 64usize;
        let (_, (msgs, bytes)) = run_world_with_stats(p, |comm| {
            let send: Vec<Vec<u8>> = (0..comm.size()).map(|_| vec![0u8; block]).collect();
            alltoallv(&comm, send);
        });
        // Each rank sends p-1 remote blocks.
        assert_eq!(msgs as usize, p * (p - 1));
        assert_eq!(bytes as usize, p * (p - 1) * block);
    }

    #[test]
    fn flat_alltoall_matches_nested() {
        use crate::fft::complex::{Complex, ZERO};
        // Variable block sizes: rank r sends r + j + 1 elements to rank j.
        let p = 3usize;
        let outs = run_world(p, |comm| {
            let me = comm.rank();
            let blocks: Vec<Vec<Complex>> = (0..p)
                .map(|j| {
                    (0..me + j + 1)
                        .map(|k| Complex::new((10 * me + j) as f64, k as f64))
                        .collect()
                })
                .collect();
            // Nested reference.
            let want = alltoallv_complex(&comm, blocks.clone());

            // Flat path with precomputed offsets.
            let mut send_offs = vec![0usize];
            let mut send = Vec::new();
            for b in &blocks {
                send.extend_from_slice(b);
                send_offs.push(send.len());
            }
            // Block arriving from rank q has q + me + 1 elements.
            let mut recv_offs = vec![0usize];
            for q in 0..p {
                recv_offs.push(recv_offs[q] + q + me + 1);
            }
            let mut recv = vec![ZERO; *recv_offs.last().unwrap()];
            alltoallv_complex_flat(&comm, &send, &send_offs, &mut recv, &recv_offs);

            let flat_as_blocks: Vec<Vec<Complex>> = (0..p)
                .map(|q| recv[recv_offs[q]..recv_offs[q + 1]].to_vec())
                .collect();
            (want, flat_as_blocks)
        });
        for (want, got) in outs {
            assert_eq!(want, got);
        }
    }

    #[test]
    fn complex_alltoall_round_values() {
        use crate::fft::complex::Complex;
        let outs = run_world(2, |comm| {
            let send: Vec<Vec<Complex>> = (0..2)
                .map(|j| vec![Complex::new(comm.rank() as f64, j as f64)])
                .collect();
            alltoallv_complex(&comm, send)
        });
        assert_eq!(outs[0][1][0], crate::fft::complex::Complex::new(1.0, 0.0));
        assert_eq!(outs[1][0][0], crate::fft::complex::Complex::new(0.0, 1.0));
    }
}
