//! In-process MPI-like communicator.
//!
//! The paper runs on MPI ranks across Perlmutter nodes; here every rank is a
//! thread in one process, and messages move through [`Mailbox`]es. The API
//! mirrors the MPI subset FFTB needs: point-to-point send/recv, communicator
//! `split` (for the row/column communicators of 2D processing grids), and
//! the collectives in [`super::collectives`] / [`super::alltoall`].
//!
//! Byte and message counters ([`CommStats`]) record exactly what crosses the
//! "wire"; the performance model (`crate::model`) converts those counts into
//! projected times on a real interconnect.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::mailbox::Mailbox;
use crate::fft::complex::{self, Complex};

/// Traffic counters, shared by every communicator derived from one world.
#[derive(Default)]
pub struct CommStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl CommStats {
    pub fn record(&self, bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.messages.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }
}

/// Shared state of a world of `p` ranks.
pub struct WorldShared {
    mailboxes: Vec<Arc<Mailbox>>,
    next_context: AtomicU64,
    pub stats: Arc<CommStats>,
}

impl WorldShared {
    pub fn new(p: usize) -> Arc<Self> {
        Arc::new(WorldShared {
            mailboxes: (0..p).map(|_| Mailbox::new()).collect(),
            // context 0 is the world communicator.
            next_context: AtomicU64::new(1),
            stats: Arc::new(CommStats::default()),
        })
    }

    pub fn size(&self) -> usize {
        self.mailboxes.len()
    }

    fn alloc_contexts(&self, n: u64) -> u64 {
        self.next_context.fetch_add(n, Ordering::SeqCst)
    }
}

/// A communicator: an ordered group of world ranks plus a context id.
#[derive(Clone)]
pub struct Comm {
    shared: Arc<WorldShared>,
    /// `ranks[i]` = world rank of communicator rank `i`.
    ranks: Arc<Vec<usize>>,
    /// This thread's rank within the communicator.
    rank: usize,
    /// My world rank (== ranks[rank]).
    world_rank: usize,
    context: u64,
}

/// Reserved tag space for collectives (user tags must stay below this).
pub const COLL_TAG_BASE: u64 = 1 << 60;

impl Comm {
    /// World communicator handle for `world_rank`.
    pub fn world(shared: Arc<WorldShared>, world_rank: usize) -> Self {
        let p = shared.size();
        Comm {
            shared,
            ranks: Arc::new((0..p).collect()),
            rank: world_rank,
            world_rank,
            context: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    pub fn world_rank(&self) -> usize {
        self.world_rank
    }

    pub fn stats(&self) -> Arc<CommStats> {
        Arc::clone(&self.shared.stats)
    }

    /// Send `bytes` to communicator rank `dst` with `tag`.
    ///
    /// Self-sends are allowed (buffered through the mailbox like MPI's
    /// eager protocol).
    pub fn send(&self, dst: usize, tag: u64, payload: Vec<u8>) {
        assert!(dst < self.size(), "send: dst {dst} out of range (size {})", self.size());
        assert!(tag < COLL_TAG_BASE, "user tag collides with collective tag space");
        self.send_raw(dst, tag, payload);
    }

    fn send_raw(&self, dst: usize, tag: u64, payload: Vec<u8>) {
        let world_dst = self.ranks[dst];
        if world_dst != self.world_rank {
            self.shared.stats.record(payload.len());
        }
        self.shared.mailboxes[world_dst].post((self.world_rank, self.context, tag), payload);
    }

    /// Blocking receive from communicator rank `src` with `tag`.
    pub fn recv(&self, src: usize, tag: u64) -> Vec<u8> {
        assert!(src < self.size(), "recv: src {src} out of range");
        assert!(tag < COLL_TAG_BASE, "user tag collides with collective tag space");
        self.recv_raw(src, tag)
    }

    fn recv_raw(&self, src: usize, tag: u64) -> Vec<u8> {
        let world_src = self.ranks[src];
        self.shared.mailboxes[self.world_rank].take((world_src, self.context, tag))
    }

    /// Internal send/recv with collective-reserved tags.
    pub(crate) fn send_coll(&self, dst: usize, tag: u64, payload: Vec<u8>) {
        self.send_raw(dst, COLL_TAG_BASE + tag, payload);
    }

    pub(crate) fn recv_coll(&self, src: usize, tag: u64) -> Vec<u8> {
        self.recv_raw(src, COLL_TAG_BASE + tag)
    }

    /// Typed convenience: send a complex slice (copied).
    pub fn send_complex(&self, dst: usize, tag: u64, data: &[Complex]) {
        self.send(dst, tag, complex::as_bytes(data).to_vec());
    }

    /// Typed convenience: receive a complex vector.
    pub fn recv_complex(&self, src: usize, tag: u64) -> Vec<Complex> {
        complex::from_bytes(&self.recv(src, tag))
    }

    /// Collective: split into sub-communicators by `color`; ranks within a
    /// group are ordered by `(key, parent_rank)`. Mirrors `MPI_Comm_split`.
    pub fn split(&self, color: u64, key: u64) -> Comm {
        const T_GATHER: u64 = 0xC0;
        const T_SCATTER: u64 = 0xC1;
        let p = self.size();

        // Gather (color, key) at rank 0.
        if self.rank == 0 {
            let mut triples: Vec<(u64, u64, usize)> = vec![(color, key, 0)];
            for r in 1..p {
                let b = self.recv_coll(r, T_GATHER);
                let c = u64::from_le_bytes(b[0..8].try_into().unwrap());
                let k = u64::from_le_bytes(b[8..16].try_into().unwrap());
                triples.push((c, k, r));
            }
            // Group by color.
            let mut colors: Vec<u64> = triples.iter().map(|t| t.0).collect();
            colors.sort_unstable();
            colors.dedup();
            let base_ctx = self.shared.alloc_contexts(colors.len() as u64);

            // For each member: (context, group world-ranks, member new rank).
            let mut replies: Vec<Option<(u64, Vec<usize>, usize)>> = vec![None; p];
            for (ci, &c) in colors.iter().enumerate() {
                let mut members: Vec<(u64, usize)> = triples
                    .iter()
                    .filter(|t| t.0 == c)
                    .map(|t| (t.1, t.2))
                    .collect();
                members.sort_unstable();
                let group_world: Vec<usize> =
                    members.iter().map(|&(_, pr)| self.ranks[pr]).collect();
                for (new_rank, &(_, parent_rank)) in members.iter().enumerate() {
                    replies[parent_rank] =
                        Some((base_ctx + ci as u64, group_world.clone(), new_rank));
                }
            }
            // Scatter.
            let mut my_reply = None;
            for (r, rep) in replies.into_iter().enumerate() {
                let (ctx, group, new_rank) = rep.expect("every rank belongs to a group");
                if r == 0 {
                    my_reply = Some((ctx, group, new_rank));
                } else {
                    let mut buf = Vec::with_capacity(16 + 8 * group.len());
                    buf.extend_from_slice(&ctx.to_le_bytes());
                    buf.extend_from_slice(&(new_rank as u64).to_le_bytes());
                    for wr in &group {
                        buf.extend_from_slice(&(*wr as u64).to_le_bytes());
                    }
                    self.send_coll(r, T_SCATTER, buf);
                }
            }
            let (ctx, group, new_rank) = my_reply.unwrap();
            Comm {
                shared: Arc::clone(&self.shared),
                ranks: Arc::new(group),
                rank: new_rank,
                world_rank: self.world_rank,
                context: ctx,
            }
        } else {
            let mut buf = Vec::with_capacity(16);
            buf.extend_from_slice(&color.to_le_bytes());
            buf.extend_from_slice(&key.to_le_bytes());
            self.send_coll(0, T_GATHER, buf);
            let b = self.recv_coll(0, T_SCATTER);
            let ctx = u64::from_le_bytes(b[0..8].try_into().unwrap());
            let new_rank = u64::from_le_bytes(b[8..16].try_into().unwrap()) as usize;
            let group: Vec<usize> = b[16..]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect();
            Comm {
                shared: Arc::clone(&self.shared),
                ranks: Arc::new(group),
                rank: new_rank,
                world_rank: self.world_rank,
                context: ctx,
            }
        }
    }
}

/// Run `p` ranks as scoped threads; each gets the world communicator. The
/// closure's return values are collected in rank order.
pub fn run_world<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Comm) -> T + Send + Sync,
{
    assert!(p >= 1, "world needs at least one rank");
    let shared = WorldShared::new(p);
    let results: Vec<Mutex<Option<T>>> = (0..p).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for r in 0..p {
            let comm = Comm::world(Arc::clone(&shared), r);
            let f = &f;
            let slot = &results[r];
            scope.spawn(move || {
                let out = f(comm);
                *slot.lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("rank thread panicked before producing output"))
        .collect()
}

/// Like [`run_world`] but also returns the world traffic stats.
pub fn run_world_with_stats<T, F>(p: usize, f: F) -> (Vec<T>, (u64, u64))
where
    T: Send,
    F: Fn(Comm) -> T + Send + Sync,
{
    assert!(p >= 1);
    let shared = WorldShared::new(p);
    let stats = Arc::clone(&shared.stats);
    let results: Vec<Mutex<Option<T>>> = (0..p).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for r in 0..p {
            let comm = Comm::world(Arc::clone(&shared), r);
            let f = &f;
            let slot = &results[r];
            scope.spawn(move || {
                let out = f(comm);
                *slot.lock().unwrap() = Some(out);
            });
        }
    });
    let outs = results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("rank thread panicked"))
        .collect();
    (outs, stats.snapshot())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_send_recv() {
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let next = (comm.rank() + 1) % p;
            let prev = (comm.rank() + p - 1) % p;
            comm.send(next, 1, vec![comm.rank() as u8]);
            let got = comm.recv(prev, 1);
            got[0] as usize
        });
        assert_eq!(outs, vec![3, 0, 1, 2]);
    }

    #[test]
    fn self_send_is_buffered() {
        let outs = run_world(2, |comm| {
            comm.send(comm.rank(), 5, vec![7, 8]);
            comm.recv(comm.rank(), 5)
        });
        assert_eq!(outs[0], vec![7, 8]);
    }

    #[test]
    fn split_rows_and_cols() {
        // 2x3 grid: color by row, key by col.
        let outs = run_world(6, |comm| {
            let row = comm.rank() / 3;
            let col = comm.rank() % 3;
            let row_comm = comm.split(row as u64, col as u64);
            let col_comm = comm.split(col as u64, row as u64);
            // Exchange within row: sum of cols = 0+1+2 = 3.
            row_comm.send((row_comm.rank() + 1) % 3, 2, vec![col as u8]);
            let left = row_comm.recv((row_comm.rank() + 2) % 3, 2)[0];
            (row_comm.size(), col_comm.size(), row_comm.rank(), col_comm.rank(), left)
        });
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o.0, 3, "row comm size");
            assert_eq!(o.1, 2, "col comm size");
            assert_eq!(o.2, r % 3, "row rank = col index");
            assert_eq!(o.3, r / 3, "col rank = row index");
            assert_eq!(o.4 as usize, (r % 3 + 2) % 3, "left neighbour's col");
        }
    }

    #[test]
    fn stats_count_remote_bytes_only() {
        let (_, (msgs, bytes)) = run_world_with_stats(2, |comm| {
            if comm.rank() == 0 {
                comm.send(1, 0, vec![0u8; 100]);
                comm.send(0, 1, vec![0u8; 50]); // self: not counted
                comm.recv(0, 1);
            } else {
                comm.recv(0, 0);
            }
        });
        assert_eq!(msgs, 1);
        assert_eq!(bytes, 100);
    }

    #[test]
    fn complex_round_trip_via_comm() {
        use crate::fft::complex::Complex;
        let outs = run_world(2, |comm| {
            if comm.rank() == 0 {
                comm.send_complex(1, 3, &[Complex::new(1.5, -0.5)]);
                Vec::new()
            } else {
                comm.recv_complex(0, 3)
            }
        });
        assert_eq!(outs[1], vec![crate::fft::complex::Complex::new(1.5, -0.5)]);
    }
}
