//! In-process MPI-like communicator.
//!
//! The paper runs on MPI ranks across Perlmutter nodes; here every rank is a
//! thread in one process, and messages move through [`Mailbox`]es backed by
//! a world-shared [`BufferArena`]. The API mirrors the MPI subset FFTB
//! needs: blocking and nonblocking point-to-point ([`Comm::send`],
//! [`Comm::isend`], [`Comm::irecv`], [`Request`], [`waitall`]), communicator
//! `split` (for the row/column communicators of 2D processing grids), and
//! the collectives in [`super::collectives`] / [`super::alltoall`].
//!
//! Byte and message counters ([`CommStats`]) record exactly what crosses the
//! "wire"; the performance model (`crate::model`) converts those counts into
//! projected times on a real interconnect.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::arena::{BufferArena, WireBuf};
use super::mailbox::{Key, Mailbox};
use crate::fft::complex::{self, Complex};

/// Traffic counters, shared by every communicator derived from one world.
#[derive(Default)]
pub struct CommStats {
    /// Point-to-point messages sent to *other* ranks.
    pub messages: AtomicU64,
    /// Payload bytes sent to *other* ranks.
    pub bytes: AtomicU64,
}

impl CommStats {
    /// Record one remote message of `bytes` payload.
    pub fn record(&self, bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// `(messages, bytes)` sent so far.
    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }

    /// Zero both counters.
    pub fn reset(&self) {
        self.messages.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }
}

/// Shared state of a world of `p` ranks.
pub struct WorldShared {
    mailboxes: Vec<Arc<Mailbox>>,
    next_context: AtomicU64,
    arena: BufferArena,
    /// Wire traffic counters for the whole world.
    pub stats: Arc<CommStats>,
    /// `Some(seed)` arms schedule perturbation: mailbox delivery order and
    /// exchange wait order are scrambled deterministically from the seed
    /// (verification worlds only — see [`run_world_perturbed`]).
    schedule_seed: Option<u64>,
    /// Per-exchange ticket feeding distinct sub-seeds to consecutive
    /// perturbed exchanges on the same world.
    perturb_ticket: AtomicU64,
}

impl WorldShared {
    /// Create the shared state for a world of `p` ranks.
    pub fn new(p: usize) -> Arc<Self> {
        Self::with_perturbation(p, None)
    }

    /// [`WorldShared::new`] with an optional schedule-perturbation seed;
    /// `Some(seed)` arms the delivery policy of every rank's mailbox (each
    /// with a distinct sub-seed) and the wait-order shuffle in the fused
    /// exchange engine.
    pub fn with_perturbation(p: usize, seed: Option<u64>) -> Arc<Self> {
        let mailboxes: Vec<Arc<Mailbox>> = (0..p)
            .map(|r| {
                let mb = Mailbox::new();
                if let Some(s) = seed {
                    mb.set_policy(s ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(r as u64 + 1));
                }
                mb
            })
            .collect();
        Arc::new(WorldShared {
            mailboxes,
            // context 0 is the world communicator.
            next_context: AtomicU64::new(1),
            arena: BufferArena::new(),
            stats: Arc::new(CommStats::default()),
            schedule_seed: seed,
            perturb_ticket: AtomicU64::new(0),
        })
    }

    /// Number of ranks in the world.
    pub fn size(&self) -> usize {
        self.mailboxes.len()
    }

    fn alloc_contexts(&self, n: u64) -> u64 {
        // SeqCst: context ids must be globally unique *and* every rank of
        // the splitting group must agree on the id ordering; the single
        // total order is cheap here (splits are rare, plan-time-only) and
        // removes any reasoning burden when worker threads (ROADMAP item
        // 3) start splitting concurrently.
        self.next_context.fetch_add(n, Ordering::SeqCst)
    }
}

/// A communicator: an ordered group of world ranks plus a context id.
#[derive(Clone)]
pub struct Comm {
    shared: Arc<WorldShared>,
    /// `ranks[i]` = world rank of communicator rank `i`.
    ranks: Arc<Vec<usize>>,
    /// This thread's rank within the communicator.
    rank: usize,
    /// My world rank (== ranks[rank]).
    world_rank: usize,
    context: u64,
}

/// Reserved tag space for collectives (user tags must stay below this).
pub const COLL_TAG_BASE: u64 = 1 << 60;

/// Handle to a pending nonblocking operation (MPI's `MPI_Request`).
///
/// Sends complete eagerly at post time (the mailbox buffers them, like
/// MPI's eager protocol), so a send request is born complete. A receive
/// request completes when a matching message has arrived; consume it with
/// [`Request::wait`] or drive a batch with [`waitall`].
pub struct Request {
    inner: ReqInner,
}

enum ReqInner {
    Send,
    Recv { mailbox: Arc<Mailbox>, key: Key },
}

impl Request {
    fn send_done() -> Self {
        Request { inner: ReqInner::Send }
    }

    /// Nonblocking completion probe (MPI's `MPI_Test`, without consuming
    /// the message): `true` once [`Request::wait`] would return without
    /// blocking.
    pub fn test(&self) -> bool {
        match &self.inner {
            ReqInner::Send => true,
            ReqInner::Recv { mailbox, key } => mailbox.probe(*key),
        }
    }

    /// Block until the operation completes. Returns the received payload
    /// for receive requests and `None` for send requests.
    pub fn wait(self) -> Option<WireBuf> {
        match self.inner {
            ReqInner::Send => None,
            ReqInner::Recv { mailbox, key } => Some(mailbox.take(key)),
        }
    }
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            ReqInner::Send => write!(f, "Request::Send(complete)"),
            ReqInner::Recv { key, .. } => write!(f, "Request::Recv{key:?}"),
        }
    }
}

/// Wait for every request in order (MPI's `MPI_Waitall`); element `i` is the
/// payload of `reqs[i]` (receives) or `None` (sends).
pub fn waitall(reqs: Vec<Request>) -> Vec<Option<WireBuf>> {
    reqs.into_iter().map(|r| r.wait()).collect()
}

impl Comm {
    /// World communicator handle for `world_rank`.
    pub fn world(shared: Arc<WorldShared>, world_rank: usize) -> Self {
        let p = shared.size();
        Comm {
            shared,
            ranks: Arc::new((0..p).collect()),
            rank: world_rank,
            world_rank,
            context: 0,
        }
    }

    /// This thread's rank within the communicator.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in the communicator.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// This thread's rank in the world communicator.
    pub fn world_rank(&self) -> usize {
        self.world_rank
    }

    /// Opaque identity of the communication domain this handle addresses:
    /// world instance, split context, and member set. Handles obtained
    /// from the same world/split see the same value; handles of different
    /// worlds or different splits do not (while any plan built on a world
    /// is alive, its `WorldShared` allocation is pinned, so the pointer
    /// component cannot be reused). The tuner keys cached plans with this
    /// so a plan built for one communicator is never served to another
    /// same-sized one.
    pub fn identity(&self) -> u64 {
        use crate::util::fnv::fnv1a_word;
        let mut h = crate::util::fnv::FNV_OFFSET;
        h = fnv1a_word(h, Arc::as_ptr(&self.shared) as usize as u64);
        h = fnv1a_word(h, self.context);
        for &r in self.ranks.iter() {
            h = fnv1a_word(h, r as u64);
        }
        h
    }

    /// The world's wire traffic counters.
    pub fn stats(&self) -> Arc<CommStats> {
        Arc::clone(&self.shared.stats)
    }

    /// The world's shared wire-buffer arena.
    pub fn arena(&self) -> &BufferArena {
        &self.shared.arena
    }

    /// When this world is perturbation-armed: a seeded pseudo-random
    /// permutation of the exchange rounds `1..=rounds`, distinct per call
    /// site (ticketed), per rank, and per seed. `None` on normal worlds —
    /// the fused exchange engine keeps its windowed schedule.
    pub(crate) fn perturb_order(&self, rounds: usize) -> Option<Vec<usize>> {
        let seed = self.shared.schedule_seed?;
        // Relaxed (allowlisted): fetch_add atomicity alone makes tickets
        // distinct; nothing else is published through this counter.
        let ticket = self.shared.perturb_ticket.fetch_add(1, Ordering::Relaxed);
        let mut prng = crate::util::prng::Prng::new(
            seed ^ ticket.wrapping_mul(0x2545_F491_4F6C_DD1D)
                ^ ((self.world_rank as u64) << 32)
                ^ self.context,
        );
        let mut order: Vec<usize> = (1..=rounds).collect();
        for i in (1..order.len()).rev() {
            let j = prng.next_below(i + 1);
            order.swap(i, j);
        }
        Some(order)
    }

    /// Post a wire buffer to `dst`'s mailbox, recording remote traffic.
    fn post_buf(&self, dst: usize, tag: u64, payload: WireBuf) {
        let world_dst = self.ranks[dst];
        if world_dst != self.world_rank {
            self.shared.stats.record(payload.len());
        }
        self.shared.mailboxes[world_dst].post((self.world_rank, self.context, tag), payload);
    }

    /// Send `bytes` to communicator rank `dst` with `tag`.
    ///
    /// Self-sends are allowed (buffered through the mailbox like MPI's
    /// eager protocol). The vector's storage travels as the wire buffer
    /// (no copy); a matching [`Comm::recv`] hands that same storage back
    /// to the caller, while internal receivers that drop the buffer
    /// recycle it into the shared arena.
    pub fn send(&self, dst: usize, tag: u64, payload: Vec<u8>) {
        assert!(dst < self.size(), "send: dst {dst} out of range (size {})", self.size());
        assert!(tag < COLL_TAG_BASE, "user tag collides with collective tag space");
        self.post_buf(dst, tag, self.shared.arena.adopt(payload));
    }

    /// Nonblocking send of `payload` to `dst` with `tag` (MPI's
    /// `MPI_Isend`): the bytes are copied into a recycled arena buffer and
    /// posted immediately, so the returned [`Request`] is born complete.
    pub fn isend(&self, dst: usize, tag: u64, payload: &[u8]) -> Request {
        assert!(dst < self.size(), "isend: dst {dst} out of range (size {})", self.size());
        assert!(tag < COLL_TAG_BASE, "user tag collides with collective tag space");
        self.isend_raw(dst, tag, payload)
    }

    fn isend_raw(&self, dst: usize, tag: u64, payload: &[u8]) -> Request {
        let mut buf = self.shared.arena.checkout(payload.len());
        buf.extend_from_slice(payload);
        self.post_buf(dst, tag, buf);
        Request::send_done()
    }

    /// Nonblocking receive from `src` with `tag` (MPI's `MPI_Irecv`); the
    /// payload is produced by [`Request::wait`].
    pub fn irecv(&self, src: usize, tag: u64) -> Request {
        assert!(src < self.size(), "irecv: src {src} out of range");
        assert!(tag < COLL_TAG_BASE, "user tag collides with collective tag space");
        self.irecv_raw(src, tag)
    }

    fn irecv_raw(&self, src: usize, tag: u64) -> Request {
        let world_src = self.ranks[src];
        Request {
            inner: ReqInner::Recv {
                mailbox: Arc::clone(&self.shared.mailboxes[self.world_rank]),
                key: (world_src, self.context, tag),
            },
        }
    }

    /// Blocking receive from communicator rank `src` with `tag`.
    pub fn recv(&self, src: usize, tag: u64) -> Vec<u8> {
        assert!(src < self.size(), "recv: src {src} out of range");
        assert!(tag < COLL_TAG_BASE, "user tag collides with collective tag space");
        self.recv_buf(src, tag).into_vec()
    }

    fn recv_buf(&self, src: usize, tag: u64) -> WireBuf {
        let world_src = self.ranks[src];
        self.shared.mailboxes[self.world_rank].take((world_src, self.context, tag))
    }

    /// Internal send with a collective-reserved tag; copies into an arena
    /// buffer.
    pub(crate) fn send_coll(&self, dst: usize, tag: u64, payload: &[u8]) {
        let _ = self.isend_raw(dst, COLL_TAG_BASE + tag, payload);
    }

    /// Internal zero-copy send with a collective-reserved tag: the wire
    /// buffer is posted as-is.
    pub(crate) fn send_coll_buf(&self, dst: usize, tag: u64, payload: WireBuf) {
        self.post_buf(dst, COLL_TAG_BASE + tag, payload);
    }

    /// Internal blocking receive with a collective-reserved tag.
    pub(crate) fn recv_coll(&self, src: usize, tag: u64) -> WireBuf {
        self.recv_buf(src, COLL_TAG_BASE + tag)
    }

    /// Internal nonblocking send with a collective-reserved tag.
    pub(crate) fn isend_coll(&self, dst: usize, tag: u64, payload: &[u8]) -> Request {
        self.isend_raw(dst, COLL_TAG_BASE + tag, payload)
    }

    /// Internal nonblocking receive with a collective-reserved tag.
    pub(crate) fn irecv_coll(&self, src: usize, tag: u64) -> Request {
        self.irecv_raw(src, COLL_TAG_BASE + tag)
    }

    /// Typed convenience: send a complex slice (copied).
    pub fn send_complex(&self, dst: usize, tag: u64, data: &[Complex]) {
        self.send(dst, tag, complex::as_bytes(data).to_vec());
    }

    /// Typed convenience: receive a complex vector.
    pub fn recv_complex(&self, src: usize, tag: u64) -> Vec<Complex> {
        complex::from_bytes(&self.recv(src, tag))
    }

    /// Collective: split into sub-communicators by `color`; ranks within a
    /// group are ordered by `(key, parent_rank)`. Mirrors `MPI_Comm_split`.
    pub fn split(&self, color: u64, key: u64) -> Comm {
        const T_GATHER: u64 = 0xC0;
        const T_SCATTER: u64 = 0xC1;
        let p = self.size();

        // Gather (color, key) at rank 0.
        if self.rank == 0 {
            let mut triples: Vec<(u64, u64, usize)> = vec![(color, key, 0)];
            for r in 1..p {
                let b = self.recv_coll(r, T_GATHER);
                let c = u64::from_le_bytes(b[0..8].try_into().unwrap());
                let k = u64::from_le_bytes(b[8..16].try_into().unwrap());
                triples.push((c, k, r));
            }
            // Group by color.
            let mut colors: Vec<u64> = triples.iter().map(|t| t.0).collect();
            colors.sort_unstable();
            colors.dedup();
            let base_ctx = self.shared.alloc_contexts(colors.len() as u64);

            // For each member: (context, group world-ranks, member new rank).
            let mut replies: Vec<Option<(u64, Vec<usize>, usize)>> = vec![None; p];
            for (ci, &c) in colors.iter().enumerate() {
                let mut members: Vec<(u64, usize)> = triples
                    .iter()
                    .filter(|t| t.0 == c)
                    .map(|t| (t.1, t.2))
                    .collect();
                members.sort_unstable();
                let group_world: Vec<usize> =
                    members.iter().map(|&(_, pr)| self.ranks[pr]).collect();
                for (new_rank, &(_, parent_rank)) in members.iter().enumerate() {
                    replies[parent_rank] =
                        Some((base_ctx + ci as u64, group_world.clone(), new_rank));
                }
            }
            // Scatter.
            let mut my_reply = None;
            for (r, rep) in replies.into_iter().enumerate() {
                // pallas-lint: allow(no-panic) — every slot was filled by
                // the grouping loop above: each rank has exactly one color.
                let (ctx, group, new_rank) = rep.expect("every rank belongs to a group");
                if r == 0 {
                    my_reply = Some((ctx, group, new_rank));
                } else {
                    let mut buf = Vec::with_capacity(16 + 8 * group.len());
                    buf.extend_from_slice(&ctx.to_le_bytes());
                    buf.extend_from_slice(&(new_rank as u64).to_le_bytes());
                    for wr in &group {
                        buf.extend_from_slice(&(*wr as u64).to_le_bytes());
                    }
                    self.send_coll(r, T_SCATTER, &buf);
                }
            }
            // pallas-lint: allow(no-panic) — rank 0 set its own slot in
            // the scatter loop just above.
            let (ctx, group, new_rank) = my_reply.unwrap();
            Comm {
                shared: Arc::clone(&self.shared),
                ranks: Arc::new(group),
                rank: new_rank,
                world_rank: self.world_rank,
                context: ctx,
            }
        } else {
            let mut buf = Vec::with_capacity(16);
            buf.extend_from_slice(&color.to_le_bytes());
            buf.extend_from_slice(&key.to_le_bytes());
            self.send_coll(0, T_GATHER, &buf);
            let b = self.recv_coll(0, T_SCATTER);
            let ctx = u64::from_le_bytes(b[0..8].try_into().unwrap());
            let new_rank = u64::from_le_bytes(b[8..16].try_into().unwrap()) as usize;
            let group: Vec<usize> = b[16..]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect();
            Comm {
                shared: Arc::clone(&self.shared),
                ranks: Arc::new(group),
                rank: new_rank,
                world_rank: self.world_rank,
                context: ctx,
            }
        }
    }
}

/// Shared body of the `run_world*` entry points: spawn `p` rank threads
/// over `shared`, collect their return values in rank order.
fn run_world_on<T, F>(shared: Arc<WorldShared>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Comm) -> T + Send + Sync,
{
    let p = shared.size();
    let results: Vec<Mutex<Option<T>>> = (0..p).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for r in 0..p {
            let comm = Comm::world(Arc::clone(&shared), r);
            let f = &f;
            let slot = &results[r];
            scope.spawn(move || {
                let out = f(comm);
                *slot.lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        // pallas-lint: allow(no-panic) — a rank thread that panicked has
        // already torn the scope down; re-raising here is the only option.
        .map(|m| m.into_inner().unwrap().expect("rank thread panicked before producing output"))
        .collect()
}

/// Run `p` ranks as scoped threads; each gets the world communicator. The
/// closure's return values are collected in rank order.
pub fn run_world<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Comm) -> T + Send + Sync,
{
    assert!(p >= 1, "world needs at least one rank");
    run_world_on(WorldShared::new(p), f)
}

/// [`run_world`] on a schedule-perturbed world: mailbox delivery order and
/// fused-exchange wait order are scrambled deterministically from `seed`
/// (see the `comm::mailbox` module docs). Any correct SPMD program must
/// return bit-identical results under every seed — `tests/comm_schedules.rs`
/// pins that for the exchange engine and a full SCF iteration. A zero-dep
/// "loom-lite": it explores delivery interleavings TSan would need a lucky
/// schedule to hit, though (unlike loom) not exhaustively.
pub fn run_world_perturbed<T, F>(p: usize, seed: u64, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Comm) -> T + Send + Sync,
{
    assert!(p >= 1, "world needs at least one rank");
    run_world_on(WorldShared::with_perturbation(p, Some(seed)), f)
}

/// Like [`run_world`] but also returns the world traffic stats.
pub fn run_world_with_stats<T, F>(p: usize, f: F) -> (Vec<T>, (u64, u64))
where
    T: Send,
    F: Fn(Comm) -> T + Send + Sync,
{
    assert!(p >= 1);
    let shared = WorldShared::new(p);
    let stats = Arc::clone(&shared.stats);
    let outs = run_world_on(shared, f);
    (outs, stats.snapshot())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_send_recv() {
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let next = (comm.rank() + 1) % p;
            let prev = (comm.rank() + p - 1) % p;
            comm.send(next, 1, vec![comm.rank() as u8]);
            let got = comm.recv(prev, 1);
            got[0] as usize
        });
        assert_eq!(outs, vec![3, 0, 1, 2]);
    }

    #[test]
    fn self_send_is_buffered() {
        let outs = run_world(2, |comm| {
            comm.send(comm.rank(), 5, vec![7, 8]);
            comm.recv(comm.rank(), 5)
        });
        assert_eq!(outs[0], vec![7, 8]);
    }

    #[test]
    fn isend_irecv_ring() {
        let outs = run_world(4, |comm| {
            let p = comm.size();
            let next = (comm.rank() + 1) % p;
            let prev = (comm.rank() + p - 1) % p;
            // Post the receive first, then the send: the request completes
            // once the neighbour's isend lands.
            let rx = comm.irecv(prev, 9);
            let tx = comm.isend(next, 9, &[comm.rank() as u8, 0xAA]);
            assert!(tx.test(), "sends complete eagerly");
            assert!(tx.wait().is_none(), "send requests carry no payload");
            let buf = rx.wait().expect("receive requests carry the payload");
            (buf[0] as usize, buf[1])
        });
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o.0, (r + 3) % 4);
            assert_eq!(o.1, 0xAA);
        }
    }

    #[test]
    fn waitall_preserves_request_order() {
        let outs = run_world(3, |comm| {
            let p = comm.size();
            for dst in 0..p {
                let _ = comm.isend(dst, 2, &[comm.rank() as u8, dst as u8]);
            }
            let reqs: Vec<Request> = (0..p).map(|src| comm.irecv(src, 2)).collect();
            waitall(reqs)
                .into_iter()
                .map(|b| b.expect("all were receives").into_vec())
                .collect::<Vec<_>>()
        });
        for (me, bufs) in outs.iter().enumerate() {
            for (src, b) in bufs.iter().enumerate() {
                assert_eq!(b, &vec![src as u8, me as u8]);
            }
        }
    }

    #[test]
    fn request_test_tracks_arrival() {
        run_world(2, |comm| {
            if comm.rank() == 0 {
                let rx = comm.irecv(1, 4);
                // No ordering guarantee with rank 1 here, so only check the
                // final state transitions are coherent.
                let buf = rx.wait().unwrap();
                assert_eq!(&buf[..], &[5, 6, 7]);
                // A fresh request for an already-delivered channel is
                // complete immediately after the message is queued.
                let _ = comm.isend(0, 8, &[1]);
                let rx2 = comm.irecv(0, 8);
                assert!(rx2.test());
                rx2.wait();
            } else {
                let _ = comm.isend(0, 4, &[5, 6, 7]);
            }
        });
    }

    #[test]
    fn split_rows_and_cols() {
        // 2x3 grid: color by row, key by col.
        let outs = run_world(6, |comm| {
            let row = comm.rank() / 3;
            let col = comm.rank() % 3;
            let row_comm = comm.split(row as u64, col as u64);
            let col_comm = comm.split(col as u64, row as u64);
            // Exchange within row: sum of cols = 0+1+2 = 3.
            row_comm.send((row_comm.rank() + 1) % 3, 2, vec![col as u8]);
            let left = row_comm.recv((row_comm.rank() + 2) % 3, 2)[0];
            (row_comm.size(), col_comm.size(), row_comm.rank(), col_comm.rank(), left)
        });
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o.0, 3, "row comm size");
            assert_eq!(o.1, 2, "col comm size");
            assert_eq!(o.2, r % 3, "row rank = col index");
            assert_eq!(o.3, r / 3, "col rank = row index");
            assert_eq!(o.4 as usize, (r % 3 + 2) % 3, "left neighbour's col");
        }
    }

    #[test]
    fn stats_count_remote_bytes_only() {
        let (_, (msgs, bytes)) = run_world_with_stats(2, |comm| {
            if comm.rank() == 0 {
                comm.send(1, 0, vec![0u8; 100]);
                comm.send(0, 1, vec![0u8; 50]); // self: not counted
                comm.recv(0, 1);
            } else {
                comm.recv(0, 0);
            }
        });
        assert_eq!(msgs, 1);
        assert_eq!(bytes, 100);
    }

    #[test]
    fn complex_round_trip_via_comm() {
        use crate::fft::complex::Complex;
        let outs = run_world(2, |comm| {
            if comm.rank() == 0 {
                comm.send_complex(1, 3, &[Complex::new(1.5, -0.5)]);
                Vec::new()
            } else {
                comm.recv_complex(0, 3)
            }
        });
        assert_eq!(outs[1], vec![crate::fft::complex::Complex::new(1.5, -0.5)]);
    }

    #[test]
    fn identity_distinguishes_splits_and_agrees_within() {
        let outs = run_world(4, |comm| {
            let row = comm.rank() / 2;
            let sub = comm.split(row as u64, (comm.rank() % 2) as u64);
            (comm.identity(), sub.identity())
        });
        // Every rank agrees on the world's identity.
        assert!(outs.iter().all(|o| o.0 == outs[0].0));
        // Members of one split agree; different splits (and the world)
        // have different identities.
        assert_eq!(outs[0].1, outs[1].1);
        assert_eq!(outs[2].1, outs[3].1);
        assert_ne!(outs[0].1, outs[2].1);
        for o in &outs {
            assert_ne!(o.0, o.1, "a split must not collide with its world");
        }
    }
}
