//! Collectives over [`Comm`]: barrier, broadcast, gather/allgather,
//! reductions. Tree-based where it matters; these run on tens of in-process
//! ranks, so clarity beats micro-optimization — the *traffic* they generate
//! is what the performance model consumes.

use super::communicator::Comm;
use crate::fft::complex::{self, Complex};

const T_BARRIER_UP: u64 = 0x10;
const T_BARRIER_DOWN: u64 = 0x11;
const T_BCAST: u64 = 0x12;
const T_GATHER: u64 = 0x13;
const T_REDUCE: u64 = 0x14;

/// Synchronize all ranks (gather-to-0 + broadcast).
pub fn barrier(comm: &Comm) {
    let p = comm.size();
    if p == 1 {
        return;
    }
    if comm.rank() == 0 {
        for r in 1..p {
            comm.recv_coll(r, T_BARRIER_UP);
        }
        for r in 1..p {
            comm.send_coll(r, T_BARRIER_DOWN, &[]);
        }
    } else {
        comm.send_coll(0, T_BARRIER_UP, &[]);
        comm.recv_coll(0, T_BARRIER_DOWN);
    }
}

/// Broadcast `data` from `root` to every rank (binomial tree).
pub fn bcast(comm: &Comm, root: usize, data: &mut Vec<u8>) {
    let p = comm.size();
    if p == 1 {
        return;
    }
    // Shift ranks so root is virtual rank 0.
    let vrank = (comm.rank() + p - root) % p;
    let mut mask = 1usize;
    // Receive phase: find parent.
    while mask < p {
        if vrank & mask != 0 {
            let parent = (vrank - mask + root) % p;
            let b = comm.recv_coll(parent, T_BCAST);
            data.clear();
            data.extend_from_slice(&b);
            break;
        }
        mask <<= 1;
    }
    // Send phase: forward to children below the found bit.
    mask >>= 1;
    while mask > 0 {
        if vrank + mask < p {
            let child = (vrank + mask + root) % p;
            comm.send_coll(child, T_BCAST, data.as_slice());
        }
        mask >>= 1;
    }
}

/// Gather variable-size byte blocks at `root`; returns `Some(blocks)` there.
pub fn gatherv(comm: &Comm, root: usize, mine: &[u8]) -> Option<Vec<Vec<u8>>> {
    let p = comm.size();
    if comm.rank() == root {
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); p];
        out[root] = mine.to_vec();
        for r in 0..p {
            if r != root {
                out[r] = comm.recv_coll(r, T_GATHER).into_vec();
            }
        }
        Some(out)
    } else {
        comm.send_coll(root, T_GATHER, mine);
        None
    }
}

/// All-gather variable-size byte blocks (gather at 0 + bcast of the packed
/// blocks).
pub fn allgatherv(comm: &Comm, mine: &[u8]) -> Vec<Vec<u8>> {
    let p = comm.size();
    if p == 1 {
        return vec![mine.to_vec()];
    }
    let gathered = gatherv(comm, 0, mine);
    // Pack: [count, len_0.., bytes_0..]
    let mut packed = Vec::new();
    // `gatherv` returns `Some` exactly at the root, so this branch is the
    // rank-0 branch (and stays panic-free on every rank).
    if let Some(blocks) = gathered {
        packed.extend_from_slice(&(p as u64).to_le_bytes());
        for b in &blocks {
            packed.extend_from_slice(&(b.len() as u64).to_le_bytes());
        }
        for b in &blocks {
            packed.extend_from_slice(b);
        }
    }
    bcast(comm, 0, &mut packed);
    let mut lens = Vec::with_capacity(p);
    for r in 0..p {
        let o = 8 + 8 * r;
        lens.push(u64::from_le_bytes(packed[o..o + 8].try_into().unwrap()) as usize);
    }
    let mut out = Vec::with_capacity(p);
    let mut off = 8 + 8 * p;
    for len in lens {
        out.push(packed[off..off + len].to_vec());
        off += len;
    }
    out
}

/// Element-wise sum-allreduce of an `f64` vector (gather-reduce at 0 +
/// broadcast).
pub fn allreduce_sum_f64(comm: &Comm, data: &mut [f64]) {
    let p = comm.size();
    if p == 1 {
        return;
    }
    if comm.rank() == 0 {
        let mut acc: Vec<f64> = data.to_vec();
        for r in 1..p {
            let b = comm.recv_coll(r, T_REDUCE);
            for (i, c) in b.chunks_exact(8).enumerate() {
                acc[i] += f64::from_le_bytes(c.try_into().unwrap());
            }
        }
        data.copy_from_slice(&acc);
    } else {
        comm.send_coll(0, T_REDUCE, complex::f64_as_bytes(data));
    }
    let mut buf: Vec<u8> =
        if comm.rank() == 0 { complex::f64_as_bytes(data).to_vec() } else { Vec::new() };
    bcast(comm, 0, &mut buf);
    for (i, c) in buf.chunks_exact(8).enumerate() {
        data[i] = f64::from_le_bytes(c.try_into().unwrap());
    }
}

/// Sum-allreduce of complex data (re/im pairs are plain f64 sums).
pub fn allreduce_sum_complex(comm: &Comm, data: &mut [Complex]) {
    let floats = complex::as_f64_slice_mut(data);
    allreduce_sum_f64(comm, floats);
}

/// Max-allreduce of a single f64 (convergence checks in the DFT solver).
pub fn allreduce_max_f64(comm: &Comm, value: f64) -> f64 {
    let mut v = [value];
    let p = comm.size();
    if p == 1 {
        return value;
    }
    if comm.rank() == 0 {
        let mut m = value;
        for r in 1..p {
            let b = comm.recv_coll(r, T_REDUCE);
            m = m.max(f64::from_le_bytes(b[0..8].try_into().unwrap()));
        }
        v[0] = m;
    } else {
        comm.send_coll(0, T_REDUCE, &value.to_le_bytes());
    }
    let mut buf = if comm.rank() == 0 { v[0].to_le_bytes().to_vec() } else { Vec::new() };
    bcast(comm, 0, &mut buf);
    f64::from_le_bytes(buf[0..8].try_into().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;

    #[test]
    fn barrier_completes() {
        run_world(5, |comm| {
            for _ in 0..3 {
                barrier(&comm);
            }
        });
    }

    #[test]
    fn bcast_from_each_root() {
        for root in 0..4 {
            let outs = run_world(4, move |comm| {
                let mut data =
                    if comm.rank() == root { vec![1u8, 2, 3, root as u8] } else { Vec::new() };
                bcast(&comm, root, &mut data);
                data
            });
            for o in outs {
                assert_eq!(o, vec![1, 2, 3, root as u8]);
            }
        }
    }

    #[test]
    fn allgatherv_variable_sizes() {
        let outs = run_world(4, |comm| {
            let mine = vec![comm.rank() as u8; comm.rank() + 1];
            allgatherv(&comm, &mine)
        });
        for o in outs {
            assert_eq!(o.len(), 4);
            for (r, b) in o.iter().enumerate() {
                assert_eq!(b, &vec![r as u8; r + 1]);
            }
        }
    }

    #[test]
    fn allreduce_sum() {
        let outs = run_world(4, |comm| {
            let mut v = vec![comm.rank() as f64, 1.0];
            allreduce_sum_f64(&comm, &mut v);
            v
        });
        for o in outs {
            assert_eq!(o, vec![6.0, 4.0]);
        }
    }

    #[test]
    fn allreduce_max() {
        let outs = run_world(5, |comm| allreduce_max_f64(&comm, comm.rank() as f64 * 1.5));
        for o in outs {
            assert_eq!(o, 6.0);
        }
    }
}
