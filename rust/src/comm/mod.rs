//! In-process MPI substrate.
//!
//! The paper's FFTB runs over MPI on Perlmutter; this module provides the
//! same communication semantics with ranks as threads of one process (see
//! `docs/ARCHITECTURE.md` for the layer map and DESIGN.md §3 for why this
//! substitution preserves the paper's behaviour: the planner's message
//! counts and byte volumes are exact, only wire time is modeled).
//!
//! Layering inside the substrate, bottom up:
//!
//! * [`arena`] — the world-shared pool of size-classed, recycled wire
//!   buffers ([`WireBuf`]); the modeled NIC memory.
//! * [`mailbox`] — per-rank FIFO endpoints keyed by `(source, context,
//!   tag)`.
//! * [`communicator`] — MPI-like [`Comm`]: blocking `send`/`recv`,
//!   nonblocking `isend`/`irecv` with [`Request`]/[`waitall`], and
//!   `split`.
//! * [`alltoall`] / [`collectives`] — the collectives the FFT plans drive,
//!   including the *fused* windowed overlapped pairwise exchange
//!   ([`alltoallv_fused`]) that packs each destination block straight into
//!   its recycled wire buffer round by round, tuned by [`CommTuning`].
#![warn(missing_docs)]

pub mod alltoall;
pub mod arena;
pub mod collectives;
pub mod communicator;
pub mod mailbox;
pub mod worker;

pub use alltoall::{
    alltoall, alltoall_into, alltoallv, alltoallv_complex, alltoallv_complex_flat,
    alltoallv_complex_flat_serial, alltoallv_complex_flat_tuned, alltoallv_fused,
    alltoallv_fused_threaded, A2aCounters, CommTuning, FusedBlocks, PackHalf, UnpackHalf,
};
pub use arena::{BufferArena, WireBuf};
pub use collectives::{
    allgatherv, allreduce_max_f64, allreduce_sum_complex, allreduce_sum_f64, barrier, bcast,
    gatherv,
};
pub use communicator::{
    run_world, run_world_perturbed, run_world_with_stats, waitall, Comm, CommStats, Request,
    WorldShared,
};
pub use worker::Worker;
