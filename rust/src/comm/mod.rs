//! In-process MPI substrate.
//!
//! The paper's FFTB runs over MPI on Perlmutter; this module provides the
//! same communication semantics with ranks as threads of one process (see
//! DESIGN.md §3 for why this substitution preserves the paper's behaviour:
//! the planner's message counts and byte volumes are exact, only wire time
//! is modeled).

pub mod alltoall;
pub mod collectives;
pub mod communicator;
pub mod mailbox;

pub use alltoall::{alltoall, alltoallv, alltoallv_complex, alltoallv_complex_flat};
pub use collectives::{
    allgatherv, allreduce_max_f64, allreduce_sum_complex, allreduce_sum_f64, barrier, bcast,
    gatherv,
};
pub use communicator::{run_world, run_world_with_stats, Comm, CommStats, WorldShared};
