//! Shared wire-buffer arena: size-classed recycled byte buffers backing the
//! mailbox transport.
//!
//! Before this arena existed every message posted to a [`Mailbox`] allocated
//! a fresh `Vec<u8>` — the last steady-state heap traffic left in the comm
//! layer. Now every wire payload is a [`WireBuf`] checked out of one
//! world-shared [`BufferArena`]: buffers live in power-of-two size classes,
//! a checkout pops from the class free list (allocating only when the list
//! is empty), and dropping a `WireBuf` returns its storage to the arena.
//! After the first exchange has warmed every class touched by a schedule,
//! repeated exchanges put zero new allocations on the wire — the comm-layer
//! counterpart of the plans' reusable `Workspace`s.
//!
//! [`Mailbox`]: super::mailbox::Mailbox

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Smallest size class in bytes (everything below rounds up to this).
const MIN_CLASS_BYTES: usize = 64;
/// log2 of [`MIN_CLASS_BYTES`].
const MIN_CLASS_SHIFT: u32 = 6;
/// Number of size classes (covers up to `2^(6 + 31)` bytes; the last class
/// is open-ended).
const NUM_CLASSES: usize = 32;
/// Free buffers retained per class; checkins beyond this are dropped so a
/// burst of giant messages cannot pin memory forever.
const MAX_FREE_PER_CLASS: usize = 64;

struct ArenaInner {
    /// `classes[k]` holds free buffers whose capacity is at least
    /// `2^(k + MIN_CLASS_SHIFT)` bytes.
    classes: Vec<Mutex<Vec<Vec<u8>>>>,
    /// Reuse tallies (`Ordering::Relaxed` throughout, and in the
    /// pallas-lint allowlist): pure monotone statistics read only by
    /// [`BufferArena::stats`]. Buffer ownership itself is handed over
    /// under the per-class mutex, which carries all the synchronization —
    /// the counters order nothing.
    minted: AtomicU64,
    reused: AtomicU64,
}

/// World-shared pool of recycled wire buffers, size-classed by capacity.
///
/// A cheaply cloneable handle (the pool itself is reference-counted). One
/// arena is owned by each
/// [`WorldShared`](super::communicator::WorldShared) and shared by every
/// communicator split from that world; all ranks (threads) check out of and
/// recycle into the same free lists.
#[derive(Clone)]
pub struct BufferArena {
    inner: Arc<ArenaInner>,
}

impl Default for BufferArena {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferArena {
    /// Create an empty arena.
    pub fn new() -> Self {
        BufferArena {
            inner: Arc::new(ArenaInner {
                classes: (0..NUM_CLASSES).map(|_| Mutex::new(Vec::new())).collect(),
                minted: AtomicU64::new(0),
                reused: AtomicU64::new(0),
            }),
        }
    }

    /// Size class that can *serve* a request of `len` bytes (ceiling class).
    fn class_for_len(len: usize) -> usize {
        let want = len.max(MIN_CLASS_BYTES).next_power_of_two();
        ((want.trailing_zeros() - MIN_CLASS_SHIFT) as usize).min(NUM_CLASSES - 1)
    }

    /// Size class a buffer of `cap` capacity belongs to when recycled
    /// (floor class), or `None` if it is too small to be worth keeping.
    fn class_for_cap(cap: usize) -> Option<usize> {
        if cap < MIN_CLASS_BYTES {
            return None;
        }
        let k = (usize::BITS - 1 - cap.leading_zeros() - MIN_CLASS_SHIFT) as usize;
        Some(k.min(NUM_CLASSES - 1))
    }

    /// Check out an *empty* buffer with capacity for at least `len` bytes.
    /// Served from the class free list when possible; allocates otherwise.
    pub fn checkout(&self, len: usize) -> WireBuf {
        let k = Self::class_for_len(len);
        let popped = self.inner.classes[k].lock().unwrap().pop();
        let mut buf = match popped {
            Some(b) => {
                self.inner.reused.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.inner.minted.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(MIN_CLASS_BYTES << k)
            }
        };
        buf.clear();
        // Only reachable in the open-ended last class, whose residents may
        // be smaller than the request.
        if buf.capacity() < len {
            buf.reserve(len);
        }
        WireBuf { buf: Some(buf), arena: self.clone() }
    }

    /// Wrap a caller-owned vector as a wire buffer (zero-copy send path);
    /// its storage joins the arena when the receiver drops it.
    pub fn adopt(&self, vec: Vec<u8>) -> WireBuf {
        WireBuf { buf: Some(vec), arena: self.clone() }
    }

    /// Return a buffer's storage to its floor size class.
    fn recycle(&self, buf: Vec<u8>) {
        if let Some(k) = Self::class_for_cap(buf.capacity()) {
            let mut free = self.inner.classes[k].lock().unwrap();
            if free.len() < MAX_FREE_PER_CLASS {
                free.push(buf);
            }
        }
    }

    /// `(minted, reused)` checkout counters: buffers allocated fresh vs.
    /// served from a free list. In steady state only `reused` grows.
    pub fn stats(&self) -> (u64, u64) {
        (self.inner.minted.load(Ordering::Relaxed), self.inner.reused.load(Ordering::Relaxed))
    }
}

/// One wire payload: arena-backed byte storage that recycles itself into
/// the [`BufferArena`] on drop.
///
/// Derefs to `[u8]` for readers; senders fill it with
/// [`WireBuf::extend_from_slice`]. [`WireBuf::into_vec`] defuses the
/// recycling and hands the storage to the caller (the boundary of the
/// public `Vec<u8>` receive API).
pub struct WireBuf {
    /// `Some` until dropped or converted with [`WireBuf::into_vec`].
    buf: Option<Vec<u8>>,
    arena: BufferArena,
}

impl WireBuf {
    /// Append `src`, growing only if the checkout capacity was exceeded.
    pub fn extend_from_slice(&mut self, src: &[u8]) {
        // pallas-lint: allow(no-panic) — `buf` is only `None` after
        // `into_vec`, which consumes `self`; `&mut self` here proves it
        // was not consumed.
        self.buf.as_mut().expect("WireBuf used after into_vec").extend_from_slice(src);
    }

    /// Payload length in bytes.
    pub fn len(&self) -> usize {
        self.buf.as_ref().map(|b| b.len()).unwrap_or(0)
    }

    /// Whether the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take the storage out, skipping arena recycling (used where the
    /// public API hands a plain `Vec<u8>` to the caller).
    pub fn into_vec(mut self) -> Vec<u8> {
        // pallas-lint: allow(no-panic) — `into_vec` consumes `self`, so
        // the storage can only have been taken once.
        self.buf.take().expect("WireBuf used after into_vec")
    }
}

impl std::ops::Deref for WireBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.buf.as_deref().unwrap_or(&[])
    }
}

impl std::fmt::Debug for WireBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WireBuf({} B)", self.len())
    }
}

impl Drop for WireBuf {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.arena.recycle(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_recycles_capacity() {
        let arena = BufferArena::new();
        let mut b = arena.checkout(100);
        b.extend_from_slice(&[7u8; 100]);
        assert_eq!(b.len(), 100);
        drop(b); // storage returns to the 128-byte class
        let b2 = arena.checkout(90);
        assert!(b2.is_empty(), "recycled buffers come back empty");
        let (minted, reused) = arena.stats();
        assert_eq!(minted, 1, "second checkout must reuse the first buffer");
        assert_eq!(reused, 1);
    }

    #[test]
    fn distinct_classes_do_not_mix() {
        let arena = BufferArena::new();
        drop(arena.checkout(64)); // class 0
        let big = arena.checkout(1 << 20); // fresh mint, larger class
        assert!(big.is_empty());
        let (minted, _) = arena.stats();
        assert_eq!(minted, 2);
    }

    #[test]
    fn adopt_and_into_vec_round_trip() {
        let arena = BufferArena::new();
        let wb = arena.adopt(vec![1, 2, 3]);
        assert_eq!(&wb[..], &[1, 2, 3]);
        let v = wb.into_vec();
        assert_eq!(v, vec![1, 2, 3]);
        // into_vec defuses recycling: nothing joined the arena.
        let (minted, reused) = arena.stats();
        assert_eq!((minted, reused), (0, 0));
    }

    #[test]
    fn zero_length_checkout_is_fine() {
        let arena = BufferArena::new();
        let b = arena.checkout(0);
        assert!(b.is_empty());
    }

    #[test]
    fn steady_state_mints_nothing() {
        let arena = BufferArena::new();
        for _ in 0..10 {
            let mut b = arena.checkout(256);
            b.extend_from_slice(&[0u8; 256]);
        }
        let (minted, reused) = arena.stats();
        assert_eq!(minted, 1);
        assert_eq!(reused, 9);
    }
}
