//! Point-to-point message transport between in-process ranks.
//!
//! Each rank owns one `Mailbox`: a mutex-protected map from `(source,
//! context, tag)` to a FIFO of wire payloads, with a condvar for blocking
//! receives. The `context` field namespaces sub-communicators (MPI's
//! communicator context id), so a split communicator can never intercept
//! traffic of its parent.
//!
//! This is deliberately a faithful *semantic* model of MPI two-sided
//! messaging — ordered per (source, context, tag) channel, payload copied at
//! the boundary — so byte counts measured here equal what an MPI alltoall
//! would put on a real wire. Payloads are [`WireBuf`]s checked out of the
//! world's shared [`BufferArena`](super::arena::BufferArena), so the
//! modeled NIC buffers are recycled instead of reallocated per message.
//!
//! ## Schedule perturbation
//!
//! With a delivery policy armed ([`Mailbox::set_policy`], normally via
//! `run_world_perturbed`), posted messages may be parked in a staging
//! buffer and released later in a seeded pseudo-random order — the
//! in-process analogue of network jitter. Two MPI guarantees survive
//! perturbation by construction: messages of the *same* channel are always
//! released in posting order (non-overtaking), and a blocked receiver
//! drains the staging buffer before sleeping, so every posted message
//! remains receivable (liveness). Everything else — cross-channel arrival
//! order, probe timing — is deliberately scrambled, which is exactly what
//! `tests/comm_schedules.rs` exercises.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use super::arena::WireBuf;
use crate::util::prng::Prng;

/// Message routing key: (source rank in world, context id, user tag).
pub type Key = (usize, u64, u64);

#[derive(Default)]
struct Inner {
    queues: HashMap<Key, VecDeque<WireBuf>>,
    /// Posted-but-undelivered messages, in posting order (perturbation
    /// mode only; always empty without a policy).
    staged: Vec<(Key, WireBuf)>,
    /// `Some` arms delivery perturbation; the PRNG lives under the same
    /// mutex as the queues so every delivery decision is serialized.
    policy: Option<Prng>,
}

impl Inner {
    /// Move one staged message into its delivery queue: pick one of the
    /// *distinct channel heads* (the oldest staged message of each key),
    /// keeping per-channel FIFO order intact.
    fn release_one(&mut self) {
        let mut heads: Vec<usize> = Vec::new();
        let mut seen: Vec<Key> = Vec::new();
        for (i, (k, _)) in self.staged.iter().enumerate() {
            if !seen.contains(k) {
                seen.push(*k);
                heads.push(i);
            }
        }
        if heads.is_empty() {
            return;
        }
        let pick = match &mut self.policy {
            Some(prng) => prng.next_below(heads.len()),
            None => 0,
        };
        let (key, payload) = self.staged.remove(heads[pick]);
        self.queues.entry(key).or_default().push_back(payload);
    }
}

/// One rank's receive endpoint.
#[derive(Default)]
pub struct Mailbox {
    inner: Mutex<Inner>,
    signal: Condvar,
}

impl Mailbox {
    /// Create an empty mailbox behind an `Arc` (shared with senders).
    pub fn new() -> Arc<Self> {
        Arc::new(Mailbox::default())
    }

    /// Arm the seeded delivery-perturbation policy (see the module docs).
    /// Test-only in spirit: production worlds never call this.
    pub fn set_policy(&self, seed: u64) {
        self.inner.lock().unwrap().policy = Some(Prng::new(seed));
    }

    /// Deposit a message (called by the *sender* thread).
    pub fn post(&self, key: Key, payload: WireBuf) {
        let mut inner = self.inner.lock().unwrap();
        if inner.policy.is_some() {
            // Non-overtaking: once any message of this channel is staged,
            // later ones must stage behind it.
            let must_stage = inner.staged.iter().any(|(k, _)| *k == key);
            let coin = match &mut inner.policy {
                Some(prng) => prng.next_u64() & 1 == 0,
                None => false,
            };
            if must_stage || coin {
                inner.staged.push((key, payload));
            } else {
                inner.queues.entry(key).or_default().push_back(payload);
            }
            // Let 0..=2 staged messages (any channel) through, scrambling
            // cross-channel arrival order.
            let releases = match &mut inner.policy {
                Some(prng) => prng.next_below(3),
                None => 0,
            };
            for _ in 0..releases {
                inner.release_one();
            }
        } else {
            inner.queues.entry(key).or_default().push_back(payload);
        }
        self.signal.notify_all();
    }

    /// Blocking receive of the next message matching `key`.
    pub fn take(&self, key: Key) -> WireBuf {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(q) = inner.queues.get_mut(&key) {
                if let Some(msg) = q.pop_front() {
                    return msg;
                }
            }
            if inner.staged.is_empty() {
                inner = self.signal.wait(inner).unwrap();
            } else {
                // Liveness under perturbation: drain staged deliveries
                // (one random channel head at a time) instead of sleeping
                // on messages that were posted but not yet delivered.
                inner.release_one();
            }
        }
    }

    /// Non-blocking probe: is a message matching `key` available? Staged
    /// (undelivered) messages are invisible here — under perturbation a
    /// probe can say "no" for a message that was already posted, exactly
    /// like an in-flight packet on a real network.
    pub fn probe(&self, key: Key) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.queues.get(&key).map(|q| !q.is_empty()).unwrap_or(false)
    }

    /// Total queued messages, staged deliveries included (diagnostics).
    pub fn pending(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.queues.values().map(|q| q.len()).sum::<usize>() + inner.staged.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::arena::BufferArena;
    use std::thread;

    #[test]
    fn post_take_fifo_order() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        let key = (0, 1, 7);
        mb.post(key, arena.adopt(vec![1]));
        mb.post(key, arena.adopt(vec![2]));
        assert_eq!(mb.take(key).into_vec(), vec![1]);
        assert_eq!(mb.take(key).into_vec(), vec![2]);
    }

    #[test]
    fn contexts_are_isolated() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        mb.post((0, 1, 0), arena.adopt(vec![1]));
        mb.post((0, 2, 0), arena.adopt(vec![2]));
        assert_eq!(mb.take((0, 2, 0)).into_vec(), vec![2]);
        assert_eq!(mb.take((0, 1, 0)).into_vec(), vec![1]);
    }

    #[test]
    fn blocking_take_wakes_on_post() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        let mb2 = Arc::clone(&mb);
        let h = thread::spawn(move || mb2.take((3, 0, 9)).into_vec());
        thread::sleep(std::time::Duration::from_millis(20));
        mb.post((3, 0, 9), arena.adopt(vec![42]));
        assert_eq!(h.join().unwrap(), vec![42]);
    }

    #[test]
    fn probe_and_pending() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        assert!(!mb.probe((0, 0, 0)));
        mb.post((0, 0, 0), arena.adopt(vec![9]));
        assert!(mb.probe((0, 0, 0)));
        assert_eq!(mb.pending(), 1);
        mb.take((0, 0, 0));
        assert_eq!(mb.pending(), 0);
    }

    #[test]
    fn taken_buffers_recycle_into_the_arena() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        for _ in 0..5 {
            let mut b = arena.checkout(128);
            b.extend_from_slice(&[3u8; 128]);
            mb.post((1, 0, 0), b);
            let got = mb.take((1, 0, 0));
            assert_eq!(got.len(), 128);
            // drop recycles
        }
        let (minted, reused) = arena.stats();
        assert_eq!(minted, 1, "wire buffers must be recycled across messages");
        assert_eq!(reused, 4);
    }

    #[test]
    fn tags_match_under_out_of_order_posting() {
        // Messages posted on three interleaved tag channels must come back
        // matched by tag, not by arrival order.
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        for (tag, val) in [(7u64, 70u8), (5, 50), (9, 90), (5, 51), (7, 71)] {
            mb.post((2, 0, tag), arena.adopt(vec![val]));
        }
        assert_eq!(mb.take((2, 0, 9)).into_vec(), vec![90]);
        assert_eq!(mb.take((2, 0, 7)).into_vec(), vec![70]);
        assert_eq!(mb.take((2, 0, 5)).into_vec(), vec![50]);
        assert_eq!(mb.take((2, 0, 5)).into_vec(), vec![51]);
        assert_eq!(mb.take((2, 0, 7)).into_vec(), vec![71]);
    }

    #[test]
    fn perturbed_delivery_preserves_per_channel_fifo() {
        for seed in 0..32u64 {
            let arena = BufferArena::new();
            let mb = Mailbox::new();
            mb.set_policy(seed);
            for i in 0..10u8 {
                mb.post((0, 0, 1), arena.adopt(vec![i]));
                mb.post((0, 0, 2), arena.adopt(vec![100 + i]));
            }
            for i in 0..10u8 {
                assert_eq!(mb.take((0, 0, 1)).into_vec(), vec![i], "seed {seed}");
            }
            for i in 0..10u8 {
                assert_eq!(mb.take((0, 0, 2)).into_vec(), vec![100 + i], "seed {seed}");
            }
            assert_eq!(mb.pending(), 0, "seed {seed}: no message may be lost");
        }
    }

    #[test]
    fn perturbed_blocking_take_stays_live() {
        // A receiver blocked on one channel must not deadlock on messages
        // parked in the staging buffer.
        for seed in [3u64, 17, 40_404] {
            let arena = BufferArena::new();
            let mb = Mailbox::new();
            mb.set_policy(seed);
            let mb2 = Arc::clone(&mb);
            let h = thread::spawn(move || mb2.take((1, 0, 8)).into_vec());
            thread::sleep(std::time::Duration::from_millis(10));
            mb.post((1, 0, 3), arena.adopt(vec![1]));
            mb.post((1, 0, 8), arena.adopt(vec![2]));
            assert_eq!(h.join().unwrap(), vec![2]);
            assert_eq!(mb.take((1, 0, 3)).into_vec(), vec![1]);
        }
    }

    #[test]
    fn concurrent_checkout_recycle_minted_plateaus() {
        // Hammer one shared arena from four threads; after warm-up, the
        // `minted` counter must plateau — steady-state traffic reuses
        // buffers instead of allocating.
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        let threads: usize = 4;
        let rounds: usize = 200;
        // Deterministic warm-up: mint exactly one buffer per thread (held
        // simultaneously, then returned), so the free list can absorb the
        // peak concurrent demand of the stress phase.
        let warm: Vec<_> = (0..threads).map(|_| arena.checkout(256)).collect();
        drop(warm);
        let (minted_warm, _) = arena.stats();
        assert_eq!(minted_warm, threads as u64);
        thread::scope(|s| {
            for t in 0..threads {
                let arena = &arena;
                let mb = &mb;
                s.spawn(move || {
                    for i in 0..rounds {
                        let mut b = arena.checkout(256);
                        b.extend_from_slice(&[t as u8; 256]);
                        mb.post((t, 0, i as u64), b);
                        let got = mb.take((t, 0, i as u64));
                        assert_eq!(got.len(), 256);
                    }
                });
            }
        });
        let (minted_steady, reused) = arena.stats();
        assert_eq!(minted_steady, minted_warm, "steady-state traffic must not mint");
        assert!(reused >= (threads * rounds) as u64, "every stress checkout must reuse");
    }
}
