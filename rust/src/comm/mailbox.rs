//! Point-to-point message transport between in-process ranks.
//!
//! Each rank owns one `Mailbox`: a mutex-protected map from `(source,
//! context, tag)` to a FIFO of wire payloads, with a condvar for blocking
//! receives. The `context` field namespaces sub-communicators (MPI's
//! communicator context id), so a split communicator can never intercept
//! traffic of its parent.
//!
//! This is deliberately a faithful *semantic* model of MPI two-sided
//! messaging — ordered per (source, context, tag) channel, payload copied at
//! the boundary — so byte counts measured here equal what an MPI alltoall
//! would put on a real wire. Payloads are [`WireBuf`]s checked out of the
//! world's shared [`BufferArena`](super::arena::BufferArena), so the
//! modeled NIC buffers are recycled instead of reallocated per message.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use super::arena::WireBuf;

/// Message routing key: (source rank in world, context id, user tag).
pub type Key = (usize, u64, u64);

#[derive(Default)]
struct Inner {
    queues: HashMap<Key, VecDeque<WireBuf>>,
}

/// One rank's receive endpoint.
#[derive(Default)]
pub struct Mailbox {
    inner: Mutex<Inner>,
    signal: Condvar,
}

impl Mailbox {
    /// Create an empty mailbox behind an `Arc` (shared with senders).
    pub fn new() -> Arc<Self> {
        Arc::new(Mailbox::default())
    }

    /// Deposit a message (called by the *sender* thread).
    pub fn post(&self, key: Key, payload: WireBuf) {
        let mut inner = self.inner.lock().unwrap();
        inner.queues.entry(key).or_default().push_back(payload);
        self.signal.notify_all();
    }

    /// Blocking receive of the next message matching `key`.
    pub fn take(&self, key: Key) -> WireBuf {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(q) = inner.queues.get_mut(&key) {
                if let Some(msg) = q.pop_front() {
                    return msg;
                }
            }
            inner = self.signal.wait(inner).unwrap();
        }
    }

    /// Non-blocking probe: is a message matching `key` available?
    pub fn probe(&self, key: Key) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.queues.get(&key).map(|q| !q.is_empty()).unwrap_or(false)
    }

    /// Total queued messages (diagnostics).
    pub fn pending(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.queues.values().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::arena::BufferArena;
    use std::thread;

    #[test]
    fn post_take_fifo_order() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        let key = (0, 1, 7);
        mb.post(key, arena.adopt(vec![1]));
        mb.post(key, arena.adopt(vec![2]));
        assert_eq!(mb.take(key).into_vec(), vec![1]);
        assert_eq!(mb.take(key).into_vec(), vec![2]);
    }

    #[test]
    fn contexts_are_isolated() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        mb.post((0, 1, 0), arena.adopt(vec![1]));
        mb.post((0, 2, 0), arena.adopt(vec![2]));
        assert_eq!(mb.take((0, 2, 0)).into_vec(), vec![2]);
        assert_eq!(mb.take((0, 1, 0)).into_vec(), vec![1]);
    }

    #[test]
    fn blocking_take_wakes_on_post() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        let mb2 = Arc::clone(&mb);
        let h = thread::spawn(move || mb2.take((3, 0, 9)).into_vec());
        thread::sleep(std::time::Duration::from_millis(20));
        mb.post((3, 0, 9), arena.adopt(vec![42]));
        assert_eq!(h.join().unwrap(), vec![42]);
    }

    #[test]
    fn probe_and_pending() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        assert!(!mb.probe((0, 0, 0)));
        mb.post((0, 0, 0), arena.adopt(vec![9]));
        assert!(mb.probe((0, 0, 0)));
        assert_eq!(mb.pending(), 1);
        mb.take((0, 0, 0));
        assert_eq!(mb.pending(), 0);
    }

    #[test]
    fn taken_buffers_recycle_into_the_arena() {
        let arena = BufferArena::new();
        let mb = Mailbox::new();
        for _ in 0..5 {
            let mut b = arena.checkout(128);
            b.extend_from_slice(&[3u8; 128]);
            mb.post((1, 0, 0), b);
            let got = mb.take((1, 0, 0));
            assert_eq!(got.len(), 128);
            // drop recycles
        }
        let (minted, reused) = arena.stats();
        assert_eq!(minted, 1, "wire buffers must be recycled across messages");
        assert_eq!(reused, 4);
    }
}
