//! Tenant-side handles: identities, and quota-leased request buffers.
//!
//! A [`TenantSlot`] is the unit of the service's admission control: it is
//! minted by [`TransformService::checkout`](super::TransformService::checkout)
//! against the tenant's budgeted [`SlotPool`] (the checkout *charges* the
//! buffer's capacity class against the tenant's quota), travels into the
//! batching driver on submit (the charge stays while the request is in
//! flight), and comes back wrapping the result. Dropping a slot — whether
//! the tenant read the result or abandoned it — recycles the storage into
//! the tenant's pool and releases the charge, so quota can never leak: the
//! lease *is* the buffer.

use std::cell::RefCell;
use std::rc::Rc;

use crate::fft::complex::Complex;
use crate::fftb::plan::workspace::SlotPool;

/// Opaque tenant identity handed out by
/// [`TransformService::register_tenant`](super::TransformService::register_tenant).
/// Registration order must be identical on every rank (the SPMD contract),
/// so the id doubles as the deterministic tie-breaker in coalesced batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TenantId(pub(crate) usize);

impl TenantId {
    /// Index of this tenant in registration order.
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Shared handle on one tenant's budgeted slot pool. The service is
/// single-threaded per rank (one service per SPMD thread), so plain
/// `Rc<RefCell<..>>` suffices — no atomics, no locks.
pub(crate) type PoolHandle = Rc<RefCell<SlotPool>>;

/// A quota-leased request/result buffer of one tenant.
///
/// While the slot exists (checked out, in flight, or holding a collected
/// result) its capacity class stays charged against the tenant's quota;
/// dropping it recycles the storage into the tenant's pool and releases
/// the charge. See the module docs for the full lifecycle.
pub struct TenantSlot {
    /// The buffer. `None` only transiently, while the storage rides the
    /// batching driver (the service re-wraps the result on completion).
    pub(crate) data: Option<Vec<Complex>>,
    /// The owning tenant's pool, for the drop-time recycle.
    pub(crate) pool: PoolHandle,
}

impl TenantSlot {
    /// The slot's contents (empty once the storage moved into a submit).
    pub fn data(&self) -> &[Complex] {
        self.data.as_deref().unwrap_or(&[])
    }

    /// Mutable view of the slot's contents, for filling before a submit.
    pub fn data_mut(&mut self) -> &mut [Complex] {
        match &mut self.data {
            Some(v) => v,
            None => &mut [],
        }
    }

    /// Number of elements currently held.
    pub fn len(&self) -> usize {
        self.data.as_ref().map_or(0, Vec::len)
    }

    /// Whether the slot currently holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Move the storage out for a submit, keeping the quota charge: the
    /// emptied slot drops without recycling (there is nothing to return),
    /// and the charge is released only when the *result* slot — same
    /// storage, re-wrapped by the flush path — is dropped.
    pub(crate) fn take_storage(mut self) -> Vec<Complex> {
        self.data.take().unwrap_or_default()
    }
}

impl Drop for TenantSlot {
    fn drop(&mut self) {
        if let Some(buf) = self.data.take() {
            self.pool.borrow_mut().recycle(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn dropping_a_slot_recycles_and_releases_the_charge() {
        let pool: PoolHandle =
            Rc::new(RefCell::new(SlotPool::with_budget(1 << 20)));
        let ctr = Cell::new(0u64);
        let buf = pool.borrow_mut().try_take(100, &ctr).unwrap();
        let charged = pool.borrow().charged();
        assert!(charged > 0);
        let slot = TenantSlot { data: Some(buf), pool: Rc::clone(&pool) };
        assert_eq!(slot.len(), 100);
        drop(slot);
        assert_eq!(pool.borrow().charged(), 0, "drop must release the lease");
        assert_eq!(pool.borrow().len(), 1, "storage must land back in the pool");
    }

    #[test]
    fn take_storage_keeps_the_charge() {
        let pool: PoolHandle =
            Rc::new(RefCell::new(SlotPool::with_budget(1 << 20)));
        let ctr = Cell::new(0u64);
        let buf = pool.borrow_mut().try_take(64, &ctr).unwrap();
        let charged = pool.borrow().charged();
        let slot = TenantSlot { data: Some(buf), pool: Rc::clone(&pool) };
        let storage = slot.take_storage();
        assert_eq!(storage.len(), 64);
        assert_eq!(
            pool.borrow().charged(),
            charged,
            "in-flight storage must stay charged against the quota"
        );
        // Re-wrapping and dropping (what the flush path does) releases it.
        drop(TenantSlot { data: Some(storage), pool: Rc::clone(&pool) });
        assert_eq!(pool.borrow().charged(), 0);
    }
}
