//! The multi-tenant transform service: admission, coalesced batching,
//! per-tenant quotas and metrics on top of the
//! [`BatchingDriver`](crate::coordinator::BatchingDriver).
//!
//! A plane-wave DFT application rarely runs one transform stream: several
//! SCF solvers (k-points, spins, ensembles) and auxiliary grid work share
//! one machine allocation. This layer multiplexes those client streams —
//! *tenants* — into one SPMD world so their per-band requests ride shared
//! batched executions (one fused exchange per flush instead of one per
//! stream), while keeping each stream's memory bounded and its latency
//! observable:
//!
//! * **Lanes** group compatible requests. A lane is identified by the
//!   coalescing key: the service's `(communicator, shape)` is fixed at
//!   construction, and within it the dense grid lane is keyed `0` while
//!   each cut-off sphere lane is keyed by its
//!   [`OffsetArray::fingerprint`] — two tenants share a batch exactly when
//!   they share a lane and a flush direction. Each lane owns one
//!   [`BatchingDriver`], so the plan cache, interleave blocks and warmed
//!   workspaces are shared by every tenant in the lane.
//! * **Admission** is typed, never panicking and never unbounded: a
//!   checkout past the tenant's quota returns
//!   [`ServiceError::QuotaExhausted`], a submit past the service's
//!   in-flight window returns [`ServiceError::Backlogged`], and malformed
//!   requests are rejected before they touch a driver.
//! * **Quotas** are budgeted [`SlotPool`]s, one per tenant: a checkout
//!   charges the buffer's capacity class, the charge rides the request
//!   through the driver, and dropping the result slot releases it (see
//!   [`tenant`]). Steady-state tenants therefore run allocation-free out
//!   of their own recycled storage.
//! * **Metrics** grow per tenant: submit-to-completion latency
//!   percentiles (p50/p95/p99 over a fixed-size reservoir, zero-alloc on
//!   the record path) and request/byte counters in the service's
//!   [`MetricsSink`], plus one [`FlushRecord`] per coalesced execution.
//!
//! Ordering is deterministic without communication: tenants register, and
//! requests submit, in identical order on every rank (the SPMD contract
//! the whole stack runs on), sequence ids are handed out in that order,
//! lanes flush in ascending key order, and the driver preserves submission
//! order within a flush — so all ranks assemble identical batches with no
//! coordination traffic. Within a batch every band transforms
//! independently (no plan stage mixes bands arithmetically), so a
//! tenant's coalesced results are bit-identical to the same requests run
//! alone — pinned by `tests/service.rs`.

#![warn(missing_docs)]

pub mod tenant;

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::fmt;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use crate::comm::alltoall::CommTuning;
use crate::coordinator::driver::{BatchingDriver, TransformJob};
use crate::coordinator::metrics::MetricsSink;
use crate::fft::complex::Complex;
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::{cyclic, ProcGrid};
use crate::fftb::plan::workspace::SlotPool;
use crate::fftb::sphere::OffsetArray;

pub use tenant::{TenantId, TenantSlot};

/// Lane key of the dense full-grid lane (sphere lanes use their offset
/// fingerprint, which is non-zero for any non-empty sphere).
pub const GRID_LANE: u64 = 0;

/// Typed admission/scheduling failures. Every rejection is recoverable:
/// the request's slot (if any) is released back to its tenant, nothing
/// panics, and nothing queues unboundedly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The tenant id was never registered with this service.
    UnknownTenant {
        /// The offending tenant index.
        tenant: usize,
    },
    /// The lane key names no lane of this service.
    UnknownLane {
        /// The offending lane key.
        lane: u64,
    },
    /// The checkout would push the tenant's checked-out capacity past its
    /// quota. Recycle (drop) an outstanding slot and retry.
    QuotaExhausted {
        /// Tenant index whose quota is exhausted.
        tenant: usize,
        /// Bytes the refused checkout would have charged.
        requested: usize,
        /// Bytes currently charged against the quota.
        charged: usize,
        /// The tenant's quota, in bytes.
        quota: usize,
    },
    /// The service's bounded in-flight window is full. Flush, then retry.
    Backlogged {
        /// Requests currently in flight across all lanes.
        pending: usize,
        /// The configured window ([`ServiceConfig::max_in_flight`]).
        limit: usize,
    },
    /// The submitted slot's length does not match the lane's local layout
    /// for the requested direction.
    WrongLength {
        /// Elements the lane expects for this direction.
        expected: usize,
        /// Elements the slot actually held.
        got: usize,
    },
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::UnknownTenant { tenant } => {
                write!(f, "unknown tenant id {tenant}")
            }
            ServiceError::UnknownLane { lane } => write!(f, "unknown lane {lane:#x}"),
            ServiceError::QuotaExhausted { tenant, requested, charged, quota } => write!(
                f,
                "tenant {tenant} quota exhausted: checkout of {requested} B refused \
                 with {charged} of {quota} B already charged"
            ),
            ServiceError::Backlogged { pending, limit } => {
                write!(f, "in-flight window full: {pending} of {limit} requests pending")
            }
            ServiceError::WrongLength { expected, got } => write!(
                f,
                "submit length mismatch: the lane expects {expected} elements \
                 for this direction, got {got}"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Service-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Bound on requests in flight across all lanes; submits past it are
    /// refused with [`ServiceError::Backlogged`], so the service never
    /// queues unboundedly.
    pub max_in_flight: usize,
    /// Exchange tuning handed to every lane's driver.
    pub tuning: CommTuning,
    /// Quota (bytes of checked-out slot capacity) of tenants registered
    /// through [`TransformService::register_tenant`].
    pub default_quota: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_in_flight: 4096,
            tuning: CommTuning::default(),
            default_quota: 1 << 30,
        }
    }
}

/// What one coalesced flush did — the service's audit trail: how many
/// jobs from how many distinct tenants shared the execution, and the
/// exchange/allocation telemetry the acceptance tests gate on.
#[derive(Clone, Copy, Debug)]
pub struct FlushRecord {
    /// Lane key ([`GRID_LANE`] or a sphere fingerprint).
    pub lane: u64,
    /// Direction this flush executed.
    pub dir: Direction,
    /// Jobs coalesced into the one batched execution.
    pub jobs: usize,
    /// Distinct tenants among those jobs.
    pub tenants: usize,
    /// Point-to-point messages the fused exchanges sent.
    pub messages: u64,
    /// Bytes those messages carried.
    pub bytes: u64,
    /// Workspace growth during the execution (0 in steady state).
    pub alloc_bytes: u64,
    /// Whether the batched plan came from the lane's plan cache.
    pub plan_cache_hit: bool,
}

/// One request in flight: who submitted it, when, and how big it was.
struct InFlight {
    tenant: usize,
    bytes: u64,
    t0: Instant,
}

/// One coalescing group: a driver plus the local per-band layout lengths
/// and the in-flight bookkeeping of its requests.
struct Lane {
    driver: BatchingDriver,
    /// Local elements per band on the packed (forward-input) side.
    in_per_band: usize,
    /// Local elements per band on the dense (forward-output) side.
    out_per_band: usize,
    /// Metadata of requests currently riding this lane, by sequence id.
    meta: BTreeMap<u64, InFlight>,
}

/// Server-side state of one registered tenant.
struct TenantState {
    /// Budgeted storage pool: the quota *is* the pool's budget.
    pool: tenant::PoolHandle,
    /// Allocation counter of the pool (bytes ever minted for this tenant).
    pool_ctr: Cell<u64>,
    /// The quota, kept for error reporting.
    quota_bytes: usize,
    /// Index into the sink's per-tenant metrics.
    metrics: usize,
    /// Completed results awaiting [`TransformService::collect`].
    completed: Vec<(u64, TenantSlot)>,
}

/// The multi-tenant transform service. See the module docs for the
/// admission rules, the coalescing key and the determinism argument;
/// `examples/service_multi_tenant.rs` is the runnable walkthrough.
pub struct TransformService {
    shape: [usize; 3],
    grid: Arc<ProcGrid>,
    config: ServiceConfig,
    tenants: Vec<TenantState>,
    /// Lanes by coalescing key, flushed in ascending key order.
    lanes: BTreeMap<u64, Lane>,
    /// Next request sequence id (identical on every rank).
    next_seq: u64,
    /// Requests in flight across all lanes, bounded by the config window.
    in_flight: usize,
    /// One record per coalesced flush, in flush order.
    flushes: Vec<FlushRecord>,
    /// Scratch for the distinct-tenant count of a flush record.
    tenant_scratch: Vec<usize>,
    sink: MetricsSink,
}

impl TransformService {
    /// A service for transforms of `shape` on the 1D processing `grid`.
    /// Collective: every rank constructs with identical arguments.
    pub fn new(shape: [usize; 3], grid: Arc<ProcGrid>, config: ServiceConfig) -> Result<Self> {
        if grid.ndim() != 1 {
            return Err(FftbError::Grid(format!(
                "the transform service runs on a 1D processing grid, got {}D",
                grid.ndim()
            )));
        }
        let p = grid.size();
        if p > shape[0] || p > shape[2] {
            return Err(FftbError::Unsupported(format!(
                "service lanes need p <= nx and p <= nz (p={p}, shape={shape:?})"
            )));
        }
        Ok(TransformService {
            shape,
            grid,
            config,
            tenants: Vec::new(),
            lanes: BTreeMap::new(),
            next_seq: 0,
            in_flight: 0,
            flushes: Vec::new(),
            tenant_scratch: Vec::new(),
            sink: MetricsSink::new("service"),
        })
    }

    /// Register a client stream under the config's default quota.
    /// Registration order must be identical on every rank.
    pub fn register_tenant(&mut self, label: &str) -> TenantId {
        self.register_tenant_with_quota(label, self.config.default_quota)
    }

    /// Register a client stream with an explicit quota: the byte bound on
    /// the tenant's checked-out slot capacity (size it with
    /// [`TransformService::slot_bytes`] × the slots the tenant needs in
    /// flight).
    pub fn register_tenant_with_quota(&mut self, label: &str, quota_bytes: usize) -> TenantId {
        let metrics = self.sink.register_tenant(label);
        self.tenants.push(TenantState {
            pool: Rc::new(RefCell::new(SlotPool::with_budget(quota_bytes))),
            pool_ctr: Cell::new(0),
            quota_bytes,
            metrics,
            completed: Vec::new(),
        });
        TenantId(self.tenants.len() - 1)
    }

    /// The dense full-grid lane (batched slab-pencil transforms), created
    /// on first use. Returns its lane key, [`GRID_LANE`].
    pub fn grid_lane(&mut self) -> u64 {
        if !self.lanes.contains_key(&GRID_LANE) {
            let (p, r) = (self.grid.size(), self.grid.rank());
            let [nx, ny, nz] = self.shape;
            let driver =
                BatchingDriver::with_tuning(self.shape, Arc::clone(&self.grid), self.config.tuning);
            self.lanes.insert(
                GRID_LANE,
                Lane {
                    driver,
                    in_per_band: cyclic::local_count(nx, p, r) * ny * nz,
                    out_per_band: nx * ny * cyclic::local_count(nz, p, r),
                    meta: BTreeMap::new(),
                },
            );
        }
        GRID_LANE
    }

    /// The lane of the cut-off sphere `off` (batched plane-wave
    /// transforms), created on first use. The lane key is the sphere's
    /// structural fingerprint, so every tenant handing in the same sphere
    /// — on any rank — lands in the same lane without coordination.
    pub fn sphere_lane(&mut self, off: Arc<OffsetArray>) -> Result<u64> {
        if self.shape != [off.nx, off.ny, off.nz] {
            return Err(FftbError::Shape(format!(
                "sphere offsets describe a {}x{}x{} grid but the service shape is {:?}",
                off.nx, off.ny, off.nz, self.shape
            )));
        }
        let key = off.fingerprint();
        debug_assert_ne!(key, GRID_LANE, "a non-empty sphere cannot fingerprint to 0");
        if !self.lanes.contains_key(&key) {
            let (p, r) = (self.grid.size(), self.grid.rank());
            let in_per_band = off.restrict_x_cyclic(p, r).total();
            let out_per_band =
                self.shape[0] * self.shape[1] * cyclic::local_count(self.shape[2], p, r);
            let driver = BatchingDriver::with_sphere(
                self.shape,
                Arc::clone(&self.grid),
                off,
                self.config.tuning,
            )?;
            let lane = Lane { driver, in_per_band, out_per_band, meta: BTreeMap::new() };
            self.lanes.insert(key, lane);
        }
        Ok(key)
    }

    /// Bytes one slot of `lane` charges against a quota (the capacity
    /// class of the larger of the lane's two sides), or `None` for an
    /// unknown lane — the unit tenant quotas should be sized in.
    pub fn slot_bytes(&self, lane: u64) -> Option<usize> {
        self.lanes.get(&lane).map(|l| SlotPool::class_bytes(l.in_per_band.max(l.out_per_band)))
    }

    /// Check out a request buffer for `lane`, sized for `dir`'s submit
    /// side (capacity covers the round trip, so the result never
    /// reallocates). Charges the tenant's quota; refuses with
    /// [`ServiceError::QuotaExhausted`] past it.
    pub fn checkout(
        &mut self,
        tenant: TenantId,
        lane: u64,
        dir: Direction,
    ) -> std::result::Result<TenantSlot, ServiceError> {
        let t = match self.tenants.get(tenant.0) {
            Some(t) => t,
            None => return Err(ServiceError::UnknownTenant { tenant: tenant.0 }),
        };
        let l = match self.lanes.get(&lane) {
            Some(l) => l,
            None => return Err(ServiceError::UnknownLane { lane }),
        };
        let max_len = l.in_per_band.max(l.out_per_band);
        let submit_len = match dir {
            Direction::Forward => l.in_per_band,
            Direction::Inverse => l.out_per_band,
        };
        let mut pool = t.pool.borrow_mut();
        match pool.try_take(max_len, &t.pool_ctr) {
            Some(mut buf) => {
                buf.truncate(submit_len);
                Ok(TenantSlot { data: Some(buf), pool: Rc::clone(&t.pool) })
            }
            None => Err(ServiceError::QuotaExhausted {
                tenant: tenant.0,
                requested: SlotPool::class_bytes(max_len),
                charged: pool.charged(),
                quota: t.quota_bytes,
            }),
        }
    }

    /// Submit a filled slot as one transform request on `lane`. Returns
    /// the request's sequence id (identical on every rank). On any
    /// rejection the slot is released back to its tenant — the error is
    /// the whole story, nothing leaks. Submission order must be identical
    /// on every rank.
    pub fn submit(
        &mut self,
        tenant: TenantId,
        lane: u64,
        dir: Direction,
        slot: TenantSlot,
    ) -> std::result::Result<u64, ServiceError> {
        // steady-state: service-submit
        if tenant.0 >= self.tenants.len() {
            return Err(ServiceError::UnknownTenant { tenant: tenant.0 });
        }
        let l = match self.lanes.get_mut(&lane) {
            Some(l) => l,
            None => return Err(ServiceError::UnknownLane { lane }),
        };
        let expected = match dir {
            Direction::Forward => l.in_per_band,
            Direction::Inverse => l.out_per_band,
        };
        if slot.len() != expected {
            return Err(ServiceError::WrongLength { expected, got: slot.len() });
        }
        if self.in_flight >= self.config.max_in_flight {
            return Err(ServiceError::Backlogged {
                pending: self.in_flight,
                limit: self.config.max_in_flight,
            });
        }
        let id = self.next_seq;
        self.next_seq += 1;
        let bytes = (expected * std::mem::size_of::<Complex>()) as u64;
        l.meta.insert(id, InFlight { tenant: tenant.0, bytes, t0: Instant::now() });
        l.driver.submit(TransformJob { id, data: slot.take_storage(), dir });
        self.in_flight += 1;
        Ok(id)
        // steady-state: end
    }

    /// Flush every lane's queued jobs of direction `dir` — one coalesced
    /// batched execution per lane, lanes in ascending key order. Completed
    /// results are routed to their tenants (collect them with
    /// [`TransformService::collect`]), latencies recorded, and one
    /// [`FlushRecord`] appended per lane that executed. Returns the total
    /// jobs executed. Collective over the service's communicator.
    pub fn flush(&mut self, backend: &dyn LocalFftBackend, dir: Direction) -> usize {
        let mut total = 0;
        for (key, lane) in self.lanes.iter_mut() {
            let jobs = lane.driver.flush(backend, dir);
            if jobs == 0 {
                continue;
            }
            total += jobs;
            // steady-state: service-flush-record
            let (mut messages, mut bytes, mut alloc_bytes) = (0u64, 0u64, 0u64);
            let mut hit = true;
            for tr in lane.driver.drain_traces() {
                messages += tr.comm_messages();
                bytes += tr.comm_bytes();
                alloc_bytes += tr.alloc_bytes;
                hit &= tr.plan_cache_hit;
                self.sink.record(tr);
            }
            self.tenant_scratch.clear();
            for (id, data) in lane.driver.drain_completed() {
                let info = match lane.meta.remove(&id) {
                    Some(i) => i,
                    None => continue,
                };
                self.in_flight -= 1;
                let latency_ns = info.t0.elapsed().as_nanos() as u64;
                let t = &mut self.tenants[info.tenant];
                self.sink.record_tenant(t.metrics, latency_ns, info.bytes);
                t.completed.push((
                    id,
                    TenantSlot { data: Some(data), pool: Rc::clone(&t.pool) },
                ));
                self.tenant_scratch.push(info.tenant);
            }
            self.tenant_scratch.sort_unstable();
            self.tenant_scratch.dedup();
            self.flushes.push(FlushRecord {
                lane: *key,
                dir,
                jobs,
                tenants: self.tenant_scratch.len(),
                messages,
                bytes,
                alloc_bytes,
                plan_cache_hit: hit,
            });
            // steady-state: end
        }
        total
    }

    /// Take the tenant's completed `(sequence id, result)` pairs, in
    /// submission order. Dropping a returned slot recycles its storage
    /// into the tenant's pool and releases its quota charge.
    pub fn collect(&mut self, tenant: TenantId) -> Vec<(u64, TenantSlot)> {
        match self.tenants.get_mut(tenant.0) {
            Some(t) => std::mem::take(&mut t.completed),
            None => Vec::new(),
        }
    }

    /// Requests currently in flight across all lanes.
    pub fn pending(&self) -> usize {
        self.in_flight
    }

    /// Bytes currently charged against the tenant's quota (0 for unknown
    /// tenants).
    pub fn tenant_charged(&self, tenant: TenantId) -> usize {
        self.tenants.get(tenant.0).map_or(0, |t| t.pool.borrow().charged())
    }

    /// Bytes of slot storage ever allocated for the tenant — flat from
    /// the second flush on, once the pool's recycled buffers cover the
    /// working set (the steady-state contract, pinned by
    /// `tests/service.rs`).
    pub fn tenant_alloc_bytes(&self, tenant: TenantId) -> u64 {
        self.tenants.get(tenant.0).map_or(0, |t| t.pool_ctr.get())
    }

    /// The service's metrics sink: per-flush traces plus the per-tenant
    /// latency/throughput accounting.
    pub fn metrics(&self) -> &MetricsSink {
        &self.sink
    }

    /// One record per coalesced flush so far, in flush order.
    pub fn flush_records(&self) -> &[FlushRecord] {
        &self.flushes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::phased;
    use crate::fftb::plan::SlabPencilPlan;

    fn service(p: usize, comm: &crate::comm::communicator::Comm) -> TransformService {
        let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
        TransformService::new([8, 8, 8], grid, ServiceConfig::default()).unwrap()
    }

    #[test]
    fn coalesced_flush_routes_results_to_their_tenants() {
        let p = 2;
        let outs = run_world(p, move |comm| {
            let mut svc = service(p, &comm);
            let a = svc.register_tenant("a");
            let b = svc.register_tenant("b");
            let lane = svc.grid_lane();
            let backend = RustFftBackend::new();

            // a submits 2 bands, b submits 1 — interleaved, one flush.
            let mut inputs = Vec::new();
            for (t, seed) in [(a, 1u64), (b, 2), (a, 3)] {
                let mut slot = svc.checkout(t, lane, Direction::Forward).unwrap();
                let data = phased(slot.len(), seed);
                slot.data_mut().copy_from_slice(&data);
                inputs.push(data);
                svc.submit(t, lane, Direction::Forward, slot).unwrap();
            }
            assert_eq!(svc.pending(), 3);
            assert_eq!(svc.flush(&backend, Direction::Forward), 3);
            assert_eq!(svc.pending(), 0);

            // One coalesced record: 3 jobs, 2 distinct tenants.
            let rec = svc.flush_records().last().copied().unwrap();
            assert_eq!((rec.jobs, rec.tenants, rec.lane), (3, 2, GRID_LANE));

            // Results route per tenant, FIFO, and equal the single-band
            // plan bit-for-bit (bands transform independently).
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let single = SlabPencilPlan::new([8, 8, 8], 1, grid).unwrap();
            let got_a = svc.collect(a);
            let got_b = svc.collect(b);
            assert_eq!((got_a.len(), got_b.len()), (2, 1));
            assert_eq!((got_a[0].0, got_b[0].0, got_a[1].0), (0, 1, 2));
            let mut ok = true;
            for (slot, input) in
                [(&got_a[0].1, &inputs[0]), (&got_b[0].1, &inputs[1]), (&got_a[1].1, &inputs[2])]
            {
                let (want, _) = single.forward(&backend, input.clone());
                ok &= slot.data().len() == want.len()
                    && slot.data().iter().zip(&want).all(|(x, y)| {
                        x.re.to_bits() == y.re.to_bits() && x.im.to_bits() == y.im.to_bits()
                    });
            }
            // Per-tenant accounting saw the requests.
            let tm = svc.metrics().tenant_metrics();
            ok && tm[0].requests == 2 && tm[1].requests == 1 && tm[0].p95().is_some()
        });
        assert!(outs.iter().all(|&b| b));
    }

    #[test]
    fn quota_refuses_then_recovers_when_a_slot_drops() {
        run_world(1, |comm| {
            let mut svc = service(1, &comm);
            let lane = svc.grid_lane();
            let slot_bytes = svc.slot_bytes(lane).unwrap();
            // Room for exactly two slots.
            let t = svc.register_tenant_with_quota("tight", 2 * slot_bytes);
            let s1 = svc.checkout(t, lane, Direction::Forward).unwrap();
            let _s2 = svc.checkout(t, lane, Direction::Forward).unwrap();
            assert_eq!(svc.tenant_charged(t), 2 * slot_bytes);
            match svc.checkout(t, lane, Direction::Forward) {
                Err(ServiceError::QuotaExhausted { tenant, charged, quota, .. }) => {
                    assert_eq!(tenant, t.index());
                    assert_eq!(charged, 2 * slot_bytes);
                    assert_eq!(quota, 2 * slot_bytes);
                }
                other => panic!("expected QuotaExhausted, got {other:?}"),
            }
            // Dropping an outstanding slot frees its lease.
            drop(s1);
            assert_eq!(svc.tenant_charged(t), slot_bytes);
            assert!(svc.checkout(t, lane, Direction::Forward).is_ok());
        });
    }

    #[test]
    fn backlog_window_bounds_in_flight_requests() {
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm.clone()).unwrap();
            let config = ServiceConfig { max_in_flight: 1, ..Default::default() };
            let mut svc = TransformService::new([4, 4, 4], grid, config).unwrap();
            let t = svc.register_tenant("t");
            let lane = svc.grid_lane();
            let backend = RustFftBackend::new();
            let slot = svc.checkout(t, lane, Direction::Forward).unwrap();
            svc.submit(t, lane, Direction::Forward, slot).unwrap();
            let slot = svc.checkout(t, lane, Direction::Forward).unwrap();
            match svc.submit(t, lane, Direction::Forward, slot) {
                Err(ServiceError::Backlogged { pending: 1, limit: 1 }) => {}
                other => panic!("expected Backlogged, got {other:?}"),
            }
            // The refused submit released its slot back to the tenant:
            // nothing leaked, and after a flush the window reopens.
            assert_eq!(svc.tenant_charged(t), svc.slot_bytes(lane).unwrap());
            svc.flush(&backend, Direction::Forward);
            assert_eq!(svc.pending(), 0);
            let slot = svc.checkout(t, lane, Direction::Forward).unwrap();
            assert!(svc.submit(t, lane, Direction::Forward, slot).is_ok());
        });
    }

    #[test]
    fn malformed_submits_are_typed_rejections() {
        run_world(1, |comm| {
            let mut svc = service(1, &comm);
            let t = svc.register_tenant("t");
            let lane = svc.grid_lane();
            assert!(matches!(
                svc.checkout(TenantId(9), lane, Direction::Forward),
                Err(ServiceError::UnknownTenant { tenant: 9 })
            ));
            assert!(matches!(
                svc.checkout(t, 77, Direction::Forward),
                Err(ServiceError::UnknownLane { lane: 77 })
            ));
            // A short payload is rejected before it touches the driver.
            // (Both cube sides are 512 on one rank, so hand-build the
            // mismatched slot — the fields are crate-visible.)
            let pool = Rc::new(RefCell::new(SlotPool::default()));
            let short = TenantSlot { data: Some(vec![crate::fft::complex::ZERO; 64]), pool };
            let e = svc.submit(t, lane, Direction::Forward, short);
            assert!(matches!(e, Err(ServiceError::WrongLength { expected: 512, got: 64 })));
        });
    }

    #[test]
    fn steady_state_flushes_are_allocation_free_per_tenant() {
        let p = 2;
        run_world(p, move |comm| {
            let mut svc = service(p, &comm);
            let t = svc.register_tenant("hot");
            let lane = svc.grid_lane();
            let backend = RustFftBackend::new();
            let mut after_first = 0;
            for round in 0..4u64 {
                for b in 0..2u64 {
                    let mut slot = svc.checkout(t, lane, Direction::Forward).unwrap();
                    let data = phased(slot.len(), 10 * round + b);
                    slot.data_mut().copy_from_slice(&data);
                    svc.submit(t, lane, Direction::Forward, slot).unwrap();
                }
                svc.flush(&backend, Direction::Forward);
                // Dropping the collected slots restocks the pool.
                drop(svc.collect(t));
                if round == 0 {
                    after_first = svc.tenant_alloc_bytes(t);
                    assert!(after_first > 0, "first round mints the working set");
                } else {
                    assert_eq!(
                        svc.tenant_alloc_bytes(t),
                        after_first,
                        "round {round} must run out of recycled slots"
                    );
                    let rec = svc.flush_records().last().unwrap();
                    assert!(rec.plan_cache_hit, "round {round} must hit the plan cache");
                    assert_eq!(rec.alloc_bytes, 0, "round {round} workspace must be warm");
                }
            }
            assert_eq!(svc.tenant_charged(t), 0, "all leases returned");
        });
    }
}
