//! `pallas-lint` — repo-specific static analysis for the invariants
//! rustc and clippy cannot see.
//!
//! The comm layer is threads-as-ranks with hand-rolled mailboxes, atomics,
//! a shared `BufferArena`, and unsafe byte casts on the wire path; the plan
//! execute paths promise zero steady-state allocation
//! (`ExecTrace::alloc_bytes == 0`). Those contracts are enforced by
//! machine, not review: the `pallas-lint` binary (`cargo run --bin
//! pallas-lint`) walks `rust/src/` and fails CI on any violation of the
//! four rules in [`rules`]:
//!
//! 1. `safety-comment` — every `unsafe` carries an adjacent `SAFETY:`
//!    comment.
//! 2. `atomic-ordering` — `Ordering::Relaxed` only on the allowlisted
//!    statistics counters ([`RELAXED_COUNTERS`]); synchronizing orderings
//!    state why.
//! 3. `steady-state-alloc` — no allocating calls inside annotated
//!    steady-state regions of the plan execute paths.
//! 4. `no-panic` — library code returns `FftbError` instead of
//!    panicking.
//!
//! Exceptions are explicit and diff-visible: a comment of the form
//! `pallas-lint: allow(<rule>)` on the offending line (or in the comment
//! block directly above it) silences that rule for that line, and should
//! always state the invariant that makes the exception sound.
#![warn(missing_docs)]

pub mod rules;
pub mod scanner;

use std::io;
use std::path::{Path, PathBuf};

pub use rules::{check_source, Diagnostic, FileKind, RELAXED_COUNTERS};

/// The outcome of linting a source tree.
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// All findings, ordered by file then line.
    pub diagnostics: Vec<Diagnostic>,
}

/// How a path is linted: bin targets (`src/bin/`, `src/main.rs`) and test
/// utilities may abort on bad input, so the `no-panic` rule is skipped
/// there; everything else is library code.
pub fn classify(path: &Path) -> FileKind {
    let p = path.to_string_lossy().replace('\\', "/");
    if p.ends_with("/main.rs") || p.contains("/bin/") || p.ends_with("testutil.rs") {
        FileKind::Binary
    } else {
        FileKind::Library
    }
}

/// Lint every `.rs` file under `root` (a file path is linted directly).
/// Diagnostics come back sorted by file then line; I/O errors (unreadable
/// directories, non-UTF-8 sources) abort the walk.
pub fn lint_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let n = files.len();
    let mut diagnostics = Vec::new();
    for path in files {
        let source = std::fs::read_to_string(&path)?;
        let label = path.to_string_lossy().into_owned();
        diagnostics.extend(check_source(&label, &source, classify(&path)));
    }
    Ok(Report { files: n, diagnostics })
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if path.is_dir() {
        for entry in std::fs::read_dir(path)? {
            collect_rs(&entry?.path(), out)?;
        }
    } else if matches!(path.extension(), Some(e) if e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn classification_exempts_bins_and_testutil() {
        assert_eq!(classify(Path::new("src/bin/pallas-lint.rs")), FileKind::Binary);
        assert_eq!(classify(Path::new("src/main.rs")), FileKind::Binary);
        assert_eq!(classify(Path::new("src/fftb/plan/testutil.rs")), FileKind::Binary);
        assert_eq!(classify(Path::new("src/comm/mailbox.rs")), FileKind::Library);
    }

    #[test]
    fn the_crate_lints_clean() {
        // The acceptance gate CI enforces, in-process: the whole tree under
        // `src/` must carry zero findings.
        let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
        let report = lint_tree(root).expect("src/ tree is readable");
        let rendered: Vec<String> =
            report.diagnostics.iter().map(|d| d.to_string()).collect();
        assert!(rendered.is_empty(), "pallas-lint findings:\n{}", rendered.join("\n"));
        assert!(report.files > 30, "expected to scan the full src tree");
    }
}
