//! A tiny lexical scanner: splits each source line into *code* and
//! *comment* text, with string and character literal contents blanked.
//!
//! The lint rules in [`crate::lint::rules`] are substring matchers; running
//! them over raw source would trip on tokens inside string literals, doc
//! comments, or commented-out code. The scanner removes exactly that noise
//! while keeping line numbers stable: rules see `code` (literal contents
//! dropped, comments stripped) and `comment` (the text of `//` and
//! `/* .. */` comments) per line.
//!
//! This is deliberately not a Rust parser. It understands just enough of
//! the lexical grammar — nested block comments, escapes, raw strings,
//! char literals vs lifetimes — to classify every byte as code, comment,
//! or literal content.

/// One source line after scanning.
#[derive(Clone, Debug, Default)]
pub struct Line {
    /// 1-based line number in the file.
    pub number: usize,
    /// Code text: comments removed, string/char literal contents blanked
    /// (the delimiting quotes are kept).
    pub code: String,
    /// Concatenated comment text on this line, without the `//`, `/*`,
    /// `*/` markers. Empty when the line has no comment.
    pub comment: String,
}

impl Line {
    /// True when the line holds comment text and no code.
    pub fn is_comment_only(&self) -> bool {
        self.code.trim().is_empty() && !self.comment.trim().is_empty()
    }
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    Str,
    RawStr(usize),
    Block(usize),
}

/// Scan `source` into the per-line code/comment split described on
/// [`Line`]. Literal contents never reach `code`; comment text never
/// reaches `code`; code never reaches `comment`.
pub fn scan(source: &str) -> Vec<Line> {
    let chars: Vec<char> = source.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line { number: 1, ..Line::default() };
    let mut state = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            let number = cur.number;
            lines.push(std::mem::take(&mut cur));
            cur.number = number + 1;
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    // `//`, `///` and `//!` all count as comment text.
                    i += 2;
                    while i < chars.len() && chars[i] != '\n' {
                        cur.comment.push(chars[i]);
                        i += 1;
                    }
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    state = match raw_hashes(&cur.code) {
                        Some(h) => State::RawStr(h),
                        None => State::Str,
                    };
                    cur.code.push('"');
                    i += 1;
                } else if c == '\'' && is_char_literal(&chars, i) {
                    cur.code.push('\'');
                    i += 1;
                    while i < chars.len() && chars[i] != '\n' {
                        if chars[i] == '\\' {
                            i += 2;
                        } else if chars[i] == '\'' {
                            cur.code.push('\'');
                            i += 1;
                            break;
                        } else {
                            i += 1;
                        }
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Keep a trailing `\` + newline (line continuation)
                    // visible to the newline handler so counts stay right.
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else {
                    if c == '"' {
                        cur.code.push('"');
                        state = State::Code;
                    }
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    cur.code.push('"');
                    i += 1 + hashes;
                    state = State::Code;
                } else {
                    i += 1;
                }
            }
            State::Block(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// `Some(hashes)` when the code scanned so far ends with a raw-string
/// opener (`r`, `br`, `r#`, `br##`, ...) for the `"` about to be consumed.
fn raw_hashes(code: &str) -> Option<usize> {
    let chars: Vec<char> = code.chars().collect();
    let mut k = chars.len();
    let mut hashes = 0;
    while k > 0 && chars[k - 1] == '#' {
        hashes += 1;
        k -= 1;
    }
    if k == 0 || chars[k - 1] != 'r' {
        return None;
    }
    k -= 1;
    if k > 0 && chars[k - 1] == 'b' {
        k -= 1;
    }
    // `var"` or `faster"` is not a raw string; a bare `r`/`br` prefix is.
    let ident_before = k > 0 && (chars[k - 1].is_alphanumeric() || chars[k - 1] == '_');
    if ident_before {
        None
    } else {
        Some(hashes)
    }
}

fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Distinguish `'a'` / `'\n'` (char literal) from `'a` (lifetime/label).
fn is_char_literal(chars: &[char], i: usize) -> bool {
    match (chars.get(i + 1), chars.get(i + 2)) {
        (Some('\\'), _) => true,
        (Some(_), Some('\'')) => true,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_comments_into_comment_text() {
        let lines = scan("let x = 1; // trailing note\n// full-line note\n");
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].code.trim(), "let x = 1;");
        assert_eq!(lines[0].comment.trim(), "trailing note");
        assert!(lines[1].is_comment_only());
        assert_eq!(lines[1].comment.trim(), "full-line note");
    }

    #[test]
    fn blanks_string_contents_but_keeps_quotes() {
        let lines = scan("let s = \"unsafe // not a comment\";\n");
        assert_eq!(lines[0].code, "let s = \"\";");
        assert!(lines[0].comment.is_empty());
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        let lines = scan("p.expect(b'\"')?;\nlet q: &'static str = \"x\";\n");
        assert_eq!(lines[0].code, "p.expect(b'')?;");
        assert_eq!(lines[1].code, "let q: &'static str = \"\";");
    }

    #[test]
    fn lifetimes_stay_in_code() {
        let lines = scan("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert_eq!(lines[0].code, "fn f<'a>(x: &'a str) -> &'a str { x }");
    }

    #[test]
    fn block_comments_span_lines() {
        let lines = scan("a /* one\ntwo */ b\n");
        assert_eq!(lines[0].code.trim(), "a");
        assert_eq!(lines[0].comment.trim(), "one");
        assert_eq!(lines[1].code.trim(), "b");
        assert_eq!(lines[1].comment.trim(), "two");
    }

    #[test]
    fn raw_strings_blank_their_contents() {
        let lines = scan("let j = r#\"{\"k\": \"unsafe\"}\"#;\n");
        assert_eq!(lines[0].code, "let j = r#\"\";");
    }

    #[test]
    fn multiline_strings_keep_line_numbers() {
        let lines = scan("let s = \"one\ntwo\";\nlet t = 3;\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].number, 3);
        assert_eq!(lines[2].code, "let t = 3;");
    }
}
