//! The four `pallas-lint` rules.
//!
//! Each rule walks the scanned lines of one file (see
//! [`crate::lint::scanner`]) and reports [`Diagnostic`]s. `#[cfg(test)]`
//! regions are exempt from every rule, and any finding can be silenced by
//! an explicit allow comment — on the offending line or in the comment
//! block directly above it — so exceptions stay visible in diffs:
//!
//! ```text
//! // pallas-lint: allow(no-panic) — reason the invariant holds
//! ```
//!
//! The rules:
//!
//! * `safety-comment` — every line whose code mentions `unsafe` must carry
//!   a `SAFETY:` comment on the line or in the comment block directly
//!   above it.
//! * `atomic-ordering` — `Ordering::Relaxed` may only touch statistics
//!   counters named in [`RELAXED_COUNTERS`]; synchronizing orderings
//!   (`Acquire`/`Release`/`AcqRel`/`SeqCst`) must carry a rationale
//!   comment.
//! * `steady-state-alloc` — no allocating calls inside a region opened by
//!   a comment beginning `steady-state:` and closed by one reading
//!   `steady-state: end` — the static complement of the dynamic
//!   `ExecTrace::alloc_bytes == 0` pin on the plan execute paths.
//! * `no-panic` — no `unwrap()` / `expect("..")` / `panic!`-family macros
//!   in library code (binaries and test utilities are exempt, as are the
//!   mutex-poisoning and infallible `try_into` idioms — see
//!   [`check_source`]).

use std::fmt;

use super::scanner::{scan, Line};

/// Rule id: `unsafe` requires an adjacent `SAFETY:` comment.
pub const RULE_SAFETY: &str = "safety-comment";
/// Rule id: atomic-ordering discipline (allowlisted `Relaxed` counters,
/// rationale comments on synchronizing orderings).
pub const RULE_ATOMIC: &str = "atomic-ordering";
/// Rule id: no allocating calls inside steady-state regions.
pub const RULE_STEADY: &str = "steady-state-alloc";
/// Rule id: no panicking calls in library code.
pub const RULE_NO_PANIC: &str = "no-panic";

/// Statistics counters that may legitimately use `Ordering::Relaxed`: each
/// is a monotone tally read only for reporting, never to synchronize state
/// (no other memory access is ordered against it). Everything else must
/// use a synchronizing ordering and say why.
pub const RELAXED_COUNTERS: &[&str] = &[
    // comm::CommStats traffic tallies.
    "messages",
    "bytes",
    // comm::BufferArena reuse tallies.
    "minted",
    "reused",
    // runtime::backend dispatch tallies.
    "pjrt_lines",
    "fallback_lines",
    // comm schedule-perturbation ticket: fetch_add atomicity alone
    // guarantees distinct tickets; nothing is published through it.
    "perturb_ticket",
    // comm::worker busy-time tally: written by the worker thread, read by
    // harvesters for trace attribution only; the jobs' effects are ordered
    // by their own response channels, never by this counter.
    "busy_ns",
];

/// One lint finding, formatted `file:line: [rule] message`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Path of the offending file, as given to [`check_source`].
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (one of the `RULE_*` constants).
    pub rule: &'static str,
    /// Human-readable description of the finding.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// How a file is linted: `Binary` (bin targets, test utilities) skips the
/// `no-panic` rule — a CLI aborting on bad input is fine; library code
/// must surface `FftbError` instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileKind {
    /// Library code: all four rules apply.
    Library,
    /// Binary / test-utility code: `no-panic` is skipped.
    Binary,
}

/// Run every rule over one file's source. `file` is only used to label
/// diagnostics.
pub fn check_source(file: &str, source: &str, kind: FileKind) -> Vec<Diagnostic> {
    let lines = scan(source);
    let in_test = test_mask(&lines);
    let mut out = Vec::new();
    check_safety(file, &lines, &in_test, &mut out);
    check_atomics(file, &lines, &in_test, &mut out);
    check_steady_state(file, &lines, &in_test, &mut out);
    if kind == FileKind::Library {
        check_no_panic(file, &lines, &in_test, &mut out);
    }
    out.sort_by_key(|d| d.line);
    out
}

/// Mark the lines inside `#[cfg(test)]`-gated item bodies. The attribute
/// arms the tracker; the next `{` opens the exempt region, the matching
/// `}` closes it. Test modules in this tree are always brace-delimited.
fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut test_exit: Option<i64> = None;
    for (idx, line) in lines.iter().enumerate() {
        if test_exit.is_some() {
            mask[idx] = true;
        }
        if line.code.contains("#[cfg(test)]") {
            armed = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    if armed {
                        test_exit = Some(depth);
                        armed = false;
                        mask[idx] = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if test_exit == Some(depth) {
                        test_exit = None;
                    }
                }
                _ => {}
            }
        }
    }
    mask
}

fn allowed(lines: &[Line], idx: usize, rule: &str) -> bool {
    let token = format!("pallas-lint: allow({rule})");
    if lines[idx].comment.contains(&token) {
        return true;
    }
    let mut j = idx;
    while j > 0 && lines[j - 1].is_comment_only() {
        j -= 1;
        if lines[j].comment.contains(&token) {
            return true;
        }
    }
    false
}

/// Concatenated text of the contiguous comment-only lines directly above
/// line `idx` (empty when the preceding line holds code or is blank).
fn comment_block_above(lines: &[Line], idx: usize) -> String {
    let mut j = idx;
    while j > 0 && lines[j - 1].is_comment_only() {
        j -= 1;
    }
    lines[j..idx].iter().map(|l| l.comment.as_str()).collect::<Vec<_>>().join("\n")
}

/// Substring match of an ASCII `word` with identifier boundaries on both
/// sides.
fn contains_word(text: &str, word: &str) -> bool {
    let bytes = text.as_bytes();
    let mut start = 0;
    while let Some(pos) = text[start..].find(word) {
        let begin = start + pos;
        let end = begin + word.len();
        let left_ok = begin == 0 || !is_ident(bytes[begin - 1]);
        let right_ok = end == bytes.len() || !is_ident(bytes[end]);
        if left_ok && right_ok {
            return true;
        }
        start = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn check_safety(file: &str, lines: &[Line], in_test: &[bool], out: &mut Vec<Diagnostic>) {
    for (idx, line) in lines.iter().enumerate() {
        if in_test[idx] || !contains_word(&line.code, "unsafe") {
            continue;
        }
        if allowed(lines, idx, RULE_SAFETY) {
            continue;
        }
        let covered = line.comment.contains("SAFETY:")
            || comment_block_above(lines, idx).contains("SAFETY:");
        if !covered {
            out.push(Diagnostic {
                file: file.into(),
                line: line.number,
                rule: RULE_SAFETY,
                message: "`unsafe` without an immediately preceding `SAFETY:` comment".into(),
            });
        }
    }
}

fn check_atomics(file: &str, lines: &[Line], in_test: &[bool], out: &mut Vec<Diagnostic>) {
    const SYNC: [&str; 4] =
        ["Ordering::SeqCst", "Ordering::Acquire", "Ordering::Release", "Ordering::AcqRel"];
    for (idx, line) in lines.iter().enumerate() {
        if in_test[idx] {
            continue;
        }
        if line.code.contains("Ordering::Relaxed") && !allowed(lines, idx, RULE_ATOMIC) {
            // The counter name usually sits on the same line
            // (`self.minted.fetch_add(..)`), but rustfmt may break the
            // chain — accept it up to two lines above.
            let lo = idx.saturating_sub(2);
            let ctx =
                lines[lo..=idx].iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
            if !RELAXED_COUNTERS.iter().any(|name| contains_word(&ctx, name)) {
                out.push(Diagnostic {
                    file: file.into(),
                    line: line.number,
                    rule: RULE_ATOMIC,
                    message: "`Ordering::Relaxed` outside the statistics-counter allowlist"
                        .into(),
                });
            }
        }
        if SYNC.iter().any(|s| line.code.contains(s)) && !allowed(lines, idx, RULE_ATOMIC) {
            let has_rationale = !line.comment.trim().is_empty()
                || !comment_block_above(lines, idx).trim().is_empty();
            if !has_rationale {
                out.push(Diagnostic {
                    file: file.into(),
                    line: line.number,
                    rule: RULE_ATOMIC,
                    message: "synchronizing atomic ordering without a rationale comment".into(),
                });
            }
        }
    }
}

fn check_steady_state(file: &str, lines: &[Line], in_test: &[bool], out: &mut Vec<Diagnostic>) {
    const BANNED: &[&str] = &[
        "Vec::new",
        "vec!",
        ".to_vec",
        ".clone()",
        "Box::new",
        "with_capacity",
        "String::new",
        ".to_string",
        "format!",
        ".collect",
        ".reserve",
    ];
    let mut open: Option<usize> = None;
    for (idx, line) in lines.iter().enumerate() {
        let marker = line.comment.trim();
        if let Some(rest) = marker.strip_prefix("steady-state:") {
            if rest.trim() == "end" {
                if open.take().is_none() {
                    out.push(Diagnostic {
                        file: file.into(),
                        line: line.number,
                        rule: RULE_STEADY,
                        message: "region end marker without an open steady-state region".into(),
                    });
                }
            } else if open.is_some() {
                out.push(Diagnostic {
                    file: file.into(),
                    line: line.number,
                    rule: RULE_STEADY,
                    message: "nested steady-state region".into(),
                });
            } else {
                open = Some(line.number);
            }
            continue;
        }
        if open.is_none() || in_test[idx] || allowed(lines, idx, RULE_STEADY) {
            continue;
        }
        if let Some(tok) = BANNED.iter().find(|t| line.code.contains(**t)) {
            out.push(Diagnostic {
                file: file.into(),
                line: line.number,
                rule: RULE_STEADY,
                message: format!("allocating call `{tok}` inside a steady-state region"),
            });
        }
    }
    if let Some(n) = open {
        out.push(Diagnostic {
            file: file.into(),
            line: n,
            rule: RULE_STEADY,
            message: "steady-state region never closed".into(),
        });
    }
}

fn check_no_panic(file: &str, lines: &[Line], in_test: &[bool], out: &mut Vec<Diagnostic>) {
    const MACROS: [&str; 4] = ["panic!(", "unreachable!(", "todo!(", "unimplemented!("];
    for (idx, line) in lines.iter().enumerate() {
        if in_test[idx] || allowed(lines, idx, RULE_NO_PANIC) {
            continue;
        }
        let code = line.code.as_str();
        let mut finding: Option<&str> = MACROS.iter().find(|m| code.contains(**m)).copied();
        if finding.is_none() && code.contains(".expect(\"") {
            finding = Some(".expect(..)");
        }
        if finding.is_none() && has_bare_unwrap(code) {
            finding = Some(".unwrap()");
        }
        if let Some(tok) = finding {
            out.push(Diagnostic {
                file: file.into(),
                line: line.number,
                rule: RULE_NO_PANIC,
                message: format!(
                    "`{tok}` in library code — return `FftbError`, or add an allow \
                     comment stating the invariant"
                ),
            });
        }
    }
}

/// `.unwrap()` occurrences that are neither the mutex-poisoning idiom
/// (`.lock()`, Condvar `.wait(..)`, `.into_inner()` — propagating a
/// poisoned lock is the only sane response) nor the infallible
/// `from_le_bytes(buf.try_into().unwrap())` fixed-width conversion.
fn has_bare_unwrap(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(".unwrap()") {
        let at = start + pos;
        let before = &code[..at];
        let poisoning = before.ends_with(".lock()")
            || before.ends_with(".into_inner()")
            || (before.ends_with(')') && before.contains(".wait("));
        let infallible = code.contains("from_le_bytes") && before.ends_with(".try_into()");
        if !poisoning && !infallible {
            return true;
        }
        start = at + ".unwrap()".len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(src: &str) -> Vec<Diagnostic> {
        check_source("fixture.rs", src, FileKind::Library)
    }

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let d = lint(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_SAFETY);
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn safety_comment_clears_the_unsafe_rule() {
        let src =
            "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller upholds validity.\n    unsafe { *p }\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn unsafe_in_strings_and_comments_is_ignored() {
        let src = "// unsafe is discussed here, not used\nfn f() -> &'static str {\n    \"unsafe\"\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn relaxed_outside_the_allowlist_is_flagged() {
        let src = "fn f(flag: &AtomicU64) -> u64 {\n    flag.load(Ordering::Relaxed)\n}\n";
        let d = lint(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_ATOMIC);
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn relaxed_on_an_allowlisted_counter_passes() {
        let src = "fn f(s: &Stats) {\n    s.minted.fetch_add(1, Ordering::Relaxed);\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn seqcst_requires_a_rationale_comment() {
        let bare = "fn f(x: &AtomicU64) -> u64 {\n    x.fetch_add(1, Ordering::SeqCst)\n}\n";
        let d = lint(bare);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_ATOMIC);

        let justified = "fn f(x: &AtomicU64) -> u64 {\n    // Total order across ranks.\n    x.fetch_add(1, Ordering::SeqCst)\n}\n";
        assert!(lint(justified).is_empty());
    }

    #[test]
    fn steady_state_region_rejects_allocation() {
        let src = "fn run() {\n    // steady-state: fixture\n    let v: Vec<u8> = Vec::new();\n    // steady-state: end\n}\n";
        let d = lint(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_STEADY);
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn allocation_outside_a_region_passes() {
        let src = "fn setup() {\n    let v: Vec<u8> = Vec::new();\n    drop(v);\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn unclosed_steady_state_region_is_flagged() {
        let src = "fn run() {\n    // steady-state: fixture\n    step();\n}\n";
        let d = lint(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_STEADY);
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn unwrap_in_library_code_is_flagged() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        let d = lint(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_NO_PANIC);
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn expect_and_panic_macros_are_flagged() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.expect(\"present\")\n}\n";
        assert_eq!(lint(src).len(), 1);
        let src = "fn g() {\n    panic!(\"boom\");\n}\n";
        assert_eq!(lint(src).len(), 1);
    }

    #[test]
    fn poisoning_and_le_bytes_idioms_are_exempt() {
        let src = "fn f(m: &Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n";
        assert!(lint(src).is_empty());
        let src = "fn g(b: &[u8]) -> u64 {\n    u64::from_le_bytes(b[0..8].try_into().unwrap())\n}\n";
        assert!(lint(src).is_empty());
        let src = "fn h(m: Mutex<u8>) -> u8 {\n    m.into_inner().unwrap()\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn allow_comment_suppresses_a_rule() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    // pallas-lint: allow(no-panic) — fixture invariant\n    x.unwrap()\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn test_modules_are_exempt_from_all_rules() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u8>) -> u8 { x.unwrap() }\n}\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn code_after_a_test_module_is_linted_again() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u8>) -> u8 { x.unwrap() }\n}\nfn g(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        let d = lint(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 6);
    }

    #[test]
    fn binary_files_may_panic() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        assert!(check_source("fixture.rs", src, FileKind::Binary).is_empty());
    }
}
