//! `fftb` — CLI launcher for the FFTB-rs distributed FFT framework.
//!
//! Subcommands (hand-rolled parsing: the offline dependency set has no clap):
//!
//! ```text
//! fftb info                              # artifact manifest + capability table
//! fftb transform [--n N] [--nb B] [--p P] [--sphere R] [--pjrt] [--iters K]
//! fftb dft [--n N] [--bands B] [--p P] [--ecut E] [--iters K]
//! fftb fig9 [--live-p P] [--live-n N] [--live-nb B]
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::dft::{solve_bands, EigenOptions, GaussianWells, Hamiltonian, Lattice};
use fftb::fftb::backend::{LocalFftBackend, RustFftBackend};
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{ExecTrace, PlaneWavePlan, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{fig9_row, Machine, Variant, Workload};
use fftb::runtime::{PjrtFftBackend, PjrtRuntime};
use fftb::util::stats;

/// Minimal `--key value` / `--flag` parser.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn make_backend(use_pjrt: bool) -> Arc<dyn LocalFftBackend> {
    if use_pjrt {
        match PjrtRuntime::open("artifacts") {
            Ok(rt) => {
                eprintln!("backend: pjrt-pallas (artifacts/)");
                return Arc::new(PjrtFftBackend::new(Arc::new(rt)));
            }
            Err(e) => eprintln!("warning: PJRT unavailable ({e:#}); falling back to rust"),
        }
    }
    eprintln!("backend: rust-stockham");
    Arc::new(RustFftBackend::new())
}

fn cmd_info() {
    println!("FFTB-rs — flexible distributed FFTs for plane-wave DFT codes");
    println!();
    println!("Capability matrix (paper Table 1, FFTB row):");
    println!("  transform type : CtoC (forward + inverse)");
    println!("  input/output   : cuboid grids AND cut-off spheres (CSR offsets)");
    println!("  processing grid: 1D (slab-pencil), 2D (pencil), 3D (folded pencil)");
    println!("  batching       : batched alltoalls or per-band loop");
    println!();
    match PjrtRuntime::open("artifacts") {
        Ok(rt) => {
            println!(
                "artifacts: {} entries, batch tile {}",
                rt.manifest().entries.len(),
                rt.manifest().batch
            );
            println!("  fft line sizes: {:?}", rt.manifest().fft_sizes());
        }
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
}

fn print_trace(label: &str, trace: &ExecTrace) {
    println!("--- {label} ---");
    print!("{}", trace.summary());
    println!(
        "total {:?}  comm {} B in {} msgs",
        trace.total_time(),
        trace.comm_bytes(),
        trace.comm_messages()
    );
}

fn cmd_transform(args: &Args) {
    let n: usize = args.get("n", 64);
    let nb: usize = args.get("nb", 4);
    let p: usize = args.get("p", 4);
    let iters: usize = args.get("iters", 3);
    let sphere_r: f64 = args.get("sphere", 0.0);
    let backend = make_backend(args.has("pjrt"));

    if sphere_r > 0.0 {
        println!("plane-wave transform: sphere r={sphere_r} in {n}^3, nb={nb}, p={p}");
        let spec = SphereSpec::new([n, n, n], sphere_r, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        println!(
            "sphere: {} points ({:.1}% of cube), disc {} columns",
            off.total(),
            100.0 * off.total() as f64 / (n * n * n) as f64,
            off.disc_columns().len()
        );
        let backend = Arc::clone(&backend);
        let traces = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
            let input = phased(plan.input_len(), grid.rank() as u64);
            let mut last = None;
            for _ in 0..iters {
                let (_, tr) = plan.forward(backend.as_ref(), input.clone());
                last = Some(tr);
            }
            last.unwrap()
        });
        print_trace("plane-wave forward (slowest rank)", &ExecTrace::critical_path(&traces));
    } else {
        println!("cuboid transform: {n}^3, nb={nb}, p={p} (slab-pencil)");
        let backend = Arc::clone(&backend);
        let traces = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let input = phased(plan.input_len(), grid.rank() as u64);
            let mut last = None;
            for _ in 0..iters {
                let (spec, tr1) = plan.forward(backend.as_ref(), input.clone());
                let (_, _tr2) = plan.inverse(backend.as_ref(), spec);
                last = Some(tr1);
            }
            last.unwrap()
        });
        print_trace("forward (slowest rank)", &ExecTrace::critical_path(&traces));
    }
}

fn cmd_dft(args: &Args) {
    let n: usize = args.get("n", 16);
    let nb: usize = args.get("bands", 4);
    let p: usize = args.get("p", 2);
    let ecut: f64 = args.get("ecut", 3.0);
    let iters: usize = args.get("iters", 150);
    let backend = make_backend(args.has("pjrt"));

    println!("mini plane-wave DFT: grid {n}^3, ecut={ecut}, {nb} bands, {p} ranks");
    let results = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
        let lat = Lattice::new(10.0, n, ecut);
        let npw = lat.n_pw();
        let h = Hamiltonian::new(lat, nb, &GaussianWells::dimer(2.5, 1.2, 0.3), grid);
        let mut psi =
            fftb::util::prng::Prng::new(7 + comm.rank() as u64).complex_vec(nb * h.n_local());
        let res = solve_bands(
            &h,
            backend.as_ref(),
            &comm,
            &mut psi,
            &EigenOptions { max_iters: iters, tol: 1e-6, ..Default::default() },
        );
        let density = fftb::dft::build_density(&h, backend.as_ref(), &comm, &psi);
        (res, npw, density.charge)
    });
    let (res, npw, charge) = &results[0];
    println!("plane waves: {npw}");
    println!("iterations : {}", res.iterations);
    println!("charge     : {charge:.6} (expect {nb})");
    for (b, (ev, rn)) in res.eigenvalues.iter().zip(&res.residuals).enumerate() {
        println!("  band {b}: eps = {ev:+.6}  |r| = {rn:.2e}");
    }
}

fn cmd_fig9(args: &Args) {
    let live_p: usize = args.get("live-p", 8);
    let live_n: usize = args.get("live-n", 32);
    let live_nb: usize = args.get("live-nb", 8);

    println!("# Fig. 9 — strong scaling, live (reduced size) + modeled (paper scale)");
    println!("## live: cube {live_n}^3, nb={live_nb}, sphere d={}", live_n / 2);
    let mut p = 1;
    while p <= live_p {
        let row = live_row(live_n, live_nb, p);
        println!(
            "p={p:>3}  slab-b {:>10}  slab-nb {:>10}  pw {:>10}",
            stats::fmt_duration(row.0),
            stats::fmt_duration(row.1),
            stats::fmt_duration(row.2)
        );
        p *= 2;
    }

    println!("## modeled: cube 256^3, nb=256, sphere d=128, perlmutter-a100");
    let spec = SphereSpec::new([256, 256, 256], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [256, 256, 256], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();
    println!("p, {}", Variant::all().map(|v| v.label()).join(", "));
    let mut p = 4;
    while p <= 1024 {
        let row = fig9_row(&w, p, &m);
        println!(
            "{p}, {}",
            row.iter().map(|t| format!("{t:.4}")).collect::<Vec<_>>().join(", ")
        );
        p *= 2;
    }
}

/// One live Fig. 9 row: (slab batched, slab non-batched, plane-wave).
fn live_row(
    n: usize,
    nb: usize,
    p: usize,
) -> (std::time::Duration, std::time::Duration, std::time::Duration) {
    use fftb::fftb::plan::NonBatchedLoop;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    let times = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let slab = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
        let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
        let pw = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();

        let input = phased(slab.input_len(), 3);
        let s1 = fftb::util::stats::bench(1, 3, || {
            let _ = slab.forward(&backend, input.clone());
        });
        let s2 = fftb::util::stats::bench(1, 2, || {
            let _ = looped.forward(&backend, input.clone());
        });
        let pw_in = phased(pw.input_len(), 4);
        let s3 = fftb::util::stats::bench(1, 3, || {
            let _ = pw.forward(&backend, pw_in.clone());
        });
        (s1.mean(), s2.mean(), s3.mean())
    });
    (
        times.iter().map(|t| t.0).max().unwrap(),
        times.iter().map(|t| t.1).max().unwrap(),
        times.iter().map(|t| t.2).max().unwrap(),
    )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("info");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "info" => cmd_info(),
        "transform" => cmd_transform(&args),
        "dft" => cmd_dft(&args),
        "fig9" => cmd_fig9(&args),
        other => {
            eprintln!("unknown subcommand `{other}`");
            eprintln!("usage: fftb <info|transform|dft|fig9> [--flags]");
            std::process::exit(2);
        }
    }
}
