//! L3 service layer: a leader that coalesces transform requests into
//! batched executions (the paper's batching contribution as a service),
//! plus metrics collection for the benches.
//!
//! A DFT code's CG iteration produces many band-block transform requests;
//! `BatchingDriver` is the component that aggregates them so every
//! communication stage runs once per *batch*, not once per band — the
//! difference between the dark- and light-blue lines of Fig. 9.
#![warn(missing_docs)]

pub mod driver;
pub mod metrics;

pub use driver::{BatchingDriver, TransformJob};
pub use metrics::{LatencyReservoir, MetricsSink, TenantMetrics};
